"""AOT pipeline tests: HLO text emission, artifact layout, L2 efficiency.

These run the lowering in-process (no files needed beyond a tmpdir), so
they also serve as the L2 "no redundant recomputation" check from
DESIGN.md SS8: the fused train_step must contain exactly one convolution
chain forward + its transpose, and lowering must produce parseable HLO
text whose entry signature matches the meta the rust loader relies on.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M


class TestHloText:
    def test_hlo_text_shape_signature(self):
        spec = M.get_model("mlp")
        n = M.param_count(spec)
        w = jax.ShapeDtypeStruct((n,), jnp.float32)
        x = jax.ShapeDtypeStruct((8, *spec.input_shape), jnp.float32)
        y = jax.ShapeDtypeStruct((8,), jnp.int32)
        txt = aot.to_hlo_text(jax.jit(M.make_train_step(spec)).lower(w, x, y))
        assert "HloModule" in txt
        assert f"f32[{n}]" in txt  # weight parameter and gradient output
        assert "s32[8]" in txt  # labels

    def test_train_step_single_forward(self):
        """The fwd+bwd lowering must not duplicate the forward pass: for
        tiny_cnn (2 convs) expect exactly 2 forward convolutions plus
        their backward (input- and weight-grad) counterparts — i.e. the
        HLO convolution count is bounded by 3x the forward count, not 2x
        that (which would indicate recomputation)."""
        spec = M.get_model("tiny_cnn")
        n = M.param_count(spec)
        w = jax.ShapeDtypeStruct((n,), jnp.float32)
        x = jax.ShapeDtypeStruct((8, *spec.input_shape), jnp.float32)
        y = jax.ShapeDtypeStruct((8,), jnp.int32)
        txt = aot.to_hlo_text(jax.jit(M.make_train_step(spec)).lower(w, x, y))
        n_conv = txt.count(" convolution(")
        # 2 fwd + 2 input-grad (first conv has no input grad needed... jax
        # may still emit it) + 2 weight-grad = at most 6.
        assert 4 <= n_conv <= 6, n_conv


class TestArtifacts:
    @pytest.fixture(scope="class")
    def vdir(self, tmp_path_factory):
        out = tmp_path_factory.mktemp("artifacts")
        aot.lower_variant("mlp", 8, str(out))
        return os.path.join(str(out), "mlp_b8")

    def test_layout(self, vdir):
        for f in [
            "train_step.hlo.txt", "eval_step.hlo.txt", "dc_step.hlo.txt",
            "init_params.bin", "decay_mask.bin", "meta.json",
        ]:
            assert os.path.exists(os.path.join(vdir, f)), f

    def test_meta_consistent(self, vdir):
        meta = json.load(open(os.path.join(vdir, "meta.json")))
        spec = M.get_model("mlp")
        assert meta["param_count"] == M.param_count(spec)
        assert meta["batch"] == 8
        assert meta["num_classes"] == spec.num_classes
        layer_total = sum(int(np.prod(l["shape"])) for l in meta["layers"])
        assert layer_total == meta["param_count"]

    def test_init_params_size_and_finite(self, vdir):
        meta = json.load(open(os.path.join(vdir, "meta.json")))
        w = np.fromfile(os.path.join(vdir, "init_params.bin"), dtype=np.float32)
        assert w.shape[0] == meta["param_count"]
        assert np.isfinite(w).all()
        assert np.abs(w).max() > 0  # not all zeros

    def test_decay_mask_binary(self, vdir):
        m = np.fromfile(os.path.join(vdir, "decay_mask.bin"), dtype=np.float32)
        assert set(np.unique(m)).issubset({0.0, 1.0})

    def test_dc_step_contains_pallas_lowering(self, vdir):
        """interpret=True lowers the pallas kernel into plain HLO (a while
        loop over grid steps in older jax, or fused elementwise); it must
        NOT contain a Mosaic/tpu custom-call, which the CPU PJRT client
        cannot execute."""
        txt = open(os.path.join(vdir, "dc_step.hlo.txt")).read()
        assert "tpu_custom_call" not in txt
        assert "mosaic" not in txt.lower()
