"""L2 correctness: model zoo shapes, pack/unpack, gradients, training signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

ALL = sorted(M.MODELS)


def _batch(spec, b=4, seed=1):
    x = jax.random.normal(jax.random.PRNGKey(seed), (b, *spec.input_shape), jnp.float32)
    y = jnp.arange(b, dtype=jnp.int32) % spec.num_classes
    return x, y


class TestPackUnpack:
    @pytest.mark.parametrize("name", ALL)
    def test_roundtrip(self, name):
        spec = M.get_model(name)
        w = M.init_flat(spec, jax.random.PRNGKey(0))
        assert w.shape == (M.param_count(spec),)
        tree = M.unpack(spec, w)
        w2 = M.pack(spec, tree)
        np.testing.assert_array_equal(np.asarray(w), np.asarray(w2))

    @pytest.mark.parametrize("name", ALL)
    def test_layer_shapes(self, name):
        spec = M.get_model(name)
        tree = M.unpack(spec, M.init_flat(spec, jax.random.PRNGKey(0)))
        for pname, shape in spec.params:
            assert tree[pname].shape == shape

    @pytest.mark.parametrize("name", ALL)
    def test_decay_mask_exempts_biases(self, name):
        spec = M.get_model(name)
        mask = M.decay_mask(spec)
        assert mask.shape == (M.param_count(spec),)
        off = 0
        for pname, shape in spec.params:
            n = int(np.prod(shape))
            expect = 0.0 if len(shape) == 1 else 1.0
            assert (mask[off : off + n] == expect).all(), pname
            off += n


class TestForward:
    @pytest.mark.parametrize("name", ALL)
    @pytest.mark.parametrize("b", [1, 4])
    def test_logit_shapes(self, name, b):
        spec = M.get_model(name)
        w = M.init_flat(spec, jax.random.PRNGKey(0))
        x, _ = _batch(spec, b)
        logits = spec.apply(M.unpack(spec, w), x)
        assert logits.shape == (b, spec.num_classes)
        assert bool(jnp.all(jnp.isfinite(logits)))

    @pytest.mark.parametrize("name", ALL)
    def test_init_loss_near_uniform(self, name):
        """He init with zero biases: loss should start near ln(C)."""
        spec = M.get_model(name)
        w = M.init_flat(spec, jax.random.PRNGKey(0))
        x, y = _batch(spec, 16)
        loss, err = M.make_eval_step(spec)(w, x, y)
        assert 0.3 * np.log(spec.num_classes) < float(loss) < 5 * np.log(
            spec.num_classes
        )

    def test_batch_independence(self):
        """Per-sample outputs must not depend on other samples in the batch
        (no cross-batch ops like BN — by design, see DESIGN.md)."""
        spec = M.get_model("tiny_cnn")
        w = M.init_flat(spec, jax.random.PRNGKey(0))
        x, _ = _batch(spec, 8)
        full = spec.apply(M.unpack(spec, w), x)
        half = spec.apply(M.unpack(spec, w), x[:4])
        np.testing.assert_allclose(np.asarray(full[:4]), np.asarray(half), rtol=1e-5, atol=1e-6)


class TestGradients:
    @pytest.mark.parametrize("name", ALL)
    def test_train_step_outputs(self, name):
        spec = M.get_model(name)
        w = M.init_flat(spec, jax.random.PRNGKey(0))
        x, y = _batch(spec)
        loss, err, g = jax.jit(M.make_train_step(spec))(w, x, y)
        assert g.shape == w.shape
        assert bool(jnp.all(jnp.isfinite(g)))
        assert 0.0 <= float(err) <= 1.0

    def test_grad_matches_finite_difference(self):
        """Directional derivative check on the mlp (cheap, exact-ish)."""
        spec = M.get_model("mlp")
        w = M.init_flat(spec, jax.random.PRNGKey(0))
        x, y = _batch(spec, 8)
        ts = M.make_train_step(spec)
        loss0, _, g = ts(w, x, y)
        rng = np.random.default_rng(0)
        u = rng.standard_normal(w.shape[0]).astype(np.float32)
        u /= np.linalg.norm(u)
        eps = 1e-3
        lp, _ = M.make_eval_step(spec)(w + eps * jnp.asarray(u), x, y)
        lm, _ = M.make_eval_step(spec)(w - eps * jnp.asarray(u), x, y)
        fd = (float(lp) - float(lm)) / (2 * eps)
        an = float(jnp.dot(g, jnp.asarray(u)))
        assert abs(fd - an) < 5e-3 * max(1.0, abs(an)), (fd, an)

    @pytest.mark.parametrize("name", ["mlp", "tiny_cnn"])
    def test_sgd_reduces_loss(self, name):
        """A few plain-SGD steps on a fixed batch must reduce the loss —
        the minimum signal that fwd+bwd are consistent."""
        spec = M.get_model(name)
        w = M.init_flat(spec, jax.random.PRNGKey(0))
        x, y = _batch(spec, 16)
        ts = jax.jit(M.make_train_step(spec))
        loss0, _, _ = ts(w, x, y)
        for _ in range(20):
            _, _, g = ts(w, x, y)
            w = w - 0.05 * g
        loss1, _, _ = ts(w, x, y)
        assert float(loss1) < 0.7 * float(loss0), (float(loss0), float(loss1))
