"""L1 correctness: Pallas dc_update kernel vs the pure-jnp oracle.

Hypothesis sweeps vector lengths (including non-multiples of the tile),
block shapes, scalar hyper-parameter ranges, and degenerate inputs.
"""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment"
)
import hypothesis.strategies as st  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from compile.kernels import dc_correction as dc  # noqa: E402
from compile.kernels import ref  # noqa: E402

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=30, derandomize=True
)
hypothesis.settings.load_profile("ci")


def _vecs(seed: int, n: int):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    g, d, v, w = (jax.random.normal(k, (n,), jnp.float32) for k in ks)
    return g, d, v, w


def _check(n, eta, mu, lam0, wd, seed=0, block_rows=None, scale=1.0):
    g, d, v, w = _vecs(seed, n)
    g = g * scale
    kw = {} if block_rows is None else {"block_rows": block_rows}
    dw, vn, lam = dc.dc_update(
        g, d, v, w,
        jnp.float32(eta), jnp.float32(mu), jnp.float32(lam0), jnp.float32(wd),
        **kw,
    )
    rdw, rvn, rlam = ref.dc_update_ref(g, d, v, w, eta, mu, lam0, wd)
    np.testing.assert_allclose(np.asarray(lam), np.asarray(rlam), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(rdw), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(vn), np.asarray(rvn), rtol=1e-5, atol=1e-6)


class TestDcUpdateKernel:
    @hypothesis.given(
        n=st.integers(min_value=1, max_value=40_000),
        eta=st.floats(1e-4, 1.0),
        mu=st.floats(0.0, 0.99),
        lam0=st.floats(0.0, 2.0),
        wd=st.floats(0.0, 1e-2),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref_random(self, n, eta, mu, lam0, wd, seed):
        _check(n, eta, mu, lam0, wd, seed)

    @pytest.mark.parametrize("n", [1, 127, 128, 129, 1024, 32768, 32769, 100_000])
    def test_padding_boundaries(self, n):
        """Lengths straddling the lane width and tile size."""
        _check(n, 0.1, 0.9, 0.2, 1e-4)

    @pytest.mark.parametrize("block_rows", [8, 32, 256, 1024])
    def test_block_shape_invariance(self, block_rows):
        """The result must not depend on the VMEM tiling."""
        _check(50_000, 0.1, 0.9, 0.2, 1e-4, block_rows=block_rows)

    def test_zero_distance_gives_plain_momentum(self):
        """D == 0 (all workers in sync) must reduce to plain momentum SGD
        and produce lam == 0 (guarded Eq. 17)."""
        n = 4096
        g, _, v, w = _vecs(3, n)
        d = jnp.zeros(n, jnp.float32)
        dw, vn, lam = dc.dc_update(
            g, d, v, w,
            jnp.float32(0.1), jnp.float32(0.9), jnp.float32(0.2), jnp.float32(0.0),
        )
        assert float(lam) == 0.0
        rvn = 0.9 * v + g
        np.testing.assert_allclose(np.asarray(vn), np.asarray(rvn), rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(
            np.asarray(dw), np.asarray(-0.1 * rvn), rtol=1e-6, atol=1e-7
        )

    def test_zero_gradient(self):
        """g == 0: correction is 0, update is pure momentum decay + wd."""
        n = 1000
        _, d, v, w = _vecs(4, n)
        g = jnp.zeros(n, jnp.float32)
        dw, vn, lam = dc.dc_update(
            g, d, v, w,
            jnp.float32(0.1), jnp.float32(0.9), jnp.float32(0.2), jnp.float32(1e-4),
        )
        assert float(lam) == 0.0
        np.testing.assert_allclose(
            np.asarray(vn), np.asarray(0.9 * v + 1e-4 * w), rtol=1e-5, atol=1e-7
        )

    @hypothesis.given(scale=st.floats(1e-6, 1e3))
    def test_lambda_scale_invariance(self, scale):
        """Eq. 17 makes the correction norm-proportional to ||g||: scaling g
        rescales lam so that ||lam g(.)g(.)D|| == lam0 ||g||."""
        n = 8192
        g, d, v, w = _vecs(5, n)
        g = g * scale
        _, _, lam = dc.dc_update(
            g, d, v, w,
            jnp.float32(0.1), jnp.float32(0.9), jnp.float32(0.2), jnp.float32(0.0),
        )
        corr = float(lam) * np.asarray(g) ** 2 * np.asarray(d)
        np.testing.assert_allclose(
            np.linalg.norm(corr), 0.2 * np.linalg.norm(np.asarray(g)), rtol=1e-4
        )

    def test_correction_exact_when_pseudo_hessian_is_exact(self):
        """Spec-level check of Eq. 10's Taylor logic: for a quadratic loss
        whose (diagonal) Hessian equals g (.) g at the expansion point —
        the regime the DC-ASGD pseudo-Hessian models (diag Fisher ~= diag
        Hessian for CE losses, Zheng et al. 2016) — the lam=1 correction
        recovers the displaced gradient *exactly*, since the Taylor series
        of a quadratic's gradient terminates at first order."""
        n = 512
        h = jnp.abs(_vecs(6, n)[0]) + 0.1  # diagonal Hessian
        g_local = jnp.sqrt(h)  # point where g (.) g == h exactly
        dvec = 0.1 * _vecs(8, n)[1]  # distance to average
        g_at_avg = g_local + h * dvec  # grad of the quadratic at w + D
        pseudo = ref.dc_correct(g_local, dvec, jnp.float32(1.0))
        np.testing.assert_allclose(
            np.asarray(pseudo), np.asarray(g_at_avg), rtol=1e-6, atol=1e-7
        )
