"""Property tests on the oracle itself (kernels/ref.py) — the spec both
the Pallas kernel and the rust hot path are pinned to."""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment"
)
import hypothesis.strategies as st  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from compile.kernels import ref  # noqa: E402

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("ci")


def _vecs(seed, n):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return [jax.random.normal(k, (n,), jnp.float32) for k in ks]


class TestLambda:
    @hypothesis.given(seed=st.integers(0, 1000), lam0=st.floats(0.01, 2.0))
    def test_normalizes_correction_norm(self, seed, lam0):
        g, d, _, _ = _vecs(seed, 512)
        lam = ref.dynamic_lambda(g, d, lam0)
        corr = float(lam) * np.asarray(g) ** 2 * np.asarray(d)
        np.testing.assert_allclose(
            np.linalg.norm(corr),
            lam0 * np.linalg.norm(np.asarray(g)),
            rtol=1e-4,
        )

    def test_clamped_at_lambda_max(self):
        # tiny gradients, tiny distance: the raw ratio would explode.
        n = 64
        g = jnp.full((n,), 1e-12, jnp.float32)
        d = jnp.full((n,), 1e-6, jnp.float32)
        lam = ref.dynamic_lambda(g, d, 0.2)
        assert float(lam) <= ref.LAMBDA_MAX
        assert np.isfinite(float(lam))

    def test_zero_cases(self):
        n = 16
        z = jnp.zeros((n,), jnp.float32)
        g = jnp.ones((n,), jnp.float32)
        assert float(ref.dynamic_lambda(g, z, 0.2)) == 0.0
        assert float(ref.dynamic_lambda(z, g, 0.2)) == 0.0


class TestUpdateAlgebra:
    @hypothesis.given(seed=st.integers(0, 1000))
    def test_linearity_in_eta(self, seed):
        """dw is exactly linear in eta (everything else fixed)."""
        g, d, v, w = _vecs(seed, 128)
        dw1, _, _ = ref.dc_update_ref(g, d, v, w, 0.1, 0.9, 0.2, 1e-4)
        dw2, _, _ = ref.dc_update_ref(g, d, v, w, 0.2, 0.9, 0.2, 1e-4)
        np.testing.assert_allclose(
            np.asarray(dw2), 2.0 * np.asarray(dw1), rtol=1e-5, atol=1e-7
        )

    @hypothesis.given(seed=st.integers(0, 1000))
    def test_momentum_zero_is_plain_step(self, seed):
        g, d, _, w = _vecs(seed, 128)
        v = jnp.zeros(128, jnp.float32)
        dw, vn, lam = ref.dc_update_ref(g, d, v, w, 0.5, 0.0, 0.2, 0.0)
        gt = ref.dc_correct(g, d, lam)
        np.testing.assert_allclose(np.asarray(vn), np.asarray(gt), rtol=1e-6)
        np.testing.assert_allclose(
            np.asarray(dw), -0.5 * np.asarray(gt), rtol=1e-6
        )

    @hypothesis.given(seed=st.integers(0, 1000))
    def test_correction_is_odd_in_d(self, seed):
        """Flipping D flips the correction term exactly."""
        g, d, _, _ = _vecs(seed, 128)
        lam = jnp.float32(0.7)
        plus = ref.dc_correct(g, d, lam) - g
        minus = ref.dc_correct(g, -d, lam) - g
        np.testing.assert_allclose(
            np.asarray(plus), -np.asarray(minus), rtol=1e-6, atol=1e-7
        )
