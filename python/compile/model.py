"""L2: JAX model zoo for the DC-S3GD reproduction (build-time only).

The paper trains ResNet-50/101/152 and VGG-16 on ImageNet-1k on a Cray XC
system. Per DESIGN.md SS3 we substitute CIFAR-scale members of the same
architecture families, trained on a synthetic image-classification task:

  - ``mlp``       2-hidden-layer perceptron          (~230 k params)
  - ``tiny_cnn``  2-conv VGG-style net, 16x16 input  (~10 k params)
  - ``small_cnn`` 3-block VGG-style net, 32x32 input (~300 k params)
  - ``resnet20``  norm-free ResNet-20, 32x32 input   (~270 k params)

Every model exposes its weights as a **single flat f32 vector** — that is
the contract with the rust coordinator, whose collectives, optimizer
state and delay-compensation all operate on flat buffers (exactly like
the paper's MXNet KV-store operates on a flat key space).

The jitted entry points lowered to HLO by ``aot.py``:

  train_step(w, x, y) -> (loss, err, g)    fused fwd+bwd
  eval_step(w, x, y)  -> (loss, err)       fwd only
  dc_update(...)                           L2 wrapper over the L1 Pallas
                                           kernel (kernels/dc_correction)

BatchNorm note: the paper's ResNets use BN; flat stateless weights and
tiny per-worker batches make BN a poor fit here, so resnet20 is built
*norm-free* (He-init + residual branch scaling 0.25, cf. NF-nets) — the
optimizer/communication layer under study is agnostic to this, and the
weight-decay-exempt-BN rule of SSIV-A is preserved by exempting biases
instead (see ``decay_mask``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ModelSpec",
    "MODELS",
    "get_model",
    "param_count",
    "init_flat",
    "pack",
    "unpack",
    "make_train_step",
    "make_eval_step",
    "decay_mask",
]

# --------------------------------------------------------------------------
# Parameter bookkeeping: a model is a list of (name, shape) plus an apply fn
# over the unpacked dict. Flat layout is concatenation in spec order.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """A model variant: parameter layout + forward function."""

    name: str
    input_hw: int  # square input, NHWC with C=3
    num_classes: int
    params: Tuple[Tuple[str, Tuple[int, ...]], ...]
    apply: Callable[[Dict[str, jnp.ndarray], jnp.ndarray], jnp.ndarray]

    @property
    def input_shape(self) -> Tuple[int, int, int]:
        return (self.input_hw, self.input_hw, 3)


def param_count(spec: ModelSpec) -> int:
    return int(sum(np.prod(s) for _, s in spec.params))


def pack(spec: ModelSpec, tree: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Flatten a param dict into the canonical flat f32 vector."""
    return jnp.concatenate([tree[n].reshape(-1) for n, _ in spec.params])


def unpack(spec: ModelSpec, flat: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Inverse of :func:`pack` (shape-checked)."""
    sizes = [int(np.prod(s)) for _, s in spec.params]
    assert flat.shape == (sum(sizes),), (flat.shape, sum(sizes))
    parts = jnp.split(flat, np.cumsum(sizes)[:-1]) if len(sizes) > 1 else [flat]
    return {
        n: p.reshape(s) for (n, s), p in zip(spec.params, parts)
    }


def decay_mask(spec: ModelSpec) -> np.ndarray:
    """Per-element weight-decay mask (1 = decayed, 0 = exempt).

    Paper SSIV-A exempts batch-norm parameters from weight decay; the
    norm-free analogue is exempting biases (all rank-1 params here).
    """
    mask = np.ones(param_count(spec), dtype=np.float32)
    off = 0
    for _, shape in spec.params:
        n = int(np.prod(shape))
        if len(shape) == 1:  # bias
            mask[off : off + n] = 0.0
        off += n
    return mask


# --------------------------------------------------------------------------
# Initializers (match the paper's He-style CNN init)
# --------------------------------------------------------------------------


def _he_normal(key, shape, fan_in, scale=2.0):
    std = np.sqrt(scale / fan_in)
    return std * jax.random.normal(key, shape, jnp.float32)


def init_flat(spec: ModelSpec, key: jax.Array) -> jnp.ndarray:
    """He-normal init for weights, zeros for biases, as a flat vector."""
    keys = jax.random.split(key, len(spec.params))
    tree = {}
    for k, (name, shape) in zip(keys, spec.params):
        if len(shape) == 1:
            tree[name] = jnp.zeros(shape, jnp.float32)
        elif len(shape) == 2:  # dense: (in, out)
            tree[name] = _he_normal(k, shape, fan_in=shape[0])
        elif len(shape) == 4:  # conv HWIO
            fan_in = shape[0] * shape[1] * shape[2]
            tree[name] = _he_normal(k, shape, fan_in=fan_in)
        else:
            raise ValueError(f"unsupported param rank: {name} {shape}")
    return pack(spec, tree)


# --------------------------------------------------------------------------
# Layer helpers
# --------------------------------------------------------------------------


def _conv(x, w, b, stride=1):
    """3x3 'SAME' convolution, NHWC x HWIO -> NHWC."""
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b


def _avg_pool(x, k=2):
    y = jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, k, k, 1), (1, k, k, 1), "VALID"
    )
    return y / float(k * k)


def _global_avg_pool(x):
    return jnp.mean(x, axis=(1, 2))


# --------------------------------------------------------------------------
# Model definitions
# --------------------------------------------------------------------------


def _mlp_spec(hw=16, classes=10, hidden=(256, 128)) -> ModelSpec:
    d_in = hw * hw * 3
    params: List[Tuple[str, Tuple[int, ...]]] = []
    dims = [d_in, *hidden, classes]
    for i in range(len(dims) - 1):
        params.append((f"fc{i}.w", (dims[i], dims[i + 1])))
        params.append((f"fc{i}.b", (dims[i + 1],)))

    def apply(p, x):
        h = x.reshape(x.shape[0], -1)
        for i in range(len(dims) - 1):
            h = h @ p[f"fc{i}.w"] + p[f"fc{i}.b"]
            if i < len(dims) - 2:
                h = jax.nn.relu(h)
        return h

    return ModelSpec("mlp", hw, classes, tuple(params), apply)


def _vgg_spec(name, hw, classes, channels: Sequence[int]) -> ModelSpec:
    """VGG-16-family stand-in: stacked 3x3 conv + pool stages."""
    params: List[Tuple[str, Tuple[int, ...]]] = []
    c_in = 3
    for i, c in enumerate(channels):
        params.append((f"conv{i}.w", (3, 3, c_in, c)))
        params.append((f"conv{i}.b", (c,)))
        c_in = c
    feat_hw = hw // (2 ** len(channels))
    d_feat = feat_hw * feat_hw * channels[-1]
    params.append(("fc.w", (d_feat, classes)))
    params.append(("fc.b", (classes,)))

    def apply(p, x):
        h = x
        for i in range(len(channels)):
            h = jax.nn.relu(_conv(h, p[f"conv{i}.w"], p[f"conv{i}.b"]))
            h = _avg_pool(h)
        h = h.reshape(h.shape[0], -1)
        return h @ p["fc.w"] + p["fc.b"]

    return ModelSpec(name, hw, classes, tuple(params), apply)


def _resnet_spec(name, hw, classes, width=16, blocks_per_stage=3) -> ModelSpec:
    """Norm-free ResNet-20 family (3 stages, 2-conv residual blocks).

    Residual branches are scaled by 0.25 so depth does not blow up the
    forward variance without BatchNorm (NF-net style); stage transitions
    use stride-2 3x3 convs with a 1x1 strided projection shortcut.
    """
    params: List[Tuple[str, Tuple[int, ...]]] = []
    params.append(("stem.w", (3, 3, 3, width)))
    params.append(("stem.b", (width,)))
    stages = [width, 2 * width, 4 * width]
    c_in = width
    for s, c in enumerate(stages):
        for b in range(blocks_per_stage):
            pref = f"s{s}b{b}"
            stride_in = c_in if b > 0 or s == 0 else c_in
            params.append((f"{pref}.c1.w", (3, 3, c_in if b == 0 else c, c)))
            params.append((f"{pref}.c1.b", (c,)))
            params.append((f"{pref}.c2.w", (3, 3, c, c)))
            params.append((f"{pref}.c2.b", (c,)))
            if b == 0 and c != c_in:
                params.append((f"{pref}.proj.w", (1, 1, c_in, c)))
                params.append((f"{pref}.proj.b", (c,)))
        c_in = c
    params.append(("fc.w", (stages[-1], classes)))
    params.append(("fc.b", (classes,)))

    def apply(p, x):
        h = jax.nn.relu(_conv(x, p["stem.w"], p["stem.b"]))
        cin = width
        for s, c in enumerate(stages):
            for b in range(blocks_per_stage):
                pref = f"s{s}b{b}"
                stride = 2 if (b == 0 and s > 0) else 1
                y = jax.nn.relu(_conv(h, p[f"{pref}.c1.w"], p[f"{pref}.c1.b"], stride))
                y = _conv(y, p[f"{pref}.c2.w"], p[f"{pref}.c2.b"])
                if f"{pref}.proj.w" in p:
                    sc = jax.lax.conv_general_dilated(
                        h,
                        p[f"{pref}.proj.w"],
                        window_strides=(stride, stride),
                        padding="SAME",
                        dimension_numbers=("NHWC", "HWIO", "NHWC"),
                    ) + p[f"{pref}.proj.b"]
                elif stride != 1:
                    sc = _avg_pool(h, stride)
                else:
                    sc = h
                h = jax.nn.relu(sc + 0.25 * y)
            cin = c
        h = _global_avg_pool(h)
        return h @ p["fc.w"] + p["fc.b"]

    return ModelSpec(name, hw, classes, tuple(params), apply)


MODELS: Dict[str, Callable[[], ModelSpec]] = {
    "mlp": lambda: _mlp_spec(hw=16, classes=10),
    "tiny_cnn": lambda: _vgg_spec("tiny_cnn", 16, 10, channels=(16, 32)),
    "small_cnn": lambda: _vgg_spec("small_cnn", 32, 10, channels=(32, 64, 128)),
    "resnet20": lambda: _resnet_spec("resnet20", 32, 10, width=16),
}


@functools.lru_cache(maxsize=None)
def get_model(name: str) -> ModelSpec:
    if name not in MODELS:
        raise KeyError(f"unknown model {name!r}; have {sorted(MODELS)}")
    return MODELS[name]()


# --------------------------------------------------------------------------
# Training / eval steps (the functions aot.py lowers)
# --------------------------------------------------------------------------


def _loss_err(spec: ModelSpec, w_flat, x, y):
    logits = spec.apply(unpack(spec, w_flat), x)
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    err = jnp.mean((jnp.argmax(logits, axis=1) != y).astype(jnp.float32))
    return loss, err


def make_train_step(spec: ModelSpec):
    """(w, x, y) -> (loss, err, g): fused forward+backward on flat weights."""

    def train_step(w_flat, x, y):
        (loss, err), g = jax.value_and_grad(
            lambda w: _loss_err(spec, w, x, y), has_aux=True
        )(w_flat)
        return loss, err, g

    return train_step


def make_eval_step(spec: ModelSpec):
    """(w, x, y) -> (loss, err): forward only."""

    def eval_step(w_flat, x, y):
        return _loss_err(spec, w_flat, x, y)

    return eval_step
