"""L1 Pallas kernel: fused delay-compensated momentum-SGD update.

The hot elementwise path of DC-S3GD (paper Eqs. 10-12 + momentum) fused
into a single kernel so every operand is read from HBM exactly once and
every output written exactly once:

    g~  = g + lam * g (.) g (.) D        (delay compensation, Eq. 10)
    v'  = mu * v + g~ + wd * w           (momentum + weight decay)
    dw  = -eta * v'                      (update step)

Inputs are the flat parameter-sized vectors (g, D, v, w) reshaped to
(rows, 128) — the TPU lane width — and tiled into (BLOCK_ROWS, 128) VMEM
blocks by the BlockSpec. The norm reductions needed for the dynamic
lambda (Eq. 17) are *global* over the parameter vector, so they are
computed by the surrounding L2 jax function (two jnp.linalg.norm calls)
and fed into the kernel as scalars; this keeps the kernel a pure
streaming elementwise pass.

TPU mapping (DESIGN.md SSHardware-Adaptation): this kernel is VPU-bound,
not MXU-bound — the paper's CPU hot loop (MKL-DNN fused update) maps to
a VMEM-tiled streaming kernel, with BlockSpec expressing the HBM<->VMEM
double-buffered schedule the CPU version gets from hardware prefetch.

interpret=True always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU numbers are estimated analytically in
EXPERIMENTS.md SSPerf from bytes-moved roofline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

__all__ = ["dc_update", "LANES", "DEFAULT_BLOCK_ROWS"]

# TPU vector-lane width; flat vectors are reshaped to (rows, LANES).
LANES = 128
# Rows per VMEM block: 8 sublanes x 32 = 256 rows x 128 lanes x 4 B x
# 6 streams (4 in + 2 out) = 768 KiB of VMEM per in-flight block — small
# enough to double-buffer within the ~16 MiB VMEM budget with room for
# the next block's prefetch.
DEFAULT_BLOCK_ROWS = 256


def _dc_update_kernel(scal_ref, g_ref, d_ref, v_ref, w_ref, dw_ref, vn_ref):
    """One (BLOCK_ROWS, 128) tile of the fused update.

    scal_ref holds the four scalars [lam, eta, mu, wd] broadcast to every
    grid step (index_map pins it to block 0).
    """
    lam = scal_ref[0]
    eta = scal_ref[1]
    mu = scal_ref[2]
    wd = scal_ref[3]
    g = g_ref[...]
    d = d_ref[...]
    # g~ = g + lam * g*g*d — one fused multiply-add chain, no temporaries
    # spilled to HBM.
    gt = g + lam * g * g * d
    vn = mu * v_ref[...] + gt + wd * w_ref[...]
    vn_ref[...] = vn
    dw_ref[...] = -eta * vn


@functools.partial(jax.jit, static_argnames=("block_rows",))
def dc_update(
    g: jnp.ndarray,
    d: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,
    eta: jnp.ndarray,
    mu: jnp.ndarray,
    lam0: jnp.ndarray,
    wd: jnp.ndarray,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
):
    """Fused DC-S3GD update over flat f32 vectors of any length.

    Returns (dw, v_new, lam).  Matches ``ref.dc_update_ref`` bit-for-bit
    up to float32 associativity.
    """
    n = g.shape[0]
    assert g.shape == d.shape == v.shape == w.shape, "operand shape mismatch"

    # Global norm reductions for Eq. 17 live in L2 (they need the whole
    # vector); the kernel receives lam as a scalar.
    lam = ref.dynamic_lambda(g, d, lam0)

    # Pad the flat vector to a whole number of (block_rows, LANES) tiles.
    tile = block_rows * LANES
    n_pad = (n + tile - 1) // tile * tile
    pad = n_pad - n

    def pad2d(x):
        return jnp.pad(x, (0, pad)).reshape(n_pad // LANES, LANES)

    g2, d2, v2, w2 = pad2d(g), pad2d(d), pad2d(v), pad2d(w)
    rows = n_pad // LANES
    grid = (rows // block_rows,)

    scal = jnp.stack([lam, eta, mu, wd]).astype(jnp.float32)

    block = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    scal_spec = pl.BlockSpec((4,), lambda i: (0,))

    dw2, vn2 = pl.pallas_call(
        _dc_update_kernel,
        grid=grid,
        in_specs=[scal_spec, block, block, block, block],
        out_specs=[block, block],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(scal, g2, d2, v2, w2)

    dw = dw2.reshape(-1)[:n]
    vn = vn2.reshape(-1)[:n]
    return dw, vn, lam
