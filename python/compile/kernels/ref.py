"""Pure-jnp reference oracle for the DC-S3GD fused update kernel.

This module is the *specification*: the Pallas kernel in
``dc_correction.py`` must agree with these functions to float32
tolerance for every shape/dtype the test suite sweeps.

The math (paper Eqs. 10-12, 17, momentum SGD):

    lam    = lam0 * ||g|| / ||g (.) g (.) D||          (Eq. 17, safe-guarded)
    g~     = g + lam * g (.) g (.) D                   (Eq. 10)
    v'     = mu * v + g~ + wd * w                      (momentum + weight decay)
    dw     = -eta * v'                                 (update U(g~, eta, mu))

where (.) is the Hadamard product, g is the local gradient, D the
distance-to-average (Eq. 9), v the momentum buffer, w the current weights.

All functions operate on flat f32 vectors.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "dynamic_lambda",
    "dc_correct",
    "momentum_update",
    "dc_update_ref",
]


# Clamp matching rust dc::LAMBDA_MAX: near convergence the Eq. 17 ratio
# diverges (denominator shrinks quadratically in ||g||) even though the
# correction itself stays bounded at lam0*||g||.
LAMBDA_MAX = 1e6


def dynamic_lambda(g: jnp.ndarray, d: jnp.ndarray, lam0: float) -> jnp.ndarray:
    """Eq. 17: lam_i = lam0 * ||g|| / ||g (.) g (.) D||, guarded against 0/0
    and clamped to LAMBDA_MAX.

    When the correction term has zero norm (e.g. D == 0 on the very first
    iteration, when all workers still agree), the correction itself is zero,
    so any finite lambda is equivalent; we return 0 to keep the math exact.
    """
    gn = jnp.linalg.norm(g)
    cn = jnp.linalg.norm(g * g * d)
    lam = jnp.where(cn > 0.0, lam0 * gn / jnp.maximum(cn, 1e-30), 0.0)
    return jnp.minimum(lam, LAMBDA_MAX)


def dc_correct(g: jnp.ndarray, d: jnp.ndarray, lam: jnp.ndarray) -> jnp.ndarray:
    """Eq. 10: g~ = g + lam * g (.) g (.) D."""
    return g + lam * g * g * d


def momentum_update(
    gt: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,
    eta: float,
    mu: float,
    wd: float,
):
    """Momentum-SGD update U(g~, eta, mu) with decoupled-into-gradient weight
    decay (paper SS IV-A: decay applied to all weights, scheduled like eta).

    Returns (dw, v') with v' = mu v + g~ + wd w and dw = -eta v'.
    """
    v_new = mu * v + gt + wd * w
    dw = -eta * v_new
    return dw, v_new


def dc_update_ref(
    g: jnp.ndarray,
    d: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,
    eta: float,
    mu: float,
    lam0: float,
    wd: float,
):
    """Full fused reference: (g, D, v, w, scalars) -> (dw, v', lam).

    This is the oracle for the Pallas kernel path *and* for the pure-rust
    hot path (rust/src/dc/) — rust tests compare against vectors generated
    from this function (see python/tests/test_genvectors.py).
    """
    lam = dynamic_lambda(g, d, lam0)
    gt = dc_correct(g, d, lam)
    dw, v_new = momentum_update(gt, v, w, eta, mu, wd)
    return dw, v_new, lam
