"""AOT pipeline: lower the L2/L1 jax functions to HLO text artifacts.

Run once at build time (``make artifacts``); the rust coordinator then
loads the artifacts via the PJRT C API and python never appears on the
training path again.

Interchange is **HLO text**, not ``lowered.compile().serialize()`` —
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Per model variant ``<v>`` and local batch size ``<b>`` this writes:

  artifacts/<v>_b<b>/train_step.hlo.txt   (w, x, y) -> (loss, err, g)
  artifacts/<v>_b<b>/eval_step.hlo.txt    (w, x, y) -> (loss, err)
  artifacts/<v>_b<b>/dc_step.hlo.txt      (g, D, v, w, eta, mu, lam0, wd)
                                          -> (dw, v', lam)   [Pallas inside]
  artifacts/<v>_b<b>/init_params.bin      f32 LE initial flat weights
  artifacts/<v>_b<b>/decay_mask.bin       f32 LE weight-decay mask
  artifacts/<v>_b<b>/meta.json            shapes/counts for the rust loader

Usage:
  python -m compile.aot --out-dir ../artifacts \
      --variants mlp:32,tiny_cnn:16,tiny_cnn:32,tiny_cnn:64,small_cnn:32,resnet20:32
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import dc_correction

DEFAULT_VARIANTS = "mlp:32,tiny_cnn:16,tiny_cnn:32,tiny_cnn:64,small_cnn:32,resnet20:32"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(name: str, batch: int, out_dir: str, seed: int = 0) -> dict:
    """Lower train/eval/dc_step for one (model, batch) variant."""
    spec = M.get_model(name)
    n = M.param_count(spec)
    vdir = os.path.join(out_dir, f"{name}_b{batch}")
    os.makedirs(vdir, exist_ok=True)

    w_s = jax.ShapeDtypeStruct((n,), jnp.float32)
    x_s = jax.ShapeDtypeStruct((batch, *spec.input_shape), jnp.float32)
    y_s = jax.ShapeDtypeStruct((batch,), jnp.int32)
    scal = jax.ShapeDtypeStruct((), jnp.float32)

    train = jax.jit(M.make_train_step(spec)).lower(w_s, x_s, y_s)
    with open(os.path.join(vdir, "train_step.hlo.txt"), "w") as f:
        f.write(to_hlo_text(train))

    ev = jax.jit(M.make_eval_step(spec)).lower(w_s, x_s, y_s)
    with open(os.path.join(vdir, "eval_step.hlo.txt"), "w") as f:
        f.write(to_hlo_text(ev))

    # L2 wrapper over the L1 Pallas kernel, lowered at this variant's
    # parameter count. interpret=True lowers to plain HLO ops that the
    # CPU PJRT client can execute.
    dc = jax.jit(
        lambda g, d, v, w, eta, mu, lam0, wd: dc_correction.dc_update(
            g, d, v, w, eta, mu, lam0, wd
        )
    ).lower(w_s, w_s, w_s, w_s, scal, scal, scal, scal)
    with open(os.path.join(vdir, "dc_step.hlo.txt"), "w") as f:
        f.write(to_hlo_text(dc))

    w0 = np.asarray(M.init_flat(spec, jax.random.PRNGKey(seed)), dtype=np.float32)
    w0.tofile(os.path.join(vdir, "init_params.bin"))
    M.decay_mask(spec).tofile(os.path.join(vdir, "decay_mask.bin"))

    meta = {
        "model": name,
        "batch": batch,
        "param_count": n,
        "input_hw": spec.input_hw,
        "input_channels": 3,
        "num_classes": spec.num_classes,
        "seed": seed,
        "layers": [
            {"name": pn, "shape": list(ps)} for pn, ps in spec.params
        ],
        "outputs": {
            "train_step": ["loss", "err", "grad"],
            "eval_step": ["loss", "err"],
            "dc_step": ["dw", "v_new", "lam"],
        },
    }
    with open(os.path.join(vdir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return meta


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--variants", default=DEFAULT_VARIANTS,
                    help="comma list of model:batch pairs")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []
    for pair in args.variants.split(","):
        name, batch = pair.strip().split(":")
        meta = lower_variant(name, int(batch), args.out_dir, args.seed)
        manifest.append(meta)
        print(f"lowered {name}:b{batch}  params={meta['param_count']}",
              file=sys.stderr)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest)} variants to {args.out_dir}", file=sys.stderr)


if __name__ == "__main__":
    main()
