"""Generate golden test vectors for the rust unit tests.

The rust hot path re-implements the DC-S3GD math (rust/src/dc/) so the
coordinator can run without artifacts; these fixtures pin it to the
same oracle (kernels/ref.py) the Pallas kernel is verified against.

Writes small JSON files under rust/tests/golden/. Deterministic: uses
fixed PRNG keys, so re-running never changes committed fixtures.

Usage: (cd python && python -m compile.gen_golden)
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref

OUT = os.path.join(os.path.dirname(__file__), "..", "..", "rust", "tests", "golden")

CASES = [
    # (name, n, eta, mu, lam0, wd, seed)
    ("basic", 64, 0.1, 0.9, 0.2, 1e-4, 0),
    ("no_momentum", 48, 0.5, 0.0, 0.2, 0.0, 1),
    ("lam_zero", 48, 0.1, 0.9, 0.0, 0.0, 2),
    ("big_lam", 96, 0.01, 0.5, 2.0, 1e-3, 3),
    ("odd_len", 37, 0.1, 0.9, 0.2, 1e-4, 4),
]


def _vecs(seed: int, n: int):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return [np.asarray(jax.random.normal(k, (n,), jnp.float32)) for k in ks]


def main() -> None:
    os.makedirs(OUT, exist_ok=True)
    for name, n, eta, mu, lam0, wd, seed in CASES:
        g, d, v, w = _vecs(seed, n)
        dw, vn, lam = ref.dc_update_ref(
            jnp.asarray(g), jnp.asarray(d), jnp.asarray(v), jnp.asarray(w),
            eta, mu, lam0, wd,
        )
        case = {
            "name": name,
            "eta": eta, "mu": mu, "lam0": lam0, "wd": wd,
            "g": g.tolist(), "d": d.tolist(), "v": v.tolist(), "w": w.tolist(),
            "lam": float(lam),
            "dw": np.asarray(dw).tolist(),
            "v_new": np.asarray(vn).tolist(),
        }
        path = os.path.join(OUT, f"dc_{name}.json")
        with open(path, "w") as f:
            json.dump(case, f)
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
