#!/usr/bin/env python3
"""Convert a dcs3gd --trace-out JSONL journal to chrome://tracing JSON.

Each journal line is one event with virtual-time `t_start`/`t_end`
(seconds), a `rank`, a `window` and a `kind`. Span-shaped kinds
(`round_sealed`, `window_consumed`, `epoch_transition`) become complete
("X") events; instant-shaped kinds (`round_posted`, `decision`, `fault`,
`probe`) become instant ("i") events. Virtual seconds map to trace
microseconds, ranks map to tids, so the timeline reads directly as the
per-rank overlap picture of Fig. 2.

Usage:
  python3 tools/trace_to_chrome.py run.trace.jsonl --out run.chrome.json

Load the output at chrome://tracing or https://ui.perfetto.dev
(stdlib-only; no network, no third-party deps).
"""

import argparse
import json
import sys

# Kinds whose [t_start, t_end) extent is meaningful.
SPAN_KINDS = {"round_sealed", "window_consumed", "epoch_transition"}


def to_chrome(lines):
    """Yield chrome trace event dicts from JSONL lines (skips blanks)."""
    for lineno, raw in enumerate(lines, 1):
        raw = raw.strip()
        if not raw:
            continue
        try:
            ev = json.loads(raw)
        except json.JSONDecodeError as e:
            raise SystemExit(f"line {lineno}: bad JSON ({e})")
        kind = ev.get("kind", "?")
        rank = int(ev.get("rank", 0))
        t_start_us = float(ev.get("t_start", 0.0)) * 1e6
        t_end_us = float(ev.get("t_end", ev.get("t_start", 0.0))) * 1e6
        args = {"window": ev.get("window"), "seq": ev.get("seq")}
        if ev.get("detail"):
            args["detail"] = ev["detail"]
        base = {
            "name": kind,
            "cat": "dcs3gd",
            "pid": 1,
            "tid": rank,
            "ts": t_start_us,
            "args": args,
        }
        if kind in SPAN_KINDS and t_end_us > t_start_us:
            yield {**base, "ph": "X", "dur": t_end_us - t_start_us}
        else:
            yield {**base, "ph": "i", "s": "t"}


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="JSONL journal written by --trace-out")
    ap.add_argument("--out", default=None, help="output path (default: stdout)")
    opts = ap.parse_args()

    with open(opts.trace, encoding="utf-8") as f:
        events = list(to_chrome(f))
    if not events:
        raise SystemExit(f"{opts.trace}: no events (run with --trace-capacity > 0?)")

    doc = {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"source": "dcs3gd --trace-out", "ranks_as_tids": True},
    }
    if opts.out:
        with open(opts.out, "w", encoding="utf-8") as f:
            json.dump(doc, f)
        print(f"wrote {len(events)} events to {opts.out}", file=sys.stderr)
    else:
        json.dump(doc, sys.stdout)


if __name__ == "__main__":
    main()
