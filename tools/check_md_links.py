#!/usr/bin/env python3
"""Markdown link checker for README.md + docs/ (offline, stdlib-only).

Checks every inline markdown link `[text](target)`:
  * relative file targets must exist (resolved against the source file);
  * `#anchor` / `file#anchor` targets must match a heading in the
    target file (GitHub-style slugs: lowercase, punctuation stripped,
    spaces -> dashes);
  * http(s)/mailto links are out of scope (no network in CI).

Usage: python3 tools/check_md_links.py README.md docs/*.md
Exits non-zero listing every broken link.
"""

import re
import sys
from pathlib import Path

LINK = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE = re.compile(r"```.*?```", re.DOTALL)


def slug(heading: str) -> str:
    """GitHub-style anchor slug of a heading line."""
    text = re.sub(r"`([^`]*)`", r"\1", heading.strip())
    text = re.sub(r"[^\w\- ]", "", text, flags=re.UNICODE)
    return text.lower().replace(" ", "-")


def anchors_of(path: Path) -> set:
    text = CODE_FENCE.sub("", path.read_text(encoding="utf-8"))
    return {slug(m.group(1)) for m in HEADING.finditer(text)}


def main(files):
    errors = []
    for name in files:
        src = Path(name)
        if not src.exists():
            errors.append(f"{name}: source file missing")
            continue
        text = CODE_FENCE.sub("", src.read_text(encoding="utf-8"))
        for m in LINK.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, anchor = target.partition("#")
            dest = src if not path_part else (src.parent / path_part)
            if not dest.exists():
                errors.append(f"{name}: broken link -> {target} (no {dest})")
                continue
            if anchor and dest.suffix == ".md" and slug(anchor) not in anchors_of(dest):
                errors.append(f"{name}: broken anchor -> {target}")
    if errors:
        print("\n".join(errors))
        return 1
    print(f"checked {len(files)} files: all markdown links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
