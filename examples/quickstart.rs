//! Quickstart: train a small model with DC-S3GD on 4 simulated workers
//! and print the learning curve.
//!
//! Uses the PJRT CNN artifacts when present (`make artifacts`), else
//! falls back to the pure-rust linear model so the example always runs:
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Pass `--trace-out FILE` to also journal the run's round-level events
//! as JSONL (see docs/observability.md), run an SSGD twin for contrast,
//! and print both trace reports — DC-S3GD's overlap efficiency is > 0
//! (compute hides the in-flight collective), SSGD's is exactly 0.

use dcs3gd::algo::Algo;
use dcs3gd::config::ExperimentConfig;
use dcs3gd::obs::report::{analyze, parse_jsonl, render};
use dcs3gd::simtime::ComputeModel;

fn main() -> anyhow::Result<()> {
    // Prefer the AOT CNN artifact; fall back to the rust linear model.
    let have_artifacts = std::path::Path::new("artifacts/tiny_cnn_b32/meta.json").exists();
    let (variant, batch) = if have_artifacts { ("tiny_cnn_b32", 32) } else { ("linear", 32) };
    let trace_out = std::env::args()
        .skip(1)
        .collect::<Vec<_>>()
        .windows(2)
        .find(|w| w[0] == "--trace-out")
        .map(|w| w[1].clone());
    println!("backend: {variant}\n");
    println!("DC-S3GD | 4 workers | global batch {} | 150 steps", 4 * batch);

    // `RunBuilder` is the one typed entry point: configure, then `.run()`
    // straight to the report (no separate build + run_experiment step).
    let builder = |name: &str, algo: Algo, trace: Option<&str>| {
        let mut b = ExperimentConfig::builder(variant)
            .name(name)
            .algo(algo)
            .nodes(4)
            .local_batch(batch)
            .steps(150)
            .eta_single(0.05)
            .base_batch(128)
            .data(4096, 512, 0.6)
            .compute(ComputeModel::uniform(2e-3))
            .eval_every(25, 4);
        if let Some(path) = trace {
            b = b.trace_out(path);
        }
        b
    };
    let report = builder("quickstart", Algo::DcS3gd, trace_out.as_deref()).run()?;

    println!("\nper-epoch train error:");
    for (epoch, err) in report.recorder.epoch_train_err() {
        let bar = "#".repeat((err * 50.0) as usize);
        println!("  epoch {epoch:>2}  {:>5.1}%  {bar}", err * 100.0);
    }
    println!("\nvalidation checkpoints:");
    for e in report.recorder.evals() {
        println!(
            "  iter {:>4}  val loss {:.4}  val err {:>5.1}%",
            e.iteration,
            e.val_loss,
            e.val_err * 100.0
        );
    }
    println!("\n{}", report.table_row());
    println!(
        "simulated cluster time {:.1}s | wall {:.1}s",
        report.sim_time_s, report.wall_time_s
    );

    // With --trace-out: analyze the DC-S3GD journal, then run a
    // synchronous SSGD twin into "<path>.ssgd.jsonl" for the overlap
    // contrast the paper's pipelining argument rests on.
    if let Some(path) = trace_out {
        let ssgd_path = format!("{path}.ssgd.jsonl");
        let ssgd = builder("quickstart_ssgd", Algo::Ssgd, Some(&ssgd_path)).run()?;
        for (title, p, rep) in [
            ("DC-S3GD", &path, &report),
            ("SSGD", &ssgd_path, &ssgd),
        ] {
            let events = parse_jsonl(&std::fs::read_to_string(p)?)?;
            println!("\n=== trace-report: {title} ({p}) ===");
            print!("{}", render(&analyze(&events)));
            let eff = rep
                .obs
                .as_ref()
                .map(|o| o.overlap_efficiency_mean())
                .unwrap_or(0.0);
            println!("run-JSON overlap_efficiency_mean: {eff:.4}");
        }
        println!(
            "\nconvert either journal for chrome://tracing with:\n  \
             python3 tools/trace_to_chrome.py {path} --out trace.json"
        );
    }
    Ok(())
}
