//! Quickstart: train a small model with DC-S3GD on 4 simulated workers
//! and print the learning curve.
//!
//! Uses the PJRT CNN artifacts when present (`make artifacts`), else
//! falls back to the pure-rust linear model so the example always runs:
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use dcs3gd::algo::Algo;
use dcs3gd::config::ExperimentConfig;
use dcs3gd::simtime::ComputeModel;

fn main() -> anyhow::Result<()> {
    // Prefer the AOT CNN artifact; fall back to the rust linear model.
    let have_artifacts = std::path::Path::new("artifacts/tiny_cnn_b32/meta.json").exists();
    let (variant, batch) = if have_artifacts { ("tiny_cnn_b32", 32) } else { ("linear", 32) };
    println!("backend: {variant}\n");
    println!("DC-S3GD | 4 workers | global batch {} | 150 steps", 4 * batch);

    // `RunBuilder` is the one typed entry point: configure, then `.run()`
    // straight to the report (no separate build + run_experiment step).
    let report = ExperimentConfig::builder(variant)
        .name("quickstart")
        .algo(Algo::DcS3gd)
        .nodes(4)
        .local_batch(batch)
        .steps(150)
        .eta_single(0.05)
        .base_batch(128)
        .data(4096, 512, 0.6)
        .compute(ComputeModel::uniform(2e-3))
        .eval_every(25, 4)
        .run()?;

    println!("\nper-epoch train error:");
    for (epoch, err) in report.recorder.epoch_train_err() {
        let bar = "#".repeat((err * 50.0) as usize);
        println!("  epoch {epoch:>2}  {:>5.1}%  {bar}", err * 100.0);
    }
    println!("\nvalidation checkpoints:");
    for e in report.recorder.evals() {
        println!(
            "  iter {:>4}  val loss {:.4}  val err {:>5.1}%",
            e.iteration,
            e.val_loss,
            e.val_err * 100.0
        );
    }
    println!("\n{}", report.table_row());
    println!(
        "simulated cluster time {:.1}s | wall {:.1}s",
        report.sim_time_s, report.wall_time_s
    );
    Ok(())
}
