//! Hierarchy sweep: quantify the t_AR win of the hierarchical
//! (Layered-SGD) collective schedule over the flat ring at 128–1024
//! simulated ranks, then run the `schedule_coupled` control policy end
//! to end and show its (k, schedule) decisions landing in the run's
//! metrics JSON.
//!
//! Part 1 is pure cost-model analysis on the default Aries-like
//! dragonfly: the flat ring pays 2(N−1) α-terms while the hierarchical
//! schedule pays 2(m−1) local + 2(G−1) global, so from N ≈ 256 the
//! grouped schedule wins at paper-scale payloads — the headroom the
//! Eq. 14 bound `max(t_C, t_AR)` leaves on the table when t_AR is
//! treated as opaque.
//!
//! Part 2 trains the linear model on a latency-dominated flat fabric
//! with a fast dragonfly available: the `schedule_coupled` policy must
//! switch the collective to `hierarchical`, cut the virtual wall-clock
//! vs the fixed flat-ring run, and export the decision trace (schedule
//! names + local/global phase split) into `runs/hierarchy/*_run.json`.
//!
//! ```sh
//! cargo run --release --example hierarchy_sweep [-- fast]
//! ```

use dcs3gd::algo::{run_experiment, Algo, RunReport};
use dcs3gd::comm::{AllReduceAlgo, Dragonfly, NetModel};
use dcs3gd::config::ExperimentConfig;
use dcs3gd::control::ControlPolicy;
use dcs3gd::simtime::ComputeModel;
use dcs3gd::util::Json;

/// ResNet-20 / ResNet-50 parameter counts — the paper's payloads.
const PAYLOADS: [(&str, usize); 3] =
    [("tiny", 10_000), ("resnet20", 271_690), ("resnet50", 25_600_000)];

fn sweep() {
    let net = NetModel::default();
    println!("== t_AR: flat ring vs hierarchical (default dragonfly links) ==");
    for (name, elems) in PAYLOADS {
        println!("\n{name} ({elems} f32):");
        println!(
            "{:>6} {:>6} {:>5} {:>12} {:>12} {:>9} {:>8}",
            "N", "G", "m", "t_ring", "t_hier", "global%", "speedup"
        );
        for n in [128usize, 256, 512, 1024] {
            let fly = Dragonfly::for_nodes(n);
            let ring = NetModel { algo: AllReduceAlgo::Ring, ..net }.allreduce_time(elems, n);
            let p = NetModel { algo: AllReduceAlgo::Hierarchical(fly), ..net }
                .allreduce_phases(elems, n);
            println!(
                "{n:>6} {:>6} {:>5} {ring:>12.3e} {:>12.3e} {:>8.1}% {:>7.2}x",
                fly.groups,
                fly.nodes_per_group,
                p.total(),
                100.0 * p.global_s / p.total().max(1e-30),
                ring / p.total(),
            );
        }
    }
    println!(
        "\nReading: the hierarchical schedule wins wherever the ring's 2(N-1)\n\
         latency terms dominate — from N=256 at the ResNet-20 payload — and\n\
         loses where bandwidth dominates (ResNet-50 at small N): exactly the\n\
         split a schedule-aware controller can arbitrate per window.\n"
    );
}

fn cfg(name: &str, policy: ControlPolicy, steps: u64) -> ExperimentConfig {
    ExperimentConfig::builder("linear")
        .name(name)
        .algo(Algo::DcS3gd)
        .nodes(8)
        .local_batch(16)
        .steps(steps)
        .eta_single(0.02)
        .base_batch(16)
        .data(2048, 256, 0.5)
        .compute(ComputeModel::uniform(1e-5))
        // latency-dominated flat fabric: the ring is the bottleneck
        .net(NetModel { alpha_s: 1.5e-6, beta_bytes_per_s: 2e6, algo: AllReduceAlgo::Ring })
        // ...but a fast dragonfly is available to the scheduler
        .dragonfly(Dragonfly {
            groups: 4,
            nodes_per_group: 2,
            alpha_local_s: 1e-6,
            beta_local: 1e9,
            alpha_global_s: 2e-6,
            beta_global: 2e8,
            ..Dragonfly::default()
        })
        .control_policy(policy)
        .k_bounds(1, 4)
        .out_dir("runs/hierarchy")
        .build()
}

fn summarize(label: &str, r: &RunReport) {
    let comm = r.control.comm_summary();
    println!(
        "{label:<24} sim {:>8.4}s | iter {:>9.6}s | train loss {:.4} | schedule switches {} | t_AR global {:.1}%",
        r.sim_time_s,
        r.mean_iter_time,
        r.final_train_loss,
        comm.schedule_switches,
        100.0 * comm.global_s / comm.total_s().max(1e-30),
    );
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "fast");
    let steps = if fast { 40 } else { 200 };

    sweep();

    println!("== end to end: fixed flat ring vs schedule_coupled (8 ranks) ==");
    let fixed = run_experiment(&cfg("hier_fixed_ring", ControlPolicy::Fixed, steps))?;
    let coupled = run_experiment(&cfg("hier_coupled", ControlPolicy::ScheduleCoupled, steps))?;
    summarize("fixed (flat ring)", &fixed);
    summarize("schedule_coupled", &coupled);
    let speedup = fixed.sim_time_s / coupled.sim_time_s;
    println!("\nschedule_coupled speedup: {speedup:.2}x");
    assert!(
        coupled.control.records().iter().any(|r| r.schedule.as_deref() == Some("hierarchical")),
        "controller never switched to the hierarchical schedule"
    );
    assert!(speedup > 1.0, "schedule_coupled must beat the fixed flat ring here");

    // The decision trace — (k, schedule) per window with the phase
    // split — must be in the metrics JSON export.
    let text = std::fs::read_to_string("runs/hierarchy/hier_coupled_run.json")?;
    let json = Json::parse(&text).map_err(|e| anyhow::anyhow!("bad run json: {e}"))?;
    let control = json.get("control").and_then(Json::as_arr).expect("control trace");
    let hier_windows = control
        .iter()
        .filter(|r| r.get("schedule").and_then(Json::as_str) == Some("hierarchical"))
        .count();
    println!(
        "decision trace: {} records in runs/hierarchy/hier_coupled_run.json ({} hierarchical windows)",
        control.len(),
        hier_windows
    );
    assert!(hier_windows > 0);
    let comm = json.get("comm").expect("comm phase summary");
    println!(
        "comm summary: local {:.6}s, global {:.6}s over {} rounds",
        comm.get("local_s").and_then(Json::as_f64).unwrap_or(0.0),
        comm.get("global_s").and_then(Json::as_f64).unwrap_or(0.0),
        comm.get("rounds").and_then(Json::as_f64).unwrap_or(0.0),
    );
    Ok(())
}
