//! Table I reproduction driver (E1): accuracy + throughput rows for
//! DC-S3GD across {model, global batch, N}, with SSGD reference rows —
//! the scaled-down analog of the paper's Table I (see DESIGN.md §3 for
//! the scaling map: ImageNet-1k/ResNet-50 → synthetic corpus/CIFAR-scale
//! CNNs, |B|/|X| ratios preserved: 1.5%…25% of the corpus per step).
//!
//! The compute model is calibrated to the paper's hardware (≈15 ms per
//! sample ⇒ ~65 img/s per dual-Skylake node for ResNet-50), so the
//! Speed column lands in the paper's units and range.
//!
//! ```sh
//! make artifacts && cargo run --release --example table1_sweep [-- fast] [-- ablation]
//! ```

use dcs3gd::algo::{run_experiment, Algo, RunReport};
use dcs3gd::config::ExperimentConfig;
use dcs3gd::simtime::ComputeModel;

struct Row {
    label: &'static str,
    variant: &'static str,
    local_batch: usize,
    nodes: usize,
}

fn available(variant: &str) -> bool {
    variant == "linear"
        || std::path::Path::new(&format!("artifacts/{variant}/meta.json")).exists()
}

fn run_row(row: &Row, algo: Algo, steps: u64) -> anyhow::Result<RunReport> {
    let cfg = ExperimentConfig::builder(row.variant)
        .name(format!("t1_{}_{}_n{}", row.label, algo.name(), row.nodes).leak())
        .algo(algo)
        .nodes(row.nodes)
        .local_batch(row.local_batch)
        .steps(steps)
        .eta_single(0.05)
        .base_batch(256)
        .momentum(0.9)
        .warmup(0.5, 1.0 / 6.0)
        .data(8192, 1024, 2.5)
        .compute(ComputeModel::default()) // paper-calibrated 15 ms/sample
        .build();
    run_experiment(&cfg)
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "fast");
    let ablation = std::env::args().any(|a| a == "ablation");
    let steps: u64 = if fast { 50 } else { 250 };

    // Paper Table I rows, scaled. |B|/corpus ratios bracket the paper's
    // 16k/1.28M … 128k/1.28M (= 1.25% … 10%).
    let rows = [
        Row { label: "tiny16", variant: "tiny_cnn_b16", local_batch: 16, nodes: 8 },   // |B|=128 (1.6%)
        Row { label: "tiny32", variant: "tiny_cnn_b32", local_batch: 32, nodes: 8 },   // |B|=256 (3.1%)
        Row { label: "tiny32w", variant: "tiny_cnn_b32", local_batch: 32, nodes: 16 }, // |B|=512 (6.3%)
        Row { label: "tiny64w", variant: "tiny_cnn_b64", local_batch: 64, nodes: 16 }, // |B|=1024 (12.5%)
        Row { label: "tiny64x", variant: "tiny_cnn_b64", local_batch: 64, nodes: 32 }, // |B|=2048 (25%) — the "128k" row
        Row { label: "small32", variant: "small_cnn_b32", local_batch: 32, nodes: 16 },// ResNet-101 analog
        Row { label: "res20", variant: "resnet20_b32", local_batch: 32, nodes: 16 },   // ResNet-152 analog
        Row { label: "mlp32", variant: "mlp_b32", local_batch: 32, nodes: 16 },        // VGG-16 analog
    ];

    if ablation {
        return run_ablation(steps);
    }

    println!("== Table I (scaled): DC-S3GD rows with SSGD reference ==\n");
    println!(
        "{:<10} {:>6} {:>4} | {:>9} {:>9} {:>11} | {:>13}",
        "row", "|B|", "N", "train acc", "val acc", "speed img/s", "ref SSGD val"
    );
    for row in &rows {
        if !available(row.variant) {
            println!("{:<10}  (skipped: artifacts/{} missing)", row.label, row.variant);
            continue;
        }
        let dc = run_row(row, Algo::DcS3gd, steps)?;
        let ssgd = run_row(row, Algo::Ssgd, steps)?;
        println!(
            "{:<10} {:>6} {:>4} | {:>8.1}% {:>8.1}% {:>11.0} | {:>12.1}%",
            row.label,
            row.nodes * row.local_batch,
            row.nodes,
            100.0 * (1.0 - dc.final_train_err),
            100.0 * (1.0 - dc.final_val_err),
            dc.sim_throughput,
            100.0 * (1.0 - ssgd.final_val_err),
        );
    }
    println!(
        "\nShape checks vs paper Table I: val acc ≈ SSGD reference on small/\n\
         medium |B|; accuracy drops on the largest |B| row; speed scales\n\
         with N and exceeds SSGD at equal N (overlap)."
    );
    Ok(())
}

fn run_ablation(steps: u64) -> anyhow::Result<()> {
    let variant = if available("tiny_cnn_b32") { "tiny_cnn_b32" } else { "linear" };
    println!("== ablations on {variant}, N=8, |B|=256 ==\n");

    println!("-- λ0 sweep (Eq. 17 variance control; 0 = S3GD) --");
    println!("{:>6} {:>10} {:>10}", "λ0", "train err", "val err");
    for lam0 in [0.0f32, 0.1, 0.2, 0.5, 1.0] {
        let mut cfg = ExperimentConfig::builder(variant)
            .name(format!("abl_lam{lam0}").leak())
            .algo(Algo::DcS3gd)
            .nodes(8)
            .local_batch(32)
            .steps(steps)
            .eta_single(0.05)
            .base_batch(256)
            .data(8192, 1024, 2.5)
            .compute(ComputeModel::default())
            .build();
        cfg.lam0 = lam0;
        let r = run_experiment(&cfg)?;
        println!("{lam0:>6.1} {:>9.1}% {:>9.1}%", r.final_train_err * 100.0, r.final_val_err * 100.0);
    }

    println!("\n-- max staleness sweep (§V extension) --");
    println!("{:>6} {:>10} {:>10} {:>12}", "k", "train err", "val err", "iter time");
    for k in [1usize, 2, 4] {
        let cfg = ExperimentConfig::builder(variant)
            .name(format!("abl_stale{k}").leak())
            .algo(Algo::DcS3gd)
            .nodes(8)
            .local_batch(32)
            .steps(steps)
            .staleness(k)
            .eta_single(0.05)
            .base_batch(256)
            .data(8192, 1024, 2.5)
            .compute(ComputeModel::default())
            .build();
        let r = run_experiment(&cfg)?;
        println!(
            "{k:>6} {:>9.1}% {:>9.1}% {:>12.4}",
            r.final_train_err * 100.0,
            r.final_val_err * 100.0,
            r.mean_iter_time
        );
    }

    println!("\n-- local optimizer (§V: LARS / Adam) --");
    println!("{:>10} {:>10} {:>10}", "optimizer", "train err", "val err");
    for opt in ["momentum", "lars", "adam"] {
        let cfg = ExperimentConfig::builder(variant)
            .name(format!("abl_opt_{opt}").leak())
            .algo(Algo::DcS3gd)
            .nodes(8)
            .local_batch(32)
            .steps(steps)
            .optimizer(opt)
            .eta_single(if opt == "adam" { 0.002 } else { 0.05 })
            .base_batch(256)
            .data(8192, 1024, 2.5)
            .compute(ComputeModel::default())
            .build();
        let r = run_experiment(&cfg)?;
        println!("{opt:>10} {:>9.1}% {:>9.1}%", r.final_train_err * 100.0, r.final_val_err * 100.0);
    }
    Ok(())
}
