//! Production-parity parameter-server demo: a compressed, sharded,
//! replicated DC-ASGD run that loses a worker mid-run, gains a fresh
//! one, and keeps converging — while the tier cuts its wire volume with
//! top-k sparsification.
//!
//! The tier under demonstration:
//!
//! * 8 workers push to a 4-shard server through per-worker error-
//!   feedback top-k codecs (ratio 0.1): gradients are priced at the
//!   compressed wire volume, decoded bitwise at tier ingress, and the
//!   Eq. 6 delay compensation (adaptive elementwise λ) is applied over
//!   the *decompressed* payload.
//! * Each shard serves pulls from 2 placement-aware replicas with read
//!   coalescing; pushes land at the epoch's primary and fan out to the
//!   secondaries through the contended optics.
//! * Rank 1 departs (no respawn) at t ≈ 20 ms and rank 8 joins at
//!   t ≈ 40 ms: the tier re-prices crossings from the live roster and
//!   the primary rotates with the membership epoch.
//! * The run JSON's `"ps"` block accounts for it all — and the wire
//!   bytes come in ≥ 3× under the dense equivalent.
//!
//! ```sh
//! cargo run --release --example ps_tier [-- fast]
//! ```

use dcs3gd::algo::{run_experiment, Algo};
use dcs3gd::comm::{AllReduceAlgo, Dragonfly, NetModel};
use dcs3gd::config::ExperimentConfig;
use dcs3gd::control::FaultPlan;
use dcs3gd::simtime::ComputeModel;
use dcs3gd::util::Json;

const INITIAL: usize = 8;
const DEPART_AT_S: f64 = 0.02;
const JOIN_AT_S: f64 = 0.04;

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "fast");
    let steps: u64 = if fast { 40 } else { 120 };

    let d = Dragonfly { groups: 2, nodes_per_group: 4, ..Dragonfly::default() };
    let cfg = ExperimentConfig::builder("linear")
        .name("ps_tier")
        .algo(Algo::DcAsgd)
        .nodes(INITIAL)
        .local_batch(8)
        .steps(steps)
        .eta_single(0.02)
        .base_batch(8)
        .data(2048, 512, 0.5)
        .compute(ComputeModel::uniform(1e-3))
        .net(NetModel {
            alpha_s: 1.5e-6,
            beta_bytes_per_s: 10e9,
            algo: AllReduceAlgo::Hierarchical(d),
        })
        .compress_topk(0.1)
        .ps_shards(4)
        .ps_replicas(2)
        .ps_lambda("adaptive")
        .faults(FaultPlan::new().depart(1, DEPART_AT_S))
        .join(INITIAL, JOIN_AT_S)
        .join_warmup(4)
        .out_dir("runs/ps_tier")
        .build();

    println!(
        "== ps tier: {INITIAL} workers, 4 shards x 2 replicas, top-k 0.1, \
         −rank1 @ {DEPART_AT_S}s, +rank{INITIAL} @ {JOIN_AT_S}s, {steps} steps ==\n"
    );

    let report = run_experiment(&cfg)?;

    // The realized membership trajectory.
    println!("{:>6} {:>6} {:>10} {:>6} {:>7}", "epoch", "world", "sim_time", "left", "joined");
    for tr in report.epochs.transitions() {
        println!(
            "{:>6} {:>6} {:>9.4}s {:>6} {:>7}",
            tr.epoch,
            tr.world,
            tr.sim_time,
            tr.departed.len(),
            tr.joined.len(),
        );
    }

    // Acceptance 1: the world really went 8 -> 7 -> 8.
    assert_eq!(
        report.epochs.worlds(),
        vec![INITIAL, INITIAL - 1, INITIAL],
        "epoch trajectory wrong"
    );

    // Acceptance 2: the tier's accounting landed in the report and the
    // top-k codecs cut the client wire volume >= 3x.
    let ps = report.ps.as_ref().expect("ps block");
    let num = |k: &str| ps.get(k).and_then(Json::as_f64).unwrap_or(0.0);
    println!(
        "\nps block: {} shards x {} replicas, {} epochs | {} pushes, {} pulls \
         ({} coalesced, {} replica transfers)",
        num("shards"),
        num("replicas"),
        num("epochs"),
        num("pushes"),
        num("pulls"),
        num("coalesced"),
        num("repl_transfers"),
    );
    let cut = num("wire_cut_x");
    println!(
        "wire: {:.0} dense bytes -> {:.0} compressed ({cut:.1}x cut)",
        num("dense_bytes"),
        num("wire_bytes"),
    );
    assert_eq!(ps.get("enabled"), Some(&Json::Bool(true)));
    assert_eq!(num("shards") as usize, 4);
    assert_eq!(num("replicas") as usize, 2);
    assert_eq!(num("epochs") as usize, 3, "tier saw both membership epochs");
    assert!(cut >= 3.0, "top-k 0.1 must cut wire bytes >= 3x, got {cut:.2}");

    // Acceptance 3: the run keeps converging through churn +
    // compression + replication.
    let early = report.recorder.mean_loss_between(0, 4);
    assert!(report.final_train_loss.is_finite(), "loss diverged");
    assert!(
        report.final_train_loss < early,
        "no progress: final {} vs early {}",
        report.final_train_loss,
        early
    );
    let err_bound = if fast { 0.9 } else { 0.85 };
    assert!(
        report.final_val_err < err_bound,
        "val err {} above {err_bound}",
        report.final_val_err
    );
    println!(
        "loss {early:.4} -> {:.4} | val err {:.1}% | sim {:.4}s",
        report.final_train_loss,
        100.0 * report.final_val_err,
        report.sim_time_s
    );

    // Acceptance 4: the "ps" block round-trips through the run JSON.
    let json_path = "runs/ps_tier/ps_tier_run.json";
    let parsed = Json::parse(&std::fs::read_to_string(json_path)?)
        .map_err(|e| anyhow::anyhow!("bad metrics JSON: {e}"))?;
    let ps_json = parsed
        .get("ps")
        .ok_or_else(|| anyhow::anyhow!("no ps block in {json_path}"))?;
    assert_eq!(ps_json.get("enabled"), Some(&Json::Bool(true)));
    assert_eq!(ps_json.get("wire_cut_x"), ps.get("wire_cut_x"));
    println!("ps block round-tripped through {json_path}");

    println!("\ncompressed, sharded, replicated, churned — and it still converged.");
    Ok(())
}
