//! Gradient-compression sweep: dense vs error-feedback top-k vs QSGD
//! on the same DC-S3GD run, then an end-to-end `compress_coupled` run
//! showing the control plane co-tuning (k, schedule, ratio) online.
//!
//! Part 1 holds the step budget fixed on a wire-bound fabric and sweeps
//! the compressor: the table shows the achieved per-rank wire bytes,
//! the simulated wall-clock, and the final loss — compression buys
//! wall-clock, error feedback holds convergence.
//!
//! Part 2 starts `compress_coupled` at a deliberately lazy ratio on the
//! same fabric: the policy must tighten the ratio until the collective
//! hides behind the window's compute, and the (k, schedule, ratio)
//! decision trace must land in the run's metrics JSON.
//!
//! ```sh
//! cargo run --release --example compression_sweep [-- fast]
//! ```

use dcs3gd::algo::{run_experiment, Algo, RunReport};
use dcs3gd::comm::{AllReduceAlgo, NetModel};
use dcs3gd::compress::CompressorKind;
use dcs3gd::config::ExperimentConfig;
use dcs3gd::control::ControlPolicy;
use dcs3gd::simtime::ComputeModel;
use dcs3gd::util::Json;

const NODES: usize = 8;

fn base(steps: u64, name: &str) -> ExperimentConfig {
    ExperimentConfig::builder("linear")
        .name(name)
        .algo(Algo::DcS3gd)
        .nodes(NODES)
        .local_batch(16)
        .steps(steps)
        .eta_single(0.05)
        .base_batch(16)
        .data(4096, 512, 0.5)
        .compute(ComputeModel::uniform(2e-4)) // t_C = 3.2 ms / step
        .net(NetModel { alpha_s: 1.5e-6, beta_bytes_per_s: 2e6, algo: AllReduceAlgo::Ring })
        .build()
}

fn run_scheme(
    steps: u64,
    name: &str,
    kind: CompressorKind,
    ratio: f32,
    bits: u32,
) -> RunReport {
    let mut cfg = base(steps, name);
    cfg.compress.kind = kind;
    cfg.compress.ratio = ratio;
    cfg.compress.bits = bits;
    run_experiment(&cfg).expect("run")
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "fast");
    let steps: u64 = if fast { 48 } else { 160 };

    println!("== gradient compression sweep: {NODES} ranks, wire-bound ring, {steps} steps ==\n");
    println!(
        "{:<16} {:>12} {:>12} {:>12} {:>10}",
        "scheme", "wire B/round", "sim time", "final loss", "val err"
    );
    let dense = run_scheme(steps, "sweep_dense", CompressorKind::None, 0.05, 8);
    let schemes: Vec<(&str, RunReport)> = vec![
        ("topk r=0.05", run_scheme(steps, "sweep_topk05", CompressorKind::TopK, 0.05, 8)),
        ("topk r=0.01", run_scheme(steps, "sweep_topk01", CompressorKind::TopK, 0.01, 8)),
        ("qsgd b=8", run_scheme(steps, "sweep_qsgd8", CompressorKind::Qsgd, 0.05, 8)),
        ("qsgd b=4", run_scheme(steps, "sweep_qsgd4", CompressorKind::Qsgd, 0.05, 4)),
    ];
    let print_row = |name: &str, r: &RunReport| {
        println!(
            "{name:<16} {:>12.0} {:>11.4}s {:>12.4} {:>9.1}%",
            r.control.compress_summary().mean_wire_bytes(),
            r.sim_time_s,
            r.final_train_loss,
            100.0 * r.final_val_err,
        );
    };
    print_row("dense", &dense);
    for (name, r) in &schemes {
        print_row(name, r);
    }

    // Acceptance 1: compression buys simulated wall-clock on the
    // wire-bound fabric…
    for (name, r) in &schemes {
        assert!(
            r.sim_time_s < dense.sim_time_s,
            "{name} not faster than dense: {} vs {}",
            r.sim_time_s,
            dense.sim_time_s
        );
    }
    // …and error feedback keeps every scheme inside the dense loss
    // envelope.
    for (name, r) in &schemes {
        assert!(
            r.final_train_loss < dense.final_train_loss * 1.5 + 0.25,
            "{name} fell out of the dense loss envelope: {} vs {}",
            r.final_train_loss,
            dense.final_train_loss
        );
    }
    println!("\nall compressed schemes faster than dense, losses inside the envelope");

    // Part 2: compress_coupled co-tunes (k, schedule, ratio) online.
    let mut cfg = base(steps, "sweep_coupled");
    cfg.compute = ComputeModel::uniform(2e-5); // tighter budget: t_C = 0.32 ms
    cfg.compress.kind = CompressorKind::TopK;
    cfg.compress.ratio = 0.25; // deliberately lazy start
    cfg.control.policy = ControlPolicy::CompressCoupled;
    cfg.control.k_max = 4;
    cfg.out_dir = Some("runs/compression".into());
    let coupled = run_experiment(&cfg)?;
    let s = coupled.control.compress_summary();
    println!(
        "\ncompress_coupled: ratio 0.25 -> {} over {} change(s), mean wire {:.0} B/round",
        s.final_ratio,
        s.ratio_changes,
        s.mean_wire_bytes()
    );
    assert!(s.ratio_changes >= 1, "the policy never moved the ratio");
    assert!(s.final_ratio < 0.25, "the policy never tightened the ratio");

    // Acceptance 2: the (k, schedule, ratio) decision trace landed in
    // the metrics JSON.
    let json_path = "runs/compression/sweep_coupled_run.json";
    let parsed = Json::parse(&std::fs::read_to_string(json_path)?)
        .map_err(|e| anyhow::anyhow!("bad metrics JSON: {e}"))?;
    let control = parsed
        .get("control")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("no control trace in {json_path}"))?;
    let with_ratio = control
        .iter()
        .filter(|r| {
            r.get("schedule").unwrap().as_str().is_some()
                && r.get("compress_ratio").unwrap().as_f64().is_some()
                && r.get("k").unwrap().as_f64().is_some()
        })
        .count();
    assert!(with_ratio > 0, "no (k, schedule, ratio) records in {json_path}");
    let summary = parsed
        .get("compress")
        .ok_or_else(|| anyhow::anyhow!("no compress summary in {json_path}"))?;
    assert_eq!(summary.get("kind").and_then(Json::as_str), Some("topk"));
    println!(
        "decision trace: {} (k, schedule, ratio) records + compress summary in {json_path}"
    );
    println!("\ncompressed the wire, kept the loss, and the control plane tuned it live.");
    Ok(())
}
