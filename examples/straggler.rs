//! Straggler / overlap study (E3): how SSGD and DC-S3GD iteration time
//! respond to slow nodes and slow networks — the Eq. 13 vs Eq. 14
//! story, plus the §II-A straggler sensitivity claim.
//!
//! ```sh
//! cargo run --release --example straggler
//! ```

use dcs3gd::algo::{run_experiment, Algo, RunReport};
use dcs3gd::comm::{AllReduceAlgo, NetModel};
use dcs3gd::config::ExperimentConfig;
use dcs3gd::simtime::ComputeModel;

fn run(algo: Algo, compute: ComputeModel, net: NetModel) -> anyhow::Result<RunReport> {
    let cfg = ExperimentConfig::builder("linear")
        .name(format!("straggler_{}", algo.name()).leak())
        .algo(algo)
        .nodes(8)
        .local_batch(32)
        .steps(60)
        .eta_single(0.02)
        .base_batch(32)
        .data(4096, 512, 0.6)
        .compute(compute)
        .net(net)
        .build();
    run_experiment(&cfg)
}

fn main() -> anyhow::Result<()> {
    let base_net = NetModel { alpha_s: 1.5e-6, beta_bytes_per_s: 2e8, algo: AllReduceAlgo::Ring };
    let base_compute = ComputeModel::uniform(2e-4); // 6.4 ms/batch

    println!("8 workers, batch 32, linear model ({}k params)\n", 769);

    println!("== network speed sweep: per-iteration time (Eq. 13 vs 14) ==");
    println!(
        "{:>12} {:>12} {:>12} {:>12} {:>10}",
        "β (B/s)", "ssgd", "dcs3gd", "speedup", "hidden?"
    );
    for beta in [1e9, 4e8, 2e8, 1e8, 5e7] {
        let net = NetModel { beta_bytes_per_s: beta, ..base_net };
        let s = run(Algo::Ssgd, base_compute.clone(), net)?;
        let d = run(Algo::DcS3gd, base_compute.clone(), net)?;
        let hidden = if d.mean_iter_time < s.mean_iter_time * 0.99 { "yes" } else { "no" };
        println!(
            "{beta:>12.0e} {:>12.5} {:>12.5} {:>11.2}x {:>10}",
            s.mean_iter_time,
            d.mean_iter_time,
            s.mean_iter_time / d.mean_iter_time,
            hidden
        );
    }

    println!("\n== straggler sweep: one worker k× slower ==");
    println!(
        "{:>8} {:>12} {:>12} {:>12}",
        "factor", "ssgd", "dcs3gd", "ssgd/dcs3gd"
    );
    for factor in [1.0, 1.5, 2.0, 4.0] {
        let compute = ComputeModel::uniform(2e-4).with_straggler(3, factor, 8);
        let s = run(Algo::Ssgd, compute.clone(), base_net)?;
        let d = run(Algo::DcS3gd, compute, base_net)?;
        println!(
            "{factor:>8.1} {:>12.5} {:>12.5} {:>12.2}",
            s.mean_iter_time,
            d.mean_iter_time,
            s.mean_iter_time / d.mean_iter_time
        );
    }
    println!(
        "\nNote: with staleness 1 a persistent straggler still gates every\n\
         round (the collective needs all posts) — the overlap hides the\n\
         *network*, not persistent compute imbalance; transient jitter\n\
         (below) is partially absorbed by the one-iteration slack."
    );

    println!("\n== compute jitter sweep (transient stragglers) ==");
    println!("{:>8} {:>12} {:>12}", "jitter", "ssgd", "dcs3gd");
    for jitter in [0.0, 0.2, 0.5] {
        let compute = ComputeModel::uniform(2e-4).with_jitter(jitter);
        let s = run(Algo::Ssgd, compute.clone(), base_net)?;
        let d = run(Algo::DcS3gd, compute, base_net)?;
        println!("{jitter:>8.1} {:>12.5} {:>12.5}", s.mean_iter_time, d.mean_iter_time);
    }
    Ok(())
}
