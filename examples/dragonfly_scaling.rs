//! Dragonfly scaling study: the paper's testbed fabric (Cray Aries,
//! dragonfly topology, §IV-B) modelled explicitly — where does the
//! Eq. 14 overlap stop hiding communication as the cluster and the
//! model grow?
//!
//! Pure cost-model analysis (runs in milliseconds):
//! for each (model size, node count), compare
//!   t_SSGD    = t_C + t_AR^dragonfly
//!   t_DC-S3GD = max(t_C, t_AR^dragonfly)
//! with t_C from the paper-calibrated 15 ms/sample Skylake model at
//! local batch 512 (the paper's large-memory CPU setting).
//!
//! ```sh
//! cargo run --release --example dragonfly_scaling
//! ```

use dcs3gd::comm::Dragonfly;

fn main() {
    let local_batch = 512usize;
    let t_c = 15e-3 * local_batch as f64; // 7.68 s per local batch

    println!(
        "dragonfly fabric (Aries-like): local α=1.2µs β=14GB/s, global α=2.2µs β=4.7GB/s"
    );
    println!("t_C = {t_c:.2}s (local batch {local_batch} @ 15 ms/sample)\n");
    println!(
        "{:<14} {:>6} {:>12} {:>12} {:>12} {:>9} {:>8}",
        "model", "N", "t_AR", "t_ssgd", "t_dcs3gd", "speedup", "hidden%"
    );

    // (name, params) — paper's models plus a large-model stress point.
    let models = [
        ("ResNet-50", 25_600_000usize),
        ("ResNet-101", 44_500_000),
        ("ResNet-152", 60_200_000),
        ("VGG-16", 138_000_000),
        ("1B-param", 1_000_000_000),
    ];

    for (name, params) in models {
        for n in [32usize, 64, 128, 512] {
            let fly = Dragonfly::for_nodes(n);
            let t_ar = fly.hierarchical_allreduce_time(params, n);
            let t_ssgd = t_c + t_ar;
            let t_dc = t_c.max(t_ar);
            let hidden = 100.0 * (1.0 - (t_dc - t_c).max(0.0) / t_ar.max(1e-30));
            println!(
                "{name:<14} {n:>6} {:>11.4}s {:>11.4}s {:>11.4}s {:>8.2}x {:>7.1}%",
                t_ar,
                t_ssgd,
                t_dc,
                t_ssgd / t_dc,
                hidden
            );
        }
        println!();
    }

    println!(
        "Reading: at the paper's scales (≤138M params, ≤128 nodes) t_AR ≪ t_C\n\
         on CPU nodes, so DC-S3GD hides communication completely — consistent\n\
         with the paper's speed column scaling ~linearly in N. The crossover\n\
         (overlap no longer fully hiding comm) appears only at ~1B params,\n\
         where max(t_C, t_AR) is still up to 2× better than t_C + t_AR."
    );
}
