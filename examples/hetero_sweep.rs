//! Heterogeneous-fleet sweep: fixed-k DC-S3GD vs the per-worker
//! staleness engines (`dyn_ssp`, `sgs`) on the same mixed-tier + spot +
//! diurnal fleet (see docs/heterogeneity.md).
//!
//! The scenario is selected *structurally*: the example scans seeds for
//! a resolved hetero profile with a real tier mix among the ranks that
//! survive the spot revocation, so the comparison is never vacuous.
//! Fixed-k pays every window at the slowest tier's pace; `dyn_ssp`
//! rebalances each window's per-rank step budget from the piggybacked
//! compute split, so the same scheduled-step budget finishes in less
//! simulated wall-clock — the acceptance assertion at the bottom.
//!
//! ```sh
//! cargo run --release --example hetero_sweep [-- fast]
//! ```

use dcs3gd::algo::{run_experiment, Algo, RunReport};
use dcs3gd::config::ExperimentConfig;
use dcs3gd::hetero::{HeteroConfig, HeteroProfile};
use dcs3gd::simtime::ComputeModel;
use dcs3gd::util::Json;

const NODES: usize = 8;

fn fleet() -> HeteroConfig {
    HeteroConfig {
        enabled: true,
        tiers: vec![1.0, 4.0],
        spot_fraction: 0.3,
        spot_mtbf_s: 0.5,
        spot_correlation: 0.5,
        diurnal_amplitude: 0.2,
        diurnal_period_s: 0.8,
        link_spread: 0.3,
        ..HeteroConfig::default()
    }
}

/// First seed whose resolved profile realizes the scenario: 1–2 spot
/// revocations landing mid-run, and at least two ranks of each tier
/// among the survivors (so the mixed-tier comparison is never
/// vacuous). Pure profile arithmetic — no training runs.
fn pick_seed(h: &HeteroConfig) -> u64 {
    (0..4096u64)
        .find(|&s| {
            let p = HeteroProfile::resolve(h, s, NODES, NODES, 2);
            let revoked: Vec<usize> = p.revocations.iter().map(|r| r.0).collect();
            let timing_ok = !p.revocations.is_empty()
                && p.revocations.len() <= 2
                && p.revocations.iter().all(|&(_, t)| (0.3..=0.7).contains(&t));
            let survivors = |tier: f64| {
                (0..NODES).filter(|r| !revoked.contains(r) && p.tier[*r] == tier).count()
            };
            timing_ok && survivors(1.0) >= 2 && survivors(4.0) >= 2
        })
        .expect("a seed realizing the mixed-tier + spot scenario exists in 0..4096")
}

fn run_engine(algo: Algo, seed: u64, steps: u64, out: bool) -> RunReport {
    let mut cfg = ExperimentConfig::builder("linear")
        .name(&format!("hetero_{}", algo.name()))
        .algo(algo)
        .nodes(NODES)
        .local_batch(16)
        .steps(steps)
        .seed(seed)
        .eta_single(0.05)
        .base_batch(16)
        .data(4096, 512, 0.5)
        .compute(ComputeModel::uniform(1e-3)) // t_C = 16 ms / step at tier 1
        .staleness(8)
        .k_bounds(2, 8)
        .hetero(fleet())
        .build();
    if out {
        cfg.out_dir = Some("runs/hetero".into());
    }
    run_experiment(&cfg).expect("hetero run")
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "fast");
    let steps: u64 = if fast { 64 } else { 128 };

    let seed = pick_seed(&fleet());
    let profile = HeteroProfile::resolve(&fleet(), seed, NODES, NODES, 2);
    println!("== heterogeneous fleet: {NODES} ranks, tiers {:?}, seed {seed} ==", profile.tier);
    println!(
        "spot revocations: {:?} | diurnal ±20% | link spread 0.3 | {steps} scheduled steps\n",
        profile.revocations
    );

    println!("{:<10} {:>12} {:>12} {:>10} {:>8}", "engine", "sim time", "final loss", "val err", "epochs");
    let rows: Vec<(Algo, RunReport)> = vec![
        (Algo::DcS3gd, run_engine(Algo::DcS3gd, seed, steps, false)),
        (Algo::DynSsp, run_engine(Algo::DynSsp, seed, steps, true)),
        (Algo::Sgs, run_engine(Algo::Sgs, seed, steps, false)),
    ];
    for (algo, r) in &rows {
        println!(
            "{:<10} {:>11.4}s {:>12.4} {:>9.1}% {:>8}",
            algo.name(),
            r.sim_time_s,
            r.final_train_loss,
            100.0 * r.final_val_err,
            r.epochs.worlds().len(),
        );
    }
    let fixed = &rows[0].1;
    let dyn_ssp = &rows[1].1;

    // Acceptance 1: the per-worker bounds buy simulated wall-clock on
    // the same scheduled-step budget — fixed-k pays every window at the
    // slowest tier's pace, dyn_ssp rebalances it.
    assert!(
        dyn_ssp.sim_time_s < fixed.sim_time_s,
        "dyn_ssp must finish the budget faster than fixed-k: {} vs {}",
        dyn_ssp.sim_time_s,
        fixed.sim_time_s
    );
    // …without falling out of the fixed-k loss envelope.
    for (algo, r) in &rows[1..] {
        assert!(
            r.final_train_loss < fixed.final_train_loss * 1.5 + 0.25,
            "{} fell out of the fixed-k loss envelope: {} vs {}",
            algo.name(),
            r.final_train_loss,
            fixed.final_train_loss
        );
    }
    // …and the spot revocation really shrank the run.
    assert!(
        fixed.epochs.worlds().len() >= 2 && dyn_ssp.epochs.worlds().len() >= 2,
        "the spot revocation never landed"
    );
    println!(
        "\ndyn_ssp: {:.1}% of the fixed-k wall-clock on the same step budget",
        100.0 * dyn_ssp.sim_time_s / fixed.sim_time_s
    );

    // Acceptance 2: the run JSON is self-describing — the resolved
    // profile landed under "hetero".
    let json_path = "runs/hetero/hetero_dyn_ssp_run.json";
    let parsed = Json::parse(&std::fs::read_to_string(json_path)?)
        .map_err(|e| anyhow::anyhow!("bad metrics JSON: {e}"))?;
    let block = parsed
        .get("hetero")
        .ok_or_else(|| anyhow::anyhow!("no hetero block in {json_path}"))?;
    anyhow::ensure!(block.get("enabled").and_then(Json::as_bool) == Some(true));
    anyhow::ensure!(
        block.get("tier").and_then(Json::as_arr).map(|t| t.len()) == Some(NODES),
        "hetero block must carry the capacity-sized tier vector"
    );
    anyhow::ensure!(
        !block.get("revocations").and_then(Json::as_arr).unwrap_or(&[]).is_empty(),
        "hetero block must carry the derived revocations"
    );
    println!("hetero profile exported in {json_path}");
    println!("\nmixed fleet survived, per-worker bounds paid off, trace self-describing.");
    Ok(())
}
