//! Figure-1 / E2+E4 driver: convergence curves for every algorithm at
//! several (N, global-batch) settings, plus the §III-D.2 weight-distance
//! comparison between DC-S3GD and DC-ASGD.
//!
//! Emits `runs/fig1/<name>_{steps,evals}.csv` for each run and prints a
//! compact error-curve table (the CSV series are the Figure 1 analog).
//!
//! ```sh
//! cargo run --release --example convergence_compare [-- fast] [-- distances]
//! ```

use dcs3gd::algo::{run_experiment, Algo, RunReport};
use dcs3gd::config::ExperimentConfig;
use dcs3gd::simtime::ComputeModel;

fn run(algo: Algo, nodes: usize, local_batch: usize, steps: u64) -> anyhow::Result<RunReport> {
    let cfg = ExperimentConfig::builder("linear")
        .name(format!("fig1_{}_n{}_b{}", algo.name(), nodes, nodes * local_batch).leak())
        .algo(algo)
        .nodes(nodes)
        .local_batch(local_batch)
        .steps(steps)
        .eta_single(0.04)
        .base_batch(32)
        .data(8192, 1024, 2.0)
        .compute(ComputeModel::uniform(1e-4))
        .eval_every((steps / 10).max(1), 8)
        .out_dir("runs/fig1")
        .build();
    run_experiment(&cfg)
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "fast");
    let steps: u64 = if fast { 80 } else { 400 };

    // Figure 1 reproduces (N, |B|) combinations; scaled per DESIGN.md §3.
    let combos: &[(usize, usize)] = if fast {
        &[(4, 32), (8, 32)]
    } else {
        &[(4, 32), (8, 32), (8, 64), (16, 32)]
    };
    let algos = [Algo::Ssgd, Algo::S3gd, Algo::DcS3gd, Algo::Asgd, Algo::DcAsgd];

    println!("== Figure 1 analog: final/best val error by (N, |B|) and algorithm ==\n");
    println!(
        "{:<8} {:<8} | {:>8} {:>8} {:>8} {:>8} {:>8}",
        "N", "|B|", "ssgd", "s3gd", "dcs3gd", "asgd", "dcasgd"
    );
    let mut dist_rows = Vec::new();
    for &(n, lb) in combos {
        let mut errs = Vec::new();
        for algo in algos {
            let rep = run(algo, n, lb, steps)?;
            if matches!(algo, Algo::DcS3gd | Algo::DcAsgd) {
                dist_rows.push((algo, n, rep.mean_dist_to_avg));
            }
            errs.push(rep.best_val_err);
        }
        println!(
            "{:<8} {:<8} | {:>7.1}% {:>7.1}% {:>7.1}% {:>7.1}% {:>7.1}%",
            n,
            n * lb,
            errs[0] * 100.0,
            errs[1] * 100.0,
            errs[2] * 100.0,
            errs[3] * 100.0,
            errs[4] * 100.0
        );
    }

    println!("\n== §III-D.2: staleness distance vs N (E4) ==");
    println!("{:<8} {:>6} {:>14}", "algo", "N", "mean distance");
    dist_rows.sort_by_key(|(a, n, _)| (a.name(), *n));
    for (algo, n, d) in &dist_rows {
        println!("{:<8} {:>6} {:>14.4e}", algo.name(), n, d);
    }
    println!(
        "\nExpected shape: dcasgd distance grows ~linearly in N; dcs3gd's\n\
         distance-to-average grows much more slowly (the paper's argument\n\
         for decentralized averaging). CSV series: runs/fig1/*.csv"
    );
    Ok(())
}
