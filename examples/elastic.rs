//! Elastic control plane demo: dynamic staleness vs fixed-k under an
//! injected 2× straggler, plus fault-tolerant recovery from a mid-run
//! worker kill.
//!
//! The acceptance scenario for the control plane: with one worker
//! running 2× slower, the `dss_pid` policy must reach ≥10% lower
//! virtual wall-clock than fixed-k DC-S3GD at (near-)equal final loss,
//! and the per-window k/λ decision trace must land in the metrics JSON
//! (`runs/elastic/*_run.json`).
//!
//! ```sh
//! cargo run --release --example elastic [-- fast]
//! ```

use dcs3gd::algo::{run_experiment, Algo, RunReport};
use dcs3gd::comm::{AllReduceAlgo, NetModel};
use dcs3gd::config::ExperimentConfig;
use dcs3gd::control::{ControlPolicy, FaultPlan};
use dcs3gd::simtime::ComputeModel;
use dcs3gd::util::Json;

const NODES: usize = 8;
const STRAGGLER_RANK: usize = 3;
const STRAGGLER_FACTOR: f64 = 2.0;

fn cfg(name: &str, policy: ControlPolicy, steps: u64) -> ExperimentConfig {
    ExperimentConfig::builder("linear")
        .name(name)
        .algo(Algo::DcS3gd)
        .nodes(NODES)
        .local_batch(32)
        .steps(steps)
        .eta_single(0.02)
        .base_batch(32)
        .data(4096, 512, 0.5)
        // one worker persistently 2× slower — the §II-A straggler
        .compute(ComputeModel::uniform(2e-4).with_straggler(
            STRAGGLER_RANK,
            STRAGGLER_FACTOR,
            NODES,
        ))
        // network slow enough that k=1 cannot hide t_AR (Eq. 14)
        .net(NetModel { alpha_s: 1.5e-6, beta_bytes_per_s: 1.2e6, algo: AllReduceAlgo::Ring })
        .control_policy(policy)
        .k_bounds(1, 6)
        .out_dir("runs/elastic")
        .build()
}

fn summarize(label: &str, r: &RunReport) {
    println!(
        "{label:<22} sim {:>7.3}s | iter {:>8.5}s | train loss {:.4} | val err {:>5.1}% | k changes {}",
        r.sim_time_s,
        r.mean_iter_time,
        r.final_train_loss,
        100.0 * r.final_val_err,
        r.control.k_changes(),
    );
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "fast");
    let steps: u64 = if fast { 120 } else { 300 };

    println!(
        "== elastic staleness: {NODES} workers, rank {STRAGGLER_RANK} running {STRAGGLER_FACTOR}× slow ==\n"
    );

    let fixed = run_experiment(&cfg("elastic_fixed", ControlPolicy::Fixed, steps))?;
    let adaptive = run_experiment(&cfg("elastic_dss_pid", ControlPolicy::DssPid, steps))?;

    summarize("fixed-k dcs3gd", &fixed);
    summarize("dss_pid dcs3gd", &adaptive);

    let speedup = fixed.sim_time_s / adaptive.sim_time_s;
    let loss_ratio = adaptive.final_train_loss / fixed.final_train_loss;
    println!(
        "\nvirtual wall-clock: {speedup:.2}× faster with dss_pid ({:.1}% lower)",
        100.0 * (1.0 - adaptive.sim_time_s / fixed.sim_time_s)
    );
    println!("final-loss ratio adaptive/fixed: {loss_ratio:.3}");

    // The k trajectory the controller walked (from the decision trace).
    let recs = adaptive.control.records();
    let ks: Vec<usize> = recs.iter().map(|r| r.k).collect();
    let (k_first, k_last) = (ks.first().copied().unwrap_or(1), ks.last().copied().unwrap_or(1));
    println!("k trajectory: start {k_first} → end {k_last} over {} windows", ks.len());

    // Acceptance: ≥10% lower virtual wall-clock at (near-)equal loss.
    assert!(
        adaptive.sim_time_s <= 0.90 * fixed.sim_time_s,
        "adaptive {:.3}s not ≥10% below fixed {:.3}s",
        adaptive.sim_time_s,
        fixed.sim_time_s
    );
    assert!(
        loss_ratio <= 1.10,
        "adaptive final loss {:.4} strayed >10% above fixed {:.4}",
        adaptive.final_train_loss,
        fixed.final_train_loss
    );

    // Decision trace must be in the metrics JSON export.
    let json_path = "runs/elastic/elastic_dss_pid_run.json";
    let parsed = Json::parse(&std::fs::read_to_string(json_path)?)
        .map_err(|e| anyhow::anyhow!("bad metrics JSON: {e}"))?;
    let trace = parsed
        .get("control")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("no control trace in {json_path}"))?;
    assert!(!trace.is_empty(), "empty decision trace in {json_path}");
    println!("decision trace: {} records in {json_path}", trace.len());

    // == fault tolerance: kill a worker mid-run, recover from snapshot ==
    println!("\n== fault tolerance: kill rank 2 mid-run (heartbeat detect + snapshot restore) ==\n");
    let mut kcfg = cfg("elastic_kill", ControlPolicy::LambdaCoupled, steps);
    kcfg.control.faults = FaultPlan::new().kill(2, 1.0);
    kcfg.control.snapshot_every = 5;
    let killed = run_experiment(&kcfg)?;
    summarize("lambda_coupled+kill", &killed);
    for e in killed.control.events() {
        println!(
            "  event @ iter {:>4} (t={:.3}s, worker {}): {}",
            e.iteration,
            e.sim_time,
            e.worker,
            e.event.as_deref().unwrap_or("")
        );
    }
    assert!(
        killed.control.events().iter().any(|e| {
            e.event.as_deref().map(|s| s.contains("restored_from")).unwrap_or(false)
        }),
        "kill was never detected/recovered"
    );
    assert!(killed.final_train_loss.is_finite());
    println!("\nrecovered and converged: final val err {:.1}%", 100.0 * killed.final_val_err);
    Ok(())
}
