//! End-to-end validation driver (DESIGN.md E5): the full three-layer
//! stack on a real workload.
//!
//! Trains the ResNet-20-family CNN (AOT-lowered jax fwd/bwd, executed
//! through PJRT from the rust coordinator) for several hundred steps of
//! DC-S3GD on 8 simulated workers over the synthetic ImageNet stand-in,
//! logging the loss curve and validation error — the run recorded in
//! EXPERIMENTS.md §E5.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_train [-- fast]
//! ```
//!
//! `fast` cuts steps for smoke runs. Falls back from `small_cnn_b32` to
//! `tiny_cnn_b32` to `linear` depending on available artifacts.

use dcs3gd::algo::{run_experiment, Algo};
use dcs3gd::config::ExperimentConfig;
use dcs3gd::simtime::ComputeModel;

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "fast");
    let variant = ["small_cnn_b32", "tiny_cnn_b32"]
        .iter()
        .find(|v| std::path::Path::new(&format!("artifacts/{v}/meta.json")).exists())
        .copied()
        .unwrap_or("linear");
    let steps = if fast { 60 } else { 300 };

    let cfg = ExperimentConfig::builder(variant)
        .name("e2e_train")
        .algo(Algo::DcS3gd)
        .nodes(8)
        .local_batch(32)
        .steps(steps)
        .eta_single(0.05)
        .base_batch(256)
        .momentum(0.9)
        .warmup(0.5, 1.0 / 6.0)
        .data(8192, 1024, 2.5)
        // drive virtual time from the measured PJRT step time: the
        // simulated cluster inherits this machine's real compute cost
        .time_from_wall(variant != "linear")
        .compute(ComputeModel::uniform(2e-3))
        .eval_every(25, 8)
        .out_dir("runs/e2e")
        .build();

    eprintln!(
        "e2e: {} | DC-S3GD | N={} | global batch {} | {} steps (≈{:.1} epochs)",
        variant,
        cfg.nodes,
        cfg.global_batch(),
        cfg.steps,
        (cfg.steps as f64 * cfg.global_batch() as f64) / cfg.n_train as f64,
    );

    let t0 = std::time::Instant::now();
    let report = run_experiment(&cfg)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\n== loss curve (mean over workers, every 10 iters) ==");
    let steps_rec = report.recorder.steps();
    let iters = steps_rec.iter().map(|s| s.iteration).max().unwrap() + 1;
    for it in (0..iters).step_by(10) {
        let batch: Vec<_> = steps_rec.iter().filter(|s| s.iteration == it).collect();
        let loss = batch.iter().map(|s| s.loss).sum::<f32>() / batch.len() as f32;
        let err = batch.iter().map(|s| s.train_err).sum::<f32>() / batch.len() as f32;
        let lam = batch.iter().map(|s| s.lambda).sum::<f32>() / batch.len() as f32;
        println!("iter {it:>4}  loss {loss:>7.4}  train_err {:>5.1}%  λ {lam:>8.3}", err * 100.0);
    }

    println!("\n== validation ==");
    for e in report.recorder.evals() {
        println!(
            "iter {:>4}  val loss {:.4}  val err {:>5.1}%",
            e.iteration,
            e.val_loss,
            e.val_err * 100.0
        );
    }

    println!("\n{}", report.table_row());
    println!(
        "simulated cluster time {:.1}s | throughput {:.0} img/s (sim) | wall {:.0}s",
        report.sim_time_s, report.sim_throughput, wall
    );
    println!("CSV dumps in runs/e2e/");

    // Hard checks so this driver doubles as an acceptance test.
    anyhow::ensure!(report.final_train_loss.is_finite(), "diverged");
    let first_loss = {
        let first: Vec<_> = steps_rec.iter().filter(|s| s.iteration == 0).collect();
        first.iter().map(|s| s.loss).sum::<f32>() / first.len() as f32
    };
    anyhow::ensure!(
        report.final_train_loss < first_loss,
        "no learning: {first_loss} → {}",
        report.final_train_loss
    );
    println!("\nE2E OK: loss {first_loss:.3} → {:.3}", report.final_train_loss);
    Ok(())
}
