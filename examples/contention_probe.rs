//! Contention-aware global links + online schedule probing, end to end.
//!
//! The scenario is constructed so the three cost models straddle each
//! other with a margin beyond the schedule hysteresis:
//!
//! ```text
//! t_hier(dedicated taper) < t_ring < t_hier(taper = 1)
//! ```
//!
//! (the flat ring's β is *derived* as the geometric mean of the two
//! hierarchical costs, so the premise is asserted, not hand-tuned).
//! Two runs on that fabric, both with `schedule_coupled` and
//! `probe = "interval"`:
//!
//! 1. **Dedicated optics** (`global_taper = 2`): the controller starts
//!    on the configured ring and — because probing never acts on an
//!    unvalidated model — holds it until the scheduled probe runs the
//!    hierarchical candidate for one window. The probe's observed phase
//!    split validates the model, and the switch lands **at the probe**:
//!    the run JSON's decision trace must show a `probe` record before
//!    the first non-probe hierarchical window, and the probed run must
//!    beat the fixed flat-ring baseline on simulated wall-clock.
//! 2. **Contended optics** (`global_taper = 1`): the identical probe
//!    fires, but the contention-aware pricing (concurrent leader flows
//!    divide the per-group global β) puts the hierarchical candidate
//!    *above* the ring — the controller must keep the ring through
//!    every probe (zero schedule switches), which the dedicated-optics
//!    model would have gotten wrong.
//!
//! ```sh
//! cargo run --release --example contention_probe [-- fast]
//! ```

use dcs3gd::algo::{run_experiment, Algo, RunReport, WorkerHarness};
use dcs3gd::comm::{AllReduceAlgo, Dragonfly, NetModel};
use dcs3gd::compress::ctrl_slots;
use dcs3gd::config::ExperimentConfig;
use dcs3gd::control::{ControlPolicy, ProbeMode};
use dcs3gd::simtime::ComputeModel;
use dcs3gd::util::Json;

const NODES: usize = 8;
const HYSTERESIS: f64 = 0.1;
const PROBE_INTERVAL: u64 = 4;

fn dragonfly(taper: usize) -> Dragonfly {
    Dragonfly {
        groups: 4,
        nodes_per_group: 2,
        alpha_local_s: 1e-6,
        beta_local: 1e9,
        alpha_global_s: 2e-6,
        beta_global: 1e8,
        global_taper: taper,
    }
}

fn cfg(
    name: &str,
    policy: ControlPolicy,
    probe: ProbeMode,
    taper: usize,
    ring_beta: f64,
    steps: u64,
) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::builder("linear")
        .name(name)
        .algo(Algo::DcS3gd)
        .nodes(NODES)
        .local_batch(16)
        .steps(steps)
        .eta_single(0.02)
        .base_batch(16)
        .data(2048, 256, 0.5)
        .compute(ComputeModel::uniform(1e-6))
        .net(NetModel { alpha_s: 1.5e-6, beta_bytes_per_s: ring_beta, algo: AllReduceAlgo::Ring })
        .dragonfly(dragonfly(taper))
        .control_policy(policy)
        .k_bounds(1, 4)
        .out_dir("runs/contention")
        .build();
    cfg.control.schedule_hysteresis = HYSTERESIS;
    cfg.control.probe = probe;
    cfg.control.probe_interval = PROBE_INTERVAL;
    cfg
}

/// The schedule-record view of a run's decision trace, from its JSON:
/// (schedule name, probe flag) per collective window, in trace order.
fn schedule_trace(name: &str) -> anyhow::Result<Vec<(String, bool)>> {
    let text = std::fs::read_to_string(format!("runs/contention/{name}_run.json"))?;
    let json = Json::parse(&text).map_err(|e| anyhow::anyhow!("bad run json: {e}"))?;
    let control = json.get("control").and_then(Json::as_arr).expect("control trace");
    Ok(control
        .iter()
        .filter_map(|r| {
            let sched = r.get("schedule")?.as_str()?.to_string();
            let probe = r.get("probe").and_then(Json::as_bool).unwrap_or(false);
            Some((sched, probe))
        })
        .collect())
}

fn probe_rounds(name: &str) -> anyhow::Result<f64> {
    let text = std::fs::read_to_string(format!("runs/contention/{name}_run.json"))?;
    let json = Json::parse(&text).map_err(|e| anyhow::anyhow!("bad run json: {e}"))?;
    Ok(json
        .get("comm")
        .and_then(|c| c.get("probe"))
        .and_then(|p| p.get("rounds"))
        .and_then(Json::as_f64)
        .unwrap_or(0.0))
}

fn summarize(label: &str, r: &RunReport) {
    let comm = r.control.comm_summary();
    println!(
        "{label:<28} sim {:>9.5}s | switches {} | probes {} | t_AR global {:.1}%",
        r.sim_time_s,
        comm.schedule_switches,
        comm.probe_rounds,
        100.0 * comm.global_s / comm.total_s().max(1e-30),
    );
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "fast");
    let steps = if fast { 40 } else { 80 };

    // ---- derive the fabric so the premise is provable, not tuned ----
    // The controller prices candidates at the full wire payload: model
    // parameters plus the control piggyback tail.
    let probe_cfg = cfg("probe_setup", ControlPolicy::Fixed, ProbeMode::Off, 2, 10e9, steps);
    let n = WorkerHarness::prepare(&probe_cfg)?.n_params();
    let elems = n + ctrl_slots(NODES);
    let hier_t = |taper: usize| {
        NetModel {
            alpha_s: 1.5e-6,
            beta_bytes_per_s: 10e9,
            algo: AllReduceAlgo::Hierarchical(dragonfly(taper)),
        }
        .allreduce_time(elems, NODES)
    };
    let (t_ded, t_con) = (hier_t(2), hier_t(1));
    // Ring target: geometric mean of the two hierarchical costs; solve
    // the flat-ring formula for β at the default α.
    let alpha = 1.5e-6;
    let t_ring = (t_ded * t_con).sqrt();
    let per_step = t_ring / (2.0 * (NODES as f64 - 1.0)) - alpha;
    assert!(per_step > 0.0, "ring target too small to solve for beta");
    let ring_beta = (elems as f64 * 4.0 / NODES as f64) / per_step;
    println!("== premise (payload {elems} f32, N = {NODES}) ==");
    println!("t_hier dedicated {t_ded:.3e}s < t_ring {t_ring:.3e}s < t_hier taper=1 {t_con:.3e}s");
    assert!(
        t_ded * (1.0 + HYSTERESIS) < t_ring,
        "dedicated hier must undercut the ring past the hysteresis"
    );
    assert!(
        t_ring * (1.0 + HYSTERESIS) < t_con,
        "contended hier must overshoot the ring past the hysteresis"
    );

    // ---- scenario 1: dedicated optics, probe-triggered switch ----
    println!("\n== dedicated optics (taper 2): the probe validates hier and the switch lands ==");
    let fixed = run_experiment(&cfg(
        "probe_fixed_ring",
        ControlPolicy::Fixed,
        ProbeMode::Off,
        2,
        ring_beta,
        steps,
    ))?;
    let probed = run_experiment(&cfg(
        "probe_dedicated",
        ControlPolicy::ScheduleCoupled,
        ProbeMode::Interval,
        2,
        ring_beta,
        steps,
    ))?;
    summarize("fixed (flat ring)", &fixed);
    summarize("schedule_coupled + probe", &probed);

    let trace = schedule_trace("probe_dedicated")?;
    let first_probe = trace
        .iter()
        .position(|r| r.1)
        .expect("no probe record in the decision trace");
    assert_eq!(trace[first_probe].0, "hierarchical", "the probe must run the inactive candidate");
    let first_real_hier = trace
        .iter()
        .position(|r| !r.1 && r.0 == "hierarchical")
        .expect("the probe never triggered the switch");
    assert!(
        first_real_hier > first_probe,
        "switch at record {first_real_hier} must come after the probe at {first_probe}"
    );
    assert!(
        trace[..first_probe].iter().all(|r| r.0 == "ring"),
        "the unvalidated hierarchical model was trusted before any probe: {trace:?}"
    );
    assert!(
        trace[first_real_hier..].iter().filter(|r| !r.1).all(|r| r.0 == "hierarchical"),
        "flapped after the probe-triggered switch: {trace:?}"
    );
    assert!(probe_rounds("probe_dedicated")? >= 1.0, "comm JSON lost the probe summary");
    assert!(
        probed.sim_time_s < fixed.sim_time_s,
        "probed run {} not faster than the fixed ring {}",
        probed.sim_time_s,
        fixed.sim_time_s
    );
    println!(
        "decision trace: probe at record {first_probe}, switch at {first_real_hier}, \
         speedup {:.2}x",
        fixed.sim_time_s / probed.sim_time_s
    );

    // ---- scenario 2: contended optics, probe validates and holds ----
    println!("\n== contended optics (taper 1): the probe validates the ring and holds it ==");
    let contended = run_experiment(&cfg(
        "probe_contended",
        ControlPolicy::ScheduleCoupled,
        ProbeMode::Interval,
        1,
        ring_beta,
        steps,
    ))?;
    summarize("schedule_coupled + probe", &contended);
    let trace = schedule_trace("probe_contended")?;
    assert!(
        trace.iter().any(|r| r.1 && r.0 == "hierarchical"),
        "the contended run never probed the hierarchical arm"
    );
    assert!(
        trace.iter().filter(|r| !r.1).all(|r| r.0 == "ring"),
        "contention-aware pricing must keep the ring: {trace:?}"
    );
    assert_eq!(
        contended.control.comm_summary().schedule_switches,
        0,
        "a probe excursion is not a switch"
    );
    println!(
        "probes: {} excursions onto the contended hierarchical arm, zero switches — \
         the dedicated-optics model would have switched and lost",
        contended.control.comm_summary().probe_rounds
    );
    Ok(())
}
