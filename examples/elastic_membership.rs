//! Elastic cluster membership demo: a 64-rank DC-S3GD run that loses a
//! quarter of its workers mid-run (64 → 48), then grows past its launch
//! size (48 → 80) from scripted arrivals — and keeps converging.
//!
//! The acceptance scenario for membership epochs:
//!
//! * 16 ranks are killed *without respawn* at t ≈ 24 ms: their
//!   in-flight round resolves over the 48 survivors (re-weighted mean),
//!   the epoch advances, data re-shards 64-wide → 48-wide, the
//!   dragonfly topology refits, and the controller re-baselines.
//! * 32 fresh ranks join at t ≈ 48 ms: they bootstrap from the
//!   survivors' published epoch checkpoint (zeroed momentum and
//!   compression residuals) and the world grows to 80 — running their
//!   first `join_warmup_windows` windows on a linearly ramped LR to
//!   damp the entry noise.
//! * At **every** epoch boundary all members hold bit-identical
//!   parameters (asserted via the epoch trace's FNV checksums), and the
//!   epoch trace lands in the run's metrics JSON under `"epochs"`.
//!
//! ```sh
//! cargo run --release --example elastic_membership [-- fast]
//! ```

use dcs3gd::algo::{run_experiment, Algo};
use dcs3gd::config::ExperimentConfig;
use dcs3gd::control::FaultPlan;
use dcs3gd::simtime::ComputeModel;
use dcs3gd::util::Json;

const INITIAL: usize = 64; // launch world
const DEPARTS: usize = 16; // ranks 48..64 leave          -> 48
const JOINS: usize = 32; // ranks 64..96 arrive           -> 80
const DEPART_AT_S: f64 = 0.024;
const JOIN_AT_S: f64 = 0.048;
const WARMUP_WINDOWS: u64 = 4;

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "fast");
    let steps: u64 = if fast { 36 } else { 96 };

    let mut faults = FaultPlan::new();
    for rank in INITIAL - DEPARTS..INITIAL {
        faults = faults.depart(rank, DEPART_AT_S);
    }
    let mut builder = ExperimentConfig::builder("linear")
        .name("elastic_membership")
        .algo(Algo::DcS3gd)
        .nodes(INITIAL)
        .local_batch(8)
        .steps(steps)
        .eta_single(0.06)
        .base_batch(INITIAL * 8)
        .warmup(0.2, 0.1)
        .data(4096, 512, 0.5)
        .compute(ComputeModel::uniform(2.5e-4)) // t_C = 2 ms / step
        .eval_every(0, 32)
        .faults(faults)
        .join_warmup(WARMUP_WINDOWS)
        .out_dir("runs/membership");
    for rank in INITIAL..INITIAL + JOINS {
        builder = builder.join(rank, JOIN_AT_S);
    }
    let cfg = builder.build();

    println!(
        "== elastic membership: {INITIAL} ranks -> {} (−{DEPARTS} @ {DEPART_AT_S}s) -> {} \
         (+{JOINS} @ {JOIN_AT_S}s), {steps} healthy-k steps ==\n",
        INITIAL - DEPARTS,
        INITIAL - DEPARTS + JOINS,
    );

    let report = run_experiment(&cfg)?;

    // The realized epoch trajectory.
    println!(
        "{:>6} {:>6} {:>12} {:>10} {:>8} {:>8}  crc",
        "epoch", "world", "sched_steps", "sim_time", "left", "joined"
    );
    for tr in report.epochs.transitions() {
        println!(
            "{:>6} {:>6} {:>12} {:>9.4}s {:>8} {:>8}  {:016x}",
            tr.epoch,
            tr.world,
            tr.sched_steps,
            tr.sim_time,
            tr.departed.len(),
            tr.joined.len(),
            tr.w_crc,
        );
    }

    // Acceptance 1: the world really went 64 -> 48 -> 80.
    let worlds = report.epochs.worlds();
    assert_eq!(
        worlds,
        vec![INITIAL, INITIAL - DEPARTS, INITIAL - DEPARTS + JOINS],
        "epoch trajectory wrong"
    );

    // Acceptance 2: bit-identical parameters across ranks at every
    // epoch boundary (survivors adopt the resync mean; joiners restore
    // the published bootstrap).
    let mismatches = report.epochs.crc_mismatches();
    assert!(mismatches.is_empty(), "parameter divergence at epochs {mismatches:?}");
    println!("\nparameters bit-identical across ranks at all {} epochs", worlds.len());

    // Acceptance 3: the run keeps converging through both transitions.
    let early = report.recorder.mean_loss_between(0, 4);
    assert!(report.final_train_loss.is_finite(), "loss diverged");
    assert!(
        report.final_train_loss < early,
        "no progress: final {} vs early {}",
        report.final_train_loss,
        early
    );
    let err_bound = if fast { 0.88 } else { 0.85 };
    assert!(
        report.final_val_err < err_bound,
        "val err {} above {err_bound}",
        report.final_val_err
    );
    println!(
        "loss {early:.4} -> {:.4} | val err {:.1}% | sim {:.4}s",
        report.final_train_loss,
        100.0 * report.final_val_err,
        report.sim_time_s
    );

    // Acceptance 4: the joiner warm-up really damped the arrivals' LR —
    // at the first iteration a joiner recorded, its LR must sit below
    // an initial rank's LR for the same iteration, and the ramp must
    // release by the end of the run.
    let steps = report.recorder.steps();
    let joiner = INITIAL; // first arriving rank
    let first_join_iter = steps
        .iter()
        .filter(|s| s.worker == joiner)
        .map(|s| s.iteration)
        .min()
        .expect("joiner ran steps");
    let lr_at = |w: usize, it: u64| {
        steps.iter().find(|s| s.worker == w && s.iteration == it).map(|s| s.lr)
    };
    let joiner_lr = lr_at(joiner, first_join_iter).unwrap();
    let initial_lr = lr_at(0, first_join_iter).expect("initial rank shares the iteration");
    assert!(
        joiner_lr < initial_lr,
        "join warm-up missing: joiner LR {joiner_lr} vs initial {initial_lr}"
    );
    let last_join_iter =
        steps.iter().filter(|s| s.worker == joiner).map(|s| s.iteration).max().unwrap();
    if let (Some(j), Some(i)) = (lr_at(joiner, last_join_iter), lr_at(0, last_join_iter)) {
        assert_eq!(j, i, "warm-up ramp failed to release after {WARMUP_WINDOWS} windows");
    }
    println!(
        "join warm-up: joiner LR {joiner_lr:.4} < schedule {initial_lr:.4} at entry, \
         released by iteration {last_join_iter}"
    );

    // Acceptance 5: the epoch trace landed in the metrics JSON.
    let json_path = "runs/membership/elastic_membership_run.json";
    let parsed = Json::parse(&std::fs::read_to_string(json_path)?)
        .map_err(|e| anyhow::anyhow!("bad metrics JSON: {e}"))?;
    let epochs = parsed
        .get("epochs")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("no epochs trace in {json_path}"))?;
    assert_eq!(epochs.len(), 3, "expected 3 epoch records in {json_path}");
    for e in epochs {
        assert_eq!(
            e.get("params_identical"),
            Some(&Json::Bool(true)),
            "epoch trace flags divergence: {e:?}"
        );
    }
    println!("epoch trace: {} records in {json_path}", epochs.len());
    println!("\nshrunk, grew, and kept converging — membership epochs hold.");
    Ok(())
}
