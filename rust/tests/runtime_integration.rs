//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These need `make artifacts` to have run; they SKIP (with a notice)
//! when artifacts are absent so `cargo test` stays green standalone.

use dcs3gd::algo::{run_experiment, Algo};
use dcs3gd::config::ExperimentConfig;
use dcs3gd::dc;
use dcs3gd::model::StepBackend;
use dcs3gd::runtime::ComputeServer;
use dcs3gd::util::Rng;

fn artifacts_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn variant_dir(name: &str) -> Option<std::path::PathBuf> {
    let d = artifacts_root().join(name);
    if d.join("meta.json").exists() {
        Some(d)
    } else {
        eprintln!("SKIP: artifacts/{name} absent — run `make artifacts`");
        None
    }
}

#[test]
fn train_step_executes_and_learns() {
    let Some(dir) = variant_dir("tiny_cnn_b16") else { return };
    let server = ComputeServer::start(&dir).unwrap();
    let meta = server.meta().clone();
    let mut be = server.backend();
    let mut w = meta.load_init_params().unwrap();
    let mut rng = Rng::new(0);
    let mut x = vec![0.0f32; meta.x_len()];
    rng.fill_normal(&mut x);
    let y: Vec<i32> = (0..meta.batch as i32).map(|i| i % meta.num_classes as i32).collect();
    let mut g = vec![0.0f32; meta.param_count];

    let (loss0, err0) = be.train_step(&w, &x, &y, &mut g);
    assert!(loss0.is_finite() && (0.0..=1.0).contains(&err0));
    assert!(g.iter().any(|&v| v != 0.0), "gradient all zero");
    assert!(be.last_compute_s().unwrap() > 0.0);

    // 20 SGD steps on the fixed batch must reduce the loss (fwd/bwd
    // consistency through the whole AOT path).
    for _ in 0..20 {
        be.train_step(&w, &x, &y, &mut g);
        for (wi, gi) in w.iter_mut().zip(&g) {
            *wi -= 0.05 * gi;
        }
    }
    let (loss1, _) = be.eval_step(&w, &x, &y);
    assert!(loss1 < 0.7 * loss0, "no learning through PJRT: {loss0} → {loss1}");
}

#[test]
fn eval_matches_train_forward() {
    let Some(dir) = variant_dir("tiny_cnn_b16") else { return };
    let server = ComputeServer::start(&dir).unwrap();
    let meta = server.meta().clone();
    let mut be = server.backend();
    let w = meta.load_init_params().unwrap();
    let mut rng = Rng::new(1);
    let mut x = vec![0.0f32; meta.x_len()];
    rng.fill_normal(&mut x);
    let y: Vec<i32> = (0..meta.batch as i32).map(|i| i % meta.num_classes as i32).collect();
    let mut g = vec![0.0f32; meta.param_count];
    let (lt, et) = be.train_step(&w, &x, &y, &mut g);
    let (le, ee) = be.eval_step(&w, &x, &y);
    assert!((lt - le).abs() < 1e-4, "train fwd {lt} vs eval fwd {le}");
    assert_eq!(et, ee);
}

#[test]
fn dc_step_artifact_matches_rust_math() {
    // Three-layer agreement: the AOT dc_step (jax L2 + Pallas L1,
    // executed via PJRT) must match the fused rust path bit-closely.
    let Some(dir) = variant_dir("tiny_cnn_b16") else { return };
    let server = ComputeServer::start(&dir).unwrap();
    let n = server.meta().param_count;
    let mut rng = Rng::new(7);
    let mut g = vec![0.0f32; n];
    let mut d = vec![0.0f32; n];
    let mut v = vec![0.0f32; n];
    let mut w = vec![0.0f32; n];
    rng.fill_normal(&mut g);
    rng.fill_normal(&mut d);
    rng.fill_normal(&mut v);
    rng.fill_normal(&mut w);
    let (eta, mu, lam0, wd) = (0.1f32, 0.9f32, 0.2f32, 1e-4f32);

    let (dw_x, vn_x, lam_x) = server.dc_step(&g, &d, &v, &w, eta, mu, lam0, wd).unwrap();

    let mut v_r = v.clone();
    let mut w_r = w.clone();
    let mut dw_r = vec![0.0f32; n];
    let info = dc::dc_correct_update(
        &g,
        Some(&d),
        &mut v_r,
        &mut w_r,
        None,
        dc::DcHyper { eta, mu, lam0, wd },
        &mut dw_r,
    );
    assert!((lam_x - info.lam).abs() <= 1e-4 * info.lam.abs().max(1e-6), "λ {lam_x} vs {}", info.lam);
    for i in 0..n {
        assert!((dw_x[i] - dw_r[i]).abs() <= 1e-4 * dw_r[i].abs().max(1e-5), "dw[{i}]");
        assert!((vn_x[i] - v_r[i]).abs() <= 1e-4 * v_r[i].abs().max(1e-5), "v[{i}]");
    }
}

#[test]
fn full_dcs3gd_run_on_xla_backend() {
    // End-to-end: 4 workers, tiny CNN artifacts, a few dozen steps.
    let Some(_) = variant_dir("tiny_cnn_b16") else { return };
    let cfg = ExperimentConfig::builder("tiny_cnn_b16")
        .artifacts_root(artifacts_root())
        .algo(Algo::DcS3gd)
        .nodes(4)
        .local_batch(16)
        .steps(25)
        .eta_single(0.05)
        .base_batch(64)
        .data(2048, 256, 0.5)
        .build();
    let report = run_experiment(&cfg).unwrap();
    assert_eq!(report.recorder.n_steps(), 25 * 4);
    assert!(report.final_train_loss.is_finite());
    assert!(report.final_val_err < 0.95, "val err {}", report.final_val_err);
    assert!(report.sim_time_s > 0.0);
}

#[test]
fn ssgd_run_on_xla_backend() {
    let Some(_) = variant_dir("tiny_cnn_b16") else { return };
    let cfg = ExperimentConfig::builder("tiny_cnn_b16")
        .artifacts_root(artifacts_root())
        .algo(Algo::Ssgd)
        .nodes(2)
        .local_batch(16)
        .steps(15)
        .eta_single(0.05)
        .base_batch(32)
        .data(1024, 256, 0.5)
        .build();
    let report = run_experiment(&cfg).unwrap();
    assert!(report.final_train_loss.is_finite());
}

#[test]
fn batch_mismatch_is_rejected() {
    let Some(_) = variant_dir("tiny_cnn_b16") else { return };
    let cfg = ExperimentConfig::builder("tiny_cnn_b16")
        .artifacts_root(artifacts_root())
        .local_batch(32) // artifact was lowered for 16
        .steps(1)
        .build();
    assert!(run_experiment(&cfg).is_err());
}
