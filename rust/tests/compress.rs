//! Integration tests for the gradient-compression subsystem: the
//! golden top-k fixture (pinned against an independent mirror of the
//! algorithm), the dense-loss-envelope convergence guarantee, and the
//! `compress_coupled` decision trace in the run's metrics JSON.

use dcs3gd::algo::{run_experiment, Algo};
use dcs3gd::comm::{AllReduceAlgo, NetModel};
use dcs3gd::compress::{CompressorKind, GradCompressor, TopK};
use dcs3gd::config::ExperimentConfig;
use dcs3gd::control::ControlPolicy;
use dcs3gd::simtime::ComputeModel;
use dcs3gd::util::Json;

fn fixture() -> Json {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/compress_topk.json");
    Json::parse(&std::fs::read_to_string(&path).expect("golden fixture exists"))
        .expect("golden fixture parses")
}

#[test]
fn golden_topk_two_window_trajectory() {
    let fix = fixture();
    let n = fix.get("n").unwrap().as_usize().unwrap();
    let ratio = fix.get("ratio").unwrap().as_f64().unwrap() as f32;
    let k = fix.get("k").unwrap().as_usize().unwrap();
    let mut comp = TopK::new(n, ratio);
    assert_eq!(comp.k(), k, "k derivation drifted from the fixture");
    for (w, win) in fix.get("windows").unwrap().as_arr().unwrap().iter().enumerate() {
        let delta = win.get("delta").unwrap().as_f32_vec().unwrap();
        let want_idx: Vec<u32> = win
            .get("indices")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap() as u32)
            .collect();
        let want_vals = win.get("values").unwrap().as_f32_vec().unwrap();
        let (idx, vals) = comp.compress_window(&delta);
        assert_eq!(idx, want_idx, "window {w}: selected support diverged");
        // every fixture value is an exact dyadic rational: bit-exact
        for (i, (got, want)) in vals.iter().zip(&want_vals).enumerate() {
            assert_eq!(got.to_bits(), want.to_bits(), "window {w} value {i}: {got} vs {want}");
        }
    }
    let want_resid = fix.get("final_residual").unwrap().as_f32_vec().unwrap();
    for (i, (got, want)) in comp.residual().iter().zip(&want_resid).enumerate() {
        assert_eq!(got.to_bits(), want.to_bits(), "residual[{i}]: {got} vs {want}");
    }
}

fn conv_cfg(name: &str) -> ExperimentConfig {
    ExperimentConfig::builder("linear")
        .name(name)
        .algo(Algo::DcS3gd)
        .nodes(4)
        .local_batch(16)
        .steps(120)
        .eta_single(0.05)
        .base_batch(16)
        .data(2048, 512, 0.5)
        .compute(ComputeModel::uniform(1e-3))
        .build()
}

#[test]
fn topk_one_percent_stays_in_the_dense_loss_envelope() {
    // The acceptance bar: top-k at 1% density (error feedback on) must
    // land inside the dense run's loss envelope — same budget, same
    // data, two orders of magnitude less wire.
    let dense = run_experiment(&conv_cfg("envelope_dense")).unwrap();
    let mut cfg = conv_cfg("envelope_topk");
    cfg.compress.kind = CompressorKind::TopK;
    cfg.compress.ratio = 0.01;
    let topk = run_experiment(&cfg).unwrap();
    assert!(dense.final_train_loss.is_finite() && topk.final_train_loss.is_finite());
    assert!(
        topk.final_train_loss < dense.final_train_loss * 1.35 + 0.1,
        "top-k 1% left the dense envelope: {} vs dense {}",
        topk.final_train_loss,
        dense.final_train_loss
    );
    assert!(
        topk.final_val_err < dense.final_val_err + 0.1,
        "top-k 1% val err {} vs dense {}",
        topk.final_val_err,
        dense.final_val_err
    );
    // and it really was ~1%: mean wire bytes ≲ 3% of the dense payload
    let n = 16 * 16 * 3 * 10 + 10; // linear model parameter count
    let wire = topk.control.compress_summary().mean_wire_bytes();
    assert!(
        wire < 0.03 * (n as f64 * 4.0),
        "wire {wire} B not ~1% of dense {} B",
        n * 4
    );
}

#[test]
fn compress_coupled_trace_lands_in_run_json() {
    // A t_AR-dominated fabric under compress_coupled: the run JSON must
    // carry the (k, schedule, ratio) decision trace under "control" and
    // the aggregated "compress" key, with the ratio actually moving.
    let dir = std::env::temp_dir().join(format!("dcs3gd_compress_{}", std::process::id()));
    let mut cfg = conv_cfg("cc_trace");
    cfg.steps = 60;
    cfg.compute = ComputeModel::uniform(1e-5);
    cfg.net = NetModel { alpha_s: 0.0, beta_bytes_per_s: 2e5, algo: AllReduceAlgo::Ring };
    cfg.compress.kind = CompressorKind::TopK;
    cfg.compress.ratio = 0.25;
    cfg.control.policy = ControlPolicy::CompressCoupled;
    cfg.control.k_max = 2;
    cfg.out_dir = Some(dir.clone());
    run_experiment(&cfg).unwrap();
    let parsed =
        Json::parse(&std::fs::read_to_string(dir.join("cc_trace_run.json")).unwrap()).unwrap();
    let control = parsed.get("control").and_then(Json::as_arr).expect("control trace");
    let windows: Vec<&Json> =
        control.iter().filter(|r| r.get("schedule").unwrap().as_str().is_some()).collect();
    assert!(!windows.is_empty(), "no window records in the trace");
    for r in &windows {
        // every window record carries the full (k, schedule, ratio) triple
        assert!(r.get("k").unwrap().as_f64().is_some());
        assert!(r.get("compress_ratio").unwrap().as_f64().is_some());
        assert_eq!(r.get("compress").unwrap().as_str(), Some("topk"));
        assert!(r.get("wire_bytes").unwrap().as_f64().unwrap() > 0.0);
    }
    let ratios: Vec<f64> =
        windows.iter().map(|r| r.get("compress_ratio").unwrap().as_f64().unwrap()).collect();
    assert!(
        ratios.iter().any(|&r| r < 0.25),
        "compress_coupled never tightened the ratio: {ratios:?}"
    );
    let summary = parsed.get("compress").expect("compress summary key");
    assert_eq!(summary.get("kind").unwrap().as_str(), Some("topk"));
    assert!(summary.get("ratio_changes").unwrap().as_f64().unwrap() >= 1.0);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn ssgd_compressed_matches_engine_restriction_and_runs() {
    // Compression is rejected on the PS engines at config time…
    let mut bad = conv_cfg("bad");
    bad.algo = Algo::DcAsgd;
    bad.compress.kind = CompressorKind::Qsgd;
    assert!(bad.validate().is_err());
    // …and runs on SSGD.
    let mut cfg = conv_cfg("ssgd_q8");
    cfg.algo = Algo::Ssgd;
    cfg.steps = 40;
    cfg.compress.kind = CompressorKind::Qsgd;
    cfg.compress.bits = 8;
    let report = run_experiment(&cfg).unwrap();
    assert!(report.final_train_loss.is_finite());
    assert_eq!(report.control.compress_summary().kind, "qsgd");
}
