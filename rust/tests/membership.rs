//! Integration tests for elastic cluster membership: the golden
//! shrink-then-grow trajectory (64 → 48 → 80), loss continuity across
//! epoch boundaries, cross-rank parameter bit-identity, and
//! determinism of elastic runs.

use dcs3gd::algo::{run_experiment, Algo, RunReport};
use dcs3gd::config::ExperimentConfig;
use dcs3gd::control::FaultPlan;
use dcs3gd::simtime::ComputeModel;
use dcs3gd::util::Json;

/// The golden fixture describing the scenario *and* the expected epoch
/// trajectory — the config is built from it, the realized trace is
/// compared against it.
fn fixture() -> Json {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/membership_64_48_80.json");
    Json::parse(&std::fs::read_to_string(&path).expect("golden fixture exists"))
        .expect("golden fixture parses")
}

fn ranks_of(j: &Json) -> Vec<usize> {
    j.as_arr().unwrap().iter().map(|v| v.as_usize().unwrap()).collect()
}

fn cfg_from_fixture(fix: &Json) -> ExperimentConfig {
    let initial = fix.get("initial_world").unwrap().as_usize().unwrap();
    let depart_at = fix.get("depart_at_s").unwrap().as_f64().unwrap();
    let join_at = fix.get("join_at_s").unwrap().as_f64().unwrap();
    let transitions = fix.get("transitions").unwrap().as_arr().unwrap();
    let mut faults = FaultPlan::new();
    for rank in ranks_of(transitions[0].get("departed").unwrap()) {
        faults = faults.depart(rank, depart_at);
    }
    let mut builder = ExperimentConfig::builder("linear")
        .name("membership_golden")
        .algo(Algo::DcS3gd)
        .nodes(initial)
        .local_batch(4)
        .steps(14)
        .eta_single(0.05)
        .base_batch(initial * 4)
        .warmup(0.2, 0.1)
        .data(2048, 512, 0.5)
        .compute(ComputeModel::uniform(1e-3)) // 4 ms / step
        .faults(faults);
    for rank in ranks_of(transitions[1].get("joined").unwrap()) {
        builder = builder.join(rank, join_at);
    }
    builder.build()
}

fn run_golden() -> (Json, RunReport) {
    let fix = fixture();
    let cfg = cfg_from_fixture(&fix);
    let report = run_experiment(&cfg).expect("elastic run completes");
    (fix, report)
}

#[test]
fn golden_shrink_then_grow_trajectory() {
    let (fix, report) = run_golden();

    // World trajectory matches the fixture: 64 -> 48 -> 80.
    let want_worlds = ranks_of(fix.get("worlds").unwrap());
    assert_eq!(report.epochs.worlds(), want_worlds, "epoch world trajectory diverged");

    // Each transition's member movement matches.
    let transitions = report.epochs.transitions();
    let want = fix.get("transitions").unwrap().as_arr().unwrap();
    assert_eq!(transitions.len(), want.len() + 1, "epoch 0 + one record per transition");
    for (got, want) in transitions[1..].iter().zip(want) {
        assert_eq!(got.epoch, want.get("epoch").unwrap().as_f64().unwrap() as u64);
        assert_eq!(got.world, want.get("world").unwrap().as_usize().unwrap());
        assert_eq!(got.departed, ranks_of(want.get("departed").unwrap()));
        assert_eq!(got.joined, ranks_of(want.get("joined").unwrap()));
    }

    // Bit-identical parameters across ranks at every epoch boundary.
    assert!(
        report.epochs.crc_mismatches().is_empty(),
        "parameter divergence at epochs {:?}",
        report.epochs.crc_mismatches()
    );

    // Loss continuity across each boundary: the re-synced cluster must
    // pick up roughly where it left off, not regress to scratch.
    for tr in &transitions[1..] {
        let s = tr.sched_steps;
        let pre = report.recorder.mean_loss_between(s.saturating_sub(3), s);
        let post = report.recorder.mean_loss_between(s, s + 3);
        assert!(pre.is_finite() && post.is_finite(), "missing steps around epoch {}", tr.epoch);
        assert!(
            post < pre * 1.75 + 0.25,
            "loss discontinuity at epoch {}: {pre} -> {post}",
            tr.epoch
        );
    }

    // The departures were logged by the leavers themselves.
    let departs = report
        .control
        .events()
        .iter()
        .filter(|e| e.event.as_deref().is_some_and(|s| s.starts_with("depart@")))
        .count();
    let expected_departs = ranks_of(
        fix.get("transitions").unwrap().as_arr().unwrap()[0].get("departed").unwrap(),
    )
    .len();
    assert_eq!(departs, expected_departs, "every leaver records its departure");

    // And the run still trains.
    assert!(report.final_train_loss.is_finite());
    let early = report.recorder.mean_loss_between(0, 3);
    assert!(
        report.final_train_loss < early * 1.05,
        "no learning across the elastic run: {} vs early {}",
        report.final_train_loss,
        early
    );
}

#[test]
fn elastic_golden_run_is_deterministic() {
    let (_, a) = run_golden();
    let (_, b) = run_golden();
    assert_eq!(a.final_train_loss, b.final_train_loss);
    assert_eq!(a.sim_time_s, b.sim_time_s);
    assert_eq!(a.epochs.records(), b.epochs.records());
}

#[test]
fn epoch_trace_lands_in_run_json() {
    let dir = std::env::temp_dir().join(format!("dcs3gd_membership_{}", std::process::id()));
    let fix = fixture();
    let mut cfg = cfg_from_fixture(&fix);
    cfg.out_dir = Some(dir.clone());
    run_experiment(&cfg).unwrap();
    let parsed = Json::parse(
        &std::fs::read_to_string(dir.join("membership_golden_run.json")).unwrap(),
    )
    .unwrap();
    let epochs = parsed.get("epochs").and_then(Json::as_arr).expect("epochs key");
    assert_eq!(epochs.len(), 3);
    for e in epochs {
        assert_eq!(e.get("params_identical"), Some(&Json::Bool(true)));
    }
    let worlds: Vec<usize> =
        epochs.iter().map(|e| e.get("world").unwrap().as_usize().unwrap()).collect();
    assert_eq!(worlds, ranks_of(fix.get("worlds").unwrap()));
    std::fs::remove_dir_all(&dir).unwrap();
}
