//! Integration tests for the heterogeneous-fabric subsystem and the
//! per-worker staleness engines: draw determinism under membership
//! growth (hand-rolled property loops), the golden Dynamic-SSP
//! trajectory under a correlated spot revocation, end-to-end `dyn_ssp`
//! / `sgs` runs under the coupled control policies with membership
//! churn, and the run-JSON `"hetero"` export.

use dcs3gd::algo::{run_experiment, Algo, RunReport};
use dcs3gd::config::ExperimentConfig;
use dcs3gd::control::{ControlPolicy, FaultPlan, SgsStaleness};
use dcs3gd::hetero::{
    diurnal_factor, link_scale, revocation_time, tier_multiplier, HeteroConfig, HeteroProfile,
};
use dcs3gd::simtime::ComputeModel;
use dcs3gd::util::Json;

// ---------------------------------------------------------------------
// Determinism properties (hand-rolled loops — the draw functions are
// the pinned contract).
// ---------------------------------------------------------------------

fn property_cfg() -> HeteroConfig {
    HeteroConfig {
        enabled: true,
        tiers: vec![1.0, 1.6, 2.5],
        spot_fraction: 0.6,
        spot_mtbf_s: 2.0,
        spot_correlation: 0.4,
        diurnal_amplitude: 0.25,
        diurnal_period_s: 7.5,
        link_spread: 0.4,
        ..HeteroConfig::default()
    }
}

#[test]
fn hetero_draws_are_pure_in_seed_and_rank() {
    let cfg = property_cfg();
    for seed in [0u64, 7, 42, 0xDEAD] {
        for rank in 0..24usize {
            assert_eq!(tier_multiplier(&cfg, seed, rank), tier_multiplier(&cfg, seed, rank));
            assert_eq!(revocation_time(&cfg, seed, rank), revocation_time(&cfg, seed, rank));
            assert_eq!(
                diurnal_factor(&cfg, seed, rank, 3.5),
                diurnal_factor(&cfg, seed, rank, 3.5)
            );
            assert_eq!(link_scale(&cfg, seed, rank), link_scale(&cfg, seed, rank));
        }
        // Distinct seeds must decorrelate at least one rank's tier.
        let other = seed.wrapping_add(1);
        assert!(
            (0..24).any(|r| tier_multiplier(&cfg, seed, r) != tier_multiplier(&cfg, other, r)),
            "seed {seed} and {other} drew identical tier vectors"
        );
    }
}

#[test]
fn hetero_profile_survives_membership_growth() {
    // The epoch-transition property at the draw level: resolving the
    // profile over a larger capacity (joiners admitted) must not move
    // any existing rank's draws, and the link bottlenecks must not
    // depend on the rank count at all.
    let cfg = property_cfg();
    for seed in [3u64, 11, 99] {
        for cap in [4usize, 8, 16] {
            let small = HeteroProfile::resolve(&cfg, seed, cap, cap, 2);
            let large = HeteroProfile::resolve(&cfg, seed, cap * 2, cap, 2);
            assert_eq!(&large.tier[..cap], &small.tier[..], "tiers moved under growth");
            assert_eq!(&large.spot[..cap], &small.spot[..], "spot cohort moved under growth");
            for rt in &small.revocations {
                assert!(large.revocations.contains(rt), "revocation {rt:?} lost under growth");
            }
            assert_eq!(small.link_scale_local, large.link_scale_local);
            assert_eq!(small.link_scale_global, large.link_scale_global);
        }
    }
}

#[test]
fn sgs_draws_are_deterministic_and_bounded() {
    for seed in [1u64, 9, 77] {
        for slot in 0..8usize {
            for window in 0..50u64 {
                let a = SgsStaleness::draw(seed, slot, window, 4, 2, 8);
                assert_eq!(a, SgsStaleness::draw(seed, slot, window, 4, 2, 8));
                // k ± k/2 clipped to the bounds: lo = 2, hi = 6
                assert!((2..=6).contains(&a), "draw {a} escaped [2, 6]");
            }
        }
        // The stream must actually vary along windows and across slots.
        let row: Vec<usize> = (0..40).map(|w| SgsStaleness::draw(seed, 0, w, 4, 1, 8)).collect();
        assert!(row.windows(2).any(|w| w[0] != w[1]), "window stream is constant");
        let col: Vec<usize> = (0..40).map(|s| SgsStaleness::draw(seed, s, 0, 4, 1, 8)).collect();
        assert!(col.windows(2).any(|w| w[0] != w[1]), "slot stream is constant");
    }
}

// ---------------------------------------------------------------------
// Golden Dynamic-SSP trajectory under a correlated spot revocation.
// ---------------------------------------------------------------------

fn fixture() -> Json {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/hetero_dyn_ssp_spot.json");
    Json::parse(&std::fs::read_to_string(&path).expect("golden fixture exists"))
        .expect("golden fixture parses")
}

fn ranks_of(j: &Json) -> Vec<usize> {
    j.as_arr().unwrap().iter().map(|v| v.as_usize().unwrap()).collect()
}

fn golden_cfg(fix: &Json) -> ExperimentConfig {
    let h = fix.get("hetero").unwrap();
    let tiers = h
        .get("tiers")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect();
    let hetero = HeteroConfig {
        enabled: true,
        tiers,
        spot_fraction: h.get("spot_fraction").unwrap().as_f64().unwrap(),
        spot_mtbf_s: h.get("spot_mtbf_s").unwrap().as_f64().unwrap(),
        spot_correlation: h.get("spot_correlation").unwrap().as_f64().unwrap(),
        ..HeteroConfig::default()
    };
    let nodes = fix.get("initial_world").unwrap().as_usize().unwrap();
    let batch = fix.get("local_batch").unwrap().as_usize().unwrap();
    let mut cfg = ExperimentConfig::builder("linear")
        .name("hetero_dyn_ssp_golden")
        .algo(Algo::parse(fix.get("algo").unwrap().as_str().unwrap()).unwrap())
        .nodes(nodes)
        .local_batch(batch)
        .seed(fix.get("seed").unwrap().as_f64().unwrap() as u64)
        .steps(1) // sized below, off the resolved cohort instant
        .eta_single(0.05)
        .base_batch(nodes * batch)
        .warmup(0.2, 0.1)
        .data(1024, 256, 0.5)
        .staleness(fix.get("staleness").unwrap().as_usize().unwrap())
        .k_bounds(
            fix.get("k_min").unwrap().as_usize().unwrap(),
            fix.get("k_max").unwrap().as_usize().unwrap(),
        )
        .compute(ComputeModel::uniform(fix.get("sec_per_sample").unwrap().as_f64().unwrap()))
        .hetero(hetero)
        .build();
    // Size the step budget so the run outlasts the cohort instant: each
    // scheduled step advances the shared clock by at least
    // (k_min / k_max) · batch · sec_per_sample (the slowest admissible
    // window at the fastest tier), so this budget lands the revocation
    // comfortably mid-run whatever the exponential draw came out as.
    let t_star = cfg.hetero_profile().expect("hetero enabled").revocations[0].1;
    let per_step = fix.get("sec_per_sample").unwrap().as_f64().unwrap()
        * batch as f64
        * cfg.control.k_min as f64
        / cfg.control.k_max as f64;
    cfg.steps = (t_star / per_step).ceil() as u64 + 16;
    cfg.validate().expect("golden config validates");
    cfg
}

fn run_golden() -> (Json, RunReport) {
    let fix = fixture();
    let cfg = golden_cfg(&fix);
    let report = run_experiment(&cfg).expect("golden run completes");
    (fix, report)
}

#[test]
fn golden_dyn_ssp_spot_revocation_trajectory() {
    let (fix, report) = run_golden();
    let expected = fix.get("expected").unwrap();
    let want_revoked = ranks_of(expected.get("revoked_ranks").unwrap());

    // The resolved profile matches the fixture: every non-anchor rank
    // is spot, and the fully-correlated cohort shares one instant.
    let profile = report.hetero.as_ref().expect("run carries the hetero profile");
    let spot_ranks: Vec<usize> =
        (0..profile.spot.len()).filter(|&r| profile.spot[r]).collect();
    assert_eq!(spot_ranks, want_revoked, "spot cohort diverged from the fixture");
    let revoked: Vec<usize> = profile.revocations.iter().map(|&(r, _)| r).collect();
    assert_eq!(revoked, want_revoked, "revoked set diverged from the fixture");
    let instants: Vec<f64> = profile.revocations.iter().map(|&(_, t)| t).collect();
    assert!(
        instants.windows(2).all(|w| w[0] == w[1]),
        "correlated cohort must share one revocation instant: {instants:?}"
    );
    let menu = fix.get("hetero").unwrap().get("tiers").unwrap();
    for t in &profile.tier {
        assert!(
            menu.as_arr().unwrap().iter().any(|m| m.as_f64().unwrap() == *t),
            "tier {t} not on the fixture's menu"
        );
    }

    // The realized epoch trajectory: a monotone shrink from the full
    // world to the lone anchor. Tier-skewed clocks drift within a
    // window, so the simultaneous deaths may resolve over adjacent
    // boundaries — the fixture pins the structure, the determinism
    // test below pins the exact trace.
    let initial = fix.get("initial_world").unwrap().as_usize().unwrap();
    let final_world = expected.get("final_world").unwrap().as_usize().unwrap();
    let worlds = report.epochs.worlds();
    assert_eq!(worlds.first(), Some(&initial), "run must start at the full world");
    assert_eq!(worlds.last(), Some(&final_world), "run must end at the lone anchor");
    assert!(worlds.windows(2).all(|w| w[1] < w[0]), "worlds must shrink monotonically: {worlds:?}");
    let transitions = report.epochs.transitions();
    let departed: Vec<usize> =
        transitions.iter().flat_map(|t| t.departed.iter().copied()).collect();
    let mut departed_sorted = departed.clone();
    departed_sorted.sort_unstable();
    assert_eq!(departed_sorted, want_revoked, "realized departures diverged from the cohort");
    assert!(transitions.iter().all(|t| t.joined.is_empty()));
    assert!(report.epochs.crc_mismatches().is_empty());

    // The anchor finishes the run: training is alive end to end.
    assert!(report.final_train_loss.is_finite());
    assert!(report.sim_time_s > instants[0], "the run must outlast the revocation");
}

#[test]
fn golden_dyn_ssp_run_is_deterministic() {
    let (_, a) = run_golden();
    let (_, b) = run_golden();
    assert_eq!(a.final_train_loss, b.final_train_loss);
    assert_eq!(a.sim_time_s, b.sim_time_s);
    assert_eq!(a.epochs.records(), b.epochs.records());
    assert_eq!(a.hetero, b.hetero);
}

// ---------------------------------------------------------------------
// End-to-end engine runs under the coupled policies with churn.
// ---------------------------------------------------------------------

fn engine_cfg(algo: Algo, policy: ControlPolicy) -> ExperimentConfig {
    let hetero = HeteroConfig {
        enabled: true,
        tiers: vec![1.0, 2.0],
        diurnal_amplitude: 0.2,
        diurnal_period_s: 0.5,
        link_spread: 0.3,
        ..HeteroConfig::default()
    };
    let mut builder = ExperimentConfig::builder("linear")
        .name("hetero_engine")
        .algo(algo)
        .nodes(6)
        .local_batch(4)
        .steps(30)
        .seed(11)
        .eta_single(0.05)
        .base_batch(24)
        .warmup(0.2, 0.1)
        .data(1024, 256, 0.5)
        .staleness(4)
        .k_bounds(2, 4)
        .control_policy(policy)
        .compute(ComputeModel::uniform(1e-3))
        .hetero(hetero)
        .faults(FaultPlan::new().depart(5, 0.012))
        .join(6, 0.028);
    if policy == ControlPolicy::CompressCoupled {
        builder = builder.compress_topk(0.25);
    }
    builder.build()
}

#[test]
fn engines_run_under_coupled_policies_with_churn() {
    for algo in [Algo::DynSsp, Algo::Sgs] {
        for policy in [ControlPolicy::ScheduleCoupled, ControlPolicy::CompressCoupled] {
            let cfg = engine_cfg(algo, policy);
            cfg.validate().expect("engine config validates");
            let a = run_experiment(&cfg).expect("engine run completes");
            assert!(a.final_train_loss.is_finite(), "{algo:?}/{policy:?} diverged");

            // The scripted churn really happened: rank 5 departed,
            // rank 6 joined, and the run ends back at world 6.
            let departed: Vec<usize> = a
                .epochs
                .transitions()
                .iter()
                .flat_map(|t| t.departed.iter().copied())
                .collect();
            let joined: Vec<usize> = a
                .epochs
                .transitions()
                .iter()
                .flat_map(|t| t.joined.iter().copied())
                .collect();
            assert_eq!(departed, vec![5], "{algo:?}/{policy:?}: departures diverged");
            assert_eq!(joined, vec![6], "{algo:?}/{policy:?}: joins diverged");
            assert_eq!(a.epochs.worlds().last(), Some(&6), "{algo:?}/{policy:?}");
            assert!(a.epochs.crc_mismatches().is_empty(), "{algo:?}/{policy:?}");

            // Window decisions stay inside the configured bounds.
            for r in a.control.records() {
                assert!(
                    (2..=4).contains(&r.k),
                    "{algo:?}/{policy:?}: k {} escaped [2, 4]",
                    r.k
                );
            }

            // Bit-identical replay: the whole stack — tiers, diurnal
            // curves, link spread, churn, the engine's per-rank bounds
            // — is a pure function of the config.
            let b = run_experiment(&cfg).expect("replay completes");
            assert_eq!(a.final_train_loss, b.final_train_loss, "{algo:?}/{policy:?}");
            assert_eq!(a.sim_time_s, b.sim_time_s, "{algo:?}/{policy:?}");
            assert_eq!(a.epochs.records(), b.epochs.records(), "{algo:?}/{policy:?}");
        }
    }
}

// ---------------------------------------------------------------------
// Run-JSON export.
// ---------------------------------------------------------------------

#[test]
fn run_json_carries_the_hetero_block() {
    let dir = std::env::temp_dir().join(format!("dcs3gd_hetero_{}", std::process::id()));
    let hetero = HeteroConfig {
        enabled: true,
        tiers: vec![1.0, 1.5],
        diurnal_amplitude: 0.2,
        diurnal_period_s: 1.0,
        ..HeteroConfig::default()
    };
    let cfg = ExperimentConfig::builder("linear")
        .name("hetero_json")
        .algo(Algo::DcS3gd)
        .nodes(4)
        .local_batch(4)
        .steps(8)
        .base_batch(16)
        .data(512, 128, 0.5)
        .compute(ComputeModel::uniform(1e-3))
        .hetero(hetero)
        .out_dir(dir.clone())
        .build();
    cfg.validate().unwrap();
    run_experiment(&cfg).unwrap();
    let parsed =
        Json::parse(&std::fs::read_to_string(dir.join("hetero_json_run.json")).unwrap()).unwrap();
    let block = parsed.get("hetero").expect("hetero key");
    assert_eq!(block.get("enabled"), Some(&Json::Bool(true)));
    assert_eq!(block.get("tier").and_then(Json::as_arr).unwrap().len(), 4);
    assert_eq!(block.get("spot").and_then(Json::as_arr).unwrap().len(), 4);
    assert!(
        block.get("revocations").and_then(Json::as_arr).unwrap().is_empty(),
        "no spot cohort configured, no revocations"
    );
    assert_eq!(block.get("link_scale_local").and_then(Json::as_f64), Some(1.0));
    assert_eq!(block.get("diurnal_amplitude").and_then(Json::as_f64), Some(0.2));
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn run_json_carries_the_obs_block_with_the_per_rank_split() {
    // The per-rank t_C/t_AR split under `"obs"`, golden-pinned two
    // ways: relationally against the control trace (the leader's
    // exposed-wait series must be bit-equal between the two exports),
    // and by byte-identity across a re-run (the block is virtual-time
    // only, so it must not move between wall-clock executions).
    let dir = std::env::temp_dir().join(format!("dcs3gd_obs_{}", std::process::id()));
    let mk = || {
        let hetero = HeteroConfig {
            enabled: true,
            tiers: vec![1.0, 1.7],
            link_spread: 0.3,
            ..HeteroConfig::default()
        };
        ExperimentConfig::builder("linear")
            .name("obs_json")
            .algo(Algo::DynSsp)
            .nodes(4)
            .local_batch(4)
            .steps(16)
            .base_batch(16)
            .data(512, 128, 0.5)
            .staleness(3)
            .k_bounds(2, 4)
            .control_policy(ControlPolicy::DynSsp)
            .compute(ComputeModel::uniform(1e-3))
            .hetero(hetero)
            .out_dir(dir.clone())
            .build()
    };
    let report = run_experiment(&mk()).unwrap();
    let obs = report.obs.as_ref().expect("run carries the obs hub");

    // Relational pin: the leader's window rows and its consume-site
    // control records describe the same waits — identical blocked_s
    // series, bit for bit.
    let mut row_blocked: Vec<u64> = obs
        .windows()
        .iter()
        .filter(|r| r.worker == 0)
        .map(|r| r.blocked_s.to_bits())
        .collect();
    let mut rec_blocked: Vec<u64> = report
        .control
        .records()
        .iter()
        .filter(|r| r.worker == 0 && r.schedule.is_some())
        .map(|r| r.blocked_s.to_bits())
        .collect();
    assert!(!row_blocked.is_empty(), "leader consumed no windows");
    row_blocked.sort_unstable();
    rec_blocked.sort_unstable();
    assert_eq!(row_blocked, rec_blocked, "obs rows and control records disagree on waits");

    // Golden-pin one window: the leader's first consumed window must
    // carry a real split — compute spent, latency observed, the wait
    // no longer than the latency, efficiency inside [0, 1].
    let first = obs.windows().into_iter().find(|r| r.worker == 0).unwrap();
    assert!(first.t_c > 0.0, "t_c {}", first.t_c);
    assert!(first.t_ar > 0.0, "t_ar {}", first.t_ar);
    assert!(first.blocked_s <= first.t_ar + 1e-12);
    assert!((0.0..=1.0).contains(&first.overlap_efficiency()));

    // The exported JSON block carries the headline keys.
    let parsed =
        Json::parse(&std::fs::read_to_string(dir.join("obs_json_run.json")).unwrap()).unwrap();
    let block = parsed.get("obs").expect("obs key");
    assert_eq!(block.get("enabled"), Some(&Json::Bool(true)));
    assert_eq!(block.get("ranks").and_then(Json::as_arr).unwrap().len(), 4);
    assert!(!block.get("windows").and_then(Json::as_arr).unwrap().is_empty());
    assert!(!block.get("staleness").and_then(Json::as_arr).unwrap().is_empty());
    assert!(block.get("overlap_efficiency_mean").and_then(Json::as_f64).unwrap() > 0.0);
    for rank_row in block.get("ranks").and_then(Json::as_arr).unwrap() {
        assert!(rank_row.get("t_c_mean").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(rank_row.get("t_ar_mean").and_then(Json::as_f64).unwrap() > 0.0);
    }

    // Byte-identity across a re-run: wall-clock never leaks in.
    let again = run_experiment(&mk()).unwrap();
    assert_eq!(
        obs.to_json().to_string(),
        again.obs.as_ref().unwrap().to_json().to_string(),
        "the obs block moved between two identical runs"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn disabled_hetero_exports_a_stub() {
    let dir = std::env::temp_dir().join(format!("dcs3gd_hetero_off_{}", std::process::id()));
    let cfg = ExperimentConfig::builder("linear")
        .name("hetero_off")
        .algo(Algo::DcS3gd)
        .nodes(2)
        .local_batch(4)
        .steps(4)
        .base_batch(8)
        .data(256, 64, 0.5)
        .compute(ComputeModel::uniform(1e-3))
        .out_dir(dir.clone())
        .build();
    run_experiment(&cfg).unwrap();
    let parsed =
        Json::parse(&std::fs::read_to_string(dir.join("hetero_off_run.json")).unwrap()).unwrap();
    let block = parsed.get("hetero").expect("the hetero key is always exported");
    assert_eq!(block.get("enabled"), Some(&Json::Bool(false)));
    assert!(block.get("tier").is_none(), "disabled runs export only the stub");
    std::fs::remove_dir_all(&dir).unwrap();
}
