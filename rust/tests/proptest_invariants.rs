//! Property-based tests over the coordinator invariants (offline build:
//! randomized-case harness with seeded shrink-free generation — each
//! failure prints its case seed for reproduction).

use dcs3gd::algo::{run_experiment, Algo};
use dcs3gd::comm::{
    hier::hier_network, ring::ring_network, schedule::Hierarchical, AllReduceAlgo,
    CollectiveSchedule, Dragonfly, GlobalContention, Group, Link, NetModel, LEADER_RING_FLOWS,
};
use dcs3gd::config::ExperimentConfig;
use dcs3gd::control::FaultPlan;
use dcs3gd::hetero::HeteroConfig;
use dcs3gd::simtime::ComputeModel;
use dcs3gd::compress::{CompressConfig, CompressorKind, GradCompressor, Qsgd, TopK, WindowCodec};
use dcs3gd::data::{ShardSampler, Split, SyntheticDataset};
use dcs3gd::dc;
use dcs3gd::optim::{LrSchedule, MomentumSgd};
use dcs3gd::ps::{PsMode, PsTier, PsTierSpec, ReplicaPlan};
use dcs3gd::tensor;
use dcs3gd::util::Rng;

const CASES: u64 = 40;

fn randvec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    let mut v = vec![0.0; n];
    rng.fill_normal(&mut v);
    v.iter_mut().for_each(|x| *x *= scale);
    v
}

/// Property: rendezvous all-reduce == serial elementwise sum, for any
/// rank count, vector length, and per-rank post times; and the reported
/// completion time equals max(post) + t_AR for every rank.
#[test]
fn prop_allreduce_is_sum_with_correct_timing() {
    for case in 0..CASES {
        let mut rng = Rng::keyed(0xA11E, 0, case);
        let n_ranks = 1 + rng.below(8) as usize;
        let len = 1 + rng.below(500) as usize;
        let algo = match rng.below(4) {
            0 => AllReduceAlgo::Ring,
            1 => AllReduceAlgo::Tree,
            2 => AllReduceAlgo::Flat,
            _ => AllReduceAlgo::Hierarchical(Dragonfly {
                nodes_per_group: 1 + rng.below(4) as usize,
                ..Dragonfly::default()
            }),
        };
        let net = NetModel {
            alpha_s: rng.uniform() * 1e-5,
            beta_bytes_per_s: 1e6 + rng.uniform() * 1e9,
            algo,
        };
        let inputs: Vec<Vec<f32>> = (0..n_ranks)
            .map(|r| {
                let mut rr = Rng::keyed(case, r as u64, 0);
                randvec(&mut rr, len, 1.0)
            })
            .collect();
        let posts: Vec<f64> = (0..n_ranks).map(|_| rng.uniform() * 10.0).collect();
        let mut expect = vec![0.0f32; len];
        for v in &inputs {
            tensor::add_assign(&mut expect, v);
        }
        let t_expect = posts.iter().cloned().fold(f64::MIN, f64::max)
            + net.allreduce_time(len, n_ranks);

        let group = Group::new(n_ranks, net);
        let handles: Vec<_> = (0..n_ranks)
            .map(|r| {
                let mut c = group.comm(r);
                let data = inputs[r].clone();
                let post = posts[r];
                std::thread::spawn(move || c.allreduce(&data, post))
            })
            .collect();
        for h in handles {
            let (sum, t_done) = h.join().unwrap();
            for (i, (a, b)) in sum.iter().zip(&expect).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-4 * b.abs().max(1.0),
                    "case {case}: sum[{i}] {a} vs {b}"
                );
            }
            assert!((t_done - t_expect).abs() < 1e-9, "case {case}: time {t_done} vs {t_expect}");
        }
    }
}

/// Property: the wire-level ring all-reduce agrees with the serial sum
/// for any (ranks, length) — including lengths < ranks.
#[test]
fn prop_ring_allreduce_matches_sum() {
    for case in 0..CASES {
        let mut rng = Rng::keyed(0x3136, 1, case);
        let n_ranks = 1 + rng.below(7) as usize;
        let len = 1 + rng.below(300) as usize;
        let inputs: Vec<Vec<f32>> = (0..n_ranks)
            .map(|r| {
                let mut rr = Rng::keyed(case ^ 0xFF, r as u64, 1);
                randvec(&mut rr, len, 1.0)
            })
            .collect();
        let mut expect = vec![0.0f32; len];
        for v in &inputs {
            tensor::add_assign(&mut expect, v);
        }
        let comms = ring_network(n_ranks);
        let handles: Vec<_> = comms
            .into_iter()
            .zip(inputs)
            .map(|(c, mut buf)| {
                std::thread::spawn(move || {
                    c.allreduce(&mut buf);
                    buf
                })
            })
            .collect();
        for h in handles {
            let got = h.join().unwrap();
            for (a, b) in got.iter().zip(&expect) {
                assert!((a - b).abs() <= 1e-4 * b.abs().max(1.0), "case {case}");
            }
        }
    }
}

/// Property: schedules decide routing and cost, never the arithmetic —
/// the Hierarchical and Ring rendezvous sums are **bit-identical** for
/// any payload and rank count (the flat-path equivalence the schedule
/// refactor is differential-tested on).
#[test]
fn prop_hierarchical_and_ring_sums_bit_identical() {
    for case in 0..CASES {
        let mut rng = Rng::keyed(0x41E2, 7, case);
        let n_ranks = 1 + rng.below(8) as usize;
        let len = 1 + rng.below(400) as usize;
        let topology = Dragonfly {
            groups: 1 + rng.below(4) as usize,
            nodes_per_group: 1 + rng.below(4) as usize,
            ..Dragonfly::default()
        };
        let inputs: Vec<Vec<f32>> = (0..n_ranks)
            .map(|r| {
                let mut rr = Rng::keyed(case ^ 0xABC, r as u64, 2);
                let scale = 10f32.powf(rr.uniform_range(-2.0, 2.0));
                randvec(&mut rr, len, scale)
            })
            .collect();
        let run = |algo: AllReduceAlgo| -> Vec<Vec<f32>> {
            let net = NetModel { algo, ..NetModel::default() };
            let group = Group::new(n_ranks, net);
            let handles: Vec<_> = (0..n_ranks)
                .map(|r| {
                    let mut c = group.comm(r);
                    let data = inputs[r].clone();
                    std::thread::spawn(move || c.allreduce(&data, 0.0).0.as_ref().clone())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        };
        let ring = run(AllReduceAlgo::Ring);
        let hier = run(AllReduceAlgo::Hierarchical(topology));
        for (rs, hs) in ring.iter().zip(&hier) {
            for (a, b) in rs.iter().zip(hs) {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "case {case}: schedules changed the sum ({a} vs {b})"
                );
            }
        }
    }
}

/// Property: every schedule's per-phase times are non-negative, add up
/// to the reported total exactly, and the phases handed back by
/// `wait_timed` are the model's phases with completion
/// `max(post) + total`.
#[test]
fn prop_phase_times_sum_to_total() {
    for case in 0..CASES {
        let mut rng = Rng::keyed(0x9A5E, 8, case);
        let n_ranks = 1 + rng.below(6) as usize;
        let len = rng.below(2000) as usize;
        let algo = match rng.below(4) {
            0 => AllReduceAlgo::Ring,
            1 => AllReduceAlgo::Tree,
            2 => AllReduceAlgo::Flat,
            _ => AllReduceAlgo::Hierarchical(Dragonfly {
                groups: 1 + rng.below(5) as usize,
                nodes_per_group: 1 + rng.below(5) as usize,
                ..Dragonfly::default()
            }),
        };
        let net = NetModel {
            alpha_s: rng.uniform() * 1e-5,
            beta_bytes_per_s: 1e6 + rng.uniform() * 1e9,
            algo,
        };
        let phases = net.allreduce_phases(len, n_ranks);
        assert!(phases.local_s >= 0.0 && phases.global_s >= 0.0, "case {case}");
        assert_eq!(
            phases.total(),
            net.allreduce_time(len, n_ranks),
            "case {case}: phases do not sum to the reported total"
        );
        let posts: Vec<f64> = (0..n_ranks).map(|_| rng.uniform() * 5.0).collect();
        let max_post = posts.iter().cloned().fold(f64::MIN, f64::max);
        let group = Group::new(n_ranks, net);
        let handles: Vec<_> = (0..n_ranks)
            .map(|r| {
                let mut c = group.comm(r);
                let post = posts[r];
                std::thread::spawn(move || {
                    c.iallreduce(&vec![1.0f32; len], post).wait_timed(post)
                })
            })
            .collect();
        for h in handles {
            let (_, t_done, got) = h.join().unwrap();
            assert_eq!(got, phases, "case {case}: wait_timed phases mismatch");
            assert!(
                (t_done - (max_post + phases.total())).abs() < 1e-9,
                "case {case}: completion {t_done} vs {}",
                max_post + phases.total()
            );
        }
    }
}

/// Property: global-link contention can only *slow* the global phase —
/// for any payload, rank count, group shape and taper, the contended
/// [`dcs3gd::comm::PhaseTimes`] dominate the dedicated ones with
/// bit-equal local phases, and a taper at or above the leader-phase
/// flow count (or a single concurrent flow) prices exactly the
/// dedicated link.
#[test]
fn prop_contended_phases_dominate_dedicated() {
    for case in 0..CASES {
        let mut rng = Rng::keyed(0xC027, 9, case);
        let n_ranks = 2 + rng.below(63) as usize;
        let len = 1 + rng.below(5000) as usize;
        let npg = 1 + rng.below(6) as usize;
        let taper = 1 + rng.below(4) as usize;
        let base = Dragonfly {
            nodes_per_group: npg,
            global_taper: 8, // >= LEADER_RING_FLOWS: dedicated
            ..Dragonfly::default()
        };
        let contended = Dragonfly { global_taper: taper, ..base };
        let pd = Hierarchical { topology: base }.allreduce_phases(len, n_ranks);
        let pc = Hierarchical { topology: contended }.allreduce_phases(len, n_ranks);
        assert_eq!(
            pc.local_s.to_bits(),
            pd.local_s.to_bits(),
            "case {case}: contention touched the local phase"
        );
        assert!(
            pc.global_s >= pd.global_s,
            "case {case}: contention sped the global phase up ({} < {})",
            pc.global_s,
            pd.global_s
        );
        if taper >= LEADER_RING_FLOWS {
            assert_eq!(
                pc.global_s.to_bits(),
                pd.global_s.to_bits(),
                "case {case}: taper {taper} >= flows must be dedicated"
            );
        }
        // refit keeps the contention parameters — the membership
        // transition invariant
        let refit = contended.refit(1 + rng.below(100) as usize);
        assert_eq!(refit.global_taper, contended.global_taper, "case {case}");
        assert_eq!(refit.beta_global, contended.beta_global, "case {case}");
        // one concurrent flow never contends, whatever the link count
        let link = Link {
            alpha_s: rng.uniform() * 1e-5,
            beta_bytes_per_s: 1e6 + rng.uniform() * 1e10,
        };
        let one = GlobalContention { links: taper, flows: 1 }.contend(link);
        assert_eq!(one, link, "case {case}: a single flow contended");
    }
}

/// Property: the wire-level hierarchical executor (grouped data
/// movement) agrees with the wire-level ring for any group shape —
/// including uneven, singleton, and oversize groups.
#[test]
fn prop_wire_hier_matches_wire_ring() {
    for case in 0..CASES {
        let mut rng = Rng::keyed(0x41E5, 9, case);
        let n_ranks = 1 + rng.below(9) as usize;
        let m = 1 + rng.below(5) as usize;
        let len = 1 + rng.below(300) as usize;
        let inputs: Vec<Vec<f32>> = (0..n_ranks)
            .map(|r| {
                let mut rr = Rng::keyed(case ^ 0x717, r as u64, 3);
                randvec(&mut rr, len, 1.0)
            })
            .collect();
        let spawn_all = |bufs: Vec<Vec<f32>>, use_hier: bool| -> Vec<Vec<f32>> {
            if use_hier {
                let comms = hier_network(n_ranks, m);
                let handles: Vec<_> = comms
                    .into_iter()
                    .zip(bufs)
                    .map(|(c, mut buf)| {
                        std::thread::spawn(move || {
                            c.allreduce(&mut buf);
                            buf
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            } else {
                let comms = ring_network(n_ranks);
                let handles: Vec<_> = comms
                    .into_iter()
                    .zip(bufs)
                    .map(|(c, mut buf)| {
                        std::thread::spawn(move || {
                            c.allreduce(&mut buf);
                            buf
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            }
        };
        let ring_out = spawn_all(inputs.clone(), false);
        let hier_out = spawn_all(inputs, true);
        for (r_buf, h_buf) in ring_out.iter().zip(&hier_out) {
            for (a, b) in r_buf.iter().zip(h_buf) {
                assert!(
                    (a - b).abs() <= 1e-4 * b.abs().max(1.0),
                    "case {case} (n={n_ranks}, m={m}): {a} vs {b}"
                );
            }
        }
    }
}

/// Property (membership epochs): a round that resolves over the
/// survivors after a departure is **bit-identical** to a flat
/// all-reduce recomputed on the survivor set alone — the epoch
/// transition changes who participates, never the arithmetic — and the
/// contributor set reported to the consumers is exactly the survivors.
#[test]
fn prop_epoch_transition_allreduce_matches_survivor_recompute() {
    for case in 0..CASES {
        let mut rng = Rng::keyed(0xE90C, 10, case);
        let n_ranks = 2 + rng.below(7) as usize;
        let len = 1 + rng.below(300) as usize;
        // at least one survivor, at least one leaver
        let n_leavers = 1 + rng.below(n_ranks as u64 - 1) as usize;
        let mut ranks: Vec<usize> = (0..n_ranks).collect();
        rng.shuffle(&mut ranks);
        let mut leavers = ranks[..n_leavers].to_vec();
        let mut survivors = ranks[n_leavers..].to_vec();
        leavers.sort_unstable();
        survivors.sort_unstable();
        let inputs: Vec<Vec<f32>> = (0..n_ranks)
            .map(|r| {
                let mut rr = Rng::keyed(case ^ 0xE1A5, r as u64, 4);
                randvec(&mut rr, len, 1.0)
            })
            .collect();

        // Round 0: everyone posts. Round 1: only the survivors post —
        // the leavers deregister instead, so round 1 must resolve over
        // the survivor set.
        let group = Group::new(n_ranks, NetModel::instant());
        let mut handles = Vec::new();
        for r in 0..n_ranks {
            let mut c = group.comm(r);
            let data = inputs[r].clone();
            let is_leaver = leavers.contains(&r);
            handles.push(std::thread::spawn(move || {
                let h0 = c.iallreduce(&data, 0.0);
                if is_leaver {
                    c.leave();
                    let _ = h0.wait(0.0);
                    None
                } else {
                    let _ = h0.wait(0.0);
                    let out = c.iallreduce(&data, 0.0).wait_outcome(0.0);
                    Some((out.data.as_ref().clone(), out.contributors.as_ref().clone()))
                }
            }));
        }
        let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();

        // Flat recompute on the survivor set, in rank order — the
        // bitwise reference.
        let flat = Group::new(survivors.len(), NetModel::instant());
        let flat_handles: Vec<_> = survivors
            .iter()
            .enumerate()
            .map(|(slot, &r)| {
                let mut c = flat.comm(slot);
                let data = inputs[r].clone();
                std::thread::spawn(move || c.allreduce(&data, 0.0).0.as_ref().clone())
            })
            .collect();
        let flat_sums: Vec<Vec<f32>> =
            flat_handles.into_iter().map(|h| h.join().unwrap()).collect();
        let reference = &flat_sums[0];

        for (r, res) in results.iter().enumerate() {
            let Some((sum, contributors)) = res else {
                assert!(leavers.contains(&r), "case {case}: survivor produced no round 1");
                continue;
            };
            assert_eq!(contributors, &survivors, "case {case}: contributor set wrong");
            for (i, (a, b)) in sum.iter().zip(reference.iter()).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "case {case}: survivor-set sum differs from flat recompute at [{i}]"
                );
            }
        }
    }
}

/// Property (error feedback): for any gradient stream and any top-k
/// ratio, the per-window identity `q_t + e_t == v_t` with
/// `v_t = g_t + e_{t−1}` holds **bitwise** — top-k masks coordinates,
/// it never rounds them, so the dropped mass telescopes exactly.
#[test]
fn prop_error_feedback_telescopes_bitwise() {
    for case in 0..CASES {
        let mut rng = Rng::keyed(0xEF00, 11, case);
        let n = 2 + rng.below(400) as usize;
        let ratio = rng.uniform_range(0.01, 1.0);
        let mut comp = TopK::new(n, ratio);
        let windows = 1 + rng.below(6);
        for w in 0..windows {
            let mut delta = vec![0.0f32; n];
            let mut dr = Rng::keyed(case ^ 0xEF, w, 5);
            dr.fill_normal(&mut delta);
            let e_before: Vec<f32> = comp.residual().to_vec();
            let mut own = vec![0.0f32; n];
            comp.compress(&delta, &mut own, 0);
            for i in 0..n {
                let v = delta[i] + e_before[i];
                let q_plus_e = own[i] + comp.residual()[i];
                // bitwise, modulo the sign of zero (q + 0.0 normalizes
                // a −0.0 that the mask would have preserved)
                assert!(
                    v.to_bits() == q_plus_e.to_bits() || (v == 0.0 && q_plus_e == 0.0),
                    "case {case} window {w} elem {i}: q+e != v ({v} vs {q_plus_e})"
                );
            }
        }
    }
}

/// Property: at ratio 1.0, a top-k round decoded through the codec is
/// **bit-identical** to the dense all-reduce of the same contributions
/// — the sparse scatter-add accumulates per element in the same rank
/// order the dense reduction does.
#[test]
fn prop_topk_ratio_one_decodes_to_dense_sum_bitwise() {
    for case in 0..CASES {
        let mut rng = Rng::keyed(0x701C, 12, case);
        let n_ranks = 1 + rng.below(6) as usize;
        let n = 1 + rng.below(300) as usize;
        let inputs: Vec<Vec<f32>> = (0..n_ranks)
            .map(|r| {
                let mut rr = Rng::keyed(case ^ 0x70, r as u64, 6);
                let mut v = vec![0.0f32; n];
                rr.fill_normal(&mut v);
                v
            })
            .collect();
        // dense reference: accumulate in rank order (what the
        // rendezvous substrate does)
        let mut dense = vec![0.0f32; n];
        for v in &inputs {
            tensor::add_assign(&mut dense, v);
        }
        // sparse path: every rank encodes at ratio 1, segments are
        // concatenated in rank order, the codec scatter-adds
        let cfg = CompressConfig { kind: CompressorKind::TopK, ratio: 1.0, ..Default::default() };
        let mut payload = Vec::new();
        for (r, v) in inputs.iter().enumerate() {
            let mut codec = WindowCodec::new(&cfg, n, 0, r);
            codec.rebind(r, n_ranks);
            let mut own = vec![0.0f32; n];
            payload.extend(codec.encode(v, 0.0, 0.0, &mut own));
        }
        let decoder = {
            let mut c = WindowCodec::new(&cfg, n, 0, 0);
            c.rebind(0, n_ranks);
            c
        };
        let mut sum = vec![0.0f32; n];
        decoder.decode(&payload, n_ranks, &mut sum);
        for i in 0..n {
            assert_eq!(
                sum[i].to_bits(),
                dense[i].to_bits(),
                "case {case}: sparse ratio-1 sum differs from dense at [{i}]"
            );
        }
    }
}

/// Property (QSGD): for any input and bit width, the quantization error
/// per coordinate is at most one level step `max|v| / (2^(bits−1) − 1)`,
/// and `q + e` reconstructs `v` to f32 subtraction accuracy.
#[test]
fn prop_qsgd_error_bounded_by_level_step() {
    for case in 0..CASES {
        let mut rng = Rng::keyed(0x95D9, 13, case);
        let n = 1 + rng.below(300) as usize;
        let bits = 2 + rng.below(7) as u32;
        let mut comp = Qsgd::new(n, bits, case, rng.below(16));
        let mut delta = vec![0.0f32; n];
        rng.fill_normal(&mut delta);
        let mut own = vec![0.0f32; n];
        comp.compress(&delta, &mut own, 0);
        let s = delta.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        let step = s / ((1u64 << (bits - 1)) - 1) as f32;
        for i in 0..n {
            let err = (own[i] - delta[i]).abs();
            assert!(
                err <= step * 1.0001,
                "case {case} elem {i}: |q − v| = {err} > step {step} (bits {bits})"
            );
            let recon = own[i] + comp.residual()[i];
            assert!(
                (recon - delta[i]).abs() <= 1e-5 * s.max(1e-20),
                "case {case} elem {i}: q + e does not reconstruct v"
            );
        }
    }
}

/// Property (Eq. 8/9): for any worker updates, applying `w_i + D_i`
/// brings every worker exactly to `w̄ + mean(Δw)`, and Σ_i D_i = 0.
#[test]
fn prop_averaging_identity() {
    for case in 0..CASES {
        let mut rng = Rng::keyed(0xE98, 2, case);
        let n_workers = 2 + rng.below(14) as usize;
        let n = 1 + rng.below(200) as usize;
        let w_bar = randvec(&mut rng, n, 1.0);
        let deltas: Vec<Vec<f32>> =
            (0..n_workers).map(|_| randvec(&mut rng, n, 0.1)).collect();
        let mut sum = vec![0.0f32; n];
        for d in &deltas {
            tensor::add_assign(&mut sum, d);
        }
        let mut d_total = vec![0.0f64; n];
        for delta in &deltas {
            let mut dist = vec![0.0f32; n];
            dc::distance_to_average(&sum, delta, n_workers, &mut dist);
            let wi: Vec<f32> = w_bar
                .iter()
                .zip(delta)
                .zip(&dist)
                .map(|((w, d), dd)| w + d + dd)
                .collect();
            for i in 0..n {
                let want = w_bar[i] + sum[i] / n_workers as f32;
                assert!((wi[i] - want).abs() <= 1e-4, "case {case} elem {i}");
                d_total[i] += dist[i] as f64;
            }
        }
        for (i, t) in d_total.iter().enumerate() {
            assert!(t.abs() <= 1e-3, "case {case}: Σ D_i [{i}] = {t} ≠ 0");
        }
    }
}

/// Property (Eq. 17): the dynamic λ always normalizes the correction to
/// exactly λ0·‖g‖, for any non-degenerate inputs, at any scale.
#[test]
fn prop_lambda_normalization() {
    for case in 0..CASES {
        let mut rng = Rng::keyed(0x1AB, 3, case);
        let n = 2 + rng.below(400) as usize;
        let scale = 10f32.powf(rng.uniform_range(-3.0, 3.0));
        let g = randvec(&mut rng, n, scale);
        let d = randvec(&mut rng, n, 0.1);
        let lam0 = rng.uniform_range(0.01, 2.0);
        let lam = dc::dynamic_lambda(&g, &d, lam0);
        let corr: Vec<f32> = (0..n).map(|i| lam * g[i] * g[i] * d[i]).collect();
        let want = lam0 as f64 * tensor::norm2(&g);
        let got = tensor::norm2(&corr);
        assert!(
            (got - want).abs() <= 1e-3 * want.max(1e-12),
            "case {case}: ‖corr‖ {got} vs λ0‖g‖ {want}"
        );
    }
}

/// Property: the LR schedule is piecewise linear, continuous at the
/// warmup stop, non-negative, and zero at/after `total`.
#[test]
fn prop_schedule_shape() {
    for case in 0..CASES {
        let mut rng = Rng::keyed(0x5C4E, 4, case);
        let total = 10 + rng.below(5000);
        let planned = 1 + rng.below(total);
        let stop = rng.below(planned + 1).min(total - 1);
        let peak = rng.uniform_range(0.01, 20.0);
        let s = LrSchedule::paper(peak, planned, stop, total);
        let mut prev = 0.0f32;
        for it in 0..total + 10 {
            let v = s.at(it);
            assert!(v >= 0.0, "case {case}: negative lr at {it}");
            assert!(v <= peak * 1.0001, "case {case}: above peak at {it}");
            if it >= total {
                assert_eq!(v, 0.0, "case {case}: nonzero after total");
            }
            if it < stop {
                assert!(v >= prev, "case {case}: warmup not increasing at {it}");
            } else if it > stop && it < total {
                assert!(v <= prev + 1e-6, "case {case}: decay not decreasing at {it}");
            }
            prev = v;
        }
        // continuity at the stop: |lr(stop) − reached| small
        if stop > 0 {
            let jump = (s.at(stop) - s.reached_peak()).abs();
            assert!(jump <= peak / planned as f32 + 1e-6, "case {case}: jump {jump}");
        }
    }
}

/// Property: shard sampling partitions the corpus for any (n_train,
/// n_ranks), and every epoch visits each shard index exactly once.
#[test]
fn prop_sharding_partition() {
    for case in 0..CASES {
        let mut rng = Rng::keyed(0x5A4D, 5, case);
        let n_ranks = 1 + rng.below(9) as usize;
        let n_train = (n_ranks * (1 + rng.below(40) as usize)).max(n_ranks);
        let ds = SyntheticDataset::new(case, 8, 3, n_train, 4);
        let mut seen = vec![0u32; n_train];
        for rank in 0..n_ranks {
            let shard_len = (rank..n_train).step_by(n_ranks).count();
            if shard_len == 0 {
                continue;
            }
            let batch = 1 + rng.below(shard_len as u64) as usize;
            let mut s = ShardSampler::new(&ds, rank, n_ranks, batch);
            let full_batches = shard_len / batch;
            for _ in 0..full_batches {
                for idx in s.next_batch() {
                    seen[idx] += 1;
                }
            }
            // each index seen at most once per epoch
        }
        assert!(seen.iter().all(|&c| c <= 1), "case {case}: duplicate across shards");
    }
}

/// Property (engine core): the `[perf]` worker pool moves wall-clock
/// only. For any engine × schedule × compression × heterogeneity ×
/// membership-churn draw, the same config run at `threads ∈ {1, 2, 8}`
/// produces byte-identical deterministic run JSON (the metrics export
/// minus the wall-clock `"perf"` / `"wall_time_s"` fields) and
/// identical epoch param CRCs. The PS baselines join the property at
/// `nodes = 1` only (the last two cases), where the request stream is
/// program-ordered and determinism is contractual; at `nodes ≥ 2` they
/// stay excluded by design — ASGD applies updates in *arrival* order,
/// and that nondeterminism is the phenomenon under study, not a pool
/// artifact.
#[test]
fn prop_parallel_engine_bitwise_equals_serial() {
    // Each case is three full runs — fewer, fatter cases than the
    // kernel properties above.
    const ENGINE_CASES: u64 = 10;
    for case in 0..ENGINE_CASES {
        let mut rng = Rng::keyed(0xE291, 14, case);
        let algo = match case {
            c if c == ENGINE_CASES - 2 => Algo::Asgd,
            c if c == ENGINE_CASES - 1 => Algo::DcAsgd,
            _ => match rng.below(5) {
                0 => Algo::Ssgd,
                1 => Algo::S3gd,
                2 => Algo::DcS3gd,
                3 => Algo::DynSsp,
                _ => Algo::Sgs,
            },
        };
        let drawn_nodes = 2 + rng.below(4) as usize;
        let nodes = if algo.is_decentralized() { drawn_nodes } else { 1 };
        let steps = 6 + rng.below(7);
        let local_batch = [4usize, 8][rng.below(2) as usize];
        let net_algo = match rng.below(4) {
            0 => AllReduceAlgo::Ring,
            1 => AllReduceAlgo::Tree,
            2 => AllReduceAlgo::Flat,
            _ => AllReduceAlgo::Hierarchical(Dragonfly {
                nodes_per_group: 1 + rng.below(3) as usize,
                ..Dragonfly::default()
            }),
        };
        let net = NetModel { alpha_s: 1e-6, beta_bytes_per_s: 1e9, algo: net_algo };

        let mut b = ExperimentConfig::builder("linear")
            .name("prop_engine")
            .algo(algo)
            .nodes(nodes)
            .local_batch(local_batch)
            .steps(steps)
            .seed(1000 + case)
            .eta_single(0.05)
            .base_batch(nodes * local_batch)
            .data(512, 128, 0.5)
            .eval_every(4, 2)
            .compute(ComputeModel::uniform(1e-3))
            .net(net);
        // Compression (every decentralized engine supports it).
        match rng.below(3) {
            0 => {}
            1 => b = b.compress_topk(rng.uniform_range(0.05, 0.5)),
            _ => b = b.compress_qsgd([4u32, 8][rng.below(2) as usize]),
        }
        // Heterogeneity: tier spread + diurnal load + link spread. The
        // profile is a seeded draw from the config — identical across
        // the three runs by construction.
        if rng.below(2) == 1 {
            b = b.hetero(HeteroConfig {
                enabled: true,
                tiers: vec![1.0, 1.0 + rng.uniform()],
                diurnal_amplitude: 0.2,
                diurnal_period_s: 0.05,
                link_spread: 0.2,
                ..HeteroConfig::default()
            });
        }
        // The PS cases exercise the tier shape too: sharding,
        // replication and the λ rule must all be invisible to the
        // single-worker weight trajectory.
        if !algo.is_decentralized() {
            b = b
                .ps_shards(1 + rng.below(3) as usize)
                .ps_replicas(1 + rng.below(2) as usize)
                .ps_lambda(["dynamic", "adaptive"][rng.below(2) as usize]);
        }
        // Membership churn rides the windowed engines: one mid-run
        // departure, sometimes followed by a join of a fresh rank.
        if algo.is_windowed() && nodes >= 3 && rng.below(2) == 1 {
            let leaver = 1 + rng.below(nodes as u64 - 1) as usize;
            let t_dep = rng.uniform_range(0.005, 0.03) as f64;
            b = b.faults(FaultPlan::new().depart(leaver, t_dep));
            if rng.below(2) == 1 {
                b = b.join(nodes, t_dep + 0.02);
            }
        }
        let cfg = b.build();

        let runs: Vec<(String, Vec<u64>, String)> = [1usize, 2, 8]
            .iter()
            .map(|&threads| {
                let mut c = cfg.clone();
                c.perf.threads = threads;
                let report = run_experiment(&c)
                    .unwrap_or_else(|e| panic!("case {case} (threads {threads}): {e}"));
                let json = report.deterministic_json().to_string();
                let crcs: Vec<u64> =
                    report.epochs.records().iter().map(|r| r.w_crc).collect();
                (json, crcs, report.obs_journal_canonical())
            })
            .collect();
        assert!(
            !runs[0].2.is_empty(),
            "case {case} ({}): serial run journaled no events",
            cfg.algo.name()
        );
        for (i, (json, crcs, trace)) in runs.iter().enumerate().skip(1) {
            let threads = [1usize, 2, 8][i];
            assert_eq!(
                json, &runs[0].0,
                "case {case} ({}): run JSON at threads={threads} diverged from serial",
                cfg.algo.name()
            );
            assert_eq!(
                crcs, &runs[0].1,
                "case {case} ({}): epoch param CRCs at threads={threads} diverged",
                cfg.algo.name()
            );
            // The obs journal's virtual-time event sequence (wall-time
            // stripped) is part of the contract too: same events, same
            // order, whatever the thread interleaving was.
            assert_eq!(
                trace, &runs[0].2,
                "case {case} ({}): obs journal at threads={threads} diverged from serial",
                cfg.algo.name()
            );
        }
    }
}

/// Property (PS tier): replication is placement/service state only.
/// For any shards × replicas × mode × compression × fabric × churn
/// draw, a fixed sequential request stream produces bit-identical
/// replies and final weights on a replicated deployment and its
/// single-home counterpart. Timing (`done_at`) is allowed to differ —
/// that is precisely what replication changes.
#[test]
fn prop_ps_replication_bitwise_equals_single_home() {
    const PS_CASES: u64 = 12;
    for case in 0..PS_CASES {
        let mut rng = Rng::keyed(0x9512, 21, case);
        let n = 32 + rng.below(300) as usize;
        let workers = 2 + rng.below(5) as usize;
        let shards = 1 + rng.below(4) as usize;
        let replicas = 2 + rng.below(3) as usize;
        let mode = match rng.below(3) {
            0 => PsMode::Asgd,
            1 => PsMode::DcAsgd { lam0: rng.uniform_range(0.1, 0.5) },
            _ => PsMode::DcAsgdAdaptive { lam0: rng.uniform_range(0.1, 0.5) },
        };
        let compress = match rng.below(3) {
            0 => CompressConfig::default(),
            1 => CompressConfig {
                kind: CompressorKind::TopK,
                ratio: rng.uniform_range(0.05, 0.5),
                ..CompressConfig::default()
            },
            _ => CompressConfig {
                kind: CompressorKind::Qsgd,
                bits: [4u32, 8][rng.below(2) as usize],
                ..CompressConfig::default()
            },
        };
        let net_algo = if rng.below(2) == 0 {
            AllReduceAlgo::Ring
        } else {
            AllReduceAlgo::Hierarchical(Dragonfly {
                groups: 2,
                nodes_per_group: 1 + rng.below(3) as usize,
                ..Dragonfly::default()
            })
        };
        let net = NetModel { alpha_s: 1e-6, beta_bytes_per_s: 1e9, algo: net_algo };
        // Churn in half the cases: one mid-roster rank leaves at the
        // t = 0.5 boundary (the primary rotates with the epoch on the
        // replicated side — the weights must not notice).
        let full: Vec<usize> = (0..workers).collect();
        let (boundaries, rosters) = if workers > 2 && rng.below(2) == 1 {
            let leaver = 1 + rng.below(workers as u64 - 1) as usize;
            let shrunk: Vec<usize> =
                full.iter().copied().filter(|&r| r != leaver).collect();
            (vec![0.5], vec![full.clone(), shrunk])
        } else {
            (Vec::new(), vec![full.clone()])
        };
        let mu = [0.0f32, 0.9][rng.below(2) as usize];
        let init = {
            let mut ir = Rng::keyed(case, 77, 0);
            randvec(&mut ir, n, 0.5)
        };
        let seed = 100 + case;

        let run = |reps: usize| -> Vec<Vec<f32>> {
            let plan = ReplicaPlan::place(
                reps,
                &net,
                workers,
                true,
                boundaries.clone(),
                rosters.clone(),
            );
            let spec = PsTierSpec {
                n_shards: shards,
                mode,
                net,
                serve_s_per_elem: 1e-8,
                compress,
                seed,
                capacity: workers,
                plan,
            };
            let tier = PsTier::spawn(&init, spec, &mut |lo, hi| {
                Box::new(MomentumSgd::new(hi - lo, mu))
            });
            let mut clients: Vec<_> = (0..workers).map(|r| tier.client(r)).collect();
            for (slot, c) in clients.iter_mut().enumerate() {
                c.rebind(slot, workers);
            }
            let mut replies = Vec::new();
            // Epoch 0: three sequential rounds over the full roster.
            for it in 0..3u64 {
                for (j, &w) in rosters[0].iter().enumerate() {
                    let mut gr = Rng::keyed(case ^ 0xA5, it * 16 + j as u64, 2);
                    let g = randvec(&mut gr, n, 0.1);
                    let t = 0.03 * (it as f64 * workers as f64 + j as f64);
                    replies.push(clients[w].push_pull(w, &g, t, 0.05, 1e-4).weights);
                }
            }
            // Past the boundary: survivors rebind to their shrunk
            // slots and keep pushing.
            if rosters.len() > 1 {
                for (slot, &w) in rosters[1].iter().enumerate() {
                    clients[w].rebind(slot, rosters[1].len());
                }
                for it in 0..2u64 {
                    for (j, &w) in rosters[1].iter().enumerate() {
                        let mut gr = Rng::keyed(case ^ 0x5A, it * 16 + j as u64, 3);
                        let g = randvec(&mut gr, n, 0.1);
                        let t = 1.0 + 0.03 * (it as f64 * workers as f64 + j as f64);
                        replies.push(clients[w].push_pull(w, &g, t, 0.05, 1e-4).weights);
                    }
                }
            }
            // A read-only refresh rides the same contract.
            let reader = rosters[rosters.len() - 1][0];
            replies.push(clients[reader].pull(reader, 2.0).weights);
            drop(clients);
            let (w_final, _, _) = tier.shutdown();
            replies.push(w_final);
            replies
        };

        let single = run(1);
        let replicated = run(replicas);
        assert_eq!(single.len(), replicated.len());
        for (i, (a, b)) in single.iter().zip(&replicated).enumerate() {
            for (j, (x, y)) in a.iter().zip(b).enumerate() {
                assert!(
                    x.to_bits() == y.to_bits(),
                    "case {case} ({} shards, {replicas} replicas, {}): reply {i} \
                     elem {j} diverged: replicated {y} != single-home {x}",
                    shards,
                    compress.kind.name()
                );
            }
        }
    }
}

/// Property (simulator backends): the folded rendezvous backend is
/// **bit-identical** to the dense one. For any engine × schedule ×
/// compression × heterogeneity × membership-churn × probing draw at
/// N ∈ {64, 256, 1024}, the same config run with
/// `[sim] backend = "dense"` and `"folded"` produces byte-identical
/// deterministic run JSON and identical epoch param CRCs — the folded
/// backend changes how rounds store contributions and detect
/// completion (poster-only arena + contributor-count deltas vs
/// capacity-wide slots + roster scan), never what they compute.
#[test]
fn prop_folded_backend_equals_dense() {
    use dcs3gd::comm::SimBackend;
    use dcs3gd::control::{ControlPolicy, ProbeMode};
    // Larger fleets get fewer draws — each case is two full runs.
    for &(nodes, cases) in &[(64usize, 3u64), (256, 2), (1024, 1)] {
        for case in 0..cases {
            let mut rng = Rng::keyed(0xF01D, nodes as u64, case);
            let algo = match rng.below(4) {
                0 => Algo::Ssgd,
                1 => Algo::DcS3gd,
                2 => Algo::DynSsp,
                _ => Algo::Sgs,
            };
            let net_algo = match rng.below(4) {
                0 => AllReduceAlgo::Ring,
                1 => AllReduceAlgo::Tree,
                2 => AllReduceAlgo::Flat,
                _ => AllReduceAlgo::Hierarchical(Dragonfly::for_nodes(nodes)),
            };
            let net = NetModel { alpha_s: 1e-6, beta_bytes_per_s: 1e9, algo: net_algo };
            let steps = 4 + rng.below(3);

            let mut b = ExperimentConfig::builder("linear")
                .name("prop_backend")
                .algo(algo)
                .nodes(nodes)
                .local_batch(2)
                .steps(steps)
                .seed(7000 + case)
                .eta_single(0.05)
                .base_batch(nodes * 2)
                .data(nodes * 4, 64, 0.5)
                .eval_every(0, 2)
                .compute(ComputeModel::uniform(1e-3))
                .threads(4)
                .net(net);
            // Compression (every decentralized engine supports it).
            match rng.below(3) {
                0 => {}
                1 => b = b.compress_topk(rng.uniform_range(0.05, 0.5)),
                _ => b = b.compress_qsgd([4u32, 8][rng.below(2) as usize]),
            }
            // Heterogeneity: tier spread + diurnal load + link spread
            // (seeded draw — identical across both runs).
            if rng.below(2) == 1 {
                b = b.hetero(HeteroConfig {
                    enabled: true,
                    tiers: vec![1.0, 1.0 + rng.uniform()],
                    diurnal_amplitude: 0.2,
                    diurnal_period_s: 0.05,
                    link_spread: 0.2,
                    ..HeteroConfig::default()
                });
            }
            // Schedule probing rides the control plane.
            if rng.below(2) == 1 {
                b = b.control_policy(ControlPolicy::ScheduleCoupled);
            }
            // Membership churn on the windowed engines: a mid-run
            // departure, sometimes followed by a fresh-rank join.
            if algo.is_windowed() && rng.below(2) == 1 {
                let leaver = 1 + rng.below(nodes as u64 - 1) as usize;
                let t_dep = rng.uniform_range(0.005, 0.03) as f64;
                b = b.faults(FaultPlan::new().depart(leaver, t_dep));
                if rng.below(2) == 1 {
                    b = b.join(nodes, t_dep + 0.02);
                }
            }
            let mut cfg = b.build();
            if rng.below(2) == 1 {
                cfg.control.probe = ProbeMode::Interval;
                cfg.control.probe_interval = 3;
            }

            let runs: Vec<(String, Vec<u64>, String)> = [SimBackend::Dense, SimBackend::Folded]
                .iter()
                .map(|&backend| {
                    let mut c = cfg.clone();
                    c.sim.backend = backend;
                    let report = run_experiment(&c).unwrap_or_else(|e| {
                        panic!("N={nodes} case {case} ({}): {e}", backend.name())
                    });
                    let json = report.deterministic_json().to_string();
                    let crcs: Vec<u64> =
                        report.epochs.records().iter().map(|r| r.w_crc).collect();
                    (json, crcs, report.obs_journal_canonical())
                })
                .collect();
            assert_eq!(
                runs[1].0,
                runs[0].0,
                "N={nodes} case {case} ({}): folded run JSON diverged from dense",
                cfg.algo.name()
            );
            assert_eq!(
                runs[1].1,
                runs[0].1,
                "N={nodes} case {case} ({}): folded epoch param CRCs diverged",
                cfg.algo.name()
            );
            assert!(!runs[0].2.is_empty(), "N={nodes} case {case}: empty dense journal");
            assert_eq!(
                runs[1].2,
                runs[0].2,
                "N={nodes} case {case} ({}): folded obs journal diverged from dense",
                cfg.algo.name()
            );
        }
    }
}

/// Property: dataset samples are identical regardless of generation
/// order or batch grouping (pure function of index).
#[test]
fn prop_dataset_order_independent() {
    for case in 0..10 {
        let ds = SyntheticDataset::new(case, 8, 4, 64, 8);
        let px = 8 * 8 * 3;
        let mut rng = Rng::keyed(0xDA7A, 6, case);
        let i = rng.below(64) as usize;
        let mut a = vec![0.0; px];
        let la = ds.sample_into(Split::Train, i, &mut a);
        // generate a bunch of other samples in between
        let mut scratch = vec![0.0; px];
        for j in 0..10 {
            ds.sample_into(Split::Train, (i + j + 1) % 64, &mut scratch);
        }
        let mut b = vec![0.0; px];
        let lb = ds.sample_into(Split::Train, i, &mut b);
        assert_eq!(la, lb);
        assert_eq!(a, b, "case {case}");
    }
}
