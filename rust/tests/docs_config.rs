//! The docs/ book cannot rot: every TOML snippet in `docs/config.md`
//! must parse through the real config parser, and the run-JSON keys
//! documented in `docs/run-json.md` must match what the exporter
//! actually emits.

use dcs3gd::algo::run_experiment;
use dcs3gd::config::ExperimentConfig;
use dcs3gd::simtime::ComputeModel;
use dcs3gd::util::Json;

fn doc(name: &str) -> String {
    let path = format!("{}/../docs/{name}", env!("CARGO_MANIFEST_DIR"));
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("reading {path}: {e}"))
}

/// Extract the fenced ```toml blocks of a markdown page as
/// (starting line, body) pairs.
fn toml_snippets(text: &str) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    let mut cur: Option<(usize, String)> = None;
    for (i, line) in text.lines().enumerate() {
        let t = line.trim();
        match &mut cur {
            None if t == "```toml" => cur = Some((i + 2, String::new())),
            Some((start, body)) => {
                if t == "```" {
                    out.push((*start, std::mem::take(body)));
                    cur = None;
                } else {
                    body.push_str(line);
                    body.push('\n');
                }
            }
            None => {}
        }
    }
    assert!(cur.is_none(), "unterminated ```toml fence");
    out
}

#[test]
fn every_documented_toml_snippet_parses() {
    let text = doc("config.md");
    let snippets = toml_snippets(&text);
    assert!(
        snippets.len() >= 10,
        "docs/config.md lost its examples (found {})",
        snippets.len()
    );
    for (line, body) in snippets {
        if let Err(e) = ExperimentConfig::from_toml_str(&body) {
            panic!("docs/config.md snippet at line {line} does not parse: {e:#}\n---\n{body}");
        }
    }
}

#[test]
fn config_reference_names_every_table() {
    let text = doc("config.md");
    for table in [
        "[optim]",
        "[data]",
        "[net]",
        "[comm]",
        "[comm.contention]",
        "[compute]",
        "[eval]",
        "[control]",
        "[[control.fault]]",
        "[[control.join]]",
        "[compress]",
        "[ps]",
        "[hetero]",
        "[perf]",
        "[sim]",
        "[trace]",
        "Deprecated aliases",
    ] {
        assert!(text.contains(table), "docs/config.md lost the {table} section");
    }
    // the probing and heterogeneity knobs are the newest keys — pin
    // them explicitly
    for key in [
        "probe_interval",
        "probe_epsilon",
        "global_taper",
        "spot_fraction",
        "spot_correlation",
        "diurnal_amplitude",
        "link_spread",
        "tier_weights",
        "pin_chunk",
        "--sim-backend",
        "fault_duration_s",
        "--trace-out",
        "--trace-capacity",
        "--ps-shards",
        "--ps-lambda",
    ] {
        assert!(text.contains(key), "docs/config.md lost the {key} key");
    }
    // the parameter-server book page documents the tier's contracts:
    // bitwise replication, coalescing, Eq. 6 over decompressed payloads
    let ps = doc("parameter-server.md");
    for name in [
        "single-home",
        "coalesce",
        "repl_transfers",
        "wire_cut_x",
        "adaptive",
        "ps_parity.rs",
        "decompressed",
    ] {
        assert!(ps.contains(name), "docs/parameter-server.md lost {name:?}");
    }
    // the observability book page documents the trace subsystem:
    // event schema, metric registry, analyzer and the determinism
    // contract
    let obs = doc("observability.md");
    for name in [
        "round_posted",
        "round_sealed",
        "window_consumed",
        "epoch_transition",
        "overlap efficiency",
        "trace-report",
        "trace_to_chrome.py",
        "deterministic_json",
        "comp_ratio",
    ] {
        assert!(obs.contains(name), "docs/observability.md lost {name:?}");
    }
    // the heterogeneity book page documents both new engines
    let hetero = doc("heterogeneity.md");
    for name in ["dyn_ssp", "sgs", "k_min", "on-demand anchor"] {
        assert!(hetero.contains(name), "docs/heterogeneity.md lost {name:?}");
    }
    // the performance book page documents the engine-core knobs, its
    // determinism contract, and the bench lane's env switches
    let perf = doc("performance.md");
    for name in [
        "--threads",
        "--pin-chunk",
        "--sim-backend",
        "bit-identical",
        "BENCH_scale",
        "DCS3GD_BENCH_FAST",
        "DCS3GD_ENGINE_MIN_SPEEDUP",
    ] {
        assert!(perf.contains(name), "docs/performance.md lost {name:?}");
    }
    // the architecture page documents the event core's fold criterion
    // and the Engine/RoundDriver contract
    let arch = doc("architecture.md");
    for name in [
        "contributor-set deltas",
        "RoundDriver",
        "engine_registry",
        "REFOLD_QUIET_ROUNDS",
        "prop_folded_backend_equals_dense",
    ] {
        assert!(arch.contains(name), "docs/architecture.md lost {name:?}");
    }
}

#[test]
fn run_json_top_level_keys_match_docs() {
    // A real (tiny) run's exported JSON vs the documented key set —
    // both directions: nothing undocumented, nothing phantom.
    let cfg = ExperimentConfig::builder("linear")
        .name("docs_probe")
        .nodes(2)
        .local_batch(8)
        .steps(6)
        .data(256, 64, 0.5)
        .compute(ComputeModel::uniform(1e-4))
        .build();
    let report = run_experiment(&cfg).expect("tiny run");
    let json = report.to_json();
    let Json::Obj(map) = &json else { panic!("run JSON must be an object") };
    let docs = doc("run-json.md");
    for key in map.keys() {
        assert!(
            docs.contains(&format!("`{key}`")) || docs.contains(&format!("`\"{key}\"`")),
            "run-JSON key {key:?} is not documented in docs/run-json.md"
        );
    }
    // and the documented composite keys really exist in the export
    for key in ["control", "comm", "compress", "epochs", "evals", "hetero", "perf", "obs", "ps"] {
        assert!(map.contains_key(key), "documented key {key:?} missing from the export");
    }
    // decentralized runs carry the ps stub (consumers always find the
    // key); only PS-engine runs flip it on
    assert_eq!(
        json.get("ps").and_then(|p| p.get("enabled")),
        Some(&Json::Bool(false)),
        "a decentralized run must export the disabled ps stub"
    );
    // the engine-core profile carries its per-phase histograms, and the
    // deterministic view strips it together with wall_time_s
    assert!(
        json.get("perf").and_then(|p| p.get("phases")).is_some(),
        "perf JSON lost its phase histograms"
    );
    let det = report.deterministic_json();
    assert!(det.get("perf").is_none(), "deterministic JSON must strip \"perf\"");
    assert!(det.get("wall_time_s").is_none(), "deterministic JSON must strip \"wall_time_s\"");
    assert!(det.get("obs").is_none(), "deterministic JSON must strip \"obs\"");
    // the obs block itself is always present in the full export and
    // carries its headline metrics
    let obs = json.get("obs").expect("obs key");
    assert_eq!(obs.get("enabled"), Some(&Json::Bool(true)));
    for key in ["journal", "metrics", "windows", "ranks", "staleness", "overlap_efficiency_mean"] {
        assert!(obs.get(key).is_some(), "obs JSON lost {key:?}");
    }
    // the probe summary must be nested under "comm"
    assert!(
        json.get("comm").and_then(|c| c.get("probe")).is_some(),
        "comm JSON lost its probe summary"
    );
    // every control record carries the probe marker
    if let Some(records) = json.get("control").and_then(Json::as_arr) {
        for r in records {
            assert!(r.get("probe").and_then(Json::as_bool).is_some());
        }
    }
}
