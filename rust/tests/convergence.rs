//! Paper-shape convergence tests on the pure-rust backend: the
//! qualitative claims of §III-D / §IV that the benches quantify.
//!
//! These use the linear model (fast, deterministic) with enough steps
//! that the ordering DC-S3GD ≈ SSGD ≥ S3GD(λ=0) is stable.

use dcs3gd::algo::{run_experiment, Algo};
use dcs3gd::comm::NetModel;
use dcs3gd::config::ExperimentConfig;
use dcs3gd::simtime::ComputeModel;

fn cfg(algo: Algo, nodes: usize, seed: u64) -> ExperimentConfig {
    ExperimentConfig::builder("linear")
        .name(format!("conv_{}_{nodes}", algo.name()).leak())
        .algo(algo)
        .nodes(nodes)
        .local_batch(16)
        .steps(150)
        .eta_single(0.04)
        .base_batch(16)
        .momentum(0.9)
        .seed(seed)
        .data(2048, 512, 0.5)
        .compute(ComputeModel::uniform(1e-4))
        .net(NetModel::default())
        .build()
}

#[test]
fn dcs3gd_close_to_ssgd_final_loss() {
    // The paper's headline: stale-synchronous + compensation reaches
    // SSGD-level quality. Tolerance: within 15% relative train loss.
    let ssgd = run_experiment(&cfg(Algo::Ssgd, 4, 0)).unwrap();
    let dc = run_experiment(&cfg(Algo::DcS3gd, 4, 0)).unwrap();
    assert!(
        dc.final_train_loss <= ssgd.final_train_loss * 1.15,
        "dcs3gd {} vs ssgd {}",
        dc.final_train_loss,
        ssgd.final_train_loss
    );
    assert!(dc.final_val_err <= ssgd.final_val_err + 0.05);
}

#[test]
fn dcs3gd_is_faster_than_ssgd_when_comm_matters() {
    // With a slow network, overlap must beat blocking: Eq. 14 < Eq. 13.
    let slow_net = NetModel { alpha_s: 1e-5, beta_bytes_per_s: 5e7, ..NetModel::default() };
    let mut c_ssgd = cfg(Algo::Ssgd, 4, 0);
    c_ssgd.net = slow_net;
    c_ssgd.steps = 50;
    let mut c_dc = cfg(Algo::DcS3gd, 4, 0);
    c_dc.net = slow_net;
    c_dc.steps = 50;
    let ssgd = run_experiment(&c_ssgd).unwrap();
    let dc = run_experiment(&c_dc).unwrap();
    assert!(
        dc.mean_iter_time < ssgd.mean_iter_time,
        "overlap not faster: dcs3gd {} vs ssgd {}",
        dc.mean_iter_time,
        ssgd.mean_iter_time
    );
    assert!(dc.sim_throughput > ssgd.sim_throughput);
}

#[test]
fn compensation_distance_stays_bounded_as_n_grows() {
    // §III-D.2: DC-S3GD's correction distance ‖D_i‖ grows slowly with N
    // (distance to the *average*), while DC-ASGD's PS-to-worker distance
    // grows ~linearly. Check the ratio between N=2 and N=8 for both.
    let d2 = run_experiment(&cfg(Algo::DcS3gd, 2, 0)).unwrap().mean_dist_to_avg;
    let d8 = run_experiment(&cfg(Algo::DcS3gd, 8, 0)).unwrap().mean_dist_to_avg;
    let a2 = run_experiment(&cfg(Algo::DcAsgd, 2, 0)).unwrap().mean_dist_to_avg;
    let a8 = run_experiment(&cfg(Algo::DcAsgd, 8, 0)).unwrap().mean_dist_to_avg;
    assert!(d2 > 0.0 && a2 > 0.0, "distances must be observed");
    let dc_growth = d8 / d2;
    let ps_growth = a8 / a2;
    assert!(
        dc_growth < ps_growth,
        "DC-S3GD distance growth {dc_growth:.2}× should undercut DC-ASGD {ps_growth:.2}×"
    );
}

/// Mean per-iteration train-loss trajectory (averaged over workers).
fn loss_trajectory(report: &dcs3gd::algo::RunReport) -> Vec<f64> {
    let steps = report.recorder.steps();
    let iters = steps.iter().map(|s| s.iteration).max().unwrap() + 1;
    let mut acc = vec![(0f64, 0usize); iters as usize];
    for s in &steps {
        let e = &mut acc[s.iteration as usize];
        e.0 += s.loss as f64;
        e.1 += 1;
    }
    acc.into_iter().map(|(s, n)| s / n as f64).collect()
}

#[test]
fn trajectories_stay_close_to_ssgd_reference() {
    // The compensation's purpose (§III-B): make stale updates
    // approximate what synchronous training would have done. Assert the
    // DC-S3GD loss trajectory tracks SSGD closely (mean absolute gap a
    // small fraction of the loss range), across seeds — on a convex
    // model the three schemes converge to the same optimum, so this
    // mid-training tracking is the meaningful fidelity metric.
    for seed in 0..3 {
        let mut c_ref = cfg(Algo::Ssgd, 8, seed);
        c_ref.eta_single = 0.08;
        let mut c_dc = cfg(Algo::DcS3gd, 8, seed);
        c_dc.eta_single = 0.08;
        let ssgd = loss_trajectory(&run_experiment(&c_ref).unwrap());
        let dc = loss_trajectory(&run_experiment(&c_dc).unwrap());
        let range = ssgd[0] - ssgd[ssgd.len() - 1];
        assert!(range > 0.0, "seed {seed}: SSGD did not learn");
        let gap: f64 = ssgd
            .iter()
            .zip(&dc)
            .map(|(a, b)| (a - b).abs())
            .sum::<f64>()
            / ssgd.len() as f64;
        assert!(
            gap < 0.10 * range,
            "seed {seed}: trajectory gap {gap:.4} vs range {range:.4}"
        );
    }
}

#[test]
fn larger_global_batch_degrades_late_accuracy() {
    // Table I trend: at fixed steps, much larger global batch (same
    // corpus) converges less per-sample-epoch — 128-node rows lose
    // accuracy. Compare global batch 32 vs 512 at equal *steps*.
    let small = run_experiment(&cfg(Algo::DcS3gd, 2, 1)).unwrap();
    let mut big_cfg = cfg(Algo::DcS3gd, 32, 1);
    big_cfg.local_batch = 16; // global 512 vs 32
    let big = run_experiment(&big_cfg).unwrap();
    // big-batch should NOT be better on val error at equal steps with
    // LR scaled by Eq. 16 (it sees 16× the data but the large-batch
    // regime loses generalization per the paper's 128k row).
    assert!(
        big.final_val_err >= small.final_val_err - 0.08,
        "unexpected: big batch {} much better than small {}",
        big.final_val_err,
        small.final_val_err
    );
}

#[test]
fn deterministic_across_identical_runs() {
    let a = run_experiment(&cfg(Algo::DcS3gd, 4, 3)).unwrap();
    let b = run_experiment(&cfg(Algo::DcS3gd, 4, 3)).unwrap();
    assert_eq!(a.final_train_loss, b.final_train_loss);
    assert_eq!(a.final_val_err, b.final_val_err);
    assert_eq!(a.mean_dist_to_avg, b.mean_dist_to_avg);
}

#[test]
fn checkpoint_roundtrip_through_run() {
    use dcs3gd::model::Checkpoint;
    let report = run_experiment(&cfg(Algo::DcS3gd, 2, 5)).unwrap();
    let ck = Checkpoint {
        iteration: report.steps,
        weights: vec![1.0; 8],
        velocity: vec![0.5; 8],
    };
    let p = std::env::temp_dir().join(format!("dcs3gd_conv_ckpt_{}.bin", std::process::id()));
    ck.save(&p).unwrap();
    assert_eq!(Checkpoint::load(&p).unwrap(), ck);
    std::fs::remove_file(&p).unwrap();
}
