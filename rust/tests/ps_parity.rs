//! Differential battery for the parameter-server tier: the golden
//! compressed-PS-under-churn trajectory, the replicated ≡ single-home
//! bitwise contract on quiescent traffic, and Eq. 6-over-decompressed
//! exactness against an independently hand-rolled dense mirror.
//!
//! Everything here pins *arithmetic*: replication, sharding and
//! compression are allowed to move virtual time, never the weight
//! trajectory (given the same request order). Engine-level runs with
//! concurrent workers are covered by `src/algo/psasync.rs`'s own tests
//! — the ASGD family's arrival-order dependence is the phenomenon
//! under study there, so the bitwise pins below all drive the tier
//! with a sequential (quiescent) request stream.

use dcs3gd::algo::{run_experiment, Algo, RunReport};
use dcs3gd::comm::{AllReduceAlgo, Dragonfly, NetModel};
use dcs3gd::compress::{CompressConfig, CompressorKind, WindowCodec};
use dcs3gd::config::ExperimentConfig;
use dcs3gd::control::FaultPlan;
use dcs3gd::optim::MomentumSgd;
use dcs3gd::ps::{PsMode, PsTier, PsTierSpec, ReplicaPlan};
use dcs3gd::simtime::ComputeModel;
use dcs3gd::util::Json;

/// The golden fixture describing the compressed-PS-under-churn
/// scenario *and* its expected trajectory — the config is built from
/// it, the realized run is compared against it.
fn fixture() -> Json {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/ps_topk_churn.json");
    Json::parse(&std::fs::read_to_string(&path).expect("golden fixture exists"))
        .expect("golden fixture parses")
}

fn ranks_of(j: &Json) -> Vec<usize> {
    j.as_arr().unwrap().iter().map(|v| v.as_usize().unwrap()).collect()
}

fn cfg_from_fixture(fix: &Json) -> ExperimentConfig {
    let get_f = |k: &str| fix.get(k).unwrap().as_f64().unwrap();
    let get_u = |k: &str| fix.get(k).unwrap().as_usize().unwrap();
    let initial = get_u("initial_world");
    let d = Dragonfly { groups: 2, nodes_per_group: 2, ..Default::default() };
    let mut cfg = ExperimentConfig::builder("linear")
        .name("ps_golden")
        .algo(Algo::parse(fix.get("algo").unwrap().as_str().unwrap()).unwrap())
        .nodes(initial)
        .local_batch(16)
        .steps(60)
        .eta_single(0.02)
        .base_batch(16)
        .data(1024, 256, 0.5)
        .compute(ComputeModel::uniform(1e-3))
        .net(NetModel {
            alpha_s: 1.5e-6,
            beta_bytes_per_s: 10e9,
            algo: AllReduceAlgo::Hierarchical(d),
        })
        .compress_topk(get_f("topk_ratio") as f32)
        .ps_shards(get_u("shards"))
        .ps_replicas(get_u("replicas"))
        .ps_lambda(fix.get("lambda").unwrap().as_str().unwrap())
        .faults(FaultPlan::new().depart(get_u("depart_rank"), get_f("depart_at_s")))
        .join(get_u("join_rank"), get_f("join_at_s"))
        .join_warmup(4)
        .build();
    cfg.control.restore_s = 0.005;
    cfg
}

fn run_golden() -> (Json, RunReport) {
    let fix = fixture();
    let cfg = cfg_from_fixture(&fix);
    let report = run_experiment(&cfg).expect("compressed elastic PS run completes");
    (fix, report)
}

#[test]
fn golden_compressed_ps_churn_trajectory() {
    let (fix, report) = run_golden();

    // World trajectory matches the fixture: 4 -> 3 -> 4.
    let want_worlds = ranks_of(fix.get("worlds").unwrap());
    assert_eq!(report.epochs.worlds(), want_worlds, "epoch world trajectory diverged");

    // Each transition's member movement matches. The PS epoch records
    // are leader-only (slot 0) — the weights are arrival-order state,
    // so there is no cross-rank CRC contract to assert here (that pin
    // belongs to the decentralized engines).
    let transitions = report.epochs.transitions();
    let want = fix.get("transitions").unwrap().as_arr().unwrap();
    assert_eq!(transitions.len(), want.len() + 1, "epoch 0 + one record per transition");
    for (got, w) in transitions[1..].iter().zip(want) {
        assert_eq!(got.epoch, w.get("epoch").unwrap().as_f64().unwrap() as u64);
        assert_eq!(got.world, w.get("world").unwrap().as_usize().unwrap());
        assert_eq!(got.departed, ranks_of(w.get("departed").unwrap()));
        assert_eq!(got.joined, ranks_of(w.get("joined").unwrap()));
    }

    // The leaver logged its own departure; the joiner really stepped.
    assert!(
        report
            .control
            .events()
            .iter()
            .any(|e| e.event.as_deref().is_some_and(|s| s.starts_with("depart@"))),
        "departure not logged"
    );
    let joiner = fix.get("join_rank").unwrap().as_usize().unwrap();
    assert!(
        report.recorder.steps().iter().any(|s| s.worker == joiner),
        "joiner never stepped"
    );

    // The run JSON's "ps" block carries the tier shape and the
    // compressed wire accounting promised by the fixture.
    let want_ps = fix.get("ps").unwrap();
    let ps = report.ps.as_ref().expect("PS run exports the ps block");
    assert_eq!(ps.get("enabled"), Some(&Json::Bool(true)));
    assert_eq!(
        ps.get("shards").and_then(Json::as_f64),
        fix.get("shards").unwrap().as_f64()
    );
    assert_eq!(
        ps.get("replicas").and_then(Json::as_f64),
        fix.get("replicas").unwrap().as_f64()
    );
    assert_eq!(ps.get("compress"), want_ps.get("compress"));
    assert_eq!(ps.get("epochs"), want_ps.get("epochs"));
    let cut = ps.get("wire_cut_x").and_then(Json::as_f64).unwrap();
    let min_cut = want_ps.get("min_wire_cut_x").unwrap().as_f64().unwrap();
    assert!(cut >= min_cut, "wire cut {cut} under the fixture's {min_cut}x floor");

    // And the run still trains through both transitions.
    assert!(report.final_train_loss.is_finite());
    assert!(report.final_val_err < 0.85, "val err {}", report.final_val_err);
}

// ---------------------------------------------------------------------
// Replicated ≡ single-home on quiescent traffic
// ---------------------------------------------------------------------

/// Drive one tier deployment with a fixed sequential request stream
/// spanning a membership boundary (roster 0,1,2,3 → 0,2,3 at t = 0.5)
/// and return every reply's weights plus the final weights.
fn quiescent_stream(replicas: usize, compress: CompressConfig) -> Vec<Vec<f32>> {
    let n = 256;
    let d = Dragonfly { groups: 2, nodes_per_group: 2, ..Default::default() };
    let net = NetModel { algo: AllReduceAlgo::Hierarchical(d), ..NetModel::default() };
    let boundaries = vec![0.5];
    let rosters = vec![vec![0, 1, 2, 3], vec![0, 2, 3]];
    let plan = ReplicaPlan::place(replicas, &net, 4, true, boundaries, rosters);
    let init: Vec<f32> = (0..n).map(|i| 0.01 * (i as f32) - 1.0).collect();
    let spec = PsTierSpec {
        n_shards: 2,
        mode: PsMode::DcAsgdAdaptive { lam0: 0.2 },
        net,
        serve_s_per_elem: 1e-8,
        compress,
        seed: 11,
        capacity: 4,
        plan,
    };
    let tier = PsTier::spawn(&init, spec, &mut |lo, hi| {
        Box::new(MomentumSgd::new(hi - lo, 0.9))
    });
    let mut clients: Vec<_> = (0..4).map(|r| tier.client(r)).collect();
    for (slot, c) in clients.iter_mut().enumerate() {
        c.rebind(slot, 4);
    }
    let mut replies = Vec::new();
    // Epoch 0: three rounds over the full roster, strictly sequential.
    for it in 0..3 {
        for w in 0..4usize {
            let t = 0.01 * (it * 4 + w) as f64;
            let g: Vec<f32> =
                (0..n).map(|i| 0.005 * ((i + w) as f32) + 0.001 * (it + 1) as f32).collect();
            replies.push(clients[w].push_pull(w, &g, t, 0.05, 0.0).weights);
        }
    }
    // Epoch 1: rank 1 is gone; survivors rebind to their new slots and
    // keep pushing past the boundary (primary rotates in the
    // replicated deployment — weights must not notice).
    for (slot, &w) in [0usize, 2, 3].iter().enumerate() {
        clients[w].rebind(slot, 3);
    }
    for it in 0..3 {
        for (j, &w) in [0usize, 2, 3].iter().enumerate() {
            let t = 1.0 + 0.01 * (it * 3 + j) as f64;
            let g: Vec<f32> =
                (0..n).map(|i| 0.004 * ((i + w) as f32) + 0.002 * (it + 1) as f32).collect();
            replies.push(clients[w].push_pull(w, &g, t, 0.05, 0.0).weights);
        }
    }
    // A read-only refresh rides the same contract.
    replies.push(clients[2].pull(2, 2.0).weights);
    drop(clients);
    let (w_final, _, _) = tier.shutdown();
    replies.push(w_final);
    replies
}

#[test]
fn replicated_tier_bitwise_equals_single_home_on_quiescent_traffic() {
    // Replication is service/placement state: under an identical
    // (sequential) request order, every reply and the final weights
    // are bit-identical whether the shards run 1 replica or 3 —
    // compressed or dense.
    for compress in [
        CompressConfig::default(),
        CompressConfig { kind: CompressorKind::TopK, ratio: 0.1, ..Default::default() },
        CompressConfig { kind: CompressorKind::Qsgd, bits: 4, ..Default::default() },
    ] {
        let single = quiescent_stream(1, compress);
        let replicated = quiescent_stream(3, compress);
        assert_eq!(single.len(), replicated.len());
        for (i, (a, b)) in single.iter().zip(&replicated).enumerate() {
            for (j, (x, y)) in a.iter().zip(b).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{} reply {i} elem {j}: replicated {y} != single-home {x}",
                    compress.kind.name()
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// Eq. 6 over decompressed payloads vs an independent dense mirror
// ---------------------------------------------------------------------

#[test]
fn adaptive_correction_applies_eq6_over_decompressed_payload_exactly() {
    // An independent mirror of the whole tier: its own copy of each
    // worker's codec (same seed/rank ⇒ same sparsity draws and
    // error-feedback residuals) plus a hand-rolled dense DC-ASGD
    // adaptive-λ server (EWMA of the *decoded* gradient, Eq. 6
    // correction, momentum-free SGD). The tier — 2 shards, top-k 0.1 —
    // must reproduce it bitwise at every step: compression happens on
    // the wire, compensation on the decompressed payload, and sharding
    // never perturbs the elementwise rule.
    const BETA: f32 = 0.95; // the server's EWMA decay (ps/mod.rs)
    const EPS: f32 = 1e-7; // and its numerical floor
    let n = 500;
    let lam0 = 0.3f32;
    let eta = 0.1f32;
    let compress = CompressConfig { kind: CompressorKind::TopK, ratio: 0.1, ..Default::default() };
    let init: Vec<f32> = (0..n).map(|i| 0.5 - 0.001 * i as f32).collect();
    let spec = PsTierSpec {
        n_shards: 2,
        mode: PsMode::DcAsgdAdaptive { lam0 },
        net: NetModel::instant(),
        serve_s_per_elem: 0.0,
        compress,
        seed: 7,
        capacity: 2,
        plan: ReplicaPlan::single_home(2),
    };
    let tier = PsTier::spawn(&init, spec, &mut |lo, hi| {
        Box::new(MomentumSgd::new(hi - lo, 0.0))
    });
    let mut clients: Vec<_> = (0..2).map(|r| tier.client(r)).collect();
    for (slot, c) in clients.iter_mut().enumerate() {
        c.rebind(slot, 2);
    }

    // The mirror: codecs keyed exactly like the tier's clients, plus
    // dense per-worker DC-ASGD state.
    let mut mirrors: Vec<WindowCodec> = (0..2)
        .map(|r| {
            let mut c = WindowCodec::new(&compress, n, 7, r);
            c.rebind(r, 2);
            c
        })
        .collect();
    let mut w_mirror = init;
    let mut bak = vec![w_mirror.clone(), w_mirror.clone()];
    let mut mse = vec![vec![0.0f32; n]; 2];
    let mut pushes = [0u64; 2];
    let mut own = vec![0.0f32; n];
    let mut decoded = vec![0.0f32; n];

    for it in 0..20 {
        for u in 0..2usize {
            let g: Vec<f32> = (0..n)
                .map(|i| 0.01 * ((i % 11) as f32) + 0.002 * (it + u + 1) as f32)
                .collect();
            let r = clients[u].push_pull(u, &g, it as f64, eta, 0.0);

            // Mirror: decode through the worker's codec replica, then
            // the server's exact elementwise recurrence.
            let payload = mirrors[u].encode(&g, 0.0, 0.0, &mut own);
            decoded.fill(0.0);
            mirrors[u].decode(&payload, 1, &mut decoded);
            pushes[u] += 1;
            let bias = 1.0 - BETA.powi(pushes[u] as i32);
            for i in 0..n {
                let gi = decoded[i];
                mse[u][i] = BETA * mse[u][i] + (1.0 - BETA) * gi * gi;
                let mse_hat = mse[u][i] / bias;
                let lam = lam0 / (mse_hat + EPS).sqrt();
                let gt = gi + lam * gi * gi * (w_mirror[i] - bak[u][i]);
                w_mirror[i] -= eta * gt;
            }
            bak[u].copy_from_slice(&w_mirror);

            assert_eq!(
                r.weights, w_mirror,
                "tier diverged from the dense mirror at iter {it}, worker {u}"
            );
        }
    }
    drop(clients);
    let (w_final, updates, _) = tier.shutdown();
    assert_eq!(w_final, w_mirror);
    assert_eq!(updates, 2 * 2 * 20, "2 shards x 2 workers x 20 pushes");
}
