//! Integration tests for the elastic control plane: fault-tolerant
//! recovery (kill → heartbeat detect → snapshot restore → converge) and
//! straggler mitigation (adaptive k beating fixed k on virtual
//! wall-clock at near-equal loss).

use dcs3gd::algo::{run_experiment, Algo};
use dcs3gd::comm::{AllReduceAlgo, NetModel};
use dcs3gd::config::ExperimentConfig;
use dcs3gd::control::{ControlPolicy, FaultPlan};
use dcs3gd::simtime::ComputeModel;

fn base_cfg(name: &str) -> ExperimentConfig {
    ExperimentConfig::builder("linear")
        .name(name)
        .algo(Algo::DcS3gd)
        .nodes(4)
        .local_batch(16)
        .steps(150)
        .eta_single(0.04)
        .base_batch(16)
        .data(2048, 512, 0.5)
        .compute(ComputeModel::uniform(1e-3))
        .net(NetModel::default())
        .build()
}

#[test]
fn mid_run_kill_recovers_from_checkpoint_and_converges() {
    // Kill worker 2 at t = 0.5s (≈ step 31 of 150). By then the leader
    // has refreshed the snapshot several times (every 5 windows), so
    // recovery must come from a checkpoint, not a cold restart, and the
    // run must still converge.
    let mut cfg = base_cfg("kill_recovery");
    cfg.control.faults = FaultPlan::new().kill(2, 0.5);
    cfg.control.snapshot_every = 5;
    cfg.control.heartbeat_timeout_s = 0.3;
    cfg.control.restore_s = 0.1;
    let report = run_experiment(&cfg).unwrap();

    let events = report.control.events();
    assert_eq!(events.len(), 1, "expected exactly one recovery event, got {events:?}");
    let ev = &events[0];
    assert_eq!(ev.worker, 2);
    let desc = ev.event.as_deref().unwrap();
    assert!(desc.contains("kill@0.5"), "event description {desc:?}");
    assert!(
        desc.contains("restored_from=snapshot@"),
        "recovery did not come from a checkpoint: {desc:?}"
    );
    // Downtime accounting: detection (heartbeat timeout from the last
    // *pre-crash* beat) + restore must appear on the recovered worker's
    // clock. The (rank, epoch) heartbeat dedupe means the dead rank's
    // post-crash step no longer beats the board, so detection lands
    // strictly before crash + timeout (it used to double-count that
    // beat and land at or beyond it).
    assert!(ev.sim_time >= 0.5 + cfg.control.restore_s - 1e-9, "recovery earlier than restore");
    assert!(
        ev.sim_time < 0.5 + cfg.control.heartbeat_timeout_s + cfg.control.restore_s,
        "post-crash heartbeat double-counted into detection: recovered at {}",
        ev.sim_time
    );

    // ...and the run still learns (chance err for 10 classes is 0.9).
    assert!(
        report.final_val_err < 0.75,
        "no convergence after recovery: val err {}",
        report.final_val_err
    );
    assert!(report.final_train_loss.is_finite());
}

#[test]
fn kill_before_any_snapshot_cold_restarts_and_survives() {
    let mut cfg = base_cfg("kill_cold");
    cfg.control.faults = FaultPlan::new().kill(1, 0.02); // ≈ step 1
    cfg.control.snapshot_every = 1000; // never refreshed in 150 steps
    cfg.control.heartbeat_timeout_s = 0.1;
    cfg.control.restore_s = 0.05;
    let report = run_experiment(&cfg).unwrap();
    let events = report.control.events();
    assert_eq!(events.len(), 1);
    assert!(
        events[0].event.as_deref().unwrap().contains("restored_from=init"),
        "expected cold restart: {:?}",
        events[0].event
    );
    assert!(report.final_val_err < 0.75, "val err {}", report.final_val_err);
}

#[test]
fn faulty_runs_are_deterministic() {
    let mk = || {
        let mut cfg = base_cfg("kill_det");
        cfg.control.faults =
            FaultPlan::new().kill(2, 0.5).slow(1, 0.2, 2.0, 0.3).delay(3, 0.4, 0.05);
        cfg.control.snapshot_every = 5;
        cfg
    };
    let a = run_experiment(&mk()).unwrap();
    let b = run_experiment(&mk()).unwrap();
    assert_eq!(a.final_train_loss, b.final_train_loss);
    assert_eq!(a.final_val_err, b.final_val_err);
    assert_eq!(a.sim_time_s, b.sim_time_s);
    assert_eq!(a.control.records().len(), b.control.records().len());
}

#[test]
fn adaptive_k_mitigates_straggler_at_equal_loss() {
    // The acceptance scenario at test scale: 2× straggler + slow
    // network; dss_pid must cut virtual wall-clock ≥10% below fixed-k
    // at near-equal final loss.
    let mk = |name: &str, policy: ControlPolicy| {
        let mut cfg = base_cfg(name);
        cfg.nodes = 8;
        cfg.steps = 120;
        cfg.compute = ComputeModel::uniform(2e-4).with_straggler(3, 2.0, 8);
        cfg.net =
            NetModel { alpha_s: 1.5e-6, beta_bytes_per_s: 1.2e6, algo: AllReduceAlgo::Ring };
        cfg.control.policy = policy;
        cfg.control.k_max = 6;
        cfg
    };
    let fixed = run_experiment(&mk("strag_fixed", ControlPolicy::Fixed)).unwrap();
    let adaptive = run_experiment(&mk("strag_dss", ControlPolicy::DssPid)).unwrap();
    assert!(
        adaptive.sim_time_s <= 0.90 * fixed.sim_time_s,
        "adaptive {:.4}s vs fixed {:.4}s — less than 10% saved",
        adaptive.sim_time_s,
        fixed.sim_time_s
    );
    assert!(
        adaptive.final_train_loss <= fixed.final_train_loss * 1.15,
        "adaptive loss {} strayed from fixed {}",
        adaptive.final_train_loss,
        fixed.final_train_loss
    );
    // the mitigation must be visible in the decision trace
    assert!(adaptive.control.k_changes() > 0);
    assert!(adaptive.control.records().iter().any(|r| r.k > 1));
}

#[test]
fn ssgd_logs_straggler_blocked_time() {
    // SSGD wires the control plane in observation mode: the per-step
    // blocked time (straggler signal) must show up in the trace.
    let mut cfg = base_cfg("ssgd_obs");
    cfg.algo = Algo::Ssgd;
    cfg.steps = 30;
    cfg.compute = ComputeModel::uniform(1e-3).with_straggler(1, 3.0, 4);
    cfg.net = NetModel::instant();
    let report = run_experiment(&cfg).unwrap();
    let recs = report.control.records();
    assert_eq!(recs.len(), 30, "one record per iteration");
    // rank 0 computes 16 ms/step but waits for the 48 ms straggler:
    // blocked ≈ 32 ms on (nearly) every step after the first.
    let blocked: Vec<f64> = recs.iter().skip(1).map(|r| r.blocked_s).collect();
    assert!(
        blocked.iter().filter(|&&b| b > 0.01).count() >= blocked.len() / 2,
        "straggler wait not captured: {blocked:?}"
    );
}

#[test]
fn control_toml_drives_an_elastic_run() {
    // End-to-end: a TOML [control] table steers a real run.
    let doc = r#"
        name = "toml_elastic"
        variant = "linear"
        algo = "dc_s3gd"
        nodes = 4
        local_batch = 16
        steps = 40

        [optim]
        eta_single = 0.05
        base_batch = 16

        [data]
        n_train = 1024
        n_val = 256

        [control]
        policy = "dss_pid"
        k_min = 1
        k_max = 4
    "#;
    let mut cfg = ExperimentConfig::from_toml_str(doc).unwrap();
    cfg.compute = ComputeModel::uniform(1e-5);
    cfg.net = NetModel { alpha_s: 0.0, beta_bytes_per_s: 1e6, algo: AllReduceAlgo::Ring };
    let report = run_experiment(&cfg).unwrap();
    assert!(report.control.records().iter().map(|r| r.k).max().unwrap() > 1);
    assert!(report.final_train_loss.is_finite());
}
