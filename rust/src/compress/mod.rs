//! Gradient compression: error-feedback top-k / quantized collectives.
//!
//! DC-S3GD hides t_AR behind t_C by overlapping the all-reduce with the
//! next window's compute (Eq. 14); compression attacks the same
//! bottleneck from the other side by shrinking the payload itself, and
//! the two compose — the λ-correction (Eq. 10/17) is applied to the
//! *decompressed* aggregate, so delay compensation repairs the residual
//! staleness error exactly as it repairs the overlap error (cf.
//! *Asynchronous SGD with Delay Compensation*, Zheng et al.).
//!
//! Three compressors behind the [`GradCompressor`] trait, each carrying
//! a per-rank **error-feedback residual**: the compression error of
//! window j is folded back into window j+1's gradient before
//! compressing, so the dropped mass telescopes instead of vanishing
//! (Stich et al., *Sparsified SGD with Memory*):
//!
//! * [`TopK`] — keep the k = ⌈ratio·n⌉ largest-magnitude coordinates;
//!   the wire payload is a sparse `[indices…, values…]` segment
//!   exchanged with an **all-gather** round (each rank posts O(k), the
//!   aggregate is rebuilt by scatter-add in rank order, bit-identically
//!   on every rank).
//! * [`Qsgd`] — stochastic quantization to `bits`-bit levels (sign +
//!   2^(bits−1)−1 magnitude levels against the max-norm). Quantized
//!   values are exact f32s, so the payload still rides the dense
//!   **all-reduce** — only the *priced* wire volume shrinks to
//!   bits/32 of dense.
//! * `None` — the identity pass-through: bit-for-bit the uncompressed
//!   engine path (payload, timing, and arithmetic all unchanged).
//!
//! [`WindowCodec`] is the engine-facing wrapper: it owns the wire
//! format, appends the control plane's piggyback tail (the cross-rank
//! t_C/t_AR observation slots that used to be assembled inline in
//! `algo::dcs3gd`), and decodes the completed round back into the dense
//! aggregate plus a [`CtrlObs`] — identical on every rank, so the
//! deterministic controllers keep their lock-step contract.
//!
//! ## Residuals across membership epochs
//!
//! At every membership-epoch boundary the survivors adopt the resync
//! mean and joiners restore the published bootstrap; a residual carried
//! across that boundary would re-inject error measured against weights
//! that no longer exist. [`WindowCodec::rebind`] therefore **zeroes the
//! residual** at every transition (and joiners start zeroed), the same
//! rule the engines apply to momentum — the pending error of the old
//! epoch is dropped, and the bit-identity invariant at epoch boundaries
//! is untouched by compression.

pub mod qsgd;
pub mod topk;

pub use qsgd::Qsgd;
pub use topk::{topk_k, TopK};

use anyhow::{bail, Result};

/// Fixed control-plane elements on each posted window: `[mean per-step
/// t_C of the window, last observed t_AR]`. On the dense path they are
/// summed into cross-rank means by the all-reduce; on the sparse path
/// every rank's pair arrives verbatim in its gathered segment.
pub const CTRL_BASE_SLOTS: usize = 2;

/// Total dense-path piggyback width: the two mean slots plus one
/// slot-offset element per member carrying that member's own t_C
/// (everyone else contributes zero there, so the sum *is* the
/// per-member value).
pub fn ctrl_slots(world: usize) -> usize {
    CTRL_BASE_SLOTS + world
}

/// How a compressed window travels through the rendezvous substrate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundMode {
    /// Dense payload, summed elementwise by the substrate; the *priced*
    /// wire volume may be smaller than the payload (quantization).
    DenseReduce,
    /// Per-rank sparse segment, concatenated by an all-gather round;
    /// the codec rebuilds the dense aggregate by scatter-add.
    SparseGather,
}

/// Which compressor a run uses (the `[compress]` config enum).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CompressorKind {
    /// Identity: the uncompressed engine path, bit-for-bit.
    #[default]
    None,
    /// Error-feedback top-k sparsification (sparse all-gather payload).
    TopK,
    /// Error-feedback stochastic quantization (dense reduce payload).
    Qsgd,
}

impl CompressorKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "none" | "off" | "dense" => CompressorKind::None,
            "topk" | "top-k" | "top_k" => CompressorKind::TopK,
            "qsgd" | "quant" | "quantized" => CompressorKind::Qsgd,
            other => bail!("unknown compressor {other:?} (none | topk | qsgd)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            CompressorKind::None => "none",
            CompressorKind::TopK => "topk",
            CompressorKind::Qsgd => "qsgd",
        }
    }
}

/// The `[compress]` table of an experiment config.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CompressConfig {
    pub kind: CompressorKind,
    /// Top-k density: fraction of coordinates kept per window.
    pub ratio: f32,
    /// QSGD bits per element (sign + 2^(bits−1)−1 magnitude levels),
    /// in 2..=16 — the f32 level arithmetic holds the one-level-step
    /// error bound only up to 15-bit magnitudes.
    pub bits: u32,
    /// Bounds the `compress_coupled` policy moves the ratio within.
    pub ratio_min: f32,
    pub ratio_max: f32,
}

impl Default for CompressConfig {
    fn default() -> Self {
        CompressConfig {
            kind: CompressorKind::None,
            ratio: 0.05,
            bits: 8,
            ratio_min: 0.005,
            ratio_max: 0.25,
        }
    }
}

impl CompressConfig {
    pub fn validate(&self) -> Result<()> {
        if !(self.ratio > 0.0 && self.ratio <= 1.0) {
            bail!("compress.ratio must be in (0, 1], got {}", self.ratio);
        }
        if !(2..=16).contains(&self.bits) {
            bail!("compress.bits must be in 2..=16, got {}", self.bits);
        }
        if !(self.ratio_min > 0.0 && self.ratio_min <= self.ratio_max && self.ratio_max <= 1.0) {
            bail!(
                "compress ratio bounds must be 0 < ratio_min <= ratio_max <= 1, got [{}, {}]",
                self.ratio_min,
                self.ratio_max
            );
        }
        Ok(())
    }

    /// Fresh compressor for one rank over an `n`-element gradient.
    pub fn build(&self, n: usize, seed: u64, rank: usize) -> Box<dyn GradCompressor> {
        match self.kind {
            CompressorKind::None => Box::new(Identity::new(n)),
            CompressorKind::TopK => Box::new(TopK::new(n, self.ratio)),
            CompressorKind::Qsgd => Box::new(Qsgd::new(n, self.bits, seed, rank as u64)),
        }
    }

    /// Per-rank wire payload in f32-equivalent elements (excluding the
    /// control tail) at the configured operating point — the modelled
    /// volume the benches and the `compress_coupled` pricing use.
    pub fn wire_elems(&self, n: usize) -> usize {
        match self.kind {
            CompressorKind::None => n,
            CompressorKind::TopK => 2 * topk_k(n, self.ratio),
            CompressorKind::Qsgd => qsgd::qsgd_wire_elems(n, self.bits),
        }
    }
}

/// A gradient compressor with an error-feedback residual. One instance
/// per rank; the residual buffer is rank-local state, never exchanged.
pub trait GradCompressor: Send {
    fn name(&self) -> &'static str;

    /// How this compressor's payload travels (and is priced).
    fn mode(&self) -> RoundMode;

    /// Fold the residual into `delta`, compress, and update the
    /// residual with this window's compression error. Writes the
    /// **decompressed own contribution** (exactly what the decoded
    /// aggregate will contain for this rank) into `own_out` and returns
    /// the wire payload: the dense (possibly quantized) vector for
    /// [`RoundMode::DenseReduce`], `[indices…, values…]` for
    /// [`RoundMode::SparseGather`]. `tail_room` is extra capacity to
    /// reserve past the payload (the codec appends the control tail in
    /// place — the wire buffer must never reallocate for it).
    fn compress(&mut self, delta: &[f32], own_out: &mut [f32], tail_room: usize) -> Vec<f32>;

    /// Scatter one contributor's wire segment into the dense sum
    /// (sparse mode only; dense payloads are summed by the substrate).
    fn accumulate(&self, segment: &[f32], dense_sum: &mut [f32]);

    /// Per-rank wire volume in f32-equivalent elements at the current
    /// operating point (pricing only; excludes the control tail).
    fn wire_elems(&self) -> usize;

    /// The compression knob as a wire fraction: top-k density, bits/32
    /// for QSGD, 1.0 for the identity.
    fn ratio(&self) -> f32 {
        1.0
    }

    /// Retune the operating point (the `compress_coupled` hook); no-op
    /// where the knob does not apply.
    fn set_ratio(&mut self, _ratio: f32) {}

    /// Zero the residual (membership-epoch boundary, crash recovery,
    /// joiner bootstrap).
    fn reset(&mut self);

    /// The current residual (tests / diagnostics).
    fn residual(&self) -> &[f32];
}

/// The identity compressor: dense pass-through, no residual.
#[derive(Debug)]
pub struct Identity {
    n: usize,
    /// Kept empty-but-typed so `residual()` has something to hand back.
    empty: Vec<f32>,
}

impl Identity {
    pub fn new(n: usize) -> Self {
        Identity { n, empty: Vec::new() }
    }
}

impl GradCompressor for Identity {
    fn name(&self) -> &'static str {
        "none"
    }

    fn mode(&self) -> RoundMode {
        RoundMode::DenseReduce
    }

    fn compress(&mut self, delta: &[f32], own_out: &mut [f32], tail_room: usize) -> Vec<f32> {
        assert_eq!(delta.len(), self.n);
        own_out.copy_from_slice(delta);
        let mut wire = Vec::with_capacity(self.n + tail_room);
        wire.extend_from_slice(delta);
        wire
    }

    fn accumulate(&self, _segment: &[f32], _dense_sum: &mut [f32]) {
        unreachable!("dense payloads are summed by the substrate");
    }

    fn wire_elems(&self) -> usize {
        self.n
    }

    fn reset(&mut self) {}

    fn residual(&self) -> &[f32] {
        &self.empty
    }
}

/// The cross-rank observations decoded from a completed round —
/// identical on every rank (means of what every contributor posted),
/// the controllers' determinism anchor.
#[derive(Debug, Clone)]
pub struct CtrlObs {
    /// Cross-rank mean per-step compute time over the window (s).
    pub t_compute: f64,
    /// Cross-rank mean of the last observed collective latency (s).
    pub t_allreduce: f64,
    /// Per-member per-step compute time, in member (slot) order.
    pub per_rank_t_c: Vec<f64>,
}

/// Engine-facing codec: one per worker. Owns the compressor (and its
/// residual), the wire layout, and the control piggyback tail.
pub struct WindowCodec {
    n: usize,
    slot: usize,
    world: usize,
    comp: Box<dyn GradCompressor>,
}

impl WindowCodec {
    /// Build for one rank over an `n`-element gradient. Call
    /// [`WindowCodec::rebind`] before the first window to set the
    /// (slot, world) view.
    pub fn new(cfg: &CompressConfig, n: usize, seed: u64, rank: usize) -> Self {
        WindowCodec { n, slot: 0, world: 1, comp: cfg.build(n, seed, rank) }
    }

    /// Adopt a (slot, world) view — at launch and at every
    /// membership-epoch transition. Zeroes the residual: the error
    /// pending against the old epoch's weights must not leak into the
    /// new epoch (see the module docs).
    pub fn rebind(&mut self, slot: usize, world: usize) {
        self.slot = slot;
        self.world = world.max(1);
        self.comp.reset();
    }

    /// Zero the residual without changing the membership view (crash
    /// recovery restores snapshot weights the residual predates).
    pub fn reset_residual(&mut self) {
        self.comp.reset();
    }

    pub fn mode(&self) -> RoundMode {
        self.comp.mode()
    }

    pub fn name(&self) -> &'static str {
        self.comp.name()
    }

    pub fn ratio(&self) -> f32 {
        self.comp.ratio()
    }

    pub fn set_ratio(&mut self, ratio: f32) {
        self.comp.set_ratio(ratio);
    }

    /// Per-rank wire volume in f32-equivalent elements **including**
    /// the control tail — what the posted round is priced at.
    pub fn wire_elems(&self) -> usize {
        match self.mode() {
            RoundMode::DenseReduce => self.comp.wire_elems() + ctrl_slots(self.world),
            RoundMode::SparseGather => self.comp.wire_elems() + CTRL_BASE_SLOTS,
        }
    }

    /// Per-rank wire volume in bytes (the metrics export).
    pub fn wire_bytes(&self) -> f64 {
        self.wire_elems() as f64 * 4.0
    }

    /// Compress `delta` (folding the residual) and append the control
    /// tail. `own_out` receives the decompressed own contribution — the
    /// engine's Eq. 9 reference for `D_i = Σq/N − q_i`.
    pub fn encode(&mut self, delta: &[f32], t_c: f64, t_ar: f64, own_out: &mut [f32]) -> Vec<f32> {
        let tail_room = match self.mode() {
            RoundMode::DenseReduce => ctrl_slots(self.world),
            RoundMode::SparseGather => CTRL_BASE_SLOTS,
        };
        let mut wire = self.comp.compress(delta, own_out, tail_room);
        wire.push(t_c as f32);
        wire.push(t_ar as f32);
        if self.mode() == RoundMode::DenseReduce {
            for s in 0..self.world {
                wire.push(if s == self.slot { t_c as f32 } else { 0.0 });
            }
        }
        wire
    }

    /// Decode a completed round: rebuild the dense aggregate into
    /// `dense_sum` and return the cross-rank observations. Pure
    /// function of (payload, contributor count) — identical on every
    /// rank by construction.
    pub fn decode(&self, payload: &[f32], n_contrib: usize, dense_sum: &mut [f32]) -> CtrlObs {
        assert!(n_contrib >= 1, "round decoded with no contributors");
        assert_eq!(dense_sum.len(), self.n);
        match self.mode() {
            RoundMode::DenseReduce => {
                let slots = ctrl_slots(self.world);
                assert_eq!(payload.len(), self.n + slots, "dense payload width mismatch");
                dense_sum.copy_from_slice(&payload[..self.n]);
                let tail = &payload[self.n..self.n + slots];
                let inv_n = 1.0 / n_contrib as f64;
                CtrlObs {
                    t_compute: tail[0] as f64 * inv_n,
                    t_allreduce: tail[1] as f64 * inv_n,
                    per_rank_t_c: tail[CTRL_BASE_SLOTS..].iter().map(|x| *x as f64).collect(),
                }
            }
            RoundMode::SparseGather => {
                assert_eq!(payload.len() % n_contrib, 0, "ragged sparse round");
                let seg = payload.len() / n_contrib;
                assert!(seg > CTRL_BASE_SLOTS, "sparse segment too short");
                dense_sum.iter_mut().for_each(|x| *x = 0.0);
                let mut t_c_sum = 0.0f64;
                let mut t_ar_sum = 0.0f64;
                let mut per_rank = Vec::with_capacity(n_contrib);
                for s in payload.chunks_exact(seg) {
                    self.comp.accumulate(&s[..seg - CTRL_BASE_SLOTS], dense_sum);
                    let t_c = s[seg - 2] as f64;
                    t_c_sum += t_c;
                    t_ar_sum += s[seg - 1] as f64;
                    per_rank.push(t_c);
                }
                let inv_n = 1.0 / n_contrib as f64;
                CtrlObs {
                    t_compute: t_c_sum * inv_n,
                    t_allreduce: t_ar_sum * inv_n,
                    per_rank_t_c: per_rank,
                }
            }
        }
    }

    /// The residual (tests / diagnostics).
    pub fn residual(&self) -> &[f32] {
        self.comp.residual()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for k in [CompressorKind::None, CompressorKind::TopK, CompressorKind::Qsgd] {
            assert_eq!(CompressorKind::parse(k.name()).unwrap(), k);
        }
        assert_eq!(CompressorKind::parse("Top-K").unwrap(), CompressorKind::TopK);
        assert!(CompressorKind::parse("zip").is_err());
    }

    #[test]
    fn config_validation() {
        CompressConfig::default().validate().unwrap();
        let mut c = CompressConfig { ratio: 0.0, ..Default::default() };
        assert!(c.validate().is_err());
        c.ratio = 0.1;
        c.bits = 1;
        assert!(c.validate().is_err());
        c.bits = 17; // past the f32-exact level range
        assert!(c.validate().is_err());
        c.bits = 8;
        c.ratio_min = 0.5;
        c.ratio_max = 0.25;
        assert!(c.validate().is_err());
    }

    #[test]
    fn identity_codec_matches_legacy_wire_layout() {
        // kind = none must reproduce the pre-compression payload
        // bit-for-bit: [delta, mean t_C, last t_AR, slot offsets].
        let cfg = CompressConfig::default();
        let mut codec = WindowCodec::new(&cfg, 3, 0, 1);
        codec.rebind(1, 4);
        let delta = [1.0f32, -2.0, 3.0];
        let mut own = [0.0f32; 3];
        let wire = codec.encode(&delta, 0.25, 0.5, &mut own);
        assert_eq!(own, delta);
        assert_eq!(
            wire,
            vec![1.0, -2.0, 3.0, 0.25, 0.5, 0.0, 0.25, 0.0, 0.0],
            "identity wire layout drifted from the legacy piggyback"
        );
        assert_eq!(codec.wire_elems(), 3 + ctrl_slots(4));
    }

    #[test]
    fn identity_decode_matches_legacy_observation_math() {
        let cfg = CompressConfig::default();
        let mut codec = WindowCodec::new(&cfg, 2, 0, 0);
        codec.rebind(0, 2);
        // simulated all-reduced payload from 2 ranks
        let payload = [3.0f32, 4.0, 0.6, 0.2, 0.1, 0.5];
        let mut sum = [0.0f32; 2];
        let obs = codec.decode(&payload, 2, &mut sum);
        assert_eq!(sum, [3.0, 4.0]);
        assert!((obs.t_compute - 0.3).abs() < 1e-6);
        assert!((obs.t_allreduce - 0.1).abs() < 1e-6);
        assert_eq!(obs.per_rank_t_c, vec![0.1f32 as f64, 0.5f32 as f64]);
    }

    #[test]
    fn sparse_decode_rebuilds_sum_and_observations() {
        let cfg = CompressConfig { kind: CompressorKind::TopK, ratio: 0.5, ..Default::default() };
        let mut codec = WindowCodec::new(&cfg, 4, 0, 0);
        codec.rebind(0, 2);
        // two contributor segments, k = 2: [idx, idx, val, val, t_c, t_ar]
        // rank 0 contributes {0: 10, 2: 20}; rank 1 contributes {1: 5, 2: 7}
        let mut payload = vec![0.0f32, 2.0, 10.0, 20.0, 0.1, 1.0];
        payload.extend_from_slice(&[1.0, 2.0, 5.0, 7.0, 0.3, 3.0]);
        let mut sum = [0.0f32; 4];
        let obs = codec.decode(&payload, 2, &mut sum);
        assert_eq!(sum, [10.0, 5.0, 27.0, 0.0]);
        assert!((obs.t_compute - 0.2).abs() < 1e-7);
        assert!((obs.t_allreduce - 2.0).abs() < 1e-7);
        assert_eq!(obs.per_rank_t_c.len(), 2);
    }

    #[test]
    fn rebind_resets_residual() {
        let cfg = CompressConfig { kind: CompressorKind::TopK, ratio: 0.25, ..Default::default() };
        let mut codec = WindowCodec::new(&cfg, 8, 0, 0);
        codec.rebind(0, 2);
        let delta: Vec<f32> = (0..8).map(|i| i as f32 - 3.5).collect();
        let mut own = [0.0f32; 8];
        codec.encode(&delta, 0.0, 0.0, &mut own);
        assert!(codec.residual().iter().any(|&x| x != 0.0), "top-k must leave a residual");
        codec.rebind(0, 3);
        assert!(codec.residual().iter().all(|&x| x == 0.0), "rebind must zero the residual");
    }

    #[test]
    fn configured_wire_elems_match_kinds() {
        let n = 1000;
        let none = CompressConfig::default();
        assert_eq!(none.wire_elems(n), n);
        let topk = CompressConfig { kind: CompressorKind::TopK, ratio: 0.1, ..Default::default() };
        assert_eq!(topk.wire_elems(n), 200);
        let q8 = CompressConfig { kind: CompressorKind::Qsgd, bits: 8, ..Default::default() };
        assert_eq!(q8.wire_elems(n), 251); // ceil(1000·8/32) + scale
    }
}
