//! Error-feedback top-k sparsification.
//!
//! Keep the k = ⌈ratio·n⌉ largest-magnitude coordinates of the
//! residual-corrected window update; everything dropped goes back into
//! the residual, so the error telescopes across windows: with
//! `v_t = g_t + e_{t−1}`, `q_t = C(v_t)`, `e_t = v_t − q_t`, the
//! per-window identity `q_t + e_t = v_t` holds **bitwise** (every
//! coordinate of `q_t` is either `v_t[i]` or 0, and the residual is the
//! complementary mask — no rounding anywhere), which is what the
//! proptests pin.
//!
//! Selection is a pure function of the input: coordinates are ranked by
//! (|v| descending, index ascending) — a total order, so ties resolve
//! identically on every rank and every run. At `ratio = 1.0` the
//! compressor is the identity (all coordinates selected, residual
//! stays zero), and the scatter-add decode reproduces the dense
//! rank-order reduction bit-for-bit.
//!
//! Wire format: `[idx_0 … idx_{k−1}, val_0 … val_{k−1}]` with indices
//! stored as exactly-representable f32s (asserted `n < 2^24`), indices
//! ascending. The payload rides a rendezvous **all-gather** round: each
//! rank injects O(k), and the decode accumulates segments in contributor
//! rank order — the same per-element addition order as the dense
//! reduction, hence bit-identical sums at ratio 1.0.

use super::{GradCompressor, RoundMode};

/// Number of kept coordinates for an `n`-element gradient at `ratio`.
pub fn topk_k(n: usize, ratio: f32) -> usize {
    ((ratio as f64 * n as f64).ceil() as usize).clamp(1, n.max(1))
}

/// Error-feedback top-k compressor (one per rank).
#[derive(Debug)]
pub struct TopK {
    n: usize,
    ratio: f32,
    residual: Vec<f32>,
    /// Scratch: residual-corrected input of the current window.
    v: Vec<f32>,
    /// Scratch: |v| magnitudes, filled by one vectorized pass per
    /// window so the partial select's comparator reads a flat f32
    /// instead of recomputing `abs` on every comparison. Pure
    /// precompute — the comparator's total order is unchanged.
    mag: Vec<f32>,
}

impl TopK {
    pub fn new(n: usize, ratio: f32) -> Self {
        assert!(n < (1 << 24), "top-k indices ride as exact f32s: n must be < 2^24");
        assert!(ratio > 0.0 && ratio <= 1.0, "top-k ratio must be in (0, 1]");
        TopK { n, ratio, residual: vec![0.0; n], v: vec![0.0; n], mag: vec![0.0; n] }
    }

    pub fn k(&self) -> usize {
        topk_k(self.n, self.ratio)
    }

    /// One window: fold the residual, select, and split `v` into the
    /// kept (indices, values) and the new residual. Exposed for the
    /// golden-fixture test; the trait wraps it into the wire format.
    pub fn compress_window(&mut self, delta: &[f32]) -> (Vec<u32>, Vec<f32>) {
        assert_eq!(delta.len(), self.n);
        for ((v, d), e) in self.v.iter_mut().zip(delta).zip(&self.residual) {
            *v = d + e;
        }
        let k = self.k();
        let mut idx: Vec<u32> = (0..self.n as u32).collect();
        if k < self.n {
            for (m, v) in self.mag.iter_mut().zip(&self.v) {
                *m = v.abs();
            }
            let mag = &self.mag;
            // Total order: |v| descending, index ascending — the
            // deterministic selection every rank agrees on.
            let cmp = |&a: &u32, &b: &u32| {
                mag[b as usize].total_cmp(&mag[a as usize]).then(a.cmp(&b))
            };
            idx.select_nth_unstable_by(k - 1, cmp);
            idx.truncate(k);
            idx.sort_unstable();
        }
        let vals: Vec<f32> = idx.iter().map(|&i| self.v[i as usize]).collect();
        // Residual = the complementary mask: exact, no rounding.
        self.residual.copy_from_slice(&self.v);
        for &i in &idx {
            self.residual[i as usize] = 0.0;
        }
        (idx, vals)
    }
}

impl GradCompressor for TopK {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn mode(&self) -> RoundMode {
        RoundMode::SparseGather
    }

    fn compress(&mut self, delta: &[f32], own_out: &mut [f32], tail_room: usize) -> Vec<f32> {
        assert_eq!(own_out.len(), self.n);
        let (idx, vals) = self.compress_window(delta);
        own_out.iter_mut().for_each(|x| *x = 0.0);
        for (j, &i) in idx.iter().enumerate() {
            own_out[i as usize] = vals[j];
        }
        let mut wire = Vec::with_capacity(2 * idx.len() + tail_room);
        wire.extend(idx.iter().map(|&i| i as f32));
        wire.extend_from_slice(&vals);
        wire
    }

    fn accumulate(&self, segment: &[f32], dense_sum: &mut [f32]) {
        assert_eq!(segment.len() % 2, 0, "sparse segment must be [indices…, values…]");
        let k = segment.len() / 2;
        for j in 0..k {
            let i = segment[j] as usize;
            dense_sum[i] += segment[k + j];
        }
    }

    fn wire_elems(&self) -> usize {
        2 * self.k()
    }

    fn ratio(&self) -> f32 {
        self.ratio
    }

    fn set_ratio(&mut self, ratio: f32) {
        self.ratio = ratio.clamp(f32::MIN_POSITIVE, 1.0);
    }

    fn reset(&mut self) {
        self.residual.iter_mut().for_each(|x| *x = 0.0);
    }

    fn residual(&self) -> &[f32] {
        &self.residual
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k_of_ratio() {
        assert_eq!(topk_k(100, 0.1), 10);
        assert_eq!(topk_k(100, 1.0), 100);
        assert_eq!(topk_k(100, 0.001), 1); // never zero
        assert_eq!(topk_k(7, 0.5), 4); // ceil
    }

    #[test]
    fn selects_largest_magnitudes_with_exact_residual() {
        let mut c = TopK::new(6, 0.34); // k = ceil(2.04) = 3
        let delta = [1.0f32, -5.0, 0.5, 4.0, -0.25, 2.0];
        let (idx, vals) = c.compress_window(&delta);
        assert_eq!(idx, vec![1, 3, 5]);
        assert_eq!(vals, vec![-5.0, 4.0, 2.0]);
        assert_eq!(c.residual(), &[1.0, 0.0, 0.5, 0.0, -0.25, 0.0]);
    }

    #[test]
    fn ties_break_to_the_lowest_index() {
        let mut c = TopK::new(4, 0.5); // k = 2
        let (idx, _) = c.compress_window(&[2.0, -2.0, 2.0, -2.0]);
        assert_eq!(idx, vec![0, 1], "equal magnitudes must keep the lowest indices");
    }

    #[test]
    fn error_feedback_folds_into_next_window() {
        let mut c = TopK::new(4, 0.25); // k = 1
        c.compress_window(&[1.0, 3.0, -2.0, 0.5]); // keeps idx 1; e = [1, 0, -2, 0.5]
        // next window: v = delta + e = [2, 0, -4, 1] -> keeps idx 2
        let (idx, vals) = c.compress_window(&[1.0, 0.0, -2.0, 0.5]);
        assert_eq!(idx, vec![2]);
        assert_eq!(vals, vec![-4.0]);
        assert_eq!(c.residual(), &[2.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn ratio_one_is_identity() {
        let mut c = TopK::new(5, 1.0);
        let delta = [0.5f32, -1.5, 0.0, 2.5, -3.5];
        let mut own = [0.0f32; 5];
        let wire = c.compress(&delta, &mut own, 0);
        assert_eq!(own, delta);
        assert!(c.residual().iter().all(|&x| x == 0.0));
        // scatter-add of the full wire reproduces the dense vector
        let mut sum = [0.0f32; 5];
        c.accumulate(&wire, &mut sum);
        assert_eq!(sum, delta);
    }

    #[test]
    fn per_window_identity_is_bitwise() {
        // q + e == v bit-for-bit: selection masks, never rounds.
        let mut c = TopK::new(64, 0.1);
        let mut rng = crate::util::Rng::new(7);
        for _ in 0..5 {
            let mut delta = vec![0.0f32; 64];
            rng.fill_normal(&mut delta);
            let before: Vec<f32> = c.residual().to_vec();
            let mut own = vec![0.0f32; 64];
            c.compress(&delta, &mut own, 0);
            for i in 0..64 {
                let v = delta[i] + before[i];
                let q_plus_e = own[i] + c.residual()[i];
                // bitwise, modulo the sign of zero
                assert!(
                    v.to_bits() == q_plus_e.to_bits() || (v == 0.0 && q_plus_e == 0.0),
                    "elem {i}: {v} vs {q_plus_e}"
                );
            }
        }
    }

    #[test]
    fn set_ratio_moves_k() {
        let mut c = TopK::new(100, 0.1);
        assert_eq!(c.k(), 10);
        c.set_ratio(0.05);
        assert_eq!(c.k(), 5);
        assert_eq!(c.wire_elems(), 10);
    }
}
