//! Error-feedback stochastic quantization (QSGD, Alistarh et al.).
//!
//! Each window the residual-corrected update `v = Δw + e` is quantized
//! against its max-norm: with `s = max|v|` and `L = 2^(bits−1) − 1`
//! magnitude levels, every coordinate becomes
//! `q_i = sign(v_i) · s · l_i / L` where `l_i` rounds `|v_i|/s · L`
//! **stochastically** — up with probability equal to the fractional
//! part — so the quantizer is unbiased (E[q] = v) and the residual
//! `e' = v − q` only has to carry the variance, not a systematic bias.
//!
//! The quantized values are exact f32s, so the payload still rides the
//! dense all-reduce (sums of quantized values are ordinary sums); what
//! shrinks is the **wire volume the round is priced at**: `bits` bits
//! per element plus one f32 scale, i.e. `⌈n·bits/32⌉ + 1`
//! f32-equivalents instead of `n`.
//!
//! Determinism: the rounding draws come from a counter-based RNG keyed
//! `(seed, rank, window)` — a pure function of the run config, so two
//! identical runs quantize identically, and each rank's stream is
//! independent. The *aggregate* stays deterministic because the
//! substrate reduces contributions in rank order, exactly as for dense
//! payloads.
//!
//! `bits` is capped at 16: the level arithmetic runs in f32, where
//! `|v|/s·L` is exact to well under half a level for L ≤ 2¹⁵ − 1;
//! wider levels would let f32 rounding exceed the documented
//! one-level-step error bound (and 16-bit quantization already halves
//! the wire — past that, run dense).

use crate::util::Rng;

use super::{GradCompressor, RoundMode};

/// Priced wire volume of an `n`-element QSGD payload, in f32-equivalent
/// elements: `bits` bits per element plus the f32 scale.
pub fn qsgd_wire_elems(n: usize, bits: u32) -> usize {
    (n * bits as usize).div_ceil(32) + 1
}

/// Error-feedback stochastic quantizer (one per rank).
#[derive(Debug)]
pub struct Qsgd {
    n: usize,
    bits: u32,
    residual: Vec<f32>,
    seed: u64,
    rank: u64,
    window: u64,
    /// Scratch for the chunked two-pass encode: floor levels,
    /// fractional parts, and the per-element rounding draws. The draws
    /// are pulled one-per-element in element order — exactly the
    /// counter stream the scalar encoder consumed — so splitting the
    /// loop moves no bits.
    lvl0: Vec<f32>,
    frac: Vec<f32>,
    draws: Vec<f32>,
}

impl Qsgd {
    pub fn new(n: usize, bits: u32, seed: u64, rank: u64) -> Self {
        assert!((2..=16).contains(&bits), "qsgd bits must be in 2..=16 (f32 level arithmetic)");
        Qsgd {
            n,
            bits,
            residual: vec![0.0; n],
            seed,
            rank,
            window: 0,
            lvl0: vec![0.0; n],
            frac: vec![0.0; n],
            draws: vec![0.0; n],
        }
    }

    /// Magnitude levels: sign bit + (bits−1)-bit magnitude.
    fn levels(&self) -> f32 {
        ((1u64 << (self.bits - 1)) - 1) as f32
    }
}

impl GradCompressor for Qsgd {
    fn name(&self) -> &'static str {
        "qsgd"
    }

    fn mode(&self) -> RoundMode {
        RoundMode::DenseReduce
    }

    fn compress(&mut self, delta: &[f32], own_out: &mut [f32], tail_room: usize) -> Vec<f32> {
        assert_eq!(delta.len(), self.n);
        assert_eq!(own_out.len(), self.n);
        let mut rng = Rng::keyed(self.seed ^ 0xC0DEC, self.rank, self.window);
        self.window += 1;
        let lvl = self.levels();
        let mut s = 0.0f32;
        for i in 0..self.n {
            let v = delta[i] + self.residual[i];
            self.residual[i] = v; // hold v; becomes v − q below
            s = s.max(v.abs());
        }
        let mut q = Vec::with_capacity(self.n + tail_room);
        if s == 0.0 || !s.is_finite() {
            // Nothing to quantize (or a non-finite input the training
            // loop will catch): ship zeros, keep v in the residual.
            own_out.iter_mut().for_each(|x| *x = 0.0);
            q.resize(self.n, 0.0);
            return q;
        }
        // Chunked three-pass encode. Passes 1 and 3 are branch-free
        // zipped subslice walks the autovectorizer handles; pass 2 is
        // the inherently serial RNG drain. Bit-identical to the old
        // scalar loop: same per-element arithmetic, same draw order,
        // and `(u < f) as u32 as f32` is the old branch made data.
        let cw = crate::exec::pin_chunk();
        let mut lo = 0;
        while lo < self.n {
            let hi = (lo + cw).min(self.n);
            let wr = self.lvl0[lo..hi].iter_mut().zip(self.frac[lo..hi].iter_mut());
            for (v, (l0, f)) in self.residual[lo..hi].iter().zip(wr) {
                let p = v.abs() / s * lvl;
                let l = p.floor();
                *l0 = l;
                *f = p - l;
            }
            lo = hi;
        }
        for u in self.draws.iter_mut() {
            *u = rng.uniform() as f32;
        }
        q.resize(self.n, 0.0);
        let mut lo = 0;
        while lo < self.n {
            let hi = (lo + cw).min(self.n);
            let rd = self.lvl0[lo..hi].iter().zip(&self.frac[lo..hi]).zip(&self.draws[lo..hi]);
            let wr = self.residual[lo..hi]
                .iter_mut()
                .zip(own_out[lo..hi].iter_mut())
                .zip(q[lo..hi].iter_mut());
            for (((l0, f), u), ((v, o), qo)) in rd.zip(wr) {
                let bump = ((*u < *f) as u32) as f32;
                let l = l0 + bump;
                let qi = v.signum() * s * (l / lvl);
                *qo = qi;
                *o = qi;
                *v -= qi;
            }
            lo = hi;
        }
        q
    }

    fn accumulate(&self, _segment: &[f32], _dense_sum: &mut [f32]) {
        unreachable!("dense payloads are summed by the substrate");
    }

    fn wire_elems(&self) -> usize {
        qsgd_wire_elems(self.n, self.bits)
    }

    fn ratio(&self) -> f32 {
        self.bits as f32 / 32.0
    }

    /// The `compress_coupled` hook: a ratio is `bits/32`, snapped to the
    /// nearest rung of the 4 ↔ 8 ↔ 16 ladder the policy walks.
    fn set_ratio(&mut self, ratio: f32) {
        let bits = (ratio * 32.0).round().clamp(2.0, 16.0) as u32;
        self.bits = crate::control::snap_qsgd_bits(bits);
    }

    fn reset(&mut self) {
        self.residual.iter_mut().for_each(|x| *x = 0.0);
    }

    fn residual(&self) -> &[f32] {
        &self.residual
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_elems_formula() {
        assert_eq!(qsgd_wire_elems(1000, 8), 251);
        assert_eq!(qsgd_wire_elems(1000, 4), 126);
        assert_eq!(qsgd_wire_elems(1000, 16), 501);
        assert_eq!(qsgd_wire_elems(0, 8), 1);
    }

    #[test]
    fn quantization_error_bounded_by_one_level() {
        let mut c = Qsgd::new(256, 8, 1, 0);
        let mut rng = Rng::new(3);
        let mut delta = vec![0.0f32; 256];
        rng.fill_normal(&mut delta);
        let mut own = vec![0.0f32; 256];
        c.compress(&delta, &mut own, 0);
        let s = delta.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        let step = s / c.levels();
        for i in 0..256 {
            // first window: v == delta (zero residual)
            assert!(
                (own[i] - delta[i]).abs() <= step * 1.0001,
                "elem {i}: |q − v| = {} > level step {step}",
                (own[i] - delta[i]).abs()
            );
            assert!(
                (own[i] + c.residual()[i] - delta[i]).abs() <= 1e-6 * s,
                "q + e must reconstruct v (elem {i})"
            );
        }
    }

    #[test]
    fn deterministic_per_run_distinct_per_rank() {
        let mut delta = vec![0.0f32; 64];
        Rng::new(9).fill_normal(&mut delta);
        let run = |rank: u64| {
            let mut c = Qsgd::new(64, 4, 42, rank);
            let mut own = vec![0.0f32; 64];
            c.compress(&delta, &mut own, 0);
            own
        };
        assert_eq!(run(0), run(0), "same (seed, rank, window) must quantize identically");
        assert_ne!(run(0), run(1), "ranks must draw independent rounding streams");
    }

    #[test]
    fn zero_input_ships_zeros() {
        let mut c = Qsgd::new(8, 8, 0, 0);
        let mut own = [1.0f32; 8];
        let wire = c.compress(&[0.0; 8], &mut own, 0);
        assert!(wire.iter().all(|&x| x == 0.0));
        assert!(own.iter().all(|&x| x == 0.0));
        assert!(c.residual().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn set_ratio_walks_the_bits_ladder() {
        let mut c = Qsgd::new(8, 16, 0, 0);
        c.set_ratio(8.0 / 32.0);
        assert_eq!(c.ratio(), 8.0 / 32.0);
        assert_eq!(c.wire_elems(), qsgd_wire_elems(8, 8));
        c.set_ratio(4.0 / 32.0);
        assert_eq!(c.ratio(), 4.0 / 32.0);
        // off-rung ratios snap to the nearest rung
        c.set_ratio(6.0 / 32.0);
        assert_eq!(c.ratio(), 4.0 / 32.0);
        c.set_ratio(13.0 / 32.0);
        assert_eq!(c.ratio(), 16.0 / 32.0);
    }

    #[test]
    fn residual_feeds_back() {
        // A value below half a level quantizes to 0 but persists in the
        // residual until it accumulates past the rounding threshold (in
        // expectation); with error feedback it cannot be lost.
        let mut c = Qsgd::new(2, 8, 7, 0);
        let mut own = [0.0f32; 2];
        c.compress(&[1.0, 0.001], &mut own, 0);
        let e = c.residual()[1];
        // q[1] + e[1] == 0.001 up to f32 rounding
        assert!((own[1] + e - 0.001).abs() < 1e-7);
    }
}
