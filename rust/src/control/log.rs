//! The control plane's flight recorder: one record per decision point
//! (window boundary, fault, recovery), shared across workers and
//! exported through the metrics layer as JSON. Since PR 2 each record
//! also carries the collective schedule the window ran on and the
//! local/global split of its t_AR — the evidence trail for the
//! schedule-coupled policy's decisions. Since the compression subsystem
//! it also carries the compressor, the active ratio, and the achieved
//! per-rank wire bytes of the round — the (k, schedule, ratio) decision
//! trace the `compress_coupled` policy is judged by, aggregated into
//! the run JSON's `"compress"` key.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::metrics::{CommPhaseSummary, CompressSummary};
use crate::util::Json;

/// One control-plane decision / event.
#[derive(Debug, Clone, PartialEq)]
pub struct ControlRecord {
    pub worker: usize,
    /// Completed-window index (0 for per-step engines like SSGD).
    pub window: u64,
    /// Worker-local iteration at the record point.
    pub iteration: u64,
    pub sim_time: f64,
    /// Window length in force after this decision.
    pub k: usize,
    /// λ0 multiplier in force after this decision.
    pub lam_scale: f32,
    /// Collective schedule the window's all-reduce ran on (None for
    /// records without a collective, e.g. kill/recovery events).
    pub schedule: Option<String>,
    /// Observed mean per-step compute time (s).
    pub t_compute: f64,
    /// Observed collective latency, post → completion (s).
    pub t_allreduce: f64,
    /// Modelled intra-group (local-link) share of the collective (s).
    pub t_ar_local: f64,
    /// Modelled inter-group (global-link) share of the collective (s).
    pub t_ar_global: f64,
    /// Time this worker spent blocked in the wait (s) — the straggler
    /// signal.
    pub blocked_s: f64,
    /// Compressor the window's payload rode (None for records without
    /// a collective, e.g. kill/recovery events).
    pub compress: Option<String>,
    /// Compression knob as a wire fraction in force for the round:
    /// top-k density, bits/32 for QSGD, 1.0 dense.
    pub compress_ratio: f64,
    /// Achieved per-rank wire payload of the round, in bytes (0 for
    /// records without a collective).
    pub wire_bytes: f64,
    /// The window ran its schedule as a control-plane **probe** of a
    /// non-active candidate (a one-window excursion, excluded from the
    /// schedule-switch accounting).
    pub probe: bool,
    /// Fault / recovery / quarantine annotation, if any.
    pub event: Option<String>,
}

impl ControlRecord {
    fn to_json(&self) -> Json {
        // NaN/∞ have no JSON representation → null (keeps the whole
        // metrics file parseable even if an observation went bad).
        let num = |x: f64| {
            if x.is_finite() {
                Json::Num(x)
            } else {
                Json::Null
            }
        };
        let opt_str = |s: &Option<String>| match s {
            Some(v) => Json::Str(v.clone()),
            None => Json::Null,
        };
        let mut m = BTreeMap::new();
        m.insert("worker".into(), Json::Num(self.worker as f64));
        m.insert("window".into(), Json::Num(self.window as f64));
        m.insert("iteration".into(), Json::Num(self.iteration as f64));
        m.insert("sim_time".into(), num(self.sim_time));
        m.insert("k".into(), Json::Num(self.k as f64));
        m.insert("lam_scale".into(), num(self.lam_scale as f64));
        m.insert("schedule".into(), opt_str(&self.schedule));
        m.insert("t_compute".into(), num(self.t_compute));
        m.insert("t_allreduce".into(), num(self.t_allreduce));
        m.insert("t_ar_local".into(), num(self.t_ar_local));
        m.insert("t_ar_global".into(), num(self.t_ar_global));
        m.insert("blocked_s".into(), num(self.blocked_s));
        m.insert("compress".into(), opt_str(&self.compress));
        m.insert("compress_ratio".into(), num(self.compress_ratio));
        m.insert("wire_bytes".into(), num(self.wire_bytes));
        m.insert("probe".into(), Json::Bool(self.probe));
        m.insert("event".into(), opt_str(&self.event));
        Json::Obj(m)
    }
}

/// Thread-safe, cheaply-clonable recorder shared by a run's workers.
#[derive(Debug, Clone, Default)]
pub struct ControlLog {
    inner: Arc<Mutex<Vec<ControlRecord>>>,
}

impl ControlLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, r: ControlRecord) {
        self.inner.lock().unwrap().push(r);
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All records, ordered by (iteration, worker) so exports are
    /// deterministic regardless of thread interleaving.
    pub fn records(&self) -> Vec<ControlRecord> {
        let mut v = self.inner.lock().unwrap().clone();
        v.sort_by_key(|r| (r.iteration, r.worker));
        v
    }

    /// Records carrying a fault/recovery/quarantine annotation.
    pub fn events(&self) -> Vec<ControlRecord> {
        self.records().into_iter().filter(|r| r.event.is_some()).collect()
    }

    /// Number of times the decided k changed along the trace.
    pub fn k_changes(&self) -> usize {
        let ks: Vec<usize> =
            self.records().iter().filter(|r| r.event.is_none()).map(|r| r.k).collect();
        ks.windows(2).filter(|w| w[0] != w[1]).count()
    }

    /// Number of times the collective schedule changed along the trace.
    pub fn schedule_switches(&self) -> usize {
        self.comm_summary().schedule_switches
    }

    /// Aggregate comm-phase accounting over the decision trace (records
    /// carrying a collective, i.e. `schedule.is_some()`), computed in a
    /// single ordered pass over one snapshot of the log. Probe windows
    /// count into the phase totals and the `probe` sub-summary, but a
    /// probe excursion (and the return from it) is **not** a schedule
    /// switch — only changes between non-probe windows are.
    pub fn comm_summary(&self) -> CommPhaseSummary {
        let records = self.records();
        let mut s = CommPhaseSummary::default();
        let mut prev: Option<&str> = None;
        for r in &records {
            if let Some(name) = r.schedule.as_deref() {
                s.local_s += r.t_ar_local;
                s.global_s += r.t_ar_global;
                s.rounds += 1;
                if r.probe {
                    s.probe_rounds += 1;
                } else {
                    if prev.is_some_and(|p| p != name) {
                        s.schedule_switches += 1;
                    }
                    prev = Some(name);
                }
            }
        }
        s
    }

    /// Aggregate compression accounting over the decision trace
    /// (records carrying a collective), exported under the run JSON's
    /// `"compress"` key.
    pub fn compress_summary(&self) -> CompressSummary {
        let records = self.records();
        let mut s = CompressSummary::default();
        let mut prev_ratio: Option<f64> = None;
        for r in &records {
            if r.schedule.is_none() {
                continue;
            }
            s.rounds += 1;
            s.wire_bytes_total += r.wire_bytes;
            if let Some(name) = r.compress.as_deref() {
                s.kind = name.to_string();
            }
            if prev_ratio.is_some_and(|p| p != r.compress_ratio) {
                s.ratio_changes += 1;
            }
            prev_ratio = Some(r.compress_ratio);
            s.final_ratio = r.compress_ratio;
        }
        s
    }

    /// The decision trace as a JSON array (the `control` key of the run's
    /// metrics JSON).
    pub fn to_json(&self) -> Json {
        Json::Arr(self.records().iter().map(ControlRecord::to_json).collect())
    }

    pub fn write_json(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_json().to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(worker: usize, iteration: u64, k: usize, event: Option<&str>) -> ControlRecord {
        ControlRecord {
            worker,
            window: iteration / 2,
            iteration,
            sim_time: iteration as f64 * 0.1,
            k,
            lam_scale: 1.0,
            schedule: event.is_none().then(|| "ring".to_string()),
            t_compute: 1e-3,
            t_allreduce: 2e-3,
            t_ar_local: 1.5e-3,
            t_ar_global: 0.5e-3,
            blocked_s: 0.0,
            compress: event.is_none().then(|| "none".to_string()),
            compress_ratio: 1.0,
            wire_bytes: 4000.0,
            probe: false,
            event: event.map(String::from),
        }
    }

    #[test]
    fn records_sorted_and_counted() {
        let log = ControlLog::new();
        log.record(rec(1, 4, 2, None));
        log.record(rec(0, 2, 1, None));
        log.record(rec(0, 6, 2, Some("kill")));
        assert_eq!(log.len(), 3);
        let rs = log.records();
        assert_eq!(rs[0].iteration, 2);
        assert_eq!(rs[2].event.as_deref(), Some("kill"));
        assert_eq!(log.events().len(), 1);
        assert_eq!(log.k_changes(), 1); // 1 → 2 over the non-event records
    }

    #[test]
    fn schedule_switches_and_comm_summary() {
        let log = ControlLog::new();
        log.record(rec(0, 0, 1, None));
        let mut hier = rec(0, 2, 1, None);
        hier.schedule = Some("hierarchical".into());
        log.record(hier);
        log.record(rec(0, 4, 1, Some("kill"))); // no schedule: not counted
        assert_eq!(log.schedule_switches(), 1);
        let s = log.comm_summary();
        assert_eq!(s.rounds, 2);
        assert!((s.local_s - 3e-3).abs() < 1e-12);
        assert!((s.global_s - 1e-3).abs() < 1e-12);
        assert_eq!(s.schedule_switches, 1);
    }

    #[test]
    fn probe_rounds_counted_and_excluded_from_switches() {
        let log = ControlLog::new();
        log.record(rec(0, 0, 1, None)); // ring
        let mut probe = rec(0, 2, 1, None); // probe excursion onto hier
        probe.schedule = Some("hierarchical".into());
        probe.probe = true;
        log.record(probe);
        log.record(rec(0, 4, 1, None)); // back on ring: NOT a switch
        let mut switched = rec(0, 6, 1, None); // a real switch
        switched.schedule = Some("hierarchical".into());
        log.record(switched);
        let s = log.comm_summary();
        assert_eq!(s.rounds, 4, "probe rounds still count into the totals");
        assert_eq!(s.probe_rounds, 1);
        assert_eq!(s.schedule_switches, 1, "the probe excursion must not count as switches");
        let j = s.to_json();
        assert_eq!(
            j.get("probe").unwrap().get("rounds").unwrap().as_f64(),
            Some(1.0),
            "probe summary missing from the comm JSON"
        );
    }

    #[test]
    fn compress_summary_tracks_ratio_and_bytes() {
        let log = ControlLog::new();
        log.record(rec(0, 0, 1, None)); // ratio 1.0, 4000 B
        let mut tk = rec(0, 2, 1, None);
        tk.compress = Some("topk".into());
        tk.compress_ratio = 0.1;
        tk.wire_bytes = 800.0;
        log.record(tk);
        log.record(rec(0, 4, 1, Some("kill"))); // no collective: not counted
        let s = log.compress_summary();
        assert_eq!(s.rounds, 2);
        assert!((s.wire_bytes_total - 4800.0).abs() < 1e-9);
        assert_eq!(s.ratio_changes, 1);
        assert_eq!(s.final_ratio, 0.1);
        assert_eq!(s.kind, "topk");
        assert!(Json::parse(&s.to_json().to_string()).is_ok());
    }

    #[test]
    fn json_roundtrip_shape() {
        let log = ControlLog::new();
        log.record(rec(0, 1, 1, None));
        log.record(rec(0, 3, 2, Some("recovered")));
        let j = log.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("k").unwrap().as_f64(), Some(1.0));
        assert_eq!(arr[0].get("schedule").unwrap().as_str(), Some("ring"));
        assert_eq!(arr[0].get("t_ar_local").unwrap().as_f64(), Some(1.5e-3));
        assert_eq!(arr[1].get("event").unwrap().as_str(), Some("recovered"));
        assert_eq!(arr[0].get("event"), Some(&Json::Null));
        assert_eq!(arr[0].get("probe"), Some(&Json::Bool(false)));
    }

    #[test]
    fn write_json_to_disk() {
        let log = ControlLog::new();
        log.record(rec(0, 0, 1, None));
        let p = std::env::temp_dir().join(format!("dcs3gd_ctl_{}.json", std::process::id()));
        log.write_json(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(Json::parse(&text).is_ok());
        std::fs::remove_file(&p).unwrap();
    }
}
