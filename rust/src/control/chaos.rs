//! Fault injection and recovery primitives: scripted worker failures in
//! *virtual* time, heartbeat-based detection, and checkpoint snapshots
//! the engines restore from.
//!
//! A [`FaultPlan`] scripts events against the simulated cluster:
//!
//! * **Kill** — the worker loses its local state (weights, momentum) at
//!   the event time. With `respawn: true` (the default) the failure is
//!   *detected* when its heartbeat (last rendezvous/step timestamp on
//!   the [`HeartbeatBoard`]) goes stale past the configured timeout,
//!   and the respawned worker restores from the latest
//!   [`SnapshotStore`] checkpoint, paying `detect + restore` seconds of
//!   virtual downtime. With `respawn: false` the rank **departs**: it
//!   deregisters from the communicator group and the membership epoch
//!   advances (see [`crate::control::MembershipLog`]).
//! * **Slow** — a transient straggler: compute runs `factor×` slower
//!   for a duration (e.g. a co-scheduled job, thermal throttling).
//! * **Delay** — a one-shot stall of `extra_s` (e.g. a GC pause or
//!   network hiccup).
//!
//! Each worker owns a [`ChaosInjector`] over its slice of the plan;
//! the plan itself lives in the experiment config so runs stay
//! deterministic and reproducible.

use std::sync::{Arc, Mutex};

use crate::model::Checkpoint;

/// What happens to a worker at a scripted virtual time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Crash: local state lost. `respawn: true` recovers the same rank
    /// from a snapshot; `respawn: false` is a permanent departure (the
    /// membership epoch shrinks).
    Kill { respawn: bool },
    /// Compute runs `factor×` slower for `duration_s` seconds.
    Slow { factor: f64, duration_s: f64 },
    /// One-shot stall of `extra_s` seconds.
    Delay { extra_s: f64 },
}

/// One scripted event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub rank: usize,
    /// Virtual time the event fires (seconds on the worker's clock).
    pub at_s: f64,
    pub kind: FaultKind,
}

/// The full scripted schedule for a run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, e: FaultEvent) {
        self.events.push(e);
    }

    /// Builder: kill `rank` at `at_s` (crash-and-respawn).
    pub fn kill(mut self, rank: usize, at_s: f64) -> Self {
        self.push(FaultEvent { rank, at_s, kind: FaultKind::Kill { respawn: true } });
        self
    }

    /// Builder: `rank` departs permanently at `at_s` — a kill that is
    /// *not* respawned; the membership epoch shrinks around it.
    pub fn depart(mut self, rank: usize, at_s: f64) -> Self {
        self.push(FaultEvent { rank, at_s, kind: FaultKind::Kill { respawn: false } });
        self
    }

    /// Builder: slow `rank` by `factor` for `duration_s` starting `at_s`.
    pub fn slow(mut self, rank: usize, at_s: f64, factor: f64, duration_s: f64) -> Self {
        self.push(FaultEvent { rank, at_s, kind: FaultKind::Slow { factor, duration_s } });
        self
    }

    /// Builder: stall `rank` once for `extra_s` at `at_s`.
    pub fn delay(mut self, rank: usize, at_s: f64, extra_s: f64) -> Self {
        self.push(FaultEvent { rank, at_s, kind: FaultKind::Delay { extra_s } });
        self
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Does the plan kill anyone? (Engines use this to decide whether
    /// snapshots are worth taking by default.)
    pub fn has_kills(&self) -> bool {
        self.events.iter().any(|e| matches!(e.kind, FaultKind::Kill { .. }))
    }

    /// Does the plan contain permanent departures (kills that are not
    /// respawned)? These drive the membership epoch.
    pub fn has_departures(&self) -> bool {
        self.events.iter().any(|e| matches!(e.kind, FaultKind::Kill { respawn: false }))
    }

    /// This rank's events, ordered by fire time.
    pub fn for_rank(&self, rank: usize) -> Vec<FaultEvent> {
        let mut out: Vec<FaultEvent> =
            self.events.iter().copied().filter(|e| e.rank == rank).collect();
        out.sort_by(|a, b| a.at_s.partial_cmp(&b.at_s).unwrap());
        out
    }
}

/// Per-worker view of the plan: tracks which one-shot events have fired.
#[derive(Debug)]
pub struct ChaosInjector {
    events: Vec<FaultEvent>,
    fired: Vec<bool>,
}

impl ChaosInjector {
    pub fn new(plan: &FaultPlan, rank: usize) -> Self {
        let events = plan.for_rank(rank);
        let fired = vec![false; events.len()];
        ChaosInjector { events, fired }
    }

    /// No events scripted for this rank at all.
    pub fn is_inert(&self) -> bool {
        self.events.is_empty()
    }

    /// Product of the Slow factors active at `now` (1.0 when healthy).
    pub fn compute_factor(&self, now: f64) -> f64 {
        let mut f = 1.0;
        for e in &self.events {
            if let FaultKind::Slow { factor, duration_s } = e.kind {
                if now >= e.at_s && now < e.at_s + duration_s {
                    f *= factor.max(0.0);
                }
            }
        }
        f
    }

    /// Total one-shot Delay seconds due at/before `now`; each is
    /// consumed exactly once.
    pub fn take_delay(&mut self, now: f64) -> f64 {
        let mut extra = 0.0;
        for (i, e) in self.events.iter().enumerate() {
            if self.fired[i] {
                continue;
            }
            if let FaultKind::Delay { extra_s } = e.kind {
                if now >= e.at_s {
                    self.fired[i] = true;
                    extra += extra_s.max(0.0);
                }
            }
        }
        extra
    }

    /// A kill is due at/before `now` but not yet consumed: the worker
    /// is scripted-dead. Heartbeats must stop counting from the crash
    /// time, not from whenever the engine notices — letting a dead
    /// rank's post-crash step beat the board double-counts its
    /// liveness into the detection window (see [`HeartbeatBoard`]).
    pub fn kill_pending(&self, now: f64) -> bool {
        self.events.iter().enumerate().any(|(i, e)| {
            !self.fired[i] && matches!(e.kind, FaultKind::Kill { .. }) && now >= e.at_s
        })
    }

    /// The earliest unconsumed Kill due at/before `now`, if any.
    pub fn take_kill(&mut self, now: f64) -> Option<FaultEvent> {
        for (i, e) in self.events.iter().enumerate() {
            if self.fired[i] {
                continue;
            }
            if matches!(e.kind, FaultKind::Kill { .. }) && now >= e.at_s {
                self.fired[i] = true;
                return Some(*e);
            }
        }
        None
    }
}

/// Last-seen virtual timestamps, one per rank, written at every step /
/// rendezvous boundary. Failure detection is a stale heartbeat: a rank
/// whose last beat is older than the timeout is *suspected*, and the
/// recovery clock starts from `last_seen + timeout`.
///
/// Liveness windows are keyed by **(rank, liveness epoch)**. The
/// same-window double-count fix has two halves: the engines stop a
/// scripted-dead rank from beating at all
/// ([`ChaosInjector::kill_pending`] gates `WorkerCtx::beat`), and
/// every worker beat carries its incarnation — `WorkerCtx` routes
/// through [`HeartbeatBoard::beat_epoch`], and
/// [`HeartbeatBoard::respawn`] (called at recovery and at
/// membership-epoch changes) starts a fresh epoch so a beat tagged
/// with a dead incarnation is dropped instead of extending the new
/// window. [`HeartbeatBoard::beat`] is the epoch-agnostic write into
/// the rank's current window, kept for callers without incarnation
/// tracking.
#[derive(Debug, Clone)]
pub struct HeartbeatBoard {
    /// Per rank: (liveness epoch, last beat in that epoch).
    inner: Arc<Mutex<Vec<(u64, f64)>>>,
}

impl HeartbeatBoard {
    pub fn new(n_ranks: usize) -> Self {
        HeartbeatBoard { inner: Arc::new(Mutex::new(vec![(0, 0.0); n_ranks])) }
    }

    /// Record life from `rank` at virtual time `now` (monotone within
    /// the rank's current liveness epoch).
    pub fn beat(&self, rank: usize, now: f64) {
        let mut v = self.inner.lock().unwrap();
        if now > v[rank].1 {
            v[rank].1 = now;
        }
    }

    /// Record life from `rank` under a specific liveness epoch. Beats
    /// from an older epoch (a dead incarnation) are dropped; a newer
    /// epoch replaces the window instead of maxing into it.
    pub fn beat_epoch(&self, rank: usize, epoch: u64, now: f64) {
        let mut v = self.inner.lock().unwrap();
        let (cur, last) = v[rank];
        if epoch < cur {
            return; // stale incarnation: deduped
        }
        if epoch > cur {
            v[rank] = (epoch, now);
        } else if now > last {
            v[rank].1 = now;
        }
    }

    /// Start a new liveness epoch for `rank` (respawn or membership
    /// change) anchored at `now`; returns the new epoch.
    pub fn respawn(&self, rank: usize, now: f64) -> u64 {
        let mut v = self.inner.lock().unwrap();
        let next = v[rank].0 + 1;
        v[rank] = (next, now);
        next
    }

    pub fn last_seen(&self, rank: usize) -> f64 {
        self.inner.lock().unwrap()[rank].1
    }

    /// The rank's current liveness epoch.
    pub fn epoch_of(&self, rank: usize) -> u64 {
        self.inner.lock().unwrap()[rank].0
    }

    /// Heartbeat-timeout detection: is `rank` presumed dead at `now`?
    pub fn suspected(&self, rank: usize, now: f64, timeout_s: f64) -> bool {
        now - self.last_seen(rank) > timeout_s
    }

    /// The virtual time the failure of `rank` is *detected*: one timeout
    /// after its last heartbeat (never earlier than the crash itself).
    pub fn detect_time(&self, rank: usize, crash_at: f64, timeout_s: f64) -> f64 {
        (self.last_seen(rank) + timeout_s).max(crash_at)
    }
}

/// Recent recovery checkpoints, shared by all workers of a run. The
/// leader refreshes the store at window boundaries (the averaged
/// weights are canonical there, Eq. 8); a respawned worker restores
/// from it.
///
/// Recovery must stay **deterministic** even though the leader's thread
/// races ahead or behind the crashed worker in wall-clock time. The
/// store therefore keeps a short history, and recovery selects with
/// [`SnapshotStore::latest_at_or_before`] using an iteration bound the
/// engine derives from the rendezvous happens-before order (every
/// snapshot at or below the bound is guaranteed published; anything
/// newer is raced and must be ignored).
#[derive(Debug, Clone, Default)]
pub struct SnapshotStore {
    inner: Arc<Mutex<Vec<Checkpoint>>>,
}

/// History depth: the leader can be at most ~3 windows ahead of the
/// recovery bound, so 8 leaves ample slack at any snapshot cadence.
const SNAPSHOT_HISTORY: usize = 8;

impl SnapshotStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a snapshot (kept in iteration order; oldest dropped past
    /// the history cap; stale duplicates ignored).
    pub fn put(&self, ck: Checkpoint) {
        let mut g = self.inner.lock().unwrap();
        if g.last().map(|old| ck.iteration <= old.iteration).unwrap_or(false) {
            return;
        }
        g.push(ck);
        if g.len() > SNAPSHOT_HISTORY {
            g.remove(0);
        }
    }

    /// Clone of the newest snapshot, if any exists yet.
    pub fn latest(&self) -> Option<Checkpoint> {
        self.inner.lock().unwrap().last().cloned()
    }

    /// Newest snapshot with `iteration <= bound` — the deterministic
    /// recovery selector (see the type docs).
    pub fn latest_at_or_before(&self, bound: u64) -> Option<Checkpoint> {
        self.inner.lock().unwrap().iter().rev().find(|c| c.iteration <= bound).cloned()
    }

    pub fn latest_iteration(&self) -> Option<u64> {
        self.inner.lock().unwrap().last().map(|c| c.iteration)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_slices_by_rank_in_time_order() {
        let plan = FaultPlan::new()
            .slow(1, 2.0, 3.0, 1.0)
            .kill(0, 5.0)
            .delay(1, 0.5, 0.1)
            .kill(1, 9.0);
        assert!(plan.has_kills());
        assert!(!plan.has_departures());
        assert_eq!(plan.for_rank(0).len(), 1);
        let r1 = plan.for_rank(1);
        assert_eq!(r1.len(), 3);
        assert!(r1.windows(2).all(|w| w[0].at_s <= w[1].at_s));
        assert!(plan.for_rank(7).is_empty());
    }

    #[test]
    fn departures_are_unrespawned_kills() {
        let plan = FaultPlan::new().depart(2, 1.0);
        assert!(plan.has_kills(), "a departure is still a kill");
        assert!(plan.has_departures());
        assert_eq!(plan.for_rank(2)[0].kind, FaultKind::Kill { respawn: false });
        let mut inj = ChaosInjector::new(&plan, 2);
        let ev = inj.take_kill(1.5).unwrap();
        assert!(matches!(ev.kind, FaultKind::Kill { respawn: false }));
    }

    #[test]
    fn slow_window_applies_only_inside_interval() {
        let plan = FaultPlan::new().slow(0, 1.0, 2.0, 3.0);
        let inj = ChaosInjector::new(&plan, 0);
        assert_eq!(inj.compute_factor(0.5), 1.0);
        assert_eq!(inj.compute_factor(1.0), 2.0);
        assert_eq!(inj.compute_factor(3.9), 2.0);
        assert_eq!(inj.compute_factor(4.0), 1.0);
    }

    #[test]
    fn overlapping_slows_compound() {
        let plan = FaultPlan::new().slow(0, 0.0, 2.0, 10.0).slow(0, 5.0, 1.5, 10.0);
        let inj = ChaosInjector::new(&plan, 0);
        assert_eq!(inj.compute_factor(1.0), 2.0);
        assert!((inj.compute_factor(6.0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn delays_fire_once() {
        let plan = FaultPlan::new().delay(0, 1.0, 0.5).delay(0, 2.0, 0.25);
        let mut inj = ChaosInjector::new(&plan, 0);
        assert_eq!(inj.take_delay(0.5), 0.0);
        assert_eq!(inj.take_delay(1.5), 0.5);
        assert_eq!(inj.take_delay(1.6), 0.0); // consumed
        assert_eq!(inj.take_delay(10.0), 0.25);
        assert_eq!(inj.take_delay(10.0), 0.0);
    }

    #[test]
    fn kill_fires_once_and_is_peekable() {
        let plan = FaultPlan::new().kill(3, 2.0);
        let mut inj = ChaosInjector::new(&plan, 3);
        assert!(!inj.kill_pending(1.9));
        assert!(inj.take_kill(1.9).is_none());
        assert!(inj.kill_pending(2.05), "kill due: the rank is scripted-dead");
        let e = inj.take_kill(2.1).unwrap();
        assert_eq!(e.at_s, 2.0);
        assert!(!inj.kill_pending(2.1), "consumed kill no longer pending");
        assert!(inj.take_kill(100.0).is_none());
    }

    #[test]
    fn heartbeat_detection() {
        let hb = HeartbeatBoard::new(2);
        hb.beat(0, 1.0);
        hb.beat(0, 0.5); // stale beat must not move time backwards
        assert_eq!(hb.last_seen(0), 1.0);
        assert!(!hb.suspected(0, 1.2, 0.5));
        assert!(hb.suspected(0, 1.6, 0.5));
        // detection = last beat + timeout, floored at the crash time
        assert_eq!(hb.detect_time(0, 1.1, 0.5), 1.5);
        assert_eq!(hb.detect_time(0, 2.0, 0.5), 2.0);
    }

    #[test]
    fn respawn_dedupes_beats_by_rank_and_epoch() {
        // The kill + immediate-respawn double-count: a beat from the
        // dead incarnation must not extend the respawned incarnation's
        // liveness window.
        let hb = HeartbeatBoard::new(1);
        hb.beat(0, 1.0);
        assert_eq!(hb.epoch_of(0), 0);
        let e = hb.respawn(0, 1.5);
        assert_eq!(e, 1);
        assert_eq!(hb.last_seen(0), 1.5, "respawn anchors the new window");
        // a dead-incarnation beat with a *later* timestamp is dropped
        hb.beat_epoch(0, e - 1, 9.0);
        assert_eq!(hb.last_seen(0), 1.5, "stale-epoch beat must be deduped");
        // same-epoch beats stay monotone
        hb.beat_epoch(0, e, 1.2);
        assert_eq!(hb.last_seen(0), 1.5);
        hb.beat_epoch(0, e, 2.0);
        assert_eq!(hb.last_seen(0), 2.0);
        // a newer epoch replaces rather than maxes
        hb.beat_epoch(0, e + 1, 0.7);
        assert_eq!(hb.last_seen(0), 0.7);
        assert_eq!(hb.epoch_of(0), e + 1);
    }

    #[test]
    fn snapshot_store_keeps_newest() {
        let s = SnapshotStore::new();
        assert!(s.latest().is_none());
        s.put(Checkpoint { iteration: 10, weights: vec![1.0], velocity: vec![0.0] });
        // stale put: ignored
        s.put(Checkpoint { iteration: 5, weights: vec![2.0], velocity: vec![0.0] });
        assert_eq!(s.latest_iteration(), Some(10));
        assert_eq!(s.latest().unwrap().weights, vec![1.0]);
        s.put(Checkpoint { iteration: 20, weights: vec![3.0], velocity: vec![0.0] });
        assert_eq!(s.latest_iteration(), Some(20));
    }

    #[test]
    fn snapshot_selection_respects_bound() {
        let s = SnapshotStore::new();
        for it in [5u64, 10, 15, 20] {
            s.put(Checkpoint { iteration: it, weights: vec![it as f32], velocity: vec![] });
        }
        assert_eq!(s.latest_at_or_before(4), None);
        assert_eq!(s.latest_at_or_before(5).unwrap().iteration, 5);
        assert_eq!(s.latest_at_or_before(14).unwrap().iteration, 10);
        assert_eq!(s.latest_at_or_before(100).unwrap().iteration, 20);
    }

    #[test]
    fn snapshot_history_is_bounded() {
        let s = SnapshotStore::new();
        for it in 1..=20u64 {
            s.put(Checkpoint { iteration: it, weights: vec![], velocity: vec![] });
        }
        assert_eq!(s.latest_iteration(), Some(20));
        // oldest entries dropped, recent window retained
        assert!(s.latest_at_or_before(5).is_none());
        assert_eq!(s.latest_at_or_before(15).unwrap().iteration, 15);
    }
}
