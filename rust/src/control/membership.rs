//! Elastic cluster membership: the scripted membership event log and
//! the epoch trace recorder.
//!
//! PR 1's fault path could only respawn a killed worker on the *same*
//! rank because the rendezvous substrate pinned N for the lifetime of a
//! run. Membership is now a first-class **epoch**: kills that are not
//! respawned ([`crate::control::FaultPlan::depart`]) shrink the group,
//! scripted `[[control.join]]` arrivals grow it, and each change
//! advances the epoch at a window boundary. The substrate mechanics
//! live in [`crate::comm`] (roster intervals, survivor-set round
//! resolution, join admission); this module owns the two control-plane
//! pieces:
//!
//! * [`MembershipLog`] — the scripted event schedule, derived from the
//!   experiment config. Deterministic and identical on every rank, so
//!   every member computes the same transition at the same window
//!   boundary: departures are *observed* from the short round's
//!   contributor set, joins *fire* when the shared round-completion
//!   time reaches their `at_s`.
//! * [`EpochTrace`] — the realized transitions: one record per member
//!   per epoch, carrying the member's post-resync parameter checksum.
//!   Ranks are bit-identical at every epoch boundary by construction
//!   (everyone adopts the resync mean; joiners restore the published
//!   bootstrap), and the trace proves it — the checksum agreement is
//!   asserted by `tests/membership.rs` and exported under the run
//!   JSON's `"epochs"` key.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::util::Json;

use super::chaos::{FaultKind, FaultPlan};

/// A scripted arrival: `rank` joins the run once the cluster's shared
/// virtual time reaches `at_s`. Join ranks are fresh identities above
/// the initial world (departed rank ids are retired, like a replaced
/// machine).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JoinEvent {
    pub rank: usize,
    pub at_s: f64,
}

/// The scripted membership schedule of a run: the initial world size,
/// the joins (sorted by fire time), and the scripted departures
/// (informational — departures are *observed* through the rendezvous
/// rounds, not predicted).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MembershipLog {
    initial: usize,
    joins: Vec<JoinEvent>,
    departs: Vec<(usize, f64)>,
}

impl MembershipLog {
    /// Derive the schedule from a run's control config: joins from the
    /// `[[control.join]]` events, departures from the fault plan's
    /// non-respawned kills.
    pub fn new(initial: usize, joins: &[JoinEvent], faults: &FaultPlan) -> Self {
        let mut joins = joins.to_vec();
        joins.sort_by(|a, b| a.at_s.partial_cmp(&b.at_s).unwrap().then(a.rank.cmp(&b.rank)));
        let departs = faults
            .events()
            .iter()
            .filter(|e| matches!(e.kind, FaultKind::Kill { respawn: false }))
            .map(|e| (e.rank, e.at_s))
            .collect();
        MembershipLog { initial, joins, departs }
    }

    /// Does this run shrink or grow at all? (Non-elastic runs skip the
    /// whole transition machinery.)
    pub fn is_elastic(&self) -> bool {
        !self.joins.is_empty() || !self.departs.is_empty()
    }

    pub fn initial_world(&self) -> usize {
        self.initial
    }

    /// Rank-slot capacity the communicator group needs: the initial
    /// world plus every scripted joiner.
    pub fn capacity(&self) -> usize {
        self.joins.iter().map(|j| j.rank + 1).fold(self.initial, usize::max)
    }

    /// Is `rank` a scripted joiner (its worker thread starts parked in
    /// admission)?
    pub fn is_join_rank(&self, rank: usize) -> bool {
        self.joins.iter().any(|j| j.rank == rank)
    }

    pub fn joins(&self) -> &[JoinEvent] {
        &self.joins
    }

    pub fn departs(&self) -> &[(usize, f64)] {
        &self.departs
    }

    /// Joins past `cursor` whose fire time has been reached by the
    /// shared round-completion time `now`. Joins fire in schedule
    /// order, so the fired set is always a prefix — and the cursor
    /// rides the epoch bootstrap (`JoinBootstrap::join_cursor`), since
    /// it cannot be reconstructed from a member list once an earlier
    /// joiner departs again.
    pub fn joins_due(&self, cursor: usize, now: f64) -> Vec<usize> {
        self.joins[cursor.min(self.joins.len())..]
            .iter()
            .take_while(|j| j.at_s <= now)
            .map(|j| j.rank)
            .collect()
    }

    /// The scripted roster schedule: epoch boundary times (sorted,
    /// deduplicated — coincident events share one boundary) and the
    /// active rank roster of each epoch (`boundaries.len() + 1`
    /// entries, sorted ranks). This is the *virtual-time* view the
    /// centralized engines and the PS [`crate::ps::ReplicaPlan`] use —
    /// a pure function of the config, identical everywhere, with no
    /// collective rendezvous needed to agree on it.
    pub fn roster_schedule(&self) -> (Vec<f64>, Vec<Vec<usize>>) {
        let mut times: Vec<f64> = self
            .joins
            .iter()
            .map(|j| j.at_s)
            .chain(self.departs.iter().map(|&(_, at)| at))
            .collect();
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        times.dedup();
        let mut rosters: Vec<Vec<usize>> = vec![(0..self.initial).collect()];
        for &t in &times {
            let mut next: Vec<usize> = rosters
                .last()
                .unwrap()
                .iter()
                .copied()
                .filter(|&r| !self.departs.iter().any(|&(dr, at)| dr == r && at == t))
                .collect();
            next.extend(self.joins.iter().filter(|j| j.at_s == t).map(|j| j.rank));
            next.sort_unstable();
            rosters.push(next);
        }
        (times, rosters)
    }
}

/// FNV-1a over the raw bit patterns — the parameter checksum the epoch
/// trace uses to pin bit-identity across ranks (float equality would
/// hide sign-of-zero / NaN-payload drift).
pub fn param_crc(w: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for x in w {
        h ^= x.to_bits() as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// One member's view of one epoch boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct EpochRecord {
    pub epoch: u64,
    pub rank: usize,
    /// Position in the epoch's member list (slot 0 = leader).
    pub slot: usize,
    /// World size of the epoch.
    pub world: usize,
    /// Cumulative healthy-rank step count at the boundary (identical
    /// across ranks — the trace's iteration axis).
    pub sched_steps: u64,
    /// Shared virtual time the epoch began.
    pub sim_time: f64,
    /// Checksum of this member's parameters right after the boundary.
    pub w_crc: u64,
    /// Leader-only annotations (empty on member records).
    pub joined: Vec<usize>,
    pub departed: Vec<usize>,
}

/// Thread-safe, cheaply-clonable recorder of realized epoch
/// transitions, shared by a run's workers and exported under the run
/// JSON's `"epochs"` key.
#[derive(Debug, Clone, Default)]
pub struct EpochTrace {
    inner: Arc<Mutex<Vec<EpochRecord>>>,
}

impl EpochTrace {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, r: EpochRecord) {
        self.inner.lock().unwrap().push(r);
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All records, ordered by (epoch, rank) so exports are
    /// deterministic regardless of thread interleaving.
    pub fn records(&self) -> Vec<EpochRecord> {
        let mut v = self.inner.lock().unwrap().clone();
        v.sort_by_key(|r| (r.epoch, r.rank));
        v
    }

    /// The leader records, one per epoch — the transition summaries.
    pub fn transitions(&self) -> Vec<EpochRecord> {
        self.records().into_iter().filter(|r| r.slot == 0).collect()
    }

    /// World-size trajectory, one entry per epoch (from the leader
    /// records): e.g. `[64, 48, 80]` for a shrink-then-grow run.
    pub fn worlds(&self) -> Vec<usize> {
        self.transitions().iter().map(|r| r.world).collect()
    }

    /// Were every epoch's member parameters bit-identical? Returns the
    /// epochs that violate the invariant (empty = all good).
    pub fn crc_mismatches(&self) -> Vec<u64> {
        let mut by_epoch: BTreeMap<u64, Vec<u64>> = BTreeMap::new();
        for r in self.records() {
            by_epoch.entry(r.epoch).or_default().push(r.w_crc);
        }
        by_epoch
            .into_iter()
            .filter(|(_, crcs)| crcs.windows(2).any(|w| w[0] != w[1]))
            .map(|(e, _)| e)
            .collect()
    }

    /// The epoch trace as a JSON array (the `epochs` key of the run's
    /// metrics JSON): one object per epoch from the leader record, plus
    /// the cross-rank checksum agreement.
    pub fn to_json(&self) -> Json {
        let num = |x: f64| {
            if x.is_finite() {
                Json::Num(x)
            } else {
                Json::Null
            }
        };
        let mismatches = self.crc_mismatches();
        Json::Arr(
            self.transitions()
                .iter()
                .map(|r| {
                    let mut m = BTreeMap::new();
                    m.insert("epoch".to_string(), Json::Num(r.epoch as f64));
                    m.insert("world".into(), Json::Num(r.world as f64));
                    m.insert("sched_steps".into(), Json::Num(r.sched_steps as f64));
                    m.insert("sim_time".into(), num(r.sim_time));
                    m.insert("w_crc".into(), Json::Str(format!("{:016x}", r.w_crc)));
                    m.insert(
                        "params_identical".into(),
                        Json::Bool(!mismatches.contains(&r.epoch)),
                    );
                    m.insert(
                        "joined".into(),
                        Json::Arr(r.joined.iter().map(|&x| Json::Num(x as f64)).collect()),
                    );
                    m.insert(
                        "departed".into(),
                        Json::Arr(r.departed.iter().map(|&x| Json::Num(x as f64)).collect()),
                    );
                    Json::Obj(m)
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn log_4_to_3_to_5() -> MembershipLog {
        let joins = [JoinEvent { rank: 4, at_s: 2.0 }, JoinEvent { rank: 5, at_s: 2.0 }];
        let faults = FaultPlan::new().depart(3, 1.0).kill(0, 0.5);
        MembershipLog::new(4, &joins, &faults)
    }

    #[test]
    fn log_derives_capacity_and_events() {
        let log = log_4_to_3_to_5();
        assert!(log.is_elastic());
        assert_eq!(log.initial_world(), 4);
        assert_eq!(log.capacity(), 6);
        assert!(log.is_join_rank(5));
        assert!(!log.is_join_rank(3));
        // the respawned kill is not a departure
        assert_eq!(log.departs(), &[(3, 1.0)]);
    }

    #[test]
    fn joins_fire_as_a_prefix_in_time_order() {
        let log = log_4_to_3_to_5();
        assert!(log.joins_due(0, 1.9).is_empty());
        assert_eq!(log.joins_due(0, 2.0), vec![4, 5]);
        assert_eq!(log.joins_due(1, 2.0), vec![5], "cursor skips already-fired joins");
        assert_eq!(log.joins_due(2, 99.0), Vec::<usize>::new(), "cursor past the schedule");
    }

    #[test]
    fn roster_schedule_folds_events_into_epochs() {
        let log = log_4_to_3_to_5();
        let (boundaries, rosters) = log.roster_schedule();
        assert_eq!(boundaries, vec![1.0, 2.0]);
        assert_eq!(
            rosters,
            vec![vec![0, 1, 2, 3], vec![0, 1, 2], vec![0, 1, 2, 4, 5]],
            "depart at 1.0 shrinks, the coincident joins at 2.0 share one boundary"
        );
        // non-elastic: a single epoch, no boundaries
        let inert = MembershipLog::new(3, &[], &FaultPlan::new());
        let (b, r) = inert.roster_schedule();
        assert!(b.is_empty());
        assert_eq!(r, vec![vec![0, 1, 2]]);
    }

    #[test]
    fn non_elastic_log_is_inert() {
        let log = MembershipLog::new(4, &[], &FaultPlan::new().kill(1, 1.0));
        assert!(!log.is_elastic());
        assert_eq!(log.capacity(), 4);
    }

    #[test]
    fn param_crc_is_bit_sensitive() {
        let a = param_crc(&[1.0, 2.0, 3.0]);
        assert_eq!(a, param_crc(&[1.0, 2.0, 3.0]));
        assert_ne!(a, param_crc(&[1.0, 2.0, 3.0000001]));
        // float equality would call these identical; the bit checksum
        // must not
        assert_ne!(param_crc(&[0.0]), param_crc(&[-0.0]));
    }

    fn rec(epoch: u64, rank: usize, slot: usize, crc: u64) -> EpochRecord {
        EpochRecord {
            epoch,
            rank,
            slot,
            world: 3,
            sched_steps: epoch * 10,
            sim_time: epoch as f64,
            w_crc: crc,
            joined: Vec::new(),
            departed: Vec::new(),
        }
    }

    #[test]
    fn trace_orders_and_summarizes() {
        let trace = EpochTrace::new();
        trace.record(rec(1, 2, 1, 7));
        trace.record(rec(0, 0, 0, 5));
        trace.record(rec(1, 1, 0, 7));
        trace.record(rec(0, 1, 1, 5));
        assert_eq!(trace.len(), 4);
        assert_eq!(trace.worlds(), vec![3, 3]);
        assert!(trace.crc_mismatches().is_empty());
        let rs = trace.records();
        assert_eq!((rs[0].epoch, rs[0].rank), (0, 0));
        assert_eq!(trace.transitions().len(), 2);
    }

    #[test]
    fn crc_disagreement_is_flagged_and_exported() {
        let trace = EpochTrace::new();
        trace.record(rec(0, 0, 0, 5));
        trace.record(rec(0, 1, 1, 6)); // diverged!
        assert_eq!(trace.crc_mismatches(), vec![0]);
        let j = trace.to_json();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("params_identical"), Some(&Json::Bool(false)));
        assert!(Json::parse(&j.to_string()).is_ok());
    }
}
