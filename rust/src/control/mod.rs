//! Elastic control plane: online steering of the training engines.
//!
//! DC-S3GD's engines fix the staleness bound k and the compensation
//! base λ0 up front, but the profitable operating point depends on the
//! *live* ratio of compute to all-reduce time (Eqs. 13/14) — which
//! drifts with stragglers, payload size and topology — and on whether
//! workers are healthy at all. Since the collective schedule itself is
//! now first-class ([`crate::comm::CollectiveSchedule`]), t_AR is no
//! longer an opaque constant either: the control plane can pick *both*
//! the window length k and the schedule per window. This subsystem
//! closes the loop:
//!
//! * [`staleness`] — the [`StalenessController`] policies ([`Fixed`],
//!   [`DssPid`], [`LambdaCoupled`], [`ScheduleCoupled`],
//!   [`CompressCoupled`]) that adapt k, λ0, the collective schedule and
//!   the compression ratio from observed t_C / t_AR, quarantine
//!   persistent stragglers inside their dragonfly group, and — with
//!   [`ProbeMode`] enabled — periodically run the *inactive* candidate
//!   schedule for one window so its α-β calibration tracks fabric
//!   drift instead of rotting; consulted by the engines at every
//!   wait/post boundary.
//! * [`chaos`] — the [`FaultPlan`] / [`ChaosInjector`] that script
//!   kills, slowdowns and stalls in virtual time, with heartbeat
//!   detection ([`HeartbeatBoard`]) and checkpoint recovery
//!   ([`SnapshotStore`]).
//! * [`membership`] — elastic cluster membership: the scripted
//!   [`MembershipLog`] (departures = non-respawned kills, arrivals =
//!   `[[control.join]]` events) that shrinks and grows the group
//!   across **membership epochs**, and the [`EpochTrace`] recorder
//!   whose per-epoch world/checksum records land in the metrics JSON
//!   under `"epochs"`.
//! * [`log`] — the [`ControlLog`] flight recorder whose per-window
//!   k/λ/schedule/straggler decisions (and the local/global t_AR phase
//!   split) ride into the metrics JSON export.
//!
//! **Consensus without extra rounds**: adaptive decisions only work if
//! every rank switches windows at the same point, or the rendezvous
//! rounds unmatch and the run deadlocks. Rather than a separate control
//! collective, the engines piggyback each worker's observations as
//! extra elements on the update all-reduce itself — the cross-rank
//! means plus a rank-offset slot carrying each rank's own t_C — so
//! every rank sees identical observations and the (deterministic)
//! controllers reach the identical (k, λ, schedule, quarantine)
//! decision with no extra communication round. The control plane rides
//! the data plane.

pub mod chaos;
pub mod log;
pub mod membership;
pub mod staleness;

pub use chaos::{ChaosInjector, FaultEvent, FaultKind, FaultPlan, HeartbeatBoard, SnapshotStore};
pub use log::{ControlLog, ControlRecord};
pub use membership::{param_crc, EpochRecord, EpochTrace, JoinEvent, MembershipLog};
pub use staleness::{
    snap_qsgd_bits, CompressCoupled, Decision, DssPid, DynSspStaleness, Fixed, LambdaCoupled,
    ProbeCfg, ProbeMode, Quarantine, ScheduleCoupled, ScheduleEnv, SgsStaleness,
    StalenessController, WindowObs, QSGD_BITS_LADDER,
};

use anyhow::{bail, Result};

/// Which staleness policy the control plane runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ControlPolicy {
    /// Static k (the paper's behaviour); the control plane only observes.
    #[default]
    Fixed,
    /// DSSP-style bounded adaptation of k from the t_AR / t_C ratio.
    DssPid,
    /// [`ControlPolicy::DssPid`] plus λ0 rescaling with effective
    /// staleness.
    LambdaCoupled,
    /// [`ControlPolicy::LambdaCoupled`] plus per-window collective
    /// schedule selection (flat ring vs hierarchical dragonfly) and
    /// group-local straggler quarantine.
    ScheduleCoupled,
    /// [`ControlPolicy::ScheduleCoupled`] plus per-window compression
    /// ratio selection, with the schedule candidates priced at the
    /// compressed wire volume.
    CompressCoupled,
    /// [`ControlPolicy::DssPid`] plus **per-worker** dynamic staleness
    /// bounds from the piggybacked per-rank t_C split (Dynamic SSP,
    /// 1908.11848) — slow ranks run shorter windows, fast ranks fill
    /// the same wall time with more local steps.
    DynSsp,
}

impl ControlPolicy {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "fixed" | "static" => ControlPolicy::Fixed,
            "dss_pid" | "dss-pid" | "dsspid" | "dssp" => ControlPolicy::DssPid,
            "lambda_coupled" | "lambda-coupled" | "lambdacoupled" => ControlPolicy::LambdaCoupled,
            "schedule_coupled" | "schedule-coupled" | "schedulecoupled" => {
                ControlPolicy::ScheduleCoupled
            }
            "compress_coupled" | "compress-coupled" | "compresscoupled" => {
                ControlPolicy::CompressCoupled
            }
            "dyn_ssp" | "dyn-ssp" | "dynssp" => ControlPolicy::DynSsp,
            other => bail!(
                "unknown control policy {other:?} \
                 (fixed | dss_pid | lambda_coupled | schedule_coupled | compress_coupled \
                 | dyn_ssp)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ControlPolicy::Fixed => "fixed",
            ControlPolicy::DssPid => "dss_pid",
            ControlPolicy::LambdaCoupled => "lambda_coupled",
            ControlPolicy::ScheduleCoupled => "schedule_coupled",
            ControlPolicy::CompressCoupled => "compress_coupled",
            ControlPolicy::DynSsp => "dyn_ssp",
        }
    }
}

/// The `[control]` table of an experiment config: policy, bounds, fault
/// schedule and recovery parameters.
#[derive(Debug, Clone)]
pub struct ControlConfig {
    pub policy: ControlPolicy,
    /// Bounds on the adapted staleness k.
    pub k_min: usize,
    pub k_max: usize,
    /// PI gains of the adaptive policies.
    pub gain_p: f64,
    pub gain_i: f64,
    /// Minimum windows between k changes (hysteresis).
    pub adjust_every: u64,
    /// Bounds on the λ0 multiplier ([`LambdaCoupled`]).
    pub lam_scale_min: f32,
    pub lam_scale_max: f32,
    /// Relative margin a candidate schedule's calibrated cost must
    /// undercut the active schedule's before [`ScheduleCoupled`]
    /// switches to it (noise guard against schedule flapping).
    pub schedule_hysteresis: f64,
    /// Online schedule probing ([`ProbeMode`]): `off` trusts the cost
    /// models (the pre-probing behavior), `interval` runs the inactive
    /// candidate for one window every `probe_interval` windows,
    /// `bandit` alternates the arms ε-greedily.
    pub probe: ProbeMode,
    /// Windows between probes (`interval` mode).
    pub probe_interval: u64,
    /// Exploration rate of `bandit` mode (explores every ⌈1/ε⌉-th
    /// window).
    pub probe_epsilon: f64,
    /// A rank this much slower than the mean of the rest is a straggler.
    pub straggler_factor: f64,
    /// Consecutive slow (healthy) windows before a quarantine engages
    /// (lifts).
    pub quarantine_after: u64,
    /// Heartbeat staleness that marks a worker dead (virtual seconds).
    pub heartbeat_timeout_s: f64,
    /// Time to restore a worker from a snapshot (virtual seconds).
    pub restore_s: f64,
    /// Refresh the recovery snapshot every this many windows (0 = only
    /// when the fault plan contains kills, every 10 windows).
    pub snapshot_every: u64,
    /// Scripted faults (empty = healthy cluster).
    pub faults: FaultPlan,
    /// Scripted arrivals (`[[control.join]]`): fresh ranks admitted at
    /// a membership-epoch boundary once the shared virtual time
    /// reaches their `at_s`.
    pub joins: Vec<JoinEvent>,
    /// LR warm-up ramp for joiners: a rank bootstrapping from the epoch
    /// checkpoint (zeroed momentum and compression residuals) runs its
    /// first windows at a linearly ramped learning rate, reaching the
    /// schedule LR after this many windows (0 = no ramp).
    pub join_warmup_windows: u64,
}

impl Default for ControlConfig {
    fn default() -> Self {
        ControlConfig {
            policy: ControlPolicy::Fixed,
            k_min: 1,
            k_max: 8,
            gain_p: 0.5,
            gain_i: 0.1,
            adjust_every: 1,
            lam_scale_min: 0.25,
            lam_scale_max: 4.0,
            schedule_hysteresis: 0.1,
            probe: ProbeMode::Off,
            probe_interval: 8,
            probe_epsilon: 0.125,
            straggler_factor: 1.5,
            quarantine_after: 3,
            heartbeat_timeout_s: 0.5,
            restore_s: 0.2,
            snapshot_every: 0,
            faults: FaultPlan::default(),
            joins: Vec::new(),
            join_warmup_windows: 0,
        }
    }
}

impl ControlConfig {
    pub fn validate(&self) -> Result<()> {
        if self.k_min == 0 {
            bail!("control.k_min must be ≥ 1");
        }
        if self.k_min > self.k_max {
            bail!("control.k_min {} exceeds control.k_max {}", self.k_min, self.k_max);
        }
        if self.lam_scale_min > self.lam_scale_max {
            bail!("control.lam_scale_min exceeds control.lam_scale_max");
        }
        if self.heartbeat_timeout_s < 0.0 || self.restore_s < 0.0 {
            bail!("control timeouts must be non-negative");
        }
        if self.schedule_hysteresis < 0.0 {
            bail!("control.schedule_hysteresis must be non-negative");
        }
        if self.probe_interval == 0 {
            bail!("control.probe_interval must be ≥ 1");
        }
        if !(self.probe_epsilon > 0.0 && self.probe_epsilon <= 1.0) {
            bail!("control.probe_epsilon must be in (0, 1], got {}", self.probe_epsilon);
        }
        if self.straggler_factor < 1.0 {
            bail!("control.straggler_factor must be ≥ 1");
        }
        if self.quarantine_after == 0 {
            bail!("control.quarantine_after must be ≥ 1");
        }
        for (i, j) in self.joins.iter().enumerate() {
            if !j.at_s.is_finite() || j.at_s < 0.0 {
                bail!("control.join at_s must be finite and non-negative");
            }
            if self.joins[..i].iter().any(|p| p.rank == j.rank) {
                bail!("control.join rank {} scripted twice", j.rank);
            }
        }
        Ok(())
    }

    /// The run's scripted membership schedule (joins + non-respawned
    /// kills), for a given initial world size.
    pub fn membership_log(&self, initial_world: usize) -> MembershipLog {
        MembershipLog::new(initial_world, &self.joins, &self.faults)
    }

    /// Fresh controller for one worker, seeded with the configured
    /// staleness; `env` prices the schedule candidates for
    /// [`ScheduleCoupled`] (ignored by the other policies). All workers
    /// must build identical controllers (see the module docs'
    /// determinism contract).
    /// The probing knobs as the policies take them.
    pub fn probe_cfg(&self) -> ProbeCfg {
        ProbeCfg { mode: self.probe, interval: self.probe_interval, epsilon: self.probe_epsilon }
    }

    pub fn build_controller(
        &self,
        k_init: usize,
        env: ScheduleEnv,
    ) -> Box<dyn StalenessController> {
        match self.policy {
            ControlPolicy::Fixed => Box::new(Fixed::new(k_init)),
            ControlPolicy::DssPid => Box::new(DssPid::new(
                k_init,
                self.k_min,
                self.k_max,
                self.gain_p,
                self.gain_i,
                self.adjust_every,
            )),
            ControlPolicy::LambdaCoupled => Box::new(LambdaCoupled::new(
                k_init,
                self.k_min,
                self.k_max,
                self.gain_p,
                self.gain_i,
                self.adjust_every,
                self.lam_scale_min,
                self.lam_scale_max,
            )),
            ControlPolicy::ScheduleCoupled => Box::new(ScheduleCoupled::new(
                k_init,
                self.k_min,
                self.k_max,
                self.gain_p,
                self.gain_i,
                self.adjust_every,
                self.lam_scale_min,
                self.lam_scale_max,
                env,
                self.schedule_hysteresis,
                self.straggler_factor,
                self.quarantine_after,
                self.probe_cfg(),
            )),
            ControlPolicy::CompressCoupled => Box::new(CompressCoupled::new(
                k_init,
                self.k_min,
                self.k_max,
                self.gain_p,
                self.gain_i,
                self.adjust_every,
                self.lam_scale_min,
                self.lam_scale_max,
                env,
                self.schedule_hysteresis,
                self.straggler_factor,
                self.quarantine_after,
                self.probe_cfg(),
            )),
            ControlPolicy::DynSsp => Box::new(DynSspStaleness::new(
                Box::new(DssPid::new(
                    k_init,
                    self.k_min,
                    self.k_max,
                    self.gain_p,
                    self.gain_i,
                    self.adjust_every,
                )),
                env.n_ranks,
                self.k_min,
                self.k_max,
            )),
        }
    }

    /// Effective snapshot cadence in windows (0 = snapshots off).
    pub fn snapshot_cadence(&self) -> u64 {
        if self.snapshot_every > 0 {
            self.snapshot_every
        } else if self.faults.has_kills() {
            10
        } else {
            0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse_roundtrip() {
        for p in [
            ControlPolicy::Fixed,
            ControlPolicy::DssPid,
            ControlPolicy::LambdaCoupled,
            ControlPolicy::ScheduleCoupled,
            ControlPolicy::CompressCoupled,
            ControlPolicy::DynSsp,
        ] {
            assert_eq!(ControlPolicy::parse(p.name()).unwrap(), p);
        }
        assert_eq!(ControlPolicy::parse("DSS-PID").unwrap(), ControlPolicy::DssPid);
        assert_eq!(
            ControlPolicy::parse("schedule-coupled").unwrap(),
            ControlPolicy::ScheduleCoupled
        );
        assert_eq!(
            ControlPolicy::parse("compress-coupled").unwrap(),
            ControlPolicy::CompressCoupled
        );
        assert!(ControlPolicy::parse("bogus").is_err());
    }

    #[test]
    fn defaults_validate_and_build() {
        let c = ControlConfig::default();
        c.validate().unwrap();
        let ctl = c.build_controller(1, ScheduleEnv::default());
        assert_eq!(ctl.name(), "fixed");
        assert_eq!(ctl.current().k, 1);
        assert_eq!(c.snapshot_cadence(), 0);
    }

    #[test]
    fn invalid_bounds_rejected() {
        let mut c = ControlConfig { k_min: 4, k_max: 2, ..Default::default() };
        assert!(c.validate().is_err());
        c.k_max = 4;
        c.validate().unwrap();
        c.lam_scale_min = 5.0;
        assert!(c.validate().is_err());
        c.lam_scale_min = 0.25;
        c.straggler_factor = 0.5;
        assert!(c.validate().is_err());
        c.straggler_factor = 1.5;
        c.quarantine_after = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn builder_honours_policy_and_clamps_seed_k() {
        let c = ControlConfig {
            policy: ControlPolicy::DssPid,
            k_min: 2,
            k_max: 4,
            ..Default::default()
        };
        let ctl = c.build_controller(1, ScheduleEnv::default()); // below k_min: clamped up
        assert_eq!(ctl.name(), "dss_pid");
        assert_eq!(ctl.current().k, 2);
        let ctl = c.build_controller(9, ScheduleEnv::default()); // above k_max: clamped down
        assert_eq!(ctl.current().k, 4);
    }

    #[test]
    fn schedule_coupled_builds_with_env() {
        let c = ControlConfig { policy: ControlPolicy::ScheduleCoupled, ..Default::default() };
        let env = ScheduleEnv {
            n_elems: 271_690,
            n_ranks: 256,
            topology: crate::comm::Dragonfly::for_nodes(256),
            ..ScheduleEnv::default()
        };
        let ctl = c.build_controller(1, env);
        assert_eq!(ctl.name(), "schedule_coupled");
        // before any observation the configured schedule stands
        assert_eq!(ctl.current().schedule, Some(env.net.algo));
    }

    #[test]
    fn compress_coupled_builds_with_env() {
        let c = ControlConfig { policy: ControlPolicy::CompressCoupled, ..Default::default() };
        let mut env = ScheduleEnv {
            n_elems: 271_690,
            n_ranks: 64,
            topology: crate::comm::Dragonfly::for_nodes(64),
            ..ScheduleEnv::default()
        };
        env.compress = crate::compress::CompressConfig {
            kind: crate::compress::CompressorKind::TopK,
            ratio: 0.05,
            ..Default::default()
        };
        let ctl = c.build_controller(1, env);
        assert_eq!(ctl.name(), "compress_coupled");
        assert_eq!(ctl.current().compress_ratio, Some(0.05));
    }

    #[test]
    fn probe_config_validates_and_builds() {
        let mut c = ControlConfig {
            policy: ControlPolicy::ScheduleCoupled,
            probe: ProbeMode::Interval,
            probe_interval: 4,
            ..Default::default()
        };
        c.validate().unwrap();
        assert_eq!(
            c.probe_cfg(),
            ProbeCfg { mode: ProbeMode::Interval, interval: 4, epsilon: 0.125 }
        );
        c.probe_interval = 0;
        assert!(c.validate().is_err());
        c.probe_interval = 4;
        c.probe_epsilon = 0.0;
        assert!(c.validate().is_err());
        c.probe_epsilon = 1.5;
        assert!(c.validate().is_err());
        // defaults keep probing off — the pre-probing controller
        assert_eq!(ControlConfig::default().probe, ProbeMode::Off);
    }

    #[test]
    fn kill_plans_get_default_snapshot_cadence() {
        let c = ControlConfig { faults: FaultPlan::new().kill(0, 1.0), ..Default::default() };
        assert_eq!(c.snapshot_cadence(), 10);
        let c2 = ControlConfig { snapshot_every: 3, ..c };
        assert_eq!(c2.snapshot_cadence(), 3);
    }
}
