//! Staleness controllers: the policies that pick the window length k
//! (and the compensation strength λ0's scale, and — new — the
//! collective schedule) online.
//!
//! The paper fixes k a priori, but its own Eq. 13/14 analysis says the
//! profitable overlap depth depends on the live ratio t_AR / t_C — a
//! quantity that drifts with stragglers, payload size and topology.
//! Dynamic-SSP (Zhao et al., 1908.11848) shows a bounded online
//! adaptation of k beats any static choice; DC-ASGD (Zheng et al.,
//! 1609.08326) shows the compensation strength must co-adapt with the
//! effective staleness; and Layered SGD (Yu & Yoo 2019) shows t_AR
//! itself is a *choice* — the hierarchical schedule beats the flat ring
//! whenever latency dominates. Five policies:
//!
//! * [`Fixed`] — the paper's static k (the control-plane no-op).
//! * [`DssPid`] — DSSP-style bounded adaptation: drive k toward
//!   ceil(t_AR / t_C) with a PI step of at most ±1 per decision,
//!   clamped to `[k_min, k_max]`.
//! * [`LambdaCoupled`] — [`DssPid`] plus λ0 rescaling ∝ k/k_ref
//!   (stronger compensation at deeper staleness, bounded).
//! * [`ScheduleCoupled`] — [`LambdaCoupled`] plus (a) per-window
//!   collective-schedule selection between the flat fabric model and
//!   the hierarchical dragonfly schedule, from the modelled t_AR of
//!   each candidate confirmed against the observed t_AR, (b)
//!   **straggler quarantine**: a rank whose piggybacked per-step t_C
//!   persistently exceeds the rest is quarantined inside its dragonfly
//!   group — the group keeps the base window while every other rank's
//!   k is boosted, so healthy ranks fill the straggler's extra wall
//!   time with useful local steps instead of blocking in the wait —
//!   and (c) **online schedule probing** ([`ProbeMode`]): every
//!   `probe_interval` windows the *inactive* candidate runs for one
//!   window (or an ε-greedy bandit alternates the arms), its observed
//!   phase split folds into that candidate's α-β calibration with EWMA
//!   decay, and the decision trace records the excursion as a
//!   [`Decision::probe`] — so fabric drift can no longer silently
//!   invalidate the schedule the controller isn't watching.
//! * [`CompressCoupled`] — [`ScheduleCoupled`] plus per-window
//!   **compression-ratio** selection: when the observed t_AR
//!   persistently overshoots the window's k·t_C hiding budget the
//!   top-k ratio halves (more compression), relaxing back once the
//!   wire is comfortably hidden, with the schedule candidates priced
//!   at the *compressed* wire volume.
//!
//! Determinism contract: every worker runs its own controller instance,
//! but all instances must make **identical decisions** — the engines
//! feed them the *cross-rank* observations carried on the collective
//! itself (see `algo::dcs3gd`): the all-reduced tail hands every rank
//! the same mean t_C / t_AR and the same per-rank t_C vector, so
//! identical inputs ⇒ identical (k, schedule, quarantine) on every
//! rank ⇒ the rendezvous rounds stay matched. Controllers must
//! therefore be pure functions of their observation history (no RNG,
//! no wall clock).
//!
//! Membership epochs extend the contract: at an epoch transition the
//! engine **rebuilds** every controller from the config against the new
//! [`ScheduleEnv`] (new world size, refitted topology, new payload
//! width). That re-baselines the t_C/t_AR evidence and re-decides
//! (k, schedule) from the bootstrap models — and it is the only
//! construction under which a joiner's fresh controller and a
//! survivor's controller are guaranteed to agree on every subsequent
//! decision (any carried-over EMA state would diverge them). Any
//! quarantine in force simply lifts: the groups it referenced no longer
//! exist, and a persistent straggler re-earns its quarantine against
//! the new topology within `quarantine_after` windows.

use anyhow::{bail, Result};

use crate::comm::{AllReduceAlgo, Dragonfly, NetModel};
use crate::compress::{ctrl_slots, topk_k, CompressConfig, CompressorKind};

/// What the engine asks the controller after each completed window.
#[derive(Debug, Clone)]
pub struct WindowObs {
    /// Completed-window index (0-based).
    pub window: u64,
    /// Worker-local iteration at the window boundary.
    pub iteration: u64,
    /// Cross-rank mean per-*step* compute time t_C over the window (s).
    pub t_compute: f64,
    /// Cross-rank mean observed collective latency t_AR of the previous
    /// window's all-reduce, post → completion (s). 0 until one has
    /// completed.
    pub t_allreduce: f64,
    /// Per-rank per-step compute time over the window (s), identical on
    /// every rank (each rank's slot rides the all-reduce zero-padded).
    /// Empty when the engine does not piggyback the per-rank split.
    pub per_rank_t_c: Vec<f64>,
    /// Observed phase split of the window's *own* completed collective
    /// (the round's shared [`crate::comm::PhaseTimes`] — identical on
    /// every rank by construction; zero before one completes). The
    /// probing layer's calibration signal: pure collective time,
    /// skew-free.
    pub t_ar_local: f64,
    pub t_ar_global: f64,
    /// The schedule that collective rode (the probe-attribution key;
    /// `None` before the first round, or from engines that do not
    /// thread it).
    pub ran: Option<AllReduceAlgo>,
    /// The completed round rode its schedule as a one-window **probe**
    /// excursion: its t_AR is evidence about the probed candidate, not
    /// about the standing operating point — the k-loop must discount
    /// it instead of reacting to it.
    pub probe: bool,
}

/// An active straggler quarantine: `rank` (in dragonfly group `group`)
/// is persistently slow; its whole group runs the group-local window
/// `k_group` while every other rank runs the boosted [`Decision::k`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Quarantine {
    pub rank: usize,
    pub group: usize,
    /// Window length inside the quarantined group (≤ [`Decision::k`]).
    pub k_group: usize,
}

/// The controller's answer: window length for the next window, a
/// multiplier on the configured λ0, and (for schedule-aware policies)
/// the collective schedule plus any straggler quarantine.
#[derive(Debug, Clone, PartialEq)]
pub struct Decision {
    pub k: usize,
    pub lam_scale: f32,
    /// Collective schedule for the next window's all-reduce; `None`
    /// keeps the configured one.
    pub schedule: Option<AllReduceAlgo>,
    /// Straggler quarantine in force, if any.
    pub quarantine: Option<Quarantine>,
    /// Top-k density for the next window's compressed payload; `None`
    /// keeps the configured operating point (only the
    /// `compress_coupled` policy moves it).
    pub compress_ratio: Option<f32>,
    /// The next window runs [`Decision::schedule`] as a **probe** of a
    /// non-active candidate (one-window excursion, not a switch) — the
    /// trace marker that keeps probe windows out of the
    /// schedule-switch accounting.
    pub probe: bool,
    /// Per-worker window lengths (slot-indexed), for the
    /// heterogeneity-aware policies ([`DynSspStaleness`],
    /// [`SgsStaleness`]) that bound staleness per rank instead of
    /// fleet-wide. `None` = every rank runs [`Decision::k`] (modulo
    /// quarantine). Shared via `Arc`: the vector is identical on every
    /// rank by the determinism contract.
    pub per_rank_k: Option<std::sync::Arc<Vec<usize>>>,
}

impl Decision {
    /// A schedule-agnostic decision (the pre-schedule-aware shape).
    pub fn plain(k: usize, lam_scale: f32) -> Self {
        Decision {
            k,
            lam_scale,
            schedule: None,
            quarantine: None,
            compress_ratio: None,
            probe: false,
            per_rank_k: None,
        }
    }

    /// The window length `rank` runs. Per-rank bounds take precedence
    /// (the general heterogeneity-aware policy); the group-granular
    /// quarantine is the special case that survives for policies
    /// without per-rank bounds.
    pub fn k_for(&self, rank: usize, nodes_per_group: usize) -> usize {
        if let Some(ks) = &self.per_rank_k {
            if let Some(&k) = ks.get(rank) {
                return k;
            }
        }
        match self.quarantine {
            Some(q) if rank / nodes_per_group.max(1) == q.group => q.k_group,
            _ => self.k,
        }
    }

    /// One-line `key=value` summary for the obs journal's `decision`
    /// event detail (space-separated so the trace analyzer can split
    /// it back into fields).
    pub fn describe(&self) -> String {
        let mut s = format!("k={} lam_scale={}", self.k, self.lam_scale);
        if let Some(a) = self.schedule {
            s.push_str(&format!(" sched={}", a.name()));
        }
        if let Some(q) = &self.quarantine {
            s.push_str(&format!(" quarantine=g{}", q.group));
        }
        if let Some(r) = self.compress_ratio {
            s.push_str(&format!(" ratio={r}"));
        }
        if self.probe {
            s.push_str(" probe=1");
        }
        s
    }
}

/// A staleness policy. One instance per worker; see the module docs for
/// the determinism contract.
pub trait StalenessController: Send {
    fn name(&self) -> &'static str;

    /// The standing decision, without new observations.
    fn current(&self) -> Decision;

    /// Observe one completed window; returns the decision for the next.
    fn on_window(&mut self, obs: &WindowObs) -> Decision;
}

/// The paper's static policy: k and λ0 never move.
#[derive(Debug, Clone)]
pub struct Fixed {
    k: usize,
}

impl Fixed {
    pub fn new(k: usize) -> Self {
        Fixed { k: k.max(1) }
    }
}

impl StalenessController for Fixed {
    fn name(&self) -> &'static str {
        "fixed"
    }

    fn current(&self) -> Decision {
        Decision::plain(self.k, 1.0)
    }

    fn on_window(&mut self, _obs: &WindowObs) -> Decision {
        self.current()
    }
}

/// DSSP-style bounded adaptation of k with a PI control law.
///
/// One collective per window of k steps overlaps the *next* window's k
/// compute steps, so communication is hidden iff k·t_C ≥ t_AR; the
/// setpoint is k* = t_AR / t_C. Each decision moves k by at most one,
/// within `[k_min, k_max]`, after `adjust_every` windows of evidence —
/// the bounded, hysteretic step that keeps the schedule stable under
/// noisy observations.
#[derive(Debug, Clone)]
pub struct DssPid {
    k: usize,
    k_min: usize,
    k_max: usize,
    gain_p: f64,
    gain_i: f64,
    adjust_every: u64,
    windows_since_adjust: u64,
    integral: f64,
}

impl DssPid {
    pub fn new(
        k_init: usize,
        k_min: usize,
        k_max: usize,
        gain_p: f64,
        gain_i: f64,
        adjust_every: u64,
    ) -> Self {
        let k_min = k_min.max(1);
        let k_max = k_max.max(k_min);
        DssPid {
            k: k_init.clamp(k_min, k_max),
            k_min,
            k_max,
            gain_p,
            gain_i,
            adjust_every: adjust_every.max(1),
            windows_since_adjust: 0,
            integral: 0.0,
        }
    }

    /// The raw setpoint from one observation, clamped to the k bounds.
    fn target(&self, obs: &WindowObs) -> Option<f64> {
        if obs.t_compute <= 0.0 || obs.t_allreduce <= 0.0 {
            return None; // no evidence yet (first window, or a free network)
        }
        Some((obs.t_allreduce / obs.t_compute).clamp(self.k_min as f64, self.k_max as f64))
    }
}

impl StalenessController for DssPid {
    fn name(&self) -> &'static str {
        "dss_pid"
    }

    fn current(&self) -> Decision {
        Decision::plain(self.k, 1.0)
    }

    fn on_window(&mut self, obs: &WindowObs) -> Decision {
        // A probe window's t_AR belongs to the probed candidate, not
        // the standing schedule: folding it into the PI state would
        // make every probe excursion yank k. Discount it — the probing
        // layer owns that evidence.
        if obs.probe {
            return self.current();
        }
        if let Some(target) = self.target(obs) {
            let err = target - self.k as f64;
            // Anti-windup clamp: the integral can demand at most a few
            // consecutive unit steps on its own.
            self.integral = (self.integral + err).clamp(-8.0, 8.0);
            self.windows_since_adjust += 1;
            if self.windows_since_adjust >= self.adjust_every {
                let drive = self.gain_p * err + self.gain_i * self.integral;
                if drive >= 0.5 && self.k < self.k_max {
                    self.k += 1;
                    self.windows_since_adjust = 0;
                    self.integral = 0.0;
                } else if drive <= -0.5 && self.k > self.k_min {
                    self.k -= 1;
                    self.windows_since_adjust = 0;
                    self.integral = 0.0;
                }
            }
        }
        self.current()
    }
}

/// [`DssPid`] plus DC-ASGD-style λ co-adaptation: when the effective
/// staleness k moves away from the reference k_ref the workers drift
/// further from the average between corrections, so the compensation
/// base λ0 is rescaled by k/k_ref, clamped to
/// `[lam_scale_min, lam_scale_max]`.
#[derive(Debug, Clone)]
pub struct LambdaCoupled {
    inner: DssPid,
    k_ref: usize,
    lam_scale_min: f32,
    lam_scale_max: f32,
}

impl LambdaCoupled {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        k_init: usize,
        k_min: usize,
        k_max: usize,
        gain_p: f64,
        gain_i: f64,
        adjust_every: u64,
        lam_scale_min: f32,
        lam_scale_max: f32,
    ) -> Self {
        let lam_scale_min = lam_scale_min.max(0.0);
        let lam_scale_max = lam_scale_max.max(lam_scale_min);
        LambdaCoupled {
            inner: DssPid::new(k_init, k_min, k_max, gain_p, gain_i, adjust_every),
            k_ref: k_init.max(1),
            lam_scale_min,
            lam_scale_max,
        }
    }

    fn lam_scale(&self) -> f32 {
        let raw = self.inner.k as f32 / self.k_ref as f32;
        raw.clamp(self.lam_scale_min, self.lam_scale_max)
    }
}

impl StalenessController for LambdaCoupled {
    fn name(&self) -> &'static str {
        "lambda_coupled"
    }

    fn current(&self) -> Decision {
        Decision::plain(self.inner.k, self.lam_scale())
    }

    fn on_window(&mut self, obs: &WindowObs) -> Decision {
        self.inner.on_window(obs);
        self.current()
    }
}

/// When (and whether) the schedule-aware policies run the schedule they
/// are *not* using, to keep its calibration honest.
///
/// The un-probed controller calibrates only the **active** schedule
/// (the only one it observes), so fabric drift silently invalidates
/// the inactive candidate's α-β estimate — and, symmetrically, a
/// candidate whose model has never been validated is trusted on faith.
/// Probing closes that loop, Dynamic-SSP-style: online re-estimation of
/// the synchronization cost is what makes the adaptive schedule pay
/// off.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ProbeMode {
    /// Never probe: switches trust the (calibrated) cost models — the
    /// pre-probing behavior, and the default.
    #[default]
    Off,
    /// Every `probe_interval` windows, run the inactive candidate for
    /// one window and fold its observed phase split into that
    /// candidate's calibration (EWMA decay). Switches then require the
    /// candidate to have been **observed**, not just modelled — an
    /// unvalidated model is never acted on.
    Interval,
    /// Deterministic ε-greedy bandit over the schedules: each window
    /// runs the arm with the lowest calibrated observed cost, except
    /// every ⌈1/ε⌉-th window which explores the other arm. (No RNG —
    /// the exploration cadence is a pure function of the window index,
    /// preserving the cross-rank determinism contract.)
    Bandit,
}

impl ProbeMode {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "off" | "none" => ProbeMode::Off,
            "interval" | "periodic" => ProbeMode::Interval,
            "bandit" | "epsilon" | "eps_greedy" | "eps-greedy" => ProbeMode::Bandit,
            other => bail!("unknown probe mode {other:?} (off | interval | bandit)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ProbeMode::Off => "off",
            ProbeMode::Interval => "interval",
            ProbeMode::Bandit => "bandit",
        }
    }
}

/// The probing knobs handed to the schedule-aware policies (the
/// `control.probe*` config keys).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProbeCfg {
    pub mode: ProbeMode,
    /// Windows between probes ([`ProbeMode::Interval`]).
    pub interval: u64,
    /// Exploration rate of [`ProbeMode::Bandit`] (explores every
    /// ⌈1/ε⌉-th window).
    pub epsilon: f64,
}

impl ProbeCfg {
    /// Probing disabled — the pre-probing controller, verbatim.
    pub fn off() -> Self {
        ProbeCfg { mode: ProbeMode::Off, interval: 8, epsilon: 0.125 }
    }

    /// The probe cadence in windows for the configured mode.
    fn cadence(&self) -> u64 {
        match self.mode {
            ProbeMode::Off => u64::MAX,
            ProbeMode::Interval => self.interval.max(1),
            ProbeMode::Bandit => (1.0 / self.epsilon.clamp(1e-6, 1.0)).round().max(1.0) as u64,
        }
    }
}

impl Default for ProbeCfg {
    fn default() -> Self {
        Self::off()
    }
}

/// Everything the schedule-aware policy needs to price its candidate
/// schedules: the fabric, the topology, and the collective's payload.
/// The default (zero payload/ranks) prices nothing — the policy then
/// simply keeps the configured schedule.
#[derive(Debug, Clone, Copy)]
pub struct ScheduleEnv {
    /// The configured fabric model (its `algo` is the starting
    /// schedule; its α-β pair prices the flat candidates).
    pub net: NetModel,
    /// The dragonfly the hierarchical candidate runs on (also defines
    /// the quarantine group boundaries).
    pub topology: Dragonfly,
    /// All-reduced payload in f32 elements (model + control piggyback).
    pub n_elems: usize,
    pub n_ranks: usize,
    /// The run's `[compress]` operating point — what the
    /// `compress_coupled` policy tunes (and prices schedules at).
    pub compress: CompressConfig,
    /// Residual link-spread asymmetry the *flat* candidates suffer
    /// when the fleet spans more than one dragonfly group:
    /// `min(link_scale_local, link_scale_global) / link_scale_local`
    /// from the resolved hetero profile
    /// ([`crate::config::ExperimentConfig::flat_link_residual`]).
    /// `with_hetero_applied` bakes only the *local* scale into the
    /// flat β, but a flat ring crosses the global optics too — its
    /// bottleneck is the slowest link class. 1.0 when the hetero
    /// subsystem is off or the spread favors no candidate.
    pub flat_link_scale: f64,
}

impl Default for ScheduleEnv {
    fn default() -> Self {
        ScheduleEnv {
            net: NetModel::default(),
            topology: Dragonfly::default(),
            n_elems: 0,
            n_ranks: 0,
            compress: CompressConfig::default(),
            // a derived 0.0 would price flat candidates as infinitely
            // slow — no spread means no asymmetry
            flat_link_scale: 1.0,
        }
    }
}

#[derive(Debug, Clone, Copy)]
struct ActiveQuarantine {
    rank: usize,
    group: usize,
    /// Extra window depth granted to ranks outside the group.
    boost: usize,
    /// Consecutive healthy windows seen since the last slow one.
    healthy_streak: u64,
}

/// [`LambdaCoupled`] plus per-window (schedule, quarantine) selection —
/// the policy that closes the loop DSSP leaves open by adapting only k.
///
/// Schedule: the controller prices the flat-ring and hierarchical
/// candidates on the [`ScheduleEnv`]'s cost models and keeps a
/// per-schedule *calibration* — an EMA of observed t_AR over modelled
/// t_AR, learned for whichever schedule is active. Each window it
/// compares the calibrated costs and switches when the candidate
/// undercuts the active schedule by the hysteresis margin. A schedule
/// that underperforms its model (congested optics, mispriced fabric)
/// therefore gets abandoned on evidence, while post-skew that inflates
/// every schedule's observations equally cancels out of the
/// comparison — the hysteresis keeps noise from flapping the fleet.
///
/// Quarantine: from the piggybacked per-rank t_C vector, a rank whose
/// compute time exceeds `straggler_factor ×` the mean of the others for
/// `quarantine_after` consecutive windows is quarantined inside its
/// dragonfly group: the group keeps the base window while everyone
/// else's k is boosted by `round(k·(slowdown − 1))` (clamped to k_max),
/// so the healthy ranks spend the straggler's extra wall time computing
/// instead of blocked. The quarantine lifts after `quarantine_after`
/// consecutive healthy windows.
#[derive(Debug, Clone)]
pub struct ScheduleCoupled {
    inner: LambdaCoupled,
    env: ScheduleEnv,
    k_max: usize,
    hysteresis: f64,
    straggler_factor: f64,
    quarantine_after: u64,
    active: AllReduceAlgo,
    bootstrapped: bool,
    /// Observed-over-modelled t_AR calibration per candidate (EMA,
    /// learned while that candidate is active; 1.0 until evidence).
    cal_flat: f64,
    cal_hier: f64,
    slow_streak: u64,
    slow_rank: Option<usize>,
    quarantine: Option<ActiveQuarantine>,
    // --- probing (inert when probe.mode == Off) ---
    probe: ProbeCfg,
    /// The schedule the *next* window runs as a probe (None = active).
    probing: Option<AllReduceAlgo>,
    windows_since_probe: u64,
    /// Observed-over-modelled calibration per candidate from completed
    /// rounds' **phase splits** (probe evidence; EWMA with gain
    /// `CAL_GAIN`, 1.0 prior), and whether the candidate has ever been
    /// observed (never-observed arms are not switch-eligible under
    /// probing).
    probe_cal_flat: f64,
    probe_cal_hier: f64,
    seen_flat: bool,
    seen_hier: bool,
}

/// EMA weight of the newest calibration sample.
const CAL_GAIN: f64 = 0.3;

impl ScheduleCoupled {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        k_init: usize,
        k_min: usize,
        k_max: usize,
        gain_p: f64,
        gain_i: f64,
        adjust_every: u64,
        lam_scale_min: f32,
        lam_scale_max: f32,
        env: ScheduleEnv,
        hysteresis: f64,
        straggler_factor: f64,
        quarantine_after: u64,
        probe: ProbeCfg,
    ) -> Self {
        ScheduleCoupled {
            inner: LambdaCoupled::new(
                k_init,
                k_min,
                k_max,
                gain_p,
                gain_i,
                adjust_every,
                lam_scale_min,
                lam_scale_max,
            ),
            env,
            k_max: k_max.max(k_min.max(1)),
            hysteresis: hysteresis.max(0.0),
            straggler_factor: straggler_factor.max(1.0),
            quarantine_after: quarantine_after.max(1),
            active: env.net.algo,
            bootstrapped: false,
            cal_flat: 1.0,
            cal_hier: 1.0,
            slow_streak: 0,
            slow_rank: None,
            quarantine: None,
            probe,
            probing: None,
            windows_since_probe: 0,
            probe_cal_flat: 1.0,
            probe_cal_hier: 1.0,
            seen_flat: false,
            seen_hier: false,
        }
    }

    /// Modelled t_AR of a candidate schedule on this run's payload.
    /// Flat candidates crossing group boundaries carry the residual
    /// link-spread asymmetry (`env.flat_link_scale`): the hierarchical
    /// candidate prices its local/global phases on their own β's, but
    /// a flat schedule rides its single β — which the hetero merge
    /// scaled by the *local* link class only — while actually being
    /// bottlenecked by the slowest link it crosses.
    fn modelled(&self, algo: AllReduceAlgo) -> f64 {
        let mut net = NetModel { algo, ..self.env.net };
        if !Self::is_hier(algo)
            && self.env.topology.groups_spanned(self.env.n_ranks.max(1)) > 1
        {
            net.beta_bytes_per_s *= self.env.flat_link_scale;
        }
        net.allreduce_time(self.env.n_elems, self.env.n_ranks)
    }

    /// The flat and hierarchical candidates (the configured schedule is
    /// always one of them).
    fn candidates(&self) -> (AllReduceAlgo, AllReduceAlgo) {
        let flat = match self.env.net.algo {
            AllReduceAlgo::Hierarchical(_) => AllReduceAlgo::Ring,
            other => other,
        };
        (flat, AllReduceAlgo::Hierarchical(self.env.topology))
    }

    /// Is a candidate the hierarchical arm? (The calibration registers
    /// are keyed flat-vs-hierarchical.)
    fn is_hier(algo: AllReduceAlgo) -> bool {
        matches!(algo, AllReduceAlgo::Hierarchical(_))
    }

    /// Fold a completed round's observed phase split into the
    /// calibration of the schedule it rode — probe evidence and
    /// active-schedule tenure alike keep that candidate's α-β estimate
    /// fresh (EWMA decay, so stale evidence fades).
    fn note_probe_observation(&mut self, obs: &WindowObs) {
        let Some(ran) = obs.ran else { return };
        let observed = obs.t_ar_local + obs.t_ar_global;
        let modelled = self.modelled(ran);
        if observed <= 0.0 || modelled <= 0.0 {
            return;
        }
        let sample = observed / modelled;
        let (cal, seen) = if Self::is_hier(ran) {
            (&mut self.probe_cal_hier, &mut self.seen_hier)
        } else {
            (&mut self.probe_cal_flat, &mut self.seen_flat)
        };
        *cal = (1.0 - CAL_GAIN) * *cal + CAL_GAIN * sample;
        *seen = true;
    }

    /// A candidate's calibrated cost under probing, and whether it has
    /// ever been observed.
    fn probed_cost(&self, algo: AllReduceAlgo) -> (f64, bool) {
        if Self::is_hier(algo) {
            (self.probe_cal_hier * self.modelled(algo), self.seen_hier)
        } else {
            (self.probe_cal_flat * self.modelled(algo), self.seen_flat)
        }
    }

    /// Probing (interval mode) switch rule: never act on an unvalidated
    /// model. The active schedule holds until the candidate has been
    /// *observed* (via a probe, or an earlier tenure kept fresh by
    /// probes) and its calibrated cost undercuts the active schedule's
    /// by the hysteresis margin.
    fn pick_schedule_probed(&mut self) {
        self.bootstrapped = true; // probing never trusts the raw argmin
        let (flat, hier) = self.candidates();
        let other = if Self::is_hier(self.active) { flat } else { hier };
        let (eff_active, _) = self.probed_cost(self.active);
        let (eff_other, other_seen) = self.probed_cost(other);
        if other_seen && eff_active > 0.0 && eff_other * (1.0 + self.hysteresis) < eff_active {
            self.active = other;
        }
    }

    /// Bandit greedy step: run the *observed* arm with the lowest
    /// calibrated cost (exploration keeps both estimates fresh, so the
    /// greedy pick is trusted without hysteresis); unobserved arms are
    /// not eligible, and with nothing observed the configured schedule
    /// stands.
    fn pick_schedule_bandit(&mut self) {
        self.bootstrapped = true;
        let (flat, hier) = self.candidates();
        let mut best: Option<(f64, AllReduceAlgo)> = None;
        for arm in [flat, hier] {
            let (cost, seen) = self.probed_cost(arm);
            if !seen {
                continue;
            }
            let better = match best {
                None => true,
                Some((b, _)) => cost < b,
            };
            if better {
                best = Some((cost, arm));
            }
        }
        if let Some((_, algo)) = best {
            self.active = algo;
        }
    }

    /// Arm the next window's probe when the cadence is due: one window
    /// on the non-active arm, marked [`Decision::probe`] so the trace
    /// records an excursion, not a switch. (With exactly two candidate
    /// schedules, interval probing and ε-greedy exploration both
    /// degenerate to "run the other arm".)
    fn schedule_probe(&mut self) {
        self.probing = None;
        if self.probe.mode == ProbeMode::Off || self.env.n_elems == 0 || self.env.n_ranks <= 1 {
            return;
        }
        self.windows_since_probe += 1;
        if self.windows_since_probe < self.probe.cadence() {
            return;
        }
        let (flat, hier) = self.candidates();
        let target = if Self::is_hier(self.active) { flat } else { hier };
        if target != self.active {
            self.probing = Some(target);
            self.windows_since_probe = 0;
        }
    }

    fn pick_schedule(&mut self, obs: &WindowObs) {
        if self.env.n_elems == 0 || self.env.n_ranks <= 1 {
            return; // nothing to price
        }
        match self.probe.mode {
            ProbeMode::Off => self.pick_schedule_modelled(obs),
            ProbeMode::Interval => {
                self.note_probe_observation(obs);
                self.pick_schedule_probed();
            }
            ProbeMode::Bandit => {
                self.note_probe_observation(obs);
                self.pick_schedule_bandit();
            }
        }
    }

    /// The probe-free policy: bootstrap on the raw model argmin, then
    /// calibrate the *active* schedule from the piggybacked observed
    /// t_AR and switch on the hysteresis margin.
    fn pick_schedule_modelled(&mut self, obs: &WindowObs) {
        let (flat, hier) = self.candidates();
        if !self.bootstrapped {
            // First decision: argmin of the raw models (no observation
            // exists yet; ties keep the configured schedule).
            self.bootstrapped = true;
            let (t_flat, t_hier) = (self.modelled(flat), self.modelled(hier));
            if t_hier < t_flat {
                self.active = hier;
            } else if t_flat < t_hier {
                self.active = flat;
            }
            return;
        }
        // Steady state: learn the active schedule's calibration from
        // the observed t_AR, then compare calibrated costs. The switch
        // fires when the candidate undercuts what we are actually
        // paying by the hysteresis margin — so a schedule whose model
        // is optimistic gets abandoned on evidence, in either
        // direction.
        let active_is_hier = matches!(self.active, AllReduceAlgo::Hierarchical(_));
        let m_active = self.modelled(self.active);
        if obs.t_allreduce > 0.0 && m_active > 0.0 {
            let cal = if active_is_hier {
                &mut self.cal_hier
            } else {
                &mut self.cal_flat
            };
            *cal = (1.0 - CAL_GAIN) * *cal + CAL_GAIN * (obs.t_allreduce / m_active);
        }
        let eff_flat = self.cal_flat * self.modelled(flat);
        let eff_hier = self.cal_hier * self.modelled(hier);
        let (other, eff_other, eff_active) = if active_is_hier {
            (flat, eff_flat, eff_hier)
        } else {
            (hier, eff_hier, eff_flat)
        };
        if eff_active > 0.0 && eff_other * (1.0 + self.hysteresis) < eff_active {
            self.active = other;
        }
    }

    fn update_quarantine(&mut self, obs: &WindowObs) {
        let t = &obs.per_rank_t_c;
        if t.len() != self.env.n_ranks || self.env.n_ranks < 2 {
            return;
        }
        // Quarantine is group-granular: with every rank in one dragonfly
        // group there is nobody left to boost, only a run to shorten.
        if self.env.topology.groups_spanned(self.env.n_ranks) < 2 {
            return;
        }
        // Slowest rank (ties break to the lowest rank — determinism).
        let mut slow = 0usize;
        for (r, v) in t.iter().enumerate() {
            if *v > t[slow] {
                slow = r;
            }
        }
        let total: f64 = t.iter().sum();
        let rest_mean = (total - t[slow]) / (t.len() - 1) as f64;
        let is_slow = rest_mean > 0.0 && t[slow] > self.straggler_factor * rest_mean;

        if is_slow {
            // Streaks are per-culprit: a different rank restarts them.
            if self.slow_rank != Some(slow) {
                self.slow_rank = Some(slow);
                self.slow_streak = 0;
            }
            self.slow_streak += 1;
            if let Some(q) = &mut self.quarantine {
                q.healthy_streak = 0;
            }
            if self.slow_streak >= self.quarantine_after {
                let base_k = self.inner.inner.k;
                // No headroom above the base window means no boost to
                // hand the healthy ranks — engaging would only log a
                // mitigation that cannot happen.
                let headroom = self.k_max.saturating_sub(base_k);
                if headroom == 0 {
                    self.quarantine = None;
                } else {
                    let slowdown = t[slow] / rest_mean;
                    let boost = ((slowdown - 1.0) * base_k as f64).round().max(1.0) as usize;
                    self.quarantine = Some(ActiveQuarantine {
                        rank: slow,
                        group: self.env.topology.group_of(slow),
                        boost: boost.min(headroom),
                        healthy_streak: 0,
                    });
                }
            }
        } else {
            self.slow_rank = None;
            self.slow_streak = 0;
            if let Some(q) = &mut self.quarantine {
                q.healthy_streak += 1;
                if q.healthy_streak >= self.quarantine_after {
                    self.quarantine = None;
                }
            }
        }
    }
}

impl StalenessController for ScheduleCoupled {
    fn name(&self) -> &'static str {
        "schedule_coupled"
    }

    fn current(&self) -> Decision {
        let mut d = self.inner.current();
        let base_k = d.k;
        d.schedule = Some(self.probing.unwrap_or(self.active));
        d.probe = self.probing.is_some();
        if let Some(q) = &self.quarantine {
            d.k = (base_k + q.boost).min(self.k_max);
            d.quarantine = Some(Quarantine { rank: q.rank, group: q.group, k_group: base_k });
        }
        d
    }

    fn on_window(&mut self, obs: &WindowObs) -> Decision {
        self.inner.on_window(obs);
        self.pick_schedule(obs);
        self.update_quarantine(obs);
        self.schedule_probe();
        self.current()
    }
}

/// [`ScheduleCoupled`] plus per-window **compression-ratio** selection —
/// the policy that co-tunes (k, schedule, ratio) from the live t_C/t_AR
/// evidence.
///
/// The window of k steps hides the collective iff `t_AR ≤ k·t_C`
/// (Eq. 14). When the observed t_AR persistently overshoots that budget
/// by the hysteresis margin — i.e. k alone cannot amortize the wire —
/// the ratio halves (more compression), bounded below by `ratio_min`;
/// when t_AR sits comfortably under half the budget the ratio doubles
/// back toward `ratio_max` (less compression, less error-feedback
/// noise). Streak counters (`adjust_every` consecutive windows of
/// one-sided evidence) keep observation noise from flapping the knob,
/// exactly like the schedule switch's hysteresis.
///
/// The inner schedule choice is priced at the **compressed wire
/// volume**: top-k's sparse all-gather of `2k + 2` elements per rank is
/// folded to its dense-equivalent all-reduce volume `per·N/2` (the two
/// move the same bytes per rank under the flat α-β model), QSGD to
/// `⌈n·bits/32⌉`, so the flat-vs-hierarchical crossover tracks what the
/// fabric actually carries.
///
/// Ratio adaptation engages for [`CompressorKind::TopK`] (the density
/// knob) and [`CompressorKind::Qsgd`] (the 4 ↔ 8 ↔ 16 **bits ladder**:
/// hot evidence steps the quantization down a rung, cold evidence back
/// up, surfaced as `compress_ratio = bits/32` so the codec's
/// [`crate::compress::GradCompressor::set_ratio`] snaps to the rung) —
/// the identity has no knob — and the wire-aware schedule pricing
/// applies to all three kinds. Same determinism contract as every
/// policy: pure function of the observation history.
#[derive(Debug, Clone)]
pub struct CompressCoupled {
    inner: ScheduleCoupled,
    kind: CompressorKind,
    ratio: f32,
    ratio_min: f32,
    ratio_max: f32,
    /// Current rung of the QSGD bits ladder (QSGD runs only).
    bits: u32,
    hysteresis: f64,
    adjust_after: u64,
    hot_streak: u64,
    cold_streak: u64,
    /// Dense payload width (model + piggyback) the wire volumes derive
    /// from.
    dense_elems: usize,
}

/// The QSGD quantization rungs `compress_coupled` walks.
pub const QSGD_BITS_LADDER: [u32; 3] = [4, 8, 16];

/// The nearest ladder rung to an arbitrary bit width (ties take the
/// smaller rung — more compression).
pub fn snap_qsgd_bits(bits: u32) -> u32 {
    *QSGD_BITS_LADDER
        .iter()
        .min_by_key(|&&b| (b as i64 - bits as i64).unsigned_abs())
        .unwrap()
}

impl CompressCoupled {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        k_init: usize,
        k_min: usize,
        k_max: usize,
        gain_p: f64,
        gain_i: f64,
        adjust_every: u64,
        lam_scale_min: f32,
        lam_scale_max: f32,
        env: ScheduleEnv,
        hysteresis: f64,
        straggler_factor: f64,
        quarantine_after: u64,
        probe: ProbeCfg,
    ) -> Self {
        let compress = env.compress;
        let ratio = compress.ratio.clamp(compress.ratio_min, compress.ratio_max);
        let mut c = CompressCoupled {
            inner: ScheduleCoupled::new(
                k_init,
                k_min,
                k_max,
                gain_p,
                gain_i,
                adjust_every,
                lam_scale_min,
                lam_scale_max,
                env,
                hysteresis,
                straggler_factor,
                quarantine_after,
                probe,
            ),
            kind: compress.kind,
            ratio,
            ratio_min: compress.ratio_min,
            ratio_max: compress.ratio_max,
            bits: snap_qsgd_bits(compress.bits),
            hysteresis: hysteresis.max(0.0),
            adjust_after: adjust_every.max(1),
            hot_streak: 0,
            cold_streak: 0,
            dense_elems: env.n_elems,
        };
        // The config's rung must respect the ratio band like every
        // other knob: a 16-bit config under ratio_max = 0.25 would
        // otherwise surface compress_ratio = 0.5 — outside the bounds
        // the operator asked for.
        c.bits = c.clamp_bits_to_band(c.bits);
        c.inner.env.n_elems = c.wire_pricing_elems();
        c
    }

    /// Model width without the control piggyback.
    fn model_elems(&self) -> usize {
        self.dense_elems.saturating_sub(ctrl_slots(self.inner.env.n_ranks)).max(1)
    }

    /// Dense-equivalent all-reduce volume of the current operating
    /// point, for the inner schedule comparison.
    fn wire_pricing_elems(&self) -> usize {
        let n = self.model_elems();
        let ranks = self.inner.env.n_ranks.max(1);
        match self.kind {
            CompressorKind::None => self.dense_elems,
            CompressorKind::TopK => {
                // all-gather of `per` per rank moves (N−1)·per bytes —
                // the same as a ring all-reduce of per·N/2.
                let per = 2 * topk_k(n, self.ratio) + crate::compress::CTRL_BASE_SLOTS;
                (per * ranks).div_ceil(2).max(1)
            }
            CompressorKind::Qsgd => {
                // Priced at the *current* ladder rung, not the config
                // constant — the schedule comparison must track what
                // the fabric actually carries.
                crate::compress::qsgd::qsgd_wire_elems(n, self.bits) + ctrl_slots(ranks)
            }
        }
    }

    /// Whether a rung's wire ratio `bits/32` sits inside the configured
    /// `[ratio_min, ratio_max]` band (epsilon so a bound is itself a
    /// legal rung).
    fn bits_allowed(&self, bits: u32) -> bool {
        const EPS: f32 = 1e-6;
        let r = bits as f32 / 32.0;
        r >= self.ratio_min - EPS && r <= self.ratio_max + EPS
    }

    /// Nearest in-band rung to `bits` (ties take the smaller rung, like
    /// [`snap_qsgd_bits`]). A band that excludes every rung degrades to
    /// the rung nearest the band's midpoint — the ladder then has one
    /// rung and never moves.
    fn clamp_bits_to_band(&self, bits: u32) -> u32 {
        let nearest = QSGD_BITS_LADDER
            .iter()
            .copied()
            .filter(|&b| self.bits_allowed(b))
            .min_by_key(|&b| (b as i64 - bits as i64).unsigned_abs());
        nearest.unwrap_or_else(|| {
            let mid = 32.0 * 0.5 * (self.ratio_min + self.ratio_max);
            snap_qsgd_bits(mid.round().max(2.0) as u32)
        })
    }

    /// One rung down (hot) or up (cold) the QSGD bits ladder, refusing
    /// any rung whose wire ratio leaves `[ratio_min, ratio_max]`.
    fn step_bits(&mut self, down: bool) -> bool {
        let pos = QSGD_BITS_LADDER.iter().position(|&b| b == self.bits).unwrap_or(1);
        let next = if down {
            pos.checked_sub(1)
        } else {
            (pos + 1 < QSGD_BITS_LADDER.len()).then_some(pos + 1)
        };
        match next {
            Some(p) if self.bits_allowed(QSGD_BITS_LADDER[p]) => {
                self.bits = QSGD_BITS_LADDER[p];
                true
            }
            _ => false,
        }
    }

    fn adapt_ratio(&mut self, obs: &WindowObs) {
        if self.kind == CompressorKind::None {
            return;
        }
        if obs.t_compute <= 0.0 || obs.t_allreduce <= 0.0 {
            return;
        }
        let k = self.inner.inner.inner.k.max(1) as f64;
        let budget = k * obs.t_compute; // compute available to hide t_AR
        if obs.t_allreduce > (1.0 + self.hysteresis) * budget {
            self.cold_streak = 0;
            self.hot_streak += 1;
            if self.hot_streak >= self.adjust_after {
                let moved = match self.kind {
                    CompressorKind::TopK if self.ratio > self.ratio_min => {
                        self.ratio = (self.ratio * 0.5).max(self.ratio_min);
                        true
                    }
                    CompressorKind::Qsgd => self.step_bits(true),
                    _ => false,
                };
                if moved {
                    self.hot_streak = 0;
                }
            }
        } else if obs.t_allreduce < (1.0 - self.hysteresis) * 0.5 * budget {
            self.hot_streak = 0;
            self.cold_streak += 1;
            if self.cold_streak >= self.adjust_after {
                let moved = match self.kind {
                    CompressorKind::TopK if self.ratio < self.ratio_max => {
                        self.ratio = (self.ratio * 2.0).min(self.ratio_max);
                        true
                    }
                    CompressorKind::Qsgd => self.step_bits(false),
                    _ => false,
                };
                if moved {
                    self.cold_streak = 0;
                }
            }
        } else {
            self.hot_streak = 0;
            self.cold_streak = 0;
        }
    }
}

impl StalenessController for CompressCoupled {
    fn name(&self) -> &'static str {
        "compress_coupled"
    }

    fn current(&self) -> Decision {
        let mut d = self.inner.current();
        match self.kind {
            CompressorKind::TopK => d.compress_ratio = Some(self.ratio),
            // bits/32 is QSGD's wire ratio; the codec's `set_ratio`
            // snaps it back to the rung.
            CompressorKind::Qsgd => d.compress_ratio = Some(self.bits as f32 / 32.0),
            CompressorKind::None => {}
        }
        d
    }

    fn on_window(&mut self, obs: &WindowObs) -> Decision {
        self.adapt_ratio(obs);
        // Re-price the schedule candidates at the (possibly new) wire
        // volume before the inner policy compares them.
        self.inner.env.n_elems = self.wire_pricing_elems();
        self.inner.on_window(obs);
        self.current()
    }
}

/// Dynamic SSP (Zhao et al., 1908.11848 §4): **per-worker** dynamic
/// staleness bounds, the generalization of [`DssPid`] to heterogeneous
/// fleets. The wrapped policy still drives the *fleet-mean* window k
/// (and schedule / ratio / quarantine, if it is one of the coupled
/// policies); on top of it, the per-rank t_C vector piggybacked on the
/// collective sets each rank's own bound
///
/// ```text
/// k_i = round(k · t̄_C / t_C,i)  clamped to [k_min, k_max]
/// ```
///
/// — slow ranks run fewer local steps, fast ranks fill the same wall
/// time with more, and the rendezvous stays matched because every rank
/// still posts every round. The group-granular straggler quarantine is
/// the degenerate case (one slow rank, k_i pinned at the base window);
/// here every rank gets a bound, continuously. Same determinism
/// contract: the per-rank vector is a pure function of the shared
/// observations, so every rank computes the identical bounds.
pub struct DynSspStaleness {
    inner: Box<dyn StalenessController>,
    n_ranks: usize,
    k_min: usize,
    k_max: usize,
    per_rank: Option<std::sync::Arc<Vec<usize>>>,
}

impl DynSspStaleness {
    pub fn new(
        inner: Box<dyn StalenessController>,
        n_ranks: usize,
        k_min: usize,
        k_max: usize,
    ) -> Self {
        let k_min = k_min.max(1);
        DynSspStaleness { inner, n_ranks, k_min, k_max: k_max.max(k_min), per_rank: None }
    }
}

impl StalenessController for DynSspStaleness {
    fn name(&self) -> &'static str {
        "dyn_ssp"
    }

    fn current(&self) -> Decision {
        let mut d = self.inner.current();
        d.per_rank_k = self.per_rank.clone();
        d
    }

    fn on_window(&mut self, obs: &WindowObs) -> Decision {
        let d = self.inner.on_window(obs);
        // Probe windows leave the bounds standing (same discount rule
        // as the k-loop); otherwise re-derive them from the fresh
        // per-rank compute split.
        let t = &obs.per_rank_t_c;
        if !obs.probe && t.len() == self.n_ranks && t.iter().all(|&v| v > 0.0) {
            let mean = t.iter().sum::<f64>() / t.len() as f64;
            let ks: Vec<usize> = t
                .iter()
                .map(|&tc| {
                    ((d.k as f64 * mean / tc).round() as usize).clamp(self.k_min, self.k_max)
                })
                .collect();
            self.per_rank = Some(std::sync::Arc::new(ks));
        }
        self.current()
    }
}

/// Stochastic Gradient Staleness (2509.05679): **randomized** staleness
/// as a design point — each window, each rank draws its local step
/// count uniformly from `[k − s, k + s] ∩ [k_min, k_max]` around the
/// wrapped policy's base k, with `s = max(1, k/2)`. The randomization
/// decorrelates the ranks' positions inside the window (the gradient
/// staleness distribution flattens instead of spiking at k), at zero
/// coordination cost.
///
/// The draws come from the **keyed deterministic RNG** on a dedicated
/// stream — a pure function of `(seed, slot, window)` — so every rank
/// derives the identical per-rank vector without communication and the
/// controller stays inside the no-RNG-state determinism contract (the
/// generator is counter-based; no mutable entropy survives between
/// windows).
pub struct SgsStaleness {
    inner: Box<dyn StalenessController>,
    seed: u64,
    n_ranks: usize,
    k_min: usize,
    k_max: usize,
    per_rank: Option<std::sync::Arc<Vec<usize>>>,
}

/// Keyed-RNG stream of the SGS draws (disjoint from the hetero and
/// codec streams).
const SGS_STREAM: u64 = 0x5657_AA11;

impl SgsStaleness {
    pub fn new(
        inner: Box<dyn StalenessController>,
        seed: u64,
        n_ranks: usize,
        k_min: usize,
        k_max: usize,
    ) -> Self {
        let k_min = k_min.max(1);
        SgsStaleness { inner, seed, n_ranks, k_min, k_max: k_max.max(k_min), per_rank: None }
    }

    /// The pure draw: rank `slot`'s window length for window `window`
    /// around base `k` — pinned by the determinism tests.
    pub fn draw(
        seed: u64,
        slot: usize,
        window: u64,
        k: usize,
        k_min: usize,
        k_max: usize,
    ) -> usize {
        let s = (k / 2).max(1);
        let lo = k.saturating_sub(s).max(k_min.max(1));
        let hi = (k + s).min(k_max.max(1));
        if hi <= lo {
            return lo;
        }
        let span = (hi - lo + 1) as u64;
        let mut r = crate::util::Rng::keyed(seed ^ SGS_STREAM, slot as u64, window);
        lo + r.below(span) as usize
    }
}

impl StalenessController for SgsStaleness {
    fn name(&self) -> &'static str {
        "sgs"
    }

    fn current(&self) -> Decision {
        let mut d = self.inner.current();
        d.per_rank_k = self.per_rank.clone();
        d
    }

    fn on_window(&mut self, obs: &WindowObs) -> Decision {
        let d = self.inner.on_window(obs);
        let ks: Vec<usize> = (0..self.n_ranks)
            .map(|slot| Self::draw(self.seed, slot, obs.window + 1, d.k, self.k_min, self.k_max))
            .collect();
        self.per_rank = Some(std::sync::Arc::new(ks));
        self.current()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(window: u64, t_c: f64, t_ar: f64) -> WindowObs {
        WindowObs {
            window,
            iteration: window * 4,
            t_compute: t_c,
            t_allreduce: t_ar,
            per_rank_t_c: Vec::new(),
            t_ar_local: 0.0,
            t_ar_global: 0.0,
            ran: None,
            probe: false,
        }
    }

    /// An observation whose completed round rode `algo` at exactly its
    /// modelled phase split — what the engines feed back in-sim.
    fn obs_ran(window: u64, t_c: f64, algo: AllReduceAlgo, env: &ScheduleEnv) -> WindowObs {
        let phases = NetModel { algo, ..env.net }.allreduce_phases(env.n_elems, env.n_ranks);
        WindowObs {
            t_allreduce: phases.total(),
            t_ar_local: phases.local_s,
            t_ar_global: phases.global_s,
            ran: Some(algo),
            ..obs(window, t_c, phases.total())
        }
    }

    fn obs_ranks(window: u64, t_c: f64, t_ar: f64, per_rank: Vec<f64>) -> WindowObs {
        WindowObs { per_rank_t_c: per_rank, ..obs(window, t_c, t_ar) }
    }

    #[test]
    fn fixed_never_moves() {
        let mut c = Fixed::new(3);
        assert_eq!(c.current(), Decision::plain(3, 1.0));
        for w in 0..20 {
            let d = c.on_window(&obs(w, 1e-3, 1.0)); // huge t_AR: would tempt any adaptive policy
            assert_eq!(d, Decision::plain(3, 1.0));
        }
    }

    #[test]
    fn dss_pid_stays_within_bounds() {
        // Absurd ratios in both directions must never push k out of range.
        let mut c = DssPid::new(2, 1, 4, 0.5, 0.1, 1);
        for w in 0..50 {
            let d = c.on_window(&obs(w, 1e-6, 10.0)); // ratio 1e7
            assert!((1..=4).contains(&d.k), "k={} escaped bounds", d.k);
        }
        assert_eq!(c.current().k, 4);
        for w in 50..100 {
            let d = c.on_window(&obs(w, 10.0, 1e-6)); // ratio 1e-7
            assert!((1..=4).contains(&d.k), "k={} escaped bounds", d.k);
        }
        assert_eq!(c.current().k, 1);
    }

    #[test]
    fn dss_pid_moves_monotonically_toward_target() {
        // With a constant ratio of 3, k must climb 1 → 3 one step at a
        // time, never overshoot, and then hold.
        let mut c = DssPid::new(1, 1, 8, 0.5, 0.1, 1);
        let mut ks = Vec::new();
        for w in 0..20 {
            ks.push(c.on_window(&obs(w, 1e-3, 3e-3)).k);
        }
        for pair in ks.windows(2) {
            assert!(pair[1] >= pair[0], "non-monotone approach: {ks:?}");
            assert!(pair[1] - pair[0] <= 1, "jumped more than one: {ks:?}");
        }
        assert_eq!(*ks.last().unwrap(), 3, "did not settle on target: {ks:?}");
        // settled: further identical evidence must not oscillate
        for w in 20..40 {
            assert_eq!(c.on_window(&obs(w, 1e-3, 3e-3)).k, 3);
        }
    }

    #[test]
    fn dss_pid_ignores_empty_evidence() {
        let mut c = DssPid::new(2, 1, 8, 0.5, 0.1, 1);
        for w in 0..10 {
            assert_eq!(c.on_window(&obs(w, 0.0, 0.0)).k, 2);
            assert_eq!(c.on_window(&obs(w, 1e-3, 0.0)).k, 2);
        }
    }

    #[test]
    fn dss_pid_respects_adjust_every() {
        let mut c = DssPid::new(1, 1, 8, 1.0, 0.0, 3);
        let mut changes = 0;
        let mut prev = 1;
        for w in 0..9 {
            let k = c.on_window(&obs(w, 1e-3, 8e-3)).k;
            if k != prev {
                changes += 1;
                prev = k;
            }
        }
        assert!(changes <= 3, "changed {changes}× in 9 windows with adjust_every=3");
    }

    #[test]
    fn lambda_coupled_scales_with_k_and_stays_bounded() {
        let mut c = LambdaCoupled::new(1, 1, 8, 0.5, 0.1, 1, 0.25, 4.0);
        assert_eq!(c.current().lam_scale, 1.0);
        // drive k up; λ scale must track k/k_ref and respect the cap
        let mut last = c.current();
        for w in 0..40 {
            last = c.on_window(&obs(w, 1e-4, 1.0));
            assert!(
                last.lam_scale >= 0.25 && last.lam_scale <= 4.0,
                "λ scale {} out of bounds",
                last.lam_scale
            );
            assert!((last.lam_scale - (last.k as f32).clamp(0.25, 4.0)).abs() < 1e-6);
        }
        assert_eq!(last.k, 8);
        assert_eq!(last.lam_scale, 4.0, "cap must bind at k=8, k_ref=1");
    }

    #[test]
    fn lambda_coupled_scales_down_too() {
        let mut c = LambdaCoupled::new(4, 1, 8, 0.5, 0.1, 1, 0.25, 4.0);
        let mut last = c.current();
        for w in 0..40 {
            last = c.on_window(&obs(w, 1.0, 1e-6));
        }
        assert_eq!(last.k, 1);
        assert_eq!(last.lam_scale, 0.25);
    }

    #[test]
    fn controllers_are_deterministic() {
        // Two instances fed the same stream must agree exactly — the
        // property the rendezvous window schedule rests on.
        let mk = || LambdaCoupled::new(1, 1, 6, 0.5, 0.1, 2, 0.5, 3.0);
        let (mut a, mut b) = (mk(), mk());
        for w in 0..100 {
            let o = obs(w, 1e-3, ((w % 7) as f64 + 1.0) * 1e-3);
            assert_eq!(a.on_window(&o), b.on_window(&o), "diverged at window {w}");
        }
    }

    // --- ScheduleCoupled ---

    fn sched_env(n_elems: usize, n_ranks: usize, beta: f64) -> ScheduleEnv {
        ScheduleEnv {
            net: NetModel { alpha_s: 1.5e-6, beta_bytes_per_s: beta, algo: AllReduceAlgo::Ring },
            topology: Dragonfly::for_nodes(n_ranks),
            n_elems,
            n_ranks,
            compress: CompressConfig::default(),
            flat_link_scale: 1.0,
        }
    }

    fn sc(env: ScheduleEnv) -> ScheduleCoupled {
        ScheduleCoupled::new(1, 1, 8, 0.5, 0.1, 1, 0.25, 4.0, env, 0.1, 1.5, 3, ProbeCfg::off())
    }

    fn sc_probed(env: ScheduleEnv, probe: ProbeCfg) -> ScheduleCoupled {
        ScheduleCoupled::new(1, 1, 8, 0.5, 0.1, 1, 0.25, 4.0, env, 0.1, 1.5, 3, probe)
    }

    #[test]
    fn schedule_coupled_picks_hierarchical_at_scale() {
        // ResNet-20 payload at 256 ranks: the hierarchical model is
        // cheaper (see comm::schedule tests) — the bootstrap pick must
        // switch off the flat ring.
        let mut c = sc(sched_env(271_690, 256, 10e9));
        let d = c.on_window(&obs(0, 1e-3, 0.0));
        assert!(
            matches!(d.schedule, Some(AllReduceAlgo::Hierarchical(_))),
            "picked {:?}",
            d.schedule
        );
    }

    #[test]
    fn schedule_coupled_keeps_ring_when_flat_is_cheaper() {
        // Huge payload at small N: the flat ring's bandwidth optimality
        // wins; the pick must stay on the configured ring.
        let mut c = sc(sched_env(25_600_000, 8, 10e9));
        let d = c.on_window(&obs(0, 1e-3, 0.0));
        assert_eq!(d.schedule, Some(AllReduceAlgo::Ring));
    }

    #[test]
    fn link_spread_residual_prices_flat_candidates_down() {
        // Same scenario where the flat ring wins on symmetric links —
        // but under hetero link spread the flat candidate's β rides
        // the slow global optics (flat_link_scale < 1), so the
        // bootstrap pick must flip to the hierarchical candidate,
        // whose phases price their own link classes.
        let mut env = sched_env(25_600_000, 8, 10e9);
        assert!(env.topology.groups_spanned(8) > 1, "premise: the fleet spans groups");
        env.flat_link_scale = 0.05;
        let mut c = sc(env);
        let d = c.on_window(&obs(0, 1e-3, 0.0));
        assert!(
            matches!(d.schedule, Some(AllReduceAlgo::Hierarchical(_))),
            "picked {:?}",
            d.schedule
        );
        // single-group fleets never cross the optics: the residual
        // must not price anything there
        let mut one_group = sched_env(25_600_000, 8, 10e9);
        one_group.topology = Dragonfly { groups: 1, nodes_per_group: 8, ..Dragonfly::default() };
        one_group.flat_link_scale = 0.05;
        let mut c = sc(one_group);
        let d = c.on_window(&obs(0, 1e-3, 0.0));
        assert_eq!(d.schedule, Some(AllReduceAlgo::Ring));
    }

    #[test]
    fn schedule_coupled_switches_on_observed_evidence() {
        // Models prefer the flat ring (large payload, small N), so the
        // bootstrap picks it — but the *observed* t_AR then comes in
        // ~10× the flat model (say, congested fabric). The calibration
        // must abandon the ring for the hierarchical candidate.
        let env = sched_env(25_600_000, 8, 10e9);
        let flat_net = NetModel { algo: AllReduceAlgo::Ring, ..env.net };
        let t_flat = flat_net.allreduce_time(25_600_000, 8);
        let hier = AllReduceAlgo::Hierarchical(env.topology);
        let t_hier = NetModel { algo: hier, ..env.net }.allreduce_time(25_600_000, 8);
        assert!(t_flat < t_hier, "premise: the model must prefer flat here");
        let mut c = sc(env);
        let d0 = c.on_window(&obs(0, 1e-3, 0.0));
        assert_eq!(d0.schedule, Some(AllReduceAlgo::Ring), "bootstrap argmin");
        // evidence: we are actually paying 10× the flat model
        let mut switched_at = None;
        for w in 1..10 {
            let d = c.on_window(&obs(w, 1e-3, t_flat * 10.0));
            if d.schedule == Some(hier) {
                switched_at = Some(w);
                break;
            }
        }
        let w0 = switched_at.expect("observed evidence never triggered the switch");
        // after the switch, accurate hierarchical observations hold it
        for w in w0 + 1..w0 + 10 {
            let d = c.on_window(&obs(w, 1e-3, t_hier));
            assert_eq!(d.schedule, Some(hier), "flapped back at window {w}");
        }
    }

    #[test]
    fn schedule_coupled_accurate_observations_do_not_flap() {
        // When the active schedule performs exactly as modelled, the
        // hysteresis must keep the bootstrap pick stable forever.
        let env = sched_env(271_690, 256, 10e9); // hier wins the bootstrap
        let hier = AllReduceAlgo::Hierarchical(env.topology);
        let t_hier = NetModel { algo: hier, ..env.net }.allreduce_time(271_690, 256);
        let mut c = sc(env);
        for w in 0..30 {
            let d = c.on_window(&obs(w, 1e-3, t_hier));
            assert_eq!(d.schedule, Some(hier), "flapped at window {w}");
        }
    }

    #[test]
    fn quarantine_engages_after_streak_and_boosts_healthy_ranks() {
        let env = sched_env(10_000, 8, 10e9);
        let mut c =
            ScheduleCoupled::new(2, 1, 8, 0.0, 0.0, 1, 1.0, 1.0, env, 0.1, 1.5, 3, ProbeCfg::off());
        let npg = env.topology.nodes_per_group;
        // rank 5 runs 3× slower than everyone else
        let slow = |w| {
            let mut per = vec![1e-3; 8];
            per[5] = 3e-3;
            obs_ranks(w, 1e-3, 0.0, per)
        };
        // two slow windows: not yet quarantined
        for w in 0..2 {
            assert_eq!(c.on_window(&slow(w)).quarantine, None);
        }
        // third consecutive slow window: quarantine engages
        let d = c.on_window(&slow(2));
        let q = d.quarantine.expect("quarantine after 3 slow windows");
        assert_eq!(q.rank, 5);
        assert_eq!(q.group, env.topology.group_of(5));
        assert_eq!(q.k_group, 2, "quarantined group keeps the base window");
        assert!(d.k > 2, "healthy ranks must get the boost (k = {})", d.k);
        // per-rank view: group members pinned, others boosted
        for r in 0..8 {
            let expect = if r / npg == q.group { q.k_group } else { d.k };
            assert_eq!(d.k_for(r, npg), expect, "rank {r}");
        }
        // healthy windows lift it again after the streak
        let healthy = |w| obs_ranks(w, 1e-3, 0.0, vec![1e-3; 8]);
        assert!(c.on_window(&healthy(3)).quarantine.is_some());
        assert!(c.on_window(&healthy(4)).quarantine.is_some());
        assert_eq!(c.on_window(&healthy(5)).quarantine, None, "quarantine must lift");
    }

    #[test]
    fn quarantine_skipped_when_k_has_no_headroom() {
        // Base k pinned at k_max: there is no boost to hand out, so the
        // quarantine must not engage (and must not be logged as if it
        // mitigated anything).
        let env = sched_env(10_000, 8, 10e9);
        let mut c =
            ScheduleCoupled::new(4, 1, 4, 0.0, 0.0, 1, 1.0, 1.0, env, 0.1, 1.5, 1, ProbeCfg::off());
        let mut per = vec![1e-3; 8];
        per[5] = 5e-3;
        for w in 0..5 {
            let d = c.on_window(&obs_ranks(w, 1e-3, 0.0, per.clone()));
            assert_eq!(d.quarantine, None, "no-op quarantine engaged at window {w}");
            assert_eq!(d.k, 4);
        }
    }

    #[test]
    fn quarantine_streak_resets_when_culprit_changes() {
        let env = sched_env(10_000, 4, 10e9);
        let mut c =
            ScheduleCoupled::new(1, 1, 8, 0.0, 0.0, 1, 1.0, 1.0, env, 0.1, 1.5, 2, ProbeCfg::off());
        let mk = |w, slow_rank: usize| {
            let mut per = vec![1e-3; 4];
            per[slow_rank] = 5e-3;
            obs_ranks(w, 1e-3, 0.0, per)
        };
        assert_eq!(c.on_window(&mk(0, 1)).quarantine, None);
        // culprit changes: streak restarts, still no quarantine
        assert_eq!(c.on_window(&mk(1, 2)).quarantine, None);
        // same culprit twice in a row now trips it
        let d = c.on_window(&mk(2, 2)).quarantine.expect("streak complete");
        assert_eq!(d.rank, 2);
    }

    #[test]
    fn schedule_coupled_is_deterministic() {
        let env = sched_env(271_690, 64, 10e9);
        let mk = || sc(env);
        let (mut a, mut b) = (mk(), mk());
        for w in 0..100 {
            let mut per = vec![1e-3; 64];
            per[(w % 64) as usize] *= 1.0 + (w % 5) as f64;
            let o = obs_ranks(w, 1e-3, ((w % 7) as f64 + 1.0) * 1e-3, per.clone());
            assert_eq!(a.on_window(&o), b.on_window(&o), "diverged at window {w}");
        }
    }

    // --- probing ---

    fn probe_interval(interval: u64) -> ProbeCfg {
        ProbeCfg { mode: ProbeMode::Interval, interval, epsilon: 0.125 }
    }

    /// Drive a controller the way an engine does: each window's
    /// observation carries the phase split of the round that rode the
    /// *previous* decision's schedule.
    fn drive(c: &mut ScheduleCoupled, env: &ScheduleEnv, windows: u64) -> Vec<Decision> {
        let mut d = c.current();
        let mut trace = Vec::new();
        for w in 0..windows {
            let mut o = obs_ran(w, 1e-4, d.schedule.expect("schedule-aware"), env);
            o.probe = d.probe; // the round rode the previous decision
            d = c.on_window(&o);
            trace.push(d.clone());
        }
        trace
    }

    #[test]
    fn probe_mode_parse_roundtrip() {
        for m in [ProbeMode::Off, ProbeMode::Interval, ProbeMode::Bandit] {
            assert_eq!(ProbeMode::parse(m.name()).unwrap(), m);
        }
        assert_eq!(ProbeMode::parse("EPS-GREEDY").unwrap(), ProbeMode::Bandit);
        assert!(ProbeMode::parse("sometimes").is_err());
    }

    #[test]
    fn interval_probe_fires_on_cadence_and_triggers_the_switch() {
        // Models prefer hierarchical at this scale, but under probing
        // the controller refuses to act on the unvalidated model: it
        // holds the configured ring until the scheduled probe observes
        // the candidate, then switches on the probe's evidence.
        let env = sched_env(271_690, 256, 10e9);
        let hier = AllReduceAlgo::Hierarchical(env.topology);
        let mut c = sc_probed(env, probe_interval(3));
        let trace = drive(&mut c, &env, 8);
        // windows 0-1: ring, no probe (cadence not yet due)
        for d in &trace[..2] {
            assert_eq!(d.schedule, Some(AllReduceAlgo::Ring), "switched without evidence");
            assert!(!d.probe);
        }
        // 3rd decision: the probe excursion onto the inactive candidate
        assert!(trace[2].probe, "probe never fired: {trace:?}");
        assert_eq!(trace[2].schedule, Some(hier));
        // next decision: the probe's observation validated the model —
        // the switch lands, and it is NOT marked as a probe
        assert_eq!(trace[3].schedule, Some(hier), "probe evidence did not trigger the switch");
        assert!(!trace[3].probe);
        // steady state: active hier, periodic probes of the flat arm
        let late_probes = trace[3..].iter().filter(|d| d.probe).collect::<Vec<_>>();
        assert!(late_probes.iter().all(|d| d.schedule == Some(AllReduceAlgo::Ring)));
        assert!(
            trace[3..].iter().filter(|d| !d.probe).all(|d| d.schedule == Some(hier)),
            "flapped after the probe-triggered switch: {trace:?}"
        );
    }

    #[test]
    fn interval_probe_never_switches_without_observation() {
        // Same hier-favorable env, but the adversary never lets a hier
        // observation arrive (obs.ran stays ring): the unvalidated
        // candidate must never be switched to, however good its model.
        let env = sched_env(271_690, 256, 10e9);
        let mut c = sc_probed(env, probe_interval(4));
        let mut last = c.current();
        for w in 0..20 {
            let o = obs_ran(w, 1e-4, AllReduceAlgo::Ring, &env);
            last = c.on_window(&o);
            if !last.probe {
                assert_eq!(
                    last.schedule,
                    Some(AllReduceAlgo::Ring),
                    "switched to an arm it never observed (window {w})"
                );
            }
        }
        assert_eq!(last.schedule.map(|s| s.name()), Some("ring"));
    }

    #[test]
    fn probe_validates_contended_fabric_and_holds_the_ring() {
        // Same payload and scale where the DEDICATED hierarchical arm
        // wins (see interval_probe_fires_on_cadence_...), but on a
        // taper-1 fabric: the contention-aware pricing puts the
        // contended leader ring above the flat ring, so the probes must
        // observe the hierarchical arm, feed its calibration, and *not*
        // switch — the decision the dedicated-optics model would have
        // gotten wrong.
        let mut env = sched_env(271_690, 256, 10e9);
        env.topology = Dragonfly { global_taper: 1, ..env.topology };
        let hier = AllReduceAlgo::Hierarchical(env.topology);
        let t_ring = NetModel { algo: AllReduceAlgo::Ring, ..env.net }
            .allreduce_time(env.n_elems, env.n_ranks);
        let t_hier = NetModel { algo: hier, ..env.net }.allreduce_time(env.n_elems, env.n_ranks);
        assert!(t_hier > t_ring, "premise: contention must price hier above the ring");
        let mut c = sc_probed(env, probe_interval(2));
        let trace = drive(&mut c, &env, 12);
        assert!(trace.iter().any(|d| d.probe), "probes never fired");
        for d in trace.iter().filter(|d| !d.probe) {
            assert_eq!(d.schedule, Some(AllReduceAlgo::Ring), "probe flapped the fleet");
        }
    }

    #[test]
    fn bandit_explores_and_settles_on_the_cheaper_arm() {
        let env = sched_env(271_690, 256, 10e9);
        let hier = AllReduceAlgo::Hierarchical(env.topology);
        let probe = ProbeCfg { mode: ProbeMode::Bandit, interval: 8, epsilon: 0.5 };
        let mut c = sc_probed(env, probe);
        let trace = drive(&mut c, &env, 12);
        assert!(trace.iter().any(|d| d.probe), "bandit never explored");
        // once both arms are observed the greedy pick is the cheaper
        // hierarchical arm on every non-exploration window
        let first_hier = trace
            .iter()
            .position(|d| !d.probe && d.schedule == Some(hier))
            .expect("bandit never adopted the cheaper arm");
        for d in trace[first_hier..].iter().filter(|d| !d.probe) {
            assert_eq!(d.schedule, Some(hier));
        }
    }

    #[test]
    fn probing_controllers_are_deterministic() {
        let env = sched_env(271_690, 64, 10e9);
        for mode in [ProbeMode::Interval, ProbeMode::Bandit] {
            let mk = || sc_probed(env, ProbeCfg { mode, interval: 3, epsilon: 0.25 });
            let (mut a, mut b) = (mk(), mk());
            let mut d = a.current();
            for w in 0..60 {
                let o = obs_ran(w, 1e-4, d.schedule.unwrap(), &env);
                d = a.on_window(&o);
                assert_eq!(d, b.on_window(&o), "{mode:?} diverged at window {w}");
            }
        }
    }

    #[test]
    fn compress_coupled_passes_probe_decisions_through() {
        let env = cc_env(271_690, 256, 0.05);
        let mut c = CompressCoupled::new(
            1,
            1,
            8,
            0.5,
            0.1,
            1,
            0.25,
            4.0,
            env,
            0.1,
            1.5,
            3,
            probe_interval(2),
        );
        let mut d = c.current();
        let mut saw_probe = false;
        for w in 0..10 {
            let o = obs_ran(w, 1e-4, d.schedule.unwrap(), &env);
            d = c.on_window(&o);
            saw_probe |= d.probe;
            assert!(d.compress_ratio.is_some(), "ratio knob lost under probing");
        }
        assert!(saw_probe, "probe flag never surfaced through compress_coupled");
    }

    // --- CompressCoupled ---

    fn cc_env(n_elems: usize, n_ranks: usize, ratio: f32) -> ScheduleEnv {
        let mut env = sched_env(n_elems, n_ranks, 10e9);
        env.compress = CompressConfig {
            kind: CompressorKind::TopK,
            ratio,
            ratio_min: 0.005,
            ratio_max: 0.25,
            ..CompressConfig::default()
        };
        env
    }

    fn cc(env: ScheduleEnv) -> CompressCoupled {
        CompressCoupled::new(1, 1, 4, 0.0, 0.0, 1, 1.0, 1.0, env, 0.1, 1.5, 3, ProbeCfg::off())
    }

    #[test]
    fn compress_coupled_halves_ratio_when_t_ar_dominates() {
        let mut c = cc(cc_env(10_000, 8, 0.1));
        assert_eq!(c.current().compress_ratio, Some(0.1));
        // t_AR 100× the window budget: the ratio must walk down to the
        // floor, one halving per window (adjust_every = 1).
        let mut ratios = Vec::new();
        for w in 0..8 {
            ratios.push(c.on_window(&obs(w, 1e-3, 0.1)).compress_ratio.unwrap());
        }
        assert!(ratios[0] < 0.1, "first halving never fired: {ratios:?}");
        for pair in ratios.windows(2) {
            assert!(pair[1] <= pair[0], "ratio must be monotone under hot evidence");
        }
        assert_eq!(*ratios.last().unwrap(), 0.005, "must settle on ratio_min: {ratios:?}");
    }

    #[test]
    fn compress_coupled_relaxes_ratio_when_comm_is_hidden() {
        let mut c = cc(cc_env(10_000, 8, 0.02));
        // t_AR far under half the budget: ratio doubles toward the cap.
        let mut last = c.current();
        for w in 0..8 {
            last = c.on_window(&obs(w, 1e-3, 1e-6));
        }
        assert_eq!(last.compress_ratio, Some(0.25), "must relax to ratio_max");
    }

    #[test]
    fn compress_coupled_holds_ratio_inside_the_hysteresis_band() {
        let mut c = cc(cc_env(10_000, 8, 0.05));
        // t_AR exactly at the window budget (k = 1, t_C = 1 ms): inside
        // the band, the knob must not move.
        for w in 0..20 {
            let d = c.on_window(&obs(w, 1e-3, 1e-3));
            assert_eq!(d.compress_ratio, Some(0.05), "flapped at window {w}");
        }
    }

    #[test]
    fn compress_coupled_keeps_schedule_and_k_machinery() {
        // The inner (k, schedule) loops stay live: a slow network must
        // still deepen k, and the decision carries a schedule.
        let env = cc_env(271_690, 256, 0.05);
        let probe = ProbeCfg::off();
        let mut c =
            CompressCoupled::new(1, 1, 8, 0.5, 0.1, 1, 0.25, 4.0, env, 0.1, 1.5, 3, probe);
        let mut last = c.current();
        assert!(last.schedule.is_some());
        for w in 0..20 {
            last = c.on_window(&obs(w, 1e-4, 5e-3));
        }
        assert!(last.k > 1, "k adaptation lost under compress_coupled");
        assert!(last.compress_ratio.is_some());
    }

    #[test]
    fn compress_coupled_is_inert_for_the_identity_kind() {
        // Only the identity has no knob left: top-k walks its density,
        // QSGD its bits ladder.
        let env = sched_env(10_000, 8, 10e9); // kind = None by default
        let mut c = cc(env);
        for w in 0..5 {
            assert_eq!(c.on_window(&obs(w, 1e-3, 10.0)).compress_ratio, None);
        }
    }

    fn qsgd_env(bits: u32) -> ScheduleEnv {
        let mut env = sched_env(10_000, 8, 10e9);
        // Open the ratio band to the full ladder (16 bits = wire ratio
        // 0.5); band clamping has its own test below.
        env.compress = CompressConfig {
            kind: CompressorKind::Qsgd,
            bits,
            ratio_max: 0.5,
            ..CompressConfig::default()
        };
        env
    }

    #[test]
    fn compress_coupled_walks_the_qsgd_bits_ladder_down_when_hot() {
        let mut c = cc(qsgd_env(16));
        assert_eq!(c.current().compress_ratio, Some(0.5));
        // t_AR far above the window budget: 16 → 8 → 4, one rung per
        // window (adjust_every = 1), then pinned at the bottom rung.
        let mut ratios = Vec::new();
        for w in 0..5 {
            ratios.push(c.on_window(&obs(w, 1e-3, 10.0)).compress_ratio.unwrap());
        }
        assert_eq!(&ratios[..3], &[0.25, 0.125, 0.125]);
        assert_eq!(*ratios.last().unwrap(), 0.125, "must pin at 4 bits: {ratios:?}");
    }

    #[test]
    fn compress_coupled_relaxes_the_qsgd_bits_ladder_when_cold() {
        let mut c = cc(qsgd_env(4));
        let mut last = c.current();
        for w in 0..5 {
            last = c.on_window(&obs(w, 1e-3, 1e-9));
        }
        assert_eq!(last.compress_ratio, Some(0.5), "must relax back to 16 bits");
    }

    #[test]
    fn qsgd_ladder_respects_the_ratio_band() {
        // Default band caps at ratio_max = 0.25: a 16-bit config (wire
        // ratio 0.5) must clamp into the band at init, and no amount of
        // cold evidence may relax the ladder past the cap — the
        // regression where `current()` surfaced 0.5 and the codec's
        // `set_ratio` snapped it right back out of bounds.
        let mut env = sched_env(10_000, 8, 10e9);
        env.compress =
            CompressConfig { kind: CompressorKind::Qsgd, bits: 16, ..CompressConfig::default() };
        let (lo, hi) = (env.compress.ratio_min, env.compress.ratio_max);
        let mut c = cc(env);
        assert_eq!(c.current().compress_ratio, Some(0.25), "16 bits must clamp into the band");
        for w in 0..6 {
            let r = c.on_window(&obs(w, 1e-3, 1e-9)).compress_ratio.unwrap();
            assert!(r >= lo - 1e-6 && r <= hi + 1e-6, "window {w}: ratio {r} left [{lo}, {hi}]");
        }
        for w in 6..12 {
            let r = c.on_window(&obs(w, 1e-3, 10.0)).compress_ratio.unwrap();
            assert!(r >= lo - 1e-6 && r <= hi + 1e-6, "window {w}: ratio {r} left [{lo}, {hi}]");
        }
        assert_eq!(c.current().compress_ratio, Some(0.125), "must pin at the lowest in-band rung");

        // A band excluding every rung degrades to a single nearest rung
        // that never moves.
        let mut env = sched_env(10_000, 8, 10e9);
        env.compress = CompressConfig {
            kind: CompressorKind::Qsgd,
            bits: 8,
            ratio_min: 0.01,
            ratio_max: 0.02,
            ..CompressConfig::default()
        };
        let mut c = cc(env);
        assert_eq!(c.current().compress_ratio, Some(0.125));
        for w in 0..4 {
            assert_eq!(c.on_window(&obs(w, 1e-3, 1e-9)).compress_ratio, Some(0.125));
        }
    }

    #[test]
    fn qsgd_ladder_snaps_odd_config_bits_to_a_rung() {
        assert_eq!(snap_qsgd_bits(2), 4);
        assert_eq!(snap_qsgd_bits(5), 4);
        assert_eq!(snap_qsgd_bits(7), 8);
        assert_eq!(snap_qsgd_bits(11), 8);
        assert_eq!(snap_qsgd_bits(13), 16);
        assert_eq!(snap_qsgd_bits(16), 16);
        let mut c = cc(qsgd_env(6));
        assert_eq!(c.current().compress_ratio, Some(0.125), "6 bits snaps to 4");
        let _ = c.on_window(&obs(0, 1e-3, 1e-3));
    }

    // --- probe-tagged observations (the DssPid discount) ---

    #[test]
    fn dss_pid_discounts_probe_windows() {
        let mk = || DssPid::new(1, 1, 8, 0.5, 0.1, 1);
        let (mut probed, mut clean) = (mk(), mk());
        // Interleave: the probed controller sees every odd window as a
        // probe excursion with a wildly different t_AR; the clean one
        // sees only the even windows. Their k trajectories must agree —
        // the probe windows contribute nothing to the PI state.
        for w in 0..20 {
            let o = obs(w, 1e-3, 3e-3);
            let kp = probed.on_window(&o).k;
            let kc = clean.on_window(&o).k;
            assert_eq!(kp, kc, "diverged at window {w}");
            let probe_obs = WindowObs { probe: true, ..obs(w, 1e-3, 50.0) };
            assert_eq!(
                probed.on_window(&probe_obs).k,
                kp,
                "probe excursion moved k at window {w}"
            );
        }
        assert_eq!(probed.current().k, 3, "must still settle on the true target");
    }

    // --- DynSsp: per-worker dynamic staleness bounds ---

    fn obs_probe(window: u64, t_c: f64, t_ar: f64, per_rank: Vec<f64>) -> WindowObs {
        WindowObs { probe: true, ..obs_ranks(window, t_c, t_ar, per_rank) }
    }

    fn dyn_ssp(n_ranks: usize) -> DynSspStaleness {
        DynSspStaleness::new(Box::new(DssPid::new(2, 1, 8, 0.5, 0.1, 1)), n_ranks, 1, 8)
    }

    #[test]
    fn dyn_ssp_bounds_scale_inversely_with_per_rank_compute() {
        let mut c = dyn_ssp(4);
        // ranks 0,1 nominal; rank 2 twice as slow; rank 3 three times.
        let per = vec![1e-3, 1e-3, 2e-3, 3e-3];
        let d = c.on_window(&obs_ranks(0, 1.75e-3, 2e-3, per));
        let ks = d.per_rank_k.as_ref().expect("per-rank bounds");
        assert_eq!(ks.len(), 4);
        assert!(ks[0] > ks[2] && ks[2] >= ks[3], "bounds not inverse to t_C: {ks:?}");
        assert!(ks.iter().all(|&k| (1..=8).contains(&k)), "escaped bounds: {ks:?}");
        // k_for prefers the per-rank bound over the fleet k
        for r in 0..4 {
            assert_eq!(d.k_for(r, 2), ks[r]);
        }
    }

    #[test]
    fn dyn_ssp_holds_bounds_through_probe_windows() {
        let mut c = dyn_ssp(4);
        let per = vec![1e-3, 1e-3, 2e-3, 3e-3];
        let d = c.on_window(&obs_ranks(0, 1.75e-3, 2e-3, per));
        let ks = d.per_rank_k.clone().expect("bounds set");
        // a probe window with a skewed split must not move the bounds
        let d2 = c.on_window(&obs_probe(1, 1.75e-3, 2e-3, vec![9e-3, 1e-3, 1e-3, 1e-3]));
        assert_eq!(d2.per_rank_k, Some(ks));
    }

    #[test]
    fn dyn_ssp_without_per_rank_evidence_degenerates_to_the_inner_policy() {
        let mut c = dyn_ssp(4);
        for w in 0..10 {
            let d = c.on_window(&obs(w, 1e-3, 3e-3)); // no per-rank split
            assert_eq!(d.per_rank_k, None);
            assert_eq!(d.k_for(2, 2), d.k, "k_for must fall back to the fleet k");
        }
    }

    #[test]
    fn dyn_ssp_is_deterministic() {
        let (mut a, mut b) = (dyn_ssp(8), dyn_ssp(8));
        for w in 0..60 {
            let mut per = vec![1e-3; 8];
            per[(w % 8) as usize] *= 1.0 + (w % 4) as f64;
            let o = obs_ranks(w, 1e-3, ((w % 5) as f64 + 1.0) * 1e-3, per);
            assert_eq!(a.on_window(&o), b.on_window(&o), "diverged at window {w}");
        }
    }

    // --- SGS: stochastic staleness draws ---

    fn sgs(n_ranks: usize) -> SgsStaleness {
        SgsStaleness::new(Box::new(Fixed::new(4)), 42, n_ranks, 1, 8)
    }

    #[test]
    fn sgs_draws_are_bounded_and_pure_in_seed_slot_window() {
        for (slot, window, k) in [(0usize, 1u64, 4usize), (3, 17, 2), (7, 99, 8)] {
            let a = SgsStaleness::draw(9, slot, window, k, 1, 8);
            let b = SgsStaleness::draw(9, slot, window, k, 1, 8);
            assert_eq!(a, b);
            let s = (k / 2).max(1);
            assert!(a >= k.saturating_sub(s).max(1) && a <= (k + s).min(8));
        }
        // different slots / windows decorrelate
        let draws: Vec<usize> =
            (0..64).map(|s| SgsStaleness::draw(9, s, 5, 4, 1, 8)).collect();
        assert!(draws.iter().any(|&d| d != draws[0]), "all slots drew the same k");
    }

    #[test]
    fn sgs_emits_identical_vectors_on_every_instance() {
        let (mut a, mut b) = (sgs(8), sgs(8));
        for w in 0..40 {
            let o = obs(w, 1e-3, 2e-3);
            let da = a.on_window(&o);
            assert_eq!(da, b.on_window(&o), "diverged at window {w}");
            let ks = da.per_rank_k.expect("sgs always draws");
            assert!(ks.iter().all(|&k| (1..=8).contains(&k)));
        }
    }

    #[test]
    fn sgs_randomization_spans_more_than_one_k() {
        let mut c = sgs(8);
        let mut seen = std::collections::BTreeSet::new();
        for w in 0..30 {
            let d = c.on_window(&obs(w, 1e-3, 2e-3));
            seen.extend(d.per_rank_k.unwrap().iter().copied());
        }
        assert!(seen.len() > 1, "staleness never randomized: {seen:?}");
    }

    #[test]
    fn compress_coupled_is_deterministic() {
        let mk = || cc(cc_env(50_000, 16, 0.05));
        let (mut a, mut b) = (mk(), mk());
        for w in 0..100 {
            let o = obs(w, 1e-3, ((w % 9) as f64) * 1e-3);
            assert_eq!(a.on_window(&o), b.on_window(&o), "diverged at window {w}");
        }
    }
}
