//! Staleness controllers: the policies that pick the window length k
//! (and the compensation strength λ0's scale) online.
//!
//! The paper fixes k a priori, but its own Eq. 13/14 analysis says the
//! profitable overlap depth depends on the live ratio t_AR / t_C — a
//! quantity that drifts with stragglers, payload size and topology.
//! Dynamic-SSP (Zhao et al., 1908.11848) shows a bounded online
//! adaptation of k beats any static choice; DC-ASGD (Zheng et al.,
//! 1609.08326) shows the compensation strength must co-adapt with the
//! effective staleness. Three policies:
//!
//! * [`Fixed`] — the paper's static k (the control-plane no-op).
//! * [`DssPid`] — DSSP-style bounded adaptation: drive k toward
//!   ceil(t_AR / t_C) with a PI step of at most ±1 per decision,
//!   clamped to `[k_min, k_max]`.
//! * [`LambdaCoupled`] — [`DssPid`] plus λ0 rescaling ∝ k/k_ref
//!   (stronger compensation at deeper staleness, bounded).
//!
//! Determinism contract: every worker runs its own controller instance,
//! but all instances must make **identical decisions** — the engines
//! feed them the *cross-rank mean* observations carried on the
//! collective itself (see `algo::dcs3gd`), so identical inputs ⇒
//! identical k on every rank ⇒ identical window schedules ⇒ the
//! rendezvous rounds stay matched. Controllers must therefore be pure
//! functions of their observation history (no RNG, no wall clock).

/// What the engine asks the controller after each completed window.
#[derive(Debug, Clone, Copy)]
pub struct WindowObs {
    /// Completed-window index (0-based).
    pub window: u64,
    /// Worker-local iteration at the window boundary.
    pub iteration: u64,
    /// Cross-rank mean per-*step* compute time t_C over the window (s).
    pub t_compute: f64,
    /// Cross-rank mean observed collective latency t_AR of the previous
    /// window's all-reduce, post → completion (s). 0 until one has
    /// completed.
    pub t_allreduce: f64,
}

/// The controller's answer: window length for the next window and a
/// multiplier on the configured λ0.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Decision {
    pub k: usize,
    pub lam_scale: f32,
}

/// A staleness policy. One instance per worker; see the module docs for
/// the determinism contract.
pub trait StalenessController: Send {
    fn name(&self) -> &'static str;

    /// The standing decision, without new observations.
    fn current(&self) -> Decision;

    /// Observe one completed window; returns the decision for the next.
    fn on_window(&mut self, obs: &WindowObs) -> Decision;
}

/// The paper's static policy: k and λ0 never move.
#[derive(Debug, Clone)]
pub struct Fixed {
    k: usize,
}

impl Fixed {
    pub fn new(k: usize) -> Self {
        Fixed { k: k.max(1) }
    }
}

impl StalenessController for Fixed {
    fn name(&self) -> &'static str {
        "fixed"
    }

    fn current(&self) -> Decision {
        Decision { k: self.k, lam_scale: 1.0 }
    }

    fn on_window(&mut self, _obs: &WindowObs) -> Decision {
        self.current()
    }
}

/// DSSP-style bounded adaptation of k with a PI control law.
///
/// One collective per window of k steps overlaps the *next* window's k
/// compute steps, so communication is hidden iff k·t_C ≥ t_AR; the
/// setpoint is k* = t_AR / t_C. Each decision moves k by at most one,
/// within `[k_min, k_max]`, after `adjust_every` windows of evidence —
/// the bounded, hysteretic step that keeps the schedule stable under
/// noisy observations.
#[derive(Debug, Clone)]
pub struct DssPid {
    k: usize,
    k_min: usize,
    k_max: usize,
    gain_p: f64,
    gain_i: f64,
    adjust_every: u64,
    windows_since_adjust: u64,
    integral: f64,
}

impl DssPid {
    pub fn new(
        k_init: usize,
        k_min: usize,
        k_max: usize,
        gain_p: f64,
        gain_i: f64,
        adjust_every: u64,
    ) -> Self {
        let k_min = k_min.max(1);
        let k_max = k_max.max(k_min);
        DssPid {
            k: k_init.clamp(k_min, k_max),
            k_min,
            k_max,
            gain_p,
            gain_i,
            adjust_every: adjust_every.max(1),
            windows_since_adjust: 0,
            integral: 0.0,
        }
    }

    /// The raw setpoint from one observation, clamped to the k bounds.
    fn target(&self, obs: &WindowObs) -> Option<f64> {
        if obs.t_compute <= 0.0 || obs.t_allreduce <= 0.0 {
            return None; // no evidence yet (first window, or a free network)
        }
        Some((obs.t_allreduce / obs.t_compute).clamp(self.k_min as f64, self.k_max as f64))
    }
}

impl StalenessController for DssPid {
    fn name(&self) -> &'static str {
        "dss_pid"
    }

    fn current(&self) -> Decision {
        Decision { k: self.k, lam_scale: 1.0 }
    }

    fn on_window(&mut self, obs: &WindowObs) -> Decision {
        if let Some(target) = self.target(obs) {
            let err = target - self.k as f64;
            // Anti-windup clamp: the integral can demand at most a few
            // consecutive unit steps on its own.
            self.integral = (self.integral + err).clamp(-8.0, 8.0);
            self.windows_since_adjust += 1;
            if self.windows_since_adjust >= self.adjust_every {
                let drive = self.gain_p * err + self.gain_i * self.integral;
                if drive >= 0.5 && self.k < self.k_max {
                    self.k += 1;
                    self.windows_since_adjust = 0;
                    self.integral = 0.0;
                } else if drive <= -0.5 && self.k > self.k_min {
                    self.k -= 1;
                    self.windows_since_adjust = 0;
                    self.integral = 0.0;
                }
            }
        }
        self.current()
    }
}

/// [`DssPid`] plus DC-ASGD-style λ co-adaptation: when the effective
/// staleness k moves away from the reference k_ref the workers drift
/// further from the average between corrections, so the compensation
/// base λ0 is rescaled by k/k_ref, clamped to
/// `[lam_scale_min, lam_scale_max]`.
#[derive(Debug, Clone)]
pub struct LambdaCoupled {
    inner: DssPid,
    k_ref: usize,
    lam_scale_min: f32,
    lam_scale_max: f32,
}

impl LambdaCoupled {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        k_init: usize,
        k_min: usize,
        k_max: usize,
        gain_p: f64,
        gain_i: f64,
        adjust_every: u64,
        lam_scale_min: f32,
        lam_scale_max: f32,
    ) -> Self {
        let lam_scale_min = lam_scale_min.max(0.0);
        let lam_scale_max = lam_scale_max.max(lam_scale_min);
        LambdaCoupled {
            inner: DssPid::new(k_init, k_min, k_max, gain_p, gain_i, adjust_every),
            k_ref: k_init.max(1),
            lam_scale_min,
            lam_scale_max,
        }
    }

    fn lam_scale(&self) -> f32 {
        let raw = self.inner.k as f32 / self.k_ref as f32;
        raw.clamp(self.lam_scale_min, self.lam_scale_max)
    }
}

impl StalenessController for LambdaCoupled {
    fn name(&self) -> &'static str {
        "lambda_coupled"
    }

    fn current(&self) -> Decision {
        Decision { k: self.inner.k, lam_scale: self.lam_scale() }
    }

    fn on_window(&mut self, obs: &WindowObs) -> Decision {
        self.inner.on_window(obs);
        self.current()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(window: u64, t_c: f64, t_ar: f64) -> WindowObs {
        WindowObs { window, iteration: window * 4, t_compute: t_c, t_allreduce: t_ar }
    }

    #[test]
    fn fixed_never_moves() {
        let mut c = Fixed::new(3);
        assert_eq!(c.current(), Decision { k: 3, lam_scale: 1.0 });
        for w in 0..20 {
            let d = c.on_window(&obs(w, 1e-3, 1.0)); // huge t_AR: would tempt any adaptive policy
            assert_eq!(d, Decision { k: 3, lam_scale: 1.0 });
        }
    }

    #[test]
    fn dss_pid_stays_within_bounds() {
        // Absurd ratios in both directions must never push k out of range.
        let mut c = DssPid::new(2, 1, 4, 0.5, 0.1, 1);
        for w in 0..50 {
            let d = c.on_window(&obs(w, 1e-6, 10.0)); // ratio 1e7
            assert!((1..=4).contains(&d.k), "k={} escaped bounds", d.k);
        }
        assert_eq!(c.current().k, 4);
        for w in 50..100 {
            let d = c.on_window(&obs(w, 10.0, 1e-6)); // ratio 1e-7
            assert!((1..=4).contains(&d.k), "k={} escaped bounds", d.k);
        }
        assert_eq!(c.current().k, 1);
    }

    #[test]
    fn dss_pid_moves_monotonically_toward_target() {
        // With a constant ratio of 3, k must climb 1 → 3 one step at a
        // time, never overshoot, and then hold.
        let mut c = DssPid::new(1, 1, 8, 0.5, 0.1, 1);
        let mut ks = Vec::new();
        for w in 0..20 {
            ks.push(c.on_window(&obs(w, 1e-3, 3e-3)).k);
        }
        for pair in ks.windows(2) {
            assert!(pair[1] >= pair[0], "non-monotone approach: {ks:?}");
            assert!(pair[1] - pair[0] <= 1, "jumped more than one: {ks:?}");
        }
        assert_eq!(*ks.last().unwrap(), 3, "did not settle on target: {ks:?}");
        // settled: further identical evidence must not oscillate
        for w in 20..40 {
            assert_eq!(c.on_window(&obs(w, 1e-3, 3e-3)).k, 3);
        }
    }

    #[test]
    fn dss_pid_ignores_empty_evidence() {
        let mut c = DssPid::new(2, 1, 8, 0.5, 0.1, 1);
        for w in 0..10 {
            assert_eq!(c.on_window(&obs(w, 0.0, 0.0)).k, 2);
            assert_eq!(c.on_window(&obs(w, 1e-3, 0.0)).k, 2);
        }
    }

    #[test]
    fn dss_pid_respects_adjust_every() {
        let mut c = DssPid::new(1, 1, 8, 1.0, 0.0, 3);
        let mut changes = 0;
        let mut prev = 1;
        for w in 0..9 {
            let k = c.on_window(&obs(w, 1e-3, 8e-3)).k;
            if k != prev {
                changes += 1;
                prev = k;
            }
        }
        assert!(changes <= 3, "changed {changes}× in 9 windows with adjust_every=3");
    }

    #[test]
    fn lambda_coupled_scales_with_k_and_stays_bounded() {
        let mut c = LambdaCoupled::new(1, 1, 8, 0.5, 0.1, 1, 0.25, 4.0);
        assert_eq!(c.current().lam_scale, 1.0);
        // drive k up; λ scale must track k/k_ref and respect the cap
        let mut last = c.current();
        for w in 0..40 {
            last = c.on_window(&obs(w, 1e-4, 1.0));
            assert!(
                last.lam_scale >= 0.25 && last.lam_scale <= 4.0,
                "λ scale {} out of bounds",
                last.lam_scale
            );
            assert!((last.lam_scale - (last.k as f32).clamp(0.25, 4.0)).abs() < 1e-6);
        }
        assert_eq!(last.k, 8);
        assert_eq!(last.lam_scale, 4.0, "cap must bind at k=8, k_ref=1");
    }

    #[test]
    fn lambda_coupled_scales_down_too() {
        let mut c = LambdaCoupled::new(4, 1, 8, 0.5, 0.1, 1, 0.25, 4.0);
        let mut last = c.current();
        for w in 0..40 {
            last = c.on_window(&obs(w, 1.0, 1e-6));
        }
        assert_eq!(last.k, 1);
        assert_eq!(last.lam_scale, 0.25);
    }

    #[test]
    fn controllers_are_deterministic() {
        // Two instances fed the same stream must agree exactly — the
        // property the rendezvous window schedule rests on.
        let mk = || LambdaCoupled::new(1, 1, 6, 0.5, 0.1, 2, 0.5, 3.0);
        let (mut a, mut b) = (mk(), mk());
        for w in 0..100 {
            let o = obs(w, 1e-3, ((w % 7) as f64 + 1.0) * 1e-3);
            assert_eq!(a.on_window(&o), b.on_window(&o), "diverged at window {w}");
        }
    }
}
