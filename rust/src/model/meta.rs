//! Artifact metadata: the `meta.json` contract between `aot.py` (which
//! writes it) and the rust runtime (which loads it).

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::Json;

/// One (layer name, shape) entry of the flat weight layout.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerInfo {
    pub name: String,
    pub shape: Vec<usize>,
}

impl LayerInfo {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed `meta.json` for one model variant directory.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub dir: PathBuf,
    pub model: String,
    pub batch: usize,
    pub param_count: usize,
    pub input_hw: usize,
    pub input_channels: usize,
    pub num_classes: usize,
    pub layers: Vec<LayerInfo>,
}

impl ArtifactMeta {
    /// Load from a variant directory (e.g. `artifacts/tiny_cnn_b32`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let meta_path = dir.join("meta.json");
        let text = fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {}", meta_path.display()))?;
        let json = Json::parse(&text).context("parsing meta.json")?;

        let get_usize = |k: &str| -> Result<usize> {
            json.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("meta.json missing numeric {k:?}"))
        };
        let layers = json
            .get("layers")
            .and_then(Json::as_arr)
            .context("meta.json missing layers")?
            .iter()
            .map(|l| -> Result<LayerInfo> {
                Ok(LayerInfo {
                    name: l
                        .get("name")
                        .and_then(Json::as_str)
                        .context("layer missing name")?
                        .to_string(),
                    shape: l
                        .get("shape")
                        .and_then(Json::as_arr)
                        .context("layer missing shape")?
                        .iter()
                        .filter_map(Json::as_usize)
                        .collect(),
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let meta = ArtifactMeta {
            model: json
                .get("model")
                .and_then(Json::as_str)
                .context("meta.json missing model")?
                .to_string(),
            batch: get_usize("batch")?,
            param_count: get_usize("param_count")?,
            input_hw: get_usize("input_hw")?,
            input_channels: get_usize("input_channels")?,
            num_classes: get_usize("num_classes")?,
            layers,
            dir,
        };
        let layer_total: usize = meta.layers.iter().map(LayerInfo::numel).sum();
        if layer_total != meta.param_count {
            bail!("layer shapes sum to {layer_total}, meta says {}", meta.param_count);
        }
        Ok(meta)
    }

    /// Flat-vector (offset, len) per layer — what LARS needs.
    pub fn layer_ranges(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.layers.len());
        let mut off = 0;
        for l in &self.layers {
            let n = l.numel();
            out.push((off, n));
            off += n;
        }
        out
    }

    /// Elements per input batch (`batch · hw · hw · c`).
    pub fn x_len(&self) -> usize {
        self.batch * self.input_hw * self.input_hw * self.input_channels
    }

    pub fn train_hlo(&self) -> PathBuf {
        self.dir.join("train_step.hlo.txt")
    }

    pub fn eval_hlo(&self) -> PathBuf {
        self.dir.join("eval_step.hlo.txt")
    }

    pub fn dc_hlo(&self) -> PathBuf {
        self.dir.join("dc_step.hlo.txt")
    }

    /// Initial flat weights from `init_params.bin` (f32 LE).
    pub fn load_init_params(&self) -> Result<Vec<f32>> {
        let v = read_f32_le(&self.dir.join("init_params.bin"))?;
        if v.len() != self.param_count {
            bail!("init_params.bin has {} f32, expected {}", v.len(), self.param_count);
        }
        Ok(v)
    }

    /// Weight-decay mask from `decay_mask.bin`.
    pub fn load_decay_mask(&self) -> Result<Vec<f32>> {
        let v = read_f32_le(&self.dir.join("decay_mask.bin"))?;
        if v.len() != self.param_count {
            bail!("decay_mask.bin has {} f32, expected {}", v.len(), self.param_count);
        }
        Ok(v)
    }
}

/// Read a little-endian f32 binary file.
pub fn read_f32_le(path: &Path) -> Result<Vec<f32>> {
    let bytes = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{} length {} not a multiple of 4", path.display(), bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Discover all variant directories under an artifacts root.
pub fn discover_variants(root: impl AsRef<Path>) -> Result<Vec<ArtifactMeta>> {
    let mut out = Vec::new();
    let root = root.as_ref();
    if !root.exists() {
        return Ok(out);
    }
    for entry in fs::read_dir(root)? {
        let p = entry?.path();
        if p.is_dir() && p.join("meta.json").exists() {
            out.push(ArtifactMeta::load(&p)?);
        }
    }
    out.sort_by(|a, b| a.dir.cmp(&b.dir));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_variant(dir: &Path, param_count: usize, layers: &str) {
        fs::create_dir_all(dir).unwrap();
        let meta = format!(
            r#"{{"model":"toy","batch":4,"param_count":{param_count},
                "input_hw":8,"input_channels":3,"num_classes":5,
                "layers":{layers}}}"#
        );
        fs::write(dir.join("meta.json"), meta).unwrap();
        let mut f = fs::File::create(dir.join("init_params.bin")).unwrap();
        for i in 0..param_count {
            f.write_all(&(i as f32).to_le_bytes()).unwrap();
        }
    }

    #[test]
    fn load_roundtrip() {
        let tmp = std::env::temp_dir().join(format!("dcs3gd_meta_{}", std::process::id()));
        let dir = tmp.join("toy_b4");
        write_variant(&dir, 6, r#"[{"name":"a.w","shape":[2,2]},{"name":"a.b","shape":[2]}]"#);
        let m = ArtifactMeta::load(&dir).unwrap();
        assert_eq!(m.param_count, 6);
        assert_eq!(m.layer_ranges(), vec![(0, 4), (4, 2)]);
        assert_eq!(m.x_len(), 4 * 8 * 8 * 3);
        let w = m.load_init_params().unwrap();
        assert_eq!(w, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        let found = discover_variants(&tmp).unwrap();
        assert_eq!(found.len(), 1);
        fs::remove_dir_all(&tmp).unwrap();
    }

    #[test]
    fn rejects_inconsistent_layers() {
        let tmp = std::env::temp_dir().join(format!("dcs3gd_meta_bad_{}", std::process::id()));
        let dir = tmp.join("bad_b4");
        write_variant(&dir, 7, r#"[{"name":"a.w","shape":[2,2]}]"#); // 4 != 7
        assert!(ArtifactMeta::load(&dir).is_err());
        fs::remove_dir_all(&tmp).unwrap();
    }
}
