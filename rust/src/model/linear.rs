//! Pure-rust multinomial logistic regression — the artifact-free
//! [`StepBackend`].
//!
//! Lets the whole distributed stack (collectives, staleness, DC
//! correction, schedules) run under `cargo test` with no python/PJRT in
//! the loop, and provides the "simple model" rows of the ablation
//! benches. Flat layout: `[W (d_in × classes) | b (classes)]`, matching
//! the conventions of the jax models.

use super::StepBackend;

/// Softmax regression backend: `logits = x·W + b`, cross-entropy loss,
/// mean-over-batch gradients (identical normalization to the L2 jax
/// `train_step`).
pub struct LinearSoftmax {
    d_in: usize,
    classes: usize,
    batch: usize,
    /// scratch: logits/probs per sample (batch × classes)
    probs: Vec<f32>,
}

impl LinearSoftmax {
    pub fn new(d_in: usize, classes: usize, batch: usize) -> Self {
        LinearSoftmax { d_in, classes, batch, probs: vec![0.0; batch * classes] }
    }

    /// For an image dataset: `d_in = hw·hw·3`.
    pub fn for_images(hw: usize, classes: usize, batch: usize) -> Self {
        Self::new(hw * hw * 3, classes, batch)
    }

    pub fn d_in(&self) -> usize {
        self.d_in
    }

    pub fn classes(&self) -> usize {
        self.classes
    }

    /// Deterministic small-scale init (zeros work for logistic
    /// regression; tiny noise breaks ties).
    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::Rng::new(seed);
        let mut w = vec![0.0f32; self.n_params()];
        for v in w.iter_mut() {
            *v = 0.01 * rng.normal();
        }
        w
    }

    /// Forward pass: fills `self.probs` with softmax probabilities and
    /// returns (loss, err).
    fn forward(&mut self, w: &[f32], x: &[f32], y: &[i32]) -> (f32, f32) {
        let (d, c, b) = (self.d_in, self.classes, y.len());
        assert!(b <= self.batch);
        assert_eq!(w.len(), self.n_params());
        assert_eq!(x.len(), b * d);
        let (wmat, bias) = w.split_at(d * c);
        let mut loss = 0f64;
        let mut errs = 0usize;
        for s in 0..b {
            let xs = &x[s * d..(s + 1) * d];
            let logits = &mut self.probs[s * c..(s + 1) * c];
            logits.copy_from_slice(bias);
            // logits += xs · W  (W row-major d×c)
            for (i, &xv) in xs.iter().enumerate() {
                if xv != 0.0 {
                    let row = &wmat[i * c..(i + 1) * c];
                    for (l, wv) in logits.iter_mut().zip(row) {
                        *l += xv * wv;
                    }
                }
            }
            // softmax + CE
            let mut max = f32::NEG_INFINITY;
            let mut argmax = 0usize;
            for (j, &v) in logits.iter().enumerate() {
                if v > max {
                    max = v;
                    argmax = j;
                }
            }
            let mut z = 0f64;
            for v in logits.iter_mut() {
                *v = (*v - max).exp();
                z += *v as f64;
            }
            let label = y[s] as usize;
            assert!(label < c, "label {label} out of range");
            loss -= ((self.probs[s * c + label] as f64 / z).max(1e-30)).ln();
            for v in self.probs[s * c..(s + 1) * c].iter_mut() {
                *v = (*v as f64 / z) as f32;
            }
            if argmax != label {
                errs += 1;
            }
        }
        ((loss / b as f64) as f32, errs as f32 / b as f32)
    }
}

impl StepBackend for LinearSoftmax {
    fn n_params(&self) -> usize {
        self.d_in * self.classes + self.classes
    }

    fn batch_size(&self) -> usize {
        self.batch
    }

    fn train_step(&mut self, w: &[f32], x: &[f32], y: &[i32], grad_out: &mut [f32]) -> (f32, f32) {
        let (d, c, b) = (self.d_in, self.classes, y.len());
        assert_eq!(grad_out.len(), self.n_params());
        let (loss, err) = self.forward(w, x, y);
        grad_out.iter_mut().for_each(|g| *g = 0.0);
        let inv_b = 1.0 / b as f32;
        let (gw, gb) = grad_out.split_at_mut(d * c);
        for s in 0..b {
            let xs = &x[s * d..(s + 1) * d];
            let probs = &mut self.probs[s * c..(s + 1) * c];
            probs[y[s] as usize] -= 1.0; // dL/dlogits = p − onehot
            for (j, gbj) in gb.iter_mut().enumerate() {
                *gbj += inv_b * probs[j];
            }
            for (i, &xv) in xs.iter().enumerate() {
                if xv != 0.0 {
                    let row = &mut gw[i * c..(i + 1) * c];
                    for (gj, pj) in row.iter_mut().zip(probs.iter()) {
                        *gj += inv_b * xv * pj;
                    }
                }
            }
        }
        (loss, err)
    }

    fn eval_step(&mut self, w: &[f32], x: &[f32], y: &[i32]) -> (f32, f32) {
        self.forward(w, x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Split, SyntheticDataset};

    #[test]
    fn gradient_matches_finite_difference() {
        let mut m = LinearSoftmax::new(6, 3, 4);
        let w = m.init_params(0);
        let x: Vec<f32> = (0..24).map(|i| (i as f32 * 0.37).sin()).collect();
        let y = vec![0, 1, 2, 1];
        let mut g = vec![0.0; m.n_params()];
        m.train_step(&w, &x, &y, &mut g);
        let eps = 1e-3;
        for i in [0usize, 5, 11, 18, 20] {
            let mut wp = w.clone();
            wp[i] += eps;
            let (lp, _) = m.eval_step(&wp, &x, &y);
            let mut wm = w.clone();
            wm[i] -= eps;
            let (lm, _) = m.eval_step(&wm, &x, &y);
            let fd = (lp - lm) / (2.0 * eps);
            assert!((fd - g[i]).abs() < 1e-3, "param {i}: fd={fd} an={}", g[i]);
        }
    }

    #[test]
    fn loss_starts_near_log_c() {
        let mut m = LinearSoftmax::new(10, 5, 8);
        let w = m.init_params(1);
        let x = vec![0.1; 80];
        let y = vec![0, 1, 2, 3, 4, 0, 1, 2];
        let (loss, _) = m.eval_step(&w, &x, &y);
        assert!((loss - (5f32).ln()).abs() < 0.1, "loss {loss}");
    }

    #[test]
    fn sgd_learns_synthetic_dataset() {
        let ds = SyntheticDataset::new(3, 8, 4, 512, 128).with_noise(0.4);
        let mut m = LinearSoftmax::for_images(8, 4, 32);
        let mut w = m.init_params(0);
        let px = 8 * 8 * 3;
        let mut x = vec![0.0; 32 * px];
        let mut y = vec![0i32; 32];
        let mut g = vec![0.0; m.n_params()];
        let mut first_loss = 0.0;
        for step in 0..150 {
            let idx: Vec<usize> = (0..32).map(|i| (step * 32 + i) % 512).collect();
            ds.batch_into(Split::Train, &idx, &mut x, &mut y);
            let (loss, _) = m.train_step(&w, &x, &y, &mut g);
            if step == 0 {
                first_loss = loss;
            }
            for (wi, gi) in w.iter_mut().zip(&g) {
                *wi -= 0.05 * gi;
            }
        }
        // val error clearly better than chance (0.75)
        let mut idx: Vec<usize> = (0..128).collect();
        let mut xv = vec![0.0; 128 * px];
        let mut yv = vec![0i32; 128];
        idx.truncate(32 * (128 / 32));
        let mut errs = 0.0;
        for chunk in idx.chunks(32) {
            ds.batch_into(Split::Val, chunk, &mut xv[..32 * px], &mut yv[..32]);
            let (_, e) = m.eval_step(&w, &xv[..32 * px], &yv[..32]);
            errs += e;
        }
        let val_err = errs / 4.0;
        let (final_loss, _) = m.eval_step(&w, &xv[..32 * px], &yv[..32]);
        assert!(final_loss < first_loss, "no learning: {first_loss} -> {final_loss}");
        assert!(val_err < 0.6, "val err {val_err} not better than chance 0.75");
    }
}
