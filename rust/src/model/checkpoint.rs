//! Checkpointing: save/restore flat weights + optimizer velocity +
//! iteration counter, with a small self-describing binary header.

use std::fs;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 8] = b"DCS3GD\x01\x00";

/// A training checkpoint (one worker's view — under DC-S3GD all workers
/// converge to the same averaged weights at iteration boundaries, so
/// the leader's copy is canonical).
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub iteration: u64,
    pub weights: Vec<f32>,
    pub velocity: Vec<f32>,
}

impl Checkpoint {
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        let mut f = fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        f.write_all(MAGIC)?;
        f.write_all(&self.iteration.to_le_bytes())?;
        f.write_all(&(self.weights.len() as u64).to_le_bytes())?;
        f.write_all(&(self.velocity.len() as u64).to_le_bytes())?;
        write_f32s(&mut f, &self.weights)?;
        write_f32s(&mut f, &self.velocity)?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let mut f = fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{} is not a DCS3GD checkpoint", path.display());
        }
        let iteration = read_u64(&mut f)?;
        let nw = read_u64(&mut f)? as usize;
        let nv = read_u64(&mut f)? as usize;
        let weights = read_f32s(&mut f, nw)?;
        let velocity = read_f32s(&mut f, nv)?;
        Ok(Checkpoint { iteration, weights, velocity })
    }
}

fn write_f32s(f: &mut impl Write, xs: &[f32]) -> Result<()> {
    let mut buf = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

fn read_u64(f: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_f32s(f: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    f.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let ck = Checkpoint {
            iteration: 1234,
            weights: vec![1.0, -2.5, 3.25],
            velocity: vec![0.5, 0.0],
        };
        let path = std::env::temp_dir().join(format!("dcs3gd_ckpt_{}.bin", std::process::id()));
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join(format!("dcs3gd_garbage_{}.bin", std::process::id()));
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }
}
