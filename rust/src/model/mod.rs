//! Model-side plumbing: the compute-backend abstraction, artifact
//! metadata, a pure-rust reference model, and checkpointing.
//!
//! The training engines ([`crate::algo`]) are generic over
//! [`StepBackend`] — "given flat weights and a batch, return loss,
//! top-1 error and the flat gradient". Two implementations:
//!
//! * [`crate::runtime::XlaBackend`] — executes the AOT-compiled L2 HLO
//!   artifacts via PJRT (the production path);
//! * [`linear::LinearSoftmax`] — a pure-rust multinomial logistic
//!   regression, used by `cargo test` (no artifacts required) and as a
//!   sanity baseline.

pub mod checkpoint;
pub mod linear;
pub mod meta;

pub use checkpoint::Checkpoint;
pub use linear::LinearSoftmax;
pub use meta::ArtifactMeta;

/// One worker's compute: fused forward+backward and eval-only steps
/// over flat f32 weights and an NHWC-flat batch.
pub trait StepBackend: Send {
    /// Flat parameter count.
    fn n_params(&self) -> usize;

    /// Expected local batch size (x has `batch·hw·hw·3` elements).
    fn batch_size(&self) -> usize;

    /// Fused fwd+bwd: returns (loss, top-1 error) and writes the flat
    /// gradient into `grad_out`.
    fn train_step(&mut self, w: &[f32], x: &[f32], y: &[i32], grad_out: &mut [f32]) -> (f32, f32);

    /// Forward only: (loss, top-1 error).
    fn eval_step(&mut self, w: &[f32], x: &[f32], y: &[i32]) -> (f32, f32);

    /// Pure compute time of the last step, if the backend can separate
    /// it from call overhead (the PJRT backend reports server-measured
    /// execution time, excluding request queueing). `None` → caller
    /// falls back to its own wall measurement.
    fn last_compute_s(&self) -> Option<f64> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // StepBackend is object-safe (the engines hold Box<dyn StepBackend>).
    #[test]
    fn backend_is_object_safe() {
        fn _takes(_: &mut dyn StepBackend) {}
        let _f: Option<Box<dyn StepBackend>> = None;
    }
}
