//! Virtual-time engine: per-worker clocks and the compute-cost model.
//!
//! The paper's timing claims (Eqs. 13–15) are statements about how
//! t_C(B) (per-batch compute) and t_AR(g, N) (collective time) compose.
//! Running 32–128 physical nodes is out of scope here (DESIGN.md §3),
//! so every worker carries a **virtual clock**: compute advances it by
//! t_C from [`ComputeModel`] (either modelled, or measured wall time of
//! the real PJRT execution), and collectives advance it per
//! [`crate::comm::NetModel`]. The resulting per-iteration times
//! reproduce the paper's composition exactly and are what the
//! throughput columns of Table I report (img/s = global batch / mean
//! iteration time).

use crate::util::Rng;

/// Per-batch compute-time model t_C(B) with optional heterogeneity.
#[derive(Debug, Clone)]
pub struct ComputeModel {
    /// Seconds per sample on a nominal worker (calibrate with
    /// [`ComputeModel::calibrated`] from a measured step, or set
    /// directly for what-if studies).
    pub sec_per_sample: f64,
    /// Fixed per-batch overhead (kernel launch, data movement).
    pub overhead_s: f64,
    /// Multiplicative log-normal-ish jitter fraction (0 = deterministic):
    /// each batch takes `t * (1 + jitter * |normal|)`.
    pub jitter_frac: f64,
    /// Per-rank slowdown factors (straggler injection): rank i runs
    /// `straggler_factor[i]×` slower. Empty = homogeneous.
    pub straggler_factor: Vec<f64>,
}

impl Default for ComputeModel {
    fn default() -> Self {
        // ~ResNet-50-on-Skylake-node ballpark from Table I: 2078 img/s
        // over 32 nodes ⇒ ~65 img/s/node ⇒ ~15 ms/sample.
        ComputeModel {
            sec_per_sample: 15e-3,
            overhead_s: 1e-3,
            jitter_frac: 0.0,
            straggler_factor: Vec::new(),
        }
    }
}

impl ComputeModel {
    /// Deterministic model with the given per-sample time.
    pub fn uniform(sec_per_sample: f64) -> Self {
        ComputeModel { sec_per_sample, overhead_s: 0.0, jitter_frac: 0.0, straggler_factor: Vec::new() }
    }

    /// Calibrate from a measured (batch, seconds) pair — used when the
    /// real PJRT step time should drive the simulated cluster.
    pub fn calibrated(batch: usize, measured_s: f64) -> Self {
        ComputeModel {
            sec_per_sample: measured_s / batch as f64,
            overhead_s: 0.0,
            jitter_frac: 0.0,
            straggler_factor: Vec::new(),
        }
    }

    /// Mark `rank` as a straggler running `factor`× slower (paper §II-A:
    /// "all workers have to wait for the slowest one").
    pub fn with_straggler(mut self, rank: usize, factor: f64, n_ranks: usize) -> Self {
        if self.straggler_factor.len() < n_ranks {
            self.straggler_factor.resize(n_ranks, 1.0);
        }
        self.straggler_factor[rank] = factor;
        self
    }

    pub fn with_jitter(mut self, frac: f64) -> Self {
        self.jitter_frac = frac;
        self
    }

    /// Sample t_C(B) for `rank` processing `batch` samples.
    pub fn batch_time(&self, rank: usize, batch: usize, rng: &mut Rng) -> f64 {
        let mut t = self.overhead_s + self.sec_per_sample * batch as f64;
        if let Some(&f) = self.straggler_factor.get(rank) {
            t *= f;
        }
        if self.jitter_frac > 0.0 {
            t *= 1.0 + self.jitter_frac * rng.normal().abs() as f64;
        }
        t
    }
}

/// A worker's virtual clock.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimClock {
    now: f64,
}

impl SimClock {
    pub fn new() -> Self {
        SimClock { now: 0.0 }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance by a duration (compute, local work).
    pub fn advance(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0, "negative duration {dt}");
        self.now += dt;
    }

    /// Jump to an absolute time (collective completion); never moves
    /// backward.
    pub fn advance_to(&mut self, t: f64) {
        self.now = self.now.max(t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_monotone() {
        let mut c = SimClock::new();
        c.advance(1.5);
        assert_eq!(c.now(), 1.5);
        c.advance_to(1.0); // earlier completion: no-op
        assert_eq!(c.now(), 1.5);
        c.advance_to(2.0);
        assert_eq!(c.now(), 2.0);
    }

    #[test]
    fn batch_time_linear_in_batch() {
        let m = ComputeModel::uniform(1e-3);
        let mut rng = Rng::new(0);
        assert!((m.batch_time(0, 100, &mut rng) - 0.1).abs() < 1e-12);
        assert!((m.batch_time(0, 200, &mut rng) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn straggler_slows_one_rank() {
        let m = ComputeModel::uniform(1e-3).with_straggler(2, 3.0, 4);
        let mut rng = Rng::new(0);
        let t_fast = m.batch_time(0, 100, &mut rng);
        let t_slow = m.batch_time(2, 100, &mut rng);
        assert!((t_slow / t_fast - 3.0).abs() < 1e-9);
    }

    #[test]
    fn jitter_adds_spread_but_never_speeds_up() {
        let m = ComputeModel::uniform(1e-3).with_jitter(0.2);
        let mut rng = Rng::new(7);
        let base = 0.1;
        let mut any_above = false;
        for _ in 0..100 {
            let t = m.batch_time(0, 100, &mut rng);
            assert!(t >= base - 1e-12);
            if t > base * 1.01 {
                any_above = true;
            }
        }
        assert!(any_above);
    }

    #[test]
    fn calibration_roundtrip() {
        let m = ComputeModel::calibrated(32, 0.48);
        let mut rng = Rng::new(0);
        assert!((m.batch_time(0, 32, &mut rng) - 0.48).abs() < 1e-12);
    }
}
