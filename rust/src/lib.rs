//! # DC-S3GD — Delay-Compensated Stale-Synchronous SGD
//!
//! A reproduction of *"DC-S3GD: Delay-Compensated Stale-Synchronous SGD
//! for Large-Scale Decentralized Neural Network Training"* (A. Rigazzi,
//! Cray, 2019) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the decentralized training coordinator:
//!   simulated-MPI collectives with non-blocking semantics and
//!   pluggable, phase-split-accounted schedules over a dragonfly with
//!   contended tapered global links ([`comm`]), the stale-synchronous
//!   overlap engine and the paper's Algorithm 1 ([`algo::dcs3gd`]),
//!   the SSGD / ASGD / DC-ASGD baselines ([`algo`], [`ps`]), the
//!   elastic control plane — online staleness adaptation, schedule
//!   selection with probing, fault injection, heartbeat detection and
//!   checkpoint recovery ([`control`]) — error-feedback gradient
//!   compression ([`compress`]), optimizers and the paper's
//!   LR/weight-decay schedules ([`optim`]), a virtual-time engine for
//!   the Eq. 13/14 timing analysis ([`simtime`]), a synthetic
//!   ImageNet-style dataset ([`data`]), metrics ([`metrics`]) and a
//!   config system ([`config`]).
//!
//! The configuration and run-JSON references live in the repository's
//! `docs/` book (`docs/config.md`, `docs/run-json.md`), pinned to the
//! real parser and exporter by `tests/docs_config.rs`. The
//! load-bearing invariants are documented where they live:
//! [`comm::schedule`] (phase-split accounting, contention),
//! [`control::staleness`] (cross-rank determinism, probing), and
//! [`compress`] (piggyback slot layout, residual re-zeroing).
//! * **L2** — JAX model definitions (`python/compile/model.py`), lowered
//!   once to HLO text artifacts and executed from rust via PJRT
//!   ([`runtime`]).
//! * **L1** — the fused delay-compensation Pallas kernel
//!   (`python/compile/kernels/dc_correction.py`), embedded in the
//!   `dc_step` artifact; [`dc`] is its rust mirror used on the hot path
//!   when running without artifacts.
//!
//! Python never runs on the training path: `make artifacts` is the only
//! python invocation, after which the `dcs3gd` binary is self-contained.

pub mod algo;
pub mod bench_util;
pub mod cli;
pub mod comm;
pub mod compress;
pub mod config;
pub mod control;
pub mod data;
pub mod dc;
pub mod exec;
pub mod hetero;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod optim;
pub mod ps;
pub mod runtime;
pub mod simtime;
pub mod tensor;
pub mod util;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::algo::{engine_for, engine_registry, run_experiment, Algo, RunReport};
    pub use crate::comm::{
        AllReduceAlgo, CollectiveSchedule, Dragonfly, Group, NetModel, PhaseTimes, SimBackend,
    };
    pub use crate::compress::{CompressConfig, CompressorKind, GradCompressor};
    pub use crate::config::{ExperimentConfig, RunBuilder};
    pub use crate::control::{ControlPolicy, FaultPlan};
    pub use crate::data::SyntheticDataset;
    pub use crate::exec::{PerfConfig, Pool};
    pub use crate::hetero::{HeteroConfig, HeteroProfile};
    pub use crate::metrics::Recorder;
    pub use crate::optim::{LrSchedule, MomentumSgd, Optimizer};
    pub use crate::simtime::ComputeModel;
}
