//! Delay-compensation math (paper §III) — the rust mirror of the L1
//! Pallas kernel in `python/compile/kernels/dc_correction.py`.
//!
//! Pinned to the same oracle (`kernels/ref.py`) via golden fixtures in
//! `rust/tests/golden/` (see the `golden_vectors` integration test).
//!
//! Two entry points:
//! * [`dc_correct_update`] — fused single-pass hot path used by the
//!   coordinator when running with the rust update path.
//! * the unfused pieces (`dynamic_lambda`, `dc_correct`) used by tests and
//!   the DC-ASGD parameter-server baseline.

use crate::tensor;

/// Hyper-parameters of the fused update.
#[derive(Debug, Clone, Copy)]
pub struct DcHyper {
    /// Learning rate η (already schedule-resolved for this iteration).
    pub eta: f32,
    /// Momentum μ.
    pub mu: f32,
    /// Variance-control base λ0 (Eq. 17); λ_i is derived per call.
    pub lam0: f32,
    /// Weight decay (already schedule-resolved; applied into the
    /// gradient, masked by `decay_mask` if provided).
    pub wd: f32,
}

/// Clamp on the dynamic λ: near convergence ‖g⊙g⊙D‖ shrinks
/// quadratically in ‖g‖ while the numerator shrinks linearly, so the
/// raw Eq. 17 ratio diverges even though the *correction* stays bounded
/// at λ0‖g‖. The clamp keeps λ in f32-safe territory without touching
/// any training-relevant regime (λ is O(1)–O(10³) mid-training).
pub const LAMBDA_MAX: f32 = 1e6;

/// Eq. 17 with its reductions exposed: `(λ, ‖g‖, ‖g⊙g⊙D‖)` from one
/// fused pass — callers that also want the compensation ratio
/// λ·‖g⊙g⊙D‖/‖g‖ (the `"obs"` per-window metric) get it without a
/// second reduction.
pub fn dynamic_lambda_full(g: &[f32], d: &[f32], lam0: f32) -> (f32, f64, f64) {
    // One fused pass for both reductions (§Perf iteration 2).
    let (gn, cn) = tensor::lambda_norms(g, d);
    let lam = if cn > 0.0 {
        ((lam0 as f64 * gn / cn.max(1e-30)) as f32).min(LAMBDA_MAX)
    } else {
        0.0
    };
    (lam, gn, cn)
}

/// Eq. 17: dynamic λ_i = λ0·‖g‖ / ‖g ⊙ g ⊙ D‖, guarded for the D = 0
/// first iteration (returns 0, making the correction an exact no-op)
/// and clamped to [`LAMBDA_MAX`].
pub fn dynamic_lambda(g: &[f32], d: &[f32], lam0: f32) -> f32 {
    dynamic_lambda_full(g, d, lam0).0
}

/// Eq. 10 (unfused): `g~ = g + λ · g ⊙ g ⊙ d`.
pub fn dc_correct(g: &[f32], d: &[f32], lam: f32, out: &mut [f32]) {
    assert_eq!(g.len(), d.len());
    assert_eq!(g.len(), out.len());
    let cw = crate::exec::pin_chunk();
    let mut lo = 0;
    while lo < g.len() {
        let hi = (lo + cw).min(g.len());
        for ((o, gi), di) in out[lo..hi].iter_mut().zip(&g[lo..hi]).zip(&d[lo..hi]) {
            *o = gi + lam * gi * gi * di;
        }
        lo = hi;
    }
}

/// Result of the fused update: λ used, plus norms the metrics layer and
/// schedule logic want without recomputing reductions.
#[derive(Debug, Clone, Copy)]
pub struct DcStepInfo {
    pub lam: f32,
    pub grad_norm: f64,
    pub update_norm: f64,
    /// Eq. 17 denominator ‖g ⊙ g ⊙ D‖ (0 when no correction ran) —
    /// kept so the compensation ratio falls out of reductions the
    /// update already paid for.
    pub corr_denom: f64,
}

impl DcStepInfo {
    /// Compensation ratio ‖λ·g⊙g⊙D‖ / ‖g‖ = λ·corr_denom/‖g‖ — the
    /// DC-ASGD-style health signal for how much work the delay
    /// compensation is doing, exported per window under `"obs"`. By
    /// the Eq. 17 normalization this sits at λ0 whenever the dynamic λ
    /// is uncapped; deviations mean the [`LAMBDA_MAX`] clamp engaged
    /// (or compensation is off entirely → 0).
    pub fn comp_ratio(&self) -> f64 {
        if self.grad_norm > 0.0 {
            self.lam as f64 * self.corr_denom / self.grad_norm
        } else {
            0.0
        }
    }
}

/// Fused DC-S3GD update (Eqs. 10–12 + momentum + weight decay):
///
/// ```text
/// λ   = λ0 ‖g‖ / ‖g⊙g⊙D‖           (Eq. 17)
/// g~  = g + λ g⊙g⊙D                 (Eq. 10)
/// v'  = μ v + g~ + wd·mask·w         (momentum, decay exempt mask=0)
/// Δw  = −η v'
/// w  += D + Δw                       (Eq. 12, move-to-average + step)
/// ```
///
/// One reduction pass (for λ) + one elementwise pass over the five
/// streams. `delta_w_out` receives Δw (the quantity that is all-reduced
/// next iteration); `v` and `w` are updated in place.
///
/// When `d` is `None` the correction and the move-to-average are skipped
/// (plain momentum SGD — the SSGD baseline path).
#[allow(clippy::too_many_arguments)]
pub fn dc_correct_update(
    g: &[f32],
    d: Option<&[f32]>,
    v: &mut [f32],
    w: &mut [f32],
    decay_mask: Option<&[f32]>,
    hp: DcHyper,
    delta_w_out: &mut [f32],
) -> DcStepInfo {
    let n = g.len();
    assert_eq!(v.len(), n);
    assert_eq!(w.len(), n);
    assert_eq!(delta_w_out.len(), n);
    if let Some(d) = d {
        assert_eq!(d.len(), n);
    }
    if let Some(m) = decay_mask {
        assert_eq!(m.len(), n);
    }

    // §Perf iteration 4: one reduction pass yields both ‖g‖ (grad_norm)
    // and the Eq. 17 denominator — previously norm2(g) ran twice (once
    // here, once inside dynamic_lambda).
    let (grad_norm, lam, corr_denom) = match d {
        Some(d) if hp.lam0 != 0.0 => {
            let (gn, cn) = tensor::lambda_norms(g, d);
            let lam = if cn > 0.0 {
                ((hp.lam0 as f64 * gn / cn.max(1e-30)) as f32).min(LAMBDA_MAX)
            } else {
                0.0
            };
            (gn, lam, cn)
        }
        _ => (tensor::norm2(g), 0.0, 0.0),
    };

    // Single fused elementwise pass, blocked at the engine's pinned
    // chunk width ([`crate::exec::pin_chunk`] — per-element order is
    // unchanged, so every width is bit-identical). The match is hoisted
    // out of the loop by monomorphizing on the two Option states; the
    // inner loops are zipped subslice walks so every bounds check is
    // elided, and the body keeps to f32 so LLVM vectorizes it — the
    // update-norm diagnostic is a separate vectorized pass afterwards
    // (§Perf iteration 3: an inline f64 accumulator in this loop
    // blocked vectorization, costing ~10%).
    let cw = crate::exec::pin_chunk();
    match (d, decay_mask) {
        (Some(d), Some(m)) => {
            let mut lo = 0;
            while lo < n {
                let hi = (lo + cw).min(n);
                let rd = g[lo..hi].iter().zip(&d[lo..hi]).zip(&m[lo..hi]);
                let wr = v[lo..hi]
                    .iter_mut()
                    .zip(w[lo..hi].iter_mut())
                    .zip(delta_w_out[lo..hi].iter_mut());
                for (((gi, di), mi), ((vi, wi), oi)) in rd.zip(wr) {
                    let gt = gi + lam * gi * gi * di;
                    let vn = hp.mu * *vi + gt + hp.wd * mi * *wi;
                    *vi = vn;
                    let dw = -hp.eta * vn;
                    *oi = dw;
                    *wi += di + dw;
                }
                lo = hi;
            }
        }
        (Some(d), None) => {
            let mut lo = 0;
            while lo < n {
                let hi = (lo + cw).min(n);
                let rd = g[lo..hi].iter().zip(&d[lo..hi]);
                let wr = v[lo..hi]
                    .iter_mut()
                    .zip(w[lo..hi].iter_mut())
                    .zip(delta_w_out[lo..hi].iter_mut());
                for ((gi, di), ((vi, wi), oi)) in rd.zip(wr) {
                    let gt = gi + lam * gi * gi * di;
                    let vn = hp.mu * *vi + gt + hp.wd * *wi;
                    *vi = vn;
                    let dw = -hp.eta * vn;
                    *oi = dw;
                    *wi += di + dw;
                }
                lo = hi;
            }
        }
        (None, Some(m)) => {
            let mut lo = 0;
            while lo < n {
                let hi = (lo + cw).min(n);
                let rd = g[lo..hi].iter().zip(&m[lo..hi]);
                let wr = v[lo..hi]
                    .iter_mut()
                    .zip(w[lo..hi].iter_mut())
                    .zip(delta_w_out[lo..hi].iter_mut());
                for ((gi, mi), ((vi, wi), oi)) in rd.zip(wr) {
                    let vn = hp.mu * *vi + gi + hp.wd * mi * *wi;
                    *vi = vn;
                    let dw = -hp.eta * vn;
                    *oi = dw;
                    *wi += dw;
                }
                lo = hi;
            }
        }
        (None, None) => {
            let mut lo = 0;
            while lo < n {
                let hi = (lo + cw).min(n);
                let wr = v[lo..hi]
                    .iter_mut()
                    .zip(w[lo..hi].iter_mut())
                    .zip(delta_w_out[lo..hi].iter_mut());
                for (gi, ((vi, wi), oi)) in g[lo..hi].iter().zip(wr) {
                    let vn = hp.mu * *vi + gi + hp.wd * *wi;
                    *vi = vn;
                    let dw = -hp.eta * vn;
                    *oi = dw;
                    *wi += dw;
                }
                lo = hi;
            }
        }
    }

    DcStepInfo { lam, grad_norm, corr_denom, update_norm: tensor::norm2(delta_w_out) }
}

/// Eq. 9: `D_i = Δ̄w/N − Δw_i`, computed from the all-reduced sum of
/// updates and the local update.
pub fn distance_to_average(sum_delta: &[f32], local_delta: &[f32], n_workers: usize, out: &mut [f32]) {
    assert_eq!(sum_delta.len(), local_delta.len());
    assert_eq!(sum_delta.len(), out.len());
    let inv_n = 1.0 / n_workers as f32;
    let cw = crate::exec::pin_chunk();
    let mut lo = 0;
    while lo < out.len() {
        let hi = (lo + cw).min(out.len());
        for ((o, s), l) in out[lo..hi].iter_mut().zip(&sum_delta[lo..hi]).zip(&local_delta[lo..hi])
        {
            *o = s * inv_n - l;
        }
        lo = hi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randvec(seed: u64, n: usize) -> Vec<f32> {
        let mut r = Rng::new(seed);
        let mut v = vec![0.0; n];
        r.fill_normal(&mut v);
        v
    }

    #[test]
    fn lambda_guard_zero_distance() {
        let g = randvec(1, 100);
        let d = vec![0.0; 100];
        assert_eq!(dynamic_lambda(&g, &d, 0.2), 0.0);
    }

    #[test]
    fn lambda_normalizes_correction() {
        // Eq. 17 by construction: ‖λ g⊙g⊙D‖ == λ0 ‖g‖.
        let g = randvec(2, 500);
        let d = randvec(3, 500);
        let lam = dynamic_lambda(&g, &d, 0.2);
        let mut corr = vec![0.0; 500];
        for i in 0..500 {
            corr[i] = lam * g[i] * g[i] * d[i];
        }
        let want = 0.2 * tensor::norm2(&g);
        assert!((tensor::norm2(&corr) - want).abs() / want < 1e-5);
    }

    #[test]
    fn fused_matches_unfused() {
        let n = 333;
        let g = randvec(4, n);
        let d = randvec(5, n);
        let v0 = randvec(6, n);
        let w0 = randvec(7, n);
        let hp = DcHyper { eta: 0.1, mu: 0.9, lam0: 0.2, wd: 1e-4 };

        // fused
        let (mut v, mut w, mut dw) = (v0.clone(), w0.clone(), vec![0.0; n]);
        let info = dc_correct_update(&g, Some(&d), &mut v, &mut w, None, hp, &mut dw);

        // unfused reference
        let lam = dynamic_lambda(&g, &d, hp.lam0);
        assert!((lam - info.lam).abs() < 1e-6);
        let mut gt = vec![0.0; n];
        dc_correct(&g, &d, lam, &mut gt);
        for i in 0..n {
            let vn = hp.mu * v0[i] + gt[i] + hp.wd * w0[i];
            let dwi = -hp.eta * vn;
            assert!((v[i] - vn).abs() < 1e-6, "v[{i}]");
            assert!((dw[i] - dwi).abs() < 1e-6, "dw[{i}]");
            assert!((w[i] - (w0[i] + d[i] + dwi)).abs() < 1e-6, "w[{i}]");
        }
    }

    #[test]
    fn no_distance_is_plain_momentum_sgd() {
        let n = 64;
        let g = randvec(8, n);
        let v0 = randvec(9, n);
        let w0 = randvec(10, n);
        let hp = DcHyper { eta: 0.5, mu: 0.8, lam0: 0.2, wd: 0.0 };
        let (mut v, mut w, mut dw) = (v0.clone(), w0.clone(), vec![0.0; n]);
        let info = dc_correct_update(&g, None, &mut v, &mut w, None, hp, &mut dw);
        assert_eq!(info.lam, 0.0);
        for i in 0..n {
            let vn = 0.8 * v0[i] + g[i];
            assert!((v[i] - vn).abs() < 1e-6);
            assert!((w[i] - (w0[i] - 0.5 * vn)).abs() < 1e-6);
        }
    }

    #[test]
    fn decay_mask_exempts_elements() {
        let n = 8;
        let g = vec![0.0; n]; // isolate the decay term
        let v0 = vec![0.0; n];
        let w0 = vec![1.0; n];
        let mut mask = vec![1.0; n];
        mask[3] = 0.0;
        mask[7] = 0.0;
        let hp = DcHyper { eta: 1.0, mu: 0.0, lam0: 0.0, wd: 0.1 };
        let (mut v, mut w, mut dw) = (v0, w0.clone(), vec![0.0; n]);
        dc_correct_update(&g, None, &mut v, &mut w, Some(&mask), hp, &mut dw);
        for i in 0..n {
            let expect = if mask[i] == 1.0 { 1.0 - 0.1 } else { 1.0 };
            assert!((w[i] - expect).abs() < 1e-6, "w[{i}]={}", w[i]);
        }
    }

    #[test]
    fn comp_ratio_sits_at_lam0_when_uncapped() {
        let n = 500;
        let g = randvec(30, n);
        let d = randvec(31, n);
        let hp = DcHyper { eta: 0.1, mu: 0.9, lam0: 0.2, wd: 0.0 };
        let (mut v, mut w, mut dw) = (vec![0.0; n], randvec(32, n), vec![0.0; n]);
        let info = dc_correct_update(&g, Some(&d), &mut v, &mut w, None, hp, &mut dw);
        // Eq. 17 normalizes the correction to λ0‖g‖, so the ratio is λ0.
        assert!((info.comp_ratio() - 0.2).abs() < 1e-5, "{}", info.comp_ratio());
        assert!(info.corr_denom > 0.0);

        // Compensation off → ratio 0, denominator 0.
        let (mut v, mut w, mut dw) = (vec![0.0; n], randvec(33, n), vec![0.0; n]);
        let info = dc_correct_update(&g, None, &mut v, &mut w, None, hp, &mut dw);
        assert_eq!(info.comp_ratio(), 0.0);
        assert_eq!(info.corr_denom, 0.0);
    }

    #[test]
    fn distance_to_average_eq9() {
        // 3 workers with known updates; D_i = mean(Δw) − Δw_i.
        let d1 = vec![1.0, 0.0];
        let d2 = vec![0.0, 3.0];
        let d3 = vec![2.0, 3.0];
        let sum: Vec<f32> = (0..2).map(|i| d1[i] + d2[i] + d3[i]).collect();
        let mut out = vec![0.0; 2];
        distance_to_average(&sum, &d1, 3, &mut out);
        assert_eq!(out, vec![0.0, 2.0]);
        distance_to_average(&sum, &d3, 3, &mut out);
        assert_eq!(out, vec![-1.0, -1.0]);
    }

    #[test]
    fn averaging_identity_eq8() {
        // After every worker applies w_i + D_i, all workers agree and the
        // common value equals w̄ + mean(Δw) — the Eq. 8 invariant the
        // algorithm's correctness rests on.
        let n = 50;
        let n_workers = 4;
        let w_bar = randvec(11, n);
        let deltas: Vec<Vec<f32>> = (0..n_workers).map(|i| randvec(20 + i as u64, n)).collect();
        let mut sum = vec![0.0; n];
        for d in &deltas {
            tensor::add_assign(&mut sum, d);
        }
        let mut reached: Vec<Vec<f32>> = Vec::new();
        for d in &deltas {
            // worker state: w_i = w̄ + Δw_i  (Eq. 7)
            let mut wi: Vec<f32> = w_bar.iter().zip(d).map(|(a, b)| a + b).collect();
            let mut dist = vec![0.0; n];
            distance_to_average(&sum, d, n_workers, &mut dist);
            tensor::add_assign(&mut wi, &dist); // w_i + D_i
            reached.push(wi);
        }
        for i in 0..n {
            let want = w_bar[i] + sum[i] / n_workers as f32;
            for r in &reached {
                assert!((r[i] - want).abs() < 1e-5);
            }
        }
    }
}
