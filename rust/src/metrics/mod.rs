//! Metrics: per-step records, epoch summaries, CSV/JSON export, the
//! Table-I-style report rows, and the comm-phase accounting that
//! reports where t_AR was spent (local vs global links).

use std::collections::BTreeMap;
use std::fs;
use std::io::Write;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::Result;

/// Run-level aggregate of the collective phase split: how much of the
/// run's all-reduce time was spent on intra-group (local) vs
/// inter-group (global) links, over how many collectives, and how often
/// the control plane switched schedules. Derived from the control log's
/// decision trace and exported under the run JSON's `"comm"` key.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommPhaseSummary {
    pub local_s: f64,
    pub global_s: f64,
    pub rounds: u64,
    pub schedule_switches: usize,
    /// Windows that ran their schedule as a control-plane **probe** of
    /// a non-active candidate (counted into `rounds` and the phase
    /// totals, excluded from `schedule_switches`). Exported as the
    /// nested `"probe"` summary of the run JSON's `"comm"` key.
    pub probe_rounds: u64,
}

impl CommPhaseSummary {
    pub fn total_s(&self) -> f64 {
        self.local_s + self.global_s
    }

    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        let num = |x: f64| if x.is_finite() { Json::Num(x) } else { Json::Null };
        let mut m = BTreeMap::new();
        m.insert("local_s".to_string(), num(self.local_s));
        m.insert("global_s".into(), num(self.global_s));
        m.insert("total_s".into(), num(self.total_s()));
        m.insert("rounds".into(), Json::Num(self.rounds as f64));
        m.insert("schedule_switches".into(), Json::Num(self.schedule_switches as f64));
        let mut probe = BTreeMap::new();
        probe.insert("rounds".to_string(), Json::Num(self.probe_rounds as f64));
        m.insert("probe".into(), Json::Obj(probe));
        Json::Obj(m)
    }
}

/// Run-level aggregate of the gradient-compression accounting: which
/// compressor the run rode, how many compressed collectives it
/// completed, the total achieved per-rank wire bytes, and how the
/// `compress_coupled` policy moved the ratio. Derived from the control
/// log's decision trace and exported under the run JSON's `"compress"`
/// key.
#[derive(Debug, Clone, PartialEq)]
pub struct CompressSummary {
    /// Compressor name ("none" | "topk" | "qsgd").
    pub kind: String,
    /// Collective rounds counted into the totals.
    pub rounds: u64,
    /// Sum of per-rank wire payload bytes across the counted rounds.
    pub wire_bytes_total: f64,
    /// How often the active ratio changed along the trace (the
    /// `compress_coupled` decision count).
    pub ratio_changes: usize,
    /// The ratio in force at the end of the run (wire fraction).
    pub final_ratio: f64,
}

impl Default for CompressSummary {
    fn default() -> Self {
        CompressSummary {
            kind: "none".to_string(),
            rounds: 0,
            wire_bytes_total: 0.0,
            ratio_changes: 0,
            final_ratio: 1.0,
        }
    }
}

impl CompressSummary {
    /// Mean per-rank wire bytes per counted round.
    pub fn mean_wire_bytes(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.wire_bytes_total / self.rounds as f64
        }
    }

    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        let num = |x: f64| if x.is_finite() { Json::Num(x) } else { Json::Null };
        let mut m = BTreeMap::new();
        m.insert("kind".to_string(), Json::Str(self.kind.clone()));
        m.insert("rounds".into(), Json::Num(self.rounds as f64));
        m.insert("wire_bytes_total".into(), num(self.wire_bytes_total));
        m.insert("mean_wire_bytes".into(), num(self.mean_wire_bytes()));
        m.insert("ratio_changes".into(), Json::Num(self.ratio_changes as f64));
        m.insert("final_ratio".into(), num(self.final_ratio));
        Json::Obj(m)
    }
}

/// One training-step record from one worker.
#[derive(Debug, Clone, Copy)]
pub struct StepRecord {
    pub worker: usize,
    pub iteration: u64,
    pub epoch: u64,
    /// Worker virtual time at the end of the step (seconds).
    pub sim_time: f64,
    /// Wall-clock spent in the backend's train_step (seconds).
    pub wall_compute: f64,
    pub loss: f32,
    pub train_err: f32,
    /// λ_i used this step (0 when no correction was applied).
    pub lambda: f32,
    /// ‖D_i‖ — distance to the average weights (Eq. 9), the paper's
    /// §III-D.2 growth metric.
    pub dist_to_avg: f64,
    pub lr: f32,
}

/// One validation pass record.
#[derive(Debug, Clone, Copy)]
pub struct EvalRecord {
    pub iteration: u64,
    pub epoch: u64,
    pub sim_time: f64,
    pub val_loss: f32,
    pub val_err: f32,
}

/// Thread-safe recorder shared by all workers of a run.
#[derive(Clone, Default, Debug)]
pub struct Recorder {
    inner: Arc<Mutex<Inner>>,
}

#[derive(Default, Debug)]
struct Inner {
    steps: Vec<StepRecord>,
    evals: Vec<EvalRecord>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_step(&self, r: StepRecord) {
        self.inner.lock().unwrap().steps.push(r);
    }

    pub fn record_eval(&self, r: EvalRecord) {
        self.inner.lock().unwrap().evals.push(r);
    }

    pub fn n_steps(&self) -> usize {
        self.inner.lock().unwrap().steps.len()
    }

    pub fn steps(&self) -> Vec<StepRecord> {
        self.inner.lock().unwrap().steps.clone()
    }

    pub fn evals(&self) -> Vec<EvalRecord> {
        self.inner.lock().unwrap().evals.clone()
    }

    /// Steps sorted by (iteration, worker) — thread-interleaving-free
    /// view used by all aggregates, so reports are deterministic.
    fn sorted_steps(&self) -> Vec<StepRecord> {
        let mut steps = self.inner.lock().unwrap().steps.clone();
        steps.sort_by_key(|r| (r.iteration, r.worker));
        steps
    }

    /// Mean training loss/error over the last `k` recorded steps (in
    /// iteration order, not arrival order).
    pub fn tail_train(&self, k: usize) -> (f32, f32) {
        let steps = self.sorted_steps();
        let n = steps.len();
        if n == 0 {
            return (f32::NAN, f32::NAN);
        }
        let tail = &steps[n.saturating_sub(k)..];
        let loss = tail.iter().map(|r| r.loss).sum::<f32>() / tail.len() as f32;
        let err = tail.iter().map(|r| r.train_err).sum::<f32>() / tail.len() as f32;
        (loss, err)
    }

    /// Latest eval error, if any.
    pub fn last_val_err(&self) -> Option<f32> {
        self.inner.lock().unwrap().evals.last().map(|e| e.val_err)
    }

    /// Best (minimum) validation error seen.
    pub fn best_val_err(&self) -> Option<f32> {
        self.inner
            .lock()
            .unwrap()
            .evals
            .iter()
            .map(|e| e.val_err)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Simulated throughput in samples/second over the recorded run:
    /// total samples / max worker sim time (the Table I "Speed" column).
    pub fn sim_throughput(&self, local_batch: usize) -> f64 {
        let inner = self.inner.lock().unwrap();
        if inner.steps.is_empty() {
            return 0.0;
        }
        let total_samples = inner.steps.len() * local_batch;
        let t_end = inner.steps.iter().map(|r| r.sim_time).fold(0.0, f64::max);
        if t_end <= 0.0 {
            return 0.0;
        }
        total_samples as f64 / t_end
    }

    /// Mean per-iteration sim time (for the Eq. 13/14 comparison):
    /// max worker sim time / iterations per worker.
    pub fn mean_iter_time(&self) -> f64 {
        let inner = self.inner.lock().unwrap();
        if inner.steps.is_empty() {
            return 0.0;
        }
        let workers = inner.steps.iter().map(|r| r.worker).max().unwrap() + 1;
        let iters = inner.steps.len() / workers;
        let t_end = inner.steps.iter().map(|r| r.sim_time).fold(0.0, f64::max);
        t_end / iters.max(1) as f64
    }

    /// Mean training loss over recorded steps with iteration in
    /// `[lo, hi)`, across all workers — NaN when the range is empty.
    /// Used by the membership tests to assert loss *continuity* across
    /// an epoch boundary (the re-synced cluster must not regress).
    pub fn mean_loss_between(&self, lo: u64, hi: u64) -> f32 {
        let inner = self.inner.lock().unwrap();
        let mut sum = 0f64;
        let mut count = 0usize;
        for r in &inner.steps {
            if r.iteration >= lo && r.iteration < hi {
                sum += r.loss as f64;
                count += 1;
            }
        }
        if count == 0 {
            f32::NAN
        } else {
            (sum / count as f64) as f32
        }
    }

    /// Mean ‖D_i‖ over the last `k` steps in iteration order (E4).
    pub fn tail_dist_to_avg(&self, k: usize) -> f64 {
        let steps = self.sorted_steps();
        let n = steps.len();
        if n == 0 {
            return 0.0;
        }
        let tail = &steps[n.saturating_sub(k)..];
        tail.iter().map(|r| r.dist_to_avg).sum::<f64>() / tail.len() as f64
    }

    /// Per-epoch mean train error (Figure 1's training curves).
    pub fn epoch_train_err(&self) -> BTreeMap<u64, f32> {
        let inner = self.inner.lock().unwrap();
        let mut acc: BTreeMap<u64, (f64, usize)> = BTreeMap::new();
        for r in &inner.steps {
            let e = acc.entry(r.epoch).or_insert((0.0, 0));
            e.0 += r.train_err as f64;
            e.1 += 1;
        }
        acc.into_iter().map(|(k, (s, n))| (k, (s / n as f64) as f32)).collect()
    }

    /// Write steps as CSV.
    pub fn write_steps_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        let inner = self.inner.lock().unwrap();
        let mut f = fs::File::create(path)?;
        writeln!(
            f,
            "worker,iteration,epoch,sim_time,wall_compute,loss,train_err,lambda,dist_to_avg,lr"
        )?;
        for r in &inner.steps {
            writeln!(
                f,
                "{},{},{},{:.6},{:.6},{:.6},{:.4},{:.6},{:.6e},{:.6}",
                r.worker,
                r.iteration,
                r.epoch,
                r.sim_time,
                r.wall_compute,
                r.loss,
                r.train_err,
                r.lambda,
                r.dist_to_avg,
                r.lr
            )?;
        }
        Ok(())
    }

    /// The validation curve as a JSON array (part of the run's metrics
    /// JSON export, next to the control plane's decision trace).
    pub fn evals_json(&self) -> crate::util::Json {
        use crate::util::Json;
        // NaN/∞ (diverged runs) have no JSON representation → null.
        let num = |x: f64| if x.is_finite() { Json::Num(x) } else { Json::Null };
        let evals = self.evals();
        Json::Arr(
            evals
                .iter()
                .map(|e| {
                    let mut m = std::collections::BTreeMap::new();
                    m.insert("iteration".to_string(), Json::Num(e.iteration as f64));
                    m.insert("epoch".into(), Json::Num(e.epoch as f64));
                    m.insert("sim_time".into(), num(e.sim_time));
                    m.insert("val_loss".into(), num(e.val_loss as f64));
                    m.insert("val_err".into(), num(e.val_err as f64));
                    Json::Obj(m)
                })
                .collect(),
        )
    }

    /// Write evals as CSV.
    pub fn write_evals_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        let inner = self.inner.lock().unwrap();
        let mut f = fs::File::create(path)?;
        writeln!(f, "iteration,epoch,sim_time,val_loss,val_err")?;
        for r in &inner.evals {
            writeln!(
                f,
                "{},{},{:.6},{:.6},{:.4}",
                r.iteration, r.epoch, r.sim_time, r.val_loss, r.val_err
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(worker: usize, it: u64, epoch: u64, sim: f64, err: f32) -> StepRecord {
        StepRecord {
            worker,
            iteration: it,
            epoch,
            sim_time: sim,
            wall_compute: 0.01,
            loss: 1.0,
            train_err: err,
            lambda: 0.0,
            dist_to_avg: 0.1,
            lr: 0.1,
        }
    }

    #[test]
    fn throughput_uses_max_sim_time() {
        let rec = Recorder::new();
        // 2 workers × 3 iterations × batch 10, finishing at t=6.
        for w in 0..2 {
            for it in 0..3 {
                rec.record_step(step(w, it, 0, (it + 1) as f64 * 2.0, 0.5));
            }
        }
        // 60 samples / 6 s = 10 samples/s
        assert!((rec.sim_throughput(10) - 10.0).abs() < 1e-12);
        assert!((rec.mean_iter_time() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn tail_and_epoch_aggregates() {
        let rec = Recorder::new();
        rec.record_step(step(0, 0, 0, 1.0, 1.0));
        rec.record_step(step(0, 1, 0, 2.0, 0.5));
        rec.record_step(step(0, 2, 1, 3.0, 0.2));
        let (_, err) = rec.tail_train(2);
        assert!((err - 0.35).abs() < 1e-6);
        let by_epoch = rec.epoch_train_err();
        assert!((by_epoch[&0] - 0.75).abs() < 1e-6);
        assert!((by_epoch[&1] - 0.2).abs() < 1e-6);
    }

    #[test]
    fn mean_loss_between_windows() {
        let rec = Recorder::new();
        for (it, loss) in [(0u64, 4.0f32), (1, 2.0), (2, 1.0), (3, 0.5)] {
            let mut s = step(0, it, 0, it as f64, 0.5);
            s.loss = loss;
            rec.record_step(s);
        }
        assert!((rec.mean_loss_between(0, 2) - 3.0).abs() < 1e-6);
        assert!((rec.mean_loss_between(2, 4) - 0.75).abs() < 1e-6);
        assert!(rec.mean_loss_between(10, 20).is_nan());
    }

    #[test]
    fn eval_tracking() {
        let rec = Recorder::new();
        assert!(rec.last_val_err().is_none());
        rec.record_eval(EvalRecord { iteration: 10, epoch: 0, sim_time: 1.0, val_loss: 2.0, val_err: 0.8 });
        rec.record_eval(EvalRecord { iteration: 20, epoch: 1, sim_time: 2.0, val_loss: 1.0, val_err: 0.4 });
        rec.record_eval(EvalRecord { iteration: 30, epoch: 2, sim_time: 3.0, val_loss: 1.5, val_err: 0.6 });
        assert_eq!(rec.last_val_err(), Some(0.6));
        assert_eq!(rec.best_val_err(), Some(0.4));
    }

    #[test]
    fn evals_export_as_json() {
        let rec = Recorder::new();
        rec.record_eval(EvalRecord { iteration: 10, epoch: 0, sim_time: 1.5, val_loss: 2.0, val_err: 0.8 });
        let j = rec.evals_json();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("iteration").unwrap().as_f64(), Some(10.0));
        let err = arr[0].get("val_err").unwrap().as_f64().unwrap();
        assert!((err - 0.8).abs() < 1e-6, "val_err {err}");
        // must reparse as valid JSON
        assert!(crate::util::Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn comm_phase_summary_json() {
        let s = CommPhaseSummary {
            local_s: 0.3,
            global_s: 0.7,
            rounds: 10,
            schedule_switches: 1,
            probe_rounds: 2,
        };
        assert!((s.total_s() - 1.0).abs() < 1e-12);
        let j = s.to_json();
        assert_eq!(j.get("rounds").unwrap().as_f64(), Some(10.0));
        assert_eq!(j.get("total_s").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("probe").unwrap().get("rounds").unwrap().as_f64(), Some(2.0));
        assert!(crate::util::Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn compress_summary_json() {
        let s = CompressSummary {
            kind: "topk".into(),
            rounds: 4,
            wire_bytes_total: 800.0,
            ratio_changes: 2,
            final_ratio: 0.05,
        };
        assert!((s.mean_wire_bytes() - 200.0).abs() < 1e-12);
        let j = s.to_json();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("topk"));
        assert_eq!(j.get("mean_wire_bytes").unwrap().as_f64(), Some(200.0));
        assert!(crate::util::Json::parse(&j.to_string()).is_ok());
        assert_eq!(CompressSummary::default().kind, "none");
        assert_eq!(CompressSummary::default().mean_wire_bytes(), 0.0);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let rec = Recorder::new();
        rec.record_step(step(0, 0, 0, 1.0, 0.5));
        let p = std::env::temp_dir().join(format!("dcs3gd_steps_{}.csv", std::process::id()));
        rec.write_steps_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.starts_with("worker,iteration"));
        std::fs::remove_file(&p).unwrap();
    }
}
