//! Parallel engine core: the scoped-thread worker pool that steps ranks
//! concurrently between virtual-time rendezvous points, plus the
//! per-phase wall-time profiler behind the run JSON's `"perf"` block.
//!
//! ## Execution model
//!
//! Every engine rank is one scoped OS thread (the rendezvous substrate
//! in [`crate::comm`] blocks ranks on condvars, so rank bodies keep
//! their natural blocking control flow), but at most `threads` of them
//! are **runnable** at any instant: each rank holds an execution
//! [`Gate`] permit while it computes, and every blocking point — a
//! collective wait, a join admission, a parameter-server round trip —
//! releases the permit for the wait's duration and reacquires it before
//! resuming compute. The pool is therefore a cooperative scheduler:
//! `threads = 1` is the true serial engine (one rank computes at a
//! time, zero compute-side parallelism — the differential-testing
//! baseline), `threads = T` steps up to T ranks concurrently, and
//! `threads = 0` auto-detects the host's parallelism.
//!
//! ## Determinism contract
//!
//! The permit schedule decides only *when* a rank runs, never what it
//! computes: all cross-rank merges resolve inside the rendezvous
//! substrate in ascending rank order at round boundaries (virtual-time
//! order), every per-rank random draw is keyed `(seed, rank, round)`,
//! and shared-log aggregates sort by `(iteration, worker)` before
//! summarizing. Run results are therefore **bit-identical** for every
//! `threads` value — pinned by `prop_parallel_engine_bitwise_equals_serial`
//! and the `benches/engine.rs` differential lane. See
//! `docs/performance.md` for the full contract.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::util::Json;

/// The `[perf]` table of an experiment config: engine-core knobs that
/// change wall-clock only, never results.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PerfConfig {
    /// Concurrently runnable ranks. `0` = auto-detect the host's
    /// available parallelism; `1` = the serial reference engine.
    pub threads: usize,
    /// Element-chunk width the vectorized kernels block their loops at
    /// (`0` = the built-in [`DEFAULT_PIN_CHUNK`]). Pinned independent of
    /// `threads` so the dyadic-exact reduction order — and therefore
    /// every golden fixture and FNV CRC — never moves. Must be a power
    /// of two ≤ 4096.
    pub pin_chunk: usize,
}

impl Default for PerfConfig {
    fn default() -> Self {
        PerfConfig { threads: 0, pin_chunk: 0 }
    }
}

impl PerfConfig {
    pub fn validate(&self) -> Result<()> {
        if self.pin_chunk != 0 && (!self.pin_chunk.is_power_of_two() || self.pin_chunk > 4096) {
            bail!(
                "perf.pin_chunk must be 0 (default) or a power of two <= 4096, got {}",
                self.pin_chunk
            );
        }
        Ok(())
    }

    /// The runnable-rank budget this config resolves to on this host.
    pub fn resolved_threads(&self) -> usize {
        resolve_threads(self.threads)
    }
}

/// `threads = 0` resolved against the host.
pub fn resolve_threads(threads: usize) -> usize {
    if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    }
}

/// Default kernel chunk width (f32 elements): two 128-bit lanes, wide
/// enough for the autovectorizer, small enough to stay in registers.
pub const DEFAULT_PIN_CHUNK: usize = 8;

static PIN_CHUNK: AtomicUsize = AtomicUsize::new(DEFAULT_PIN_CHUNK);

/// Install the kernel chunk width for this process (`0` = default).
/// Bit-neutral by construction — the chunk blocks elementwise loops
/// only; reduction lane counts are pinned separately (see
/// [`crate::tensor`]).
pub fn set_pin_chunk(chunk: usize) {
    let c = if chunk == 0 { DEFAULT_PIN_CHUNK } else { chunk };
    PIN_CHUNK.store(c, Ordering::Relaxed);
}

/// The current kernel chunk width.
pub fn pin_chunk() -> usize {
    PIN_CHUNK.load(Ordering::Relaxed)
}

/// Serializes tests that set and then read back the process-global
/// chunk width (results are bit-identical at every width, so only
/// exact-readback assertions need this).
#[cfg(test)]
pub(crate) static PIN_CHUNK_TEST_LOCK: Mutex<()> = Mutex::new(());

// ---------------------------------------------------------------------
// Gate: the counting permit that bounds runnable ranks
// ---------------------------------------------------------------------

struct GateState {
    available: usize,
}

/// Counting execution permits. A rank holds one permit while computing;
/// the rendezvous substrate releases it across every blocking wait (see
/// [`crate::comm::PendingReduce::wait_outcome`]) so blocked ranks never
/// occupy a runnable slot. [`Gate::unlimited`] is the zero-overhead
/// pass-through used by every non-pooled caller (unit tests, raw
/// [`crate::comm::Group`] users).
pub struct Gate {
    limit: usize,
    state: Mutex<GateState>,
    cv: Condvar,
}

impl Gate {
    /// A gate admitting `limit` concurrently runnable ranks.
    pub fn new(limit: usize) -> Arc<Gate> {
        let limit = limit.max(1);
        Arc::new(Gate {
            limit,
            state: Mutex::new(GateState { available: limit }),
            cv: Condvar::new(),
        })
    }

    /// The no-op gate: every acquire succeeds immediately.
    pub fn unlimited() -> Arc<Gate> {
        Arc::new(Gate {
            limit: usize::MAX,
            state: Mutex::new(GateState { available: usize::MAX }),
            cv: Condvar::new(),
        })
    }

    /// Whether this gate actually bounds concurrency.
    pub fn is_bounding(&self) -> bool {
        self.limit != usize::MAX
    }

    /// The permit budget.
    pub fn limit(&self) -> usize {
        self.limit
    }

    /// Block until a permit is free, then take it.
    pub fn acquire(&self) {
        if !self.is_bounding() {
            return;
        }
        let mut st = self.state.lock().unwrap();
        while st.available == 0 {
            st = self.cv.wait(st).unwrap();
        }
        st.available -= 1;
    }

    /// Return a permit. Callers must pair every release with a prior
    /// acquire (the substrate's wait points and the pool's RAII guard
    /// both do).
    pub fn release(&self) {
        if !self.is_bounding() {
            return;
        }
        let mut st = self.state.lock().unwrap();
        debug_assert!(st.available < self.limit, "gate release without matching acquire");
        st.available += 1;
        drop(st);
        self.cv.notify_one();
    }

    /// Acquire a permit held for the returned guard's lifetime.
    pub fn permit(self: &Arc<Gate>) -> Permit {
        self.acquire();
        Permit { gate: self.clone() }
    }
}

/// RAII permit handle — a rank body holds one for its whole lifetime;
/// the substrate's blocking waits release/reacquire underneath it.
pub struct Permit {
    gate: Arc<Gate>,
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.gate.release();
    }
}

// ---------------------------------------------------------------------
// Pool: scoped-thread rank spawning under one gate
// ---------------------------------------------------------------------

/// The engine worker pool: one scoped thread per rank, all gated by a
/// shared [`Gate`] sized from [`PerfConfig::threads`].
pub struct Pool {
    gate: Arc<Gate>,
    threads: usize,
}

impl Pool {
    /// Build from the run's `[perf]` table. Also installs the kernel
    /// chunk width (process-global, bit-neutral).
    pub fn from_config(perf: &PerfConfig) -> Pool {
        set_pin_chunk(perf.pin_chunk);
        let threads = perf.resolved_threads();
        Pool { gate: Gate::new(threads), threads }
    }

    /// The resolved runnable-rank budget.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The gate rank bodies and the rendezvous substrate share.
    pub fn gate(&self) -> Arc<Gate> {
        self.gate.clone()
    }

    /// Run `body(rank)` for every rank on its own scoped thread, at
    /// most [`Pool::threads`] runnable at once. Returns the bodies'
    /// results in rank order. Blocking points inside `body` must route
    /// through gate-aware primitives (the [`crate::comm`] waits and the
    /// [`crate::ps`] client do) or the permit budget can deadlock the
    /// scope — plain compute needs no care.
    pub fn run<R, F>(&self, ranks: usize, body: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        let gate = &self.gate;
        let body = &body;
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..ranks)
                .map(|rank| {
                    s.spawn(move || {
                        let _permit = gate.permit();
                        body(rank)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("rank thread panicked")).collect()
        })
    }
}

// ---------------------------------------------------------------------
// Profiler: per-phase wall-time histograms behind the "perf" run key
// ---------------------------------------------------------------------

/// Engine phases the profiler attributes wall time to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Local training steps (forward/backward + optimizer-side math).
    Compute,
    /// Window compression + wire assembly.
    Encode,
    /// Blocked on a rendezvous round (or a PS round trip).
    CommWait,
    /// Round decode + Eq. 9 distance.
    Decode,
    /// The fused Eq. 10–12 / momentum parameter update.
    Update,
    /// Validation passes.
    Eval,
}

impl Phase {
    /// Export order (fixed — the run JSON must be deterministic).
    pub const ALL: [Phase; 6] =
        [Phase::Compute, Phase::Encode, Phase::CommWait, Phase::Decode, Phase::Update, Phase::Eval];

    pub fn name(&self) -> &'static str {
        match self {
            Phase::Compute => "compute",
            Phase::Encode => "encode",
            Phase::CommWait => "comm_wait",
            Phase::Decode => "decode",
            Phase::Update => "update",
            Phase::Eval => "eval",
        }
    }

    fn index(&self) -> usize {
        *self as usize
    }
}

/// Log₂ histogram buckets: bucket `i` holds durations in
/// `[2^i, 2^(i+1))` µs; the last bucket is open-ended.
pub const HIST_BUCKETS: usize = 20;

/// The log₂(µs) bucket a `us`-microsecond duration lands in — the one
/// histogram shape shared by the `"perf"` profiler here and the
/// [`crate::obs`] metric registry (`"obs"` histograms).
pub fn log2_us_bucket(us: u64) -> usize {
    if us == 0 {
        0
    } else {
        (63 - us.leading_zeros() as usize).min(HIST_BUCKETS - 1)
    }
}

#[derive(Debug, Clone)]
struct PhaseAccum {
    count: u64,
    total_s: f64,
    max_s: f64,
    hist: [u64; HIST_BUCKETS],
}

impl PhaseAccum {
    fn new() -> Self {
        PhaseAccum { count: 0, total_s: 0.0, max_s: 0.0, hist: [0; HIST_BUCKETS] }
    }

    fn add(&mut self, d: Duration) {
        let s = d.as_secs_f64();
        self.count += 1;
        self.total_s += s;
        self.max_s = self.max_s.max(s);
        self.hist[log2_us_bucket(d.as_micros() as u64)] += 1;
    }

    fn merge(&mut self, other: &PhaseAccum) {
        self.count += other.count;
        self.total_s += other.total_s;
        self.max_s = self.max_s.max(other.max_s);
        for (a, b) in self.hist.iter_mut().zip(&other.hist) {
            *a += b;
        }
    }

    fn to_json(&self, phase: Phase) -> Json {
        let mut m = BTreeMap::new();
        m.insert("phase".to_string(), Json::Str(phase.name().into()));
        m.insert("count".into(), Json::Num(self.count as f64));
        m.insert("total_s".into(), Json::Num(self.total_s));
        m.insert(
            "mean_s".into(),
            Json::Num(if self.count > 0 { self.total_s / self.count as f64 } else { 0.0 }),
        );
        m.insert("max_s".into(), Json::Num(self.max_s));
        // Trailing-zero-trimmed log₂(µs) histogram.
        let last = self.hist.iter().rposition(|&c| c > 0).map(|i| i + 1).unwrap_or(0);
        m.insert(
            "hist_log2_us".into(),
            Json::Arr(self.hist[..last].iter().map(|&c| Json::Num(c as f64)).collect()),
        );
        Json::Obj(m)
    }
}

/// Per-rank phase clock: accumulates locally (no shared state on the
/// hot path), merged into the shared [`Profiler`] once at rank exit.
pub struct PhaseClock {
    accum: Vec<PhaseAccum>,
}

impl Default for PhaseClock {
    fn default() -> Self {
        Self::new()
    }
}

impl PhaseClock {
    pub fn new() -> Self {
        PhaseClock { accum: Phase::ALL.iter().map(|_| PhaseAccum::new()).collect() }
    }

    /// Time `f` under `phase`.
    pub fn time<R>(&mut self, phase: Phase, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.accum[phase.index()].add(t0.elapsed());
        r
    }

    /// Record an externally measured duration.
    pub fn add(&mut self, phase: Phase, d: Duration) {
        self.accum[phase.index()].add(d);
    }
}

/// A rank's [`PhaseClock`] bound to the run [`Profiler`]: merges its
/// accumulators on drop, so every exit path of a rank body (normal
/// completion, departure, a join that never fired) folds its time in.
pub struct RankClock {
    clock: PhaseClock,
    profiler: Arc<Profiler>,
}

impl RankClock {
    pub fn new(profiler: Arc<Profiler>) -> RankClock {
        RankClock { clock: PhaseClock::new(), profiler }
    }

    /// Time `f` under `phase`.
    pub fn time<R>(&mut self, phase: Phase, f: impl FnOnce() -> R) -> R {
        self.clock.time(phase, f)
    }

    /// Record an externally measured duration.
    pub fn add(&mut self, phase: Phase, d: Duration) {
        self.clock.add(phase, d);
    }
}

impl Drop for RankClock {
    fn drop(&mut self) {
        self.profiler.merge(&self.clock);
    }
}

/// Shared run profiler: rank clocks merge in at exit; the engine
/// exports the merged histograms under the run JSON's `"perf"` key.
/// Wall-clock payloads are inherently nondeterministic — consumers
/// comparing runs for bit-identity must strip this block (see
/// `RunReport::deterministic_json`).
pub struct Profiler {
    threads: usize,
    pin_chunk: usize,
    merged: Mutex<Vec<PhaseAccum>>,
}

impl Profiler {
    pub fn new(threads: usize) -> Arc<Profiler> {
        Arc::new(Profiler {
            threads,
            pin_chunk: pin_chunk(),
            merged: Mutex::new(Phase::ALL.iter().map(|_| PhaseAccum::new()).collect()),
        })
    }

    /// Fold one rank's clock into the run totals.
    pub fn merge(&self, clock: &PhaseClock) {
        let mut m = self.merged.lock().unwrap();
        for (a, b) in m.iter_mut().zip(&clock.accum) {
            a.merge(b);
        }
    }

    /// The run JSON `"perf"` block.
    pub fn to_json(&self) -> Json {
        let m = self.merged.lock().unwrap();
        let mut obj = BTreeMap::new();
        obj.insert("threads".to_string(), Json::Num(self.threads as f64));
        obj.insert("pin_chunk".into(), Json::Num(self.pin_chunk as f64));
        obj.insert(
            "phases".into(),
            Json::Arr(Phase::ALL.iter().map(|&p| m[p.index()].to_json(p)).collect()),
        );
        Json::Obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn perf_config_validation() {
        PerfConfig::default().validate().unwrap();
        PerfConfig { threads: 7, pin_chunk: 16 }.validate().unwrap();
        assert!(PerfConfig { threads: 0, pin_chunk: 3 }.validate().is_err());
        assert!(PerfConfig { threads: 0, pin_chunk: 8192 }.validate().is_err());
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(5), 5);
    }

    #[test]
    fn gate_bounds_concurrency() {
        let gate = Gate::new(2);
        let live = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..8 {
                let (gate, live, peak) = (gate.clone(), live.clone(), peak.clone());
                s.spawn(move || {
                    let _p = gate.permit();
                    let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(now, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(2));
                    live.fetch_sub(1, Ordering::SeqCst);
                });
            }
        });
        assert!(peak.load(Ordering::SeqCst) <= 2, "gate admitted more than its limit");
    }

    #[test]
    fn unlimited_gate_is_passthrough() {
        let gate = Gate::unlimited();
        assert!(!gate.is_bounding());
        gate.acquire();
        gate.release();
        let _p = gate.permit();
    }

    #[test]
    fn pool_runs_every_rank_and_orders_results() {
        let _g = PIN_CHUNK_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let pool = Pool::from_config(&PerfConfig { threads: 3, pin_chunk: 0 });
        assert_eq!(pool.threads(), 3);
        let out = pool.run(17, |rank| rank * 2);
        assert_eq!(out, (0..17).map(|r| r * 2).collect::<Vec<_>>());
    }

    #[test]
    fn pool_release_across_blocking_waits_prevents_deadlock() {
        // More ranks than permits, every rank meeting at a rendezvous
        // round: without the wait-side release this deadlocks (the
        // permit holders would block on a round the parked ranks still
        // have to post).
        use crate::comm::{Group, NetModel};
        let _g = PIN_CHUNK_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let pool = Pool::from_config(&PerfConfig { threads: 2, pin_chunk: 0 });
        let n = 8;
        let group = Group::new(n, NetModel::instant());
        group.set_gate(pool.gate());
        let sums = pool.run(n, |rank| {
            let mut c = group.comm(rank);
            let (sum, _) = c.allreduce(&[rank as f32], 0.0);
            sum[0]
        });
        let expect: f32 = (0..n).map(|r| r as f32).sum();
        assert!(sums.iter().all(|&s| s == expect));
    }

    #[test]
    fn profiler_merges_and_exports() {
        let prof = Profiler::new(4);
        let mut clock = PhaseClock::new();
        clock.time(Phase::Compute, || std::thread::sleep(Duration::from_micros(100)));
        clock.add(Phase::CommWait, Duration::from_millis(1));
        prof.merge(&clock);
        let j = prof.to_json();
        assert_eq!(j.get("threads").unwrap().as_f64(), Some(4.0));
        let phases = match j.get("phases").unwrap() {
            Json::Arr(a) => a,
            _ => panic!("phases must be an array"),
        };
        assert_eq!(phases.len(), Phase::ALL.len());
        assert_eq!(phases[0].get("phase").unwrap().as_str(), Some("compute"));
        assert_eq!(phases[0].get("count").unwrap().as_f64(), Some(1.0));
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn pin_chunk_round_trips() {
        let _g = PIN_CHUNK_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_pin_chunk(16);
        assert_eq!(pin_chunk(), 16);
        set_pin_chunk(0);
        assert_eq!(pin_chunk(), DEFAULT_PIN_CHUNK);
    }
}
