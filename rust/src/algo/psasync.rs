//! Centralized asynchronous baselines: ASGD and DC-ASGD through the
//! parameter-server tier ([`crate::ps`]).
//!
//! Each worker loops: compute gradient on its current weights → push to
//! the PS → receive fresh weights (Eq. 15's t_W2PS round-trip, plus
//! queueing at the serialized server). Staleness arises naturally: by
//! the time a worker's gradient arrives, other workers have already
//! advanced the PS weights. DC-ASGD compensates at the server with the
//! worker-specific backup weights (§II-A / Zheng et al.); ASGD does not.
//! `ps.lambda = "adaptive"` swaps Eq. 17's global-norm λ for the
//! elementwise gradient-MSE variant (shard-invariant — see
//! [`crate::ps::PsMode::DcAsgdAdaptive`]).
//!
//! The engines now talk to the PS through [`crate::ps::PsTier`], which
//! layers three production behaviors over the shard actors:
//!
//! * **Compression** — a `[compress]` table rides each worker's
//!   [`crate::compress::WindowCodec`] through push *and* pull: the
//!   transfer is priced at the compressed wire volume, the tier decodes
//!   at ingress, and the shards apply DC-ASGD's correction over the
//!   *decompressed* payload — the same stacking order as the
//!   decentralized engines.
//! * **Sharding + replication** — `ps.shards` splits the parameter
//!   vector across independent actors (hosts staggered per shard),
//!   `ps.replicas` serves pulls from the nearest replica with
//!   read-coalescing; pushes always route to the epoch's primary, so
//!   weights stay bitwise equal to the single-home server
//!   ([`crate::ps::ReplicaPlan`]).
//! * **Elastic membership** — `[[control.fault]]` departures and
//!   `[[control.join]]` arrivals advance a membership epoch from the
//!   scripted roster schedule ([`crate::control::MembershipLog::
//!   roster_schedule`]). The schedule is a pure function of the config
//!   (virtual-time boundaries, identical on every rank), so — unlike
//!   the collective engines — no rendezvous is needed: each worker
//!   crosses a boundary on its own clock, reshards its data, rebinds
//!   its codec to the new (slot, world), and bumps its liveness
//!   incarnation. Joiners spin up at their `at_s`, bootstrap the
//!   canonical weights with a priced pull, and warm their LR up over
//!   `control.join_warmup_windows` steps. The epoch trace records one
//!   leader entry per epoch (PS weights are arrival-order dependent, so
//!   cross-rank checksum agreement is not part of the centralized
//!   contract the way it is for the collective engines).
//!
//! Chaos faults apply here too: slowdowns/stalls land in
//! `WorkerCtx::train_step` like everywhere else, and a scripted kill
//! costs the worker its detection + restore downtime before it rejoins
//! (its weights are refreshed by the next PS pull anyway — the PS is
//! the system of record, so there is no snapshot to restore).
//!
//! The schedule-aware comm refactor reaches this engine through the PS
//! transfer cost: when the run's `NetModel` carries the hierarchical
//! dragonfly schedule, the tier prices each worker's round-trip with
//! the topology-aware point-to-point model at the *actual* crossing
//! count of the epoch's roster — workers sharing the primary's group
//! ride the electrical links, everyone else crosses the optics
//! **contended** by every other remote worker's crossings into the PS
//! group ([`crate::comm::NetModel::ptp_time_between_flows`], sharing
//! the [`crate::comm::GlobalContention`] model with the collective
//! schedules). The many-to-few bottleneck the paper attributes to
//! centralized schemes thus gains both the placement asymmetry and the
//! tapered-fabric oversubscription a real dragonfly imposes.

use std::time::Instant;

use anyhow::Result;

use crate::algo::{Algo, RoundDriver, RunReport, WorkerHarness};
use crate::compress::CompressorKind;
use crate::config::{ExperimentConfig, PsLambda};
use crate::control::{param_crc, ControlRecord, EpochRecord, FaultKind};
use crate::exec::{Phase, RankClock};
use crate::obs::{EventKind, WindowRow};
use crate::optim::{build_optimizer, MomentumSgd, Optimizer};
use crate::ps::{PsMode, PsTier, PsTierSpec, ReplicaPlan};

pub fn run(cfg: &ExperimentConfig, harness: WorkerHarness) -> Result<RunReport> {
    let n = harness.n_params();
    // Engine pool: worker ranks share `perf.threads` permits; the PS
    // actors themselves stay ungated (they are service infrastructure,
    // not ranks) and each client hands its permit back across the
    // blocking round-trips.
    let driver = RoundDriver::centralized(cfg);
    let pool = &driver.pool;
    let profiler = driver.profiler.clone();
    let sched = cfg.lr_schedule();
    let t_start = Instant::now();

    let mode = match (cfg.algo, cfg.ps.lambda) {
        (Algo::Asgd, _) => PsMode::Asgd,
        (Algo::DcAsgd, PsLambda::Dynamic) => PsMode::DcAsgd { lam0: cfg.lam0 },
        (Algo::DcAsgd, PsLambda::Adaptive) => PsMode::DcAsgdAdaptive { lam0: cfg.lam0 },
        (other, _) => unreachable!("psasync engine got {other:?}"),
    };

    // The scripted membership schedule drives both the replica plan's
    // epoch routing and the workers' transitions — one source of truth,
    // identical everywhere with no rendezvous.
    let membership = harness.membership.clone();
    let capacity = membership.capacity();
    let (boundaries, rosters) = membership.roster_schedule();
    let plan = ReplicaPlan::place(
        cfg.ps.replicas,
        &cfg.net,
        capacity,
        cfg.ps.coalesce,
        boundaries.clone(),
        rosters.clone(),
    );

    // The PS applies updates with the same local-optimizer rule the
    // decentralized engines use (momentum SGD by default). A sharded
    // tier gets per-slice momentum (the configured optimizer's layer
    // map does not split across shard bounds); the single-shard default
    // keeps the full configured optimizer, bit-for-bit the legacy
    // behavior.
    let mut opt_for = |lo: usize, hi: usize| -> Box<dyn Optimizer> {
        if cfg.ps.shards <= 1 {
            build_optimizer(
                &cfg.optimizer,
                n,
                cfg.momentum,
                &harness.layer_ranges,
                harness.decay_mask.clone(),
            )
        } else {
            Box::new(MomentumSgd::new(hi - lo, cfg.momentum))
        }
    };
    // Service time: weights-update cost at each shard; modelled as one
    // memory pass over its slice at ~4 GB/s effective.
    let tier = PsTier::spawn(
        &harness.init_w,
        PsTierSpec {
            n_shards: cfg.ps.shards.max(1),
            mode,
            net: cfg.net,
            serve_s_per_elem: 4.0 / 4e9,
            compress: cfg.compress,
            seed: cfg.seed,
            capacity,
            plan,
        },
        &mut opt_for,
    );

    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for rank in 0..capacity {
            // Rank slots above the initial world exist only for
            // scripted joiners.
            if rank >= cfg.nodes && !membership.is_join_rank(rank) {
                continue;
            }
            let mut ctx = harness.make_worker(cfg, rank);
            let mut client = tier.client(rank);
            client.set_gate(pool.gate());
            let init_w = harness.init_w.clone();
            let sched = sched.clone();
            let cfg = cfg.clone();
            let gate = pool.gate();
            let profiler = profiler.clone();
            let hub = driver.obs.clone();
            let membership = membership.clone();
            let boundaries = boundaries.clone();
            let rosters = rosters.clone();

            handles.push(scope.spawn(move || -> Result<()> {
                let _permit = gate.permit();
                let mut pclock = RankClock::new(profiler);
                let mut w = init_w.clone();
                let comp_ratio = match cfg.compress.kind {
                    CompressorKind::None => 0.0,
                    _ => cfg.compress.ratio,
                };
                let join_at =
                    membership.joins().iter().find(|j| j.rank == rank).map(|j| j.at_s);
                let warmup_total =
                    if join_at.is_some() { cfg.control.join_warmup_windows } else { 0 };
                let mut steps_since_join = 0u64;
                let mut epoch_idx = 0usize;

                if let Some(at_s) = join_at {
                    // Scripted joiner: spin up at its arrival (paying the
                    // restore/provision cost), adopt the epoch its
                    // arrival opens, and bootstrap the canonical weights
                    // with a priced pull — the PS is the system of
                    // record, so there is no resync collective.
                    epoch_idx = boundaries.partition_point(|&b| b <= at_s);
                    let roster = &rosters[epoch_idx];
                    let Some(slot) = roster.iter().position(|&r| r == rank) else {
                        return Ok(());
                    };
                    ctx.clock.advance_to(at_s + cfg.control.restore_s);
                    ctx.reshard(slot, roster.len(), epoch_idx as u64);
                    client.rebind(slot, roster.len());
                    ctx.new_incarnation(ctx.clock.now());
                    let now = ctx.clock.now();
                    let reply = pclock.time(Phase::CommWait, || client.pull(rank, now));
                    ctx.clock.advance_to(reply.done_at);
                    w = reply.weights;
                } else {
                    client.rebind(rank, cfg.nodes);
                    if membership.is_elastic() && rank == 0 {
                        // Epoch 0 anchor so the trace's world trajectory
                        // starts at the initial roster.
                        ctx.epochs.record(EpochRecord {
                            epoch: 0,
                            rank,
                            slot: 0,
                            world: cfg.nodes,
                            sched_steps: 0,
                            sim_time: 0.0,
                            w_crc: param_crc(&w),
                            joined: Vec::new(),
                            departed: Vec::new(),
                        });
                    }
                }

                for t in 0..cfg.steps {
                    if !ctx.chaos.is_inert() {
                        if let Some(ev) = ctx.chaos.take_kill(ctx.clock.now()) {
                            if matches!(ev.kind, FaultKind::Kill { respawn: false }) {
                                // Departure: the rank leaves for good —
                                // the roster schedule retires it at this
                                // boundary and the survivors' plan
                                // routing sheds its crossings.
                                let now = ctx.clock.now();
                                ctx.control_log.record(ControlRecord {
                                    worker: rank,
                                    window: t,
                                    iteration: t,
                                    sim_time: now,
                                    k: 1,
                                    lam_scale: 1.0,
                                    schedule: None,
                                    t_compute: 0.0,
                                    t_allreduce: 0.0,
                                    t_ar_local: 0.0,
                                    t_ar_global: 0.0,
                                    blocked_s: 0.0,
                                    compress: None,
                                    compress_ratio: 1.0,
                                    wire_bytes: 0.0,
                                    probe: false,
                                    event: Some(format!(
                                        "depart@{:.3}s epoch={epoch_idx}",
                                        ev.at_s
                                    )),
                                });
                                hub.record(
                                    EventKind::Fault,
                                    rank,
                                    t,
                                    now,
                                    now,
                                    format!("depart epoch={epoch_idx}"),
                                );
                                hub.metrics.inc("control.departs", 1);
                                return Ok(());
                            }
                            // No snapshots in PS mode (bound 0 → cold
                            // restart); the next pull re-syncs weights.
                            ctx.recover_from_kill(
                                &ev, &cfg, &init_w, &mut w, None, 0, t, t, 1, 1.0,
                            );
                        }
                    }
                    // Membership boundary on this worker's clock: the
                    // roster schedule is scripted in virtual time, so
                    // every rank computes the same transition without a
                    // rendezvous (each crosses as its own clock passes
                    // the boundary).
                    while epoch_idx < boundaries.len()
                        && ctx.clock.now() >= boundaries[epoch_idx]
                    {
                        let at = boundaries[epoch_idx];
                        epoch_idx += 1;
                        let roster = &rosters[epoch_idx];
                        let Some(slot) = roster.iter().position(|&r| r == rank) else {
                            // Retired at this boundary (safety net — a
                            // scripted departure returns above).
                            return Ok(());
                        };
                        ctx.reshard(slot, roster.len(), epoch_idx as u64);
                        client.rebind(slot, roster.len());
                        ctx.new_incarnation(ctx.clock.now());
                        if slot == 0 {
                            // Leader-only record: PS weights are
                            // arrival-order dependent, so the epoch trace
                            // carries the leader's view rather than a
                            // cross-rank checksum contract.
                            let prev = &rosters[epoch_idx - 1];
                            let departed: Vec<usize> = prev
                                .iter()
                                .copied()
                                .filter(|r| !roster.contains(r))
                                .collect();
                            let joined: Vec<usize> = roster
                                .iter()
                                .copied()
                                .filter(|r| !prev.contains(r))
                                .collect();
                            ctx.epochs.record(EpochRecord {
                                epoch: epoch_idx as u64,
                                rank,
                                slot,
                                world: roster.len(),
                                sched_steps: t,
                                sim_time: at,
                                w_crc: param_crc(&w),
                                joined: joined.clone(),
                                departed: departed.clone(),
                            });
                            hub.record(
                                EventKind::EpochTransition,
                                rank,
                                epoch_idx as u64,
                                at,
                                at,
                                format!(
                                    "world={} departed={} joined={}",
                                    roster.len(),
                                    departed.len(),
                                    joined.len()
                                ),
                            );
                            hub.metrics.inc("membership.epochs", 1);
                            ctx.control_log.record(ControlRecord {
                                worker: rank,
                                window: t,
                                iteration: t,
                                sim_time: ctx.clock.now(),
                                k: 1,
                                lam_scale: 1.0,
                                schedule: None,
                                t_compute: 0.0,
                                t_allreduce: 0.0,
                                t_ar_local: 0.0,
                                t_ar_global: 0.0,
                                blocked_s: 0.0,
                                compress: None,
                                compress_ratio: 1.0,
                                wire_bytes: 0.0,
                                probe: false,
                                event: Some(format!(
                                    "epoch {epoch_idx}: world {} (-{departed:?} +{joined:?})",
                                    roster.len()
                                )),
                            });
                        }
                    }
                    let t_before_step = ctx.clock.now();
                    let (loss, err, wall) = pclock.time(Phase::Compute, || ctx.train_step(&w));
                    let t_c = ctx.clock.now() - t_before_step;
                    // Joiner LR warm-up, same ramp as the collective
                    // engines.
                    let warm = if steps_since_join < warmup_total {
                        (steps_since_join + 1) as f32 / (warmup_total + 1) as f32
                    } else {
                        1.0
                    };
                    let eta = sched.at(t) * warm;
                    let wd = cfg.wd_at(t, &sched);
                    let push_at = ctx.clock.now();
                    let reply = pclock.time(Phase::CommWait, || {
                        client.push_pull(rank, &ctx.g, push_at, eta, wd)
                    });
                    ctx.clock.advance_to(reply.done_at);
                    // Trace span triple: the PS round-trip is fully
                    // blocking — push and wait coincide, so the overlap
                    // efficiency reads 0, same as SSGD. Staleness is
                    // bucketed by whether the push saw intervening
                    // updates (‖w_ps − w_bak‖ > 0).
                    let win = t;
                    hub.record(EventKind::RoundPosted, rank, win, push_at, push_at, "k=1 algo=ps");
                    hub.record(EventKind::RoundSealed, rank, win, push_at, reply.done_at, "");
                    hub.record(EventKind::WindowConsumed, rank, win, push_at, reply.done_at, "");
                    hub.staleness(rank, u64::from(reply.staleness_dist > 0.0));
                    hub.metrics.inc("comm.rounds_posted", 1);
                    hub.window(WindowRow {
                        worker: rank,
                        window: win,
                        t_c,
                        t_ar: (reply.done_at - push_at).max(0.0),
                        blocked_s: (reply.done_at - push_at).max(0.0),
                        comp_ratio,
                    });
                    w = reply.weights;
                    ctx.record(t, loss, err, wall, 0.0, reply.staleness_dist, eta);
                    steps_since_join += 1;

                    if rank == 0 && cfg.eval_every > 0 && t % cfg.eval_every == 0 {
                        let (vl, ve) = pclock.time(Phase::Eval, || ctx.eval(&w, cfg.eval_batches));
                        ctx.record_eval(t, vl, ve);
                    }
                }
                if rank == 0 {
                    let (vl, ve) =
                        pclock.time(Phase::Eval, || ctx.eval(&w, cfg.eval_batches.max(8)));
                    ctx.record_eval(cfg.steps, vl, ve);
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().expect("worker panicked")?;
        }
        Ok(())
    })?;

    let (_w_final, _updates, ps_json) = tier.shutdown();

    let recorder = harness.recorder.clone();
    let final_val = recorder
        .evals()
        .last()
        .map(|e| (e.val_loss, e.val_err))
        .unwrap_or((f32::NAN, f32::NAN));
    let mut report =
        RunReport::assemble(cfg, recorder, final_val, t_start.elapsed().as_secs_f64());
    report.control = harness.control_log.clone();
    report.epochs = harness.epochs.clone();
    report.ps = Some(ps_json);
    report.perf = Some(profiler.to_json());
    report.obs = Some(driver.obs.clone());
    if let Some(path) = &cfg.trace.out {
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)?;
        }
        driver.obs.journal.write_jsonl(path)?;
    }
    if let Some(dir) = &cfg.out_dir {
        std::fs::create_dir_all(dir)?;
        report.recorder.write_steps_csv(dir.join(format!("{}_steps.csv", cfg.name)))?;
        report.recorder.write_evals_csv(dir.join(format!("{}_evals.csv", cfg.name)))?;
        report.write_json(dir.join(format!("{}_run.json", cfg.name)))?;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NetModel;
    use crate::simtime::ComputeModel;
    use crate::util::Json;

    fn base_cfg(algo: Algo) -> ExperimentConfig {
        ExperimentConfig::builder("linear")
            .name("ps_test")
            .algo(algo)
            .nodes(4)
            .local_batch(16)
            .steps(60)
            .eta_single(0.02)
            .base_batch(16)
            .data(1024, 256, 0.5)
            .compute(ComputeModel::uniform(1e-3))
            .net(NetModel::default())
            .build()
    }

    #[test]
    fn asgd_trains() {
        let cfg = base_cfg(Algo::Asgd);
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert!(report.final_val_err < 0.8, "val err {}", report.final_val_err);
    }

    #[test]
    fn dcasgd_trains() {
        let cfg = base_cfg(Algo::DcAsgd);
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert!(report.final_val_err < 0.8, "val err {}", report.final_val_err);
    }

    #[test]
    fn asgd_trains_on_hierarchical_topology() {
        // The PS round-trips price the dragonfly placement; the run must
        // still converge and cost more sim time than an instant network.
        let mut cfg = base_cfg(Algo::Asgd);
        cfg.name = "ps_hier".into();
        let d = crate::comm::Dragonfly { groups: 2, nodes_per_group: 2, ..Default::default() };
        cfg.net = NetModel {
            alpha_s: 1.5e-6,
            beta_bytes_per_s: 10e9,
            algo: crate::comm::AllReduceAlgo::Hierarchical(d),
        };
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert!(report.final_val_err < 0.85, "val err {}", report.final_val_err);
        assert!(report.sim_time_s > 0.0);
    }

    #[test]
    fn tapered_optics_cost_the_centralized_run_sim_time() {
        // Same hierarchical run at taper 2 (dedicated crossings) vs
        // taper 1 (the two remote workers share one optic): the
        // contended run must pay strictly more simulated time, and
        // still converge.
        let mk = |taper: usize| {
            let mut cfg = base_cfg(Algo::Asgd);
            cfg.name = format!("ps_taper{taper}");
            let d = crate::comm::Dragonfly {
                groups: 2,
                nodes_per_group: 2,
                global_taper: taper,
                ..Default::default()
            };
            cfg.net = NetModel {
                alpha_s: 1.5e-6,
                beta_bytes_per_s: 10e9,
                algo: crate::comm::AllReduceAlgo::Hierarchical(d),
            };
            cfg
        };
        let dedicated = run(&mk(2), WorkerHarness::prepare(&mk(2)).unwrap()).unwrap();
        let contended = run(&mk(1), WorkerHarness::prepare(&mk(1)).unwrap()).unwrap();
        assert!(
            contended.sim_time_s > dedicated.sim_time_s,
            "contended {} not slower than dedicated {}",
            contended.sim_time_s,
            dedicated.sim_time_s
        );
        assert!(contended.final_val_err < 0.85);
    }

    #[test]
    fn kill_fault_costs_downtime_and_is_logged() {
        let mut healthy = base_cfg(Algo::Asgd);
        healthy.name = "ps_healthy".into();
        let t_healthy = run(&healthy, WorkerHarness::prepare(&healthy).unwrap())
            .unwrap()
            .sim_time_s;
        let mut cfg = base_cfg(Algo::Asgd);
        cfg.name = "ps_killed".into();
        cfg.control.faults = crate::control::FaultPlan::new().kill(1, 0.3);
        cfg.control.heartbeat_timeout_s = 0.2;
        cfg.control.restore_s = 0.1;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let events = report.control.events();
        assert_eq!(events.len(), 1, "kill must be detected and logged");
        assert_eq!(events[0].worker, 1);
        assert!(report.sim_time_s > t_healthy, "kill downtime not accounted");
        assert!(report.final_val_err < 0.85, "run did not survive the kill");
    }

    #[test]
    fn staleness_distance_is_recorded() {
        let cfg = base_cfg(Algo::DcAsgd);
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        // After warm-up most pushes should see a non-zero PS-vs-backup
        // distance (other workers updated in between).
        let steps = report.recorder.steps();
        let late_nonzero = steps
            .iter()
            .filter(|s| s.iteration > 5 && s.dist_to_avg > 0.0)
            .count();
        assert!(late_nonzero > steps.len() / 4, "staleness never observed");
    }

    #[test]
    fn elastic_membership_runs_epoch_transitions() {
        // A depart at 0.02s then a join at 0.04s: the roster schedule is
        // 4 → 3 → 4, every surviving worker crosses both boundaries on
        // its own clock, and the run JSON's "epochs"/"ps" blocks carry
        // the realized transitions.
        let mut cfg = base_cfg(Algo::DcAsgd);
        cfg.name = "ps_elastic".into();
        cfg.control.faults = crate::control::FaultPlan::new().depart(1, 0.02);
        cfg.control.joins = vec![crate::control::JoinEvent { rank: 4, at_s: 0.04 }];
        cfg.control.join_warmup_windows = 4;
        cfg.control.restore_s = 0.005;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert_eq!(report.epochs.worlds(), vec![4, 3, 4], "roster trajectory");
        let transitions = report.epochs.transitions();
        assert_eq!(transitions[1].departed, vec![1]);
        assert_eq!(transitions[2].joined, vec![4]);
        // depart record + two leader epoch records
        let events = report.control.events();
        assert!(
            events.iter().any(|e| e.event.as_deref().unwrap_or("").starts_with("depart@")),
            "departure not logged"
        );
        assert_eq!(
            events.iter().filter(|e| e.event.as_deref().unwrap_or("").starts_with("epoch ")).count(),
            2,
            "one leader record per transition"
        );
        // The joiner trains: its steps appear in the recorder.
        assert!(
            report.recorder.steps().iter().any(|s| s.worker == 4),
            "joiner never stepped"
        );
        let ps = report.ps.as_ref().unwrap();
        assert_eq!(ps.get("epochs").and_then(Json::as_f64), Some(3.0));
        assert!(report.final_val_err < 0.85, "elastic run did not converge");
    }

    #[test]
    fn adaptive_lambda_ps_trains() {
        let mut cfg = base_cfg(Algo::DcAsgd);
        cfg.name = "ps_adaptive".into();
        cfg.ps.lambda = PsLambda::Adaptive;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert!(report.final_val_err < 0.8, "val err {}", report.final_val_err);
    }

    #[test]
    fn sharded_replicated_tier_reports_and_trains() {
        let mut cfg = base_cfg(Algo::DcAsgd);
        cfg.name = "ps_sharded".into();
        cfg.ps.shards = 4;
        cfg.ps.replicas = 2;
        cfg.ps.lambda = PsLambda::Adaptive;
        let d = crate::comm::Dragonfly { groups: 2, nodes_per_group: 2, ..Default::default() };
        cfg.net = NetModel {
            alpha_s: 1.5e-6,
            beta_bytes_per_s: 10e9,
            algo: crate::comm::AllReduceAlgo::Hierarchical(d),
        };
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let ps = report.ps.as_ref().unwrap();
        assert_eq!(ps.get("shards").and_then(Json::as_f64), Some(4.0));
        assert_eq!(ps.get("replicas").and_then(Json::as_f64), Some(2.0));
        assert!(report.final_val_err < 0.85, "val err {}", report.final_val_err);
    }

    #[test]
    fn compressed_ps_cuts_wire_volume() {
        let mut cfg = base_cfg(Algo::Asgd);
        cfg.name = "ps_topk".into();
        cfg.compress = crate::compress::CompressConfig {
            kind: CompressorKind::TopK,
            ratio: 0.1,
            ..Default::default()
        };
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let ps = report.ps.as_ref().unwrap();
        let cut = ps.get("wire_cut_x").and_then(Json::as_f64).unwrap();
        assert!(cut >= 3.0, "top-k @0.1 wire cut {cut} < 3x");
        assert!(report.final_val_err < 0.85, "val err {}", report.final_val_err);
    }
}
