//! Centralized asynchronous baselines: ASGD and DC-ASGD through the
//! parameter-server substrate ([`crate::ps`]).
//!
//! Each worker loops: compute gradient on its current weights → push to
//! the PS → receive fresh weights (Eq. 15's t_W2PS round-trip, plus
//! queueing at the serialized server). Staleness arises naturally: by
//! the time a worker's gradient arrives, other workers have already
//! advanced the PS weights. DC-ASGD compensates at the server with the
//! worker-specific backup weights (§II-A / Zheng et al.); ASGD does not.
//!
//! Chaos faults apply here too: slowdowns/stalls land in
//! `WorkerCtx::train_step` like everywhere else, and a scripted kill
//! costs the worker its detection + restore downtime before it rejoins
//! (its weights are refreshed by the next PS pull anyway — the PS is
//! the system of record, so there is no snapshot to restore).
//!
//! The schedule-aware comm refactor reaches this engine through the PS
//! transfer cost: when the run's `NetModel` carries the hierarchical
//! dragonfly schedule, [`crate::ps::PsClient::push_pull`] prices each
//! worker's round-trip with the topology-aware point-to-point model —
//! workers sharing rank 0's group (where the PS is hosted) ride the
//! electrical links, everyone else crosses the optics **contended** by
//! every other remote worker's crossings into the PS group
//! ([`crate::comm::NetModel::ptp_time_between_flows`], sharing the
//! [`crate::comm::GlobalContention`] model with the collective
//! schedules). The many-to-few bottleneck the paper attributes to
//! centralized schemes thus gains both the placement asymmetry and the
//! tapered-fabric oversubscription a real dragonfly imposes.

use std::time::Instant;

use anyhow::Result;

use crate::algo::{Algo, RoundDriver, RunReport, WorkerHarness};
use crate::config::ExperimentConfig;
use crate::exec::{Phase, RankClock};
use crate::obs::{EventKind, WindowRow};
use crate::optim::build_optimizer;
use crate::ps::{ParameterServer, PsMode};

pub fn run(cfg: &ExperimentConfig, harness: WorkerHarness) -> Result<RunReport> {
    let n = harness.n_params();
    // Engine pool: worker ranks share `perf.threads` permits; the PS
    // actor itself stays ungated (it is service infrastructure, not a
    // rank) and each client hands its permit back across push_pull.
    let driver = RoundDriver::centralized(cfg);
    let pool = &driver.pool;
    let profiler = driver.profiler.clone();
    let sched = cfg.lr_schedule();
    let t_start = Instant::now();

    let mode = match cfg.algo {
        Algo::Asgd => PsMode::Asgd,
        Algo::DcAsgd => PsMode::DcAsgd { lam0: cfg.lam0 },
        other => unreachable!("psasync engine got {other:?}"),
    };

    // The PS applies updates with the same local-optimizer rule the
    // decentralized engines use (momentum SGD by default).
    let ps_opt = build_optimizer(
        &cfg.optimizer,
        n,
        cfg.momentum,
        &harness.layer_ranges,
        harness.decay_mask.clone(),
    );
    // Service time: weights-update cost at the server; modelled as one
    // memory pass over the parameters at ~4 GB/s effective.
    let serve_s = (n as f64 * 4.0) / 4e9;
    let ps = ParameterServer::spawn(
        harness.init_w.clone(),
        ps_opt,
        cfg.nodes,
        mode,
        cfg.net,
        serve_s,
    );

    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for rank in 0..cfg.nodes {
            let mut ctx = harness.make_worker(cfg, rank);
            let mut client = ps.client();
            client.set_gate(pool.gate());
            let init_w = harness.init_w.clone();
            let sched = sched.clone();
            let cfg = cfg.clone();
            let gate = pool.gate();
            let profiler = profiler.clone();
            let hub = driver.obs.clone();

            handles.push(scope.spawn(move || -> Result<()> {
                let _permit = gate.permit();
                let mut pclock = RankClock::new(profiler);
                let mut w = init_w.clone();
                for t in 0..cfg.steps {
                    if !ctx.chaos.is_inert() {
                        if let Some(ev) = ctx.chaos.take_kill(ctx.clock.now()) {
                            // No snapshots in PS mode (bound 0 → cold
                            // restart); the next pull re-syncs weights.
                            ctx.recover_from_kill(
                                &ev, &cfg, &init_w, &mut w, None, 0, t, t, 1, 1.0,
                            );
                        }
                    }
                    let t_before_step = ctx.clock.now();
                    let (loss, err, wall) = pclock.time(Phase::Compute, || ctx.train_step(&w));
                    let t_c = ctx.clock.now() - t_before_step;
                    let eta = sched.at(t);
                    let wd = cfg.wd_at(t, &sched);
                    let push_at = ctx.clock.now();
                    let reply = pclock.time(Phase::CommWait, || {
                        client.push_pull(rank, ctx.g.clone(), push_at, eta, wd)
                    });
                    ctx.clock.advance_to(reply.done_at);
                    // Trace span triple: the PS round-trip is fully
                    // blocking — push and wait coincide, so the overlap
                    // efficiency reads 0, same as SSGD. Staleness is
                    // bucketed by whether the push saw intervening
                    // updates (‖w_ps − w_bak‖ > 0).
                    let win = t as u64;
                    hub.record(EventKind::RoundPosted, rank, win, push_at, push_at, "k=1 algo=ps");
                    hub.record(EventKind::RoundSealed, rank, win, push_at, reply.done_at, "");
                    hub.record(EventKind::WindowConsumed, rank, win, push_at, reply.done_at, "");
                    hub.staleness(rank, u64::from(reply.staleness_dist > 0.0));
                    hub.metrics.inc("comm.rounds_posted", 1);
                    hub.window(WindowRow {
                        worker: rank,
                        window: win,
                        t_c,
                        t_ar: (reply.done_at - push_at).max(0.0),
                        blocked_s: (reply.done_at - push_at).max(0.0),
                        comp_ratio: 0.0,
                    });
                    w = reply.weights;
                    ctx.record(t, loss, err, wall, 0.0, reply.staleness_dist, eta);

                    if rank == 0 && cfg.eval_every > 0 && t % cfg.eval_every == 0 {
                        let (vl, ve) = pclock.time(Phase::Eval, || ctx.eval(&w, cfg.eval_batches));
                        ctx.record_eval(t, vl, ve);
                    }
                }
                if rank == 0 {
                    let (vl, ve) =
                        pclock.time(Phase::Eval, || ctx.eval(&w, cfg.eval_batches.max(8)));
                    ctx.record_eval(cfg.steps, vl, ve);
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().expect("worker panicked")?;
        }
        Ok(())
    })?;

    ps.shutdown();

    let recorder = harness.recorder.clone();
    let final_val = recorder
        .evals()
        .last()
        .map(|e| (e.val_loss, e.val_err))
        .unwrap_or((f32::NAN, f32::NAN));
    let mut report =
        RunReport::assemble(cfg, recorder, final_val, t_start.elapsed().as_secs_f64());
    report.control = harness.control_log.clone();
    report.perf = Some(profiler.to_json());
    report.obs = Some(driver.obs.clone());
    if let Some(path) = &cfg.trace.out {
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)?;
        }
        driver.obs.journal.write_jsonl(path)?;
    }
    if let Some(dir) = &cfg.out_dir {
        std::fs::create_dir_all(dir)?;
        report.recorder.write_steps_csv(dir.join(format!("{}_steps.csv", cfg.name)))?;
        report.recorder.write_evals_csv(dir.join(format!("{}_evals.csv", cfg.name)))?;
        report.write_json(dir.join(format!("{}_run.json", cfg.name)))?;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NetModel;
    use crate::simtime::ComputeModel;

    fn base_cfg(algo: Algo) -> ExperimentConfig {
        ExperimentConfig::builder("linear")
            .name("ps_test")
            .algo(algo)
            .nodes(4)
            .local_batch(16)
            .steps(60)
            .eta_single(0.02)
            .base_batch(16)
            .data(1024, 256, 0.5)
            .compute(ComputeModel::uniform(1e-3))
            .net(NetModel::default())
            .build()
    }

    #[test]
    fn asgd_trains() {
        let cfg = base_cfg(Algo::Asgd);
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert!(report.final_val_err < 0.8, "val err {}", report.final_val_err);
    }

    #[test]
    fn dcasgd_trains() {
        let cfg = base_cfg(Algo::DcAsgd);
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert!(report.final_val_err < 0.8, "val err {}", report.final_val_err);
    }

    #[test]
    fn asgd_trains_on_hierarchical_topology() {
        // The PS round-trips price the dragonfly placement; the run must
        // still converge and cost more sim time than an instant network.
        let mut cfg = base_cfg(Algo::Asgd);
        cfg.name = "ps_hier".into();
        let d = crate::comm::Dragonfly { groups: 2, nodes_per_group: 2, ..Default::default() };
        cfg.net = NetModel {
            alpha_s: 1.5e-6,
            beta_bytes_per_s: 10e9,
            algo: crate::comm::AllReduceAlgo::Hierarchical(d),
        };
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert!(report.final_val_err < 0.85, "val err {}", report.final_val_err);
        assert!(report.sim_time_s > 0.0);
    }

    #[test]
    fn tapered_optics_cost_the_centralized_run_sim_time() {
        // Same hierarchical run at taper 2 (dedicated crossings) vs
        // taper 1 (the two remote workers share one optic): the
        // contended run must pay strictly more simulated time, and
        // still converge.
        let mk = |taper: usize| {
            let mut cfg = base_cfg(Algo::Asgd);
            cfg.name = format!("ps_taper{taper}");
            let d = crate::comm::Dragonfly {
                groups: 2,
                nodes_per_group: 2,
                global_taper: taper,
                ..Default::default()
            };
            cfg.net = NetModel {
                alpha_s: 1.5e-6,
                beta_bytes_per_s: 10e9,
                algo: crate::comm::AllReduceAlgo::Hierarchical(d),
            };
            cfg
        };
        let dedicated = run(&mk(2), WorkerHarness::prepare(&mk(2)).unwrap()).unwrap();
        let contended = run(&mk(1), WorkerHarness::prepare(&mk(1)).unwrap()).unwrap();
        assert!(
            contended.sim_time_s > dedicated.sim_time_s,
            "contended {} not slower than dedicated {}",
            contended.sim_time_s,
            dedicated.sim_time_s
        );
        assert!(contended.final_val_err < 0.85);
    }

    #[test]
    fn kill_fault_costs_downtime_and_is_logged() {
        let mut healthy = base_cfg(Algo::Asgd);
        healthy.name = "ps_healthy".into();
        let t_healthy = run(&healthy, WorkerHarness::prepare(&healthy).unwrap())
            .unwrap()
            .sim_time_s;
        let mut cfg = base_cfg(Algo::Asgd);
        cfg.name = "ps_killed".into();
        cfg.control.faults = crate::control::FaultPlan::new().kill(1, 0.3);
        cfg.control.heartbeat_timeout_s = 0.2;
        cfg.control.restore_s = 0.1;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let events = report.control.events();
        assert_eq!(events.len(), 1, "kill must be detected and logged");
        assert_eq!(events[0].worker, 1);
        assert!(report.sim_time_s > t_healthy, "kill downtime not accounted");
        assert!(report.final_val_err < 0.85, "run did not survive the kill");
    }

    #[test]
    fn staleness_distance_is_recorded() {
        let cfg = base_cfg(Algo::DcAsgd);
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        // After warm-up most pushes should see a non-zero PS-vs-backup
        // distance (other workers updated in between).
        let steps = report.recorder.steps();
        let late_nonzero = steps
            .iter()
            .filter(|s| s.iteration > 5 && s.dist_to_avg > 0.0)
            .count();
        assert!(late_nonzero > steps.len() / 4, "staleness never observed");
    }
}
