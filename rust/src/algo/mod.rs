//! Training algorithms: the paper's **DC-S3GD** (Algorithm 1) plus the
//! §II baselines it is compared against.
//!
//! | Variant   | Comm scheme        | Staleness | Compensation |
//! |-----------|--------------------|-----------|--------------|
//! | `Ssgd`    | blocking allreduce | 0         | —            |
//! | `S3gd`    | non-blocking       | k (≥1)    | none (λ=0)   |
//! | `DcS3gd`  | non-blocking       | k (≥1)    | Eq. 10/17    |
//! | `Asgd`    | parameter server   | async     | none         |
//! | `DcAsgd`  | parameter server   | async     | Eq. 6 at PS  |
//! | `DynSsp`  | non-blocking       | per-rank  | Eq. 10/17    |
//! | `Sgs`     | non-blocking       | random    | Eq. 10/17    |
//!
//! All engines are generic over [`crate::model::StepBackend`], so they
//! run identically over the PJRT artifacts (production) or the
//! pure-rust linear model (tests).

pub mod dcs3gd;
pub mod engine;
pub mod psasync;
pub mod ssgd;
mod worker;

pub use engine::{engine_for, engine_registry, Engine, EngineSpec, RoundDriver};
pub use worker::{RunReport, WorkerHarness};

use anyhow::{bail, Result};

/// Which training algorithm to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algo {
    /// Synchronous SGD: blocking all-reduce of gradients (Eq. 13).
    Ssgd,
    /// Stale-synchronous without compensation (DC-S3GD with λ0 = 0) —
    /// the ablation showing the correction matters.
    S3gd,
    /// The paper's algorithm (Algorithm 1).
    DcS3gd,
    /// Asynchronous SGD through a parameter server.
    Asgd,
    /// Delay-compensated ASGD (Zheng et al.) through a parameter server.
    DcAsgd,
    /// Dynamic SSP (1908.11848): the DC-S3GD engine with **per-worker**
    /// staleness bounds scaled inversely to each rank's observed t_C —
    /// the heterogeneity-aware generalization of `dss_pid`.
    DynSsp,
    /// Stochastic Gradient Staleness (2509.05679): the DC-S3GD engine
    /// with per-window *randomized* staleness draws from the
    /// deterministic counter RNG.
    Sgs,
}

impl Algo {
    /// Parse an algorithm name. Accepts the canonical names plus the
    /// `dc-s3gd` / `dc_s3gd` separators — the Python AOT config writer
    /// emits the underscore spellings.
    pub fn parse(s: &str) -> Result<Algo> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "ssgd" => Algo::Ssgd,
            "s3gd" => Algo::S3gd,
            "dcs3gd" | "dc-s3gd" | "dc_s3gd" => Algo::DcS3gd,
            "asgd" => Algo::Asgd,
            "dcasgd" | "dc-asgd" | "dc_asgd" => Algo::DcAsgd,
            "dyn_ssp" | "dyn-ssp" | "dynssp" => Algo::DynSsp,
            "sgs" => Algo::Sgs,
            other => bail!("unknown algorithm {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algo::Ssgd => "ssgd",
            Algo::S3gd => "s3gd",
            Algo::DcS3gd => "dcs3gd",
            Algo::Asgd => "asgd",
            Algo::DcAsgd => "dcasgd",
            Algo::DynSsp => "dyn_ssp",
            Algo::Sgs => "sgs",
        }
    }

    /// Decentralized (all-reduce based) vs centralized (PS based).
    pub fn is_decentralized(&self) -> bool {
        matches!(self, Algo::Ssgd | Algo::S3gd | Algo::DcS3gd | Algo::DynSsp | Algo::Sgs)
    }

    /// Engines built on the stale-synchronous window loop in
    /// [`dcs3gd`] — the full control-plane stack (adaptive staleness,
    /// probes, schedule switching). Membership epochs and compression
    /// are no longer exclusive to this family: `ssgd` and the PS tier
    /// (`asgd` | `dcasgd`) run both.
    pub fn is_windowed(&self) -> bool {
        matches!(self, Algo::S3gd | Algo::DcS3gd | Algo::DynSsp | Algo::Sgs)
    }
}

/// Run one experiment end to end per its config; resolves the engine
/// through the [`engine_registry`] and returns the aggregated report.
pub fn run_experiment(cfg: &crate::config::ExperimentConfig) -> Result<RunReport> {
    // Resolve the heterogeneity profile into the base models once, up
    // front, so every engine (and the schedule pricing inside the
    // control plane) sees the same tiered/asymmetric fabric.
    let cfg = if cfg.hetero.enabled && !cfg.hetero.applied {
        std::borrow::Cow::Owned(cfg.with_hetero_applied())
    } else {
        std::borrow::Cow::Borrowed(cfg)
    };
    let cfg = cfg.as_ref();
    let harness = WorkerHarness::prepare(cfg)?;
    engine_for(cfg.algo).run(cfg, harness)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names() {
        assert_eq!(Algo::parse("DC-S3GD").unwrap(), Algo::DcS3gd);
        assert_eq!(Algo::parse("ssgd").unwrap(), Algo::Ssgd);
        assert!(Algo::parse("sgdx").is_err());
        for a in [
            Algo::Ssgd,
            Algo::S3gd,
            Algo::DcS3gd,
            Algo::Asgd,
            Algo::DcAsgd,
            Algo::DynSsp,
            Algo::Sgs,
        ] {
            assert_eq!(Algo::parse(a.name()).unwrap(), a);
        }
        assert_eq!(Algo::parse("dyn-ssp").unwrap(), Algo::DynSsp);
    }

    #[test]
    fn parse_accepts_python_underscore_spellings() {
        // The Python AOT config writer emits snake_case names; they must
        // round-trip through parse → name → parse.
        assert_eq!(Algo::parse("dc_s3gd").unwrap(), Algo::DcS3gd);
        assert_eq!(Algo::parse("DC_S3GD").unwrap(), Algo::DcS3gd);
        assert_eq!(Algo::parse("dc_asgd").unwrap(), Algo::DcAsgd);
        for spelled in ["dc_s3gd", "dc_asgd"] {
            let a = Algo::parse(spelled).unwrap();
            assert_eq!(Algo::parse(a.name()).unwrap(), a);
        }
        // underscore variants of the hyphen-free names stay invalid
        assert!(Algo::parse("s_sgd").is_err());
    }

    #[test]
    fn centralization_split() {
        assert!(Algo::DcS3gd.is_decentralized());
        assert!(!Algo::DcAsgd.is_decentralized());
        assert!(Algo::DynSsp.is_decentralized());
        assert!(Algo::Sgs.is_windowed());
        assert!(!Algo::Ssgd.is_windowed());
    }
}
