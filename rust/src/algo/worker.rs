//! Shared per-run scaffolding: backend construction, per-worker context
//! (data shard, clock, scratch buffers), validation passes, and the
//! final [`RunReport`].

use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::ExperimentConfig;
use crate::control::{
    ChaosInjector, ControlLog, EpochTrace, HeartbeatBoard, MembershipLog, SnapshotStore,
};
use crate::data::{ShardSampler, Split, SyntheticDataset};
use crate::metrics::{EvalRecord, Recorder, StepRecord};
use crate::model::{LinearSoftmax, StepBackend};
use crate::runtime::ComputeServer;
use crate::simtime::SimClock;
use crate::util::{Json, Rng};

/// Linear-model geometry when no artifact is involved.
const LINEAR_HW: usize = 16;
const LINEAR_CLASSES: usize = 10;

enum BackendSource {
    Linear { hw: usize, classes: usize },
    Xla(ComputeServer),
}

/// Everything a run needs before workers start: dataset, initial
/// weights, backend factory, shared recorder.
pub struct WorkerHarness {
    pub dataset: SyntheticDataset,
    pub init_w: Vec<f32>,
    pub decay_mask: Option<Vec<f32>>,
    pub layer_ranges: Vec<(usize, usize)>,
    pub recorder: Recorder,
    /// Control-plane flight recorder shared by all workers.
    pub control_log: ControlLog,
    /// Heartbeat timestamps for failure detection (capacity-wide: one
    /// slot per potential member, scripted joiners included).
    pub heartbeats: HeartbeatBoard,
    /// Latest recovery checkpoint (leader-written, Eq. 8 canonical).
    pub snapshots: SnapshotStore,
    /// The run's scripted membership schedule (inert when empty).
    pub membership: MembershipLog,
    /// Realized membership-epoch transitions (exported as `"epochs"`).
    pub epochs: EpochTrace,
    pub num_classes: usize,
    pub input_hw: usize,
    source: BackendSource,
}

impl WorkerHarness {
    pub fn prepare(cfg: &ExperimentConfig) -> Result<Self> {
        let (source, init_w, decay_mask, layer_ranges, hw, classes) =
            if cfg.variant == "linear" {
                let model = LinearSoftmax::for_images(LINEAR_HW, LINEAR_CLASSES, cfg.local_batch);
                let n = crate::model::StepBackend::n_params(&model);
                let d = LINEAR_HW * LINEAR_HW * 3;
                (
                    BackendSource::Linear { hw: LINEAR_HW, classes: LINEAR_CLASSES },
                    model.init_params(cfg.seed),
                    None,
                    vec![(0, d * LINEAR_CLASSES), (d * LINEAR_CLASSES, n - d * LINEAR_CLASSES)],
                    LINEAR_HW,
                    LINEAR_CLASSES,
                )
            } else {
                let dir = cfg.artifacts_root.join(&cfg.variant);
                let server = ComputeServer::start(&dir)?;
                let meta = server.meta().clone();
                if meta.batch != cfg.local_batch {
                    return Err(anyhow!(
                        "artifact {} was lowered for batch {}, config says {}",
                        cfg.variant,
                        meta.batch,
                        cfg.local_batch
                    ));
                }
                let init = meta.load_init_params()?;
                let mask = meta.load_decay_mask().ok();
                let ranges = meta.layer_ranges();
                let (hw, classes) = (meta.input_hw, meta.num_classes);
                (BackendSource::Xla(server), init, mask, ranges, hw, classes)
            };

        let dataset = SyntheticDataset::new(cfg.seed ^ 0xDA7A, hw, classes, cfg.n_train, cfg.n_val)
            .with_noise(cfg.data_noise);

        let membership = cfg.control.membership_log(cfg.nodes);
        let capacity = membership.capacity();
        Ok(WorkerHarness {
            dataset,
            init_w,
            decay_mask,
            layer_ranges,
            recorder: Recorder::new(),
            control_log: ControlLog::new(),
            heartbeats: HeartbeatBoard::new(capacity),
            snapshots: SnapshotStore::new(),
            membership,
            epochs: EpochTrace::new(),
            num_classes: classes,
            input_hw: hw,
            source,
        })
    }

    pub fn n_params(&self) -> usize {
        self.init_w.len()
    }

    /// A fresh backend for one worker (Send; moved into its thread).
    pub fn make_backend(&self, cfg: &ExperimentConfig) -> Box<dyn StepBackend> {
        match &self.source {
            BackendSource::Linear { hw, classes } => {
                Box::new(LinearSoftmax::for_images(*hw, *classes, cfg.local_batch))
            }
            BackendSource::Xla(server) => Box::new(server.backend()),
        }
    }

    /// Per-worker context bundle.
    pub fn make_worker(&self, cfg: &ExperimentConfig, rank: usize) -> WorkerCtx {
        WorkerCtx::new(self, cfg, rank)
    }
}

/// One worker's mutable state: backend, shard iterator, scratch buffers,
/// virtual clock.
pub struct WorkerCtx {
    pub rank: usize,
    pub backend: Box<dyn StepBackend>,
    pub sampler: ShardSampler,
    pub clock: SimClock,
    pub rng: Rng,
    pub dataset: SyntheticDataset,
    pub recorder: Recorder,
    /// Scripted faults for this rank (inert when the plan is empty).
    pub chaos: ChaosInjector,
    /// Shared failure-detection board; beaten at every step boundary.
    pub heartbeats: HeartbeatBoard,
    /// Shared recovery snapshot store.
    pub snapshots: SnapshotStore,
    pub control_log: ControlLog,
    /// Shared membership-epoch trace.
    pub epochs: EpochTrace,
    /// This worker's liveness incarnation on the heartbeat board (bumped
    /// on respawn and on membership-epoch changes) — beats carry it so
    /// the board can drop anything from a dead incarnation.
    incarnation: u64,
    compute: crate::simtime::ComputeModel,
    /// Diurnal load curve in virtual time (hetero subsystem); `None`
    /// off the heterogeneous path.
    diurnal: Option<crate::hetero::DiurnalCurve>,
    time_from_wall: bool,
    local_batch: usize,
    // scratch
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub g: Vec<f32>,
}

impl WorkerCtx {
    fn new(h: &WorkerHarness, cfg: &ExperimentConfig, rank: usize) -> Self {
        let px = h.input_hw * h.input_hw * 3;
        // Scripted joiners (rank ≥ nodes) get a placeholder shard; the
        // engine reshards them from their admission slot before any
        // sampling happens.
        let shard = rank.min(cfg.nodes - 1);
        WorkerCtx {
            rank,
            backend: h.make_backend(cfg),
            sampler: ShardSampler::new(&h.dataset, shard, cfg.nodes, cfg.local_batch),
            clock: SimClock::new(),
            rng: Rng::keyed(cfg.seed, 0xC10C4, rank as u64),
            dataset: h.dataset.clone(),
            recorder: h.recorder.clone(),
            chaos: ChaosInjector::new(&cfg.control.faults, rank),
            heartbeats: h.heartbeats.clone(),
            snapshots: h.snapshots.clone(),
            control_log: h.control_log.clone(),
            epochs: h.epochs.clone(),
            incarnation: 0,
            compute: cfg.compute.clone(),
            diurnal: crate::hetero::DiurnalCurve::for_rank(&cfg.hetero, cfg.seed, rank),
            time_from_wall: cfg.time_from_wall,
            local_batch: cfg.local_batch,
            x: vec![0.0; cfg.local_batch * px],
            y: vec![0; cfg.local_batch],
            g: vec![0.0; h.init_w.len()],
        }
    }

    /// Draw the next shard batch, run fused fwd+bwd, advance the virtual
    /// clock by t_C (scaled by any active chaos slowdown, plus pending
    /// one-shot stalls), and return (loss, err, wall_compute_s). The
    /// gradient lands in `self.g`.
    pub fn train_step(&mut self, w: &[f32]) -> (f32, f32, f64) {
        if !self.chaos.is_inert() {
            let stall = self.chaos.take_delay(self.clock.now());
            if stall > 0.0 {
                self.clock.advance(stall);
            }
        }
        let idx = self.sampler.next_batch();
        self.dataset.batch_into(Split::Train, &idx, &mut self.x, &mut self.y);
        let t0 = Instant::now();
        let (loss, err) = self.backend.train_step(w, &self.x, &self.y, &mut self.g);
        let wall = self.backend.last_compute_s().unwrap_or_else(|| t0.elapsed().as_secs_f64());
        let mut t_c = if self.time_from_wall {
            wall
        } else {
            self.compute.batch_time(self.rank, self.local_batch, &mut self.rng)
        };
        if !self.chaos.is_inert() {
            t_c *= self.chaos.compute_factor(self.clock.now());
        }
        if let Some(curve) = &self.diurnal {
            t_c *= curve.factor(self.clock.now());
        }
        self.clock.advance(t_c);
        self.beat(self.clock.now());
        (loss, err, wall)
    }

    /// Record liveness — unless a scripted kill is already due, in
    /// which case the rank is dead as of the crash time and its beat
    /// must not count (the (rank, epoch) heartbeat dedupe: letting the
    /// post-crash step beat the board double-counted the dead rank's
    /// heartbeat into the same window's detection arithmetic). Beats
    /// carry this worker's incarnation, so one from a dead incarnation
    /// is dropped board-side too.
    pub fn beat(&self, now: f64) {
        if !self.chaos.is_inert() && self.chaos.kill_pending(now) {
            return;
        }
        self.heartbeats.beat_epoch(self.rank, self.incarnation, now);
    }

    /// Start a fresh liveness incarnation (respawn or membership-epoch
    /// change) anchored at `now`.
    pub fn new_incarnation(&mut self, now: f64) {
        self.incarnation = self.heartbeats.respawn(self.rank, now);
    }

    /// Re-partition this worker's data shard at a membership-epoch
    /// boundary: it becomes shard `slot` of `world` (see
    /// [`ShardSampler::reshard`]).
    pub fn reshard(&mut self, slot: usize, world: usize, membership_epoch: u64) {
        self.sampler.reshard(slot, world, membership_epoch);
    }

    /// Validation pass over the first `batches` val batches at weights
    /// `w` (virtual time not advanced: evaluation is off the training
    /// critical path, as in the paper's reported timings).
    pub fn eval(&mut self, w: &[f32], batches: usize) -> (f32, f32) {
        let px = self.x.len() / self.local_batch;
        let n_val_batches = (self.dataset.n_val / self.local_batch).max(1).min(batches.max(1));
        let mut loss = 0f64;
        let mut err = 0f64;
        for b in 0..n_val_batches {
            let idx: Vec<usize> = (0..self.local_batch)
                .map(|i| (b * self.local_batch + i) % self.dataset.n_val)
                .collect();
            self.dataset.batch_into(Split::Val, &idx, &mut self.x[..idx.len() * px], &mut self.y[..idx.len()]);
            let (l, e) = self.backend.eval_step(w, &self.x[..idx.len() * px], &self.y[..idx.len()]);
            loss += l as f64;
            err += e as f64;
        }
        ((loss / n_val_batches as f64) as f32, (err / n_val_batches as f64) as f32)
    }

    /// Record one training step into the shared recorder.
    #[allow(clippy::too_many_arguments)]
    pub fn record(
        &self,
        iteration: u64,
        loss: f32,
        train_err: f32,
        wall: f64,
        lambda: f32,
        dist_to_avg: f64,
        lr: f32,
    ) {
        self.recorder.record_step(StepRecord {
            worker: self.rank,
            iteration,
            epoch: self.sampler.epoch(),
            sim_time: self.clock.now(),
            wall_compute: wall,
            loss,
            train_err,
            lambda,
            dist_to_avg,
            lr,
        });
    }

    pub fn record_eval(&self, iteration: u64, val_loss: f32, val_err: f32) {
        self.recorder.record_eval(EvalRecord {
            iteration,
            epoch: self.sampler.epoch(),
            sim_time: self.clock.now(),
            val_loss,
            val_err,
        });
    }

    /// Crash-and-respawn this worker: restore weights (and, on the fused
    /// path, momentum) from the newest snapshot whose iteration is
    /// `<= snapshot_bound` — or cold-restart from the initial weights if
    /// none qualifies — advance the virtual clock through
    /// heartbeat-detection plus restore downtime, and log the event.
    /// Unfused optimizer state must be reset by the caller.
    ///
    /// `snapshot_bound` must be derived from the engine's rendezvous
    /// happens-before order (every snapshot at or below it is already
    /// published by the leader) so recovery is deterministic regardless
    /// of wall-clock thread interleaving.
    #[allow(clippy::too_many_arguments)]
    pub fn recover_from_kill(
        &mut self,
        event: &crate::control::FaultEvent,
        cfg: &ExperimentConfig,
        init_w: &[f32],
        w: &mut Vec<f32>,
        velocity: Option<&mut Vec<f32>>,
        snapshot_bound: u64,
        iteration: u64,
        window: u64,
        k: usize,
        lam_scale: f32,
    ) {
        let timeout = cfg.control.heartbeat_timeout_s;
        let detect = self.heartbeats.detect_time(self.rank, event.at_s, timeout);
        let recover_at = detect + cfg.control.restore_s;
        let restored_from = match self.snapshots.latest_at_or_before(snapshot_bound) {
            Some(ck) if ck.weights.len() == w.len() => {
                *w = ck.weights;
                if let Some(v) = velocity {
                    if ck.velocity.len() == v.len() {
                        *v = ck.velocity;
                    } else {
                        v.iter_mut().for_each(|x| *x = 0.0);
                    }
                }
                format!("snapshot@{}", ck.iteration)
            }
            _ => {
                *w = init_w.to_vec();
                if let Some(v) = velocity {
                    v.iter_mut().for_each(|x| *x = 0.0);
                }
                "init".to_string()
            }
        };
        self.clock.advance_to(recover_at);
        // New incarnation: the dead rank's beats stop counting.
        self.new_incarnation(self.clock.now());
        self.control_log.record(crate::control::ControlRecord {
            worker: self.rank,
            window,
            iteration,
            sim_time: self.clock.now(),
            k,
            lam_scale,
            schedule: None,
            t_compute: 0.0,
            t_allreduce: 0.0,
            t_ar_local: 0.0,
            t_ar_global: 0.0,
            blocked_s: recover_at - event.at_s,
            compress: None,
            compress_ratio: 1.0,
            wire_bytes: 0.0,
            probe: false,
            event: Some(format!(
                "kill@{:.3}s detect@{:.3}s restored_from={restored_from}",
                event.at_s, detect
            )),
        });
    }
}

/// Aggregated outcome of one run — the numbers Table I / Figure 1 are
/// built from.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub name: String,
    pub algo: super::Algo,
    pub nodes: usize,
    pub global_batch: usize,
    pub steps: u64,
    pub final_train_loss: f32,
    pub final_train_err: f32,
    pub final_val_loss: f32,
    pub final_val_err: f32,
    pub best_val_err: f32,
    /// Simulated run time (max over workers' virtual clocks).
    pub sim_time_s: f64,
    /// Simulated throughput, samples/s (the Table I Speed column).
    pub sim_throughput: f64,
    /// Mean simulated time per iteration (Eq. 13/14 comparison).
    pub mean_iter_time: f64,
    /// Mean ‖D_i‖ over the final quarter of the run (§III-D.2 metric).
    pub mean_dist_to_avg: f64,
    /// Real wall time of the whole run.
    pub wall_time_s: f64,
    pub recorder: Recorder,
    /// Control-plane decision trace (empty when the plane only observed).
    pub control: ControlLog,
    /// Membership-epoch trace (empty for fixed-membership runs).
    pub epochs: EpochTrace,
    /// The resolved heterogeneity profile (`None` for homogeneous runs).
    pub hetero: Option<crate::hetero::HeteroProfile>,
    /// Engine-core profile: resolved thread budget, kernel chunk width
    /// and the per-phase wall-time histograms (see
    /// [`crate::exec::Profiler`]). Wall-clock measurements — excluded,
    /// together with `wall_time_s`, from
    /// [`RunReport::deterministic_json`].
    pub perf: Option<Json>,
    /// Trace/metrics hub of the run (see [`crate::obs`]): event
    /// journal, metric registry, per-window overlap/compensation and
    /// per-rank staleness accounting. Virtual-time only, but exported
    /// under `"obs"` and excluded from
    /// [`RunReport::deterministic_json`] exactly like `"perf"`.
    pub obs: Option<crate::obs::ObsHub>,
    /// Parameter-server tier accounting (see [`crate::ps::PsTier`]):
    /// shard/replica shape, push/pull/coalesce counts, wire vs dense
    /// bytes. `None` on decentralized runs — exported as an
    /// `enabled: false` stub so consumers always find the `"ps"` key.
    pub ps: Option<Json>,
}

impl RunReport {
    /// Assemble from the recorder + final eval numbers.
    #[allow(clippy::too_many_arguments)]
    pub fn assemble(
        cfg: &ExperimentConfig,
        recorder: Recorder,
        final_val: (f32, f32),
        wall_time_s: f64,
    ) -> Self {
        let (final_train_loss, final_train_err) = recorder.tail_train(20 * cfg.nodes);
        let steps = recorder.steps();
        let sim_time_s = steps.iter().map(|s| s.sim_time).fold(0.0, f64::max);
        let tail = (cfg.steps as usize * cfg.nodes) / 4;
        RunReport {
            name: cfg.name.clone(),
            algo: cfg.algo,
            nodes: cfg.nodes,
            global_batch: cfg.global_batch(),
            steps: cfg.steps,
            final_train_loss,
            final_train_err,
            final_val_loss: final_val.0,
            final_val_err: final_val.1,
            best_val_err: recorder.best_val_err().unwrap_or(final_val.1).min(final_val.1),
            sim_time_s,
            sim_throughput: recorder.sim_throughput(cfg.local_batch),
            mean_iter_time: recorder.mean_iter_time(),
            mean_dist_to_avg: recorder.tail_dist_to_avg(tail.max(1)),
            wall_time_s,
            recorder,
            control: ControlLog::default(),
            epochs: EpochTrace::default(),
            hetero: cfg.hetero_profile(),
            perf: None,
            obs: None,
            ps: None,
        }
    }

    /// Metrics JSON for the whole run: summary scalars plus the
    /// control-plane decision trace under the `"control"` key.
    pub fn to_json(&self) -> Json {
        // NaN/∞ (e.g. val loss of a run with no evals) have no JSON
        // representation; map them to null.
        let num = |x: f64| if x.is_finite() { Json::Num(x) } else { Json::Null };
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("algo".into(), Json::Str(self.algo.name().into()));
        m.insert("nodes".into(), Json::Num(self.nodes as f64));
        m.insert("global_batch".into(), Json::Num(self.global_batch as f64));
        m.insert("steps".into(), Json::Num(self.steps as f64));
        m.insert("final_train_loss".into(), num(self.final_train_loss as f64));
        m.insert("final_train_err".into(), num(self.final_train_err as f64));
        m.insert("final_val_loss".into(), num(self.final_val_loss as f64));
        m.insert("final_val_err".into(), num(self.final_val_err as f64));
        m.insert("best_val_err".into(), num(self.best_val_err as f64));
        m.insert("sim_time_s".into(), num(self.sim_time_s));
        m.insert("sim_throughput".into(), num(self.sim_throughput));
        m.insert("mean_iter_time".into(), num(self.mean_iter_time));
        m.insert("mean_dist_to_avg".into(), num(self.mean_dist_to_avg));
        m.insert("wall_time_s".into(), num(self.wall_time_s));
        m.insert("evals".into(), self.recorder.evals_json());
        m.insert("control".into(), self.control.to_json());
        // Where the run's all-reduce time went: local vs global links,
        // and how often the control plane switched schedules.
        m.insert("comm".into(), self.control.comm_summary().to_json());
        // Gradient-compression accounting: compressor, achieved wire
        // bytes, and the compress_coupled ratio trace.
        m.insert("compress".into(), self.control.compress_summary().to_json());
        // Membership-epoch trace: world-size trajectory, join/depart
        // sets, and the cross-rank parameter-checksum agreement.
        m.insert("epochs".into(), self.epochs.to_json());
        // The heterogeneity profile the run executed; `enabled: false`
        // stub on the homogeneous path so consumers always find the key.
        m.insert(
            "hetero".into(),
            match &self.hetero {
                Some(p) => p.to_json(),
                None => {
                    let mut h = std::collections::BTreeMap::new();
                    h.insert("enabled".to_string(), Json::Bool(false));
                    Json::Obj(h)
                }
            },
        );
        // Engine-core profile: thread budget, kernel chunk width, phase
        // wall-time histograms. Wall-clock, hence nondeterministic.
        if let Some(p) = &self.perf {
            m.insert("perf".into(), p.clone());
        }
        // Observability block: journal summary, metric registry,
        // per-window overlap/compensation rows, per-rank t_C/t_AR and
        // staleness splits. `enabled: false` stub when an engine ran
        // without a hub, so consumers always find the key.
        m.insert(
            "obs".into(),
            match &self.obs {
                Some(o) => o.to_json(),
                None => {
                    let mut h = std::collections::BTreeMap::new();
                    h.insert("enabled".to_string(), Json::Bool(false));
                    Json::Obj(h)
                }
            },
        );
        // Parameter-server tier accounting; `enabled: false` stub on
        // decentralized runs so consumers always find the key.
        m.insert(
            "ps".into(),
            match &self.ps {
                Some(p) => p.clone(),
                None => {
                    let mut h = std::collections::BTreeMap::new();
                    h.insert("enabled".to_string(), Json::Bool(false));
                    Json::Obj(h)
                }
            },
        );
        Json::Obj(m)
    }

    /// The run JSON with every wall-clock-derived (hence
    /// nondeterministic) field removed: the `"perf"` block and
    /// `"wall_time_s"`. Two runs of the same config are byte-identical
    /// here regardless of `--threads` / `--pin-chunk` — the engine's
    /// determinism contract (docs/performance.md), pinned by
    /// `prop_parallel_engine_bitwise_equals_serial`.
    pub fn deterministic_json(&self) -> Json {
        match self.to_json() {
            Json::Obj(mut m) => {
                m.remove("perf");
                m.remove("wall_time_s");
                m.remove("obs");
                Json::Obj(m)
            }
            other => other,
        }
    }

    /// The obs journal's canonical (wall-clock-free) event text — the
    /// byte-comparable sequence the determinism proptests pin across
    /// thread counts and simulator backends. Empty when the engine ran
    /// without a hub or with tracing disabled.
    pub fn obs_journal_canonical(&self) -> String {
        self.obs.as_ref().map(|o| o.journal.canonical_text()).unwrap_or_default()
    }

    /// Write the run's metrics JSON (summary + control trace).
    pub fn write_json(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_json().to_string())?;
        Ok(())
    }

    /// One Table-I-style row.
    pub fn table_row(&self) -> String {
        format!(
            "{:<22} {:>7} {:>6} {:>6} | train {:>6.1}% val {:>6.1}% | {:>9.0} img/s | iter {:>8.4}s | ‖D‖ {:.3e}",
            self.name,
            self.algo.name(),
            self.global_batch,
            self.nodes,
            100.0 * (1.0 - self.final_train_err),
            100.0 * (1.0 - self.final_val_err),
            self.sim_throughput,
            self.mean_iter_time,
            self.mean_dist_to_avg,
        )
    }
}
