//! The DC-S3GD engine — the paper's Algorithm 1, generalized to
//! max-staleness k (§V extension; k = 1 reproduces the paper exactly).
//!
//! Per worker, per window of k local steps:
//!
//! ```text
//! MPI_Iallreduce(Δw_i)            // post previous window's update
//! g_i = ∇l(w_i)                   // overlapped compute (next batch)
//! Δ̄w  = MPI_Wait()                // blocks only if network is slower
//! D_i = Δ̄w/N − Δw_i               // Eq. 9: distance to average
//! g̃_i = g_i + λ_i g_i⊙g_i⊙D_i     // Eq. 10 + Eq. 17 (λ0 = 0 → S3GD)
//! Δw_i = U(g̃_i, η, μ)             // local optimizer
//! w_i  = w_i + D_i + Δw_i         // Eq. 12: move-to-average + step
//! ```
//!
//! The momentum-SGD path uses the fused single-pass kernel
//! ([`crate::dc::dc_correct_update`]); LARS/Adam take the unfused path
//! (correct, then `Optimizer::step`). With `cfg.lam0 == 0` or
//! `algo == S3gd` the correction is skipped but the staleness remains —
//! the ablation isolating the compensation's contribution.

use std::time::Instant;

use anyhow::Result;

use crate::algo::{Algo, RunReport, WorkerHarness};
use crate::comm::Group;
use crate::config::ExperimentConfig;
use crate::dc::{self, DcHyper};
use crate::optim::{build_optimizer, Optimizer};
use crate::tensor;

pub fn run(cfg: &ExperimentConfig, harness: WorkerHarness) -> Result<RunReport> {
    let lam0 = if cfg.algo == Algo::S3gd { 0.0 } else { cfg.lam0 };
    let n = harness.n_params();
    let group = Group::new(cfg.nodes, cfg.net);
    let sched = cfg.lr_schedule();
    let t_start = Instant::now();

    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for rank in 0..cfg.nodes {
            let mut ctx = harness.make_worker(cfg, rank);
            let mut comm = group.comm(rank);
            let init_w = harness.init_w.clone();
            let decay_mask = harness.decay_mask.clone();
            let layer_ranges = harness.layer_ranges.clone();
            let sched = sched.clone();
            let cfg = cfg.clone();

            handles.push(scope.spawn(move || -> Result<()> {
                let k = cfg.staleness as u64;
                let fused = cfg.optimizer == "momentum" || cfg.optimizer == "sgd";
                let mut w = init_w;
                // Optimizer state: fused path owns a velocity buffer
                // directly; unfused path owns a boxed optimizer.
                let mut velocity = vec![0.0f32; n];
                let mut opt: Option<Box<dyn Optimizer>> = if fused {
                    None
                } else {
                    Some(build_optimizer(
                        &cfg.optimizer,
                        n,
                        cfg.momentum,
                        &layer_ranges,
                        decay_mask.clone(),
                    ))
                };

                // Current window's accumulated update and the previous
                // posted window (handle + its Δw).
                let mut window_delta = vec![0.0f32; n];
                let mut step_delta = vec![0.0f32; n];
                let mut dist = vec![0.0f32; n];
                let mut gtilde = vec![0.0f32; n];
                let mut posted: Option<(crate::comm::PendingReduce, Vec<f32>)> = None;

                for t in 0..cfg.steps {
                    let (loss, err, wall) = ctx.train_step(&w);
                    let eta = sched.at(t);
                    let wd = cfg.wd_at(t, &sched);
                    let window_end = (t + 1) % k == 0;

                    let mut lam_used = 0.0f32;
                    let mut dist_norm = 0.0f64;

                    // Resolve the previous window's collective at this
                    // window's end: D_i per Eq. 9.
                    let d_opt: Option<&[f32]> = if window_end {
                        if let Some((handle, posted_delta)) = posted.take() {
                            let (sum, t_done) = handle.wait(ctx.clock.now());
                            ctx.clock.advance_to(t_done);
                            dc::distance_to_average(&sum, &posted_delta, cfg.nodes, &mut dist);
                            dist_norm = tensor::norm2(&dist);

                            // Periodic validation at the *average* weights
                            // w̄ = w_i + D_i (rank 0 only; Eq. 8/9).
                            if rank == 0
                                && cfg.eval_every > 0
                                && (t / k) % cfg.eval_every.max(1) == 0
                            {
                                let w_avg: Vec<f32> =
                                    w.iter().zip(&dist).map(|(a, b)| a + b).collect();
                                let (vl, ve) = ctx.eval(&w_avg, cfg.eval_batches);
                                ctx.record_eval(t, vl, ve);
                            }
                            Some(&dist)
                        } else {
                            None
                        }
                    } else {
                        None
                    };

                    if fused {
                        let hp = DcHyper { eta, mu: cfg.momentum, lam0, wd };
                        let info = dc::dc_correct_update(
                            &ctx.g,
                            d_opt,
                            &mut velocity,
                            &mut w,
                            decay_mask.as_deref(),
                            hp,
                            &mut step_delta,
                        );
                        lam_used = info.lam;
                    } else {
                        // Unfused: correct (Eq. 10/17), optimizer step,
                        // then Eq. 12 by hand.
                        let g_in: &[f32] = match d_opt {
                            Some(d) if lam0 != 0.0 => {
                                let lam = dc::dynamic_lambda(&ctx.g, d, lam0);
                                lam_used = lam;
                                dc::dc_correct(&ctx.g, d, lam, &mut gtilde);
                                &gtilde
                            }
                            _ => &ctx.g,
                        };
                        opt.as_mut().unwrap().step(g_in, &w, eta, wd, &mut step_delta);
                        if let Some(d) = d_opt {
                            tensor::add_assign(&mut w, d);
                        }
                        tensor::add_assign(&mut w, &step_delta);
                    }

                    tensor::add_assign(&mut window_delta, &step_delta);
                    ctx.record(t, loss, err, wall, lam_used, dist_norm, eta);

                    if window_end {
                        // Post this window's update (MPI_Iallreduce) and
                        // immediately continue computing — the overlap.
                        let handle = comm.iallreduce(&window_delta, ctx.clock.now());
                        posted = Some((handle, std::mem::take(&mut window_delta)));
                        window_delta = vec![0.0f32; n];
                    }
                }

                // Drain the final collective so every worker ends on the
                // averaged weights (and no request leaks).
                if let Some((handle, posted_delta)) = posted.take() {
                    let (sum, t_done) = handle.wait(ctx.clock.now());
                    ctx.clock.advance_to(t_done);
                    dc::distance_to_average(&sum, &posted_delta, cfg.nodes, &mut dist);
                    tensor::add_assign(&mut w, &dist);
                }

                // Final validation on the averaged weights (rank 0),
                // plus a checkpoint of the canonical averaged model.
                if rank == 0 {
                    let (vl, ve) = ctx.eval(&w, cfg.eval_batches.max(8));
                    ctx.record_eval(cfg.steps, vl, ve);
                    if let Some(dir) = &cfg.out_dir {
                        let ck = crate::model::Checkpoint {
                            iteration: cfg.steps,
                            weights: w.clone(),
                            velocity: velocity.clone(),
                        };
                        ck.save(dir.join(format!("{}_final.ckpt", cfg.name)))?;
                    }
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().expect("worker panicked")?;
        }
        Ok(())
    })?;

    let recorder = harness.recorder.clone();
    let final_val = recorder
        .evals()
        .last()
        .map(|e| (e.val_loss, e.val_err))
        .unwrap_or((f32::NAN, f32::NAN));
    let report = RunReport::assemble(cfg, recorder, final_val, t_start.elapsed().as_secs_f64());
    if let Some(dir) = &cfg.out_dir {
        std::fs::create_dir_all(dir)?;
        report.recorder.write_steps_csv(dir.join(format!("{}_steps.csv", cfg.name)))?;
        report.recorder.write_evals_csv(dir.join(format!("{}_evals.csv", cfg.name)))?;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NetModel;
    use crate::simtime::ComputeModel;

    fn base_cfg() -> ExperimentConfig {
        ExperimentConfig::builder("linear")
            .nodes(4)
            .local_batch(16)
            .steps(60)
            .eta_single(0.05)
            .base_batch(16)
            .data(1024, 256, 0.5)
            .compute(ComputeModel::uniform(1e-3))
            .net(NetModel::default())
            .build()
    }

    #[test]
    fn dcs3gd_trains_linear_model() {
        let cfg = base_cfg();
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert_eq!(report.recorder.n_steps(), 60 * 4);
        // better than chance (0.9 err for 10 classes)
        assert!(report.final_val_err < 0.75, "val err {}", report.final_val_err);
        assert!(report.final_train_loss.is_finite());
        assert!(report.sim_time_s > 0.0);
    }

    #[test]
    fn all_workers_converge_to_same_weights() {
        // The Eq. 8 invariant, end-to-end: with the final drain, every
        // worker's weights equal the average; we verify indirectly via
        // determinism: two identical runs produce identical reports.
        let cfg = base_cfg();
        let r1 = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let r2 = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert_eq!(r1.final_val_err, r2.final_val_err);
        assert_eq!(r1.final_train_loss, r2.final_train_loss);
    }

    #[test]
    fn staleness_two_runs() {
        let mut cfg = base_cfg();
        cfg.staleness = 2;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert!(report.final_val_err < 0.8, "val err {}", report.final_val_err);
    }

    #[test]
    fn final_checkpoint_written_and_loadable() {
        let dir = std::env::temp_dir().join(format!("dcs3gd_ckpt_run_{}", std::process::id()));
        let mut cfg = base_cfg();
        cfg.steps = 10;
        cfg.name = "ckpt_test".into();
        cfg.out_dir = Some(dir.clone());
        run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let ck =
            crate::model::Checkpoint::load(dir.join("ckpt_test_final.ckpt")).unwrap();
        assert_eq!(ck.iteration, 10);
        let h = WorkerHarness::prepare(&cfg).unwrap();
        assert_eq!(ck.weights.len(), h.n_params());
        assert!(crate::tensor::all_finite(&ck.weights));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn lam_zero_is_s3gd() {
        let mut cfg = base_cfg();
        cfg.algo = Algo::S3gd;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        // λ must be 0 on every step
        assert!(report.recorder.steps().iter().all(|s| s.lambda == 0.0));
    }

    #[test]
    fn dc_correction_engages_after_first_window() {
        let cfg = base_cfg();
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let steps = report.recorder.steps();
        // staleness 1: step 0 has no D (nothing posted yet); step 2+ do.
        let late: Vec<_> = steps.iter().filter(|s| s.iteration >= 2).collect();
        assert!(late.iter().any(|s| s.lambda > 0.0), "correction never engaged");
        assert!(late.iter().all(|s| s.dist_to_avg.is_finite()));
    }

    #[test]
    fn adam_local_optimizer_runs() {
        let mut cfg = base_cfg();
        cfg.optimizer = "adam".into();
        cfg.eta_single = 0.005;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert!(report.final_val_err < 0.85);
    }

    #[test]
    fn lars_local_optimizer_runs() {
        let mut cfg = base_cfg();
        cfg.optimizer = "lars".into();
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert!(report.final_train_loss.is_finite());
    }

    #[test]
    fn iteration_time_is_max_of_compute_and_comm_eq14() {
        // Make the network the bottleneck and verify mean iteration time
        // tracks t_AR, not t_C + t_AR.
        let mut cfg = base_cfg();
        cfg.steps = 30;
        cfg.compute = ComputeModel::uniform(1e-5); // t_C tiny: 1.6e-4/batch
        cfg.net = NetModel { alpha_s: 0.0, beta_bytes_per_s: 1e6, algo: crate::comm::AllReduceAlgo::Ring };
        let n = WorkerHarness::prepare(&cfg).unwrap().n_params();
        let t_ar = cfg.net.allreduce_time(n, cfg.nodes);
        let t_c = 16.0 * 1e-5;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let expect = t_ar.max(t_c);
        // first iteration has no wait; allow slack
        assert!(
            (report.mean_iter_time - expect).abs() / expect < 0.15,
            "iter {} vs max(t_C, t_AR) {}",
            report.mean_iter_time,
            expect
        );
    }
}
