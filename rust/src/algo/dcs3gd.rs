//! The DC-S3GD engine — the paper's Algorithm 1, generalized to
//! max-staleness k (§V extension; k = 1 reproduces the paper exactly).
//!
//! Per worker, per window of k local steps:
//!
//! ```text
//! MPI_Iallreduce(Δw_i)            // post previous window's update
//! g_i = ∇l(w_i)                   // overlapped compute (next batch)
//! Δ̄w  = MPI_Wait()                // blocks only if network is slower
//! D_i = Δ̄w/N − Δw_i               // Eq. 9: distance to average
//! g̃_i = g_i + λ_i g_i⊙g_i⊙D_i     // Eq. 10 + Eq. 17 (λ0 = 0 → S3GD)
//! Δw_i = U(g̃_i, η, μ)             // local optimizer
//! w_i  = w_i + D_i + Δw_i         // Eq. 12: move-to-average + step
//! ```
//!
//! The momentum-SGD path uses the fused single-pass kernel
//! ([`crate::dc::dc_correct_update`]); LARS/Adam take the unfused path
//! (correct, then `Optimizer::step`). With `cfg.lam0 == 0` or
//! `algo == S3gd` the correction is skipped but the staleness remains —
//! the ablation isolating the compensation's contribution.
//!
//! ## The elastic control plane
//!
//! The window length k, the λ0 scale, and — since the collective
//! schedule became first-class ([`crate::comm::CollectiveSchedule`]) —
//! the *schedule itself* are no longer static: at every wait/post
//! boundary the engine consults its
//! [`crate::control::StalenessController`], which may move k within the
//! configured bounds, rescale λ0, switch the all-reduce between the
//! flat ring and the hierarchical dragonfly schedule, and quarantine a
//! persistent straggler inside its dragonfly group (the group keeps the
//! base window while the other ranks' k is boosted, filling the
//! straggler's wall time with useful local steps).
//!
//! Because the rendezvous collective requires every rank to post the
//! identical round sequence, each posted update carries
//! [`ctrl_slots`]`(world)` piggyback elements: the rank's mean per-step
//! compute time and last observed collective latency (summed into
//! cross-rank means), plus a slot-offset element holding this rank's
//! own t_C (the zero-padded all-gather trick) — so the all-reduced tail
//! hands every rank the *same* observations, and the deterministic
//! controllers reach the same (k, schedule, quarantine) decision with
//! no extra communication round. The engine terminates on the
//! cumulative *healthy-rank* step count, so a quarantined group (which
//! runs fewer steps per window) still posts every round and the
//! rendezvous sequence stays matched.
//!
//! ## Gradient compression
//!
//! With a `[compress]` table the posted window update rides the wire
//! compressed ([`crate::compress`]): top-k as a sparse index+value
//! all-gather (each rank injects O(k)), QSGD as a dense reduce priced
//! at bits/32 of the volume. The engine's [`WindowCodec`] folds the
//! per-rank error-feedback residual into each window before
//! compressing, and Eq. 9's distance is measured against this rank's
//! *decompressed* contribution `q_i` — so `D_i = Σq/N − q_i` is exact
//! over what actually crossed the wire, the λ-correction (Eq. 10/17)
//! repairs the decompressed aggregate, and the dropped mass telescopes
//! through the residual instead of biasing the mean. Residuals re-zero
//! at every membership-epoch boundary and crash recovery (they measure
//! error against weights that no longer exist), the same rule as
//! momentum. The `compress_coupled` control policy co-tunes
//! (k, schedule, ratio) from the same piggybacked observations.
//!
//! ## Membership epochs
//!
//! The run's world size is itself elastic: a scripted kill that is not
//! respawned ([`crate::control::FaultPlan::depart`]) makes the rank
//! **leave** the group ([`crate::comm::Comm::leave`]); in-flight
//! rounds it never posts resolve over the survivors, and the engine
//! re-weights Eq. 9's mean by the actual contributor count so the
//! gradient mean stays unbiased. Survivors observe the shrink (or a
//! due `[[control.join]]` arrival, fired against the shared round
//! completion time) at their next wait and run the **epoch
//! transition** at that window boundary, identically on every rank:
//!
//! 1. advance the group epoch, admitting scripted joiners;
//! 2. all-reduce the post-update weights over the survivors and adopt
//!    the mean — every member of the new epoch holds **bit-identical**
//!    parameters (joiners bootstrap from the published
//!    [`crate::comm::JoinBootstrap`]; pinned by the epoch trace's
//!    parameter checksums);
//! 3. re-partition the data shards across the new world
//!    ([`crate::data::ShardSampler::reshard`]), re-derive the dragonfly
//!    topology from the new N ([`crate::comm::Dragonfly::refit`]), and
//!    rebuild the controller — re-baselining its t_C/t_AR evidence and
//!    re-deciding (k, schedule) for the new fabric (quarantine state is
//!    re-learned against the new groups);
//! 4. restart the window pipeline (the first window of an epoch has no
//!    staleness, exactly like the start of a run) and record the
//!    transition in the [`crate::control::EpochTrace`].
//!
//! Scripted faults ([`crate::control::FaultPlan`]) inject stragglers
//! and crashes; a killed worker that *does* respawn is detected by
//! heartbeat timeout and restored from the leader's latest
//! [`crate::control::SnapshotStore`] checkpoint, paying detection +
//! restore downtime on its virtual clock.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::algo::{Algo, RoundDriver, RunReport, WorkerHarness};
use crate::comm::{JoinBootstrap, PendingReduce};
use crate::compress::{RoundMode, WindowCodec};
use crate::config::ExperimentConfig;
use crate::control::{
    param_crc, ControlRecord, DynSspStaleness, EpochRecord, FaultKind, ScheduleEnv,
    SgsStaleness, StalenessController, WindowObs,
};
use crate::dc::{self, DcHyper};
use crate::exec::{Phase, RankClock};
use crate::obs::{EventKind, WindowRow};
use crate::model::Checkpoint;
use crate::optim::{build_optimizer, Optimizer};
use crate::tensor;

// The control piggyback layout now lives with the wire format in the
// compression subsystem ([`crate::compress`]); re-exported here for the
// engines' historical callers.
pub use crate::compress::{ctrl_slots, CTRL_BASE_SLOTS};

/// One in-flight window collective: the request, this rank's
/// *decompressed* contribution (the Eq. 9 reference `q_i` — equal to
/// the raw Δw when compression is off), the schedule it rode, and the
/// compression operating point it was posted at (for the decision
/// trace).
struct PostedWindow {
    handle: PendingReduce,
    own: Vec<f32>,
    algo: crate::comm::AllReduceAlgo,
    wire_bytes: f64,
    ratio: f64,
    /// The round rode its schedule as a control-plane probe.
    probe: bool,
    /// Window id at post time (the id the round's trace events carry).
    window: u64,
}

/// Per-worker controller for the engine variant: the configured policy
/// stack, wrapped by the per-rank bound layer when the run is a
/// `dyn_ssp` / `sgs` engine. Same construction at birth and at every
/// membership epoch transition, so the wrapped state re-baselines
/// exactly like the policy underneath it.
fn build_engine_controller(
    cfg: &ExperimentConfig,
    env: ScheduleEnv,
) -> Box<dyn StalenessController> {
    let inner = cfg.control.build_controller(cfg.staleness.max(1), env);
    match cfg.algo {
        Algo::DynSsp => Box::new(DynSspStaleness::new(
            inner,
            env.n_ranks,
            cfg.control.k_min,
            cfg.control.k_max,
        )),
        Algo::Sgs => Box::new(SgsStaleness::new(
            inner,
            cfg.seed,
            env.n_ranks,
            cfg.control.k_min,
            cfg.control.k_max,
        )),
        _ => inner,
    }
}

pub fn run(cfg: &ExperimentConfig, harness: WorkerHarness) -> Result<RunReport> {
    let lam0 = if cfg.algo == Algo::S3gd { 0.0 } else { cfg.lam0 };
    let n = harness.n_params();
    let membership = harness.membership.clone();
    let capacity = membership.capacity();
    // Engine core: rank bodies run on scoped threads but at most
    // `perf.threads` are runnable at once — each holds a pool permit
    // during compute and hands it back across every rendezvous wait
    // (the gate the driver plugs into the group). `--threads 1` is the
    // serial reference engine; results are bit-identical either way,
    // as is the dense/folded rendezvous backend the driver binds.
    let driver = RoundDriver::collective(cfg, capacity);
    let group = driver.group();
    let pool = &driver.pool;
    let profiler = driver.profiler.clone();
    let sched = cfg.lr_schedule();
    let t_start = Instant::now();

    std::thread::scope(|scope| -> Result<()> {
        let group_ref = &group;
        let mut handles = Vec::new();
        for rank in 0..capacity {
            let is_joiner = rank >= cfg.nodes;
            if is_joiner && !membership.is_join_rank(rank) {
                continue;
            }
            let mut ctx = harness.make_worker(cfg, rank);
            let initial_comm = (!is_joiner).then(|| group_ref.comm(rank));
            let init_w = harness.init_w.clone();
            let decay_mask = harness.decay_mask.clone();
            let layer_ranges = harness.layer_ranges.clone();
            let sched = sched.clone();
            let cfg = cfg.clone();
            let membership = membership.clone();
            let gate = pool.gate();
            let profiler = profiler.clone();
            let hub = driver.obs.clone();

            handles.push(scope.spawn(move || -> Result<()> {
                let _permit = gate.permit();
                let mut pclock = RankClock::new(profiler);
                let fused = cfg.optimizer == "momentum" || cfg.optimizer == "sgd";
                // Optimizer state: fused path owns a velocity buffer
                // directly; unfused path owns a boxed optimizer.
                let mut velocity = vec![0.0f32; n];
                let mut opt: Option<Box<dyn Optimizer>> = if fused {
                    None
                } else {
                    Some(build_optimizer(
                        &cfg.optimizer,
                        n,
                        cfg.momentum,
                        &layer_ranges,
                        decay_mask.clone(),
                    ))
                };

                // Membership view + resume counters. Initial members
                // start at epoch 0; scripted joiners park in admission
                // until the survivors publish their epoch's bootstrap.
                let mut epoch: u64 = 0;
                let mut t: u64 = 0;
                let mut sched_steps: u64 = 0;
                let mut window_idx: u64 = 0;
                let mut comm;
                let mut w;
                let mut world: Vec<usize>;
                let mut join_cursor = 0usize;
                if let Some(c0) = initial_comm {
                    comm = c0;
                    w = init_w.clone();
                    world = (0..cfg.nodes).collect();
                } else {
                    let admission =
                        pclock.time(Phase::CommWait, || group_ref.await_admission(rank));
                    let Some((c, boot)) = admission else {
                        return Ok(()); // run ended before our join fired
                    };
                    comm = c;
                    epoch = boot.epoch;
                    // the epoch's *pinned* member list — the live roster
                    // may already have lost a racing post-transition
                    // departer
                    world = comm.epoch_members();
                    w = boot.weights.as_ref().clone();
                    t = boot.sched_steps;
                    sched_steps = boot.sched_steps;
                    window_idx = boot.window;
                    join_cursor = boot.join_cursor;
                    ctx.clock.advance_to(boot.t_start + cfg.control.restore_s);
                    let slot =
                        world.iter().position(|&r| r == rank).expect("admitted member");
                    ctx.reshard(slot, world.len(), epoch);
                    ctx.new_incarnation(ctx.clock.now());
                    ctx.epochs.record(EpochRecord {
                        epoch,
                        rank,
                        slot,
                        world: world.len(),
                        sched_steps,
                        sim_time: boot.t_start,
                        w_crc: param_crc(&w),
                        joined: Vec::new(),
                        departed: Vec::new(),
                    });
                }

                // Per-epoch derived state. Epoch 0 runs on the
                // configured topology verbatim; transitions refit the
                // group shape to the live world size.
                let mut slot = world.iter().position(|&r| r == rank).expect("member");
                let mut leader = world[0];
                let mut slots = ctrl_slots(world.len());
                let mut topo = if epoch == 0 {
                    cfg.topology()
                } else {
                    cfg.topology().refit(world.len())
                };
                let mut npg = topo.nodes_per_group;
                let mut env = ScheduleEnv {
                    net: cfg.net,
                    topology: topo,
                    n_elems: n + slots,
                    n_ranks: world.len(),
                    compress: cfg.compress,
                    flat_link_scale: cfg.flat_link_residual(),
                };

                // Gradient compression codec: per-rank error-feedback
                // residual, rebound (and zeroed) at every membership
                // epoch. Joiners start with zeroed residuals by
                // construction.
                let mut codec = WindowCodec::new(&cfg.compress, n, cfg.seed, rank);
                codec.rebind(slot, world.len());
                // Dense aggregate of the decoded window collective.
                let mut dense_sum = vec![0.0f32; n];

                // Joiner LR warm-up: a rank bootstrapping mid-run ramps
                // its learning rate over the first
                // `control.join_warmup_windows` windows (zeroed
                // momentum + residuals make its first updates noisy).
                let warmup_total = if is_joiner { cfg.control.join_warmup_windows } else { 0 };
                let mut windows_since_join: u64 = 0;

                // Control plane: a per-worker controller instance; all
                // instances see identical (all-reduced) observations, so
                // their window/schedule decisions stay in lock-step
                // across ranks.
                let mut controller = build_engine_controller(&cfg, env);
                let mut decision = controller.current();
                let snapshot_every = cfg.control.snapshot_cadence();

                if membership.is_elastic() && epoch == 0 {
                    ctx.epochs.record(EpochRecord {
                        epoch: 0,
                        rank,
                        slot,
                        world: world.len(),
                        sched_steps: 0,
                        sim_time: 0.0,
                        w_crc: param_crc(&w),
                        joined: Vec::new(),
                        departed: Vec::new(),
                    });
                }

                // Current window's accumulated update and the previous
                // posted window (handle + its Δw + its schedule).
                let mut window_delta = vec![0.0f32; n];
                let mut step_delta = vec![0.0f32; n];
                let mut dist = vec![0.0f32; n];
                let mut gtilde = vec![0.0f32; n];
                let mut posted: Option<PostedWindow> = None;

                let mut steps_in_window = 0u64;
                let mut window_t_c = 0.0f64; // compute seconds this window
                let mut prev_t_ar = 0.0f64; // last observed collective latency
                // Start iterations of the current and previous windows —
                // `prev_window_start` is the deterministic snapshot bound:
                // this worker has completed the wait of round j−2, which
                // happens-after the leader's snapshot at the end of window
                // j−2 (iteration == start of window j−1).
                let mut cur_window_start = t;
                let mut prev_window_start = t;

                loop {
                    // Termination check up front so a zero-step run does
                    // no work at all (the post at the previous window's
                    // end already happened, keeping rounds matched).
                    if sched_steps >= cfg.steps {
                        break;
                    }

                    // Scripted crash? A respawned kill detects (heartbeat
                    // timeout) and restores from the snapshot store; an
                    // unrespawned kill is a *departure* — deregister so
                    // in-flight rounds resolve over the survivors, drain
                    // our outstanding request, and stop.
                    if !ctx.chaos.is_inert() {
                        if let Some(ev) = ctx.chaos.take_kill(ctx.clock.now()) {
                            if matches!(ev.kind, FaultKind::Kill { respawn: false }) {
                                comm.leave();
                                if let Some(p) = posted.take() {
                                    let (_, t_done) = p.handle.wait(ctx.clock.now());
                                    ctx.clock.advance_to(t_done);
                                }
                                ctx.control_log.record(ControlRecord {
                                    worker: rank,
                                    window: window_idx,
                                    iteration: t,
                                    sim_time: ctx.clock.now(),
                                    k: decision.k,
                                    lam_scale: decision.lam_scale,
                                    schedule: None,
                                    t_compute: 0.0,
                                    t_allreduce: 0.0,
                                    t_ar_local: 0.0,
                                    t_ar_global: 0.0,
                                    blocked_s: 0.0,
                                    compress: None,
                                    compress_ratio: 1.0,
                                    wire_bytes: 0.0,
                                    probe: false,
                                    event: Some(format!(
                                        "depart@{:.3}s epoch={epoch}",
                                        ev.at_s
                                    )),
                                });
                                let now = ctx.clock.now();
                                hub.record(
                                    EventKind::Fault,
                                    rank,
                                    window_idx,
                                    now,
                                    now,
                                    format!("depart epoch={epoch}"),
                                );
                                hub.metrics.inc("control.departs", 1);
                                return Ok(());
                            }
                            ctx.recover_from_kill(
                                &ev,
                                &cfg,
                                &init_w,
                                &mut w,
                                if fused { Some(&mut velocity) } else { None },
                                prev_window_start,
                                t,
                                window_idx,
                                decision.k,
                                decision.lam_scale,
                            );
                            if let Some(o) = opt.as_mut() {
                                o.reset();
                            }
                            // The restored snapshot predates the
                            // residual's reference point: drop it.
                            codec.reset_residual();
                        }
                    }

                    let t_before_step = ctx.clock.now();
                    let (loss, err, wall) = pclock.time(Phase::Compute, || ctx.train_step(&w));
                    window_t_c += ctx.clock.now() - t_before_step;
                    steps_in_window += 1;
                    let warm = if warmup_total > 0 && windows_since_join < warmup_total {
                        (windows_since_join + 1) as f32 / (warmup_total + 1) as f32
                    } else {
                        1.0
                    };
                    let eta = sched.at(t) * warm;
                    let wd = cfg.wd_at(t, &sched);
                    let my_k = decision.k_for(slot, npg);
                    let window_end = steps_in_window >= my_k as u64;
                    // k of the window being completed, as seen by
                    // healthy ranks — the termination currency.
                    let window_k = decision.k as u64;

                    let mut lam_used = 0.0f32;
                    let mut dist_norm = 0.0f64;
                    // Compensation ratio of this iteration's update and
                    // the consumed window's (id, t_c, t_ar, blocked) —
                    // joined into one obs row after the update runs.
                    let mut comp_ratio = 0.0f64;
                    let mut consumed: Option<(u64, f64, f64, f64)> = None;
                    // Membership transition decided at this window's
                    // wait: (departed ranks, joins due).
                    let mut pending_transition: Option<(Vec<usize>, Vec<usize>)> = None;

                    // Resolve the previous window's collective at this
                    // window's end: D_i per Eq. 9 — re-weighted by the
                    // actual contributor count, so a round that resolved
                    // over the survivors still averages unbiasedly.
                    let d_opt: Option<&[f32]> = if window_end {
                        if let Some(p) = posted.take() {
                            let post_time = p.handle.post_time;
                            let now_before_wait = ctx.clock.now();
                            let out = pclock
                                .time(Phase::CommWait, || p.handle.wait_outcome(now_before_wait));
                            ctx.clock.advance_to(out.time);
                            ctx.beat(out.time);
                            let blocked = out.blocked_since(now_before_wait);
                            prev_t_ar = out.latency_since(post_time);
                            // Seal span (our post → global completion),
                            // exposed wait, and the staleness this
                            // window's data was consumed at — the
                            // Fig. 2 overlap accounting.
                            hub.record(
                                EventKind::RoundSealed,
                                rank,
                                p.window,
                                post_time,
                                out.time,
                                "",
                            );
                            hub.record(
                                EventKind::WindowConsumed,
                                rank,
                                p.window,
                                now_before_wait,
                                out.time,
                                "",
                            );
                            if p.probe {
                                hub.record(
                                    EventKind::Probe,
                                    rank,
                                    p.window,
                                    post_time,
                                    out.time,
                                    p.algo.name(),
                                );
                            }
                            hub.staleness(rank, steps_in_window);
                            consumed = Some((
                                p.window,
                                (now_before_wait - post_time).max(0.0),
                                prev_t_ar,
                                blocked,
                            ));
                            let n_contrib = out.contributors.len();
                            // Decode: rebuild the dense aggregate (and
                            // the cross-rank observations) from the
                            // possibly-compressed round; Eq. 9 then
                            // measures against this rank's own
                            // *decompressed* contribution, so the
                            // residual error stays in the error-feedback
                            // loop, not in D_i.
                            let ctrl = pclock.time(Phase::Decode, || {
                                let ctrl = codec.decode(&out.data, n_contrib, &mut dense_sum);
                                dc::distance_to_average(
                                    &dense_sum,
                                    &p.own,
                                    n_contrib,
                                    &mut dist,
                                );
                                dist_norm = tensor::norm2(&dist);
                                ctrl
                            });

                            // Membership change? Departures show up as a
                            // short contributor set; arrivals fire when
                            // the shared completion time reaches their
                            // scripted at_s. Identical on every rank.
                            let joins_due =
                                membership.joins_due(join_cursor, out.t_complete);
                            if n_contrib < world.len() || !joins_due.is_empty() {
                                let departed: Vec<usize> = world
                                    .iter()
                                    .copied()
                                    .filter(|r| !out.contributors.contains(r))
                                    .collect();
                                pending_transition = Some((departed, joins_due));
                            }

                            // Periodic validation at the *average* weights
                            // w̄ = w_i + D_i (leader only; Eq. 8/9).
                            if rank == leader
                                && cfg.eval_every > 0
                                && window_idx % cfg.eval_every.max(1) == 0
                            {
                                let w_avg: Vec<f32> =
                                    w.iter().zip(&dist).map(|(a, b)| a + b).collect();
                                let (vl, ve) = pclock
                                    .time(Phase::Eval, || ctx.eval(&w_avg, cfg.eval_batches));
                                ctx.record_eval(t, vl, ve);
                            }

                            // Wait/post boundary: hand the cross-rank mean
                            // observations and the per-member t_C split
                            // (decoded from the round's control tail) to
                            // the controller — unless a transition is
                            // pending, which re-baselines the controller
                            // instead.
                            let obs = WindowObs {
                                window: window_idx,
                                iteration: t,
                                t_compute: ctrl.t_compute,
                                t_allreduce: ctrl.t_allreduce,
                                per_rank_t_c: ctrl.per_rank_t_c,
                                // The completed round's shared phase
                                // split and schedule — the probing
                                // layer's calibration attribution.
                                t_ar_local: out.phases.local_s,
                                t_ar_global: out.phases.global_s,
                                ran: Some(p.algo),
                                probe: p.probe,
                            };
                            let prev = decision.clone();
                            if pending_transition.is_none() {
                                decision = controller.on_window(&obs);
                            }
                            if rank == leader {
                                let mut notes: Vec<String> = Vec::new();
                                if decision.k != prev.k {
                                    notes.push(format!("k {} -> {}", prev.k, decision.k));
                                }
                                if decision.schedule != prev.schedule {
                                    notes.push(format!(
                                        "schedule {} -> {}",
                                        prev.schedule.map_or("default", |s| s.name()),
                                        decision.schedule.map_or("default", |s| s.name()),
                                    ));
                                }
                                match (prev.quarantine, decision.quarantine) {
                                    (None, Some(q)) => notes.push(format!(
                                        "quarantine rank={} group={} k_group={}",
                                        q.rank, q.group, q.k_group
                                    )),
                                    (Some(_), None) => notes.push("quarantine lifted".into()),
                                    _ => {}
                                }
                                if decision.compress_ratio != prev.compress_ratio {
                                    notes.push(format!(
                                        "ratio {} -> {}",
                                        prev.compress_ratio.unwrap_or(1.0),
                                        decision.compress_ratio.unwrap_or(1.0),
                                    ));
                                }
                                if p.probe {
                                    notes.push(format!("probe {}", p.algo.name()));
                                }
                                // Piggybacked per-slot per-step t_C split
                                // → per-rank audit trail for the dyn_ssp
                                // k_i decisions (µs histograms, one per
                                // rank, under "obs" metrics).
                                for (s, &tc) in obs.per_rank_t_c.iter().enumerate() {
                                    if let Some(&r) = world.get(s) {
                                        hub.metrics.observe_us(
                                            &format!("ctrl.per_step_t_c_us.rank{r}"),
                                            (tc.max(0.0) * 1e6) as u64,
                                        );
                                    }
                                }
                                ctx.control_log.record(ControlRecord {
                                    worker: rank,
                                    window: window_idx,
                                    iteration: t,
                                    sim_time: ctx.clock.now(),
                                    k: decision.k,
                                    lam_scale: decision.lam_scale,
                                    schedule: Some(p.algo.name().to_string()),
                                    t_compute: obs.t_compute,
                                    t_allreduce: obs.t_allreduce,
                                    t_ar_local: out.phases.local_s,
                                    t_ar_global: out.phases.global_s,
                                    blocked_s: blocked,
                                    compress: Some(codec.name().to_string()),
                                    compress_ratio: p.ratio,
                                    wire_bytes: p.wire_bytes,
                                    probe: p.probe,
                                    event: (!notes.is_empty()).then(|| notes.join("; ")),
                                });
                            }
                            Some(&dist[..])
                        } else {
                            None
                        }
                    } else {
                        None
                    };

                    let lam0_eff = lam0 * decision.lam_scale;
                    pclock.time(Phase::Update, || {
                        if fused {
                            let hp = DcHyper { eta, mu: cfg.momentum, lam0: lam0_eff, wd };
                            let info = dc::dc_correct_update(
                                &ctx.g,
                                d_opt,
                                &mut velocity,
                                &mut w,
                                decay_mask.as_deref(),
                                hp,
                                &mut step_delta,
                            );
                            lam_used = info.lam;
                            comp_ratio = info.comp_ratio();
                        } else {
                            // Unfused: correct (Eq. 10/17), optimizer
                            // step, then Eq. 12 by hand.
                            let g_in: &[f32] = match d_opt {
                                Some(d) if lam0_eff != 0.0 => {
                                    let (lam, gn, cn) =
                                        dc::dynamic_lambda_full(&ctx.g, d, lam0_eff);
                                    lam_used = lam;
                                    if gn > 0.0 {
                                        comp_ratio = lam as f64 * cn / gn;
                                    }
                                    dc::dc_correct(&ctx.g, d, lam, &mut gtilde);
                                    &gtilde
                                }
                                _ => &ctx.g,
                            };
                            opt.as_mut().unwrap().step(g_in, &w, eta, wd, &mut step_delta);
                            if let Some(d) = d_opt {
                                tensor::add_assign(&mut w, d);
                            }
                            tensor::add_assign(&mut w, &step_delta);
                        }
                    });

                    tensor::add_assign(&mut window_delta, &step_delta);
                    ctx.record(t, loss, err, wall, lam_used, dist_norm, eta);

                    // One obs row per consumed window, now that the
                    // update supplied the compensation ratio; the leader
                    // also journals the (k, λ, schedule) decision the
                    // controller made at the wait boundary.
                    if let Some((win, t_c, t_ar, blocked_s)) = consumed {
                        hub.window(WindowRow {
                            worker: rank,
                            window: win,
                            t_c,
                            t_ar,
                            blocked_s,
                            comp_ratio,
                        });
                        if rank == leader {
                            let now = ctx.clock.now();
                            hub.record(
                                EventKind::Decision,
                                rank,
                                win,
                                now,
                                now,
                                format!("{} comp={comp_ratio:.6}", decision.describe()),
                            );
                        }
                    }

                    if window_end {
                        windows_since_join += 1;
                        if let Some((departed, joins)) = pending_transition.take() {
                            // ---- membership epoch transition ----
                            // Every member of the old epoch reaches this
                            // point at the same round boundary with the
                            // identical (departed, joins) view.
                            epoch += 1;
                            world = comm.advance_epoch(epoch, &joins);
                            join_cursor += joins.len();
                            // Resync: survivors all-reduce their post-
                            // update weights and adopt the mean — the
                            // canonical epoch state, bit-identical on
                            // every member (identical payload × identical
                            // scale).
                            let resync_now = ctx.clock.now();
                            let sync = pclock.time(Phase::CommWait, || {
                                comm.iallreduce_sched(&w, resync_now, cfg.net.algo)
                                    .wait_outcome(resync_now)
                            });
                            ctx.clock.advance_to(sync.time);
                            let inv = 1.0 / sync.contributors.len() as f32;
                            for (wi, s) in w.iter_mut().zip(sync.data.iter()) {
                                *wi = s * inv;
                            }
                            velocity.iter_mut().for_each(|v| *v = 0.0);
                            if let Some(o) = opt.as_mut() {
                                o.reset();
                            }
                            window_idx += 1;
                            sched_steps += window_k;

                            // Joiners bootstrap from this exact state.
                            comm.publish_bootstrap(JoinBootstrap {
                                epoch,
                                weights: Arc::new(w.clone()),
                                t_start: sync.t_complete,
                                sched_steps,
                                window: window_idx,
                                join_cursor,
                            });

                            // Re-shard, re-derive the topology from the
                            // new N, and rebuild the controller — the
                            // t_C/t_AR evidence re-baselines and (k,
                            // schedule) is re-decided against the new
                            // fabric.
                            slot = world
                                .iter()
                                .position(|&r| r == rank)
                                .expect("survivor is a member");
                            leader = world[0];
                            ctx.reshard(slot, world.len(), epoch);
                            slots = ctrl_slots(world.len());
                            topo = cfg.topology().refit(world.len());
                            npg = topo.nodes_per_group;
                            env = ScheduleEnv {
                                net: cfg.net,
                                topology: topo,
                                n_elems: n + slots,
                                n_ranks: world.len(),
                                compress: cfg.compress,
                                flat_link_scale: cfg.flat_link_residual(),
                            };
                            // Residuals measure error against the old
                            // epoch's weights; the resync mean replaced
                            // them, so the residual re-zeroes with the
                            // new (slot, world) view — same rule as
                            // momentum.
                            codec.rebind(slot, world.len());
                            controller = build_engine_controller(&cfg, env);
                            decision = controller.current();
                            ctx.new_incarnation(ctx.clock.now());

                            ctx.epochs.record(EpochRecord {
                                epoch,
                                rank,
                                slot,
                                world: world.len(),
                                sched_steps,
                                sim_time: sync.t_complete,
                                w_crc: param_crc(&w),
                                joined: if slot == 0 { joins.clone() } else { Vec::new() },
                                departed: if slot == 0 {
                                    departed.clone()
                                } else {
                                    Vec::new()
                                },
                            });
                            if rank == leader {
                                hub.record(
                                    EventKind::EpochTransition,
                                    rank,
                                    epoch,
                                    resync_now,
                                    sync.t_complete,
                                    format!(
                                        "world={} departed={} joined={}",
                                        world.len(),
                                        departed.len(),
                                        joins.len()
                                    ),
                                );
                                hub.metrics.inc("membership.epochs", 1);
                                ctx.snapshots.put(Checkpoint {
                                    iteration: t + 1,
                                    weights: w.clone(),
                                    velocity: velocity.clone(),
                                });
                                ctx.control_log.record(ControlRecord {
                                    worker: rank,
                                    window: window_idx,
                                    iteration: t,
                                    sim_time: ctx.clock.now(),
                                    k: decision.k,
                                    lam_scale: decision.lam_scale,
                                    schedule: None,
                                    t_compute: 0.0,
                                    t_allreduce: 0.0,
                                    t_ar_local: 0.0,
                                    t_ar_global: 0.0,
                                    blocked_s: 0.0,
                                    compress: None,
                                    compress_ratio: 1.0,
                                    wire_bytes: 0.0,
                                    probe: false,
                                    event: Some(format!(
                                        "epoch {epoch}: world {} (-{:?} +{:?})",
                                        world.len(),
                                        departed,
                                        joins
                                    )),
                                });
                            }

                            // Fresh window pipeline: the first window of
                            // an epoch has no staleness, exactly like the
                            // start of a run.
                            window_delta.iter_mut().for_each(|x| *x = 0.0);
                            steps_in_window = 0;
                            window_t_c = 0.0;
                            prev_t_ar = 0.0;
                            prev_window_start = t + 1;
                            cur_window_start = t + 1;
                        } else {
                            // Leader refreshes the recovery snapshot: w
                            // here is the averaged state plus one local
                            // step (Eq. 8), the canonical restart point.
                            if rank == leader
                                && snapshot_every > 0
                                && (window_idx + 1) % snapshot_every == 0
                            {
                                ctx.snapshots.put(Checkpoint {
                                    iteration: t + 1,
                                    weights: w.clone(),
                                    velocity: velocity.clone(),
                                });
                            }

                            // Post this window's update on the decided
                            // schedule: the codec folds the residual,
                            // compresses, and appends the control
                            // piggyback; the engine immediately
                            // continues computing — the overlap. With
                            // compression off the wire payload (and its
                            // pricing) is bit-identical to the
                            // uncompressed path.
                            let per_step_t_c = window_t_c / steps_in_window as f64;
                            let algo = decision.schedule.unwrap_or(cfg.net.algo);
                            if let Some(r) = decision.compress_ratio {
                                codec.set_ratio(r);
                            }
                            let mut own = vec![0.0f32; n];
                            let wire = pclock.time(Phase::Encode, || {
                                codec.encode(&window_delta, per_step_t_c, prev_t_ar, &mut own)
                            });
                            let now = ctx.clock.now();
                            let handle = match codec.mode() {
                                RoundMode::DenseReduce => {
                                    comm.iallreduce_wire(&wire, now, algo, codec.wire_elems())
                                }
                                RoundMode::SparseGather => {
                                    comm.iallgather_sched(&wire, now, algo)
                                }
                            };
                            hub.record(
                                EventKind::RoundPosted,
                                rank,
                                window_idx,
                                now,
                                now,
                                format!("k={my_k} algo={}", algo.name()),
                            );
                            hub.metrics.inc("comm.rounds_posted", 1);
                            posted = Some(PostedWindow {
                                handle,
                                own,
                                algo,
                                wire_bytes: codec.wire_bytes(),
                                ratio: codec.ratio() as f64,
                                probe: decision.probe,
                                window: window_idx,
                            });
                            window_delta.iter_mut().for_each(|x| *x = 0.0);
                            window_idx += 1;
                            steps_in_window = 0;
                            window_t_c = 0.0;
                            prev_window_start = cur_window_start;
                            cur_window_start = t + 1;
                            sched_steps += window_k;
                        }
                    }
                    t += 1;
                }

                // Drain the final collective so every worker ends on the
                // averaged weights (and no request leaks). Re-weighted:
                // a departure at the very end still averages correctly.
                if let Some(p) = posted.take() {
                    let drain_now = ctx.clock.now();
                    let out = pclock.time(Phase::CommWait, || p.handle.wait_outcome(drain_now));
                    ctx.clock.advance_to(out.time);
                    pclock.time(Phase::Decode, || {
                        codec.decode(&out.data, out.contributors.len(), &mut dense_sum);
                        dc::distance_to_average(
                            &dense_sum,
                            &p.own,
                            out.contributors.len(),
                            &mut dist,
                        );
                    });
                    tensor::add_assign(&mut w, &dist);
                }

                // Unblock any scripted joiner whose event never fired —
                // before anything fallible below, so an I/O error can't
                // leave a parked joiner (and the whole scope) hanging.
                comm.shutdown();

                // Final validation on the averaged weights (leader),
                // plus a checkpoint of the canonical averaged model.
                if rank == leader {
                    let (vl, ve) =
                        pclock.time(Phase::Eval, || ctx.eval(&w, cfg.eval_batches.max(8)));
                    ctx.record_eval(cfg.steps, vl, ve);
                    if let Some(dir) = &cfg.out_dir {
                        let ck = crate::model::Checkpoint {
                            iteration: cfg.steps,
                            weights: w.clone(),
                            velocity: velocity.clone(),
                        };
                        ck.save(dir.join(format!("{}_final.ckpt", cfg.name)))?;
                    }
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().expect("worker panicked")?;
        }
        Ok(())
    })?;

    let recorder = harness.recorder.clone();
    let final_val = recorder
        .evals()
        .last()
        .map(|e| (e.val_loss, e.val_err))
        .unwrap_or((f32::NAN, f32::NAN));
    let mut report =
        RunReport::assemble(cfg, recorder, final_val, t_start.elapsed().as_secs_f64());
    report.control = harness.control_log.clone();
    report.epochs = harness.epochs.clone();
    report.perf = Some(profiler.to_json());
    report.obs = Some(driver.obs.clone());
    if let Some(path) = &cfg.trace.out {
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)?;
        }
        driver.obs.journal.write_jsonl(path)?;
    }
    if let Some(dir) = &cfg.out_dir {
        std::fs::create_dir_all(dir)?;
        report.recorder.write_steps_csv(dir.join(format!("{}_steps.csv", cfg.name)))?;
        report.recorder.write_evals_csv(dir.join(format!("{}_evals.csv", cfg.name)))?;
        report.write_json(dir.join(format!("{}_run.json", cfg.name)))?;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{AllReduceAlgo, Dragonfly, NetModel};
    use crate::control::{ControlPolicy, FaultPlan, JoinEvent};
    use crate::simtime::ComputeModel;

    fn base_cfg() -> ExperimentConfig {
        ExperimentConfig::builder("linear")
            .nodes(4)
            .local_batch(16)
            .steps(60)
            .eta_single(0.05)
            .base_batch(16)
            .data(1024, 256, 0.5)
            .compute(ComputeModel::uniform(1e-3))
            .net(NetModel::default())
            .build()
    }

    #[test]
    fn dcs3gd_trains_linear_model() {
        let cfg = base_cfg();
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert_eq!(report.recorder.n_steps(), 60 * 4);
        // better than chance (0.9 err for 10 classes)
        assert!(report.final_val_err < 0.75, "val err {}", report.final_val_err);
        assert!(report.final_train_loss.is_finite());
        assert!(report.sim_time_s > 0.0);
    }

    #[test]
    fn all_workers_converge_to_same_weights() {
        // The Eq. 8 invariant, end-to-end: with the final drain, every
        // worker's weights equal the average; we verify indirectly via
        // determinism: two identical runs produce identical reports.
        let cfg = base_cfg();
        let r1 = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let r2 = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert_eq!(r1.final_val_err, r2.final_val_err);
        assert_eq!(r1.final_train_loss, r2.final_train_loss);
    }

    #[test]
    fn zero_steps_run_does_nothing() {
        // Regression: the window-driven loop must not run a whole
        // window (and a collective) before noticing steps == 0.
        let mut cfg = base_cfg();
        cfg.steps = 0;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert_eq!(report.recorder.n_steps(), 0);
        assert_eq!(report.sim_time_s, 0.0);
    }

    #[test]
    fn staleness_two_runs() {
        let mut cfg = base_cfg();
        cfg.staleness = 2;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert!(report.final_val_err < 0.8, "val err {}", report.final_val_err);
    }

    #[test]
    fn final_checkpoint_written_and_loadable() {
        let dir = std::env::temp_dir().join(format!("dcs3gd_ckpt_run_{}", std::process::id()));
        let mut cfg = base_cfg();
        cfg.steps = 10;
        cfg.name = "ckpt_test".into();
        cfg.out_dir = Some(dir.clone());
        run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let ck =
            crate::model::Checkpoint::load(dir.join("ckpt_test_final.ckpt")).unwrap();
        assert_eq!(ck.iteration, 10);
        let h = WorkerHarness::prepare(&cfg).unwrap();
        assert_eq!(ck.weights.len(), h.n_params());
        assert!(crate::tensor::all_finite(&ck.weights));
        // The metrics JSON (summary + control trace + comm phases) must
        // round-trip.
        let j = crate::util::Json::parse(
            &std::fs::read_to_string(dir.join("ckpt_test_run.json")).unwrap(),
        )
        .unwrap();
        assert_eq!(j.get("algo").unwrap().as_str(), Some("dcs3gd"));
        assert!(j.get("control").unwrap().as_arr().is_some());
        assert!(j.get("comm").unwrap().get("rounds").is_some());
        // compression accounting is always exported; a dense run reads
        // kind = "none" at ratio 1
        assert_eq!(j.get("compress").unwrap().get("kind").unwrap().as_str(), Some("none"));
        assert_eq!(j.get("compress").unwrap().get("final_ratio").unwrap().as_f64(), Some(1.0));
        // fixed-membership runs export an empty epoch trace
        assert_eq!(j.get("epochs").unwrap().as_arr().map(|a| a.len()), Some(0));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn lam_zero_is_s3gd() {
        let mut cfg = base_cfg();
        cfg.algo = Algo::S3gd;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        // λ must be 0 on every step
        assert!(report.recorder.steps().iter().all(|s| s.lambda == 0.0));
    }

    #[test]
    fn dc_correction_engages_after_first_window() {
        let cfg = base_cfg();
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let steps = report.recorder.steps();
        // staleness 1: step 0 has no D (nothing posted yet); step 2+ do.
        let late: Vec<_> = steps.iter().filter(|s| s.iteration >= 2).collect();
        assert!(late.iter().any(|s| s.lambda > 0.0), "correction never engaged");
        assert!(late.iter().all(|s| s.dist_to_avg.is_finite()));
    }

    #[test]
    fn adam_local_optimizer_runs() {
        let mut cfg = base_cfg();
        cfg.optimizer = "adam".into();
        cfg.eta_single = 0.005;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert!(report.final_val_err < 0.85);
    }

    #[test]
    fn lars_local_optimizer_runs() {
        let mut cfg = base_cfg();
        cfg.optimizer = "lars".into();
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert!(report.final_train_loss.is_finite());
    }

    #[test]
    fn iteration_time_is_max_of_compute_and_comm_eq14() {
        // Make the network the bottleneck and verify mean iteration time
        // tracks t_AR, not t_C + t_AR.
        let mut cfg = base_cfg();
        cfg.steps = 30;
        cfg.compute = ComputeModel::uniform(1e-5); // t_C tiny: 1.6e-4/batch
        cfg.net = NetModel { alpha_s: 0.0, beta_bytes_per_s: 1e6, algo: AllReduceAlgo::Ring };
        let n = WorkerHarness::prepare(&cfg).unwrap().n_params();
        let t_ar = cfg.net.allreduce_time(n, cfg.nodes);
        let t_c = 16.0 * 1e-5;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let expect = t_ar.max(t_c);
        // first iteration has no wait; allow slack
        assert!(
            (report.mean_iter_time - expect).abs() / expect < 0.15,
            "iter {} vs max(t_C, t_AR) {}",
            report.mean_iter_time,
            expect
        );
    }

    #[test]
    fn fixed_policy_records_observations_without_moving_k() {
        let cfg = base_cfg(); // policy = Fixed by default
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let recs = report.control.records();
        assert!(!recs.is_empty(), "control trace must be recorded");
        assert!(recs.iter().all(|r| r.k == 1), "fixed policy moved k");
        assert_eq!(report.control.k_changes(), 0);
        // every window record names its schedule and the phases add up
        for r in &recs {
            assert_eq!(r.schedule.as_deref(), Some("ring"));
            assert!(r.t_ar_local >= 0.0 && r.t_ar_global == 0.0);
        }
    }

    #[test]
    fn adaptive_k_raises_staleness_on_slow_network() {
        // Network far slower than compute: the DssPid controller must
        // deepen the window to amortize t_AR.
        let mut cfg = base_cfg();
        cfg.steps = 80;
        cfg.compute = ComputeModel::uniform(1e-5);
        cfg.net = NetModel { alpha_s: 0.0, beta_bytes_per_s: 1e6, algo: AllReduceAlgo::Ring };
        cfg.control.policy = ControlPolicy::DssPid;
        cfg.control.k_max = 6;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let max_k = report.control.records().iter().map(|r| r.k).max().unwrap();
        assert!(max_k > 1, "controller never raised k (trace {:?})", report.control.records().len());
        assert!(report.control.k_changes() > 0);
        assert!(report.final_train_loss.is_finite());
    }

    #[test]
    fn adaptive_k_beats_fixed_k_wall_clock_on_slow_network() {
        let mk = |policy: ControlPolicy| {
            let mut cfg = base_cfg();
            cfg.steps = 80;
            cfg.compute = ComputeModel::uniform(1e-5);
            cfg.net = NetModel {
                alpha_s: 0.0,
                beta_bytes_per_s: 1e6,
                algo: AllReduceAlgo::Ring,
            };
            cfg.control.policy = policy;
            cfg.control.k_max = 6;
            cfg
        };
        let fixed = run(&mk(ControlPolicy::Fixed), WorkerHarness::prepare(&mk(ControlPolicy::Fixed)).unwrap()).unwrap();
        let adaptive = run(&mk(ControlPolicy::DssPid), WorkerHarness::prepare(&mk(ControlPolicy::DssPid)).unwrap()).unwrap();
        assert!(
            adaptive.sim_time_s < fixed.sim_time_s,
            "adaptive {} not faster than fixed {}",
            adaptive.sim_time_s,
            fixed.sim_time_s
        );
    }

    #[test]
    fn lambda_coupled_rescales_lam0() {
        let mut cfg = base_cfg();
        cfg.steps = 80;
        cfg.compute = ComputeModel::uniform(1e-5);
        cfg.net = NetModel { alpha_s: 0.0, beta_bytes_per_s: 1e6, algo: AllReduceAlgo::Ring };
        cfg.control.policy = ControlPolicy::LambdaCoupled;
        cfg.control.k_max = 4;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let recs = report.control.records();
        assert!(recs.iter().any(|r| r.lam_scale > 1.0), "λ never rescaled");
        assert!(recs.iter().all(|r| r.lam_scale <= cfg.control.lam_scale_max));
        assert!(report.final_train_loss.is_finite());
    }

    #[test]
    fn transient_slow_fault_costs_time_and_is_deterministic() {
        let mut cfg = base_cfg();
        cfg.net = NetModel::instant();
        let t_healthy =
            run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap().sim_time_s;
        cfg.control.faults = FaultPlan::new().slow(1, 0.0, 3.0, 0.02);
        let a = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let b = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert!(a.sim_time_s > t_healthy, "slow fault added no time");
        assert_eq!(a.sim_time_s, b.sim_time_s, "fault injection not deterministic");
        assert_eq!(a.final_train_loss, b.final_train_loss);
    }

    // --- schedule-coupled control ---

    /// A fabric where the flat ring is latency-dominated but the
    /// hierarchical dragonfly is cheap: the schedule-coupled policy
    /// must switch off the ring.
    fn hier_favorable_cfg() -> ExperimentConfig {
        let mut cfg = base_cfg();
        cfg.steps = 60;
        cfg.compute = ComputeModel::uniform(1e-5);
        // slow flat fabric
        cfg.net = NetModel { alpha_s: 1.5e-6, beta_bytes_per_s: 2e6, algo: AllReduceAlgo::Ring };
        // fast dragonfly candidate: 2 groups of 2
        cfg.dragonfly = Dragonfly {
            groups: 2,
            nodes_per_group: 2,
            alpha_local_s: 1e-6,
            beta_local: 1e9,
            alpha_global_s: 2e-6,
            beta_global: 2e8,
            ..Dragonfly::default()
        };
        cfg.control.policy = ControlPolicy::ScheduleCoupled;
        cfg.control.k_max = 4;
        cfg
    }

    #[test]
    fn schedule_coupled_switches_to_hierarchical_and_reports_phases() {
        let cfg = hier_favorable_cfg();
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let recs = report.control.records();
        assert!(
            recs.iter().any(|r| r.schedule.as_deref() == Some("hierarchical")),
            "schedule never switched (trace: {:?})",
            recs.iter().filter_map(|r| r.schedule.clone()).collect::<Vec<_>>()
        );
        assert!(report.control.schedule_switches() >= 1);
        // hierarchical windows must report a non-zero global phase
        let hier_recs: Vec<_> = recs
            .iter()
            .filter(|r| r.schedule.as_deref() == Some("hierarchical"))
            .collect();
        assert!(hier_recs.iter().all(|r| r.t_ar_global > 0.0));
        let summary = report.control.comm_summary();
        assert!(summary.global_s > 0.0 && summary.local_s > 0.0);
        assert!(report.final_train_loss.is_finite());
    }

    #[test]
    fn schedule_coupled_beats_flat_fixed_on_hier_favorable_fabric() {
        let coupled = hier_favorable_cfg();
        let mut fixed = hier_favorable_cfg();
        fixed.control.policy = ControlPolicy::Fixed;
        let r_coupled = run(&coupled, WorkerHarness::prepare(&coupled).unwrap()).unwrap();
        let r_fixed = run(&fixed, WorkerHarness::prepare(&fixed).unwrap()).unwrap();
        assert!(
            r_coupled.sim_time_s < r_fixed.sim_time_s,
            "schedule-coupled {} not faster than fixed flat {}",
            r_coupled.sim_time_s,
            r_fixed.sim_time_s
        );
    }

    #[test]
    fn quarantine_boosts_healthy_ranks_and_is_logged() {
        let mut cfg = base_cfg();
        cfg.steps = 120;
        cfg.staleness = 2;
        // rank 3 persistently 3× slower; network instant so the only
        // cost is the straggler's compute skew.
        cfg.compute = ComputeModel::uniform(1e-4).with_straggler(3, 3.0, 4);
        cfg.net = NetModel::instant();
        cfg.dragonfly = Dragonfly { groups: 2, nodes_per_group: 2, ..Dragonfly::default() };
        cfg.control.policy = ControlPolicy::ScheduleCoupled;
        cfg.control.k_max = 8;
        cfg.control.quarantine_after = 2;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let events = report.control.events();
        assert!(
            events.iter().any(|e| e
                .event
                .as_deref()
                .is_some_and(|s| s.contains("quarantine rank=3"))),
            "quarantine never engaged: {events:?}"
        );
        // rank 3 (group 1, with rank 2) must have recorded fewer local
        // steps than the boosted healthy ranks.
        let steps = report.recorder.steps();
        let count = |w: usize| steps.iter().filter(|s| s.worker == w).count();
        assert!(
            count(3) < count(0),
            "quarantined rank ran {} steps vs healthy {}",
            count(3),
            count(0)
        );
        // and its group-mate shares the group-local window
        assert_eq!(count(2), count(3), "group members must share the window");
        assert!(report.final_train_loss.is_finite());
    }

    #[test]
    fn quarantine_runs_are_deterministic() {
        let mut cfg = base_cfg();
        cfg.steps = 80;
        cfg.compute = ComputeModel::uniform(1e-4).with_straggler(1, 2.5, 4);
        cfg.net = NetModel::instant();
        cfg.control.policy = ControlPolicy::ScheduleCoupled;
        cfg.control.quarantine_after = 2;
        let a = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let b = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert_eq!(a.sim_time_s, b.sim_time_s);
        assert_eq!(a.final_train_loss, b.final_train_loss);
        assert_eq!(a.control.records(), b.control.records());
    }

    // --- gradient compression ---

    #[test]
    fn topk_compression_trains_and_cuts_wire_bytes() {
        let mut cfg = base_cfg();
        cfg.compress.kind = crate::compress::CompressorKind::TopK;
        cfg.compress.ratio = 0.05;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert!(report.final_val_err < 0.8, "val err {}", report.final_val_err);
        let s = report.control.compress_summary();
        assert_eq!(s.kind, "topk");
        assert!(s.rounds > 0);
        let n = WorkerHarness::prepare(&cfg).unwrap().n_params();
        let dense_bytes = (n + ctrl_slots(cfg.nodes)) as f64 * 4.0;
        assert!(
            s.mean_wire_bytes() < 0.2 * dense_bytes,
            "wire {} not < 20% of dense {}",
            s.mean_wire_bytes(),
            dense_bytes
        );
    }

    #[test]
    fn qsgd_compression_trains_and_prices_reduced_volume() {
        let mut cfg = base_cfg();
        cfg.compress.kind = crate::compress::CompressorKind::Qsgd;
        cfg.compress.bits = 8;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert!(report.final_val_err < 0.8, "val err {}", report.final_val_err);
        let s = report.control.compress_summary();
        assert_eq!(s.kind, "qsgd");
        let n = WorkerHarness::prepare(&cfg).unwrap().n_params();
        let dense_bytes = (n + ctrl_slots(cfg.nodes)) as f64 * 4.0;
        assert!(s.mean_wire_bytes() < 0.3 * dense_bytes, "8-bit wire must be ~1/4 dense");
    }

    #[test]
    fn compressed_runs_are_deterministic() {
        let mk = |kind| {
            let mut cfg = base_cfg();
            cfg.compress.kind = kind;
            cfg.compress.ratio = 0.1;
            cfg
        };
        for kind in
            [crate::compress::CompressorKind::TopK, crate::compress::CompressorKind::Qsgd]
        {
            let a = run(&mk(kind), WorkerHarness::prepare(&mk(kind)).unwrap()).unwrap();
            let b = run(&mk(kind), WorkerHarness::prepare(&mk(kind)).unwrap()).unwrap();
            assert_eq!(a.final_train_loss, b.final_train_loss, "{kind:?}");
            assert_eq!(a.sim_time_s, b.sim_time_s, "{kind:?}");
        }
    }

    #[test]
    fn topk_sparse_round_costs_less_than_dense_on_slow_fabric() {
        // Same slow fabric, same steps: the sparse all-gather payload
        // must buy simulated wall-clock vs the dense ring.
        let mk = |kind| {
            let mut cfg = base_cfg();
            cfg.steps = 40;
            cfg.compute = ComputeModel::uniform(1e-5);
            cfg.net = NetModel { alpha_s: 0.0, beta_bytes_per_s: 1e6, algo: AllReduceAlgo::Ring };
            cfg.compress.kind = kind;
            cfg.compress.ratio = 0.02;
            cfg
        };
        let dense = mk(crate::compress::CompressorKind::None);
        let topk = mk(crate::compress::CompressorKind::TopK);
        let r_dense = run(&dense, WorkerHarness::prepare(&dense).unwrap()).unwrap();
        let r_topk = run(&topk, WorkerHarness::prepare(&topk).unwrap()).unwrap();
        assert!(
            r_topk.sim_time_s < r_dense.sim_time_s / 2.0,
            "top-k {} not at least 2x faster than dense {}",
            r_topk.sim_time_s,
            r_dense.sim_time_s
        );
        assert!(r_topk.final_train_loss.is_finite());
    }

    #[test]
    fn topk_survives_membership_transitions_bit_identically() {
        let mut cfg = base_cfg();
        cfg.steps = 40;
        cfg.compress.kind = crate::compress::CompressorKind::TopK;
        cfg.compress.ratio = 0.1;
        cfg.control.faults = FaultPlan::new().depart(3, 0.02);
        cfg.control.joins = vec![JoinEvent { rank: 4, at_s: 0.15 }];
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert_eq!(report.epochs.worlds(), vec![4, 3, 4]);
        assert!(
            report.epochs.crc_mismatches().is_empty(),
            "compressed ranks diverged at an epoch boundary"
        );
        assert!(report.final_train_loss.is_finite());
    }

    #[test]
    fn compress_coupled_tightens_ratio_on_slow_fabric_and_traces_it() {
        // t_AR far above the k_max window budget: the policy must walk
        // the ratio down, and the (k, schedule, ratio) trace must show
        // the move.
        let mut cfg = base_cfg();
        cfg.steps = 80;
        cfg.compute = ComputeModel::uniform(1e-5);
        cfg.net = NetModel { alpha_s: 0.0, beta_bytes_per_s: 2e5, algo: AllReduceAlgo::Ring };
        cfg.compress.kind = crate::compress::CompressorKind::TopK;
        cfg.compress.ratio = 0.25;
        cfg.control.policy = ControlPolicy::CompressCoupled;
        cfg.control.k_max = 2;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let s = report.control.compress_summary();
        assert!(s.ratio_changes >= 1, "ratio never moved (final {})", s.final_ratio);
        assert!(s.final_ratio < 0.25, "ratio did not tighten: {}", s.final_ratio);
        let recs = report.control.records();
        assert!(recs.iter().any(|r| r
            .event
            .as_deref()
            .is_some_and(|e| e.contains("ratio"))));
        assert!(report.final_train_loss.is_finite());
    }

    #[test]
    fn joiner_warmup_ramps_the_learning_rate() {
        let mut cfg = base_cfg();
        cfg.steps = 40;
        cfg.control.joins = vec![JoinEvent { rank: 4, at_s: 0.02 }];
        cfg.control.join_warmup_windows = 4;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let steps = report.recorder.steps();
        let first_join_iter = steps
            .iter()
            .filter(|s| s.worker == 4)
            .map(|s| s.iteration)
            .min()
            .expect("joiner ran steps");
        let lr_at = |w: usize, it: u64| {
            steps.iter().find(|s| s.worker == w && s.iteration == it).map(|s| s.lr)
        };
        let joiner_lr = lr_at(4, first_join_iter).unwrap();
        let initial_lr = lr_at(0, first_join_iter).expect("initial rank shares the iteration");
        assert!(
            joiner_lr < initial_lr,
            "warm-up must damp the joiner's LR: {joiner_lr} vs {initial_lr}"
        );
        // the ramp releases: the joiner's last windows run the full LR
        let last_join_iter =
            steps.iter().filter(|s| s.worker == 4).map(|s| s.iteration).max().unwrap();
        if let (Some(j), Some(i)) = (lr_at(4, last_join_iter), lr_at(0, last_join_iter)) {
            assert_eq!(j, i, "ramp must release after join_warmup_windows");
        }
        assert!(report.final_train_loss.is_finite());
    }

    // --- membership epochs ---

    #[test]
    fn shrink_resolves_rounds_over_survivors_and_stays_bit_identical() {
        // 4 → 3: rank 3 departs mid-run. The epoch must advance, the
        // survivors' parameters must agree bit-for-bit at the boundary,
        // and the run must finish with the full step budget.
        let mut cfg = base_cfg();
        cfg.steps = 40;
        cfg.control.faults = FaultPlan::new().depart(3, 0.02); // ≈ step 1-2
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert_eq!(report.epochs.worlds(), vec![4, 3]);
        assert!(report.epochs.crc_mismatches().is_empty(), "ranks diverged at the boundary");
        let transitions = report.epochs.transitions();
        assert_eq!(transitions[1].departed, vec![3]);
        assert!(report.control.events().iter().any(|e| e
            .event
            .as_deref()
            .is_some_and(|s| s.starts_with("depart@"))));
        assert!(report.final_train_loss.is_finite());
        assert!(report.final_val_err < 0.85, "val err {}", report.final_val_err);
    }

    #[test]
    fn grow_admits_scripted_joiners_from_the_bootstrap() {
        // 4 → 6: two fresh ranks join once the shared round time passes
        // their at_s. They must bootstrap bit-identical and contribute
        // steps.
        let mut cfg = base_cfg();
        cfg.steps = 40;
        cfg.control.joins =
            vec![JoinEvent { rank: 4, at_s: 0.02 }, JoinEvent { rank: 5, at_s: 0.02 }];
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert_eq!(report.epochs.worlds(), vec![4, 6]);
        assert!(report.epochs.crc_mismatches().is_empty());
        assert_eq!(report.epochs.transitions()[1].joined, vec![4, 5]);
        // the joiners really ran steps
        let steps = report.recorder.steps();
        assert!(steps.iter().any(|s| s.worker == 4));
        assert!(steps.iter().any(|s| s.worker == 5));
        assert!(report.final_train_loss.is_finite());
    }

    #[test]
    fn elastic_runs_are_deterministic() {
        let mk = || {
            let mut cfg = base_cfg();
            cfg.steps = 40;
            cfg.control.faults = FaultPlan::new().depart(2, 0.015);
            // well past the shrink transition (≈ 0.048s of shared round
            // time), so the grow is its own epoch
            cfg.control.joins = vec![JoinEvent { rank: 4, at_s: 0.15 }];
            cfg
        };
        let a = run(&mk(), WorkerHarness::prepare(&mk()).unwrap()).unwrap();
        let b = run(&mk(), WorkerHarness::prepare(&mk()).unwrap()).unwrap();
        assert_eq!(a.final_train_loss, b.final_train_loss);
        assert_eq!(a.sim_time_s, b.sim_time_s);
        assert_eq!(a.epochs.records(), b.epochs.records());
        assert_eq!(a.epochs.worlds(), vec![4, 3, 4]);
    }
}
