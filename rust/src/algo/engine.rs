//! Engine registry + [`RoundDriver`] facade — the one place where a
//! run's algorithm name resolves to an executable engine and where the
//! simulator backend ([`crate::comm::SimBackend`]) is selected.
//!
//! Before this module, the algo → engine mapping was a `match` in
//! [`super::run_experiment`] and every bench table hand-rolled its own
//! engine list; now both iterate [`engine_registry`]. Likewise each
//! engine hand-rolled the `Group` + `Pool` + `Profiler` construction
//! against raw comm calls; [`RoundDriver::collective`] /
//! [`RoundDriver::centralized`] own that wiring, so backend selection
//! (`[sim] backend = "dense" | "folded"`) never touches algorithm code.

use anyhow::Result;

use crate::algo::{dcs3gd, psasync, ssgd, Algo, RunReport, WorkerHarness};
use crate::comm::{Group, SimBackend};
use crate::config::ExperimentConfig;
use crate::exec::{Pool, Profiler};
use crate::obs::ObsHub;

/// A runnable training engine. Implemented by the registry's
/// [`EngineSpec`] entries; benches and examples that want to iterate
/// "every engine" or "every bench-table engine" go through
/// [`engine_registry`] instead of naming variants.
pub trait Engine {
    /// Canonical engine name (matches [`Algo::name`]).
    fn name(&self) -> &'static str;
    /// The algorithm this engine executes.
    fn algo(&self) -> Algo;
    /// Execute a prepared run end to end.
    fn run(&self, cfg: &ExperimentConfig, harness: WorkerHarness) -> Result<RunReport>;
}

/// One registry row: engine name → factory data. The `run_fn` pointer
/// is the engine body (three distinct bodies serve the seven names:
/// the windowed family shares [`dcs3gd::run`], the PS family shares
/// [`psasync::run`]).
pub struct EngineSpec {
    pub name: &'static str,
    pub algo: Algo,
    /// Appears as a row in the staleness bench tables
    /// (`benches/table1.rs`, `benches/hetero.rs`, `benches/engine.rs`):
    /// the windowed engines whose k policies the tables compare.
    pub bench_row: bool,
    run_fn: fn(&ExperimentConfig, WorkerHarness) -> Result<RunReport>,
}

impl Engine for EngineSpec {
    fn name(&self) -> &'static str {
        self.name
    }
    fn algo(&self) -> Algo {
        self.algo
    }
    fn run(&self, cfg: &ExperimentConfig, harness: WorkerHarness) -> Result<RunReport> {
        (self.run_fn)(cfg, harness)
    }
}

/// The data-driven engine table — every [`Algo`] variant has exactly
/// one row (pinned by a test below).
static REGISTRY: [EngineSpec; 7] = [
    EngineSpec { name: "ssgd", algo: Algo::Ssgd, bench_row: false, run_fn: ssgd::run },
    EngineSpec { name: "s3gd", algo: Algo::S3gd, bench_row: false, run_fn: dcs3gd::run },
    EngineSpec { name: "dcs3gd", algo: Algo::DcS3gd, bench_row: true, run_fn: dcs3gd::run },
    EngineSpec { name: "asgd", algo: Algo::Asgd, bench_row: false, run_fn: psasync::run },
    EngineSpec { name: "dcasgd", algo: Algo::DcAsgd, bench_row: false, run_fn: psasync::run },
    EngineSpec { name: "dyn_ssp", algo: Algo::DynSsp, bench_row: true, run_fn: dcs3gd::run },
    EngineSpec { name: "sgs", algo: Algo::Sgs, bench_row: true, run_fn: dcs3gd::run },
];

/// Every registered engine, in table order.
pub fn engine_registry() -> &'static [EngineSpec] {
    &REGISTRY
}

/// The registry row for an algorithm (total: every variant has one).
pub fn engine_for(algo: Algo) -> &'static EngineSpec {
    REGISTRY
        .iter()
        .find(|e| e.algo == algo)
        .expect("every Algo variant has a registry row")
}

/// Shared run-substrate facade: the rendezvous group (on the config's
/// simulator backend), the worker pool, and the profiler, wired
/// together the one correct way (gate plugged in before any traffic).
/// Collective engines get a [`Group`]; the parameter-server family
/// runs group-less but shares the pool/profiler wiring.
pub struct RoundDriver {
    group: Option<Group>,
    /// Engine worker pool: at most `perf.threads` ranks runnable at
    /// once; rank bodies hold a permit during compute and hand it back
    /// across rendezvous waits.
    pub pool: Pool,
    /// Wall-clock phase profiler, cloned into each rank body.
    pub profiler: std::sync::Arc<Profiler>,
    /// Trace journal + metric registry (see [`crate::obs`]), cloned
    /// into each rank body; virtual-time only, so its exports stay
    /// deterministic across thread counts and backends.
    pub obs: ObsHub,
}

impl RoundDriver {
    /// Driver for the all-reduce engines: an elastic group of
    /// `capacity` slots (`cfg.nodes` initial members) on the backend
    /// `cfg.sim.backend` selects, with the pool gate already plugged
    /// into the group's blocking waits.
    pub fn collective(cfg: &ExperimentConfig, capacity: usize) -> RoundDriver {
        let group = Group::with_backend(capacity, cfg.nodes, cfg.net, cfg.sim.backend);
        let pool = Pool::from_config(&cfg.perf);
        group.set_gate(pool.gate());
        let profiler = Profiler::new(pool.threads());
        let obs = ObsHub::new(&cfg.trace);
        RoundDriver { group: Some(group), pool, profiler, obs }
    }

    /// Driver for the parameter-server engines: pool + profiler only
    /// (the PS actor is service infrastructure, not a rank, and stays
    /// ungated).
    pub fn centralized(cfg: &ExperimentConfig) -> RoundDriver {
        let pool = Pool::from_config(&cfg.perf);
        let profiler = Profiler::new(pool.threads());
        let obs = ObsHub::new(&cfg.trace);
        RoundDriver { group: None, pool, profiler, obs }
    }

    /// The rendezvous group. Panics on a [`RoundDriver::centralized`]
    /// driver — the PS engines have no collective substrate.
    pub fn group(&self) -> &Group {
        self.group.as_ref().expect("centralized driver has no rendezvous group")
    }

    /// The backend the group resolves rounds on (dense for
    /// centralized drivers, which have no rounds to resolve).
    pub fn backend(&self) -> SimBackend {
        self.group.as_ref().map_or(SimBackend::Dense, |g| g.backend())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_algo_exactly_once() {
        let all = [
            Algo::Ssgd,
            Algo::S3gd,
            Algo::DcS3gd,
            Algo::Asgd,
            Algo::DcAsgd,
            Algo::DynSsp,
            Algo::Sgs,
        ];
        assert_eq!(engine_registry().len(), all.len());
        for algo in all {
            let spec = engine_for(algo);
            assert_eq!(spec.algo, algo);
            assert_eq!(spec.name, algo.name(), "registry name matches Algo::name");
        }
    }

    #[test]
    fn bench_rows_are_the_windowed_k_policy_engines() {
        let rows: Vec<&str> =
            engine_registry().iter().filter(|e| e.bench_row).map(|e| e.name).collect();
        assert_eq!(rows, vec!["dcs3gd", "dyn_ssp", "sgs"]);
    }

    #[test]
    fn collective_driver_binds_the_configured_backend() {
        let mut cfg = ExperimentConfig::builder("linear").nodes(4).build();
        cfg.sim.backend = SimBackend::Folded;
        let driver = RoundDriver::collective(&cfg, cfg.nodes);
        assert_eq!(driver.backend(), SimBackend::Folded);
        assert_eq!(driver.group().backend(), SimBackend::Folded);
        let dense = RoundDriver::collective(
            &ExperimentConfig::builder("linear").nodes(4).build(),
            4,
        );
        assert_eq!(dense.backend(), SimBackend::Dense);
    }

    #[test]
    fn centralized_driver_has_no_group() {
        let cfg = ExperimentConfig::builder("linear").nodes(2).build();
        let driver = RoundDriver::centralized(&cfg);
        assert_eq!(driver.backend(), SimBackend::Dense);
        assert!(driver.group.is_none());
    }

    #[test]
    fn drivers_build_the_obs_hub_from_trace_config() {
        let mut cfg = ExperimentConfig::builder("linear").nodes(2).build();
        assert!(RoundDriver::collective(&cfg, cfg.nodes).obs.journal.enabled());
        cfg.trace.capacity = 0;
        assert!(!RoundDriver::centralized(&cfg).obs.journal.enabled());
    }
}
