//! Synchronous SGD baseline — blocking all-reduce of gradients.
//!
//! The §II-A reference scheme the paper compares against: every
//! iteration all workers reduce their gradients, apply the *same*
//! mean-gradient update, and stay bit-identical. Per-iteration time is
//! Eq. 13's `t_C + t_AR` (no overlap): the collective cannot be posted
//! until the gradient exists, and the update cannot be applied until the
//! collective completes.
//!
//! The control plane is wired in observation mode: SSGD has no window to
//! stretch (its wait/post boundary is every iteration and k ≡ 1), but
//! the engine still beats heartbeats, applies the scripted
//! [`crate::control::FaultPlan`] (slowdowns, stalls, kills with
//! checkpoint recovery), consults the controller at each boundary, and
//! records the per-iteration blocked time — the straggler trace the
//! elastic engines are judged against.
//!
//! The collective *schedule* and the gradient **compression** apply
//! here in full. Every posted gradient carries the same
//! [`ctrl_slots`]`(N)` piggyback tail as DC-S3GD's window updates —
//! each rank's mean t_C and last observed t_AR, summed into cross-rank
//! means, plus the slot-offset per-rank t_C split — so every rank
//! hands its controller **identical observations** and the calibrated
//! `schedule_coupled` / `compress_coupled` switches stay in lock-step
//! across ranks (the old bootstrap-argmin-only restriction is gone).
//! Compression goes through the same [`WindowCodec`] as DC-S3GD with a
//! window of one step: error feedback keeps each rank's residual
//! rank-local, while the *decoded mean gradient* is identical on every
//! rank — so the SSGD bit-identical-replicas invariant holds under
//! compression too.

use std::time::Instant;

use anyhow::Result;

use crate::algo::dcs3gd::ctrl_slots;
use crate::algo::{RoundDriver, RunReport, WorkerHarness};
use crate::compress::{RoundMode, WindowCodec};
use crate::config::ExperimentConfig;
use crate::control::{ControlRecord, ScheduleEnv, WindowObs};
use crate::exec::{Phase, RankClock};
use crate::model::Checkpoint;
use crate::obs::{EventKind, WindowRow};
use crate::optim::build_optimizer;
use crate::tensor;

pub fn run(cfg: &ExperimentConfig, harness: WorkerHarness) -> Result<RunReport> {
    let n = harness.n_params();
    // Engine pool: at most `perf.threads` ranks runnable at once; the
    // gate hands permits back across the blocking all-reduce waits.
    // SSGD runs with pinned membership, so capacity == nodes.
    let driver = RoundDriver::collective(cfg, cfg.nodes);
    let group = driver.group();
    let pool = &driver.pool;
    let profiler = driver.profiler.clone();
    let sched = cfg.lr_schedule();
    let t_start = Instant::now();
    let env = ScheduleEnv {
        net: cfg.net,
        topology: cfg.topology(),
        n_elems: n + ctrl_slots(cfg.nodes),
        n_ranks: cfg.nodes,
        compress: cfg.compress,
        flat_link_scale: cfg.flat_link_residual(),
    };

    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for rank in 0..cfg.nodes {
            let mut ctx = harness.make_worker(cfg, rank);
            let mut comm = group.comm(rank);
            let init_w = harness.init_w.clone();
            let decay_mask = harness.decay_mask.clone();
            let layer_ranges = harness.layer_ranges.clone();
            let sched = sched.clone();
            let cfg = cfg.clone();
            let gate = pool.gate();
            let profiler = profiler.clone();
            let hub = driver.obs.clone();

            handles.push(scope.spawn(move || -> Result<()> {
                let _permit = gate.permit();
                let mut pclock = RankClock::new(profiler);
                let mut w = init_w.clone();
                let mut opt = build_optimizer(
                    &cfg.optimizer,
                    n,
                    cfg.momentum,
                    &layer_ranges,
                    decay_mask.clone(),
                );
                let mut g_mean = vec![0.0f32; n];
                let mut delta = vec![0.0f32; n];
                let mut dense_sum = vec![0.0f32; n];
                let mut own = vec![0.0f32; n];
                let mut prev_t_ar = 0.0f64;
                // Compression codec: per-rank residual, fixed world
                // (SSGD runs with pinned membership).
                let mut codec = WindowCodec::new(&cfg.compress, n, cfg.seed, rank);
                codec.rebind(rank, cfg.nodes);
                // Control plane: k is pinned at 1, but the schedule and
                // compression decisions apply to the blocking
                // all-reduce — fully live, since the piggybacked
                // observations are cross-rank means identical on every
                // rank.
                let mut controller = cfg.control.build_controller(1, env);
                let mut decision = controller.current();
                let snapshot_every = cfg.control.snapshot_cadence();

                for t in 0..cfg.steps {
                    if !ctx.chaos.is_inert() {
                        if let Some(ev) = ctx.chaos.take_kill(ctx.clock.now()) {
                            // Snapshot bound t−1: this worker completed the
                            // round t−1 all-reduce, which happens-after the
                            // leader's snapshot at the end of step t−2.
                            ctx.recover_from_kill(
                                &ev,
                                &cfg,
                                &init_w,
                                &mut w,
                                None,
                                t.saturating_sub(1),
                                t,
                                t,
                                1,
                                1.0,
                            );
                            opt.reset();
                            codec.reset_residual();
                        }
                    }
                    let t_before_step = ctx.clock.now();
                    let (loss, err, wall) = pclock.time(Phase::Compute, || ctx.train_step(&w));
                    let t_c = ctx.clock.now() - t_before_step;
                    // Blocking all-reduce of gradients on the decided
                    // schedule (Eq. 13), compressed through the codec
                    // with the piggybacked observation tail.
                    let now_before_wait = ctx.clock.now();
                    let algo = decision.schedule.unwrap_or(cfg.net.algo);
                    // Whether this step's collective is a control-plane
                    // probe (captured before on_window replaces the
                    // decision below).
                    let was_probe = decision.probe;
                    if let Some(r) = decision.compress_ratio {
                        codec.set_ratio(r);
                    }
                    let wire =
                        pclock.time(Phase::Encode, || codec.encode(&ctx.g, t_c, prev_t_ar, &mut own));
                    let handle = match codec.mode() {
                        RoundMode::DenseReduce => {
                            comm.iallreduce_wire(&wire, now_before_wait, algo, codec.wire_elems())
                        }
                        RoundMode::SparseGather => {
                            comm.iallgather_sched(&wire, now_before_wait, algo)
                        }
                    };
                    let out = pclock.time(Phase::CommWait, || handle.wait_outcome(now_before_wait));
                    ctx.clock.advance_to(out.time);
                    ctx.beat(out.time);
                    prev_t_ar = out.time - now_before_wait;
                    // Trace span triple: in SSGD the post instant *is*
                    // the wait instant — Eq. 13 has no overlap — so
                    // blocked time equals the whole collective and the
                    // overlap efficiency reads 0 by construction.
                    let win = t as u64;
                    hub.record(
                        EventKind::RoundPosted,
                        rank,
                        win,
                        now_before_wait,
                        now_before_wait,
                        format!("k=1 algo={}", algo.name()),
                    );
                    hub.record(EventKind::RoundSealed, rank, win, now_before_wait, out.time, "");
                    hub.record(EventKind::WindowConsumed, rank, win, now_before_wait, out.time, "");
                    if was_probe {
                        hub.record(EventKind::Probe, rank, win, out.time, out.time, algo.name());
                    }
                    hub.staleness(rank, 0);
                    hub.metrics.inc("comm.rounds_posted", 1);
                    hub.window(WindowRow {
                        worker: rank,
                        window: win,
                        t_c,
                        t_ar: out.blocked_since(now_before_wait),
                        blocked_s: out.blocked_since(now_before_wait),
                        comp_ratio: 0.0,
                    });
                    let ctrl = pclock.time(Phase::Decode, || {
                        codec.decode(&out.data, out.contributors.len(), &mut dense_sum)
                    });
                    let inv_n = 1.0 / cfg.nodes as f32;
                    let eta = sched.at(t);
                    let wd = cfg.wd_at(t, &sched);
                    pclock.time(Phase::Update, || {
                        for (m, s) in g_mean.iter_mut().zip(dense_sum.iter()) {
                            *m = s * inv_n;
                        }
                        opt.step(&g_mean, &w, eta, wd, &mut delta);
                        tensor::add_assign(&mut w, &delta);
                    });
                    ctx.record(t, loss, err, wall, 0.0, 0.0, eta);

                    // Wait/post boundary: consult with the decoded
                    // cross-rank means (identical on every rank, so the
                    // calibrated schedule / ratio switches stay matched
                    // across the fleet).
                    decision = controller.on_window(&WindowObs {
                        window: t,
                        iteration: t,
                        t_compute: ctrl.t_compute,
                        t_allreduce: ctrl.t_allreduce,
                        per_rank_t_c: ctrl.per_rank_t_c,
                        t_ar_local: out.phases.local_s,
                        t_ar_global: out.phases.global_s,
                        ran: Some(algo),
                        probe: was_probe,
                    });
                    if rank == 0 {
                        let now = ctx.clock.now();
                        hub.record(
                            EventKind::Decision,
                            rank,
                            t as u64,
                            now,
                            now,
                            format!("{} comp=0.000000", decision.describe()),
                        );
                        ctx.control_log.record(ControlRecord {
                            worker: rank,
                            window: t,
                            iteration: t,
                            sim_time: ctx.clock.now(),
                            k: 1,
                            lam_scale: decision.lam_scale,
                            schedule: Some(algo.name().to_string()),
                            t_compute: t_c,
                            t_allreduce: out.time - now_before_wait,
                            t_ar_local: out.phases.local_s,
                            t_ar_global: out.phases.global_s,
                            blocked_s: out.time - now_before_wait,
                            compress: Some(codec.name().to_string()),
                            compress_ratio: codec.ratio() as f64,
                            wire_bytes: codec.wire_bytes(),
                            probe: was_probe,
                            event: was_probe.then(|| format!("probe {}", algo.name())),
                        });
                        if snapshot_every > 0 && (t + 1) % snapshot_every == 0 {
                            ctx.snapshots.put(Checkpoint {
                                iteration: t + 1,
                                weights: w.clone(),
                                velocity: vec![0.0; n],
                            });
                        }
                    }

                    if rank == 0 && cfg.eval_every > 0 && t % cfg.eval_every == 0 {
                        let (vl, ve) = pclock.time(Phase::Eval, || ctx.eval(&w, cfg.eval_batches));
                        ctx.record_eval(t, vl, ve);
                    }
                }

                if rank == 0 {
                    let (vl, ve) =
                        pclock.time(Phase::Eval, || ctx.eval(&w, cfg.eval_batches.max(8)));
                    ctx.record_eval(cfg.steps, vl, ve);
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().expect("worker panicked")?;
        }
        Ok(())
    })?;

    let recorder = harness.recorder.clone();
    let final_val = recorder
        .evals()
        .last()
        .map(|e| (e.val_loss, e.val_err))
        .unwrap_or((f32::NAN, f32::NAN));
    let mut report =
        RunReport::assemble(cfg, recorder, final_val, t_start.elapsed().as_secs_f64());
    report.control = harness.control_log.clone();
    report.perf = Some(profiler.to_json());
    report.obs = Some(driver.obs.clone());
    if let Some(path) = &cfg.trace.out {
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)?;
        }
        driver.obs.journal.write_jsonl(path)?;
    }
    if let Some(dir) = &cfg.out_dir {
        std::fs::create_dir_all(dir)?;
        report.recorder.write_steps_csv(dir.join(format!("{}_steps.csv", cfg.name)))?;
        report.recorder.write_evals_csv(dir.join(format!("{}_evals.csv", cfg.name)))?;
        report.write_json(dir.join(format!("{}_run.json", cfg.name)))?;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{AllReduceAlgo, NetModel};
    use crate::simtime::ComputeModel;

    fn base_cfg() -> ExperimentConfig {
        ExperimentConfig::builder("linear")
            .name("ssgd_test")
            .algo(crate::algo::Algo::Ssgd)
            .nodes(4)
            .local_batch(16)
            .steps(60)
            .eta_single(0.05)
            .base_batch(16)
            .data(1024, 256, 0.5)
            .compute(ComputeModel::uniform(1e-3))
            .build()
    }

    #[test]
    fn ssgd_trains_linear_model() {
        let cfg = base_cfg();
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert!(report.final_val_err < 0.75, "val err {}", report.final_val_err);
    }

    #[test]
    fn iteration_time_is_sum_eq13() {
        let mut cfg = base_cfg();
        cfg.steps = 30;
        cfg.compute = ComputeModel::uniform(1e-4);
        cfg.net = NetModel { alpha_s: 0.0, beta_bytes_per_s: 1e6, algo: AllReduceAlgo::Ring };
        let n = WorkerHarness::prepare(&cfg).unwrap().n_params();
        let t_ar = cfg.net.allreduce_time(n, cfg.nodes);
        let t_c = 16.0 * 1e-4;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let expect = t_c + t_ar; // Eq. 13: no overlap
        assert!(
            (report.mean_iter_time - expect).abs() / expect < 0.05,
            "iter {} vs t_C+t_AR {}",
            report.mean_iter_time,
            expect
        );
    }

    #[test]
    fn straggler_slows_every_iteration() {
        // One 3× straggler: every SSGD iteration pays for it (§II-A).
        let mut cfg = base_cfg();
        cfg.steps = 20;
        cfg.compute = ComputeModel::uniform(1e-3).with_straggler(1, 3.0, 4);
        cfg.net = NetModel::instant();
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let t_slow = 16.0 * 1e-3 * 3.0;
        assert!(
            (report.mean_iter_time - t_slow).abs() / t_slow < 0.05,
            "iter {} vs straggler-bound {}",
            report.mean_iter_time,
            t_slow
        );
    }

    #[test]
    fn ssgd_runs_on_hierarchical_schedule() {
        // Configure the collective as hierarchical: Eq. 13 must hold
        // with the dragonfly t_AR, and the trace must carry the
        // schedule name plus a non-zero global phase.
        let mut cfg = base_cfg();
        cfg.steps = 20;
        let d = crate::comm::Dragonfly { groups: 2, nodes_per_group: 2, ..Default::default() };
        cfg.compute = ComputeModel::uniform(1e-4);
        cfg.net = NetModel {
            alpha_s: 1.5e-6,
            beta_bytes_per_s: 10e9,
            algo: crate::comm::AllReduceAlgo::Hierarchical(d),
        };
        let n = WorkerHarness::prepare(&cfg).unwrap().n_params();
        let t_ar = cfg.net.allreduce_time(n, cfg.nodes);
        assert!(t_ar > 0.0);
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let expect = 16.0 * 1e-4 + t_ar;
        assert!(
            (report.mean_iter_time - expect).abs() / expect < 0.05,
            "iter {} vs t_C+t_AR {}",
            report.mean_iter_time,
            expect
        );
        let recs = report.control.records();
        assert!(recs.iter().all(|r| r.schedule.as_deref() == Some("hierarchical")));
        assert!(recs.iter().all(|r| r.t_ar_global > 0.0));
    }

    #[test]
    fn workers_stay_identical() {
        // SSGD invariant: identical gradients mean identical losses on a
        // shared eval — use determinism across runs as the proxy.
        let cfg = base_cfg();
        let a = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let b = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert_eq!(a.final_val_err, b.final_val_err);
    }

    #[test]
    fn cross_rank_observations_feed_the_controller() {
        // The piggybacked tail hands every rank the real cross-rank
        // t_AR mean. A LambdaCoupled controller turns that evidence
        // into a k (and hence λ-scale) movement — impossible under the
        // old SSGD wiring, which withheld t_allreduce entirely (the
        // trace pinned lam_scale at 1.0 forever).
        let mut cfg = base_cfg();
        cfg.steps = 40;
        cfg.compute = ComputeModel::uniform(1e-5);
        cfg.net = NetModel { alpha_s: 0.0, beta_bytes_per_s: 1e6, algo: AllReduceAlgo::Ring };
        cfg.control.policy = crate::control::ControlPolicy::LambdaCoupled;
        cfg.control.k_max = 6;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let recs = report.control.records();
        assert!(
            recs.iter().any(|r| r.lam_scale > 1.0),
            "the controller never saw the piggybacked t_AR evidence"
        );
        // and the run stayed deterministic / bit-identical across ranks
        let again = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert_eq!(report.final_val_err, again.final_val_err);
    }

    #[test]
    fn ssgd_topk_compression_trains_and_stays_deterministic() {
        let mk = || {
            let mut cfg = base_cfg();
            cfg.compress.kind = crate::compress::CompressorKind::TopK;
            cfg.compress.ratio = 0.05;
            cfg
        };
        let a = run(&mk(), WorkerHarness::prepare(&mk()).unwrap()).unwrap();
        let b = run(&mk(), WorkerHarness::prepare(&mk()).unwrap()).unwrap();
        assert_eq!(a.final_val_err, b.final_val_err, "compressed SSGD not deterministic");
        assert!(a.final_val_err < 0.8, "val err {}", a.final_val_err);
        assert_eq!(a.control.compress_summary().kind, "topk");
    }

    #[test]
    fn ssgd_topk_cuts_iteration_time_on_slow_fabric() {
        let mk = |kind| {
            let mut cfg = base_cfg();
            cfg.steps = 20;
            cfg.compute = ComputeModel::uniform(1e-5);
            cfg.net = NetModel { alpha_s: 0.0, beta_bytes_per_s: 1e6, algo: AllReduceAlgo::Ring };
            cfg.compress.kind = kind;
            cfg.compress.ratio = 0.02;
            cfg
        };
        let dense = mk(crate::compress::CompressorKind::None);
        let topk = mk(crate::compress::CompressorKind::TopK);
        let r_dense = run(&dense, WorkerHarness::prepare(&dense).unwrap()).unwrap();
        let r_topk = run(&topk, WorkerHarness::prepare(&topk).unwrap()).unwrap();
        assert!(
            r_topk.mean_iter_time < r_dense.mean_iter_time / 2.0,
            "top-k iter {} not at least 2x under dense {}",
            r_topk.mean_iter_time,
            r_dense.mean_iter_time
        );
    }

    #[test]
    fn obs_windows_report_zero_overlap() {
        // Eq. 13 in trace form: the post and wait instants coincide, so
        // every window row is fully blocked and the headline overlap
        // efficiency is exactly zero.
        let mut cfg = base_cfg();
        cfg.steps = 20;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let obs = report.obs.as_ref().expect("ssgd run carries the obs hub");
        assert!(!obs.journal.is_empty(), "journal recorded no events");
        assert!(
            obs.overlap_efficiency_mean() < 1e-9,
            "blocking baseline claims overlap: {}",
            obs.overlap_efficiency_mean()
        );
        assert_eq!(obs.metrics.counter("comm.rounds_posted"), 20 * cfg.nodes as u64);
    }

    #[test]
    fn ssgd_qsgd_compression_trains() {
        let mut cfg = base_cfg();
        cfg.compress.kind = crate::compress::CompressorKind::Qsgd;
        cfg.compress.bits = 8;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert!(report.final_val_err < 0.8, "val err {}", report.final_val_err);
    }
}
