//! Synchronous SGD baseline — blocking all-reduce of gradients.
//!
//! The §II-A reference scheme the paper compares against: every
//! iteration all workers reduce their gradients, apply the *same*
//! mean-gradient update, and stay bit-identical. Per-iteration time is
//! Eq. 13's `t_C + t_AR` (no overlap): the collective cannot be posted
//! until the gradient exists, and the update cannot be applied until the
//! collective completes.
//!
//! The control plane is wired in observation mode: SSGD has no window to
//! stretch (its wait/post boundary is every iteration and k ≡ 1), but
//! the engine still beats heartbeats, applies the scripted
//! [`crate::control::FaultPlan`] (slowdowns, stalls, kills with
//! checkpoint recovery), consults the controller at each boundary, and
//! records the per-iteration blocked time — the straggler trace the
//! elastic engines are judged against.
//!
//! The collective *schedule* and the gradient **compression** apply
//! here in full. Every posted gradient carries the same
//! [`ctrl_slots`]`(N)` piggyback tail as DC-S3GD's window updates —
//! each rank's mean t_C and last observed t_AR, summed into cross-rank
//! means, plus the slot-offset per-rank t_C split — so every rank
//! hands its controller **identical observations** and the calibrated
//! `schedule_coupled` / `compress_coupled` switches stay in lock-step
//! across ranks (the old bootstrap-argmin-only restriction is gone).
//! Compression goes through the same [`WindowCodec`] as DC-S3GD with a
//! window of one step: error feedback keeps each rank's residual
//! rank-local, while the *decoded mean gradient* is identical on every
//! rank — so the SSGD bit-identical-replicas invariant holds under
//! compression too.
//!
//! **Elastic membership** applies here with the same contract as
//! DC-S3GD: a non-respawned kill makes the rank leave the group, the
//! survivors observe the short contributor set (the gradient mean is
//! re-weighted by the actual contributor count, so it stays unbiased),
//! and a due `[[control.join]]` arrival fires against the shared round
//! completion time. The epoch transition runs at the step boundary,
//! identically on every member: advance the group epoch, all-reduce
//! the post-update weights over the survivors and adopt the mean
//! (bit-identical parameters, pinned by the epoch trace checksums),
//! publish the [`JoinBootstrap`], re-shard, refit the topology, rebind
//! the codec and rebuild the controller. One deliberate difference:
//! there is **no joiner LR warm-up** — synchronous replicas share one
//! global step, so a per-rank learning-rate ramp would fork the
//! replica state the invariant forbids; a joiner enters at full LR
//! from the resync mean, which *is* the fleet's exact state.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::algo::dcs3gd::ctrl_slots;
use crate::algo::{RoundDriver, RunReport, WorkerHarness};
use crate::comm::JoinBootstrap;
use crate::compress::{RoundMode, WindowCodec};
use crate::config::ExperimentConfig;
use crate::control::{param_crc, ControlRecord, EpochRecord, FaultKind, ScheduleEnv, WindowObs};
use crate::exec::{Phase, RankClock};
use crate::model::Checkpoint;
use crate::obs::{EventKind, WindowRow};
use crate::optim::build_optimizer;
use crate::tensor;

pub fn run(cfg: &ExperimentConfig, harness: WorkerHarness) -> Result<RunReport> {
    let n = harness.n_params();
    // Engine pool: at most `perf.threads` ranks runnable at once; the
    // gate hands permits back across the blocking all-reduce waits.
    // The group is sized to the membership capacity so scripted joiner
    // slots exist from the start (they park in admission).
    let membership = harness.membership.clone();
    let capacity = membership.capacity();
    let driver = RoundDriver::collective(cfg, capacity);
    let group = driver.group();
    let pool = &driver.pool;
    let profiler = driver.profiler.clone();
    let sched = cfg.lr_schedule();
    let t_start = Instant::now();

    std::thread::scope(|scope| -> Result<()> {
        let group_ref = &group;
        let mut handles = Vec::new();
        for rank in 0..capacity {
            let is_joiner = rank >= cfg.nodes;
            if is_joiner && !membership.is_join_rank(rank) {
                continue;
            }
            let mut ctx = harness.make_worker(cfg, rank);
            let initial_comm = (!is_joiner).then(|| group_ref.comm(rank));
            let init_w = harness.init_w.clone();
            let decay_mask = harness.decay_mask.clone();
            let layer_ranges = harness.layer_ranges.clone();
            let sched = sched.clone();
            let cfg = cfg.clone();
            let membership = membership.clone();
            let gate = pool.gate();
            let profiler = profiler.clone();
            let hub = driver.obs.clone();

            handles.push(scope.spawn(move || -> Result<()> {
                let _permit = gate.permit();
                let mut pclock = RankClock::new(profiler);
                let mut opt = build_optimizer(
                    &cfg.optimizer,
                    n,
                    cfg.momentum,
                    &layer_ranges,
                    decay_mask.clone(),
                );
                let mut g_mean = vec![0.0f32; n];
                let mut delta = vec![0.0f32; n];
                let mut dense_sum = vec![0.0f32; n];
                let mut own = vec![0.0f32; n];
                let mut prev_t_ar = 0.0f64;

                // Membership view + resume counter. Initial members
                // start at epoch 0 and step 0; scripted joiners park in
                // admission until the survivors publish their epoch's
                // bootstrap, then resume at the published step so the
                // blocking round sequence stays matched.
                let mut epoch: u64 = 0;
                let mut t: u64 = 0;
                let mut comm;
                let mut w;
                let mut world: Vec<usize>;
                let mut join_cursor = 0usize;
                if let Some(c0) = initial_comm {
                    comm = c0;
                    w = init_w.clone();
                    world = (0..cfg.nodes).collect();
                } else {
                    let admission =
                        pclock.time(Phase::CommWait, || group_ref.await_admission(rank));
                    let Some((c, boot)) = admission else {
                        return Ok(()); // run ended before our join fired
                    };
                    comm = c;
                    epoch = boot.epoch;
                    // the epoch's *pinned* member list — the live roster
                    // may already have lost a racing post-transition
                    // departer
                    world = comm.epoch_members();
                    w = boot.weights.as_ref().clone();
                    t = boot.sched_steps;
                    join_cursor = boot.join_cursor;
                    ctx.clock.advance_to(boot.t_start + cfg.control.restore_s);
                    let slot =
                        world.iter().position(|&r| r == rank).expect("admitted member");
                    ctx.reshard(slot, world.len(), epoch);
                    ctx.new_incarnation(ctx.clock.now());
                    ctx.epochs.record(EpochRecord {
                        epoch,
                        rank,
                        slot,
                        world: world.len(),
                        sched_steps: t,
                        sim_time: boot.t_start,
                        w_crc: param_crc(&w),
                        joined: Vec::new(),
                        departed: Vec::new(),
                    });
                }

                // Per-epoch derived state. Epoch 0 runs on the
                // configured topology verbatim; transitions refit the
                // group shape to the live world size.
                let mut slot = world.iter().position(|&r| r == rank).expect("member");
                let mut leader = world[0];
                let mut topo = if epoch == 0 {
                    cfg.topology()
                } else {
                    cfg.topology().refit(world.len())
                };
                let mut env = ScheduleEnv {
                    net: cfg.net,
                    topology: topo,
                    n_elems: n + ctrl_slots(world.len()),
                    n_ranks: world.len(),
                    compress: cfg.compress,
                    flat_link_scale: cfg.flat_link_residual(),
                };

                // Compression codec: per-rank residual, rebound (and
                // zeroed) at every membership epoch.
                let mut codec = WindowCodec::new(&cfg.compress, n, cfg.seed, rank);
                codec.rebind(slot, world.len());
                // Control plane: k is pinned at 1, but the schedule and
                // compression decisions apply to the blocking
                // all-reduce — fully live, since the piggybacked
                // observations are cross-rank means identical on every
                // rank.
                let mut controller = cfg.control.build_controller(1, env);
                let mut decision = controller.current();
                let snapshot_every = cfg.control.snapshot_cadence();

                if membership.is_elastic() && epoch == 0 {
                    ctx.epochs.record(EpochRecord {
                        epoch: 0,
                        rank,
                        slot,
                        world: world.len(),
                        sched_steps: 0,
                        sim_time: 0.0,
                        w_crc: param_crc(&w),
                        joined: Vec::new(),
                        departed: Vec::new(),
                    });
                }

                while t < cfg.steps {
                    if !ctx.chaos.is_inert() {
                        if let Some(ev) = ctx.chaos.take_kill(ctx.clock.now()) {
                            if matches!(ev.kind, FaultKind::Kill { respawn: false }) {
                                // Departure: deregister so the survivors'
                                // next round resolves without us (the
                                // blocking engine holds nothing in
                                // flight at the step boundary).
                                comm.leave();
                                ctx.control_log.record(ControlRecord {
                                    worker: rank,
                                    window: t,
                                    iteration: t,
                                    sim_time: ctx.clock.now(),
                                    k: 1,
                                    lam_scale: decision.lam_scale,
                                    schedule: None,
                                    t_compute: 0.0,
                                    t_allreduce: 0.0,
                                    t_ar_local: 0.0,
                                    t_ar_global: 0.0,
                                    blocked_s: 0.0,
                                    compress: None,
                                    compress_ratio: 1.0,
                                    wire_bytes: 0.0,
                                    probe: false,
                                    event: Some(format!(
                                        "depart@{:.3}s epoch={epoch}",
                                        ev.at_s
                                    )),
                                });
                                let now = ctx.clock.now();
                                hub.record(
                                    EventKind::Fault,
                                    rank,
                                    t,
                                    now,
                                    now,
                                    format!("depart epoch={epoch}"),
                                );
                                hub.metrics.inc("control.departs", 1);
                                return Ok(());
                            }
                            // Snapshot bound t−1: this worker completed the
                            // round t−1 all-reduce, which happens-after the
                            // leader's snapshot at the end of step t−2.
                            ctx.recover_from_kill(
                                &ev,
                                &cfg,
                                &init_w,
                                &mut w,
                                None,
                                t.saturating_sub(1),
                                t,
                                t,
                                1,
                                1.0,
                            );
                            opt.reset();
                            codec.reset_residual();
                        }
                    }
                    let t_before_step = ctx.clock.now();
                    let (loss, err, wall) = pclock.time(Phase::Compute, || ctx.train_step(&w));
                    let t_c = ctx.clock.now() - t_before_step;
                    // Blocking all-reduce of gradients on the decided
                    // schedule (Eq. 13), compressed through the codec
                    // with the piggybacked observation tail.
                    let now_before_wait = ctx.clock.now();
                    let algo = decision.schedule.unwrap_or(cfg.net.algo);
                    // Whether this step's collective is a control-plane
                    // probe (captured before on_window replaces the
                    // decision below).
                    let was_probe = decision.probe;
                    if let Some(r) = decision.compress_ratio {
                        codec.set_ratio(r);
                    }
                    let wire =
                        pclock.time(Phase::Encode, || codec.encode(&ctx.g, t_c, prev_t_ar, &mut own));
                    let handle = match codec.mode() {
                        RoundMode::DenseReduce => {
                            comm.iallreduce_wire(&wire, now_before_wait, algo, codec.wire_elems())
                        }
                        RoundMode::SparseGather => {
                            comm.iallgather_sched(&wire, now_before_wait, algo)
                        }
                    };
                    let out = pclock.time(Phase::CommWait, || handle.wait_outcome(now_before_wait));
                    ctx.clock.advance_to(out.time);
                    ctx.beat(out.time);
                    prev_t_ar = out.time - now_before_wait;
                    // Trace span triple: in SSGD the post instant *is*
                    // the wait instant — Eq. 13 has no overlap — so
                    // blocked time equals the whole collective and the
                    // overlap efficiency reads 0 by construction.
                    let win = t;
                    hub.record(
                        EventKind::RoundPosted,
                        rank,
                        win,
                        now_before_wait,
                        now_before_wait,
                        format!("k=1 algo={}", algo.name()),
                    );
                    hub.record(EventKind::RoundSealed, rank, win, now_before_wait, out.time, "");
                    hub.record(EventKind::WindowConsumed, rank, win, now_before_wait, out.time, "");
                    if was_probe {
                        hub.record(EventKind::Probe, rank, win, out.time, out.time, algo.name());
                    }
                    hub.staleness(rank, 0);
                    hub.metrics.inc("comm.rounds_posted", 1);
                    hub.window(WindowRow {
                        worker: rank,
                        window: win,
                        t_c,
                        t_ar: out.blocked_since(now_before_wait),
                        blocked_s: out.blocked_since(now_before_wait),
                        comp_ratio: 0.0,
                    });
                    let n_contrib = out.contributors.len();
                    let ctrl = pclock.time(Phase::Decode, || {
                        codec.decode(&out.data, n_contrib, &mut dense_sum)
                    });
                    // Re-weight by the actual contributor count: a round
                    // that resolved over the survivors of a departure
                    // still averages unbiasedly (== 1/N on full rounds).
                    let inv_n = 1.0 / n_contrib as f32;
                    let eta = sched.at(t);
                    let wd = cfg.wd_at(t, &sched);
                    pclock.time(Phase::Update, || {
                        for (m, s) in g_mean.iter_mut().zip(dense_sum.iter()) {
                            *m = s * inv_n;
                        }
                        opt.step(&g_mean, &w, eta, wd, &mut delta);
                        tensor::add_assign(&mut w, &delta);
                    });
                    ctx.record(t, loss, err, wall, 0.0, 0.0, eta);

                    // Membership change? Departures show up as a short
                    // contributor set; arrivals fire when the shared
                    // completion time reaches their scripted at_s.
                    // Identical on every rank.
                    let joins_due = membership.joins_due(join_cursor, out.t_complete);
                    if n_contrib < world.len() || !joins_due.is_empty() {
                        // ---- membership epoch transition ----
                        // Every member of the old epoch reaches this
                        // point at the same step boundary with the
                        // identical (departed, joins) view.
                        let departed: Vec<usize> = world
                            .iter()
                            .copied()
                            .filter(|r| !out.contributors.contains(r))
                            .collect();
                        epoch += 1;
                        world = comm.advance_epoch(epoch, &joins_due);
                        join_cursor += joins_due.len();
                        // Resync: survivors all-reduce their post-update
                        // weights and adopt the mean — the canonical
                        // epoch state, bit-identical on every member
                        // (identical payload × identical scale).
                        let resync_now = ctx.clock.now();
                        let sync = pclock.time(Phase::CommWait, || {
                            comm.iallreduce_sched(&w, resync_now, cfg.net.algo)
                                .wait_outcome(resync_now)
                        });
                        ctx.clock.advance_to(sync.time);
                        let inv = 1.0 / sync.contributors.len() as f32;
                        for (wi, s) in w.iter_mut().zip(sync.data.iter()) {
                            *wi = s * inv;
                        }
                        opt.reset();

                        // Joiners bootstrap from this exact state and
                        // resume at step t+1 — the same step the
                        // survivors run next, keeping the blocking round
                        // sequence matched.
                        comm.publish_bootstrap(JoinBootstrap {
                            epoch,
                            weights: Arc::new(w.clone()),
                            t_start: sync.t_complete,
                            sched_steps: t + 1,
                            window: t + 1,
                            join_cursor,
                        });

                        // Re-shard, refit the topology to the new N,
                        // rebind the codec (residuals measure error
                        // against weights the resync replaced) and
                        // rebuild the controller — its t_C/t_AR evidence
                        // re-baselines against the new fabric.
                        slot = world
                            .iter()
                            .position(|&r| r == rank)
                            .expect("survivor is a member");
                        leader = world[0];
                        ctx.reshard(slot, world.len(), epoch);
                        topo = cfg.topology().refit(world.len());
                        env = ScheduleEnv {
                            net: cfg.net,
                            topology: topo,
                            n_elems: n + ctrl_slots(world.len()),
                            n_ranks: world.len(),
                            compress: cfg.compress,
                            flat_link_scale: cfg.flat_link_residual(),
                        };
                        codec.rebind(slot, world.len());
                        controller = cfg.control.build_controller(1, env);
                        decision = controller.current();
                        prev_t_ar = 0.0;
                        ctx.new_incarnation(ctx.clock.now());

                        ctx.epochs.record(EpochRecord {
                            epoch,
                            rank,
                            slot,
                            world: world.len(),
                            sched_steps: t + 1,
                            sim_time: sync.t_complete,
                            w_crc: param_crc(&w),
                            joined: if slot == 0 { joins_due.clone() } else { Vec::new() },
                            departed: if slot == 0 { departed.clone() } else { Vec::new() },
                        });
                        if rank == leader {
                            hub.record(
                                EventKind::EpochTransition,
                                rank,
                                epoch,
                                resync_now,
                                sync.t_complete,
                                format!(
                                    "world={} departed={} joined={}",
                                    world.len(),
                                    departed.len(),
                                    joins_due.len()
                                ),
                            );
                            hub.metrics.inc("membership.epochs", 1);
                            ctx.snapshots.put(Checkpoint {
                                iteration: t + 1,
                                weights: w.clone(),
                                velocity: vec![0.0; n],
                            });
                            ctx.control_log.record(ControlRecord {
                                worker: rank,
                                window: t,
                                iteration: t,
                                sim_time: ctx.clock.now(),
                                k: 1,
                                lam_scale: decision.lam_scale,
                                schedule: None,
                                t_compute: 0.0,
                                t_allreduce: 0.0,
                                t_ar_local: 0.0,
                                t_ar_global: 0.0,
                                blocked_s: 0.0,
                                compress: None,
                                compress_ratio: 1.0,
                                wire_bytes: 0.0,
                                probe: false,
                                event: Some(format!(
                                    "epoch {epoch}: world {} (-{departed:?} +{joins_due:?})",
                                    world.len()
                                )),
                            });
                        }
                    } else {
                        // Wait/post boundary: consult with the decoded
                        // cross-rank means (identical on every rank, so
                        // the calibrated schedule / ratio switches stay
                        // matched across the fleet).
                        decision = controller.on_window(&WindowObs {
                            window: t,
                            iteration: t,
                            t_compute: ctrl.t_compute,
                            t_allreduce: ctrl.t_allreduce,
                            per_rank_t_c: ctrl.per_rank_t_c,
                            t_ar_local: out.phases.local_s,
                            t_ar_global: out.phases.global_s,
                            ran: Some(algo),
                            probe: was_probe,
                        });
                        if rank == leader {
                            let now = ctx.clock.now();
                            hub.record(
                                EventKind::Decision,
                                rank,
                                t,
                                now,
                                now,
                                format!("{} comp=0.000000", decision.describe()),
                            );
                            ctx.control_log.record(ControlRecord {
                                worker: rank,
                                window: t,
                                iteration: t,
                                sim_time: ctx.clock.now(),
                                k: 1,
                                lam_scale: decision.lam_scale,
                                schedule: Some(algo.name().to_string()),
                                t_compute: t_c,
                                t_allreduce: out.time - now_before_wait,
                                t_ar_local: out.phases.local_s,
                                t_ar_global: out.phases.global_s,
                                blocked_s: out.time - now_before_wait,
                                compress: Some(codec.name().to_string()),
                                compress_ratio: codec.ratio() as f64,
                                wire_bytes: codec.wire_bytes(),
                                probe: was_probe,
                                event: was_probe.then(|| format!("probe {}", algo.name())),
                            });
                            if snapshot_every > 0 && (t + 1) % snapshot_every == 0 {
                                ctx.snapshots.put(Checkpoint {
                                    iteration: t + 1,
                                    weights: w.clone(),
                                    velocity: vec![0.0; n],
                                });
                            }
                        }
                    }

                    if rank == leader && cfg.eval_every > 0 && t % cfg.eval_every == 0 {
                        let (vl, ve) = pclock.time(Phase::Eval, || ctx.eval(&w, cfg.eval_batches));
                        ctx.record_eval(t, vl, ve);
                    }
                    t += 1;
                }

                // Unblock any scripted joiner whose event never fired —
                // before anything fallible below, so an I/O error can't
                // leave a parked joiner (and the whole scope) hanging.
                comm.shutdown();

                if rank == leader {
                    let (vl, ve) =
                        pclock.time(Phase::Eval, || ctx.eval(&w, cfg.eval_batches.max(8)));
                    ctx.record_eval(cfg.steps, vl, ve);
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().expect("worker panicked")?;
        }
        Ok(())
    })?;

    let recorder = harness.recorder.clone();
    let final_val = recorder
        .evals()
        .last()
        .map(|e| (e.val_loss, e.val_err))
        .unwrap_or((f32::NAN, f32::NAN));
    let mut report =
        RunReport::assemble(cfg, recorder, final_val, t_start.elapsed().as_secs_f64());
    report.control = harness.control_log.clone();
    report.epochs = harness.epochs.clone();
    report.perf = Some(profiler.to_json());
    report.obs = Some(driver.obs.clone());
    if let Some(path) = &cfg.trace.out {
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)?;
        }
        driver.obs.journal.write_jsonl(path)?;
    }
    if let Some(dir) = &cfg.out_dir {
        std::fs::create_dir_all(dir)?;
        report.recorder.write_steps_csv(dir.join(format!("{}_steps.csv", cfg.name)))?;
        report.recorder.write_evals_csv(dir.join(format!("{}_evals.csv", cfg.name)))?;
        report.write_json(dir.join(format!("{}_run.json", cfg.name)))?;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{AllReduceAlgo, NetModel};
    use crate::simtime::ComputeModel;

    fn base_cfg() -> ExperimentConfig {
        ExperimentConfig::builder("linear")
            .name("ssgd_test")
            .algo(crate::algo::Algo::Ssgd)
            .nodes(4)
            .local_batch(16)
            .steps(60)
            .eta_single(0.05)
            .base_batch(16)
            .data(1024, 256, 0.5)
            .compute(ComputeModel::uniform(1e-3))
            .build()
    }

    #[test]
    fn ssgd_trains_linear_model() {
        let cfg = base_cfg();
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert!(report.final_val_err < 0.75, "val err {}", report.final_val_err);
    }

    #[test]
    fn iteration_time_is_sum_eq13() {
        let mut cfg = base_cfg();
        cfg.steps = 30;
        cfg.compute = ComputeModel::uniform(1e-4);
        cfg.net = NetModel { alpha_s: 0.0, beta_bytes_per_s: 1e6, algo: AllReduceAlgo::Ring };
        let n = WorkerHarness::prepare(&cfg).unwrap().n_params();
        let t_ar = cfg.net.allreduce_time(n, cfg.nodes);
        let t_c = 16.0 * 1e-4;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let expect = t_c + t_ar; // Eq. 13: no overlap
        assert!(
            (report.mean_iter_time - expect).abs() / expect < 0.05,
            "iter {} vs t_C+t_AR {}",
            report.mean_iter_time,
            expect
        );
    }

    #[test]
    fn straggler_slows_every_iteration() {
        // One 3× straggler: every SSGD iteration pays for it (§II-A).
        let mut cfg = base_cfg();
        cfg.steps = 20;
        cfg.compute = ComputeModel::uniform(1e-3).with_straggler(1, 3.0, 4);
        cfg.net = NetModel::instant();
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let t_slow = 16.0 * 1e-3 * 3.0;
        assert!(
            (report.mean_iter_time - t_slow).abs() / t_slow < 0.05,
            "iter {} vs straggler-bound {}",
            report.mean_iter_time,
            t_slow
        );
    }

    #[test]
    fn ssgd_runs_on_hierarchical_schedule() {
        // Configure the collective as hierarchical: Eq. 13 must hold
        // with the dragonfly t_AR, and the trace must carry the
        // schedule name plus a non-zero global phase.
        let mut cfg = base_cfg();
        cfg.steps = 20;
        let d = crate::comm::Dragonfly { groups: 2, nodes_per_group: 2, ..Default::default() };
        cfg.compute = ComputeModel::uniform(1e-4);
        cfg.net = NetModel {
            alpha_s: 1.5e-6,
            beta_bytes_per_s: 10e9,
            algo: crate::comm::AllReduceAlgo::Hierarchical(d),
        };
        let n = WorkerHarness::prepare(&cfg).unwrap().n_params();
        let t_ar = cfg.net.allreduce_time(n, cfg.nodes);
        assert!(t_ar > 0.0);
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let expect = 16.0 * 1e-4 + t_ar;
        assert!(
            (report.mean_iter_time - expect).abs() / expect < 0.05,
            "iter {} vs t_C+t_AR {}",
            report.mean_iter_time,
            expect
        );
        let recs = report.control.records();
        assert!(recs.iter().all(|r| r.schedule.as_deref() == Some("hierarchical")));
        assert!(recs.iter().all(|r| r.t_ar_global > 0.0));
    }

    #[test]
    fn workers_stay_identical() {
        // SSGD invariant: identical gradients mean identical losses on a
        // shared eval — use determinism across runs as the proxy.
        let cfg = base_cfg();
        let a = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let b = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert_eq!(a.final_val_err, b.final_val_err);
    }

    #[test]
    fn cross_rank_observations_feed_the_controller() {
        // The piggybacked tail hands every rank the real cross-rank
        // t_AR mean. A LambdaCoupled controller turns that evidence
        // into a k (and hence λ-scale) movement — impossible under the
        // old SSGD wiring, which withheld t_allreduce entirely (the
        // trace pinned lam_scale at 1.0 forever).
        let mut cfg = base_cfg();
        cfg.steps = 40;
        cfg.compute = ComputeModel::uniform(1e-5);
        cfg.net = NetModel { alpha_s: 0.0, beta_bytes_per_s: 1e6, algo: AllReduceAlgo::Ring };
        cfg.control.policy = crate::control::ControlPolicy::LambdaCoupled;
        cfg.control.k_max = 6;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let recs = report.control.records();
        assert!(
            recs.iter().any(|r| r.lam_scale > 1.0),
            "the controller never saw the piggybacked t_AR evidence"
        );
        // and the run stayed deterministic / bit-identical across ranks
        let again = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert_eq!(report.final_val_err, again.final_val_err);
    }

    #[test]
    fn ssgd_topk_compression_trains_and_stays_deterministic() {
        let mk = || {
            let mut cfg = base_cfg();
            cfg.compress.kind = crate::compress::CompressorKind::TopK;
            cfg.compress.ratio = 0.05;
            cfg
        };
        let a = run(&mk(), WorkerHarness::prepare(&mk()).unwrap()).unwrap();
        let b = run(&mk(), WorkerHarness::prepare(&mk()).unwrap()).unwrap();
        assert_eq!(a.final_val_err, b.final_val_err, "compressed SSGD not deterministic");
        assert!(a.final_val_err < 0.8, "val err {}", a.final_val_err);
        assert_eq!(a.control.compress_summary().kind, "topk");
    }

    #[test]
    fn ssgd_topk_cuts_iteration_time_on_slow_fabric() {
        let mk = |kind| {
            let mut cfg = base_cfg();
            cfg.steps = 20;
            cfg.compute = ComputeModel::uniform(1e-5);
            cfg.net = NetModel { alpha_s: 0.0, beta_bytes_per_s: 1e6, algo: AllReduceAlgo::Ring };
            cfg.compress.kind = kind;
            cfg.compress.ratio = 0.02;
            cfg
        };
        let dense = mk(crate::compress::CompressorKind::None);
        let topk = mk(crate::compress::CompressorKind::TopK);
        let r_dense = run(&dense, WorkerHarness::prepare(&dense).unwrap()).unwrap();
        let r_topk = run(&topk, WorkerHarness::prepare(&topk).unwrap()).unwrap();
        assert!(
            r_topk.mean_iter_time < r_dense.mean_iter_time / 2.0,
            "top-k iter {} not at least 2x under dense {}",
            r_topk.mean_iter_time,
            r_dense.mean_iter_time
        );
    }

    #[test]
    fn obs_windows_report_zero_overlap() {
        // Eq. 13 in trace form: the post and wait instants coincide, so
        // every window row is fully blocked and the headline overlap
        // efficiency is exactly zero.
        let mut cfg = base_cfg();
        cfg.steps = 20;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        let obs = report.obs.as_ref().expect("ssgd run carries the obs hub");
        assert!(!obs.journal.is_empty(), "journal recorded no events");
        assert!(
            obs.overlap_efficiency_mean() < 1e-9,
            "blocking baseline claims overlap: {}",
            obs.overlap_efficiency_mean()
        );
        assert_eq!(obs.metrics.counter("comm.rounds_posted"), 20 * cfg.nodes as u64);
    }

    #[test]
    fn ssgd_qsgd_compression_trains() {
        let mut cfg = base_cfg();
        cfg.compress.kind = crate::compress::CompressorKind::Qsgd;
        cfg.compress.bits = 8;
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert!(report.final_val_err < 0.8, "val err {}", report.final_val_err);
    }

    #[test]
    fn membership_shrink_then_grow_stays_bit_identical() {
        // 4 → 3 (depart at 0.2s) → 4 (join at 0.5s). Every member must
        // hold bit-identical parameters at each epoch boundary (the
        // resync mean / published bootstrap), and the whole elastic run
        // must be deterministic across repeats.
        let mk = || {
            let mut cfg = base_cfg();
            cfg.name = "ssgd_elastic".into();
            cfg.control.faults = crate::control::FaultPlan::new().depart(1, 0.2);
            cfg.control.joins = vec![crate::control::JoinEvent { rank: 4, at_s: 0.5 }];
            cfg.control.restore_s = 0.01;
            cfg
        };
        let a = run(&mk(), WorkerHarness::prepare(&mk()).unwrap()).unwrap();
        assert_eq!(a.epochs.worlds(), vec![4, 3, 4], "roster trajectory");
        assert!(
            a.epochs.crc_mismatches().is_empty(),
            "members diverged at an epoch boundary: {:?}",
            a.epochs.crc_mismatches()
        );
        let transitions = a.epochs.transitions();
        assert_eq!(transitions[1].departed, vec![1]);
        assert_eq!(transitions[2].joined, vec![4]);
        assert!(
            a.recorder.steps().iter().any(|s| s.worker == 4),
            "joiner never stepped"
        );
        let b = run(&mk(), WorkerHarness::prepare(&mk()).unwrap()).unwrap();
        assert_eq!(a.final_val_err, b.final_val_err, "elastic SSGD not deterministic");
        assert!(a.final_val_err < 0.8, "val err {}", a.final_val_err);
    }

    #[test]
    fn departure_reweights_the_gradient_mean() {
        // Shrink-only run: after the departure the survivors' mean must
        // divide by 3, not 4 — the run converges and logs exactly one
        // departure plus one epoch transition.
        let mut cfg = base_cfg();
        cfg.name = "ssgd_shrink".into();
        cfg.control.faults = crate::control::FaultPlan::new().depart(2, 0.2);
        let report = run(&cfg, WorkerHarness::prepare(&cfg).unwrap()).unwrap();
        assert_eq!(report.epochs.worlds(), vec![4, 3]);
        let events = report.control.events();
        assert_eq!(
            events.iter().filter(|e| e.event.as_deref().unwrap_or("").starts_with("depart@")).count(),
            1
        );
        assert_eq!(
            events.iter().filter(|e| e.event.as_deref().unwrap_or("").starts_with("epoch ")).count(),
            1
        );
        assert!(report.final_val_err < 0.8, "val err {}", report.final_val_err);
    }
}
