//! Local optimizers `U(g, η, μ)` and the paper's update schedules.
//!
//! The paper's experiments use momentum SGD (§III-A) with an
//! iteration-indexed **linear warmup + linear decay** learning-rate
//! schedule whose warmup is stopped early at a plateau (§IV-A), and a
//! weight-decay parameter that follows the *same* schedule scaled by a
//! constant k = 2.3. §V names LARS and Adam as drop-in local optimizers;
//! both are implemented here and selectable from the config.

mod schedule;

pub use schedule::{LrSchedule, PlateauDetector, ScheduleKind};

use crate::tensor;

/// A local optimizer: consumes a (possibly delay-compensated) gradient
/// and produces the update Δw added to the weights. Stateful (momentum /
/// moment buffers live inside).
pub trait Optimizer: Send {
    /// Compute `delta_w` from `grad` at weights `w` for iteration `it`.
    /// `eta`/`wd` are schedule-resolved by the caller.
    fn step(&mut self, grad: &[f32], w: &[f32], eta: f32, wd: f32, delta_w: &mut [f32]);

    /// Number of parameters this optimizer was sized for.
    fn n_params(&self) -> usize;

    /// Reset internal state (momentum buffers etc.).
    fn reset(&mut self);

    /// Access the momentum/velocity buffer if the optimizer has one —
    /// the fused DC hot path (dc::dc_correct_update) updates it in
    /// place.
    fn velocity_mut(&mut self) -> Option<&mut [f32]> {
        None
    }
}

/// Momentum SGD: `v' = μ v + g + wd·mask·w; Δw = −η v'` (paper §III-A).
pub struct MomentumSgd {
    mu: f32,
    v: Vec<f32>,
    decay_mask: Option<Vec<f32>>,
}

impl MomentumSgd {
    pub fn new(n: usize, mu: f32) -> Self {
        MomentumSgd { mu, v: vec![0.0; n], decay_mask: None }
    }

    /// Attach a per-element decay mask (1 = decayed, 0 = exempt); the
    /// paper exempts batch-norm params, our norm-free models exempt
    /// biases (see python/compile/model.py::decay_mask).
    pub fn with_decay_mask(mut self, mask: Vec<f32>) -> Self {
        assert_eq!(mask.len(), self.v.len());
        self.decay_mask = Some(mask);
        self
    }

    pub fn mu(&self) -> f32 {
        self.mu
    }

    pub fn decay_mask(&self) -> Option<&[f32]> {
        self.decay_mask.as_deref()
    }
}

impl Optimizer for MomentumSgd {
    fn step(&mut self, grad: &[f32], w: &[f32], eta: f32, wd: f32, delta_w: &mut [f32]) {
        let n = self.v.len();
        assert_eq!(grad.len(), n);
        assert_eq!(w.len(), n);
        assert_eq!(delta_w.len(), n);
        // Chunk-blocked, zipped subslice walks: bounds checks are elided
        // and per-element order is width-independent (bit-identical for
        // every [`crate::exec::pin_chunk`] setting).
        let cw = crate::exec::pin_chunk();
        let mu = self.mu;
        match &self.decay_mask {
            Some(m) => {
                let mut lo = 0;
                while lo < n {
                    let hi = (lo + cw).min(n);
                    let rd = grad[lo..hi].iter().zip(&w[lo..hi]).zip(&m[lo..hi]);
                    let wr = self.v[lo..hi].iter_mut().zip(delta_w[lo..hi].iter_mut());
                    for (((gi, wi), mi), (vi, oi)) in rd.zip(wr) {
                        let vn = mu * *vi + gi + wd * mi * wi;
                        *vi = vn;
                        *oi = -eta * vn;
                    }
                    lo = hi;
                }
            }
            None => {
                let mut lo = 0;
                while lo < n {
                    let hi = (lo + cw).min(n);
                    let rd = grad[lo..hi].iter().zip(&w[lo..hi]);
                    let wr = self.v[lo..hi].iter_mut().zip(delta_w[lo..hi].iter_mut());
                    for ((gi, wi), (vi, oi)) in rd.zip(wr) {
                        let vn = mu * *vi + gi + wd * wi;
                        *vi = vn;
                        *oi = -eta * vn;
                    }
                    lo = hi;
                }
            }
        }
    }

    fn n_params(&self) -> usize {
        self.v.len()
    }

    fn reset(&mut self) {
        self.v.iter_mut().for_each(|x| *x = 0.0);
    }

    fn velocity_mut(&mut self) -> Option<&mut [f32]> {
        Some(&mut self.v)
    }
}

/// LARS (You et al., 2017 — paper §V extension): layer-wise trust-ratio
/// scaling on top of momentum SGD. Requires the layer layout so each
/// layer's ratio ‖w‖/‖g + wd·w‖ is computed separately.
pub struct Lars {
    mu: f32,
    trust: f32,
    v: Vec<f32>,
    /// (offset, len) per layer in the flat vector.
    layers: Vec<(usize, usize)>,
}

impl Lars {
    pub fn new(n: usize, mu: f32, trust: f32, layers: Vec<(usize, usize)>) -> Self {
        assert_eq!(layers.iter().map(|&(_, l)| l).sum::<usize>(), n, "layers must tile the vector");
        Lars { mu, trust, v: vec![0.0; n], layers }
    }
}

impl Optimizer for Lars {
    fn step(&mut self, grad: &[f32], w: &[f32], eta: f32, wd: f32, delta_w: &mut [f32]) {
        let n = self.v.len();
        assert_eq!(grad.len(), n);
        for &(off, len) in &self.layers {
            let (g_l, w_l) = (&grad[off..off + len], &w[off..off + len]);
            let wn = tensor::norm2(w_l);
            // ‖g + wd w‖ via expansion to avoid a temp:
            let gn2 = tensor::dot(g_l, g_l)
                + 2.0 * wd as f64 * tensor::dot(g_l, w_l)
                + (wd as f64).powi(2) * wn * wn;
            let gn = gn2.max(0.0).sqrt();
            let ratio = if wn > 0.0 && gn > 0.0 {
                (self.trust as f64 * wn / gn) as f32
            } else {
                1.0
            };
            let local_eta = eta * ratio;
            for i in off..off + len {
                let vn = self.mu * self.v[i] + local_eta * (grad[i] + wd * w[i]);
                self.v[i] = vn;
                delta_w[i] = -vn;
            }
        }
    }

    fn n_params(&self) -> usize {
        self.v.len()
    }

    fn reset(&mut self) {
        self.v.iter_mut().for_each(|x| *x = 0.0);
    }
}

/// Adam (Kingma & Ba — paper §V extension).
pub struct Adam {
    beta1: f32,
    beta2: f32,
    eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    pub fn new(n: usize, beta1: f32, beta2: f32, eps: f32) -> Self {
        Adam { beta1, beta2, eps, m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, grad: &[f32], w: &[f32], eta: f32, wd: f32, delta_w: &mut [f32]) {
        let n = self.m.len();
        assert_eq!(grad.len(), n);
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..n {
            let g = grad[i] + wd * w[i];
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            delta_w[i] = -eta * mhat / (vhat.sqrt() + self.eps);
        }
    }

    fn n_params(&self) -> usize {
        self.m.len()
    }

    fn reset(&mut self) {
        self.m.iter_mut().for_each(|x| *x = 0.0);
        self.v.iter_mut().for_each(|x| *x = 0.0);
        self.t = 0;
    }
}

/// Factory used by the config layer.
pub fn build_optimizer(
    kind: &str,
    n: usize,
    mu: f32,
    layers: &[(usize, usize)],
    decay_mask: Option<Vec<f32>>,
) -> Box<dyn Optimizer> {
    match kind {
        "momentum" | "sgd" => {
            let mut o = MomentumSgd::new(n, mu);
            if let Some(m) = decay_mask {
                o = o.with_decay_mask(m);
            }
            Box::new(o)
        }
        "lars" => Box::new(Lars::new(n, mu, 0.001, layers.to_vec())),
        "adam" => Box::new(Adam::new(n, 0.9, 0.999, 1e-8)),
        other => panic!("unknown optimizer kind {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randvec(seed: u64, n: usize) -> Vec<f32> {
        let mut r = Rng::new(seed);
        let mut v = vec![0.0; n];
        r.fill_normal(&mut v);
        v
    }

    #[test]
    fn momentum_accumulates() {
        let mut opt = MomentumSgd::new(2, 0.5);
        let w = [0.0, 0.0];
        let g = [1.0, -2.0];
        let mut dw = [0.0; 2];
        opt.step(&g, &w, 0.1, 0.0, &mut dw);
        assert_eq!(dw, [-0.1, 0.2]); // v = g
        opt.step(&g, &w, 0.1, 0.0, &mut dw);
        // v = 0.5*g + g = 1.5g
        assert!((dw[0] + 0.15).abs() < 1e-7);
        assert!((dw[1] - 0.3).abs() < 1e-7);
    }

    #[test]
    fn momentum_matches_dc_fused_path() {
        // The standalone optimizer and the fused dc path must produce
        // identical updates when D is absent.
        let n = 200;
        let g = randvec(1, n);
        let w0 = randvec(2, n);
        let mut opt = MomentumSgd::new(n, 0.9);
        let mut dw_a = vec![0.0; n];
        opt.step(&g, &w0, 0.1, 1e-4, &mut dw_a);

        let mut v = vec![0.0; n];
        let mut w = w0.clone();
        let mut dw_b = vec![0.0; n];
        crate::dc::dc_correct_update(
            &g,
            None,
            &mut v,
            &mut w,
            None,
            crate::dc::DcHyper { eta: 0.1, mu: 0.9, lam0: 0.2, wd: 1e-4 },
            &mut dw_b,
        );
        for i in 0..n {
            assert!((dw_a[i] - dw_b[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        // minimize 0.5‖w − t‖²; grad = w − t.
        let t = [3.0f32, -1.0, 0.5];
        let mut w = vec![0.0f32; 3];
        let mut opt = MomentumSgd::new(3, 0.9);
        let mut dw = vec![0.0; 3];
        for _ in 0..200 {
            let g: Vec<f32> = w.iter().zip(&t).map(|(a, b)| a - b).collect();
            opt.step(&g, &w, 0.05, 0.0, &mut dw);
            tensor::add_assign(&mut w, &dw);
        }
        for i in 0..3 {
            assert!((w[i] - t[i]).abs() < 1e-3, "w[{i}]={}", w[i]);
        }
    }

    #[test]
    fn lars_converges_on_quadratic() {
        let t = [2.0f32, -2.0, 1.0, 4.0];
        let mut w = vec![0.1f32; 4];
        let mut opt = Lars::new(4, 0.9, 0.01, vec![(0, 2), (2, 2)]);
        let mut dw = vec![0.0; 4];
        for _ in 0..3000 {
            let g: Vec<f32> = w.iter().zip(&t).map(|(a, b)| a - b).collect();
            opt.step(&g, &w, 1.0, 0.0, &mut dw);
            tensor::add_assign(&mut w, &dw);
        }
        for i in 0..4 {
            assert!((w[i] - t[i]).abs() < 0.05, "w[{i}]={}", w[i]);
        }
    }

    #[test]
    fn adam_converges_on_quadratic() {
        let t = [1.0f32, -3.0];
        let mut w = vec![0.0f32; 2];
        let mut opt = Adam::new(2, 0.9, 0.999, 1e-8);
        let mut dw = vec![0.0; 2];
        for _ in 0..2000 {
            let g: Vec<f32> = w.iter().zip(&t).map(|(a, b)| a - b).collect();
            opt.step(&g, &w, 0.05, 0.0, &mut dw);
            tensor::add_assign(&mut w, &dw);
        }
        for i in 0..2 {
            assert!((w[i] - t[i]).abs() < 0.01, "w[{i}]={}", w[i]);
        }
    }

    #[test]
    fn reset_clears_state() {
        let mut opt = MomentumSgd::new(2, 0.9);
        let mut dw = [0.0; 2];
        opt.step(&[1.0, 1.0], &[0.0, 0.0], 0.1, 0.0, &mut dw);
        opt.reset();
        opt.step(&[1.0, 1.0], &[0.0, 0.0], 0.1, 0.0, &mut dw);
        assert_eq!(dw, [-0.1, -0.1]); // no momentum carried over
    }

    #[test]
    #[should_panic]
    fn lars_rejects_bad_layout() {
        Lars::new(10, 0.9, 0.01, vec![(0, 4)]); // doesn't tile 10
    }
}
