//! Iteration-indexed hyper-parameter schedules (paper §IV-A).
//!
//! The paper defines η_theo = N·η_sn (linear scaling, Eq. 16), an
//! **iteration-dependent** linear warmup toward η_theo that is *stopped
//! early* when the training-error plateau is reached (15 epochs for
//! batches ≤ 64k, 20 for 128k), followed by a linear decrease to zero at
//! max_iterations. Weight decay follows the same shape, scaled by the
//! constant factor k = 2.3 applied to the literature base value.

/// Shape of the schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScheduleKind {
    /// Constant at the peak value.
    Constant,
    /// Paper schedule: linear warmup for `warmup_iters` (toward the
    /// *theoretical* peak, possibly truncated early), then linear decay
    /// to zero at `total_iters`.
    LinearWarmupLinearDecay,
}

/// An iteration-indexed schedule producing η (or wd) for each step.
#[derive(Debug, Clone)]
pub struct LrSchedule {
    kind: ScheduleKind,
    /// η_theo = N · η_sn (Eq. 16): the value warmup aims at.
    peak: f32,
    /// Iterations of warmup *as originally planned* (used for the slope;
    /// the paper plans half the total run).
    planned_warmup: u64,
    /// Iteration at which warmup actually stops (plateau detection,
    /// §IV-A: "we stopped the warm-up phase at the reached learning
    /// rate"). `<= planned_warmup`.
    warmup_stop: u64,
    total: u64,
}

impl LrSchedule {
    /// Paper schedule. `planned_warmup` defines the warmup slope
    /// (peak / planned_warmup per iteration); `warmup_stop` truncates it.
    pub fn paper(peak: f32, planned_warmup: u64, warmup_stop: u64, total: u64) -> Self {
        assert!(warmup_stop <= planned_warmup, "stop must not exceed plan");
        assert!(warmup_stop < total);
        LrSchedule {
            kind: ScheduleKind::LinearWarmupLinearDecay,
            peak,
            planned_warmup: planned_warmup.max(1),
            warmup_stop,
            total,
        }
    }

    pub fn constant(v: f32) -> Self {
        LrSchedule {
            kind: ScheduleKind::Constant,
            peak: v,
            planned_warmup: 1,
            warmup_stop: 0,
            total: u64::MAX,
        }
    }

    /// Linear-scaling rule, Eq. 16: η_theo = N·η_sn (with the reference
    /// base batch): peak = η_sn · (global_batch / base_batch).
    pub fn scaled_peak(eta_single: f32, global_batch: usize, base_batch: usize) -> f32 {
        eta_single * global_batch as f32 / base_batch as f32
    }

    /// The value reached when warmup stopped (the plateau LR the decay
    /// phase starts from).
    pub fn reached_peak(&self) -> f32 {
        match self.kind {
            ScheduleKind::Constant => self.peak,
            ScheduleKind::LinearWarmupLinearDecay => {
                self.peak * self.warmup_stop as f32 / self.planned_warmup as f32
            }
        }
    }

    /// η at iteration `it` (0-based).
    pub fn at(&self, it: u64) -> f32 {
        match self.kind {
            ScheduleKind::Constant => self.peak,
            ScheduleKind::LinearWarmupLinearDecay => {
                if it < self.warmup_stop {
                    // climb toward the theoretical peak with the planned slope
                    self.peak * (it + 1) as f32 / self.planned_warmup as f32
                } else if it >= self.total {
                    0.0
                } else {
                    // linear decrease from the *reached* value to 0 at total
                    let reached = self.reached_peak();
                    let frac = (self.total - it) as f32
                        / (self.total - self.warmup_stop) as f32;
                    reached * frac
                }
            }
        }
    }

    pub fn total_iters(&self) -> u64 {
        self.total
    }
}

/// Plateau detector automating §IV-A's "identification of the plateau
/// was done by direct observation ... could easily be automated, by e.g.
/// checking for training error reduction every five epochs during the
/// warm-up phase".
#[derive(Debug, Clone)]
pub struct PlateauDetector {
    /// Check interval in iterations (the paper suggests five epochs).
    interval: u64,
    /// Minimum relative improvement of train error to count as progress.
    min_rel_improvement: f64,
    last_check_it: u64,
    last_err: f64,
    triggered: bool,
}

impl PlateauDetector {
    pub fn new(interval: u64, min_rel_improvement: f64) -> Self {
        PlateauDetector {
            interval,
            min_rel_improvement,
            last_check_it: 0,
            last_err: f64::INFINITY,
            triggered: false,
        }
    }

    /// Feed the running train error; returns true the first time a
    /// plateau is detected.
    pub fn observe(&mut self, it: u64, train_err: f64) -> bool {
        if self.triggered || it < self.last_check_it + self.interval {
            return false;
        }
        let improved = train_err < self.last_err * (1.0 - self.min_rel_improvement);
        self.last_check_it = it;
        if self.last_err.is_finite() && !improved {
            self.triggered = true;
            return true;
        }
        self.last_err = train_err;
        false
    }

    pub fn triggered(&self) -> bool {
        self.triggered
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq16_linear_scaling() {
        // ResNet reference: η_sn = 0.1 at batch 256 ⇒ 32k batch → 12.8.
        let peak = LrSchedule::scaled_peak(0.1, 32_768, 256);
        assert!((peak - 12.8).abs() < 1e-5);
    }

    #[test]
    fn warmup_is_linear_with_planned_slope() {
        // plan 100 warmup iters to peak 1.0, stop at 50 → slope 0.01/iter.
        let s = LrSchedule::paper(1.0, 100, 50, 200);
        assert!((s.at(0) - 0.01).abs() < 1e-6);
        assert!((s.at(49) - 0.50).abs() < 1e-6);
        // the reached value is peak/2 — "one third for a 15-epoch warmup"
        // in the paper's 45-epoch plan; here one half.
        assert!((s.reached_peak() - 0.5).abs() < 1e-6);
    }

    #[test]
    fn decay_reaches_zero_at_total() {
        let s = LrSchedule::paper(1.0, 100, 50, 200);
        assert!(s.at(50) <= 0.5 + 1e-6);
        assert!(s.at(199) > 0.0);
        assert_eq!(s.at(200), 0.0);
        assert_eq!(s.at(1000), 0.0);
        // monotone decreasing after the stop
        let mut prev = s.at(50);
        for it in 51..200 {
            let v = s.at(it);
            assert!(v <= prev + 1e-7);
            prev = v;
        }
    }

    #[test]
    fn early_stop_reduces_reached_peak() {
        let full = LrSchedule::paper(1.0, 100, 100, 300);
        let early = LrSchedule::paper(1.0, 100, 33, 300);
        assert!((full.reached_peak() - 1.0).abs() < 1e-6);
        // "we reach only a small fraction of the maximum step length
        // (e.g. one third for a 15-epoch warm-up)"
        assert!((early.reached_peak() - 0.33).abs() < 1e-2);
    }

    #[test]
    fn constant_schedule() {
        let s = LrSchedule::constant(0.25);
        assert_eq!(s.at(0), 0.25);
        assert_eq!(s.at(10_000_000), 0.25);
    }

    #[test]
    fn plateau_detector_fires_on_stall() {
        let mut d = PlateauDetector::new(10, 0.01);
        // improving: never fires
        assert!(!d.observe(10, 0.9));
        assert!(!d.observe(20, 0.8));
        assert!(!d.observe(30, 0.7));
        // stall: fires once
        assert!(d.observe(40, 0.7));
        assert!(d.triggered());
        assert!(!d.observe(50, 0.1)); // latched
    }

    #[test]
    fn plateau_detector_respects_interval() {
        let mut d = PlateauDetector::new(100, 0.01);
        assert!(!d.observe(10, 0.5));
        assert!(!d.observe(99, 0.5)); // within interval: ignored
        assert!(!d.observe(100, 0.4)); // improving
        assert!(d.observe(200, 0.4)); // stalled
    }
}
