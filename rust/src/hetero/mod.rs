//! Heterogeneous-fabric subsystem: per-rank compute tiers, bandwidth-
//! asymmetric links, spot/preemptible cohorts with correlated
//! revocations, and diurnal load curves — the fleet realities the
//! paper's homogeneous-cluster analysis abstracts away, and exactly the
//! regime where per-worker staleness bounds (Dynamic SSP, 1908.11848;
//! stochastic staleness, 2509.05679) earn their keep.
//!
//! Everything here is **deterministic from (seed, rank, round)**: every
//! draw goes through [`crate::util::Rng::keyed`] on a dedicated stream
//! constant, so any sample can be regenerated in O(1) without its
//! predecessors, the draws are independent of evaluation order, and —
//! critically — they survive membership epoch transitions unchanged
//! (rank 3's tier is rank 3's tier whether the world holds 4 ranks or
//! 40). The pure per-rank functions ([`tier_multiplier`], [`is_spot`],
//! [`revocation_time`], [`diurnal_factor`], [`link_scale`]) are the
//! pinned contract; [`HeteroProfile::resolve`] just evaluates them over
//! a capacity.
//!
//! The subsystem *layers onto* the existing models rather than forking
//! them:
//!
//! * tier multipliers merge into [`crate::simtime::ComputeModel`]'s
//!   per-rank `straggler_factor` (the straggler machinery generalizes:
//!   a scripted straggler is just a one-rank tier),
//! * link asymmetry scales the α-β fabrics — the collective is gated by
//!   its slowest link, so the flat [`crate::comm::NetModel`] and both
//!   dragonfly β's take the bottleneck (minimum) of their per-link
//!   draws,
//! * spot revocations become derived [`crate::control::FaultPlan`]
//!   depart events, so membership epochs, resync, and re-sharding all
//!   run unchanged,
//! * the diurnal curve multiplies t_C in virtual time inside
//!   [`crate::algo`]'s train step, per-rank phase-shifted.
//!
//! Rank 0 is exempt from the spot cohort (the "on-demand anchor"): a
//! run where every rank can revoke has no survivor to finish it.

use crate::util::{Json, Rng};
use std::collections::BTreeMap;

/// Keyed-RNG stream constants — one per draw family, disjoint from the
/// worker (`0xC10C4`), dataset (`0xDA7A`) and QSGD (`0xC0DEC`) streams.
const TIER_STREAM: u64 = 0x7E12_7135;
const SPOT_STREAM: u64 = 0x59_07C0;
const DIURNAL_STREAM: u64 = 0xD1_FA5E;
const LINK_STREAM: u64 = 0x11CC_BE7A;
/// The correlated-revocation cohort event shares one draw index,
/// outside the rank range.
const COHORT_INDEX: u64 = u64::MAX;

/// The `[hetero]` config table: a generative description of the fleet.
/// Disabled by default — every existing run is bit-identical with the
/// subsystem compiled in.
#[derive(Debug, Clone, PartialEq)]
pub struct HeteroConfig {
    /// Master switch; `false` leaves every model untouched.
    pub enabled: bool,
    /// Compute-tier menu: each rank draws one multiplier on t_C
    /// (e.g. `[1.0, 1.6, 2.5]` for three GPU generations). Empty or
    /// `[1.0]` = homogeneous compute.
    pub tiers: Vec<f64>,
    /// Optional per-tier draw weights (same length as `tiers`); empty =
    /// uniform.
    pub tier_weights: Vec<f64>,
    /// Fraction of ranks (excluding rank 0) in the spot/preemptible
    /// cohort.
    pub spot_fraction: f64,
    /// Mean virtual time-to-revocation of a spot rank (s). 0 disables
    /// revocations even for spot ranks.
    pub spot_mtbf_s: f64,
    /// Probability that a spot rank revokes *with the cohort* (one
    /// shared revocation instant) instead of independently — the
    /// correlated capacity-reclaim pattern.
    pub spot_correlation: f64,
    /// Diurnal load amplitude: t_C swings by `±amplitude` around 1 over
    /// `diurnal_period_s`, per-rank phase-shifted. 0 disables.
    pub diurnal_amplitude: f64,
    /// Diurnal period in virtual seconds.
    pub diurnal_period_s: f64,
    /// Per-link bandwidth spread: each link's β is scaled by a draw in
    /// `[1/(1+spread), 1]`; the fabric models take the bottleneck link.
    /// 0 disables.
    pub link_spread: f64,
    /// Set by
    /// [`crate::config::ExperimentConfig::with_hetero_applied`] once
    /// the profile has been merged into the base models; guards against
    /// double-application.
    pub applied: bool,
}

impl Default for HeteroConfig {
    fn default() -> Self {
        HeteroConfig {
            enabled: false,
            tiers: vec![1.0],
            tier_weights: Vec::new(),
            spot_fraction: 0.0,
            spot_mtbf_s: 0.0,
            spot_correlation: 0.0,
            diurnal_amplitude: 0.0,
            diurnal_period_s: 86_400.0,
            link_spread: 0.0,
            applied: false,
        }
    }
}

impl HeteroConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.tiers.iter().any(|&t| t <= 0.0 || !t.is_finite()) {
            anyhow::bail!("hetero.tiers must be positive finite multipliers: {:?}", self.tiers);
        }
        if !self.tier_weights.is_empty() {
            if self.tier_weights.len() != self.tiers.len() {
                anyhow::bail!(
                    "hetero.tier_weights length {} != hetero.tiers length {}",
                    self.tier_weights.len(),
                    self.tiers.len()
                );
            }
            if self.tier_weights.iter().any(|&w| w < 0.0 || !w.is_finite())
                || self.tier_weights.iter().sum::<f64>() <= 0.0
            {
                anyhow::bail!("hetero.tier_weights must be non-negative with a positive sum");
            }
        }
        if !(0.0..=1.0).contains(&self.spot_fraction) {
            anyhow::bail!("hetero.spot_fraction must be in [0, 1]: {}", self.spot_fraction);
        }
        if !(0.0..=1.0).contains(&self.spot_correlation) {
            anyhow::bail!("hetero.spot_correlation must be in [0, 1]: {}", self.spot_correlation);
        }
        if self.spot_mtbf_s < 0.0 {
            anyhow::bail!("hetero.spot_mtbf_s must be >= 0: {}", self.spot_mtbf_s);
        }
        if !(0.0..1.0).contains(&self.diurnal_amplitude) {
            anyhow::bail!("hetero.diurnal_amplitude must be in [0, 1): {}", self.diurnal_amplitude);
        }
        if self.diurnal_period_s <= 0.0 {
            anyhow::bail!("hetero.diurnal_period_s must be > 0: {}", self.diurnal_period_s);
        }
        if self.link_spread < 0.0 {
            anyhow::bail!("hetero.link_spread must be >= 0: {}", self.link_spread);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// The pure per-(seed, rank, round) draw functions — the determinism
// contract the tests pin. Each is O(1) and independent of every other
// draw.
// ---------------------------------------------------------------------

/// The compute-tier multiplier rank `rank` draws from the tier menu.
pub fn tier_multiplier(cfg: &HeteroConfig, seed: u64, rank: usize) -> f64 {
    if cfg.tiers.is_empty() {
        return 1.0;
    }
    let u = Rng::keyed(seed, TIER_STREAM, rank as u64).uniform();
    if cfg.tier_weights.is_empty() {
        return cfg.tiers[(u * cfg.tiers.len() as f64) as usize % cfg.tiers.len()];
    }
    let total: f64 = cfg.tier_weights.iter().sum();
    let mut acc = 0.0;
    for (t, w) in cfg.tiers.iter().zip(&cfg.tier_weights) {
        acc += w / total;
        if u < acc {
            return *t;
        }
    }
    *cfg.tiers.last().unwrap()
}

/// Whether `rank` is in the spot/preemptible cohort. Rank 0 never is.
pub fn is_spot(cfg: &HeteroConfig, seed: u64, rank: usize) -> bool {
    if rank == 0 || cfg.spot_fraction <= 0.0 {
        return false;
    }
    Rng::keyed(seed, SPOT_STREAM, rank as u64).uniform() < cfg.spot_fraction
}

/// The virtual-time instant at which spot rank `rank` is revoked, if it
/// is in the cohort and revocations are enabled. Correlated ranks share
/// the single cohort draw; independent ranks draw their own
/// exponential.
pub fn revocation_time(cfg: &HeteroConfig, seed: u64, rank: usize) -> Option<f64> {
    if !is_spot(cfg, seed, rank) || cfg.spot_mtbf_s <= 0.0 {
        return None;
    }
    let mut r = Rng::keyed(seed, SPOT_STREAM, rank as u64);
    let _membership = r.uniform(); // the is_spot draw, consumed in order
    let correlated = r.uniform() < cfg.spot_correlation;
    if correlated {
        Some(Rng::keyed(seed, SPOT_STREAM, COHORT_INDEX).exponential(cfg.spot_mtbf_s))
    } else {
        Some(r.exponential(cfg.spot_mtbf_s))
    }
}

/// The diurnal t_C multiplier for `rank` at virtual time `t`:
/// `1 + amplitude · sin(2π(t/period + phase(rank)))`, with a per-rank
/// phase drawn once — time zones, staggered tenants. Always positive
/// (amplitude < 1).
pub fn diurnal_factor(cfg: &HeteroConfig, seed: u64, rank: usize, t: f64) -> f64 {
    if cfg.diurnal_amplitude <= 0.0 {
        return 1.0;
    }
    let phase = Rng::keyed(seed, DIURNAL_STREAM, rank as u64).uniform();
    let x = 2.0 * std::f64::consts::PI * (t / cfg.diurnal_period_s + phase);
    1.0 + cfg.diurnal_amplitude * x.sin()
}

/// The bandwidth scale of link `link` (an opaque per-fabric index): a
/// draw in `[1/(1+spread), 1]` — 1 is the nominal link, the floor the
/// most degraded.
pub fn link_scale(cfg: &HeteroConfig, seed: u64, link: usize) -> f64 {
    if cfg.link_spread <= 0.0 {
        return 1.0;
    }
    let u = Rng::keyed(seed, LINK_STREAM, link as u64).uniform();
    1.0 / (1.0 + cfg.link_spread * u)
}

// ---------------------------------------------------------------------
// The resolved profile.
// ---------------------------------------------------------------------

/// A diurnal curve bound to one rank (phase resolved), evaluated on the
/// worker's virtual clock inside the train step.
#[derive(Debug, Clone)]
pub struct DiurnalCurve {
    amplitude: f64,
    period_s: f64,
    phase: f64,
}

impl DiurnalCurve {
    /// The rank's curve, or `None` when the diurnal model is off.
    pub fn for_rank(cfg: &HeteroConfig, seed: u64, rank: usize) -> Option<Self> {
        if !cfg.enabled || cfg.diurnal_amplitude <= 0.0 {
            return None;
        }
        Some(DiurnalCurve {
            amplitude: cfg.diurnal_amplitude,
            period_s: cfg.diurnal_period_s,
            phase: Rng::keyed(seed, DIURNAL_STREAM, rank as u64).uniform(),
        })
    }

    /// The t_C multiplier at virtual time `t` (identical to
    /// [`diurnal_factor`] for the bound rank).
    pub fn factor(&self, t: f64) -> f64 {
        let x = 2.0 * std::f64::consts::PI * (t / self.period_s + self.phase);
        1.0 + self.amplitude * x.sin()
    }
}

/// The fleet profile a run actually executes: every per-rank draw
/// evaluated over the run's capacity (initial ranks + scripted
/// joiners), plus the bottleneck link scales. Exported verbatim as the
/// run JSON's `"hetero"` block so a trace is self-describing.
#[derive(Debug, Clone, PartialEq)]
pub struct HeteroProfile {
    /// Per-rank tier multiplier on t_C, capacity-sized.
    pub tier: Vec<f64>,
    /// Per-rank spot-cohort membership.
    pub spot: Vec<bool>,
    /// Derived `(rank, at_s)` revocation events (become
    /// `FaultPlan::depart`s), rank-ordered.
    pub revocations: Vec<(usize, f64)>,
    /// Bottleneck scale on the flat fabric's β (and the dragonfly local
    /// links).
    pub link_scale_local: f64,
    /// Bottleneck scale on the dragonfly global links.
    pub link_scale_global: f64,
    /// The diurnal knobs echoed for the export.
    pub diurnal_amplitude: f64,
    pub diurnal_period_s: f64,
}

impl HeteroProfile {
    /// Evaluate the draw functions over `capacity` ranks.
    /// `local_links` / `global_links` size the bottleneck minimum for
    /// the two fabric levels (pass the rank count and the dragonfly
    /// group count).
    pub fn resolve(
        cfg: &HeteroConfig,
        seed: u64,
        capacity: usize,
        local_links: usize,
        global_links: usize,
    ) -> Self {
        let tier = (0..capacity).map(|r| tier_multiplier(cfg, seed, r)).collect();
        let spot: Vec<bool> = (0..capacity).map(|r| is_spot(cfg, seed, r)).collect();
        let revocations = (0..capacity)
            .filter_map(|r| revocation_time(cfg, seed, r).map(|t| (r, t)))
            .collect();
        // Local links are indexed 0.., global links continue after them
        // so the two families never share a draw.
        let bottleneck = |lo: usize, hi: usize| {
            (lo..hi).map(|l| link_scale(cfg, seed, l)).fold(1.0f64, f64::min)
        };
        HeteroProfile {
            tier,
            spot,
            revocations,
            link_scale_local: bottleneck(0, local_links),
            link_scale_global: bottleneck(local_links, local_links + global_links),
            diurnal_amplitude: cfg.diurnal_amplitude,
            diurnal_period_s: cfg.diurnal_period_s,
        }
    }

    /// The run-JSON `"hetero"` block.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("enabled".into(), Json::Bool(true));
        m.insert("tier".into(), Json::Arr(self.tier.iter().map(|&t| Json::Num(t)).collect()));
        m.insert("spot".into(), Json::Arr(self.spot.iter().map(|&s| Json::Bool(s)).collect()));
        m.insert(
            "revocations".into(),
            Json::Arr(
                self.revocations
                    .iter()
                    .map(|&(r, t)| {
                        let mut e = BTreeMap::new();
                        e.insert("rank".into(), Json::Num(r as f64));
                        e.insert("at_s".into(), Json::Num(t));
                        Json::Obj(e)
                    })
                    .collect(),
            ),
        );
        m.insert("link_scale_local".into(), Json::Num(self.link_scale_local));
        m.insert("link_scale_global".into(), Json::Num(self.link_scale_global));
        m.insert("diurnal_amplitude".into(), Json::Num(self.diurnal_amplitude));
        m.insert("diurnal_period_s".into(), Json::Num(self.diurnal_period_s));
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> HeteroConfig {
        HeteroConfig {
            enabled: true,
            tiers: vec![1.0, 1.6, 2.5],
            spot_fraction: 0.5,
            spot_mtbf_s: 10.0,
            spot_correlation: 0.5,
            diurnal_amplitude: 0.3,
            diurnal_period_s: 100.0,
            link_spread: 0.5,
            ..HeteroConfig::default()
        }
    }

    #[test]
    fn draws_are_bit_identical_and_order_independent() {
        let c = cfg();
        // Evaluate rank 7 first, then after a sweep of other ranks: the
        // keyed construction must make the order irrelevant.
        let t7 = tier_multiplier(&c, 42, 7);
        let s7 = is_spot(&c, 42, 7);
        let r7 = revocation_time(&c, 42, 7);
        let d7 = diurnal_factor(&c, 42, 7, 3.25);
        let l7 = link_scale(&c, 42, 7);
        for r in 0..32 {
            let _ = (tier_multiplier(&c, 42, r), revocation_time(&c, 42, r));
        }
        assert_eq!(tier_multiplier(&c, 42, 7), t7);
        assert_eq!(is_spot(&c, 42, 7), s7);
        assert_eq!(revocation_time(&c, 42, 7), r7);
        assert_eq!(diurnal_factor(&c, 42, 7, 3.25), d7);
        assert_eq!(link_scale(&c, 42, 7), l7);
    }

    #[test]
    fn tiers_come_from_the_menu_and_weights_bias_the_draw() {
        let c = cfg();
        for r in 0..100 {
            let t = tier_multiplier(&c, 1, r);
            assert!(c.tiers.contains(&t), "tier {t} not in the menu");
        }
        // All weight on the last tier: every rank draws it.
        let biased = HeteroConfig {
            tier_weights: vec![0.0, 0.0, 1.0],
            ..c
        };
        for r in 0..50 {
            assert_eq!(tier_multiplier(&biased, 1, r), 2.5);
        }
    }

    #[test]
    fn rank_zero_is_never_spot() {
        let c = HeteroConfig { spot_fraction: 1.0, ..cfg() };
        for seed in 0..50 {
            assert!(!is_spot(&c, seed, 0));
            assert!(revocation_time(&c, seed, 0).is_none());
            // with fraction 1 every other rank is spot
            assert!(is_spot(&c, seed, 1));
        }
    }

    #[test]
    fn correlated_revocations_share_the_cohort_instant() {
        let c = HeteroConfig { spot_fraction: 1.0, spot_correlation: 1.0, ..cfg() };
        let times: Vec<f64> =
            (1..8).filter_map(|r| revocation_time(&c, 5, r)).collect();
        assert_eq!(times.len(), 7);
        assert!(times.windows(2).all(|w| w[0] == w[1]), "cohort must revoke together");
        // fully independent: the draws must differ
        let ind = HeteroConfig { spot_correlation: 0.0, ..c };
        let it: Vec<f64> = (1..8).filter_map(|r| revocation_time(&ind, 5, r)).collect();
        assert!(it.windows(2).any(|w| w[0] != w[1]));
    }

    #[test]
    fn diurnal_factor_is_positive_and_periodic() {
        let c = cfg();
        for r in 0..4 {
            for i in 0..200 {
                let t = i as f64 * 1.7;
                let f = diurnal_factor(&c, 3, r, t);
                assert!(f > 0.0 && (f - 1.0).abs() <= c.diurnal_amplitude + 1e-12);
                let g = diurnal_factor(&c, 3, r, t + c.diurnal_period_s);
                assert!((f - g).abs() < 1e-9, "not periodic: {f} vs {g}");
            }
        }
        // the curve matches the bound form
        let curve = DiurnalCurve::for_rank(&c, 3, 2).unwrap();
        assert_eq!(curve.factor(12.5), diurnal_factor(&c, 3, 2, 12.5));
    }

    #[test]
    fn link_scale_bounded_by_spread() {
        let c = cfg();
        for l in 0..100 {
            let s = link_scale(&c, 9, l);
            assert!((1.0 / 1.5..=1.0).contains(&s), "scale {s} out of range");
        }
        let off = HeteroConfig { link_spread: 0.0, ..c };
        assert_eq!(link_scale(&off, 9, 3), 1.0);
    }

    #[test]
    fn profile_draws_survive_capacity_changes() {
        // The membership-epoch property at the draw level: growing the
        // world must not move any existing rank's draws.
        let c = cfg();
        let small = HeteroProfile::resolve(&c, 11, 4, 4, 2);
        let large = HeteroProfile::resolve(&c, 11, 8, 4, 2);
        assert_eq!(&large.tier[..4], &small.tier[..]);
        assert_eq!(&large.spot[..4], &small.spot[..]);
        for (r, t) in &small.revocations {
            assert!(large.revocations.contains(&(*r, *t)));
        }
        assert_eq!(small.link_scale_local, large.link_scale_local);
        assert_eq!(small.link_scale_global, large.link_scale_global);
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        let ok = cfg();
        assert!(ok.validate().is_ok());
        assert!(HeteroConfig { tiers: vec![0.0], ..cfg() }.validate().is_err());
        assert!(HeteroConfig { tier_weights: vec![1.0], ..cfg() }.validate().is_err());
        assert!(HeteroConfig { spot_fraction: 1.5, ..cfg() }.validate().is_err());
        assert!(HeteroConfig { diurnal_amplitude: 1.0, ..cfg() }.validate().is_err());
        assert!(HeteroConfig { diurnal_period_s: 0.0, ..cfg() }.validate().is_err());
        assert!(HeteroConfig { link_spread: -0.1, ..cfg() }.validate().is_err());
    }

    #[test]
    fn profile_json_block_has_the_documented_keys() {
        let p = HeteroProfile::resolve(&cfg(), 7, 4, 4, 2);
        let j = p.to_json();
        for key in
            ["enabled", "tier", "spot", "revocations", "link_scale_local", "diurnal_amplitude"]
        {
            assert!(j.get(key).is_some(), "hetero JSON lost {key}");
        }
    }
}
