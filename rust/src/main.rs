//! `dcs3gd` — the launcher binary.
//!
//! Subcommands:
//!   train          run one experiment (config file and/or flags)
//!   sweep          run a {algo × nodes × batch} sweep, print table rows
//!   bench-comm     all-reduce cost-model sweep
//!   trace-report   analyze a --trace-out JSONL journal
//!   list-artifacts show the AOT variants the runtime can load
//!   help           this text

use anyhow::{bail, Result};

use dcs3gd::algo::{run_experiment, Algo};
use dcs3gd::cli::Args;
use dcs3gd::comm::{AllReduceAlgo, Dragonfly, NetModel, SimBackend};
use dcs3gd::compress::CompressorKind;
use dcs3gd::config::{parse_schedule, ExperimentConfig, PsLambda};
use dcs3gd::control::{ControlPolicy, FaultEvent, FaultKind, JoinEvent, ProbeMode};
use dcs3gd::model::meta::discover_variants;
use dcs3gd::simtime::ComputeModel;

const USAGE: &str = "\
dcs3gd — Delay-Compensated Stale-Synchronous SGD training runtime

USAGE:
  dcs3gd train [--config FILE] [--variant V] [--algo A] [--nodes N]
               [--local-batch B] [--steps S] [--lam0 L] [--staleness K]
               [--eval-every E] [--out-dir DIR] [--time-from-wall]
               [--schedule S] [--groups G] [--nodes-per-group M]
               [--global-taper L]
               [--control-policy P] [--k-min K] [--k-max K]
               [--probe off|interval|bandit] [--probe-interval W]
               [--probe-epsilon E]
               [--adjust-every W] [--snapshot-every W]
               [--straggler-factor X] [--quarantine-after W]
               [--heartbeat-timeout S] [--restore-s S]
               [--fault-kind F --fault-rank R --fault-at T]
               [--fault-factor X] [--fault-duration S] [--fault-extra S]
               [--fault-respawn true|false]
               [--join-count N --join-at T [--join-first-rank R]]
               [--join-warmup W]
               [--compress C] [--topk-ratio R] [--qsgd-bits B]
               [--ps-shards S] [--ps-replicas R] [--ps-coalesce true|false]
               [--ps-lambda dynamic|adaptive]
               [--hetero] [--hetero-tiers a,b,..] [--hetero-tier-weights w,..]
               [--hetero-spot-fraction F] [--hetero-spot-mtbf S]
               [--hetero-spot-correlation C] [--hetero-diurnal-amplitude A]
               [--hetero-diurnal-period S] [--hetero-link-spread X]
               [--threads T] [--pin-chunk C] [--sim-backend dense|folded]
               [--trace-out FILE] [--trace-capacity N]
  dcs3gd sweep [--variant V] [--algos a,b,c] [--nodes 2,4,8] [--steps S]
  dcs3gd bench-comm [--elems N] [--max-ranks R]
  dcs3gd trace-report --trace FILE
  dcs3gd list-artifacts [--root DIR]

Algorithms:       ssgd | s3gd | dcs3gd | dyn_ssp | sgs | asgd | dcasgd
Variants:         linear (pure-rust) or an artifacts/ dir like tiny_cnn_b32
Schedules:        ring | tree | flat | hierarchical (Layered-SGD dragonfly)
Control policies: fixed | dss_pid | lambda_coupled | schedule_coupled
                  | compress_coupled (co-tunes k, schedule and ratio)
                  | dyn_ssp (per-worker dynamic staleness bounds)
Contention:       --global-taper L = global links per dragonfly group
                  (leader phases and PS crossings contend past L flows)
Probing:          --probe interval runs the inactive schedule candidate
                  for one window every --probe-interval windows;
                  --probe bandit explores eps-greedily
Compressors:      none | topk | qsgd (error-feedback gradient compression;
                  --topk-ratio sets the kept density, --qsgd-bits the
                  quantization width)
Parameter server: --ps-shards splits the asgd/dcasgd server into S
                  independent shard actors; --ps-replicas serves pulls
                  from R placement-aware replicas (--ps-coalesce windows
                  concurrent reads); --ps-lambda adaptive swaps Eq. 17's
                  global-norm λ for the elementwise gradient-MSE rule —
                  see docs/parameter-server.md
Fault kinds:      kill | slow | delay (virtual-time chaos injection);
                  a kill with --fault-respawn false departs permanently
                  (the membership epoch shrinks); --join-* grows it, and
                  --join-warmup ramps the joiners' LR over W windows
Engine:           --threads T bounds the concurrently runnable simulated
                  ranks (0 = auto-detect, 1 = the serial reference
                  engine); --pin-chunk C sets the vectorized kernels'
                  chunk width (0 = default, power of two). Both are
                  wall-clock knobs only: results are bit-identical for
                  every setting — see docs/performance.md
Backend:          --sim-backend folded swaps the rendezvous substrate's
                  dense roster scans for the event core's contributor-set
                  deltas (sparse rounds); dense is the default. Results
                  are bit-identical either way — see docs/architecture.md
Heterogeneity:    --hetero turns on the heterogeneous fabric: per-rank
                  compute tiers (--hetero-tiers, drawn by weight), spot
                  cohorts that revoke mid-run (--hetero-spot-*; rank 0 is
                  the on-demand anchor), diurnal load curves in virtual
                  time (--hetero-diurnal-*) and per-link bandwidth
                  spread (--hetero-link-spread); all draws are pure in
                  (seed, rank) — see docs/heterogeneity.md
Tracing:          --trace-out FILE streams the run's event journal as
                  JSONL (convert with tools/trace_to_chrome.py for the
                  chrome://tracing view); --trace-capacity N bounds the
                  per-rank ring buffer (0 disables tracing entirely).
                  `trace-report` prints overlap efficiency, straggler
                  attribution and anomaly flags — see
                  docs/observability.md
";

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_str() {
        "train" => cmd_train(&args),
        "sweep" => cmd_sweep(&args),
        "bench-comm" => cmd_bench_comm(&args),
        "trace-report" => cmd_trace_report(&args),
        "list-artifacts" => cmd_list_artifacts(&args),
        "" | "help" | "--help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

fn cfg_from_args(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::from_toml_file(path)?,
        None => ExperimentConfig::builder(args.get_or("variant", "linear")).build(),
    };
    if let Some(v) = args.get("variant") {
        cfg.variant = v.to_string();
    }
    if let Some(a) = args.get("algo") {
        cfg.algo = Algo::parse(a)?;
    }
    cfg.nodes = args.get_usize("nodes", cfg.nodes)?;
    cfg.local_batch = args.get_usize("local-batch", cfg.local_batch)?;
    cfg.steps = args.get_u64("steps", cfg.steps)?;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    cfg.lam0 = args.get_f64("lam0", cfg.lam0 as f64)? as f32;
    cfg.staleness = args.get_usize("staleness", cfg.staleness)?;
    cfg.eta_single = args.get_f64("eta-single", cfg.eta_single as f64)? as f32;
    cfg.base_batch = args.get_usize("base-batch", cfg.base_batch)?;
    cfg.momentum = args.get_f64("momentum", cfg.momentum as f64)? as f32;
    cfg.data_noise = args.get_f64("noise", cfg.data_noise as f64)? as f32;
    cfg.n_train = args.get_usize("n-train", cfg.n_train)?;
    cfg.n_val = args.get_usize("n-val", cfg.n_val)?;
    if let Some(o) = args.get("optimizer") {
        cfg.optimizer = o.to_string();
    }
    cfg.warmup_frac = args.get_f64("warmup-frac", cfg.warmup_frac as f64)? as f32;
    cfg.warmup_stop_frac =
        args.get_f64("warmup-stop-frac", cfg.warmup_stop_frac as f64)? as f32;
    cfg.eval_every = args.get_u64("eval-every", cfg.eval_every)?;
    // collective schedule / dragonfly topology: explicit shape flags
    // win (half-specified shapes derive the other dimension from the
    // node count); a bare --nodes override refits the shape to the new
    // count. Both keep any config-file link parameters and re-bind an
    // already-hierarchical schedule so the flags actually take effect.
    let explicit_shape = args.get("groups").is_some() || args.get("nodes-per-group").is_some();
    let nodes = cfg.nodes.max(1);
    let reshaped = if explicit_shape {
        let fitted = Dragonfly::for_nodes(nodes);
        let groups = args.get_usize("groups", 0)?;
        let npg = args.get_usize("nodes-per-group", 0)?;
        let (groups, npg) = match (groups, npg) {
            (0, 0) => (fitted.groups, fitted.nodes_per_group),
            (g, 0) => (g, nodes.div_ceil(g).max(1)),
            (0, m) => (nodes.div_ceil(m).max(1), m),
            (g, m) => (g, m),
        };
        Some((groups, npg))
    } else if args.get("nodes").is_some() || args.get("config").is_none() {
        // bare --nodes override, or no config file at all: fit the
        // shape to the run's node count
        let fitted = Dragonfly::for_nodes(nodes);
        Some((fitted.groups, fitted.nodes_per_group))
    } else {
        None
    };
    if let Some((groups, npg)) = reshaped {
        // keep the configured link parameters, change only the shape
        cfg.dragonfly = Dragonfly { groups, nodes_per_group: npg, ..cfg.dragonfly };
        if matches!(cfg.net.algo, AllReduceAlgo::Hierarchical(_)) {
            cfg.net.algo = AllReduceAlgo::Hierarchical(cfg.dragonfly);
        }
    }
    // global-link contention: links per group (re-binds an already
    // hierarchical schedule so the flag takes effect)
    if args.get("global-taper").is_some() {
        let taper = args.get_usize("global-taper", cfg.dragonfly.global_taper)?;
        cfg.dragonfly.global_taper = taper.max(1);
        if matches!(cfg.net.algo, AllReduceAlgo::Hierarchical(_)) {
            cfg.net.algo = AllReduceAlgo::Hierarchical(cfg.dragonfly);
        }
    }
    if let Some(s) = args.get("schedule") {
        cfg.net.algo = parse_schedule(s, cfg.dragonfly)?;
    }
    // elastic control plane
    if let Some(p) = args.get("control-policy") {
        cfg.control.policy = ControlPolicy::parse(p)?;
    }
    cfg.control.k_min = args.get_usize("k-min", cfg.control.k_min)?;
    cfg.control.k_max = args.get_usize("k-max", cfg.control.k_max)?;
    cfg.control.adjust_every = args.get_u64("adjust-every", cfg.control.adjust_every)?;
    cfg.control.gain_p = args.get_f64("gain-p", cfg.control.gain_p)?;
    cfg.control.gain_i = args.get_f64("gain-i", cfg.control.gain_i)?;
    cfg.control.snapshot_every = args.get_u64("snapshot-every", cfg.control.snapshot_every)?;
    cfg.control.schedule_hysteresis =
        args.get_f64("schedule-hysteresis", cfg.control.schedule_hysteresis)?;
    if let Some(p) = args.get("probe") {
        cfg.control.probe = ProbeMode::parse(p)?;
    }
    cfg.control.probe_interval = args.get_u64("probe-interval", cfg.control.probe_interval)?;
    cfg.control.probe_epsilon = args.get_f64("probe-epsilon", cfg.control.probe_epsilon)?;
    cfg.control.straggler_factor =
        args.get_f64("straggler-factor", cfg.control.straggler_factor)?;
    cfg.control.quarantine_after =
        args.get_u64("quarantine-after", cfg.control.quarantine_after)?;
    cfg.control.heartbeat_timeout_s =
        args.get_f64("heartbeat-timeout", cfg.control.heartbeat_timeout_s)?;
    cfg.control.restore_s = args.get_f64("restore-s", cfg.control.restore_s)?;
    if let Some(kind) = args.get("fault-kind") {
        let rank = args.get_usize("fault-rank", 0)?;
        let at_s = args.get_f64("fault-at", 0.0)?;
        let kind = match kind {
            "kill" => {
                let respawn = match args.get_or("fault-respawn", "true") {
                    "true" => true,
                    "false" => false,
                    other => bail!("--fault-respawn expects true|false, got {other:?}"),
                };
                FaultKind::Kill { respawn }
            }
            "slow" => FaultKind::Slow {
                factor: args.get_f64("fault-factor", 2.0)?,
                duration_s: args.get_f64("fault-duration", 1.0)?,
            },
            "delay" => FaultKind::Delay { extra_s: args.get_f64("fault-extra", 0.5)? },
            other => bail!("unknown --fault-kind {other:?} (kill | slow | delay)"),
        };
        cfg.control.faults.push(FaultEvent { rank, at_s, kind });
    }
    // Scripted arrivals: N fresh ranks join at --join-at (ids start at
    // --join-first-rank, default right above the initial world).
    let join_count = args.get_usize("join-count", 0)?;
    if join_count > 0 {
        let at_s = args.get_f64("join-at", 0.0)?;
        let first = args.get_usize("join-first-rank", cfg.nodes)?;
        for rank in first..first + join_count {
            cfg.control.joins.push(JoinEvent { rank, at_s });
        }
    }
    cfg.control.join_warmup_windows =
        args.get_u64("join-warmup", cfg.control.join_warmup_windows)?;
    // gradient compression
    if let Some(c) = args.get("compress") {
        cfg.compress.kind = CompressorKind::parse(c)?;
    }
    cfg.compress.ratio = args.get_f64("topk-ratio", cfg.compress.ratio as f64)? as f32;
    cfg.compress.bits = args.get_usize("qsgd-bits", cfg.compress.bits as usize)? as u32;
    // parameter-server tier (asgd / dcasgd engines)
    cfg.ps.shards = args.get_usize("ps-shards", cfg.ps.shards)?;
    cfg.ps.replicas = args.get_usize("ps-replicas", cfg.ps.replicas)?;
    if let Some(c) = args.get("ps-coalesce") {
        cfg.ps.coalesce = match c {
            "true" => true,
            "false" => false,
            other => bail!("--ps-coalesce expects true|false, got {other:?}"),
        };
    }
    if let Some(l) = args.get("ps-lambda") {
        cfg.ps.lambda = PsLambda::parse(l)?;
    }
    // heterogeneous fabric: compute tiers, spot cohorts, diurnal load,
    // per-link bandwidth spread
    if args.flag("hetero") {
        cfg.hetero.enabled = true;
    }
    let parse_csv_f64 = |raw: &str, what: &str| -> Result<Vec<f64>> {
        raw.split(',')
            .map(|s| s.trim().parse().map_err(|_| anyhow::anyhow!("bad {what} {s:?}")))
            .collect()
    };
    if let Some(t) = args.get("hetero-tiers") {
        cfg.hetero.tiers = parse_csv_f64(t, "tier multiplier")?;
    }
    if let Some(w) = args.get("hetero-tier-weights") {
        cfg.hetero.tier_weights = parse_csv_f64(w, "tier weight")?;
    }
    cfg.hetero.spot_fraction =
        args.get_f64("hetero-spot-fraction", cfg.hetero.spot_fraction)?;
    cfg.hetero.spot_mtbf_s = args.get_f64("hetero-spot-mtbf", cfg.hetero.spot_mtbf_s)?;
    cfg.hetero.spot_correlation =
        args.get_f64("hetero-spot-correlation", cfg.hetero.spot_correlation)?;
    cfg.hetero.diurnal_amplitude =
        args.get_f64("hetero-diurnal-amplitude", cfg.hetero.diurnal_amplitude)?;
    cfg.hetero.diurnal_period_s =
        args.get_f64("hetero-diurnal-period", cfg.hetero.diurnal_period_s)?;
    cfg.hetero.link_spread = args.get_f64("hetero-link-spread", cfg.hetero.link_spread)?;
    // engine core: worker-pool thread budget + kernel chunk width
    cfg.perf.threads = args.get_usize("threads", cfg.perf.threads)?;
    cfg.perf.pin_chunk = args.get_usize("pin-chunk", cfg.perf.pin_chunk)?;
    // simulator backend: dense rendezvous vs cohort-folded rounds
    if let Some(b) = args.get("sim-backend") {
        cfg.sim.backend = SimBackend::parse(b)
            .ok_or_else(|| anyhow::anyhow!("unknown --sim-backend {b:?} (dense | folded)"))?;
    }
    // trace/metrics subsystem: JSONL journal sink + ring-buffer bound
    cfg.trace.capacity = args.get_usize("trace-capacity", cfg.trace.capacity)?;
    if let Some(p) = args.get("trace-out") {
        cfg.trace.out = Some(p.into());
    }
    if let Some(d) = args.get("out-dir") {
        cfg.out_dir = Some(d.into());
    }
    if let Some(r) = args.get("artifacts-root") {
        cfg.artifacts_root = r.into();
    }
    if args.flag("time-from-wall") {
        cfg.time_from_wall = true;
    }
    if let Some(n) = args.get("name") {
        cfg.name = n.to_string();
    } else {
        cfg.name = format!("{}_{}_n{}_b{}", cfg.variant, cfg.algo.name(), cfg.nodes, cfg.local_batch);
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = cfg_from_args(args)?;
    eprintln!(
        "training {} | algo={} nodes={} global-batch={} steps={} lam0={} staleness={}",
        cfg.variant,
        cfg.algo.name(),
        cfg.nodes,
        cfg.global_batch(),
        cfg.steps,
        cfg.lam0,
        cfg.staleness
    );
    let report = run_experiment(&cfg)?;
    println!("{}", report.table_row());
    println!(
        "sim time {:.2}s | wall {:.2}s | best val err {:.3}",
        report.sim_time_s, report.wall_time_s, report.best_val_err
    );
    if cfg.control.policy != ControlPolicy::Fixed || !cfg.control.faults.is_empty() {
        let recs = report.control.records();
        let final_k = recs.last().map(|r| r.k).unwrap_or(cfg.staleness);
        let final_sched = recs
            .iter()
            .rev()
            .find_map(|r| r.schedule.clone())
            .unwrap_or_else(|| cfg.net.algo.name().to_string());
        println!(
            "control: policy={} k changes={} final k={} schedule switches={} final schedule={} fault/recovery events={}",
            cfg.control.policy.name(),
            report.control.k_changes(),
            final_k,
            report.control.schedule_switches(),
            final_sched,
            report.control.events().len(),
        );
        let comm = report.control.comm_summary();
        if comm.rounds > 0 {
            println!(
                "comm:    t_AR total {:.4}s over {} rounds ({:.1}% on global links)",
                comm.total_s(),
                comm.rounds,
                100.0 * comm.global_s / comm.total_s().max(1e-30),
            );
        }
        if comm.probe_rounds > 0 {
            println!(
                "probe:   mode={} | {} probe windows along the trace",
                cfg.control.probe.name(),
                comm.probe_rounds,
            );
        }
    }
    if cfg.compress.kind != CompressorKind::None {
        let s = report.control.compress_summary();
        println!(
            "compress: {} | mean wire {:.0} B/round/rank | final ratio {:.4} | ratio changes {}",
            s.kind,
            s.mean_wire_bytes(),
            s.final_ratio,
            s.ratio_changes,
        );
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let algos: Vec<Algo> = args
        .get_or("algos", "ssgd,s3gd,dcs3gd")
        .split(',')
        .map(Algo::parse)
        .collect::<Result<_>>()?;
    let nodes: Vec<usize> = args
        .get_or("nodes", "2,4,8")
        .split(',')
        .map(|s| s.parse().map_err(|_| anyhow::anyhow!("bad node count {s:?}")))
        .collect::<Result<_>>()?;
    println!(
        "{:<22} {:>7} {:>6} {:>6} | accuracy | speed | iter | dist",
        "name", "algo", "|B|", "N"
    );
    for &n in &nodes {
        for &algo in &algos {
            let mut cfg = cfg_from_args(args)?;
            cfg.algo = algo;
            cfg.nodes = n;
            cfg.name = format!("{}_{}_n{}", cfg.variant, algo.name(), n);
            // the per-point overrides can break invariants the first
            // validate() pass established (e.g. membership events vs a
            // different node count or engine) — re-check
            cfg.validate()?;
            let report = run_experiment(&cfg)?;
            println!("{}", report.table_row());
        }
    }
    Ok(())
}

fn cmd_bench_comm(args: &Args) -> Result<()> {
    let elems = args.get_usize("elems", 1_000_000)?;
    let max_ranks = args.get_usize("max-ranks", 128)?;
    let net = NetModel::default();
    println!("all-reduce cost model (α={}s, β={}B/s), {} f32", net.alpha_s, net.beta_bytes_per_s, elems);
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>12} {:>9}",
        "N", "ring", "tree", "flat", "hier", "hier gl%"
    );
    let mut n = 2;
    while n <= max_ranks {
        let ring = NetModel { algo: AllReduceAlgo::Ring, ..net }.allreduce_time(elems, n);
        let tree = NetModel { algo: AllReduceAlgo::Tree, ..net }.allreduce_time(elems, n);
        let flat = NetModel { algo: AllReduceAlgo::Flat, ..net }.allreduce_time(elems, n);
        let fly = Dragonfly::for_nodes(n);
        let phases =
            NetModel { algo: AllReduceAlgo::Hierarchical(fly), ..net }.allreduce_phases(elems, n);
        println!(
            "{n:>6} {ring:>12.6} {tree:>12.6} {flat:>12.6} {:>12.6} {:>8.1}%",
            phases.total(),
            100.0 * phases.global_s / phases.total().max(1e-30),
        );
        n *= 2;
    }
    let _ = ComputeModel::default(); // keep the import honest
    Ok(())
}

fn cmd_trace_report(args: &Args) -> Result<()> {
    use dcs3gd::obs::report::{analyze, parse_jsonl, render};
    let path = args
        .get("trace")
        .ok_or_else(|| anyhow::anyhow!("trace-report needs --trace FILE (a --trace-out journal)"))?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read trace {path:?}: {e}"))?;
    let events = parse_jsonl(&text)?;
    if events.is_empty() {
        bail!("trace {path:?} holds no events (was the run started with --trace-capacity 0?)");
    }
    let report = analyze(&events);
    print!("{}", render(&report));
    Ok(())
}

fn cmd_list_artifacts(args: &Args) -> Result<()> {
    let root = args.get_or("root", "artifacts");
    let variants = discover_variants(root)?;
    if variants.is_empty() {
        println!("no artifacts under {root:?} — run `make artifacts`");
        return Ok(());
    }
    println!("{:<20} {:>10} {:>6} {:>6} {:>8}", "variant", "params", "batch", "hw", "classes");
    for m in variants {
        println!(
            "{:<20} {:>10} {:>6} {:>6} {:>8}",
            m.dir.file_name().unwrap().to_string_lossy(),
            m.param_count,
            m.batch,
            m.input_hw,
            m.num_classes
        );
    }
    Ok(())
}
