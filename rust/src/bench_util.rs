//! Tiny benchmark harness (offline build: no `criterion`).
//!
//! Benches are `harness = false` binaries that call [`Bencher`] and
//! print a fixed-format report; `cargo bench` runs them all. Supports
//! warmup, configurable measurement time, mean/std/p50/p95, and
//! throughput annotation.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::util::stats::{percentile, Running};
use crate::util::Json;

/// One benchmark's collected timings.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<f64>,
    /// Optional elements-per-iteration for throughput reporting.
    pub elems_per_iter: Option<usize>,
}

impl Measurement {
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn report(&self) -> String {
        let mut r = Running::new();
        for &s in &self.samples {
            r.push(s);
        }
        let p50 = percentile(&self.samples, 50.0);
        let p95 = percentile(&self.samples, 95.0);
        let mut line = format!(
            "{:<44} {:>12} {:>12} {:>12} {:>12}",
            self.name,
            fmt_time(r.mean()),
            fmt_time(r.std()),
            fmt_time(p50),
            fmt_time(p95),
        );
        if let Some(n) = self.elems_per_iter {
            let rate = n as f64 / r.mean();
            line.push_str(&format!(" {:>14}/s", fmt_si(rate)));
        }
        line
    }

    /// The measurement's summary statistics as a JSON object — one row
    /// of the machine-readable `target/bench_results.json` export.
    pub fn to_json(&self) -> Json {
        let mut r = Running::new();
        for &s in &self.samples {
            r.push(s);
        }
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("samples".into(), Json::Num(self.samples.len() as f64));
        m.insert("mean_s".into(), Json::Num(r.mean()));
        m.insert("std_s".into(), Json::Num(r.std()));
        m.insert("p50_s".into(), Json::Num(percentile(&self.samples, 50.0)));
        m.insert("p95_s".into(), Json::Num(percentile(&self.samples, 95.0)));
        if let Some(n) = self.elems_per_iter {
            m.insert("elems_per_iter".into(), Json::Num(n as f64));
            m.insert("elems_per_s".into(), Json::Num(n as f64 / r.mean()));
        }
        Json::Obj(m)
    }
}

/// Runs closures repeatedly and records wall time per iteration.
pub struct Bencher {
    warmup: Duration,
    measure: Duration,
    min_samples: usize,
    max_samples: usize,
    results: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(1500),
            min_samples: 10,
            max_samples: 10_000,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick mode for CI / smoke runs (honours `DCS3GD_BENCH_FAST=1`).
    pub fn from_env() -> Self {
        let mut b = Self::default();
        if std::env::var("DCS3GD_BENCH_FAST").as_deref() == Ok("1") {
            b.warmup = Duration::from_millis(20);
            b.measure = Duration::from_millis(200);
            b.min_samples = 3;
        }
        b
    }

    pub fn measure_time(mut self, d: Duration) -> Self {
        self.measure = d;
        self
    }

    /// Benchmark `f`, labelling the result `name`.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> &Measurement {
        self.bench_with_elems(name, None, &mut f)
    }

    /// Benchmark with a throughput annotation of `elems` per iteration.
    pub fn bench_elems(&mut self, name: &str, elems: usize, mut f: impl FnMut()) -> &Measurement {
        self.bench_with_elems(name, Some(elems), &mut f)
    }

    fn bench_with_elems(
        &mut self,
        name: &str,
        elems: Option<usize>,
        f: &mut dyn FnMut(),
    ) -> &Measurement {
        // warmup
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            f();
        }
        // measure
        let mut samples = Vec::new();
        let t0 = Instant::now();
        while (t0.elapsed() < self.measure || samples.len() < self.min_samples)
            && samples.len() < self.max_samples
        {
            let s = Instant::now();
            f();
            samples.push(s.elapsed().as_secs_f64());
        }
        self.results.push(Measurement { name: name.to_string(), samples, elems_per_iter: elems });
        self.results.last().unwrap()
    }

    /// Print the standard report table.
    pub fn report(&self) {
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>12}",
            "benchmark", "mean", "std", "p50", "p95"
        );
        println!("{}", "-".repeat(110));
        for m in &self.results {
            println!("{}", m.report());
        }
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// All measurements as a JSON array (rows of [`Measurement::to_json`]).
    pub fn results_json(&self) -> Json {
        Json::Arr(self.results.iter().map(Measurement::to_json).collect())
    }
}

/// Default location of the machine-readable bench export, relative to
/// the crate root `cargo bench` runs from.
pub const BENCH_RESULTS_PATH: &str = "target/bench_results.json";

/// Merge `payload` into `target/bench_results.json` under `section`
/// (each bench binary owns one section, so `cargo bench` runs compose
/// into a single artifact instead of clobbering each other). Returns
/// the path written. CI uploads this file as the run's perf-trajectory
/// artifact.
pub fn write_bench_json(section: &str, payload: Json) -> std::io::Result<PathBuf> {
    let path = Path::new(BENCH_RESULTS_PATH).to_path_buf();
    let mut root = std::fs::read_to_string(&path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
        .and_then(|j| match j {
            Json::Obj(m) => Some(m),
            _ => None,
        })
        .unwrap_or_default();
    root.insert(section.to_string(), payload);
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&path, Json::Obj(root).to_string())?;
    Ok(path)
}

/// Keep a value alive and opaque to the optimizer (std::hint wrapper).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

fn fmt_si(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2} G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2} M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2} k", x / 1e3)
    } else {
        format!("{x:.1} ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples_and_reports() {
        let mut b = Bencher::new().measure_time(Duration::from_millis(30));
        b.warmup = Duration::from_millis(5);
        let m = b.bench("noop", || {
            black_box(1 + 1);
        });
        assert!(m.samples.len() >= 10);
        assert!(m.mean() >= 0.0);
        let report = m.report();
        assert!(report.contains("noop"));
    }

    #[test]
    fn throughput_annotation() {
        let mut b = Bencher::new().measure_time(Duration::from_millis(10));
        b.warmup = Duration::from_millis(1);
        let m = b.bench_elems("sum", 1000, || {
            black_box((0..1000u32).sum::<u32>());
        });
        assert!(m.report().ends_with("/s"));
    }

    #[test]
    fn measurement_json_row_shape() {
        let m = Measurement {
            name: "x".into(),
            samples: vec![1.0, 2.0, 3.0],
            elems_per_iter: Some(100),
        };
        let j = m.to_json();
        assert_eq!(j.get("name").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("mean_s").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("elems_per_iter").unwrap().as_f64(), Some(100.0));
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time(2.0), "2.000 s");
        assert_eq!(fmt_time(2e-3), "2.000 ms");
        assert_eq!(fmt_time(2e-6), "2.000 µs");
        assert_eq!(fmt_time(2e-9), "2.0 ns");
    }
}
