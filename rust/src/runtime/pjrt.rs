//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them
//! from the rust training path.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), while the
//! training engines run one OS thread per simulated worker. A single
//! [`ComputeServer`] therefore owns the client and all compiled
//! executables on a dedicated thread, and hands out [`XlaBackend`]
//! handles (which are `Send`) that forward step requests over channels.
//! This matches the testbed anyway: with one physical CPU, worker
//! compute is time-sliced, and per-worker *virtual* time uses the
//! server-measured execution wall time of each request, not the queue
//! wait (see [`crate::simtime`]).
//!
//! Interchange is HLO **text** (see `python/compile/aot.py` for why).

use std::path::Path;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

// Without the vendored bindings (`pjrt-xla` off), the declaration-only
// shim keeps this whole module type-checked by `cargo check --features
// pjrt`; client construction then fails at runtime with a clear error.
#[cfg(not(feature = "pjrt-xla"))]
use super::xla_shim as xla;

use crate::model::{ArtifactMeta, StepBackend};

/// Which compiled entry point a request targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum EntryKind {
    Train,
    Eval,
    DcStep,
}

struct Request {
    kind: EntryKind,
    /// Flat f32 inputs in HLO parameter order (y sent separately).
    inputs: Vec<Vec<f32>>,
    /// Labels for train/eval entries.
    labels: Vec<i32>,
    reply: Sender<Result<Response>>,
}

struct Response {
    /// Flat f32 outputs in HLO tuple order (scalars as 1-element vecs).
    outputs: Vec<Vec<f32>>,
    /// Pure execution time of the PJRT call (excludes queueing).
    exec_s: f64,
}

/// Owns the PJRT client + executables for one artifact variant on a
/// dedicated thread.
pub struct ComputeServer {
    tx: Sender<Request>,
    handle: Option<JoinHandle<()>>,
    meta: ArtifactMeta,
}

impl ComputeServer {
    /// Compile `train_step` / `eval_step` (and `dc_step` if present) for
    /// the given variant directory and start serving.
    pub fn start(variant_dir: impl AsRef<Path>) -> Result<Self> {
        let meta = ArtifactMeta::load(variant_dir.as_ref())?;
        let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
        let meta2 = meta.clone();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("pjrt-compute".into())
            .spawn(move || server_main(meta2, rx, ready_tx))
            .context("spawning compute server")?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("compute server died during startup"))??;
        Ok(ComputeServer { tx, handle: Some(handle), meta })
    }

    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// A `Send` per-worker backend handle.
    pub fn backend(&self) -> XlaBackend {
        XlaBackend {
            tx: self.tx.clone(),
            n_params: self.meta.param_count,
            batch: self.meta.batch,
            last_exec_s: 0.0,
        }
    }

    /// Run the fused Pallas `dc_step` artifact:
    /// `(g, D, v, w, η, μ, λ0, wd) → (Δw, v', λ)`.
    pub fn dc_step(
        &self,
        g: &[f32],
        d: &[f32],
        v: &[f32],
        w: &[f32],
        eta: f32,
        mu: f32,
        lam0: f32,
        wd: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        let (reply, rx) = channel();
        self.tx
            .send(Request {
                kind: EntryKind::DcStep,
                inputs: vec![
                    g.to_vec(),
                    d.to_vec(),
                    v.to_vec(),
                    w.to_vec(),
                    vec![eta],
                    vec![mu],
                    vec![lam0],
                    vec![wd],
                ],
                labels: Vec::new(),
                reply,
            })
            .map_err(|_| anyhow!("compute server gone"))?;
        let resp = rx.recv().map_err(|_| anyhow!("compute server gone"))??;
        let mut outs = resp.outputs.into_iter();
        let dw = outs.next().ok_or_else(|| anyhow!("missing dw"))?;
        let vn = outs.next().ok_or_else(|| anyhow!("missing v_new"))?;
        let lam = outs.next().and_then(|v| v.first().copied()).unwrap_or(0.0);
        Ok((dw, vn, lam))
    }
}

impl Drop for ComputeServer {
    fn drop(&mut self) {
        // Closing the channel stops the server loop.
        let (tx, _) = channel();
        let _ = std::mem::replace(&mut self.tx, tx);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn server_main(meta: ArtifactMeta, rx: Receiver<Request>, ready: Sender<Result<()>>) {
    let setup = (|| -> Result<_> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt client: {e:?}"))?;
        let load = |path: &Path| -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))
        };
        let train = load(&meta.train_hlo())?;
        let eval = load(&meta.eval_hlo())?;
        let dc = if meta.dc_hlo().exists() { Some(load(&meta.dc_hlo())?) } else { None };
        Ok((train, eval, dc))
    })();

    let (train, eval, dc) = match setup {
        Ok(t) => {
            let _ = ready.send(Ok(()));
            t
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    let hw = meta.input_hw as i64;
    let ch = meta.input_channels as i64;
    let b = meta.batch as i64;

    while let Ok(req) = rx.recv() {
        let result = (|| -> Result<Response> {
            let exe = match req.kind {
                EntryKind::Train => &train,
                EntryKind::Eval => &eval,
                EntryKind::DcStep => dc.as_ref().ok_or_else(|| anyhow!("no dc_step artifact"))?,
            };
            let mut literals: Vec<xla::Literal> = Vec::new();
            match req.kind {
                EntryKind::Train | EntryKind::Eval => {
                    let w = &req.inputs[0];
                    let x = &req.inputs[1];
                    literals.push(xla::Literal::vec1(w));
                    literals.push(
                        xla::Literal::vec1(x)
                            .reshape(&[b, hw, hw, ch])
                            .map_err(|e| anyhow!("reshape x: {e:?}"))?,
                    );
                    literals.push(xla::Literal::vec1(&req.labels));
                }
                EntryKind::DcStep => {
                    for (i, v) in req.inputs.iter().enumerate() {
                        if v.len() == 1 && i >= 4 {
                            literals.push(xla::Literal::scalar(v[0]));
                        } else {
                            literals.push(xla::Literal::vec1(v));
                        }
                    }
                }
            }
            let t0 = Instant::now();
            let bufs = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("execute: {e:?}"))?;
            let result = bufs[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e:?}"))?;
            let exec_s = t0.elapsed().as_secs_f64();
            let parts = result.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))?;
            let outputs = parts
                .into_iter()
                .map(|l| l.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
                .collect::<Result<Vec<_>>>()?;
            Ok(Response { outputs, exec_s })
        })();
        if req.reply.send(result).is_err() {
            // requester gone; keep serving others
        }
    }
}

/// Per-worker `Send` handle implementing [`StepBackend`] over the
/// compute server.
pub struct XlaBackend {
    tx: Sender<Request>,
    n_params: usize,
    batch: usize,
    last_exec_s: f64,
}

impl XlaBackend {
    fn call(&mut self, kind: EntryKind, w: &[f32], x: &[f32], y: &[i32]) -> Result<Response> {
        let (reply, rx) = channel();
        self.tx
            .send(Request {
                kind,
                inputs: vec![w.to_vec(), x.to_vec()],
                labels: y.to_vec(),
                reply,
            })
            .map_err(|_| anyhow!("compute server gone"))?;
        rx.recv().map_err(|_| anyhow!("compute server gone"))?
    }

    /// Server-measured wall time of the last executed step (excludes
    /// queue wait — the per-worker compute cost a dedicated node would
    /// see).
    pub fn last_exec_s(&self) -> f64 {
        self.last_exec_s
    }
}

impl StepBackend for XlaBackend {
    fn n_params(&self) -> usize {
        self.n_params
    }

    fn batch_size(&self) -> usize {
        self.batch
    }

    fn train_step(&mut self, w: &[f32], x: &[f32], y: &[i32], grad_out: &mut [f32]) -> (f32, f32) {
        let resp = self.call(EntryKind::Train, w, x, y).expect("train_step failed");
        self.last_exec_s = resp.exec_s;
        let loss = resp.outputs[0][0];
        let err = resp.outputs[1][0];
        grad_out.copy_from_slice(&resp.outputs[2]);
        (loss, err)
    }

    fn eval_step(&mut self, w: &[f32], x: &[f32], y: &[i32]) -> (f32, f32) {
        let resp = self.call(EntryKind::Eval, w, x, y).expect("eval_step failed");
        self.last_exec_s = resp.exec_s;
        (resp.outputs[0][0], resp.outputs[1][0])
    }

    fn last_compute_s(&self) -> Option<f64> {
        Some(self.last_exec_s)
    }
}

#[cfg(test)]
mod tests {
    // PJRT-dependent tests live in rust/tests/runtime_integration.rs and
    // skip when artifacts are absent; unit-level coverage here is the
    // request plumbing with a poisoned channel.
    use super::*;

    #[test]
    fn backend_errors_when_server_gone() {
        let (tx, rx) = channel::<Request>();
        drop(rx);
        let mut be = XlaBackend { tx, n_params: 4, batch: 1, last_exec_s: 0.0 };
        let r = be.call(EntryKind::Train, &[0.0; 4], &[0.0; 4], &[0]);
        assert!(r.is_err());
    }
}
