//! Declaration-only stand-in for the vendored `xla` bindings.
//!
//! The real `xla` crate ships with the rust_pallas toolchain, not
//! crates.io, so an offline `--features pjrt` build would previously
//! fail to *resolve* — which meant the whole PJRT runtime
//! ([`super::pjrt`]) bit-rotted silently: nothing type-checked it. This
//! shim mirrors exactly the API surface `pjrt.rs` consumes, with every
//! entry point failing at runtime, so `cargo check --features pjrt`
//! keeps the runtime honest in CI while the vendored crate stays
//! optional. Enabling the `pjrt-xla` feature (plus the vendored
//! dependency in Cargo.toml) swaps this shim for the real bindings
//! without touching `pjrt.rs`.

/// Error type matching the real bindings' `{e:?}` formatting use.
#[derive(Debug)]
pub struct XlaError(pub &'static str);

const UNAVAILABLE: XlaError =
    XlaError("xla bindings not vendored — check-only shim (enable `pjrt-xla` to link them)");

/// Mirrors `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, XlaError> {
        Err(UNAVAILABLE)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(UNAVAILABLE)
    }
}

/// Mirrors `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, XlaError> {
        Err(UNAVAILABLE)
    }
}

/// Mirrors `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Mirrors `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(UNAVAILABLE)
    }
}

/// Mirrors `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(UNAVAILABLE)
    }
}

/// Mirrors `xla::Literal`.
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Self {
        Literal
    }

    pub fn scalar(_v: f32) -> Self {
        Literal
    }

    pub fn reshape(self, _dims: &[i64]) -> Result<Self, XlaError> {
        Ok(self)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        Err(UNAVAILABLE)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(UNAVAILABLE)
    }
}
