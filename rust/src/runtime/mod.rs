//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them
//! from the rust training path.
//!
//! The real implementation ([`pjrt`]) needs the `xla` bindings, which
//! ship with the vendored rust_pallas toolchain rather than crates.io.
//! The default (offline) build therefore compiles a [`stub`] with the
//! same API whose `ComputeServer::start` fails with a clear message —
//! every non-artifact path (the `linear` backend, all tier-1 tests, the
//! benches and examples without `make artifacts`) is unaffected.
//!
//! Feature ladder:
//! * *(default)* — the [`stub`]; nothing PJRT-shaped compiles.
//! * `pjrt` — compiles the full [`pjrt`] module against a
//!   declaration-only `xla` shim, so `cargo check --features pjrt`
//!   type-checks the runtime in CI without the vendored crate (client
//!   construction fails at runtime with an actionable error).
//! * `pjrt-xla` — swaps the shim for the real vendored `xla`
//!   dependency (uncomment it in Cargo.toml) and executes artifacts.

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(all(feature = "pjrt", not(feature = "pjrt-xla")))]
mod xla_shim;
#[cfg(feature = "pjrt")]
pub use pjrt::{ComputeServer, XlaBackend};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{ComputeServer, XlaBackend};
