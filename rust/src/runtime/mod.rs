//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them
//! from the rust training path.
//!
//! The real implementation ([`pjrt`]) needs the `xla` bindings, which
//! ship with the vendored rust_pallas toolchain rather than crates.io.
//! The default (offline) build therefore compiles a [`stub`] with the
//! same API whose `ComputeServer::start` fails with a clear message —
//! every non-artifact path (the `linear` backend, all tier-1 tests, the
//! benches and examples without `make artifacts`) is unaffected. Build
//! with `--features pjrt` (and the vendored `xla` dependency declared
//! in Cargo.toml) to execute artifacts for real.

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{ComputeServer, XlaBackend};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{ComputeServer, XlaBackend};
