//! API-compatible stand-in for the PJRT runtime when the crate is built
//! without the `pjrt` feature (the offline default).
//!
//! [`ComputeServer::start`] always fails — with an actionable message —
//! so any config that selects an artifact variant errors out cleanly at
//! startup instead of at link time. Nothing else can be reached: the
//! only constructor fails, so the remaining methods are unreachable by
//! construction.

use std::path::Path;

use anyhow::{bail, Result};

use crate::model::{ArtifactMeta, StepBackend};

/// Stub compute server; cannot be constructed.
pub struct ComputeServer {
    meta: ArtifactMeta,
}

impl ComputeServer {
    /// Always fails: artifact execution needs the `pjrt` feature.
    pub fn start(variant_dir: impl AsRef<Path>) -> Result<Self> {
        bail!(
            "artifact variant {:?} needs the PJRT runtime, but this build has no `pjrt` \
             feature — rebuild with `--features pjrt` (plus the vendored `xla` dependency, \
             see rust/Cargo.toml) or use the pure-rust `linear` variant",
            variant_dir.as_ref().display().to_string()
        )
    }

    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    pub fn backend(&self) -> XlaBackend {
        unreachable!("stub ComputeServer cannot be constructed")
    }

    /// Mirror of the PJRT `dc_step` entry point.
    #[allow(clippy::too_many_arguments)]
    pub fn dc_step(
        &self,
        _g: &[f32],
        _d: &[f32],
        _v: &[f32],
        _w: &[f32],
        _eta: f32,
        _mu: f32,
        _lam0: f32,
        _wd: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        unreachable!("stub ComputeServer cannot be constructed")
    }
}

/// Stub backend handle; cannot be obtained (see [`ComputeServer`]).
pub struct XlaBackend {
    _private: (),
}

impl XlaBackend {
    pub fn last_exec_s(&self) -> f64 {
        unreachable!("stub XlaBackend cannot be constructed")
    }
}

impl StepBackend for XlaBackend {
    fn n_params(&self) -> usize {
        unreachable!("stub XlaBackend cannot be constructed")
    }

    fn batch_size(&self) -> usize {
        unreachable!("stub XlaBackend cannot be constructed")
    }

    fn train_step(
        &mut self,
        _w: &[f32],
        _x: &[f32],
        _y: &[i32],
        _grad_out: &mut [f32],
    ) -> (f32, f32) {
        unreachable!("stub XlaBackend cannot be constructed")
    }

    fn eval_step(&mut self, _w: &[f32], _x: &[f32], _y: &[i32]) -> (f32, f32) {
        unreachable!("stub XlaBackend cannot be constructed")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn start_fails_with_actionable_message() {
        // (no unwrap_err: ComputeServer deliberately has no Debug impl)
        let err = ComputeServer::start("artifacts/tiny_cnn_b16")
            .err()
            .expect("stub start must fail");
        let msg = format!("{err}");
        assert!(msg.contains("pjrt"), "unhelpful stub error: {msg}");
        assert!(msg.contains("linear"), "no fallback hint: {msg}");
    }
}
