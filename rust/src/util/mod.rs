//! Small self-contained utilities the rest of the crate builds on.
//!
//! The build environment is offline (no crates.io beyond the `xla`
//! closure), so the RNG, JSON codec and statistics helpers that would
//! normally come from `rand` / `serde_json` / `criterion` are
//! implemented here, with their own tests.

pub mod json;
pub mod rng;
pub mod stats;

pub use json::Json;
pub use rng::Rng;
