//! Summary statistics used by the bench harness and metrics module.

/// Online mean/variance (Welford) plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile of a sample (nearest-rank; sorts a copy).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Ordinary least squares slope of y over x (for trend assertions).
pub fn ols_slope(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let cov: f64 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
    let var: f64 = x.iter().map(|a| (a - mx) * (a - mx)).sum();
    cov / var
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert_eq!(r.count(), 8);
        assert!((r.mean() - 5.0).abs() < 1e-12);
        assert!((r.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(r.min(), 2.0);
        assert_eq!(r.max(), 9.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.0).abs() <= 1.0);
    }

    #[test]
    fn slope() {
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 * v + 1.0).collect();
        assert!((ols_slope(&x, &y) - 3.0).abs() < 1e-12);
    }
}
