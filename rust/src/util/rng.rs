//! Deterministic, splittable pseudo-random numbers.
//!
//! SplitMix64 core (Steele et al., "Fast splittable pseudorandom number
//! generators") with a counter-based keyed constructor so dataset
//! samples can be generated independently by index — the property the
//! data pipeline relies on for deterministic sharding across workers.

/// SplitMix64 generator. Cheap, decent quality, fully deterministic.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create from a seed.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Counter-based keyed construction: mixes `(seed, stream, index)` so
    /// that any sample can be generated without generating its
    /// predecessors (O(1) random access into the virtual dataset).
    pub fn keyed(seed: u64, stream: u64, index: u64) -> Self {
        let mut r = Rng::new(seed ^ stream.rotate_left(17).wrapping_mul(0xA24B_AED4_963E_E407));
        let mix = r.next_u64() ^ index.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng::new(mix)
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Multiply-shift; bias is negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box-Muller (one value per call; simple > fast
    /// here — dataset generation is not on the training hot path).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Fill a slice with standard normals.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Exponential with the given mean (for jitter / service-time models).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.uniform(); // (0,1]
        -mean * u.ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn keyed_random_access_is_order_independent() {
        let r1 = Rng::keyed(7, 1, 1000).next_u64();
        // generate a bunch of other keys first; index 1000 must not change
        for i in 0..50 {
            let _ = Rng::keyed(7, 1, i).next_u64();
        }
        assert_eq!(Rng::keyed(7, 1, 1000).next_u64(), r1);
        // different stream/index give different values
        assert_ne!(Rng::keyed(7, 2, 1000).next_u64(), r1);
        assert_ne!(Rng::keyed(7, 1, 1001).next_u64(), r1);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let (mut s, mut s2) = (0f64, 0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "vanishingly unlikely");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.5).abs() < 0.05, "mean {mean}");
    }
}
