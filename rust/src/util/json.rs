//! Minimal JSON parser/emitter (offline build: no `serde_json`).
//!
//! Supports the full JSON grammar except `\u` surrogate pairs are passed
//! through unvalidated. Used for artifact `meta.json`, golden test
//! fixtures and metric dumps — small documents, so a straightforward
//! recursive-descent parser is plenty.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Numeric access.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Convenience: array of f32 (used by golden fixtures).
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|v| v as f32).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u"))?;
                            let n = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            self.pos += 4;
                            out.push(char::from_u32(n).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let s = &self.b[self.pos..];
                    let len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..len.min(s.len())])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(chunk);
                    self.pos += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn nested() {
        let doc = r#"{"a": [1, 2, {"b": "x", "c": null}], "d": -0.25}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("d").unwrap().as_f64(), Some(-0.25));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"name":"dc_basic","vals":[1,2.5,-3],"ok":true,"none":null}"#;
        let v = Json::parse(doc).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn f32_vec() {
        let v = Json::parse("[0.5, 1, -2.25]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![0.5, 1.0, -2.25]);
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn utf8_passthrough() {
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }
}
