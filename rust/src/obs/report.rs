//! The `trace-report` analyzer: reads a `--trace-out` JSONL journal
//! back and derives straggler attribution (which rank gated each
//! seal), the overlap-efficiency timeline, and anomaly flags
//! (compensation-ratio spikes, overlap collapses).
//!
//! Works from events alone — per `(window, rank)` it pairs the
//! `round_posted` instant with the `window_consumed` span:
//! `t_AR = consume_end − post`, `blocked = consume_end − wait_start`,
//! `efficiency = (t_AR − blocked) / t_AR`. Accepts both the full JSONL
//! (with `wall_s`) and the canonical wall-free view.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::util::Json;

/// One JSONL line, schema-checked but kind kept as a string so reports
/// survive vocabulary growth.
#[derive(Debug, Clone, PartialEq)]
pub struct ParsedEvent {
    pub kind: String,
    pub rank: usize,
    pub window: u64,
    pub t_start: f64,
    pub t_end: f64,
    pub detail: String,
}

/// Parse a JSONL trace (one JSON object per non-empty line).
pub fn parse_jsonl(text: &str) -> Result<Vec<ParsedEvent>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line).with_context(|| format!("trace line {}", i + 1))?;
        let field = |k: &str| {
            j.get(k)
                .and_then(Json::as_f64)
                .with_context(|| format!("trace line {}: missing numeric {k:?}", i + 1))
        };
        let Some(kind) = j.get("kind").and_then(Json::as_str) else {
            bail!("trace line {}: missing \"kind\"", i + 1);
        };
        out.push(ParsedEvent {
            kind: kind.to_string(),
            rank: field("rank")? as usize,
            window: field("window")? as u64,
            t_start: field("t_start")?,
            t_end: field("t_end")?,
            detail: j.get("detail").and_then(Json::as_str).unwrap_or("").to_string(),
        });
    }
    Ok(out)
}

/// Per-window digest in the report.
#[derive(Debug, Clone, PartialEq)]
pub struct WindowSummary {
    pub window: u64,
    /// Ranks with a paired post + consume this window.
    pub ranks: usize,
    pub t_ar_mean: f64,
    pub blocked_mean: f64,
    /// Mean overlap efficiency over the window's ranks.
    pub efficiency: f64,
    /// The rank whose post sealed the round (latest post instant).
    pub gated_by: Option<usize>,
    /// Compensation ratio from the window's `decision` event, if its
    /// detail carries a `comp=` field.
    pub comp_ratio: Option<f64>,
}

/// The analyzed trace.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceReport {
    pub events: usize,
    pub windows: Vec<WindowSummary>,
    /// rank → number of seals that rank gated.
    pub gated: BTreeMap<usize, u64>,
    pub mean_efficiency: f64,
    pub mean_comp_ratio: f64,
    pub anomalies: Vec<String>,
}

fn detail_field(detail: &str, key: &str) -> Option<f64> {
    detail
        .split_whitespace()
        .filter_map(|tok| tok.split_once('='))
        .find(|(k, _)| *k == key)
        .and_then(|(_, v)| v.parse::<f64>().ok())
}

/// Derive the report from parsed events.
pub fn analyze(events: &[ParsedEvent]) -> TraceReport {
    let mut posts: BTreeMap<(u64, usize), f64> = BTreeMap::new();
    let mut consumes: BTreeMap<(u64, usize), (f64, f64)> = BTreeMap::new();
    let mut comp: BTreeMap<u64, f64> = BTreeMap::new();
    for e in events {
        match e.kind.as_str() {
            "round_posted" => {
                posts.insert((e.window, e.rank), e.t_start);
            }
            "window_consumed" => {
                consumes.insert((e.window, e.rank), (e.t_start, e.t_end));
            }
            "decision" => {
                if let Some(c) = detail_field(&e.detail, "comp") {
                    comp.insert(e.window, c);
                }
            }
            _ => {}
        }
    }

    let mut report = TraceReport { events: events.len(), ..TraceReport::default() };
    let window_ids: Vec<u64> = {
        let mut ids: Vec<u64> = consumes.keys().map(|(w, _)| *w).collect();
        ids.dedup();
        ids
    };

    let (mut eff_sum, mut eff_n) = (0.0, 0u64);
    for w in window_ids {
        let mut ranks = 0usize;
        let (mut t_ar_sum, mut blocked_sum, mut eff_w) = (0.0, 0.0, 0.0);
        for ((win, rank), (wait_start, t_end)) in consumes.range((w, 0)..=(w, usize::MAX)) {
            debug_assert_eq!(*win, w);
            let Some(post) = posts.get(&(w, *rank)) else { continue };
            let t_ar = t_end - post;
            let blocked = t_end - wait_start;
            let eff = if t_ar > 0.0 { ((t_ar - blocked) / t_ar).clamp(0.0, 1.0) } else { 0.0 };
            ranks += 1;
            t_ar_sum += t_ar;
            blocked_sum += blocked;
            eff_w += eff;
        }
        if ranks == 0 {
            continue;
        }
        let n = ranks as f64;
        // Straggler attribution: the seal closes when the last
        // contribution arrives, so the latest poster gated it.
        let gated_by = posts
            .range((w, 0)..=(w, usize::MAX))
            .max_by(|a, b| a.1.total_cmp(b.1).then(a.0 .1.cmp(&b.0 .1)))
            .map(|((_, rank), _)| *rank);
        if let Some(r) = gated_by {
            *report.gated.entry(r).or_insert(0) += 1;
        }
        let efficiency = eff_w / n;
        eff_sum += efficiency;
        eff_n += 1;
        report.windows.push(WindowSummary {
            window: w,
            ranks,
            t_ar_mean: t_ar_sum / n,
            blocked_mean: blocked_sum / n,
            efficiency,
            gated_by,
            comp_ratio: comp.get(&w).copied(),
        });
    }
    report.mean_efficiency = if eff_n > 0 { eff_sum / eff_n as f64 } else { 0.0 };

    let comps: Vec<f64> = report.windows.iter().filter_map(|w| w.comp_ratio).collect();
    report.mean_comp_ratio =
        if comps.is_empty() { 0.0 } else { comps.iter().sum::<f64>() / comps.len() as f64 };

    for w in &report.windows {
        if report.mean_efficiency > 0.0 && w.efficiency < 0.5 * report.mean_efficiency {
            report.anomalies.push(format!(
                "window {}: overlap collapse (eff {:.3} < 0.5 x mean {:.3})",
                w.window, w.efficiency, report.mean_efficiency
            ));
        }
        if let Some(c) = w.comp_ratio {
            if report.mean_comp_ratio > 0.0 && c > 2.0 * report.mean_comp_ratio {
                report.anomalies.push(format!(
                    "window {}: compensation spike (comp {:.3} > 2 x mean {:.3})",
                    w.window, c, report.mean_comp_ratio
                ));
            }
        }
    }
    report
}

/// Human-readable report text (what `trace-report` prints).
pub fn render(r: &TraceReport) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "trace-report: {} events, {} windows\n",
        r.events,
        r.windows.len()
    ));
    out.push_str(&format!("mean overlap efficiency: {:.3}\n", r.mean_efficiency));
    if r.mean_comp_ratio > 0.0 {
        out.push_str(&format!("mean compensation ratio: {:.3}\n", r.mean_comp_ratio));
    }

    out.push_str("\noverlap-efficiency timeline\n");
    out.push_str("  window  ranks     eff   t_ar_mean  blocked_mean    comp  gated_by\n");
    for w in &r.windows {
        out.push_str(&format!(
            "  {:>6}  {:>5}  {:>6.3}  {:>10.6}  {:>12.6}  {}  {}\n",
            w.window,
            w.ranks,
            w.efficiency,
            w.t_ar_mean,
            w.blocked_mean,
            w.comp_ratio.map_or("     -".to_string(), |c| format!("{c:>6.3}")),
            w.gated_by.map_or("-".to_string(), |g| format!("rank {g}")),
        ));
    }

    out.push_str("\nstraggler attribution (rank whose post gated each seal)\n");
    if r.gated.is_empty() {
        out.push_str("  (no sealed windows)\n");
    } else {
        let total: u64 = r.gated.values().sum();
        out.push_str("  rank  gated  share\n");
        for (rank, n) in &r.gated {
            out.push_str(&format!(
                "  {:>4}  {:>5}  {:.2}\n",
                rank,
                n,
                *n as f64 / total as f64
            ));
        }
    }

    out.push_str("\nanomalies\n");
    if r.anomalies.is_empty() {
        out.push_str("  (none)\n");
    } else {
        for a in &r.anomalies {
            out.push_str(&format!("  {a}\n"));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: &str, rank: usize, window: u64, t_start: f64, t_end: f64) -> ParsedEvent {
        ParsedEvent {
            kind: kind.to_string(),
            rank,
            window,
            t_start,
            t_end,
            detail: String::new(),
        }
    }

    #[test]
    fn pairs_posts_with_consumes_into_efficiency() {
        // rank 0 posts at t=1, computes until t=2, round seals at t=2.5:
        // t_ar = 1.5, blocked = 0.5, eff = 2/3.
        let events = vec![
            ev("round_posted", 0, 0, 1.0, 1.0),
            ev("round_posted", 1, 0, 1.2, 1.2),
            ev("window_consumed", 0, 0, 2.0, 2.5),
            ev("window_consumed", 1, 0, 2.5, 2.5),
        ];
        let r = analyze(&events);
        assert_eq!(r.windows.len(), 1);
        let w = &r.windows[0];
        assert_eq!(w.ranks, 2);
        // rank 1 fully overlapped (blocked 0), rank 0 eff = 2/3.
        assert!((w.efficiency - (2.0 / 3.0 + 1.0) / 2.0).abs() < 1e-12);
        assert_eq!(w.gated_by, Some(1));
        assert_eq!(r.gated.get(&1), Some(&1));
    }

    #[test]
    fn blocking_trace_reports_zero_efficiency() {
        // SSGD shape: post and wait at the same instant → fully exposed.
        let events = vec![
            ev("round_posted", 0, 0, 1.0, 1.0),
            ev("window_consumed", 0, 0, 1.0, 1.5),
        ];
        let r = analyze(&events);
        assert_eq!(r.mean_efficiency, 0.0);
    }

    #[test]
    fn decision_comp_field_feeds_anomaly_flags() {
        let mut events = Vec::new();
        for w in 0..4u64 {
            events.push(ev("round_posted", 0, w, w as f64, w as f64));
            events.push(ev("window_consumed", 0, w, w as f64 + 0.9, w as f64 + 1.0));
            let mut d = ev("decision", 0, w, w as f64 + 1.0, w as f64 + 1.0);
            d.detail = format!("k=1 comp={}", if w == 3 { 0.9 } else { 0.1 });
            events.push(d);
        }
        let r = analyze(&events);
        assert!(r.mean_comp_ratio > 0.0);
        assert!(r.anomalies.iter().any(|a| a.contains("compensation spike")));
        assert!(r.anomalies.iter().any(|a| a.contains("window 3")));
    }

    #[test]
    fn jsonl_round_trips_through_the_parser() {
        let line = concat!(
            r#"{"detail":"k=2","kind":"round_posted","rank":3,"seq":0,"#,
            r#""t_end":1.5,"t_start":1.5,"wall_s":0.001,"window":7}"#
        );
        let events = parse_jsonl(&format!("{line}\n\n")).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].rank, 3);
        assert_eq!(events[0].window, 7);
        assert_eq!(events[0].detail, "k=2");
        assert!(parse_jsonl("{\"rank\":0}").is_err());
        assert!(parse_jsonl("not json").is_err());
    }

    #[test]
    fn render_mentions_the_headline_sections() {
        let events = vec![
            ev("round_posted", 0, 0, 0.0, 0.0),
            ev("window_consumed", 0, 0, 0.5, 1.0),
        ];
        let text = render(&analyze(&events));
        assert!(text.contains("overlap-efficiency timeline"));
        assert!(text.contains("straggler attribution"));
        assert!(text.contains("anomalies"));
    }
}
