//! Observability: the span/event journal, the metric registry, and the
//! run-JSON `"obs"` exporter shared by every engine.
//!
//! The paper's whole pitch is overlapping the all-reduce under the next
//! window's compute (arXiv 1911.02516 Eq. 13 vs Eq. 14) and paying for
//! the induced staleness with the Eq. 9/17 correction — this module is
//! the instrument that *measures* both. Three pieces:
//!
//! 1. [`Journal`] — a bounded ring-buffer of typed [`TraceEvent`]s
//!    (`[trace] capacity` per rank lane, drop-oldest with a dropped
//!    count), recorded in **virtual time** and exported as JSONL
//!    (`--trace-out`); `tools/trace_to_chrome.py` turns the JSONL into
//!    a chrome://tracing view.
//! 2. [`Metrics`] — named counters / gauges / log₂-µs histograms (the
//!    same bucket shape as [`crate::exec::Profiler`], via
//!    [`crate::exec::log2_us_bucket`]), populated by the algo / comm /
//!    control / compress / hetero layers.
//! 3. [`ObsHub`] — the per-run handle engines thread through their rank
//!    bodies; it derives the headline metrics: **overlap efficiency**
//!    per window (fraction of t_AR hidden under t_C — the paper's
//!    Fig. 2 quantity), the **staleness distribution** per rank, and
//!    the **compensation ratio** ‖λ·g⊙g⊙D‖/‖g‖ per window
//!    (arXiv 1609.08326's health signal for delay compensation).
//!
//! Determinism contract: every exported field is a pure function of
//! virtual time, so the `"obs"` block is byte-identical run-to-run and
//! across `[perf] threads` / `[sim] backend` settings (pinned by the
//! engine proptests). The only wall-clock field anywhere is the
//! `wall_s` annotation on JSONL lines, which [`Journal::canonical_text`]
//! strips; like `"perf"`, the whole `"obs"` block is removed by
//! `RunReport::deterministic_json`. See `docs/observability.md`.

pub mod report;

use std::collections::{BTreeMap, VecDeque};
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::config::TraceConfig;
use crate::exec::{log2_us_bucket, HIST_BUCKETS};
use crate::util::Json;

/// The typed event vocabulary. Names are the JSONL `"kind"` strings
/// (see `docs/observability.md` for the schema table).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A rank posted its window contribution to the collective (or the
    /// PS push departed). Span start = post instant.
    RoundPosted,
    /// The round's contributor set closed and the reduction completed:
    /// span runs from the rank's own post to the global seal.
    RoundSealed,
    /// The rank blocked on (and consumed) a sealed window: span runs
    /// from wait-entry to consumption — its length is the *exposed*
    /// (non-overlapped) part of t_AR.
    WindowConsumed,
    /// A membership epoch boundary (world resize + resync).
    EpochTransition,
    /// A controller decision `(k, λ-scale, schedule, …)` for the next
    /// window; `detail` carries [`crate::control::Decision::describe`].
    Decision,
    /// A scripted or derived fault: departure, revocation, slowdown.
    Fault,
    /// A probe window ran the inactive schedule candidate.
    Probe,
}

impl EventKind {
    /// Every kind, in declaration order.
    pub const ALL: [EventKind; 7] = [
        EventKind::RoundPosted,
        EventKind::RoundSealed,
        EventKind::WindowConsumed,
        EventKind::EpochTransition,
        EventKind::Decision,
        EventKind::Fault,
        EventKind::Probe,
    ];

    /// The JSONL `"kind"` string.
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::RoundPosted => "round_posted",
            EventKind::RoundSealed => "round_sealed",
            EventKind::WindowConsumed => "window_consumed",
            EventKind::EpochTransition => "epoch_transition",
            EventKind::Decision => "decision",
            EventKind::Fault => "fault",
            EventKind::Probe => "probe",
        }
    }

    /// Inverse of [`EventKind::name`] (used by the trace analyzer).
    pub fn parse(s: &str) -> Option<EventKind> {
        EventKind::ALL.iter().copied().find(|k| k.name() == s)
    }
}

/// One journal entry: a virtual-time span (`t_start == t_end` for
/// instantaneous events) tagged with the rank that recorded it and the
/// window / round / epoch id it belongs to.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub kind: EventKind,
    /// Recording rank (leader rank for leader-only events).
    pub rank: usize,
    /// Window / round id; epoch id for [`EventKind::EpochTransition`].
    pub window: u64,
    /// Virtual-time span start (seconds).
    pub t_start: f64,
    /// Virtual-time span end (seconds, `>= t_start`).
    pub t_end: f64,
    /// Short free-form annotation (`"k=2 lam=1.00"`, `"depart"`, …).
    pub detail: String,
    /// Per-rank-lane sequence number (record order within the rank).
    pub seq: u64,
    /// Wall-clock seconds since journal creation — the one
    /// nondeterministic field; JSONL-only, stripped from canonical
    /// views.
    pub wall_s: f64,
}

impl TraceEvent {
    /// The deterministic (virtual-time-only) JSON object: no `wall_s`.
    pub fn canonical_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("kind".to_string(), Json::Str(self.kind.name().to_string()));
        m.insert("rank".to_string(), Json::Num(self.rank as f64));
        m.insert("window".to_string(), Json::Num(self.window as f64));
        m.insert("t_start".to_string(), Json::Num(self.t_start));
        m.insert("t_end".to_string(), Json::Num(self.t_end));
        m.insert("seq".to_string(), Json::Num(self.seq as f64));
        if !self.detail.is_empty() {
            m.insert("detail".to_string(), Json::Str(self.detail.clone()));
        }
        Json::Obj(m)
    }

    /// The full JSONL record: canonical fields plus the wall-clock
    /// annotation.
    pub fn to_json(&self) -> Json {
        let mut j = self.canonical_json();
        if let Json::Obj(m) = &mut j {
            m.insert("wall_s".to_string(), Json::Num(self.wall_s));
        }
        j
    }
}

#[derive(Debug, Default)]
struct Lane {
    events: VecDeque<TraceEvent>,
    seq: u64,
    dropped: u64,
}

/// Bounded span/event journal. Each rank records into its own lane
/// (per-lane sequence numbers, per-lane drop-oldest at `capacity`), so
/// record order never depends on thread interleaving; the export merge
/// sorts by `(t_start, rank, seq)` and applies the global `capacity`
/// cap drop-oldest — both deterministic. `capacity = 0` disables
/// recording entirely (the tracing-off mode the overhead gate in
/// `benches/engine.rs` measures against).
#[derive(Debug, Clone)]
pub struct Journal {
    lanes: Arc<Mutex<BTreeMap<usize, Lane>>>,
    capacity: usize,
    started: Instant,
}

impl Journal {
    pub fn new(capacity: usize) -> Journal {
        Journal { lanes: Arc::new(Mutex::new(BTreeMap::new())), capacity, started: Instant::now() }
    }

    /// Whether events are being recorded (`[trace] capacity > 0`).
    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    /// Configured ring capacity (per rank lane and per export).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Record one event. No-op when the journal is disabled. `t_start`
    /// and `t_end` are virtual-time seconds; the wall-clock annotation
    /// is stamped here and never leaves the JSONL view.
    pub fn record(
        &self,
        kind: EventKind,
        rank: usize,
        window: u64,
        t_start: f64,
        t_end: f64,
        detail: impl Into<String>,
    ) {
        if self.capacity == 0 {
            return;
        }
        let wall_s = self.started.elapsed().as_secs_f64();
        let mut lanes = self.lanes.lock().unwrap();
        let lane = lanes.entry(rank).or_default();
        let seq = lane.seq;
        lane.seq += 1;
        lane.events.push_back(TraceEvent {
            kind,
            rank,
            window,
            t_start,
            t_end,
            detail: detail.into(),
            seq,
            wall_s,
        });
        if lane.events.len() > self.capacity {
            lane.events.pop_front();
            lane.dropped += 1;
        }
    }

    /// The merged journal: events sorted by `(t_start, rank, seq)`
    /// with the global capacity cap applied (oldest dropped first),
    /// plus the total dropped count (per-lane drops + merge drops).
    pub fn events(&self) -> (Vec<TraceEvent>, u64) {
        let lanes = self.lanes.lock().unwrap();
        let mut all: Vec<TraceEvent> =
            lanes.values().flat_map(|l| l.events.iter().cloned()).collect();
        let mut dropped: u64 = lanes.values().map(|l| l.dropped).sum();
        all.sort_by(|a, b| {
            a.t_start
                .total_cmp(&b.t_start)
                .then(a.rank.cmp(&b.rank))
                .then(a.seq.cmp(&b.seq))
        });
        if self.capacity > 0 && all.len() > self.capacity {
            let overflow = all.len() - self.capacity;
            all.drain(..overflow);
            dropped += overflow as u64;
        }
        (all, dropped)
    }

    /// Retained event count after the merge cap.
    pub fn len(&self) -> usize {
        self.events().0.len()
    }

    /// True when no events are retained.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total events dropped by the ring (per-lane + merge).
    pub fn dropped(&self) -> u64 {
        self.events().1
    }

    /// The deterministic journal view: one canonical JSON object per
    /// line, wall-clock fields stripped. Byte-identical across thread
    /// counts and simulator backends (pinned by the engine proptests).
    pub fn canonical_text(&self) -> String {
        let (events, _) = self.events();
        let mut out = String::new();
        for e in &events {
            out.push_str(&e.canonical_json().to_string());
            out.push('\n');
        }
        out
    }

    /// The full JSONL export (`--trace-out` payload): canonical fields
    /// plus the `wall_s` annotation per line.
    pub fn to_jsonl(&self) -> String {
        let (events, _) = self.events();
        let mut out = String::new();
        for e in &events {
            out.push_str(&e.to_json().to_string());
            out.push('\n');
        }
        out
    }

    /// Write [`Journal::to_jsonl`] to `path`.
    pub fn write_jsonl(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_jsonl())
    }
}

#[derive(Debug, Default)]
struct MetricsInner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Vec<u64>>,
}

/// Named counters / gauges / log₂-µs histograms. Exported sorted by
/// name under the run JSON's `"obs"` key, so layers register metrics
/// just by populating them. Values must be virtual-time-derived —
/// wall-clock readings belong in `"perf"`, not here (the `"obs"`
/// block is pinned byte-identical run-to-run).
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    inner: Arc<Mutex<MetricsInner>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Add `by` to the named counter (registering it at 0 first).
    pub fn inc(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Raise the named counter to `v` if `v` is larger (high-water
    /// marks, e.g. the cohort arena).
    pub fn counter_max(&self, name: &str, v: u64) {
        let mut g = self.inner.lock().unwrap();
        let c = g.counters.entry(name.to_string()).or_insert(0);
        *c = (*c).max(v);
    }

    /// Set the named gauge to `v` (last write wins).
    pub fn gauge(&self, name: &str, v: f64) {
        let mut g = self.inner.lock().unwrap();
        g.gauges.insert(name.to_string(), v);
    }

    /// Record a `us`-microsecond observation into the named log₂
    /// histogram (same bucket shape as the `"perf"` profiler).
    pub fn observe_us(&self, name: &str, us: u64) {
        let mut g = self.inner.lock().unwrap();
        let h = g.hists.entry(name.to_string()).or_insert_with(|| vec![0; HIST_BUCKETS]);
        h[log2_us_bucket(us)] += 1;
    }

    /// Current value of a counter (0 if unregistered).
    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    /// `{"counters": {..}, "gauges": {..}, "hist_log2_us": {..}}` with
    /// histograms trailing-zero-trimmed like the profiler's.
    pub fn to_json(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let num = |x: f64| if x.is_finite() { Json::Num(x) } else { Json::Null };
        let mut out = BTreeMap::new();
        out.insert(
            "counters".to_string(),
            Json::Obj(
                g.counters.iter().map(|(k, v)| (k.clone(), Json::Num(*v as f64))).collect(),
            ),
        );
        out.insert(
            "gauges".to_string(),
            Json::Obj(g.gauges.iter().map(|(k, v)| (k.clone(), num(*v))).collect()),
        );
        out.insert(
            "hist_log2_us".to_string(),
            Json::Obj(
                g.hists
                    .iter()
                    .map(|(k, h)| {
                        let keep = h.iter().rposition(|&c| c != 0).map_or(0, |i| i + 1);
                        (
                            k.clone(),
                            Json::Arr(h[..keep].iter().map(|&c| Json::Num(c as f64)).collect()),
                        )
                    })
                    .collect(),
            ),
        );
        Json::Obj(out)
    }
}

/// One consumed window's overlap/compensation accounting, recorded at
/// the rank's wait site. All fields are virtual-time seconds.
#[derive(Debug, Clone, PartialEq)]
pub struct WindowRow {
    /// Consuming rank.
    pub worker: usize,
    /// Consumed window id.
    pub window: u64,
    /// Compute time the rank spent between posting this window and
    /// blocking on it — the budget t_AR can hide under (Eq. 14).
    pub t_c: f64,
    /// Observed end-to-end all-reduce latency: post → seal/consume.
    pub t_ar: f64,
    /// Exposed wait: the part of `t_ar` that was *not* hidden.
    pub blocked_s: f64,
    /// ‖λ·g⊙g⊙D‖ / ‖g‖ for the correction applied at this window
    /// (0 when no compensation ran).
    pub comp_ratio: f64,
}

impl WindowRow {
    /// Fraction of `t_ar` hidden under compute — the paper's Fig. 2
    /// quantity. 1.0 = fully overlapped, 0.0 = fully exposed (blocking
    /// SSGD); 0.0 when `t_ar` is zero.
    pub fn overlap_efficiency(&self) -> f64 {
        if self.t_ar > 0.0 {
            ((self.t_ar - self.blocked_s) / self.t_ar).clamp(0.0, 1.0)
        } else {
            0.0
        }
    }

    fn to_json(&self) -> Json {
        let num = |x: f64| if x.is_finite() { Json::Num(x) } else { Json::Null };
        let mut m = BTreeMap::new();
        m.insert("worker".to_string(), Json::Num(self.worker as f64));
        m.insert("window".to_string(), Json::Num(self.window as f64));
        m.insert("t_c".to_string(), num(self.t_c));
        m.insert("t_ar".to_string(), num(self.t_ar));
        m.insert("blocked_s".to_string(), num(self.blocked_s));
        m.insert("overlap_efficiency".to_string(), num(self.overlap_efficiency()));
        m.insert("comp_ratio".to_string(), num(self.comp_ratio));
        Json::Obj(m)
    }
}

/// Per-rank t_C/t_AR running totals — the observation split `dyn_ssp`
/// tunes `k_i` from, exported so its decisions can be audited post-run.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RankObs {
    pub windows: u64,
    pub t_c_total: f64,
    pub t_ar_total: f64,
}

/// The per-run observability handle: journal + metric registry + the
/// derived per-window / per-rank accounting. Cloned into each rank
/// body by the engines (all state is `Arc`-shared); built by
/// `RoundDriver` from `[trace]`.
#[derive(Debug, Clone)]
pub struct ObsHub {
    pub journal: Journal,
    pub metrics: Metrics,
    windows: Arc<Mutex<Vec<WindowRow>>>,
    ranks: Arc<Mutex<BTreeMap<usize, RankObs>>>,
    staleness: Arc<Mutex<BTreeMap<(usize, u64), u64>>>,
}

impl ObsHub {
    pub fn new(cfg: &TraceConfig) -> ObsHub {
        ObsHub {
            journal: Journal::new(cfg.capacity),
            metrics: Metrics::new(),
            windows: Arc::new(Mutex::new(Vec::new())),
            ranks: Arc::new(Mutex::new(BTreeMap::new())),
            staleness: Arc::new(Mutex::new(BTreeMap::new())),
        }
    }

    /// Shorthand for [`Journal::record`].
    pub fn record(
        &self,
        kind: EventKind,
        rank: usize,
        window: u64,
        t_start: f64,
        t_end: f64,
        detail: impl Into<String>,
    ) {
        self.journal.record(kind, rank, window, t_start, t_end, detail);
    }

    /// Record one consumed window's accounting; also folds the row
    /// into the per-rank t_C/t_AR split.
    pub fn window(&self, row: WindowRow) {
        {
            let mut ranks = self.ranks.lock().unwrap();
            let r = ranks.entry(row.worker).or_default();
            r.windows += 1;
            r.t_c_total += row.t_c;
            r.t_ar_total += row.t_ar;
        }
        self.windows.lock().unwrap().push(row);
    }

    /// Count one window consumed by `rank` at the given staleness
    /// (window length k for the windowed engines, observed PS delay
    /// for the async family).
    pub fn staleness(&self, rank: usize, staleness: u64) {
        *self.staleness.lock().unwrap().entry((rank, staleness)).or_insert(0) += 1;
    }

    /// All window rows, sorted by `(window, worker)` — push order is
    /// thread-dependent, so the export order is imposed here.
    pub fn windows(&self) -> Vec<WindowRow> {
        let mut rows = self.windows.lock().unwrap().clone();
        rows.sort_by(|a, b| a.window.cmp(&b.window).then(a.worker.cmp(&b.worker)));
        rows
    }

    /// Mean overlap efficiency over windows with `t_ar > 0`.
    pub fn overlap_efficiency_mean(&self) -> f64 {
        let rows = self.windows();
        let (mut sum, mut n) = (0.0, 0u64);
        for r in rows.iter().filter(|r| r.t_ar > 0.0) {
            sum += r.overlap_efficiency();
            n += 1;
        }
        if n > 0 { sum / n as f64 } else { 0.0 }
    }

    /// The run JSON `"obs"` block. Deterministic: virtual-time fields
    /// only, maps sorted, rows ordered by `(window, worker)`.
    pub fn to_json(&self) -> Json {
        let num = |x: f64| if x.is_finite() { Json::Num(x) } else { Json::Null };
        let rows = self.windows();
        let (events, dropped) = self.journal.events();

        let mut comp_sum = 0.0;
        let mut comp_n = 0u64;
        for r in &rows {
            if r.comp_ratio > 0.0 {
                comp_sum += r.comp_ratio;
                comp_n += 1;
            }
        }

        let ranks = self.ranks.lock().unwrap();
        let rank_rows: Vec<Json> = ranks
            .iter()
            .map(|(rank, o)| {
                let mut m = BTreeMap::new();
                let w = o.windows.max(1) as f64;
                m.insert("rank".to_string(), Json::Num(*rank as f64));
                m.insert("windows".to_string(), Json::Num(o.windows as f64));
                m.insert("t_c_total".to_string(), num(o.t_c_total));
                m.insert("t_ar_total".to_string(), num(o.t_ar_total));
                m.insert("t_c_mean".to_string(), num(o.t_c_total / w));
                m.insert("t_ar_mean".to_string(), num(o.t_ar_total / w));
                Json::Obj(m)
            })
            .collect();

        let staleness = self.staleness.lock().unwrap();
        let stale_rows: Vec<Json> = staleness
            .iter()
            .map(|((rank, s), count)| {
                let mut m = BTreeMap::new();
                m.insert("rank".to_string(), Json::Num(*rank as f64));
                m.insert("staleness".to_string(), Json::Num(*s as f64));
                m.insert("count".to_string(), Json::Num(*count as f64));
                Json::Obj(m)
            })
            .collect();

        let mut journal = BTreeMap::new();
        journal.insert("capacity".to_string(), Json::Num(self.journal.capacity() as f64));
        journal.insert("events".to_string(), Json::Num(events.len() as f64));
        journal.insert("dropped".to_string(), Json::Num(dropped as f64));

        let mut m = BTreeMap::new();
        m.insert("enabled".to_string(), Json::Bool(self.journal.enabled()));
        m.insert("journal".to_string(), Json::Obj(journal));
        m.insert("metrics".to_string(), self.metrics.to_json());
        m.insert("windows".to_string(), Json::Arr(rows.iter().map(|r| r.to_json()).collect()));
        m.insert("ranks".to_string(), Json::Arr(rank_rows));
        m.insert("staleness".to_string(), Json::Arr(stale_rows));
        m.insert(
            "overlap_efficiency_mean".to_string(),
            num(self.overlap_efficiency_mean()),
        );
        m.insert(
            "comp_ratio_mean".to_string(),
            num(if comp_n > 0 { comp_sum / comp_n as f64 } else { 0.0 }),
        );
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hub(capacity: usize) -> ObsHub {
        ObsHub::new(&TraceConfig { capacity, out: None })
    }

    #[test]
    fn journal_merges_lanes_in_virtual_time_order() {
        let j = Journal::new(64);
        // Recorded out of virtual-time order and from interleaved
        // "ranks" — export order must depend only on (t_start, rank, seq).
        j.record(EventKind::RoundPosted, 1, 0, 2.0, 2.0, "");
        j.record(EventKind::RoundPosted, 0, 0, 1.0, 1.0, "");
        j.record(EventKind::WindowConsumed, 0, 0, 3.0, 3.5, "");
        j.record(EventKind::RoundPosted, 1, 1, 1.0, 1.0, "");
        let (events, dropped) = j.events();
        assert_eq!(dropped, 0);
        let order: Vec<(usize, f64)> = events.iter().map(|e| (e.rank, e.t_start)).collect();
        assert_eq!(order, vec![(0, 1.0), (1, 1.0), (1, 2.0), (0, 3.0)]);
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        let j = Journal::new(2);
        for i in 0..5 {
            j.record(EventKind::RoundPosted, 0, i, i as f64, i as f64, "");
        }
        let (events, dropped) = j.events();
        assert_eq!(events.len(), 2);
        assert_eq!(dropped, 3);
        assert_eq!(events[0].window, 3);
        assert_eq!(events[1].window, 4);
        // Merge-level cap also drops oldest across lanes.
        let j = Journal::new(2);
        j.record(EventKind::RoundPosted, 0, 0, 1.0, 1.0, "");
        j.record(EventKind::RoundPosted, 1, 0, 2.0, 2.0, "");
        j.record(EventKind::RoundPosted, 2, 0, 3.0, 3.0, "");
        let (events, dropped) = j.events();
        assert_eq!(events.len(), 2);
        assert_eq!(dropped, 1);
        assert_eq!(events[0].t_start, 2.0);
    }

    #[test]
    fn capacity_zero_disables_recording() {
        let j = Journal::new(0);
        assert!(!j.enabled());
        j.record(EventKind::Fault, 0, 0, 1.0, 1.0, "depart");
        let (events, dropped) = j.events();
        assert!(events.is_empty());
        assert_eq!(dropped, 0);
    }

    #[test]
    fn canonical_text_is_wall_free_and_jsonl_is_not() {
        let j = Journal::new(8);
        j.record(EventKind::Decision, 0, 3, 1.5, 1.5, "k=2");
        let canon = j.canonical_text();
        assert!(canon.contains("\"kind\":\"decision\""));
        assert!(canon.contains("\"detail\":\"k=2\""));
        assert!(!canon.contains("wall_s"));
        assert!(j.to_jsonl().contains("wall_s"));
        // Each line parses back as a JSON object.
        for line in canon.lines() {
            assert!(matches!(Json::parse(line), Ok(Json::Obj(_))));
        }
    }

    #[test]
    fn event_kind_names_round_trip() {
        for k in EventKind::ALL {
            assert_eq!(EventKind::parse(k.name()), Some(k));
        }
        assert_eq!(EventKind::parse("nope"), None);
    }

    #[test]
    fn metrics_registry_counts_and_buckets() {
        let m = Metrics::new();
        m.inc("comm.rounds", 2);
        m.inc("comm.rounds", 1);
        m.counter_max("sim.cohort.arena_max", 5);
        m.counter_max("sim.cohort.arena_max", 3);
        m.gauge("hetero.tiers", 2.0);
        m.observe_us("window.t_ar", 3000); // 3000 µs → bucket 11
        assert_eq!(m.counter("comm.rounds"), 3);
        assert_eq!(m.counter("sim.cohort.arena_max"), 5);
        let j = m.to_json();
        let hist = j.get("hist_log2_us").and_then(|h| h.get("window.t_ar")).unwrap();
        let hist = hist.as_arr().unwrap();
        assert_eq!(hist.len(), 12);
        assert_eq!(hist[11].as_f64(), Some(1.0));
    }

    #[test]
    fn overlap_efficiency_bounds() {
        let full = WindowRow {
            worker: 0,
            window: 0,
            t_c: 2.0,
            t_ar: 1.0,
            blocked_s: 0.0,
            comp_ratio: 0.1,
        };
        assert_eq!(full.overlap_efficiency(), 1.0);
        let blocking = WindowRow { blocked_s: 1.0, ..full.clone() };
        assert_eq!(blocking.overlap_efficiency(), 0.0);
        let none = WindowRow { t_ar: 0.0, ..full };
        assert_eq!(none.overlap_efficiency(), 0.0);
    }

    #[test]
    fn hub_export_is_sorted_and_carries_headline_metrics() {
        let h = hub(16);
        h.window(WindowRow {
            worker: 1,
            window: 0,
            t_c: 2.0,
            t_ar: 1.0,
            blocked_s: 0.25,
            comp_ratio: 0.2,
        });
        h.window(WindowRow {
            worker: 0,
            window: 0,
            t_c: 2.0,
            t_ar: 1.0,
            blocked_s: 0.0,
            comp_ratio: 0.0,
        });
        h.staleness(0, 1);
        h.staleness(0, 1);
        h.staleness(1, 2);
        let j = h.to_json();
        let windows = j.get("windows").and_then(Json::as_arr).unwrap();
        assert_eq!(windows[0].get("worker").and_then(Json::as_f64), Some(0.0));
        assert_eq!(windows[1].get("worker").and_then(Json::as_f64), Some(1.0));
        assert_eq!(
            j.get("overlap_efficiency_mean").and_then(Json::as_f64),
            Some((1.0 + 0.75) / 2.0)
        );
        assert_eq!(j.get("comp_ratio_mean").and_then(Json::as_f64), Some(0.2));
        let ranks = j.get("ranks").and_then(Json::as_arr).unwrap();
        assert_eq!(ranks.len(), 2);
        assert_eq!(ranks[0].get("t_c_mean").and_then(Json::as_f64), Some(2.0));
        let stale = j.get("staleness").and_then(Json::as_arr).unwrap();
        assert_eq!(stale.len(), 2);
        assert_eq!(stale[0].get("count").and_then(Json::as_f64), Some(2.0));
        let dropped = j.get("journal").and_then(|x| x.get("dropped"));
        assert_eq!(dropped.and_then(Json::as_f64), Some(0.0));
    }

    #[test]
    fn hub_to_json_is_stable_across_export_calls() {
        let h = hub(16);
        h.record(EventKind::RoundPosted, 0, 0, 0.5, 0.5, "");
        h.window(WindowRow {
            worker: 0,
            window: 0,
            t_c: 1.0,
            t_ar: 0.5,
            blocked_s: 0.1,
            comp_ratio: 0.05,
        });
        assert_eq!(h.to_json().to_string(), h.to_json().to_string());
    }
}
