//! Flat f32 vector math — the rust-side compute primitives.
//!
//! Everything the coordinator does to parameters (optimizer updates,
//! delay compensation, reductions) operates on flat `&[f32]` buffers,
//! mirroring the paper's KV-store view of the weights. The elementwise
//! kernels walk the buffers in exact-width chunks (the engine's
//! [`crate::exec::pin_chunk`] hint) so LLVM sees fixed trip counts and
//! bounds checks vanish from the inner loops; the fused kernels exist
//! so the hot path touches each element once (see EXPERIMENTS.md §Perf
//! for the fused-vs-naive measurements).
//!
//! **Determinism**: chunking here is purely elementwise blocking — no
//! kernel changes its per-element evaluation order or introduces a
//! width-dependent reduction tree, so every `pin_chunk` setting is
//! bit-identical. Reductions (`dot`, `lambda_norms`, …) pin their own
//! lane counts independently of the hint.

/// `y += alpha * x` (BLAS axpy).
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    let w = crate::exec::pin_chunk();
    let mut yc = y.chunks_exact_mut(w);
    let mut xc = x.chunks_exact(w);
    for (yb, xb) in (&mut yc).zip(&mut xc) {
        for (yi, xi) in yb.iter_mut().zip(xb) {
            *yi += alpha * xi;
        }
    }
    for (yi, xi) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yi += alpha * xi;
    }
}

/// `y = alpha * x + beta * y`.
pub fn axpby(alpha: f32, x: &[f32], beta: f32, y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    let w = crate::exec::pin_chunk();
    let mut yc = y.chunks_exact_mut(w);
    let mut xc = x.chunks_exact(w);
    for (yb, xb) in (&mut yc).zip(&mut xc) {
        for (yi, xi) in yb.iter_mut().zip(xb) {
            *yi = alpha * xi + beta * *yi;
        }
    }
    for (yi, xi) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yi = alpha * xi + beta * *yi;
    }
}

/// Elementwise sum into `acc`.
pub fn add_assign(acc: &mut [f32], x: &[f32]) {
    assert_eq!(acc.len(), x.len());
    let w = crate::exec::pin_chunk();
    let mut ac = acc.chunks_exact_mut(w);
    let mut xc = x.chunks_exact(w);
    for (ab, xb) in (&mut ac).zip(&mut xc) {
        for (a, b) in ab.iter_mut().zip(xb) {
            *a += b;
        }
    }
    for (a, b) in ac.into_remainder().iter_mut().zip(xc.remainder()) {
        *a += b;
    }
}

/// Scale in place.
pub fn scale(x: &mut [f32], alpha: f32) {
    let w = crate::exec::pin_chunk();
    let mut xc = x.chunks_exact_mut(w);
    for xb in &mut xc {
        for v in xb.iter_mut() {
            *v *= alpha;
        }
    }
    for v in xc.into_remainder().iter_mut() {
        *v *= alpha;
    }
}

/// Dot product (f64 accumulator for stability on large vectors).
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| *a as f64 * *b as f64).sum()
}

/// Euclidean norm.
pub fn norm2(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

/// Norm of the DC correction term `g ⊙ g ⊙ d` without materializing it
/// (single fused pass; the denominator of Eq. 17).
pub fn corr_norm(g: &[f32], d: &[f32]) -> f64 {
    assert_eq!(g.len(), d.len());
    g.iter()
        .zip(d)
        .map(|(gi, di)| {
            let c = (*gi as f64) * (*gi as f64) * (*di as f64);
            c * c
        })
        .sum::<f64>()
        .sqrt()
}

/// Both Eq. 17 reductions — `(‖g‖, ‖g⊙g⊙d‖)` — in ONE pass over (g, d)
/// instead of two (§Perf iteration: the separate `norm2` + `corr_norm`
/// passes were ~1/3 of the whole fused-update cost at CNN sizes).
/// Accumulates in f32 lanes (4-way partial sums so LLVM vectorizes) and
/// widens to f64 at the end; relative error vs the f64 path is < 1e-6
/// for training-scale vectors (asserted in tests).
pub fn lambda_norms(g: &[f32], d: &[f32]) -> (f64, f64) {
    assert_eq!(g.len(), d.len());
    let mut gn = [0f64; 4];
    let mut cn = [0f64; 4];
    let chunks = g.len() / 4;
    for i in 0..chunks {
        for lane in 0..4 {
            let idx = i * 4 + lane;
            let gi = g[idx] as f64;
            let c = gi * gi * d[idx] as f64;
            gn[lane] += gi * gi;
            cn[lane] += c * c;
        }
    }
    for idx in chunks * 4..g.len() {
        let gi = g[idx] as f64;
        let c = gi * gi * d[idx] as f64;
        gn[0] += gi * gi;
        cn[0] += c * c;
    }
    (
        (gn[0] + gn[1] + gn[2] + gn[3]).sqrt(),
        (cn[0] + cn[1] + cn[2] + cn[3]).sqrt(),
    )
}

/// Squared Euclidean distance between two vectors.
pub fn dist2(x: &[f32], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len());
    x.iter()
        .zip(y)
        .map(|(a, b)| {
            let d = (*a - *b) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

/// Mean absolute value (diagnostics).
pub fn mean_abs(x: &[f32]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    x.iter().map(|v| v.abs() as f64).sum::<f64>() / x.len() as f64
}

/// All elements finite?
pub fn all_finite(x: &[f32]) -> bool {
    x.iter().all(|v| v.is_finite())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn axpby_basic() {
        let x = [1.0, 2.0];
        let mut y = [4.0, 8.0];
        axpby(0.5, &x, 0.25, &mut y);
        assert_eq!(y, [1.5, 3.0]);
    }

    #[test]
    fn dot_and_norm() {
        let x = [3.0, 4.0];
        assert_eq!(dot(&x, &x), 25.0);
        assert_eq!(norm2(&x), 5.0);
    }

    #[test]
    fn corr_norm_matches_materialized() {
        let g = [0.5f32, -1.0, 2.0, 0.1];
        let d = [1.0f32, 0.5, -0.25, 3.0];
        let mat: Vec<f32> = g.iter().zip(&d).map(|(a, b)| a * a * b).collect();
        assert!((corr_norm(&g, &d) - norm2(&mat)).abs() < 1e-10);
    }

    #[test]
    fn lambda_norms_matches_separate_passes() {
        // includes a non-multiple-of-4 tail
        let mut rng = crate::util::Rng::new(3);
        let mut g = vec![0.0f32; 1003];
        let mut d = vec![0.0f32; 1003];
        rng.fill_normal(&mut g);
        rng.fill_normal(&mut d);
        let (gn, cn) = lambda_norms(&g, &d);
        let gn_ref = norm2(&g);
        let cn_ref = corr_norm(&g, &d);
        assert!((gn - gn_ref).abs() / gn_ref < 1e-9, "{gn} vs {gn_ref}");
        assert!((cn - cn_ref).abs() / cn_ref < 1e-9, "{cn} vs {cn_ref}");
    }

    #[test]
    fn dist2_basic() {
        assert_eq!(dist2(&[0.0, 0.0], &[3.0, 4.0]), 5.0);
    }

    #[test]
    fn finite_detection() {
        assert!(all_finite(&[1.0, -2.0]));
        assert!(!all_finite(&[1.0, f32::NAN]));
        assert!(!all_finite(&[f32::INFINITY]));
    }

    #[test]
    #[should_panic]
    fn length_mismatch_panics() {
        let mut y = [0.0];
        axpy(1.0, &[1.0, 2.0], &mut y);
    }

    #[test]
    fn kernels_bit_identical_across_pin_chunk_widths() {
        // The determinism contract: pin_chunk is a layout hint, never a
        // semantic knob. Includes a width larger than the buffer (whole
        // vector lands in the remainder path).
        let _g = crate::exec::PIN_CHUNK_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let mut rng = crate::util::Rng::new(7);
        let mut x = vec![0.0f32; 517];
        let mut y0 = vec![0.0f32; 517];
        rng.fill_normal(&mut x);
        rng.fill_normal(&mut y0);
        let run = |w: usize| {
            crate::exec::set_pin_chunk(w);
            let mut y = y0.clone();
            axpy(0.3, &x, &mut y);
            axpby(0.7, &x, -0.2, &mut y);
            add_assign(&mut y, &x);
            scale(&mut y, 1.1);
            crate::exec::set_pin_chunk(0);
            y
        };
        let base = run(1);
        for w in [2usize, 8, 64, 4096] {
            let got = run(w);
            let same = got.iter().zip(&base).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "pin_chunk={w} diverged");
        }
    }
}
