//! Synthetic image-classification dataset — the ImageNet-1k stand-in.
//!
//! Deterministic, procedurally generated, O(1) random access (no
//! storage): sample `i` is a function of `(seed, split, i)` only, so
//! every worker can materialize exactly its shard with no data motion —
//! mirroring how the paper shards ImageNet across workers (§I: "each
//! replica is trained on a subset of the training data set").
//!
//! Construction per class `c`:
//! * a fixed smooth **prototype** pattern `P_c` (mixture of a few
//!   seeded 2-D cosine gratings + a Gaussian blob at a class-specific
//!   location) — the learnable signal;
//! * per sample: random translation of `P_c`, per-sample contrast scale,
//!   plus i.i.d. Gaussian pixel noise — the nuisance variability.
//!
//! With the default SNR a linear model reaches mid-60s% accuracy and the
//! CNNs >90%, leaving a meaningful train/val gap — enough structure for
//! the convergence phenomena under study (large-batch degradation,
//! staleness error) to show.

use crate::util::Rng;

/// Dataset splits (disjoint RNG streams).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
}

impl Split {
    fn stream(self) -> u64 {
        match self {
            Split::Train => 0x7261_494e,
            Split::Val => 0x5641_4c30,
        }
    }
}

/// Synthetic dataset descriptor. Cheap to clone; samples are generated
/// on demand.
#[derive(Debug, Clone)]
pub struct SyntheticDataset {
    pub seed: u64,
    /// Image side (square, 3 channels).
    pub hw: usize,
    pub num_classes: usize,
    pub n_train: usize,
    pub n_val: usize,
    /// Pixel noise std relative to signal (default 0.6).
    pub noise: f32,
    /// Max translation in pixels (default hw/4).
    pub max_shift: usize,
    /// Class prototypes, materialized once: `[class][h*w*3]`.
    prototypes: Vec<Vec<f32>>,
}

impl SyntheticDataset {
    pub fn new(seed: u64, hw: usize, num_classes: usize, n_train: usize, n_val: usize) -> Self {
        let noise = 0.6;
        let max_shift = hw / 4;
        let prototypes = (0..num_classes)
            .map(|c| Self::make_prototype(seed, c, hw))
            .collect();
        SyntheticDataset { seed, hw, num_classes, n_train, n_val, noise, max_shift, prototypes }
    }

    /// Sized to match an artifact's input metadata.
    pub fn for_model(seed: u64, hw: usize, num_classes: usize) -> Self {
        // Default corpus: 8192 train / 1024 val samples — large enough
        // that a 64-sample-per-worker batch regime is "small batch" and
        // a 2048 global batch is "large batch" relative to the corpus,
        // bracketing the paper's |B|/|X| ratios (16k/1.28M .. 128k/1.28M).
        SyntheticDataset::new(seed, hw, num_classes, 8192, 1024)
    }

    pub fn with_noise(mut self, noise: f32) -> Self {
        self.noise = noise;
        self
    }

    fn len(&self, split: Split) -> usize {
        match split {
            Split::Train => self.n_train,
            Split::Val => self.n_val,
        }
    }

    fn make_prototype(seed: u64, class: usize, hw: usize) -> Vec<f32> {
        let mut rng = Rng::keyed(seed, 0x5052_4f54, class as u64);
        let mut img = vec![0.0f32; hw * hw * 3];
        // 3 cosine gratings with class-specific frequency/phase/channel mix
        for _ in 0..3 {
            let fx = rng.uniform_range(0.5, 3.0) * std::f32::consts::TAU / hw as f32;
            let fy = rng.uniform_range(0.5, 3.0) * std::f32::consts::TAU / hw as f32;
            let phase = rng.uniform_range(0.0, std::f32::consts::TAU);
            let cmix = [rng.normal(), rng.normal(), rng.normal()];
            for y in 0..hw {
                for x in 0..hw {
                    let v = (fx * x as f32 + fy * y as f32 + phase).cos();
                    for (ch, m) in cmix.iter().enumerate() {
                        img[(y * hw + x) * 3 + ch] += 0.5 * v * m;
                    }
                }
            }
        }
        // Gaussian blob at a class-specific location
        let cx = rng.uniform_range(0.25, 0.75) * hw as f32;
        let cy = rng.uniform_range(0.25, 0.75) * hw as f32;
        let sigma = hw as f32 / 6.0;
        let amp = [rng.normal(), rng.normal(), rng.normal()];
        for y in 0..hw {
            for x in 0..hw {
                let d2 = (x as f32 - cx).powi(2) + (y as f32 - cy).powi(2);
                let g = (-d2 / (2.0 * sigma * sigma)).exp();
                for (ch, a) in amp.iter().enumerate() {
                    img[(y * hw + x) * 3 + ch] += g * a;
                }
            }
        }
        // normalize prototype to unit RMS
        let rms = (img.iter().map(|v| (v * v) as f64).sum::<f64>() / img.len() as f64)
            .sqrt()
            .max(1e-6) as f32;
        img.iter_mut().for_each(|v| *v /= rms);
        img
    }

    /// Generate sample `index` of `split`: writes `hw*hw*3` floats
    /// (NHWC layout for a single sample) and returns the label.
    pub fn sample_into(&self, split: Split, index: usize, out: &mut [f32]) -> i32 {
        assert!(index < self.len(split), "index {index} out of range");
        let px = self.hw * self.hw * 3;
        assert_eq!(out.len(), px);
        let mut rng = Rng::keyed(self.seed, split.stream(), index as u64);
        let label = rng.below(self.num_classes as u64) as usize;
        let proto = &self.prototypes[label];
        let shift = self.max_shift as i64;
        let dx = rng.below((2 * shift + 1) as u64) as i64 - shift;
        let dy = rng.below((2 * shift + 1) as u64) as i64 - shift;
        let contrast = 0.7 + 0.6 * rng.uniform() as f32;
        let hw = self.hw as i64;
        for y in 0..hw {
            let sy = (y + dy).rem_euclid(hw) as usize;
            for x in 0..hw {
                let sx = (x + dx).rem_euclid(hw) as usize;
                let src = (sy * self.hw + sx) * 3;
                let dst = ((y * hw + x) * 3) as usize;
                for ch in 0..3 {
                    out[dst + ch] =
                        contrast * proto[src + ch] + self.noise * rng.normal();
                }
            }
        }
        label as i32
    }

    /// Materialize a batch of samples by global indices into NHWC-flat
    /// `x` (len = batch·hw·hw·3) and labels `y`.
    pub fn batch_into(&self, split: Split, indices: &[usize], x: &mut [f32], y: &mut [i32]) {
        let px = self.hw * self.hw * 3;
        assert_eq!(x.len(), indices.len() * px);
        assert_eq!(y.len(), indices.len());
        for (b, &idx) in indices.iter().enumerate() {
            y[b] = self.sample_into(split, idx, &mut x[b * px..(b + 1) * px]);
        }
    }
}

/// Per-worker shard iterator: worker `rank` of `n_ranks` draws batches
/// from its contiguous-stride shard of the train split, reshuffled each
/// epoch with a deterministic epoch-keyed permutation.
///
/// Under elastic membership the shard key is a *slot* (position in the
/// member list), not a raw rank id: [`ShardSampler::reshard`]
/// re-partitions the full sample space across the new world size at a
/// membership-epoch boundary, deterministically — shard `i` of `W`
/// always covers indices `i, i+W, i+2W, …`, and the membership epoch
/// salts the permutation so the new partition reshuffles afresh while
/// staying a pure function of `(seed, slot, world, membership epoch)`.
#[derive(Debug)]
pub struct ShardSampler {
    ds_seed: u64,
    rank: usize,
    n_ranks: usize,
    n_train: usize,
    batch: usize,
    /// Membership-epoch salt mixed into the permutation key (0 for the
    /// launch partition).
    salt: u64,
    /// Current epoch's shuffled index order for this shard.
    order: Vec<usize>,
    cursor: usize,
    epoch: u64,
}

impl ShardSampler {
    pub fn new(ds: &SyntheticDataset, rank: usize, n_ranks: usize, batch: usize) -> Self {
        Self::for_shard(ds, rank, n_ranks, batch, 0)
    }

    /// A sampler over shard `shard` of `world`, salted by a membership
    /// epoch (0 = the launch partition, identical to
    /// [`ShardSampler::new`]).
    pub fn for_shard(
        ds: &SyntheticDataset,
        shard: usize,
        world: usize,
        batch: usize,
        membership_epoch: u64,
    ) -> Self {
        assert!(shard < world);
        let mut s = ShardSampler {
            ds_seed: ds.seed,
            rank: shard,
            n_ranks: world,
            n_train: ds.n_train,
            batch,
            salt: Self::salt_of(membership_epoch),
            order: Vec::new(),
            cursor: 0,
            epoch: 0,
        };
        s.reshuffle();
        s
    }

    fn salt_of(membership_epoch: u64) -> u64 {
        membership_epoch.wrapping_mul(0x9E37_79B9_97F4_A7C5)
    }

    /// Re-partition across a new world at a membership-epoch boundary:
    /// this sampler becomes shard `shard` of `world`, restarting its
    /// data-epoch count with an epoch-salted permutation. Every member
    /// calling this with its slot partitions the identical remaining
    /// sample space (see [`ShardSampler::shard_indices`]).
    pub fn reshard(&mut self, shard: usize, world: usize, membership_epoch: u64) {
        assert!(shard < world);
        self.rank = shard;
        self.n_ranks = world;
        self.salt = Self::salt_of(membership_epoch);
        self.epoch = 0;
        self.reshuffle();
    }

    /// Indices `rank, rank+n_ranks, rank+2·n_ranks, ...` (strided shard —
    /// every worker sees a class-balanced-in-expectation subset).
    fn shard_indices(&self) -> Vec<usize> {
        (self.rank..self.n_train).step_by(self.n_ranks).collect()
    }

    fn reshuffle(&mut self) {
        self.order = self.shard_indices();
        let mut rng =
            Rng::keyed(self.ds_seed ^ 0x5348_5546 ^ self.salt, self.rank as u64, self.epoch);
        rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    /// Samples processed per epoch by this worker.
    pub fn shard_len(&self) -> usize {
        self.order.len()
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Next batch of indices (wraps to a new epoch when exhausted;
    /// short final batches are folded into the next epoch, matching the
    /// common drop-last convention).
    pub fn next_batch(&mut self) -> Vec<usize> {
        if self.cursor + self.batch > self.order.len() {
            self.epoch += 1;
            self.reshuffle();
        }
        let out = self.order[self.cursor..self.cursor + self.batch].to_vec();
        self.cursor += self.batch;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    fn small() -> SyntheticDataset {
        SyntheticDataset::new(42, 8, 4, 64, 16)
    }

    #[test]
    fn deterministic_samples() {
        let ds = small();
        let px = 8 * 8 * 3;
        let mut a = vec![0.0; px];
        let mut b = vec![0.0; px];
        let la = ds.sample_into(Split::Train, 7, &mut a);
        let lb = ds.sample_into(Split::Train, 7, &mut b);
        assert_eq!(la, lb);
        assert_eq!(a, b);
    }

    #[test]
    fn splits_differ() {
        let ds = small();
        let px = 8 * 8 * 3;
        let mut a = vec![0.0; px];
        let mut b = vec![0.0; px];
        ds.sample_into(Split::Train, 3, &mut a);
        ds.sample_into(Split::Val, 3, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn labels_cover_all_classes() {
        let ds = small();
        let px = 8 * 8 * 3;
        let mut buf = vec![0.0; px];
        let mut seen = HashSet::new();
        for i in 0..64 {
            seen.insert(ds.sample_into(Split::Train, i, &mut buf));
        }
        assert_eq!(seen.len(), 4);
    }

    #[test]
    fn signal_is_class_separable() {
        // nearest-prototype classification on noiseless-ish samples must
        // beat chance by a wide margin — i.e. the dataset is learnable.
        let ds = SyntheticDataset::new(1, 8, 4, 256, 0).with_noise(0.3);
        let px = 8 * 8 * 3;
        let mut buf = vec![0.0; px];
        let mut correct = 0;
        for i in 0..256 {
            let label = ds.sample_into(Split::Train, i, &mut buf);
            // translation-invariant-ish match: correlation over all shifts
            // is overkill; use max correlation over the 2 shifts tested
            let mut best = (f64::NEG_INFINITY, -1i32);
            for (c, proto) in ds.prototypes.iter().enumerate() {
                // max abs correlation over all cyclic shifts would be
                // ideal; plain dot works because contrast > 0.
                let mut m = f64::NEG_INFINITY;
                for dy in 0..8i64 {
                    for dx in 0..8i64 {
                        let mut dot = 0f64;
                        for y in 0..8i64 {
                            for x in 0..8i64 {
                                let sy = ((y + dy).rem_euclid(8)) as usize;
                                let sx = ((x + dx).rem_euclid(8)) as usize;
                                for ch in 0..3 {
                                    dot += buf[((y * 8 + x) * 3) as usize + ch] as f64
                                        * proto[(sy * 8 + sx) * 3 + ch] as f64;
                                }
                            }
                        }
                        m = m.max(dot);
                    }
                }
                if m > best.0 {
                    best = (m, c as i32);
                }
            }
            if best.1 == label {
                correct += 1;
            }
        }
        let acc = correct as f64 / 256.0;
        assert!(acc > 0.6, "nearest-prototype acc {acc} ≤ chance-ish");
    }

    #[test]
    fn shards_partition_the_corpus() {
        let ds = small();
        let mut all: Vec<usize> = Vec::new();
        for rank in 0..4 {
            let s = ShardSampler::new(&ds, rank, 4, 4);
            all.extend(s.shard_indices());
        }
        all.sort_unstable();
        assert_eq!(all, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn sampler_epoch_boundary_and_coverage() {
        let ds = small();
        let mut s = ShardSampler::new(&ds, 1, 4, 4); // shard of 16, batch 4
        assert_eq!(s.shard_len(), 16);
        let mut seen = HashSet::new();
        for _ in 0..4 {
            for i in s.next_batch() {
                assert!(seen.insert(i), "duplicate within epoch");
            }
        }
        assert_eq!(seen.len(), 16);
        assert_eq!(s.epoch(), 0);
        let _ = s.next_batch();
        assert_eq!(s.epoch(), 1);
    }

    #[test]
    fn epochs_reshuffle() {
        let ds = small();
        let mut s = ShardSampler::new(&ds, 0, 1, 64);
        let e0 = s.next_batch();
        let e1 = s.next_batch();
        assert_ne!(e0, e1);
        let mut a = e0.clone();
        let mut b = e1.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b); // same set, different order
    }

    #[test]
    fn reshard_partitions_the_new_world_deterministically() {
        let ds = small(); // 64 train samples
        // 4-way launch partition shrinks to 3 ways: the three reshard
        // slots must re-cover the full corpus exactly once per epoch.
        let mut all: Vec<usize> = Vec::new();
        for slot in 0..3 {
            let mut s = ShardSampler::new(&ds, slot, 4, 4);
            s.reshard(slot, 3, 1);
            all.extend(s.shard_indices());
        }
        all.sort_unstable();
        assert_eq!(all, (0..64).collect::<Vec<_>>(), "reshard must re-partition the corpus");
        // deterministic: two samplers resharded identically draw the
        // same batches regardless of their launch shard
        let mut a = ShardSampler::new(&ds, 0, 4, 4);
        let mut b = ShardSampler::new(&ds, 1, 4, 4);
        a.reshard(2, 3, 5);
        b.reshard(2, 3, 5);
        assert_eq!(a.next_batch(), b.next_batch());
        assert_eq!(a.shard_len(), 21); // indices 2, 5, …, 62
        // a different membership epoch draws the same shard set in a
        // different order
        let mut d5 = ShardSampler::for_shard(&ds, 2, 3, 4, 5);
        let mut d6 = ShardSampler::for_shard(&ds, 2, 3, 4, 6);
        let (x, y) = (d5.next_batch(), d6.next_batch());
        assert_ne!(x, y, "membership-epoch salt must reshuffle the shard");
        for i in x.iter().chain(&y) {
            assert_eq!(i % 3, 2, "both epochs draw from the same shard set");
        }
    }

    #[test]
    fn for_shard_epoch_zero_matches_new() {
        let ds = small();
        let mut a = ShardSampler::new(&ds, 1, 4, 4);
        let mut b = ShardSampler::for_shard(&ds, 1, 4, 4, 0);
        for _ in 0..6 {
            assert_eq!(a.next_batch(), b.next_batch());
        }
    }

    #[test]
    fn batch_into_layout() {
        let ds = small();
        let px = 8 * 8 * 3;
        let idx = [0usize, 5, 9];
        let mut x = vec![0.0; 3 * px];
        let mut y = vec![0i32; 3];
        ds.batch_into(Split::Train, &idx, &mut x, &mut y);
        let mut single = vec![0.0; px];
        let l = ds.sample_into(Split::Train, 5, &mut single);
        assert_eq!(y[1], l);
        assert_eq!(&x[px..2 * px], &single[..]);
    }
}
