//! Engine-facing facade over the sharded, replicated, compressed PS.
//!
//! The run bodies (`algo/psasync.rs`) talk to [`PsTier`]: it owns the
//! [`ShardedPs`] substrate plus one [`WindowCodec`] per worker, so
//! compression, replication routing and membership epochs compose in
//! one place. The codec threading mirrors `algo/dcs3gd.rs` exactly:
//!
//! * a push **encodes** the worker's gradient (error-feedback residual
//!   folds rank-locally), the transfer is priced at
//!   [`WindowCodec::wire_elems`] — the compressed volume plus control
//!   tail — and the tier ingress **decodes** with the sender's own
//!   codec before the shard applies DC-ASGD's Eq. 6 over the
//!   *decompressed* payload, so compensation and compression stack the
//!   same way the decentralized engines stack them;
//! * a pull rides the same operating point: the reply is delta-encoded
//!   against the puller's last refresh, so its wire volume is the
//!   codec's — the weights themselves stay exact (the modeled wire
//!   and the simulated arithmetic are priced separately, as
//!   everywhere else in the timing model).
//!
//! Wire accounting (compressed vs dense bytes, per-leg) accumulates in
//! the tier and ships in the run JSON's `"ps"` block next to the shard
//! actors' service counters.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::comm::NetModel;
use crate::compress::{CompressConfig, WindowCodec};
use crate::exec::Gate;
use crate::optim::Optimizer;
use crate::ps::{PsMode, PullReply, ReplicaPlan, ShardedPs};
use crate::util::Json;

/// Construction parameters for the tier (everything the engines derive
/// from [`crate::config::ExperimentConfig`]).
pub struct PsTierSpec {
    pub n_shards: usize,
    pub mode: PsMode,
    pub net: NetModel,
    /// Per-element service time at each shard (CPU/NIC model).
    pub serve_s_per_elem: f64,
    pub compress: CompressConfig,
    /// Seed keying the per-worker codecs (sparsity draws).
    pub seed: u64,
    /// Highest worker rank (joiners included) + 1.
    pub capacity: usize,
    pub plan: ReplicaPlan,
}

/// Monotone wire-volume counters, one value per transfer leg.
#[derive(Default)]
struct TierCounters {
    pushes: AtomicU64,
    pulls: AtomicU64,
    wire_bytes: AtomicU64,
    dense_bytes: AtomicU64,
}

/// The running tier; `client(rank)` hands each worker its codec-backed
/// handle, `shutdown()` collects final weights + the `"ps"` JSON block.
pub struct PsTier {
    ps: ShardedPs,
    n: usize,
    compress: CompressConfig,
    seed: u64,
    spec_shards: usize,
    spec_replicas: usize,
    coalesce: bool,
    epochs: usize,
    counters: Arc<TierCounters>,
}

impl PsTier {
    /// Spawn the shard actors. `opt_for` builds each shard's optimizer
    /// from its slice bounds (the engines pass the configured optimizer
    /// for the single-shard case and per-slice momentum otherwise).
    pub fn spawn(
        init_w: &[f32],
        spec: PsTierSpec,
        opt_for: &mut dyn FnMut(usize, usize) -> Box<dyn Optimizer>,
    ) -> Self {
        let ps = ShardedPs::spawn_replicated(
            init_w,
            opt_for,
            spec.capacity,
            spec.n_shards,
            spec.mode,
            spec.net,
            spec.serve_s_per_elem,
            &spec.plan,
        );
        PsTier {
            ps,
            n: init_w.len(),
            compress: spec.compress,
            seed: spec.seed,
            spec_shards: spec.n_shards,
            spec_replicas: spec.plan.n_replicas(),
            coalesce: spec.plan.coalesce,
            epochs: spec.plan.rosters.len(),
            counters: Arc::new(TierCounters::default()),
        }
    }

    /// A worker's handle: its own codec (rank-keyed residual), shared
    /// shard substrate. Callers rebind to their (slot, world) before
    /// the first push — exactly like the decentralized engines.
    pub fn client(&self, rank: usize) -> PsTierClient<'_> {
        PsTierClient {
            tier: self,
            codec: WindowCodec::new(&self.compress, self.n, self.seed, rank),
            dense: vec![0.0f32; self.n],
            own: vec![0.0f32; self.n],
            gate: Gate::unlimited(),
        }
    }

    pub fn n_params(&self) -> usize {
        self.n
    }

    fn count(&self, pushes: u64, pulls: u64, wire_legs: u64, wire_bytes: f64) {
        let c = &self.counters;
        c.pushes.fetch_add(pushes, Ordering::Relaxed);
        c.pulls.fetch_add(pulls, Ordering::Relaxed);
        c.wire_bytes.fetch_add((wire_bytes * wire_legs as f64) as u64, Ordering::Relaxed);
        c.dense_bytes.fetch_add(wire_legs * 4 * self.n as u64, Ordering::Relaxed);
    }

    /// Stop the shards; returns (final weights, update count, the run
    /// JSON `"ps"` block).
    pub fn shutdown(self) -> (Vec<f32>, u64, Json) {
        let c = self.counters.clone();
        let compress = self.compress;
        let (shards, replicas, coalesce, epochs) =
            (self.spec_shards, self.spec_replicas, self.coalesce, self.epochs);
        let (w, updates, stats) = self.ps.shutdown_full();
        let wire = c.wire_bytes.load(Ordering::Relaxed);
        let dense = c.dense_bytes.load(Ordering::Relaxed);
        let mut m = std::collections::BTreeMap::new();
        m.insert("enabled".into(), Json::Bool(true));
        m.insert("shards".into(), Json::Num(shards as f64));
        m.insert("replicas".into(), Json::Num(replicas as f64));
        m.insert("coalesce".into(), Json::Bool(coalesce));
        m.insert("epochs".into(), Json::Num(epochs as f64));
        m.insert("compress".into(), Json::Str(compress.kind.name().into()));
        m.insert("pushes".into(), Json::Num(stats.pushes as f64));
        m.insert("pulls".into(), Json::Num(stats.pulls as f64));
        m.insert("coalesced".into(), Json::Num(stats.coalesced as f64));
        m.insert("repl_transfers".into(), Json::Num(stats.repl_transfers as f64));
        m.insert("updates".into(), Json::Num(updates as f64));
        m.insert("wire_bytes".into(), Json::Num(wire as f64));
        m.insert("dense_bytes".into(), Json::Num(dense as f64));
        m.insert(
            "wire_cut_x".into(),
            Json::Num(if wire > 0 { dense as f64 / wire as f64 } else { 1.0 }),
        );
        (w, updates, Json::Obj(m))
    }
}

/// Per-worker handle: codec + scratch + the pool gate.
pub struct PsTierClient<'a> {
    tier: &'a PsTier,
    codec: WindowCodec,
    dense: Vec<f32>,
    own: Vec<f32>,
    gate: Arc<Gate>,
}

impl PsTierClient<'_> {
    /// Plug the engine pool's execution [`Gate`] in: the permit is
    /// released across the blocking shard round-trips.
    pub fn set_gate(&mut self, gate: Arc<Gate>) {
        self.gate = gate;
    }

    /// Epoch transition: rebind the codec to this worker's new
    /// (slot, world) — zeroes the error-feedback residual, the same
    /// contract as the decentralized engines' `codec.rebind`.
    pub fn rebind(&mut self, slot: usize, world: usize) {
        self.codec.rebind(slot, world);
    }

    /// The codec's current compressed wire volume (elements, control
    /// tail included).
    pub fn wire_elems(&self) -> usize {
        self.codec.wire_elems()
    }

    pub fn codec_name(&self) -> &'static str {
        self.codec.name()
    }

    /// Compressed push + pull round trip. The gradient is encoded
    /// (residual folds), priced at the compressed wire volume, decoded
    /// at tier ingress with this sender's codec (bitwise-exact
    /// decompression), and the shards apply Eq. 6 over the
    /// *decompressed* payload.
    pub fn push_pull(
        &mut self,
        worker: usize,
        grad: &[f32],
        now: f64,
        eta: f32,
        wd: f32,
    ) -> PullReply {
        let payload = self.codec.encode(grad, 0.0, 0.0, &mut self.own);
        // Tier-ingress decode: one contributor, the sender itself.
        self.dense.fill(0.0);
        self.codec.decode(&payload, 1, &mut self.dense);
        let wire = self.codec.wire_elems();
        self.tier.count(1, 0, 2, self.codec.wire_bytes());
        self.gate.release();
        let r = self.tier.ps.push_pull_wire(worker, &self.dense, now, eta, wd, wire);
        self.gate.acquire();
        r
    }

    /// Compressed-volume weight read (joiner bootstrap / refresh): the
    /// reply is delta-encoded at the codec's operating point, so the
    /// wire leg is priced at `wire_elems`; the weights stay exact.
    pub fn pull(&mut self, worker: usize, now: f64) -> PullReply {
        let wire = self.codec.wire_elems();
        self.tier.count(0, 1, 1, self.codec.wire_bytes());
        self.gate.release();
        let r = self.tier.ps.pull(worker, now, wire);
        self.gate.acquire();
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CompressorKind;
    use crate::optim::MomentumSgd;
    use crate::ps::ParameterServer;

    fn spec(n_workers: usize, compress: CompressConfig) -> PsTierSpec {
        PsTierSpec {
            n_shards: 2,
            mode: PsMode::DcAsgd { lam0: 0.2 },
            net: NetModel::instant(),
            serve_s_per_elem: 0.0,
            compress,
            seed: 7,
            capacity: n_workers,
            plan: ReplicaPlan::single_home(n_workers),
        }
    }

    #[test]
    fn identity_codec_tier_matches_raw_sharded_ps() {
        // With the identity codec the tier's decode(encode(g)) is g
        // itself: the trajectory must equal a raw dense PS bitwise.
        // Adaptive-λ is fully elementwise, so sharding cannot perturb
        // the correction (unlike Eq. 17's global-norm λ).
        let init = vec![0.4f32; 64];
        let raw = ParameterServer::spawn(
            init.clone(),
            Box::new(MomentumSgd::new(64, 0.0)),
            2,
            PsMode::DcAsgdAdaptive { lam0: 0.2 },
            NetModel::instant(),
            0.0,
        );
        let rc = raw.client();
        let mut tier_spec = spec(2, CompressConfig::default());
        tier_spec.mode = PsMode::DcAsgdAdaptive { lam0: 0.2 };
        let tier = PsTier::spawn(&init, tier_spec, &mut |lo, hi| {
            Box::new(MomentumSgd::new(hi - lo, 0.0))
        });
        let mut tc = tier.client(0);
        for it in 0..5 {
            let g: Vec<f32> = (0..64).map(|i| 0.01 * ((i + it) as f32)).collect();
            let a = rc.push_pull(it % 2, g.clone(), it as f64, 0.2, 0.0);
            let b = tc.push_pull(it % 2, &g, it as f64, 0.2, 0.0);
            assert_eq!(a.weights, b.weights, "iter {it}");
        }
        raw.shutdown();
        let (_, updates, json) = tier.shutdown();
        assert_eq!(updates, 2 * 5); // 2 shards × 5 pushes
        assert_eq!(json.get("enabled"), Some(&Json::Bool(true)));
        assert_eq!(json.get("wire_cut_x").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn compressed_push_applies_exactly_the_decoded_payload() {
        // Mirror-codec differential: a client-side replica of the
        // worker's codec (same seed, same rank) must predict the tier's
        // weight trajectory bitwise — i.e. the tier applies *exactly*
        // the decoded top-k payload and the error-feedback residual
        // telescopes through the PS path the same as the decentralized
        // one.
        let compress =
            CompressConfig { kind: CompressorKind::TopK, ratio: 0.1, ..Default::default() };
        let init = vec![0.5f32; 500];
        let mut tier_spec = spec(1, compress);
        tier_spec.mode = PsMode::Asgd;
        let tier = PsTier::spawn(&init, tier_spec, &mut |lo, hi| {
            Box::new(MomentumSgd::new(hi - lo, 0.0))
        });
        let mut c = tier.client(0);
        c.rebind(0, 1);
        let mut mirror = WindowCodec::new(&compress, 500, 7, 0);
        mirror.rebind(0, 1);
        let mut w = init.clone();
        let mut w_mirror = init;
        let mut own = vec![0.0f32; 500];
        let mut decoded = vec![0.0f32; 500];
        let eta = 0.1f32;
        for it in 0..30 {
            let g: Vec<f32> =
                (0..500).map(|i| 0.01 * ((i % 7) as f32) + 0.001 * (it + 1) as f32).collect();
            let r = c.push_pull(0, &g, it as f64, eta, 0.0);
            w = r.weights;
            let payload = mirror.encode(&g, 0.0, 0.0, &mut own);
            mirror.decode(&payload, 1, &mut decoded);
            for (wm, d) in w_mirror.iter_mut().zip(&decoded) {
                *wm -= eta * *d;
            }
            assert_eq!(w, w_mirror, "tier diverged from the mirror codec at iter {it}");
        }
        let (w_final, _, json) = tier.shutdown();
        assert_eq!(w_final, w);
        let cut = json.get("wire_cut_x").and_then(Json::as_f64).unwrap();
        assert!(cut >= 3.0, "top-k @0.1 wire cut {cut} < 3x");
    }
}
