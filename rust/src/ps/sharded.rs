//! Sharded parameter server: the paper speaks of "Parameter Servers"
//! plural — production PS deployments shard the weight vector across S
//! server processes so bandwidth and update cost parallelize. This
//! models that: S independent shard actors, each owning a contiguous
//! slice; a push/pull fans out to all shards and completes when the
//! slowest shard replies (so the many-to-few bottleneck shrinks ∝ 1/S,
//! until latency α dominates — the ablation in `benches/allreduce.rs`'s
//! companion analysis and the §II-A scaling discussion).
//!
//! Each shard carries the full [`ReplicaPlan`] with its replica host
//! list *rotated by the shard index*, so the per-epoch primaries of
//! different shards land on different physical hosts — a hot shard's
//! push traffic does not pile onto the same group as its neighbours'.
//! Transfers are priced at the caller-supplied wire volume (the
//! codec's compressed element count), split across shards in
//! proportion to their slice.

use std::sync::mpsc::channel;

use crate::comm::NetModel;
use crate::optim::{MomentumSgd, Optimizer};
use crate::ps::{ParameterServer, PsMode, PsStats, PullReply, ReplicaPlan};

/// S independent single-shard servers.
pub struct ShardedPs {
    shards: Vec<ParameterServer>,
    bounds: Vec<(usize, usize)>,
    net: NetModel,
    n: usize,
}

impl ShardedPs {
    /// Split `init_w` into `n_shards` near-equal slices, one PS each.
    /// Each shard runs the same update mode with its own momentum state
    /// (single home, pinned membership — the pre-replication shape).
    pub fn spawn(
        init_w: &[f32],
        mu: f32,
        n_workers: usize,
        n_shards: usize,
        mode: PsMode,
        net: NetModel,
        serve_s_per_elem: f64,
    ) -> Self {
        Self::spawn_replicated(
            init_w,
            &mut |lo, hi| Box::new(MomentumSgd::new(hi - lo, mu)) as Box<dyn Optimizer>,
            n_workers,
            n_shards,
            mode,
            net,
            serve_s_per_elem,
            &ReplicaPlan::single_home(n_workers),
        )
    }

    /// Spawn the sharded tier under a [`ReplicaPlan`]. `opt_for` builds
    /// each shard's optimizer from its slice bounds; `capacity` is the
    /// highest worker rank (joiners included) plus one. Shard `s` sees
    /// the plan with its replica hosts rotated by `s`, staggering the
    /// per-epoch primaries across the fabric.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn_replicated(
        init_w: &[f32],
        opt_for: &mut dyn FnMut(usize, usize) -> Box<dyn Optimizer>,
        capacity: usize,
        n_shards: usize,
        mode: PsMode,
        net: NetModel,
        serve_s_per_elem: f64,
        plan: &ReplicaPlan,
    ) -> Self {
        assert!(n_shards >= 1);
        let n = init_w.len();
        let per = n.div_ceil(n_shards);
        let mut shards = Vec::new();
        let mut bounds = Vec::new();
        for s in 0..n_shards {
            let lo = (s * per).min(n);
            let hi = ((s + 1) * per).min(n);
            if lo == hi {
                break;
            }
            bounds.push((lo, hi));
            let r = plan.hosts.len();
            let shard_plan = ReplicaPlan {
                hosts: (0..r).map(|j| plan.hosts[(j + s) % r]).collect(),
                ..plan.clone()
            };
            shards.push(ParameterServer::spawn_replicated(
                init_w[lo..hi].to_vec(),
                opt_for(lo, hi),
                capacity,
                mode,
                net,
                serve_s_per_elem * (hi - lo) as f64,
                shard_plan,
            ));
        }
        ShardedPs { shards, bounds, net, n }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Total parameter count across the shards.
    pub fn n_params(&self) -> usize {
        self.n
    }

    /// Push a full gradient; returns assembled fresh weights and the
    /// completion time = max over shards (shards are contacted in
    /// parallel, each paying its own transfer + queue). Priced at the
    /// dense payload.
    pub fn push_pull(&self, worker: usize, grad: &[f32], now: f64, eta: f32, wd: f32) -> PullReply {
        self.push_pull_wire(worker, grad, now, eta, wd, grad.len())
    }

    /// Push a full gradient with the transfer priced at `wire_elems`
    /// total (each shard carries its proportional share of the wire).
    pub fn push_pull_wire(
        &self,
        worker: usize,
        grad: &[f32],
        now: f64,
        eta: f32,
        wd: f32,
        wire_elems: usize,
    ) -> PullReply {
        assert_eq!(grad.len(), self.n);
        let mut parts: Vec<(usize, PullReply)> = Vec::with_capacity(self.shards.len());
        // Scatter concurrently: each shard client blocks on its own
        // reply, so issue from scoped threads.
        std::thread::scope(|scope| {
            let (tx, rx) = channel();
            for (i, (shard, &(lo, hi))) in self.shards.iter().zip(&self.bounds).enumerate() {
                let client = shard.client();
                let g = grad[lo..hi].to_vec();
                let wire = self.shard_wire(wire_elems, lo, hi);
                let tx = tx.clone();
                scope.spawn(move || {
                    let r = client.push_pull_wire(worker, g, now, eta, wd, wire);
                    let _ = tx.send((i, r));
                });
            }
            drop(tx);
            while let Ok(p) = rx.recv() {
                parts.push(p);
            }
        });
        self.assemble(parts, now)
    }

    /// Read fresh weights from every shard without pushing (joiner
    /// bootstrap / refresh), priced at `wire_elems` total.
    pub fn pull(&self, worker: usize, now: f64, wire_elems: usize) -> PullReply {
        let mut parts: Vec<(usize, PullReply)> = Vec::with_capacity(self.shards.len());
        std::thread::scope(|scope| {
            let (tx, rx) = channel();
            for (i, (shard, &(lo, hi))) in self.shards.iter().zip(&self.bounds).enumerate() {
                let client = shard.client();
                let wire = self.shard_wire(wire_elems, lo, hi);
                let tx = tx.clone();
                scope.spawn(move || {
                    let r = client.pull_wire(worker, now, wire);
                    let _ = tx.send((i, r));
                });
            }
            drop(tx);
            while let Ok(p) = rx.recv() {
                parts.push(p);
            }
        });
        self.assemble(parts, now)
    }

    /// A shard's proportional share of the total wire volume (≥ 1
    /// element so the α term survives the split).
    fn shard_wire(&self, wire_elems: usize, lo: usize, hi: usize) -> usize {
        (wire_elems * (hi - lo)).div_ceil(self.n).max(1)
    }

    fn assemble(&self, mut parts: Vec<(usize, PullReply)>, now: f64) -> PullReply {
        parts.sort_by_key(|(i, _)| *i);
        let mut weights = vec![0.0f32; self.n];
        let mut done_at = now;
        let mut staleness = 0.0f64;
        for ((_, r), &(lo, hi)) in parts.iter().zip(&self.bounds) {
            weights[lo..hi].copy_from_slice(&r.weights);
            done_at = done_at.max(r.done_at);
            staleness += r.staleness_dist * r.staleness_dist;
        }
        PullReply { weights, done_at, staleness_dist: staleness.sqrt() }
    }

    /// Predicted round-trip under the α-β model for a payload of `n`
    /// elements split over the shards (no queueing).
    pub fn ideal_round_trip(&self, n: usize) -> f64 {
        let per = n.div_ceil(self.shards.len().max(1));
        2.0 * self.net.ptp_time(per)
    }

    pub fn shutdown(self) -> Vec<f32> {
        self.shutdown_full().0
    }

    /// Stop every shard; returns (assembled weights, total updates,
    /// aggregated service counters).
    pub fn shutdown_full(self) -> (Vec<f32>, u64, PsStats) {
        let mut out = Vec::new();
        let mut updates = 0u64;
        let mut stats = PsStats::default();
        for (shard, &(lo, hi)) in self.shards.into_iter().zip(&self.bounds) {
            let (w, u, s) = shard.shutdown_full();
            assert_eq!(w.len(), hi - lo);
            out.extend_from_slice(&w);
            updates += u;
            stats.absorb(&s);
        }
        (out, updates, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NetModel;

    #[test]
    fn sharded_matches_single_ps_update() {
        // With 1 worker (no interleaving) sharded and single PS must
        // produce identical weights.
        let init = vec![0.5f32; 10];
        let grad = vec![0.1f32; 10];

        let single = ParameterServer::spawn(
            init.clone(),
            Box::new(MomentumSgd::new(10, 0.9)),
            1,
            PsMode::Asgd,
            NetModel::instant(),
            0.0,
        );
        let r_single = single.client().push_pull(0, grad.clone(), 0.0, 0.5, 0.0);
        let w_single = r_single.weights;
        single.shutdown();

        let sharded = ShardedPs::spawn(&init, 0.9, 1, 3, PsMode::Asgd, NetModel::instant(), 0.0);
        assert_eq!(sharded.n_shards(), 3);
        let r_sharded = sharded.push_pull(0, &grad, 0.0, 0.5, 0.0);
        assert_eq!(w_single, r_sharded.weights);
        sharded.shutdown();
    }

    #[test]
    fn more_shards_cut_service_time() {
        // serve time ∝ shard size; the max-over-shards round trip must
        // shrink as shards increase.
        let init = vec![0.0f32; 9000];
        let grad = vec![0.1f32; 9000];
        let t_for = |s: usize| {
            let ps = ShardedPs::spawn(&init, 0.0, 1, s, PsMode::Asgd, NetModel::instant(), 1e-6);
            let r = ps.push_pull(0, &grad, 0.0, 0.1, 0.0);
            ps.shutdown();
            r.done_at
        };
        let t1 = t_for(1);
        let t3 = t_for(3);
        let t9 = t_for(9);
        assert!(t3 < t1, "{t3} !< {t1}");
        assert!(t9 < t3, "{t9} !< {t3}");
        assert!((t1 / t9 - 9.0).abs() < 1.0, "expected ≈9× cut, got {}", t1 / t9);
    }

    #[test]
    fn shard_reassembly_covers_whole_vector() {
        let init: Vec<f32> = (0..13).map(|i| i as f32).collect();
        let ps = ShardedPs::spawn(&init, 0.0, 1, 4, PsMode::Asgd, NetModel::instant(), 0.0);
        // zero gradient: weights must round-trip unchanged
        let r = ps.push_pull(0, &vec![0.0; 13], 0.0, 1.0, 0.0);
        assert_eq!(r.weights, init);
        assert_eq!(ps.shutdown(), init);
    }

    #[test]
    fn shard_primaries_stagger_across_hosts() {
        // 2 shards × 2 replicas: shard 1's host list is rotated, so in
        // any epoch the two shard primaries sit on different hosts —
        // the hot-shard traffic does not converge on one group.
        let d = crate::comm::Dragonfly { groups: 2, nodes_per_group: 2, ..Default::default() };
        let net =
            NetModel { algo: crate::comm::AllReduceAlgo::Hierarchical(d), ..NetModel::default() };
        let plan = ReplicaPlan::place(2, &net, 4, false, Vec::new(), vec![vec![0, 1, 2, 3]]);
        assert_eq!(plan.hosts, vec![0, 2]);
        let rotated: Vec<usize> = (0..2).map(|j| plan.hosts[(j + 1) % 2]).collect();
        assert_eq!(rotated, vec![2, 0]);
        assert_ne!(plan.hosts[plan.primary(0)], rotated[plan.primary(0)]);
    }

    #[test]
    fn compressed_wire_split_prices_cheaper() {
        // Pricing a push at 10% wire volume must beat the dense price
        // on a bandwidth-bound fabric, sharded or not.
        let net = NetModel {
            alpha_s: 0.0,
            beta_bytes_per_s: 1e6,
            algo: crate::comm::AllReduceAlgo::Ring,
        };
        let init = vec![0.0f32; 10_000];
        let grad = vec![0.1f32; 10_000];
        let ps = ShardedPs::spawn(&init, 0.0, 1, 4, PsMode::Asgd, net, 0.0);
        let dense = ps.push_pull_wire(0, &grad, 0.0, 0.1, 0.0, 10_000).done_at;
        let topk = ps.push_pull_wire(0, &grad, 100.0, 0.1, 0.0, 1_000).done_at - 100.0;
        ps.shutdown();
        assert!(
            topk < dense / 5.0,
            "compressed wire {topk} not ≥5× cheaper than dense {dense}"
        );
    }
}
