//! Sharded parameter server: the paper speaks of "Parameter Servers"
//! plural — production PS deployments shard the weight vector across S
//! server processes so bandwidth and update cost parallelize. This
//! models that: S independent shard actors, each owning a contiguous
//! slice; a push/pull fans out to all shards and completes when the
//! slowest shard replies (so the many-to-few bottleneck shrinks ∝ 1/S,
//! until latency α dominates — the ablation in `benches/allreduce.rs`'s
//! companion analysis and the §II-A scaling discussion).

use std::sync::mpsc::channel;

use crate::comm::NetModel;
use crate::optim::MomentumSgd;
use crate::ps::{ParameterServer, PsMode, PullReply};

/// S independent single-shard servers.
pub struct ShardedPs {
    shards: Vec<ParameterServer>,
    bounds: Vec<(usize, usize)>,
    net: NetModel,
}

impl ShardedPs {
    /// Split `init_w` into `n_shards` near-equal slices, one PS each.
    /// Each shard runs the same update mode with its own momentum state.
    pub fn spawn(
        init_w: &[f32],
        mu: f32,
        n_workers: usize,
        n_shards: usize,
        mode: PsMode,
        net: NetModel,
        serve_s_per_elem: f64,
    ) -> Self {
        assert!(n_shards >= 1);
        let n = init_w.len();
        let per = n.div_ceil(n_shards);
        let mut shards = Vec::new();
        let mut bounds = Vec::new();
        for s in 0..n_shards {
            let lo = (s * per).min(n);
            let hi = ((s + 1) * per).min(n);
            if lo == hi {
                break;
            }
            bounds.push((lo, hi));
            shards.push(ParameterServer::spawn(
                init_w[lo..hi].to_vec(),
                Box::new(MomentumSgd::new(hi - lo, mu)),
                n_workers,
                mode,
                net,
                serve_s_per_elem * (hi - lo) as f64,
            ));
        }
        ShardedPs { shards, bounds, net }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Push a full gradient; returns assembled fresh weights and the
    /// completion time = max over shards (shards are contacted in
    /// parallel, each paying its own transfer + queue).
    pub fn push_pull(&self, worker: usize, grad: &[f32], now: f64, eta: f32, wd: f32) -> PullReply {
        let mut parts: Vec<(usize, PullReply)> = Vec::with_capacity(self.shards.len());
        // Scatter concurrently: each shard client blocks on its own
        // reply, so issue from scoped threads.
        std::thread::scope(|scope| {
            let (tx, rx) = channel();
            for (i, (shard, &(lo, hi))) in self.shards.iter().zip(&self.bounds).enumerate() {
                let client = shard.client();
                let g = grad[lo..hi].to_vec();
                let tx = tx.clone();
                scope.spawn(move || {
                    let r = client.push_pull(worker, g, now, eta, wd);
                    let _ = tx.send((i, r));
                });
            }
            drop(tx);
            while let Ok(p) = rx.recv() {
                parts.push(p);
            }
        });
        parts.sort_by_key(|(i, _)| *i);
        let mut weights = vec![0.0f32; grad.len()];
        let mut done_at = now;
        let mut staleness = 0.0f64;
        for ((_, r), &(lo, hi)) in parts.iter().zip(&self.bounds) {
            weights[lo..hi].copy_from_slice(&r.weights);
            done_at = done_at.max(r.done_at);
            staleness += r.staleness_dist * r.staleness_dist;
        }
        PullReply { weights, done_at, staleness_dist: staleness.sqrt() }
    }

    /// Predicted round-trip under the α-β model for a payload of `n`
    /// elements split over the shards (no queueing).
    pub fn ideal_round_trip(&self, n: usize) -> f64 {
        let per = n.div_ceil(self.shards.len().max(1));
        2.0 * self.net.ptp_time(per)
    }

    pub fn shutdown(self) -> Vec<f32> {
        let mut out = Vec::new();
        for (shard, &(lo, hi)) in self.shards.into_iter().zip(&self.bounds) {
            let (w, _) = shard.shutdown();
            assert_eq!(w.len(), hi - lo);
            out.extend_from_slice(&w);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NetModel;

    #[test]
    fn sharded_matches_single_ps_update() {
        // With 1 worker (no interleaving) sharded and single PS must
        // produce identical weights.
        let init = vec![0.5f32; 10];
        let grad = vec![0.1f32; 10];

        let single = ParameterServer::spawn(
            init.clone(),
            Box::new(MomentumSgd::new(10, 0.9)),
            1,
            PsMode::Asgd,
            NetModel::instant(),
            0.0,
        );
        let r_single = single.client().push_pull(0, grad.clone(), 0.0, 0.5, 0.0);
        let w_single = r_single.weights;
        single.shutdown();

        let sharded = ShardedPs::spawn(&init, 0.9, 1, 3, PsMode::Asgd, NetModel::instant(), 0.0);
        assert_eq!(sharded.n_shards(), 3);
        let r_sharded = sharded.push_pull(0, &grad, 0.0, 0.5, 0.0);
        assert_eq!(w_single, r_sharded.weights);
        sharded.shutdown();
    }

    #[test]
    fn more_shards_cut_service_time() {
        // serve time ∝ shard size; the max-over-shards round trip must
        // shrink as shards increase.
        let init = vec![0.0f32; 9000];
        let grad = vec![0.1f32; 9000];
        let t_for = |s: usize| {
            let ps = ShardedPs::spawn(&init, 0.0, 1, s, PsMode::Asgd, NetModel::instant(), 1e-6);
            let r = ps.push_pull(0, &grad, 0.0, 0.1, 0.0);
            ps.shutdown();
            r.done_at
        };
        let t1 = t_for(1);
        let t3 = t_for(3);
        let t9 = t_for(9);
        assert!(t3 < t1, "{t3} !< {t1}");
        assert!(t9 < t3, "{t9} !< {t3}");
        assert!((t1 / t9 - 9.0).abs() < 1.0, "expected ≈9× cut, got {}", t1 / t9);
    }

    #[test]
    fn shard_reassembly_covers_whole_vector() {
        let init: Vec<f32> = (0..13).map(|i| i as f32).collect();
        let ps = ShardedPs::spawn(&init, 0.0, 1, 4, PsMode::Asgd, NetModel::instant(), 0.0);
        // zero gradient: weights must round-trip unchanged
        let r = ps.push_pull(0, &vec![0.0; 13], 0.0, 1.0, 0.0);
        assert_eq!(r.weights, init);
        assert_eq!(ps.shutdown(), init);
    }
}
