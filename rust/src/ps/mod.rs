//! Parameter-server substrate — the *centralized* baselines.
//!
//! The paper's §II-A baselines, built so the decentralized claim can be
//! tested rather than assumed:
//!
//! * **ASGD** — workers push raw gradients; the PS applies
//!   `w ← w − η·U(g)` and returns the fresh weights.
//! * **DC-ASGD** (Zheng et al.) — the PS additionally keeps a backup
//!   `w_bak(i)` of the weights it last sent to worker `i` and corrects
//!   each incoming gradient with
//!   `g̃ = g + λ g ⊙ g ⊙ (w_ps − w_bak(i))` before applying it.
//! * **DC-ASGD adaptive-λ** — the SSP-ASGD exemplar variant: the PS
//!   keeps a per-worker EWMA of `g²` and sets λ elementwise to
//!   `λ0 / √(mse_hat + ε)`, so compensation self-scales with the
//!   gradient's recent magnitude instead of riding Eq. 17's global
//!   norm ratio.
//!
//! The PS is an actor on its own thread; workers talk to it over
//! channels. Timing follows Eq. 15: each request costs the worker
//! `t_W2PS = 2·ptp(n)` of network time plus queueing at the server
//! (service time `serve_s` per request, requests serialized) — the
//! many-to-few bottleneck the paper attributes to centralized schemes.
//!
//! Under a hierarchical (dragonfly) fabric the crossings **contend**:
//! every worker outside a PS host's group funnels through that group's
//! tapered global links, so each remote transfer is priced at the
//! *actual* concurrent-crossing count through
//! [`NetModel::ptp_time_between_flows`] (the same
//! [`crate::comm::GlobalContention`] model the collective schedules
//! use). Crossings are derived per request from the [`ReplicaPlan`]:
//! the membership-epoch roster says who is alive, the replica
//! placement says which host each puller routes to — a group-local
//! pull crosses zero optics and is priced accordingly (the PR 5
//! worst-case-crossings shortcut is gone).
//!
//! **Replication.** A [`ReplicaPlan`] places `R` replicas of each
//! shard across the fabric. The canonical weight vector lives in the
//! one shard actor — replicas model *service and placement*, not
//! divergent state, so replicated and single-home deployments are
//! bitwise identical on weights by construction (pinned in
//! `tests/ps_parity.rs`). Each membership epoch deterministically
//! elects a primary (rotation over the replica set); pushes serialize
//! at the primary, which then fans the updated weights to the
//! secondaries through the contended optics (`busy` on a secondary
//! includes the replication lag). Pulls route to a group-local replica
//! when one exists, and concurrent pulls hitting the same replica's
//! in-flight read window **coalesce** into one service slot.

pub mod sharded;
pub mod tier;
pub use sharded::ShardedPs;
pub use tier::{PsTier, PsTierClient, PsTierSpec};

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::comm::{AllReduceAlgo, NetModel};
use crate::dc;
use crate::exec::Gate;
use crate::optim::Optimizer;

/// Mode of the server's update rule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PsMode {
    /// Plain asynchronous SGD (stale, uncompensated).
    Asgd,
    /// Delay-compensated ASGD with dynamic λ (Eq. 17 applied to
    /// `D = w_ps − w_bak(i)`).
    DcAsgd { lam0: f32 },
    /// Delay-compensated ASGD with the adaptive elementwise λ of the
    /// SSP-ASGD exemplar: per-worker EWMA `mse ← 0.95·mse + 0.05·g²`
    /// with bias correction, `λ = λ0 / √(mse_hat + 1e-7)`.
    DcAsgdAdaptive { lam0: f32 },
}

/// EWMA decay of the adaptive-λ second-moment estimate.
const ADAPTIVE_BETA: f32 = 0.95;
/// Numerical floor under the adaptive-λ square root.
const ADAPTIVE_EPS: f32 = 1e-7;

/// Replica placement + membership schedule for one PS shard.
///
/// The *canonical* weights live in the shard actor; replicas are
/// timing/placement state (per-replica service queues, read windows,
/// replication lag). Everything here is a pure function of the config
/// and the scripted membership log, so both sides of a client/server
/// exchange derive identical routing without coordination.
#[derive(Debug, Clone)]
pub struct ReplicaPlan {
    /// Host rank of each replica. `hosts[0]` is the epoch-0 primary;
    /// the primary rotates deterministically per membership epoch.
    pub hosts: Vec<usize>,
    /// Coalesce pulls that land inside a replica's in-flight read
    /// window into that window's single service slot.
    pub coalesce: bool,
    /// Membership-epoch boundary times (virtual seconds, ascending).
    pub boundaries: Vec<f64>,
    /// Active worker ranks per epoch (`boundaries.len() + 1` entries).
    pub rosters: Vec<Vec<usize>>,
}

impl ReplicaPlan {
    /// The pre-replication deployment: one home, pinned membership.
    pub fn single_home(n_workers: usize) -> Self {
        ReplicaPlan {
            hosts: vec![0],
            coalesce: false,
            boundaries: Vec::new(),
            rosters: vec![(0..n_workers).collect()],
        }
    }

    /// Place `replicas` hosts round-robin across the dragonfly groups
    /// spanned by `capacity` ranks (all at rank 0 on flat fabrics,
    /// where placement is symmetric).
    pub fn place(
        replicas: usize,
        net: &NetModel,
        capacity: usize,
        coalesce: bool,
        boundaries: Vec<f64>,
        rosters: Vec<Vec<usize>>,
    ) -> Self {
        let r = replicas.max(1);
        let hosts = match net.algo {
            AllReduceAlgo::Hierarchical(d) => {
                let npg = d.nodes_per_group.max(1);
                let groups = capacity.div_ceil(npg).max(1);
                (0..r).map(|j| (j % groups) * npg).collect()
            }
            _ => vec![0; r],
        };
        assert!(!rosters.is_empty(), "a plan needs at least the epoch-0 roster");
        assert_eq!(rosters.len(), boundaries.len() + 1);
        ReplicaPlan { hosts, coalesce, boundaries, rosters }
    }

    pub fn n_replicas(&self) -> usize {
        self.hosts.len()
    }

    /// Membership epoch in force at virtual time `now` (boundaries are
    /// inclusive: a request at exactly the boundary sees the new
    /// epoch).
    pub fn epoch_at(&self, now: f64) -> usize {
        self.boundaries.partition_point(|&b| b <= now)
    }

    /// Active worker ranks in `epoch` (clamped to the last roster).
    pub fn roster(&self, epoch: usize) -> &[usize] {
        let i = epoch.min(self.rosters.len() - 1);
        &self.rosters[i]
    }

    /// Deterministic primary election: rotate over the replica set per
    /// membership epoch. Returns a replica *index* into `hosts`.
    pub fn primary(&self, epoch: usize) -> usize {
        epoch % self.hosts.len()
    }

    /// The replica a pull from `worker` routes to in `epoch`: prefer a
    /// group-local replica (zero optic crossings); spread ties — and
    /// the no-local-replica fallback — round-robin by worker rank.
    pub fn serving_replica(&self, net: &NetModel, worker: usize, epoch: usize) -> usize {
        let wg = host_group(net, worker);
        let local: Vec<usize> = (0..self.hosts.len())
            .filter(|&j| host_group(net, self.hosts[j]) == wg)
            .collect();
        if local.is_empty() {
            // no group-local replica: spread remote pulls across the
            // whole set, anchored at the epoch's primary
            (self.primary(epoch) + worker) % self.hosts.len()
        } else {
            local[worker % local.len()]
        }
    }

    /// Concurrent optic crossings a *push* shares the primary host's
    /// global links with in `epoch`: the active workers outside that
    /// host's group (everyone pushes to the primary). ≥ 1.
    pub fn push_flows(&self, net: &NetModel, epoch: usize) -> usize {
        let host = self.hosts[self.primary(epoch)];
        let hg = host_group(net, host);
        self.roster(epoch).iter().filter(|&&r| host_group(net, r) != hg).count().max(1)
    }

    /// Concurrent optic crossings a *pull* from `worker` shares its
    /// serving replica's global links with in `epoch`: the active
    /// workers routed to the same replica from outside its group — the
    /// actual crossing count, not the all-remote worst case. ≥ 1.
    pub fn pull_flows(&self, net: &NetModel, worker: usize, epoch: usize) -> usize {
        let j = self.serving_replica(net, worker, epoch);
        let hg = host_group(net, self.hosts[j]);
        self.roster(epoch)
            .iter()
            .filter(|&&r| {
                self.serving_replica(net, r, epoch) == j && host_group(net, r) != hg
            })
            .count()
            .max(1)
    }
}

/// Dragonfly group of a rank (0 on flat fabrics, where every pair
/// rides the same link model).
fn host_group(net: &NetModel, rank: usize) -> usize {
    match net.algo {
        AllReduceAlgo::Hierarchical(d) => d.group_of(rank),
        _ => 0,
    }
}

/// Service counters the actor accumulates; exported via the run JSON's
/// `"ps"` block.
#[derive(Debug, Default, Clone, Copy)]
pub struct PsStats {
    pub pushes: u64,
    pub pulls: u64,
    /// Pulls absorbed into an in-flight read window (no extra service
    /// slot consumed).
    pub coalesced: u64,
    /// Primary→secondary weight fan-outs priced through the contention
    /// model.
    pub repl_transfers: u64,
}

impl PsStats {
    pub fn absorb(&mut self, o: &PsStats) {
        self.pushes += o.pushes;
        self.pulls += o.pulls;
        self.coalesced += o.coalesced;
        self.repl_transfers += o.repl_transfers;
    }
}

/// A gradient push from a worker.
struct PushMsg {
    worker: usize,
    grad: Vec<f32>,
    /// Worker's virtual send time.
    sent_at: f64,
    /// Membership epoch at send time (elects the primary).
    epoch: usize,
    /// LR for this update (schedule-resolved by the worker).
    eta: f32,
    wd: f32,
    reply: Sender<PullReply>,
}

/// A weight read (no gradient) — joiner bootstrap and eval refresh.
struct PullMsg {
    worker: usize,
    /// Arrival time at the replica (send time + transfer).
    at: f64,
    /// Serving replica index (client-resolved from the plan).
    replica: usize,
    reply: Sender<PullReply>,
}

/// The server's reply: fresh weights + the virtual time the exchange
/// completed from the worker's perspective.
pub struct PullReply {
    pub weights: Vec<f32>,
    pub done_at: f64,
    /// ‖w_ps − w_bak(worker)‖ *before* this update was applied — the
    /// distance series of experiment E4 (DESIGN.md §5).
    pub staleness_dist: f64,
}

enum Msg {
    Push(PushMsg),
    Pull(PullMsg),
    Stop,
}

/// Handle each worker uses to talk to the PS.
#[derive(Clone)]
pub struct PsClient {
    tx: Sender<Msg>,
    net: NetModel,
    n_params: usize,
    plan: Arc<ReplicaPlan>,
    /// Engine-pool execution gate (see [`crate::exec`]): the blocking
    /// reply wait releases its runnable permit so a worker parked on
    /// the PS never occupies a `--threads` slot. Unlimited by default.
    gate: Arc<Gate>,
}

impl PsClient {
    /// Plug the engine pool's execution [`Gate`] into this client's
    /// blocking reply waits. The PS actor itself is service
    /// infrastructure and stays ungated.
    pub fn set_gate(&mut self, gate: Arc<Gate>) {
        self.gate = gate;
    }

    /// Push a gradient and (blocking) pull fresh weights — the ASGD
    /// round-trip, priced at the dense payload.
    pub fn push_pull(&self, worker: usize, grad: Vec<f32>, now: f64, eta: f32, wd: f32) -> PullReply {
        let n = self.n_params;
        self.push_pull_wire(worker, grad, now, eta, wd, n)
    }

    /// Push a gradient and pull fresh weights with the transfer priced
    /// at `wire_elems` (the codec's compressed volume). `now` is the
    /// worker's virtual time.
    ///
    /// Transfer time is topology-aware: the epoch's primary hosts the
    /// canonical weights, so a worker in the primary's dragonfly group
    /// pays local-link latency while everyone else crosses the optics
    /// — contended by the *actual* concurrent crossings into that
    /// group (the epoch roster's remote members), not a static
    /// worst case.
    pub fn push_pull_wire(
        &self,
        worker: usize,
        grad: Vec<f32>,
        now: f64,
        eta: f32,
        wd: f32,
        wire_elems: usize,
    ) -> PullReply {
        assert_eq!(grad.len(), self.n_params);
        let epoch = self.plan.epoch_at(now);
        let host = self.plan.hosts[self.plan.primary(epoch)];
        let flows = self.plan.push_flows(&self.net, epoch);
        let ptp = self.net.ptp_time_between_flows(worker, host, wire_elems, flows);
        // Worker→PS transfer time happens before the server sees it.
        let arrive = now + ptp;
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Msg::Push(PushMsg {
                worker,
                grad,
                sent_at: arrive,
                epoch,
                eta,
                wd,
                reply: reply_tx,
            }))
            .expect("ps alive");
        // Hand the runnable permit back while blocked on the server.
        self.gate.release();
        let recv = reply_rx.recv();
        self.gate.acquire();
        let mut reply = recv.expect("ps alive");
        // PS→worker transfer for the fresh weights.
        reply.done_at += ptp;
        reply
    }

    /// Read fresh weights without pushing — the joiner-bootstrap /
    /// refresh path, priced at the dense payload.
    pub fn pull(&self, worker: usize, now: f64) -> PullReply {
        let n = self.n_params;
        self.pull_wire(worker, now, n)
    }

    /// Read fresh weights with the transfer priced at `wire_elems`.
    /// Routes to the plan's serving replica for `worker` (group-local
    /// when one exists — zero optic crossings), priced at the actual
    /// crossings sharing that replica's links.
    pub fn pull_wire(&self, worker: usize, now: f64, wire_elems: usize) -> PullReply {
        let epoch = self.plan.epoch_at(now);
        let replica = self.plan.serving_replica(&self.net, worker, epoch);
        let host = self.plan.hosts[replica];
        let flows = self.plan.pull_flows(&self.net, worker, epoch);
        let ptp = self.net.ptp_time_between_flows(worker, host, wire_elems, flows);
        let arrive = now + ptp;
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Msg::Pull(PullMsg { worker, at: arrive, replica, reply: reply_tx }))
            .expect("ps alive");
        self.gate.release();
        let recv = reply_rx.recv();
        self.gate.acquire();
        let mut reply = recv.expect("ps alive");
        reply.done_at += ptp;
        reply
    }
}

/// The running server; join to collect final weights.
pub struct ParameterServer {
    tx: Sender<Msg>,
    handle: JoinHandle<(Vec<f32>, u64, PsStats)>,
    net: NetModel,
    n_params: usize,
    plan: Arc<ReplicaPlan>,
}

impl ParameterServer {
    /// Spawn a single-home PS actor with initial weights, an optimizer
    /// for the update rule `U`, the number of workers, and a
    /// per-request service time (models the PS's CPU/NIC; Eq. 15's
    /// "time spent ... waiting for the PS").
    pub fn spawn(
        init_w: Vec<f32>,
        opt: Box<dyn Optimizer>,
        n_workers: usize,
        mode: PsMode,
        net: NetModel,
        serve_s: f64,
    ) -> Self {
        Self::spawn_replicated(init_w, opt, n_workers, mode, net, serve_s, ReplicaPlan::single_home(n_workers))
    }

    /// Spawn the PS actor under an explicit [`ReplicaPlan`]: per-epoch
    /// primary election, pull routing to replicas, read coalescing and
    /// replication lag all follow the plan. `n_workers` is the
    /// *capacity* — the highest rank (joiners included) plus one.
    pub fn spawn_replicated(
        init_w: Vec<f32>,
        mut opt: Box<dyn Optimizer>,
        n_workers: usize,
        mode: PsMode,
        net: NetModel,
        serve_s: f64,
        plan: ReplicaPlan,
    ) -> Self {
        let n_params = init_w.len();
        assert_eq!(opt.n_params(), n_params);
        let plan = Arc::new(plan);
        let actor_plan = plan.clone();
        let (tx, rx): (Sender<Msg>, Receiver<Msg>) = channel();
        let handle = std::thread::spawn(move || {
            let plan = actor_plan;
            let n_replicas = plan.n_replicas();
            let mut w = init_w;
            // w_bak(i): weights last sent to worker i (DC-ASGD state).
            let mut bak: Vec<Vec<f32>> = (0..n_workers).map(|_| w.clone()).collect();
            // Adaptive-λ second-moment state, per worker.
            let (mut mse, mut pushes_from): (Vec<Vec<f32>>, Vec<u64>) = match mode {
                PsMode::DcAsgdAdaptive { .. } => {
                    ((0..n_workers).map(|_| vec![0.0; n_params]).collect(), vec![0; n_workers])
                }
                _ => (Vec::new(), Vec::new()),
            };
            let mut delta = vec![0.0f32; n_params];
            let mut gtilde = vec![0.0f32; n_params];
            // Per-replica busy-until time (requests serialized at each
            // replica — the many-to-few bottleneck, now ÷ R on reads).
            let mut busy = vec![0.0f64; n_replicas];
            // Per-replica in-flight read window [start, done): pulls
            // landing inside it coalesce into the same service slot.
            let mut read_win = vec![(0.0f64, 0.0f64); n_replicas];
            let mut stats = PsStats::default();
            let mut updates = 0u64;
            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Stop => break,
                    Msg::Push(p) => {
                        let pri = plan.primary(p.epoch);
                        let start = busy[pri].max(p.sent_at);
                        let done = start + serve_s;
                        busy[pri] = done;
                        let staleness_dist = crate::tensor::dist2(&w, &bak[p.worker]);
                        let g = match mode {
                            PsMode::Asgd => &p.grad,
                            PsMode::DcAsgd { lam0 } => {
                                // D = w_ps − w_bak(i)  (Eq. 5/6 with the
                                // PS's and worker's weight copies)
                                let d: Vec<f32> = w
                                    .iter()
                                    .zip(&bak[p.worker])
                                    .map(|(a, b)| a - b)
                                    .collect();
                                let lam = dc::dynamic_lambda(&p.grad, &d, lam0);
                                dc::dc_correct(&p.grad, &d, lam, &mut gtilde);
                                &gtilde
                            }
                            PsMode::DcAsgdAdaptive { lam0 } => {
                                pushes_from[p.worker] += 1;
                                let bias =
                                    1.0 - ADAPTIVE_BETA.powi(pushes_from[p.worker] as i32);
                                let m = &mut mse[p.worker];
                                for i in 0..n_params {
                                    let gi = p.grad[i];
                                    m[i] = ADAPTIVE_BETA * m[i]
                                        + (1.0 - ADAPTIVE_BETA) * gi * gi;
                                    let mse_hat = m[i] / bias;
                                    let lam = lam0 / (mse_hat + ADAPTIVE_EPS).sqrt();
                                    gtilde[i] =
                                        gi + lam * gi * gi * (w[i] - bak[p.worker][i]);
                                }
                                &gtilde
                            }
                        };
                        opt.step(g, &w, p.eta, p.wd, &mut delta);
                        crate::tensor::add_assign(&mut w, &delta);
                        updates += 1;
                        stats.pushes += 1;
                        bak[p.worker].copy_from_slice(&w);
                        // Fan the updated weights to the secondaries
                        // through the contended optics: a secondary
                        // cannot serve past `done + repl` until the
                        // copy lands.
                        if n_replicas > 1 {
                            let src = plan.hosts[pri];
                            let fan = plan
                                .hosts
                                .iter()
                                .enumerate()
                                .filter(|&(j, &h)| {
                                    j != pri && host_group(&net, h) != host_group(&net, src)
                                })
                                .count()
                                .max(1);
                            for (j, &h) in plan.hosts.iter().enumerate() {
                                if j == pri {
                                    continue;
                                }
                                let repl = net.ptp_time_between_flows(src, h, n_params, fan);
                                busy[j] = busy[j].max(done + repl);
                                stats.repl_transfers += 1;
                            }
                        }
                        let _ = p.reply.send(PullReply {
                            weights: w.clone(),
                            done_at: done,
                            staleness_dist,
                        });
                    }
                    Msg::Pull(q) => {
                        let j = q.replica.min(n_replicas - 1);
                        let done = if plan.coalesce
                            && q.at >= read_win[j].0
                            && q.at < read_win[j].1
                        {
                            stats.coalesced += 1;
                            read_win[j].1
                        } else {
                            let start = busy[j].max(q.at);
                            let d = start + serve_s;
                            busy[j] = d;
                            read_win[j] = (start, d);
                            d
                        };
                        stats.pulls += 1;
                        let staleness_dist = crate::tensor::dist2(&w, &bak[q.worker]);
                        // The pull hands the worker fresh weights: its
                        // backup is current again (DC-ASGD semantics).
                        bak[q.worker].copy_from_slice(&w);
                        let _ = q.reply.send(PullReply {
                            weights: w.clone(),
                            done_at: done,
                            staleness_dist,
                        });
                    }
                }
            }
            (w, updates, stats)
        });
        ParameterServer { tx, handle, net, n_params, plan }
    }

    pub fn client(&self) -> PsClient {
        PsClient {
            tx: self.tx.clone(),
            net: self.net,
            n_params: self.n_params,
            plan: self.plan.clone(),
            gate: Gate::unlimited(),
        }
    }

    /// Stop the server and return (final weights, update count).
    pub fn shutdown(self) -> (Vec<f32>, u64) {
        let (w, updates, _) = self.shutdown_full();
        (w, updates)
    }

    /// Stop the server and return (final weights, update count,
    /// service counters).
    pub fn shutdown_full(self) -> (Vec<f32>, u64, PsStats) {
        let _ = self.tx.send(Msg::Stop);
        self.handle.join().expect("ps thread")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NetModel;
    use crate::optim::MomentumSgd;

    fn plain_sgd(n: usize) -> Box<dyn Optimizer> {
        Box::new(MomentumSgd::new(n, 0.0))
    }

    #[test]
    fn asgd_applies_updates_in_arrival_order() {
        let ps = ParameterServer::spawn(
            vec![0.0; 2],
            plain_sgd(2),
            2,
            PsMode::Asgd,
            NetModel::instant(),
            0.0,
        );
        let c = ps.client();
        let r1 = c.push_pull(0, vec![1.0, 0.0], 0.0, 1.0, 0.0);
        assert_eq!(r1.weights, vec![-1.0, 0.0]);
        let r2 = c.push_pull(1, vec![0.0, 2.0], 0.0, 1.0, 0.0);
        assert_eq!(r2.weights, vec![-1.0, -2.0]);
        let (w, n) = ps.shutdown();
        assert_eq!(w, vec![-1.0, -2.0]);
        assert_eq!(n, 2);
    }

    #[test]
    fn service_time_serializes_requests() {
        // Two pushes at t=0 with serve_s = 1: the second completes at 2.
        let ps = ParameterServer::spawn(
            vec![0.0; 1],
            plain_sgd(1),
            2,
            PsMode::Asgd,
            NetModel::instant(),
            1.0,
        );
        let c = ps.client();
        let r1 = c.push_pull(0, vec![0.1], 0.0, 1.0, 0.0);
        let r2 = c.push_pull(1, vec![0.1], 0.0, 1.0, 0.0);
        assert!((r1.done_at - 1.0).abs() < 1e-12);
        assert!((r2.done_at - 2.0).abs() < 1e-12);
        ps.shutdown();
    }

    #[test]
    fn network_time_added_both_ways() {
        let net = NetModel { alpha_s: 0.5, beta_bytes_per_s: f64::INFINITY, algo: crate::comm::AllReduceAlgo::Ring };
        let ps = ParameterServer::spawn(
            vec![0.0; 1],
            plain_sgd(1),
            1,
            PsMode::Asgd,
            net,
            0.0,
        );
        let c = ps.client();
        let r = c.push_pull(0, vec![0.1], 10.0, 1.0, 0.0);
        // 10 + α (push) + 0 (serve) + α (pull) = 11
        assert!((r.done_at - 11.0).abs() < 1e-12, "{}", r.done_at);
        ps.shutdown();
    }

    #[test]
    fn hierarchical_net_penalizes_cross_group_workers() {
        // PS sits with rank 0: a worker in another dragonfly group pays
        // the global link both ways.
        let d = crate::comm::Dragonfly { groups: 2, nodes_per_group: 2, ..Default::default() };
        let net = NetModel {
            algo: crate::comm::AllReduceAlgo::Hierarchical(d),
            ..NetModel::default()
        };
        let ps = ParameterServer::spawn(
            vec![0.0; 1],
            plain_sgd(1),
            4,
            PsMode::Asgd,
            net,
            0.0,
        );
        let c = ps.client();
        let local = c.push_pull(1, vec![0.1], 0.0, 1.0, 0.0).done_at;
        let remote = c.push_pull(2, vec![0.1], 0.0, 1.0, 0.0).done_at;
        assert!(remote > local, "cross-group round-trip {remote} not slower than {local}");
        ps.shutdown();
    }

    #[test]
    fn contended_optics_slow_remote_workers_only() {
        // 2 groups of 2, taper 1: the two remote workers' crossings
        // share one optic (slowdown 2). Same config at taper 2 rides
        // dedicated links — remote round-trips must be strictly slower
        // under contention, local ones identical.
        let run = |taper: usize| {
            let d = crate::comm::Dragonfly {
                groups: 2,
                nodes_per_group: 2,
                global_taper: taper,
                ..Default::default()
            };
            let net = NetModel {
                algo: crate::comm::AllReduceAlgo::Hierarchical(d),
                ..NetModel::default()
            };
            let ps = ParameterServer::spawn(
                vec![0.0; 1000],
                plain_sgd(1000),
                4,
                PsMode::Asgd,
                net,
                0.0,
            );
            let c = ps.client();
            let local = c.push_pull(1, vec![0.1; 1000], 0.0, 1.0, 0.0).done_at;
            let remote = c.push_pull(2, vec![0.1; 1000], 0.0, 1.0, 0.0).done_at;
            ps.shutdown();
            (local, remote)
        };
        let (local_ded, remote_ded) = run(2);
        let (local_con, remote_con) = run(1);
        assert_eq!(local_con, local_ded, "same-group transfers must not contend");
        assert!(
            remote_con > remote_ded,
            "contended crossing {remote_con} not slower than dedicated {remote_ded}"
        );
    }

    #[test]
    fn dcasgd_tracks_backup_distance() {
        let ps = ParameterServer::spawn(
            vec![0.0; 2],
            plain_sgd(2),
            2,
            PsMode::DcAsgd { lam0: 0.2 },
            NetModel::instant(),
            0.0,
        );
        let c = ps.client();
        // worker 0 updates once: its backup is now fresh.
        let r0 = c.push_pull(0, vec![1.0, 1.0], 0.0, 0.5, 0.0);
        assert_eq!(r0.staleness_dist, 0.0); // first push: bak == w
        // worker 1 still has the t=0 backup: distance > 0.
        let r1 = c.push_pull(1, vec![1.0, 1.0], 0.0, 0.5, 0.0);
        assert!(r1.staleness_dist > 0.0);
        // worker 0 pushes again immediately: bak is current ⇒ dist 0 ...
        // but worker 1's update happened in between, so dist > 0 again.
        let r0b = c.push_pull(0, vec![1.0, 1.0], 0.0, 0.5, 0.0);
        assert!(r0b.staleness_dist > 0.0);
        ps.shutdown();
    }

    #[test]
    fn dcasgd_correction_changes_update() {
        // Same gradient stream, with and without compensation, must give
        // different weights once staleness exists.
        let run = |mode| {
            let ps = ParameterServer::spawn(
                vec![0.5; 4],
                plain_sgd(4),
                2,
                mode,
                NetModel::instant(),
                0.0,
            );
            let c = ps.client();
            for it in 0..5 {
                let g = vec![0.1 * (it + 1) as f32; 4];
                c.push_pull(0, g.clone(), it as f64, 0.3, 0.0);
                c.push_pull(1, g, it as f64, 0.3, 0.0);
            }
            ps.shutdown().0
        };
        let plain = run(PsMode::Asgd);
        let comp = run(PsMode::DcAsgd { lam0: 0.2 });
        assert_ne!(plain, comp);
        let adaptive = run(PsMode::DcAsgdAdaptive { lam0: 0.2 });
        assert_ne!(plain, adaptive);
        assert_ne!(comp, adaptive);
    }

    #[test]
    fn adaptive_lambda_matches_hand_rolled_ewma() {
        // One worker, two pushes with staleness injected by a second
        // worker's interleaved update: the server's g̃ must equal the
        // snippet-exact EWMA recurrence computed independently.
        let lam0 = 0.5f32;
        let ps = ParameterServer::spawn(
            vec![0.0; 2],
            plain_sgd(2),
            2,
            PsMode::DcAsgdAdaptive { lam0 },
            NetModel::instant(),
            0.0,
        );
        let c = ps.client();
        // push 1 from worker 0: bak == w, correction is a no-op, and
        // the mirror tracks mse.
        let g1 = [0.3f32, -0.2];
        let r1 = c.push_pull(0, g1.to_vec(), 0.0, 1.0, 0.0);
        // mirror: t=1
        let mut mse = [0.0f32; 2];
        let mut w_mirror = [0.0f32; 2];
        let bak0 = w_mirror;
        for i in 0..2 {
            mse[i] = ADAPTIVE_BETA * mse[i] + (1.0 - ADAPTIVE_BETA) * g1[i] * g1[i];
            let hat = mse[i] / (1.0 - ADAPTIVE_BETA);
            let lam = lam0 / (hat + ADAPTIVE_EPS).sqrt();
            let gt = g1[i] + lam * g1[i] * g1[i] * (w_mirror[i] - bak0[i]);
            w_mirror[i] -= gt;
        }
        assert_eq!(r1.weights, w_mirror.to_vec());
        // worker 1 moves the PS weights: worker 0's backup goes stale.
        let rx = c.push_pull(1, vec![0.1, 0.1], 0.0, 1.0, 0.0);
        let w_after: [f32; 2] = [rx.weights[0], rx.weights[1]];
        let bak_w0: [f32; 2] = w_mirror; // weights last sent to worker 0
        // push 2 from worker 0: correction active, t=2 bias term.
        let g2 = [0.5f32, 0.4];
        let r2 = c.push_pull(0, g2.to_vec(), 0.0, 1.0, 0.0);
        let mut w2 = w_after;
        let bias = 1.0 - ADAPTIVE_BETA * ADAPTIVE_BETA;
        for i in 0..2 {
            mse[i] = ADAPTIVE_BETA * mse[i] + (1.0 - ADAPTIVE_BETA) * g2[i] * g2[i];
            let hat = mse[i] / bias;
            let lam = lam0 / (hat + ADAPTIVE_EPS).sqrt();
            let gt = g2[i] + lam * g2[i] * g2[i] * (w2[i] - bak_w0[i]);
            w2[i] -= gt;
        }
        assert_eq!(r2.weights, w2.to_vec());
        ps.shutdown();
    }

    #[test]
    fn pull_reads_without_updating() {
        let ps = ParameterServer::spawn(
            vec![0.25; 3],
            plain_sgd(3),
            2,
            PsMode::Asgd,
            NetModel::instant(),
            0.0,
        );
        let c = ps.client();
        let r = c.pull(1, 0.0);
        assert_eq!(r.weights, vec![0.25; 3]);
        let (w, updates) = ps.shutdown();
        assert_eq!(w, vec![0.25; 3]);
        assert_eq!(updates, 0, "a pull must not count as an update");
    }

    #[test]
    fn local_pull_prices_cheaper_than_remote() {
        // PR 5 regression: a group-local puller must NOT pay the
        // worst-case remote crossing count — its round trip rides the
        // local link and beats the cross-group one.
        let d = crate::comm::Dragonfly {
            groups: 2,
            nodes_per_group: 2,
            global_taper: 1,
            ..Default::default()
        };
        let net =
            NetModel { algo: crate::comm::AllReduceAlgo::Hierarchical(d), ..NetModel::default() };
        let ps = ParameterServer::spawn(
            vec![0.0; 4096],
            plain_sgd(4096),
            4,
            PsMode::Asgd,
            net,
            0.0,
        );
        let c = ps.client();
        let local = c.pull(1, 0.0).done_at;
        let remote = c.pull(2, 100.0).done_at - 100.0;
        assert!(
            local < remote,
            "group-local pull {local} must beat the cross-group pull {remote}"
        );
        ps.shutdown();
    }

    #[test]
    fn departures_shrink_the_crossing_count() {
        // 3 groups of 2; epoch 1 retires the group-2 pair. The
        // remaining remote worker's crossing shares the taper-1 optic
        // with fewer concurrent flows, so its round trip speeds up —
        // the roster-derived "actual crossings" fix in action.
        let d = crate::comm::Dragonfly {
            groups: 3,
            nodes_per_group: 2,
            global_taper: 1,
            ..Default::default()
        };
        let net =
            NetModel { algo: crate::comm::AllReduceAlgo::Hierarchical(d), ..NetModel::default() };
        let plan = ReplicaPlan {
            hosts: vec![0],
            coalesce: false,
            boundaries: vec![50.0],
            rosters: vec![vec![0, 1, 2, 3, 4, 5], vec![0, 1, 2, 3]],
        };
        let ps = ParameterServer::spawn_replicated(
            vec![0.0; 4096],
            plain_sgd(4096),
            6,
            PsMode::Asgd,
            net,
            0.0,
            plan,
        );
        let c = ps.client();
        let before = c.push_pull(2, vec![0.0; 4096], 0.0, 1.0, 0.0).done_at;
        let after = c.push_pull(2, vec![0.0; 4096], 100.0, 1.0, 0.0).done_at - 100.0;
        assert!(
            after < before,
            "post-departure crossing {after} not cheaper than pre-departure {before}"
        );
        ps.shutdown();
    }

    #[test]
    fn replicated_weights_match_single_home() {
        // The canonical weights live in the shard actor: replication is
        // timing/placement state only, so the update trajectory is
        // bitwise identical to the single-home deployment.
        let d = crate::comm::Dragonfly { groups: 2, nodes_per_group: 2, ..Default::default() };
        let net =
            NetModel { algo: crate::comm::AllReduceAlgo::Hierarchical(d), ..NetModel::default() };
        let run = |plan: ReplicaPlan| {
            let ps = ParameterServer::spawn_replicated(
                vec![0.5; 8],
                plain_sgd(8),
                4,
                PsMode::DcAsgd { lam0: 0.2 },
                net,
                1e-3,
                plan,
            );
            let c = ps.client();
            let mut ws = Vec::new();
            for it in 0..6 {
                let g = vec![0.01 * (it + 1) as f32; 8];
                ws.push(c.push_pull(it % 4, g, it as f64, 0.3, 0.0).weights);
            }
            ps.shutdown();
            ws
        };
        let single = run(ReplicaPlan::single_home(4));
        let replicated = run(ReplicaPlan::place(
            2,
            &net,
            4,
            true,
            Vec::new(),
            vec![vec![0, 1, 2, 3]],
        ));
        assert_eq!(single, replicated, "replication must not perturb the weight trajectory");
    }

    #[test]
    fn replica_serves_local_pulls_and_coalesces() {
        // 2 groups of 2, a replica in each group: group-1 pulls route
        // to the group-1 replica (cheaper than crossing), and two pulls
        // inside one read window consume a single service slot.
        let d = crate::comm::Dragonfly {
            groups: 2,
            nodes_per_group: 2,
            global_taper: 1,
            ..Default::default()
        };
        let net =
            NetModel { algo: crate::comm::AllReduceAlgo::Hierarchical(d), ..NetModel::default() };
        let serve = 0.5;
        let mk = |replicas: usize, coalesce: bool| {
            ParameterServer::spawn_replicated(
                vec![0.0; 2048],
                plain_sgd(2048),
                4,
                PsMode::Asgd,
                net,
                serve,
                ReplicaPlan::place(replicas, &net, 4, coalesce, Vec::new(), vec![vec![0, 1, 2, 3]]),
            )
        };
        // single home: worker 2 crosses the optics for every pull
        let ps1 = mk(1, false);
        let remote = ps1.client().pull(2, 0.0).done_at;
        ps1.shutdown();
        // replicated: worker 2's pull is group-local
        let ps2 = mk(2, true);
        let c = ps2.client();
        let local = c.pull(2, 0.0).done_at;
        assert!(local < remote, "replica-local pull {local} not cheaper than {remote}");
        // a second pull landing inside the first's read window
        // coalesces: same completion, one service slot
        let again = c.pull(3, 0.0).done_at;
        assert!((again - local).abs() < 1e-12, "coalesced pull must share the window");
        let (_, _, stats) = ps2.shutdown_full();
        assert_eq!(stats.pulls, 2);
        assert_eq!(stats.coalesced, 1, "second pull must coalesce");
    }

    #[test]
    fn primary_rotates_with_the_epoch() {
        let plan = ReplicaPlan {
            hosts: vec![0, 2, 4],
            coalesce: false,
            boundaries: vec![10.0, 20.0],
            rosters: vec![vec![0, 1], vec![0, 1], vec![0, 1]],
        };
        assert_eq!(plan.primary(plan.epoch_at(0.0)), 0);
        assert_eq!(plan.primary(plan.epoch_at(10.0)), 1);
        assert_eq!(plan.primary(plan.epoch_at(25.0)), 2);
    }
}
